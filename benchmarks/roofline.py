"""Roofline analysis from the dry-run artifacts (deliverable g).

Reads experiments/dryrun/*.json (launch/dryrun.py) and derives the three
roofline terms per (arch x shape x mesh) against TPU v5e constants:

  compute_s    = HLO_FLOPs_global / (chips * 197 TFLOP/s)
               = per-device HLO flops / 197e12      (SPMD: HLO is per-chip)
  memory_s     = per-device HLO bytes / 819 GB/s
  collective_s = per-device wire bytes / 50 GB/s

FLOPs/bytes/wire come from the trip-count-corrected analyzer
(repro/launch/hlo_cost.py): XLA's own ``cost_analysis()`` counts while-loop
bodies once, undercounting every scanned model by orders of magnitude.

Reported per cell:
  * the three terms and the dominant (= bottleneck) one,
  * MODEL_FLOPS (6*N_active*tokens train / 2*N_active*tokens prefill /
    2*N_active*batch decode) and MODEL_FLOPS / HLO_FLOPs_global — the
    useful-compute ratio (remat, attention, vocab, padding show up here),
  * roofline fraction = ideal_s / bound_s where ideal_s is the physical
    lower bound for the step: compute-limited for train/prefill
    (MODEL_FLOPS at peak), traffic-limited for decode (weights + caches
    must stream from HBM once: argument bytes / HBM bw).
"""

from __future__ import annotations

import glob
import json
import os

PEAK = 197e12  # bf16 FLOP/s per chip
HBM = 819e9  # B/s per chip
LINK = 50e9  # B/s per chip ICI

HERE = os.path.dirname(__file__)
DRYRUN = os.path.join(HERE, "..", "experiments", "dryrun")


def model_flops(rec: dict) -> float:
    n = rec.get("n_params", 0)
    na = rec.get("n_active_params", n)
    b = rec.get("global_batch", 1)
    s = rec.get("seq_len", 1)
    step = rec.get("step")
    if step == "train":
        return 6.0 * na * b * s
    if step == "prefill":
        return 2.0 * na * b * s
    if step == "decode":
        return 2.0 * na * b  # one token per sequence
    return 0.0


def analyse(rec: dict) -> dict:
    dev = rec["n_devices"]
    hc = rec.get("hlo_cost") or {}
    fl = hc.get("flops", rec["cost"]["flops"])  # per-device
    by = hc.get("bytes", rec["cost"]["bytes_accessed"])
    wire = hc.get("collective_wire_bytes",
                  rec["collectives"]["total_wire_bytes"])
    compute_s = fl / PEAK
    memory_s = by / HBM
    coll_s = wire / LINK
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": coll_s}
    dom = max(terms, key=terms.get)
    bound_s = max(terms.values())
    mf = model_flops(rec)
    glob_fl = fl * dev

    if rec.get("step") == "decode":
        # Decode is traffic-limited: weights + caches stream once.
        arg_bytes = rec.get("memory", {}).get("argument_bytes", 0)
        ideal_s = arg_bytes / HBM
    else:
        ideal_s = (mf / dev) / PEAK
    frac = min(1.0, ideal_s / bound_s) if (ideal_s and bound_s) else 0.0

    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "devices": dev,
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": coll_s, "dominant": dom, "bound_s": bound_s,
        "model_flops": mf, "hlo_flops_global": glob_fl,
        "useful_ratio": (mf / glob_fl) if glob_fl else 0.0,
        "ideal_s": ideal_s,
        "roofline_fraction": frac,
    }


def load(out_dir: str = DRYRUN) -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        rec = json.load(open(path))
        if rec.get("status") != "ok":
            continue
        rows.append(analyse(rec))
    return rows


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.1f}us"


def table(rows: list[dict], mesh: str = "single") -> str:
    lines = [
        "| arch | shape | compute | memory | collective | bound | "
        "useful-FLOP ratio | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['compute_s'])} | "
            f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} |"
        )
    return "\n".join(lines)


def run() -> list[dict]:
    rows = load()
    print(f"\nroofline: {len(rows)} compiled cells ({DRYRUN})")
    print(table(rows, "single"))
    from benchmarks import common
    for r in rows:
        if r["mesh"] != "single":
            continue
        common.row(
            "roofline", f"{r['arch']}/{r['shape']}",
            dominant=r["dominant"],
            bound_ms=1e3 * r["bound_s"],
            useful=round(r["useful_ratio"], 3),
            frac=round(r["roofline_fraction"], 3),
        )
    return rows


if __name__ == "__main__":
    run()
