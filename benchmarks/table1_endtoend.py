"""Table I — end-to-end throughput: Fabric 1.2 vs FastFabric.

Paper (15 servers): 3,185 +/- 62 -> 19,112 +/- 811 tx/s (~6x). Single-CPU
absolute numbers differ; the claim validated here is the RATIO between the
two configs under the full client->endorse->order->commit->store flow.
"""

from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import engine

ROUND = 1_000
N_ROUNDS = 3


def run() -> dict:
    out = {}
    for name, cfg in (("fabric-1.2", engine.FABRIC_V12),
                      ("fastfabric", engine.FASTFABRIC)):
        eng = engine.FabricEngine(cfg)
        eng.run_round(eng.make_proposals(ROUND, seed=99))  # warmup/compile
        tps = []
        for i in range(N_ROUNDS):
            stats = eng.run_round(eng.make_proposals(ROUND, seed=i))
            assert stats.n_valid == ROUND
            tps.append(stats.tps)
        verify = eng.verify()
        assert all(verify.values()), verify
        if eng.store:
            eng.store.close()
        out[name] = float(np.mean(tps))
        common.row("table1", name, tps=out[name],
                   std=float(np.std(tps)))
    common.row("table1", "speedup", ratio=out["fastfabric"]
               / out["fabric-1.2"])
    return out


if __name__ == "__main__":
    run()
    common.print_csv()
