"""Table I — end-to-end throughput: Fabric 1.2 vs FastFabric, plus the
multi-channel scale-out rows.

Paper (15 servers): 3,185 +/- 62 -> 19,112 +/- 811 tx/s (~6x). Single-CPU
absolute numbers differ; the claim validated here is the RATIO between the
two configs under the full client->endorse->order->commit->store flow.

FastFabric's deployment unit is the channel and the paper's numbers are
per channel; production deployments multiply throughput by running many.
The multi-channel section commits N independent channels through ONE
mesh dispatch per window (vmapped over the `data` axis, channel 1
resizing its table mid-run) and reports:

  * one row per channel with ``identical`` — the channel's end state
    byte-compared against a single-channel oracle replay (a CONTRACT
    column: the CI artifact assert + perf gate both pin it);
  * an aggregate ``channels_x_tps`` row (the scale-out multiplier);
  * ``fairness/uniform`` and ``fairness/zipf`` rows — min/max
    per-channel TPS ratio under uniform and Zipf-skewed per-channel
    load on the engine round path.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import endorser, engine, types, unmarshal
from repro.launch import fabric_step as fs
from repro.pipeline import engine_bridge

ROUND = 1_000
N_ROUNDS = 3
N_CHANNELS = 2
ZIPF_S = 1.2


def run(quick: bool = False) -> dict:
    from repro.obs import SLOConfig

    out = {}
    n_round = 300 if quick else ROUND
    for name, cfg in (("fabric-1.2", engine.FABRIC_V12),
                      ("fastfabric", engine.FASTFABRIC)):
        # obs on: the row reports the per-tx lifecycle decomposition
        # (queue/order/validate/commit percentiles + a p99 exemplar
        # tx-id) alongside TPS. The SLO latency objective is loosened to
        # compile-noise-proof levels so the health verdict is driven by
        # validity/overflow, the signals this table contracts on.
        eng = engine.FabricEngine(dataclasses.replace(
            cfg, obs=True, slo=SLOConfig(commit_p95_s=60.0)))
        eng.run_round(eng.make_proposals(n_round, seed=99))  # warmup/compile
        tps = []
        for i in range(N_ROUNDS):
            stats = eng.run_round(eng.make_proposals(n_round, seed=i))
            assert stats.n_valid == n_round
            tps.append(stats.tps)
        verify = eng.verify()
        assert all(verify.values()), verify
        health = eng.health().status
        assert health == "healthy", eng.health()
        phase_cols = common.txphase_cols(eng.metrics())
        assert phase_cols.get("p99_exemplar_tx"), \
            "p99 commit bucket carries no exemplar tx-id"
        if eng.store:
            eng.store.close()
        out[name] = float(np.mean(tps))
        common.row("table1", name, tps=out[name],
                   std=float(np.std(tps)), health=health, **phase_cols)
    common.row("table1", "speedup", ratio=out["fastfabric"]
               / out["fabric-1.2"])
    out.update(run_multichannel(quick=quick))
    return out


# ------------------------------------------------ multi-channel rows


def _windows(n_windows, depth, n, seed):
    """Pre-endorsed wire windows for one channel's stream."""
    dims = types.TEST_DIMS
    eng = engine.FabricEngine(
        engine.EngineConfig(dims=dims, store_blocks=False))
    outs = []
    for w in range(n_windows):
        wires, idss = [], []
        for k in range(depth):
            props = eng.make_proposals(n, seed=seed + 31 * (w * depth + k))
            txb = endorser.execute_and_endorse(
                eng.endorser_state, props, dims)
            wires.append(unmarshal.marshal(txb, dims))
            idss.append(txb.tx_id)
            eng.endorser_state = endorser.apply_validated(
                eng.endorser_state, txb, jnp.ones(n, bool))
        outs.append((jnp.stack(wires), jnp.stack(idss)))
    return outs


def run_multichannel(quick: bool = False) -> dict:
    """N channels lockstep through the mesh committer, channel 1 resized
    mid-run; per-channel oracle equivalence + aggregate TPS + fairness."""
    dims = types.TEST_DIMS
    n_dev = len(jax.devices())
    data = 2 if n_dev >= 2 else 1
    model = 2 if n_dev >= 4 else 1
    depth = 2
    n = 64 if quick else 256
    n_windows = 5 if quick else 8
    nb = 512 if quick else 1 << 11
    mesh = jax.make_mesh((data, model), ("data", "model"))
    cfg = fs.FabricStepConfig(shard_state=model > 1, pipeline_depth=depth)
    streams = [_windows(n_windows, depth, n, seed=7 * (c + 1))
               for c in range(N_CHANNELS)]

    live = engine_bridge.MeshWindowCommitter(
        dims, cfg, mesh, n_buckets=nb, slots=8, n_channels=N_CHANNELS)
    valid_live = []

    def commit(w):
        wires = jnp.stack([s[w][0] for s in streams])
        ids = jnp.stack([s[w][1] for s in streams])
        valid_live.append(live.commit_windows(wires, ids).valid)

    # Windows 0-1 at the initial layout, resize channel 1, window 2
    # compiles the post-resize grouping; windows 3.. are the timed
    # steady state.
    for w in range(2):
        commit(w)
    live.resize(2 * nb, channel=1)
    commit(2)
    live.block_until_ready()
    t0 = time.perf_counter()
    for w in range(3, n_windows):
        commit(w)
    live.block_until_ready()
    wall = time.perf_counter() - t0
    timed_txs = (n_windows - 3) * depth * n

    out = {}
    per_channel_tps = []
    for c, wins in enumerate(streams):
        oracle = engine_bridge.MeshWindowCommitter(
            dims, cfg, mesh, n_buckets=nb, slots=8)
        ident = True
        for w in range(n_windows):
            if c == 1 and w == 2:
                oracle.resize(2 * nb)
            v = oracle.commit_window(*wins[w]).valid
            ident &= bool(
                np.array_equal(np.asarray(v), np.asarray(valid_live[w][c])))
        for a, b in zip(live.channel_state(c), oracle.state):
            ident &= bool(np.array_equal(np.asarray(a), np.asarray(b)))
        ident &= bool(np.array_equal(live.tree_head(c), oracle.tree_head()))
        ident &= bool(np.array_equal(
            live.journal_head_for(c), np.asarray(oracle.journal_head)))
        ident &= live.overflow_bits_for(c) == oracle.overflow_bits
        tps_c = timed_txs / wall
        per_channel_tps.append(tps_c)
        out[f"channel{c}"] = ident
        common.row("table1", f"channel{c}", tps=tps_c, identical=ident,
                   n_buckets=live.n_buckets_for(c))
    agg = float(np.sum(per_channel_tps))
    common.row("table1", "channels_x_tps", tps=agg,
               n_channels=N_CHANNELS, data_ranks=data,
               fairness=float(np.min(per_channel_tps)
                              / np.max(per_channel_tps)))
    out["channels_x_tps"] = agg

    out["fairness/uniform"] = _fairness_row(
        "uniform", [128] * 4, quick=quick)
    weights = np.array([(c + 1) ** -ZIPF_S for c in range(4)])
    total = 512
    sizes = np.maximum(32, (total * weights / weights.sum())
                       // 32 * 32).astype(int)
    out["fairness/zipf"] = _fairness_row(
        "zipf", [int(s) for s in sizes], quick=quick, skew=ZIPF_S)
    return out


def _fairness_row(label, sizes, quick=False, **extra) -> float:
    """Min/max per-channel TPS ratio on the engine round path (lockstep
    rounds share one wall clock, so the ratio is the per-channel load
    the round actually retired)."""
    eng = engine.FabricEngine(engine.EngineConfig(
        dims=types.TEST_DIMS,
        orderer=dataclasses.replace(engine.FASTFABRIC.orderer,
                                    block_size=32),
        store_blocks=False, n_channels=len(sizes),
    ))
    mk = lambda r: [eng.make_proposals(s, seed=100 * r + c)
                    for c, s in enumerate(sizes)]
    eng.run_rounds(mk(99))  # warmup/compile
    n_rounds = 2 if quick else 4
    txs = np.zeros(len(sizes))
    wall = 0.0
    for r in range(n_rounds):
        stats = eng.run_rounds(mk(r))
        wall += stats[0].wall_s
        for c, s in enumerate(stats):
            txs[c] += s.n_txs
    tps = txs / wall
    fair = float(tps.min() / tps.max())
    common.row("table1", f"fairness/{label}", tps=float(tps.sum()),
               fairness=fair, n_channels=len(sizes),
               load=":".join(str(s) for s in sizes), **extra)
    return fair


if __name__ == "__main__":
    run()
    common.print_csv()
