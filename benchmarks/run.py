"""Benchmark suite entrypoint — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig4,table1] \
        [--json out/bench.json]

Prints per-benchmark rows as they complete and a final CSV (optionally a
JSON dump — CI uploads it as an artifact to track the perf trajectory per
PR). The roofline section summarizes the dry-run artifacts if present (run
``python -m repro.launch.dryrun --all --fabric`` first to regenerate).
"""

from __future__ import annotations

import argparse
import time

from benchmarks import common

ALL = ("fig3", "fig4", "fig5_6", "fig7", "fig8", "fig9", "fig10", "fig11",
       "fig12", "table1", "roofline")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(ALL))
    ap.add_argument("--json", default=None,
                    help="write all result rows as JSON to this path")
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized figures (currently scales fig11 down "
                         "to a smoke run; other figures keep defaults)")
    ap.add_argument("--obs-dir", default=None,
                    help="dump fig11's obs trace + metrics snapshot here "
                         "(trace.jsonl / trace_chrome.json / metrics.json)")
    args = ap.parse_args()
    which = args.only.split(",") if args.only else list(ALL)

    t0 = time.time()
    if "fig3" in which:
        from benchmarks import fig3_transfer
        print("== Fig 3: block transfer (network is not the bottleneck) ==")
        fig3_transfer.run()
    if "fig4" in which:
        from benchmarks import fig4_orderer
        print("== Fig 4: orderer TPS vs payload size ==")
        fig4_orderer.run()
    if "fig5_6" in which:
        from benchmarks import fig5_6_peer
        print("== Fig 5/6: peer latency & throughput, opts stacked ==")
        fig5_6_peer.run()
    if "fig7" in which:
        from benchmarks import fig7_sensitivity
        print("== Fig 7: parallelism sensitivity ==")
        fig7_sensitivity.run()
    if "fig8" in which:
        from benchmarks import fig8_blocksize
        print("== Fig 8: block size scan ==")
        fig8_blocksize.run()
    if "fig9" in which:
        from benchmarks import fig9_recovery
        print("== Fig 9: crash recovery (replay vs snapshot+journal) ==")
        fig9_recovery.main([])
    if "fig10" in which:
        from benchmarks import fig10_state_scaling
        print("== Fig 10: model-axis sharded world state ==")
        fig10_state_scaling.main([])
    if "fig11" in which:
        from benchmarks import fig11_pipeline
        print("== Fig 11: device-side block pipeline ==")
        # --quick keeps the full depth sweep (the CI artifact asserts the
        # fused commit at depth 8) on a small block/table size.
        fig11_args = (
            ["--depths", "1", "2", "8", "--b-round", "32",
             "--n-buckets", "1024", "--iters", "1"] if args.quick else []
        )
        if args.obs_dir:
            fig11_args += ["--obs-dir", args.obs_dir]
        fig11_pipeline.main(fig11_args)
    if "fig12" in which:
        from benchmarks import fig12_rebalance
        print("== Fig 12: elastic state (overflow-driven shard split) ==")
        # --quick shrinks the sweep but keeps the static-overflows /
        # elastic-stays-healthy contrast the CI artifact asserts.
        fig12_rebalance.main(
            ["--rounds", "10", "--round-txs", "50", "--n-buckets", "128",
             "--slots", "8", "--n-shards", "2", "--grow-free-slots", "4"]
            if args.quick else []
        )
    if "table1" in which:
        from benchmarks import table1_endtoend
        print("== Table I: end-to-end + multi-channel scale-out ==")
        # --quick shrinks round/window sizes but keeps every multi-channel
        # contract row (per-channel identical, channels_x_tps aggregate,
        # fairness under uniform + Zipf load) the CI artifact asserts.
        table1_endtoend.run(quick=args.quick)
    if "roofline" in which:
        from benchmarks import roofline
        print("== Roofline (from dry-run artifacts) ==")
        try:
            roofline.run()
        except Exception as e:  # dry-run artifacts absent
            print(f"  (skipped: {e})")

    print(f"\n== CSV ({time.time() - t0:.0f}s total) ==")
    common.print_csv()
    if args.json:
        common.dump_json(args.json)
        print(f"rows written to {args.json}")


if __name__ == "__main__":
    main()
