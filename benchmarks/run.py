"""Benchmark suite entrypoint — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig4,table1]

Prints per-benchmark rows as they complete and a final CSV. The roofline
section summarizes the dry-run artifacts if present (run
``python -m repro.launch.dryrun --all --fabric`` first to regenerate).
"""

from __future__ import annotations

import argparse
import time

from benchmarks import common

ALL = ("fig3", "fig4", "fig5_6", "fig7", "fig8", "table1", "roofline")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(ALL))
    args = ap.parse_args()
    which = args.only.split(",") if args.only else list(ALL)

    t0 = time.time()
    if "fig3" in which:
        from benchmarks import fig3_transfer
        print("== Fig 3: block transfer (network is not the bottleneck) ==")
        fig3_transfer.run()
    if "fig4" in which:
        from benchmarks import fig4_orderer
        print("== Fig 4: orderer TPS vs payload size ==")
        fig4_orderer.run()
    if "fig5_6" in which:
        from benchmarks import fig5_6_peer
        print("== Fig 5/6: peer latency & throughput, opts stacked ==")
        fig5_6_peer.run()
    if "fig7" in which:
        from benchmarks import fig7_sensitivity
        print("== Fig 7: parallelism sensitivity ==")
        fig7_sensitivity.run()
    if "fig8" in which:
        from benchmarks import fig8_blocksize
        print("== Fig 8: block size scan ==")
        fig8_blocksize.run()
    if "table1" in which:
        from benchmarks import table1_endtoend
        print("== Table I: end-to-end ==")
        table1_endtoend.run()
    if "roofline" in which:
        from benchmarks import roofline
        print("== Roofline (from dry-run artifacts) ==")
        try:
            roofline.run()
        except Exception as e:  # dry-run artifacts absent
            print(f"  (skipped: {e})")

    print(f"\n== CSV ({time.time() - t0:.0f}s total) ==")
    common.print_csv()


if __name__ == "__main__":
    main()
