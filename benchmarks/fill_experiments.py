"""Fill the generated tables in EXPERIMENTS.md from experiment artifacts."""

from __future__ import annotations

import json
import os

from benchmarks import roofline

HERE = os.path.dirname(__file__)
EXP = os.path.join(HERE, "..", "EXPERIMENTS.md")
OPT = os.path.abspath(os.path.join(HERE, "..", "experiments", "optimized"))


def memory_rows() -> str:
    rows = []
    for f in sorted(os.listdir(roofline.DRYRUN)):
        if not f.endswith("__single.json"):
            continue
        b = json.load(open(os.path.join(roofline.DRYRUN, f)))
        if b.get("status") != "ok":
            continue
        def footprint(m):
            # Donated buffers appear in both args and outputs; alias
            # subtracts the double count.
            return (m.get("argument_bytes", 0) + m.get("temp_bytes", 0)
                    + m.get("output_bytes", 0)
                    - m.get("alias_bytes", 0)) / 1e9

        tot_b = footprint(b.get("memory", {}))
        o_path = os.path.join(OPT, f)
        tot_o = None
        if os.path.exists(o_path):
            o = json.load(open(o_path))
            tot_o = footprint(o.get("memory", {}))
        if tot_b > 16 or (tot_o or 0) > 16:
            fit_o = (f"{tot_o:.1f} GB" if tot_o is not None else "—")
            rows.append(
                f"| {b['arch']} / {b['shape']} | {tot_b:.1f} GB "
                f"{'(OVER)' if tot_b > 16 else ''} | {fit_o} "
                f"{'(OVER)' if (tot_o or 0) > 16 else ''} |"
            )
    return "\n".join(rows) if rows else "| (all cells < 16 GB) | | |"


def summary() -> str:
    base = {f"{r['arch']}/{r['shape']}": r for r in roofline.load()}
    opt = {f"{r['arch']}/{r['shape']}": r for r in roofline.load(OPT)}
    tot_b = tot_o = 0.0
    improved = 0
    for k, o in opt.items():
        b = base.get(k)
        if not b:
            continue
        tot_b += b["bound_s"]
        tot_o += o["bound_s"]
        if o["bound_s"] < b["bound_s"] * 0.95:
            improved += 1
    return (
        f"**{improved}/{len(opt)} cells improve >5%; the summed bound over "
        f"all 32 single-pod cells drops {tot_b:.0f}s -> {tot_o:.0f}s "
        f"({100 * (1 - tot_o / tot_b):.0f}% lower).** Decode cells are "
        "unchanged by design (already at their streaming roofline after "
        "§Perf iteration 1)."
    )


def _splice(text: str, tag: str, body: str) -> str:
    import re

    start, end = f"<!-- {tag}_START -->", f"<!-- {tag}_END -->"
    pat = re.compile(re.escape(start) + r".*?" + re.escape(end), re.S)
    return pat.sub(start + "\n" + body + "\n" + end, text)


def main() -> None:
    text = open(EXP).read()
    text = _splice(text, "BASELINE", roofline.table(roofline.load(),
                                                    "single"))
    text = _splice(text, "OPTIMIZED", roofline.table(roofline.load(OPT),
                                                     "single"))
    text = _splice(text, "SUMMARY", summary())
    text = _splice(text, "MEMORY", memory_rows())
    open(EXP, "w").write(text)
    print("EXPERIMENTS.md tables filled")


if __name__ == "__main__":
    main()
