"""Fig 5/6 — peer block latency and throughput, optimizations stacked.

Paper (blocks of 100, isolated peer; endorsement/storage mocked):
  Fabric 1.2 ~3.2k tx/s -> P-I (hash state) ~7.5k -> P-II (parallel
  validation + role offload) ~9.5k -> P-III (unmarshal cache) ~21k, while
  block latency drops to a third. We run the same stacking: pre-built
  blocks straight into the committer, block store discarded.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks import common
from repro.core import committer, types
from repro.launch import hlo_cost

DIMS = types.PAPER_DIMS
BS = 100
N_BLOCKS = 24

CONFIGS = [
    ("fabric-1.2", committer.FABRIC_V12_PEER),
    ("P-I", committer.OPT_P1),
    ("P-I+II", committer.OPT_P2),
    ("P-I+II+III", committer.OPT_P3),
]


def _blocks(seed=0):
    outs = []
    for i in range(N_BLOCKS):
        wire, ids, _ = common.make_endorsed_wire(DIMS, BS, seed=100 + i)
        outs.append((wire, np.asarray(ids)))
    return outs


def _compiled_flops(pcfg, wire) -> float:
    """Total compiled HLO flops for one block under this config (sums the
    three stage programs for the non-cached paths). On TPU this is the
    dispatch-level work P-III removes; CPU wall-clock partially hides it."""
    import jax

    state = committer.create_peer_state(DIMS, n_buckets=1 << 12)
    ok = jax.numpy.ones((wire.shape[0],), bool)
    total = 0.0
    if pcfg.cache:
        low = jax.jit(
            lambda s, w: committer.commit_block_fused(s, w, DIMS, pcfg)
        ).lower(state, wire)
        total += hlo_cost.cost_dict(low.compile()).get("flops", 0.0)
    else:
        for lowered in (
            jax.jit(lambda w: committer.stage_syntax(w, DIMS)).lower(wire),
            jax.jit(lambda w: committer.stage_endorse(
                w, DIMS, pcfg.parallel, pcfg.tx_par)).lower(wire),
            jax.jit(lambda s, w, a, b: committer.stage_mvcc_commit(
                s, w, a, b, DIMS, pcfg.hash_state, pcfg.sequential_commit,
                pcfg.journal)
            ).lower(state, wire, ok, ok),
        ):
            total += hlo_cost.cost_dict(
                lowered.compile()).get("flops", 0.0)
    return total


def run() -> None:
    from repro.obs.metrics import Registry
    from repro.obs.txtrace import TxTracer

    blocks = _blocks()
    for name, pcfg in CONFIGS:
        # Per-config tx-lifecycle tracing: each block's txs get phase
        # stamps on the loop's EXISTING sync edges (block_until_ready on
        # the chain hash), so the decomposition columns ride the same
        # measurement the latency numbers come from. No ordering service
        # here (pre-built blocks straight into the committer), so queue/
        # order are ~0 and validate carries the block pipeline.
        reg = Registry()
        tt = TxTracer(reg)
        # fresh state per config; same blocks
        state = committer.create_peer_state(DIMS, n_buckets=1 << 12)
        # warmup/compile on a copy of block 0
        r = committer.commit_block(state, blocks[0][0], DIMS, pcfg)
        jax.block_until_ready(r.block_hash)
        state = r.state

        # --- latency: one block, synchronous (Fig 5) ---
        lat = []
        for bno, (b, ids) in enumerate(blocks[1:8], start=1):
            rt = tt.begin_round(0, ids, BS, bno)
            rt.order_start()
            rt.ordered()
            t0 = time.perf_counter()
            r = committer.commit_block(state, b, DIMS, pcfg)
            jax.block_until_ready(r.block_hash)
            rt.validated(0, 1)
            lat.append(time.perf_counter() - t0)
            state = r.state
            rt.committed()
            rt.finish(None)

        # --- throughput: pipelined stream (Fig 6) ---
        n_blocks = N_BLOCKS - 8
        reg6 = Registry()
        tt6 = TxTracer(reg6)
        rt6 = tt6.begin_round(
            0, np.concatenate([ids for _, ids in blocks[8:]]), BS, 8)
        depth = max(pcfg.pipeline_depth, 1)
        rt6.order_start()
        rt6.ordered()
        t0 = time.perf_counter()
        hashes = []
        retired = 0
        for b, _ in blocks[8:]:
            r = committer.commit_block(state, b, DIMS, pcfg)
            state = r.state
            hashes.append(r.block_hash)  # async dispatch: keep depth blocks
            if len(hashes) > depth:
                jax.block_until_ready(hashes.pop(0))
                rt6.validated(retired, retired + 1)
                retired += 1
        jax.block_until_ready(hashes)
        rt6.validated(retired, n_blocks)
        dt = time.perf_counter() - t0
        rt6.committed()
        rt6.finish(None)
        n = n_blocks * BS
        # Percentiles of the synchronous per-block commits, through the
        # same log2 histogram the engine registry uses (common.latency_hist).
        lat_cols = common.percentile_cols(common.latency_hist(lat))
        common.row("fig5", f"{name}", block_latency_ms=1e3 * float(
            np.median(lat)), **lat_cols,
            **common.txphase_cols(reg.collect()))
        # Pipelined blocks retire together — amortized per-block latency,
        # recorded once per block (the engine's round.commit does the same).
        tput_cols = common.percentile_cols(
            common.latency_hist([dt / n_blocks] * n_blocks))
        common.row("fig6", f"{name}", tps=n / dt,
                   hlo_flops_per_block=_compiled_flops(pcfg, blocks[0][0]),
                   **tput_cols, **common.txphase_cols(reg6.collect()))


if __name__ == "__main__":
    run()
    common.print_csv()
