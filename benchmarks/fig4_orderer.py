"""Fig 4 — orderer throughput vs payload size.

Paper: Fabric 1.2 orderer TPS falls with payload size (whole txs through
Kafka); O-I (IDs only into consensus) nearly flattens the curve; O-II
(pipelined admission) adds a further constant factor. We sweep payload
sizes 512B/1KB/2KB/4KB x {fabric-1.2, O-I, O-I+O-II} through the isolated
orderer (blocks discarded, as in the paper's orderer-only experiment).
"""

from __future__ import annotations

import dataclasses

import jax

from benchmarks import common
from repro.core import orderer, types

N = 2_000
CONFIGS = [
    ("fabric-1.2", orderer.OrdererConfig(separate_metadata=False,
                                         pipelined=False)),
    ("O-I", orderer.OrdererConfig(separate_metadata=True, pipelined=False)),
    ("O-I+O-II", orderer.OrdererConfig(separate_metadata=True,
                                       pipelined=True)),
]


def run() -> None:
    for payload_bytes in (512, 1024, 2048, 4096):
        dims = dataclasses.replace(types.PAPER_DIMS,
                                   payload_words=payload_bytes // 4)
        wire, ids, clients = common.make_endorsed_wire(dims, N, seed=1)
        head = jax.numpy.zeros((2,), jax.numpy.uint32)
        for name, ocfg in CONFIGS:
            ocfg = dataclasses.replace(ocfg, block_size=100)

            def order_once():
                return orderer.order_batch_jit(wire, ids, clients, head,
                                               ocfg)

            dt = common.timed(order_once, warmup=1, iters=3)
            common.row("fig4", f"{name}@{payload_bytes}B", tps=N / dt,
                       payload=payload_bytes)


if __name__ == "__main__":
    run()
    common.print_csv()
