"""Shared benchmark helpers: timing, workload construction, CSV rows."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import endorser, engine, types, unmarshal

ROWS: list[dict] = []


def row(bench: str, name: str, tps: float = None, **extra) -> dict:
    r = {"bench": bench, "name": name, "tps": tps, **extra}
    ROWS.append(r)
    keys = [k for k in ("tps", *extra.keys()) if r.get(k) is not None]

    def fmt(v):
        if isinstance(v, float) and abs(v) < 100:
            return f"{v:.3g}"
        if isinstance(v, (int, float)):
            return f"{v:,.0f}"
        return str(v)

    body = " ".join(f"{k}={fmt(r[k])}" for k in keys)
    print(f"  {bench:14s} {name:28s} {body}")
    return r


def print_csv() -> None:
    cols = sorted({k for r in ROWS for k in r})
    print(",".join(cols))
    for r in ROWS:
        print(",".join(str(r.get(c, "")) for c in cols))


def dump_json(path: str) -> None:
    """Write the collected rows as JSON (CI uploads these as artifacts so
    the per-PR perf trajectory is tracked)."""
    import json
    import os

    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)

    def clean(v):
        if isinstance(v, (np.integer,)):
            return int(v)
        if isinstance(v, (np.floating,)):
            return float(v)
        if isinstance(v, (np.bool_,)):
            return bool(v)
        return v

    with open(path, "w") as f:
        json.dump([{k: clean(v) for k, v in r.items()} for r in ROWS],
                  f, indent=1)


def make_endorsed_wire(dims: types.FabricDims, n: int, *, seed: int = 0,
                       state=None):
    """N endorsed transfer txs, marshaled. Returns (wire, tx_ids, clients)."""
    from repro.core import world_state as ws

    if state is None:
        state = ws.create(1 << 10, 8, dims.vw)
    rng = np.random.default_rng(seed)
    n_acct = max(2 * n, 4)
    perm = rng.permutation(n_acct)[: 2 * n].astype(np.uint32)
    props = endorser.Proposal(
        src=jnp.asarray(perm[:n]),
        dst=jnp.asarray(perm[n:]),
        amount=jnp.asarray(rng.integers(1, 1000, n, dtype=np.uint32)),
        client=jnp.asarray(rng.integers(0, 64, n, dtype=np.uint32)),
        nonce=jnp.arange(n, dtype=jnp.uint32),
    )
    txb = endorser.execute_and_endorse(state, props, dims)
    wire = unmarshal.marshal(txb, dims)
    return jax.block_until_ready(wire), txb.tx_id, txb.client


def timed_samples(fn, *args, warmup: int = 1, iters: int = 3) -> list[float]:
    """Wall-time samples of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return ts


def timed(fn, *args, warmup: int = 1, iters: int = 3):
    """Median wall time of fn(*args) with block_until_ready."""
    return float(np.median(timed_samples(fn, *args, warmup=warmup,
                                         iters=iters)))


def latency_hist(samples):
    """Fold wall-clock samples (seconds) into a repro.obs Histogram —
    benchmarks report percentiles through the same fixed-bucket log2
    semantics the engine registry uses, so rows and live metrics agree."""
    from repro.obs.metrics import Histogram

    h = Histogram()
    for s in samples:
        h.record(float(s))
    return h


def percentile_cols(hist, prefix: str = "commit") -> dict:
    """p50/p95/p99 columns (ms) from an obs Histogram."""
    return {
        f"{prefix}_p50_ms": 1e3 * hist.percentile(50),
        f"{prefix}_p95_ms": 1e3 * hist.percentile(95),
        f"{prefix}_p99_ms": 1e3 * hist.percentile(99),
    }


def metrics_cols(collected: dict, name: str = "commit.latency",
                 prefix: str = "commit") -> dict:
    """Absorb one histogram out of a ``Registry.collect()`` snapshot into
    row columns (p50/p95/p99 in ms + count). Empty when the metric is
    absent (obs off or the path never recorded)."""
    snap = collected.get(name)
    if not snap or not snap.get("count"):
        return {}
    return {
        f"{prefix}_p50_ms": 1e3 * snap["p50"],
        f"{prefix}_p95_ms": 1e3 * snap["p95"],
        f"{prefix}_p99_ms": 1e3 * snap["p99"],
        f"{prefix}_n": snap["count"],
    }


def txphase_cols(collected: dict) -> dict:
    """Per-tx lifecycle decomposition columns out of a registry snapshot:
    p50/p95/p99 (ms) for each tx.phase.* histogram plus tx.e2e, and the
    p99 commit bucket's most recent exemplar tx-id — a p99 row always
    names a concrete transaction (repro.obs.txtrace's contract). Columns
    are ``tx_``-prefixed: the plain ``commit_*`` columns are the
    round-level commit latency, a different measurement. Empty when tx
    tracing never ran (obs off)."""
    out = {}
    for p in ("queue", "order", "validate", "commit"):
        cols = metrics_cols(collected, f"tx.phase.{p}", f"tx_{p}")
        cols.pop(f"tx_{p}_n", None)  # every phase shares the e2e count
        out.update(cols)
    out.update(metrics_cols(collected, "tx.e2e", "tx_e2e"))
    ex = (collected.get("tx.phase.commit") or {}).get("p99_exemplars")
    if ex:
        out["p99_exemplar_tx"] = ex[-1]["tx_id"]
    return out
