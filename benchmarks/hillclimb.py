"""§Perf hillclimb driver: re-lower selected cells with candidate changes
and report the roofline-term deltas vs the baseline dry-run.

    PYTHONPATH=src python -m benchmarks.hillclimb --cell qwen2-7b/train_4k \
        --variant shard_acts

Variants are named knob bundles; results land in experiments/perf/ and the
iteration log goes into EXPERIMENTS.md §Perf by hand (hypothesis -> change
-> before -> after -> verdict).
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402

from repro.configs import shapes as shp  # noqa: E402
from repro.launch import dryrun, hlo_cost, mesh as mesh_lib  # noqa: E402

PERF_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                        "perf")

VARIANTS = {
    # iteration 2 (iteration 1 — grouped-attention decode — is already the
    # baseline; see EXPERIMENTS.md §Perf)
    "shard_acts": {"shard_acts": True},  # also covers prefill paths now
    "chunked_attn": {"attn_impl": "chunked", "q_chunk": 1024},
    "shard_acts+chunked": {"shard_acts": True, "attn_impl": "chunked",
                           "q_chunk": 1024},
    "shard_acts+dots": {"shard_acts": True, "remat": "dots"},
    "moe_cumsum": {"moe_dispatch": "cumsum"},
    "moe_cumsum+shard": {"moe_dispatch": "cumsum", "shard_acts": True},
    "moe_grouped": {"moe_dispatch": "cumsum", "moe_groups": "dp",
                    "shard_acts": True},
    "moe_all": {"moe_dispatch": "cumsum", "shard_acts": True,
                "attn_impl": "chunked", "q_chunk": 1024},
}


def run_variant(arch: str, shape_name: str, variant: str,
                mesh_name: str = "single", force: bool = False) -> dict:
    out = os.path.abspath(PERF_DIR)
    os.makedirs(out, exist_ok=True)
    path = os.path.join(out,
                        f"{arch}__{shape_name}__{mesh_name}__{variant}.json")
    if os.path.exists(path) and not force:
        return json.load(open(path))
    mesh = mesh_lib.make_production_mesh(multi_pod=(mesh_name == "multi"))
    shape = shp.SHAPES_BY_NAME[shape_name]
    t0 = time.time()
    with mesh:
        lowered, meta = dryrun.lower_cell(arch, shape, mesh,
                                          variant=VARIANTS[variant])
        compiled = lowered.compile()
        hlo = compiled.as_text()
        tc = hlo_cost.analyze(hlo)
        mem = compiled.memory_analysis()
    rec = {
        **meta, "mesh": mesh_name, "n_devices": mesh.size, "status": "ok",
        "variant": variant, "compile_s": round(time.time() - t0, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
        },
        "hlo_cost": tc,
        "cost": {"flops": 0, "bytes_accessed": 0},
        "collectives": {"total_wire_bytes": tc["collective_wire_bytes"]},
    }
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    import gzip
    with gzip.open(path.replace(".json", ".hlo.gz"), "wt") as f:
        f.write(hlo)
    return rec


def compare(arch: str, shape_name: str, variant_rec: dict) -> None:
    from benchmarks import roofline
    base_path = os.path.join(
        os.path.dirname(__file__), "..", "experiments", "dryrun",
        f"{arch}__{shape_name}__single.json")
    base = roofline.analyse(json.load(open(base_path)))
    var = roofline.analyse(variant_rec)
    print(f"\n{arch}/{shape_name} — variant {variant_rec['variant']}:")
    for k in ("compute_s", "memory_s", "collective_s", "bound_s",
              "roofline_fraction"):
        b, v = base[k], var[k]
        delta = (v - b) / b * 100 if b else float("nan")
        print(f"  {k:18s} {roofline.fmt_s(b) if k != 'roofline_fraction' else f'{b:.3f}':>10s}"
              f" -> {roofline.fmt_s(v) if k != 'roofline_fraction' else f'{v:.3f}':>10s}"
              f"  ({delta:+.1f}%)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="arch/shape")
    ap.add_argument("--variant", required=True, choices=sorted(VARIANTS))
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    arch, shape_name = args.cell.split("/")
    rec = run_variant(arch, shape_name, args.variant, args.mesh,
                      force=args.force)
    compare(arch, shape_name, rec)


if __name__ == "__main__":
    main()
