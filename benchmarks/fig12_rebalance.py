"""Fig 12 (beyond-paper) — elastic sharded world state under fill pressure.

A FastFabric peer's in-memory table is a hard capacity wall: fill it and
the channel fail-stops (PR 4 made that overflow exact and observable; this
figure makes it *elastic*). The workload keeps inserting fresh accounts
round after round — a fill-until-overflow sweep:

  * ``static/round=k``  — TPS per round on a fixed table; the sticky
    overflow flag latches once a bucket fills and the peer reports
    unhealthy forever after;
  * ``elastic/round=k`` — same workload with a between-rounds
    ``ResizePolicy``: bucket pressure (min free slots) halves/doubles the
    table, each resize committed to the journal as a re-anchor record
    (``n_buckets`` column shows the growth; ``resized`` marks the epochs);
  * ``static/final`` / ``elastic/final`` — end-of-run health: the CI
    artifact asserts ``overflow_ok`` is False for static and True for
    elastic ON THE SAME WORKLOAD — the split absorbed a load that
    overflows without it;
  * ``equivalence/elastic`` — the elastic peer's final state content
    equals an oracle that ran the whole workload on the FINAL layout from
    block 0 (the resize-epoch exactness the tests pin byte-for-byte);
  * ``recovery/full`` and ``recovery/shard=m`` — restart cost from the
    per-shard snapshot + journal suffix across the re-anchors: the full
    merged recovery vs one bucket shard alone (``parts`` counts snapshot
    shard files read — a shard recovers from 2^epochs parts, not the
    whole table).

Run:  PYTHONPATH=src python -m benchmarks.fig12_rebalance
"""

from __future__ import annotations

import argparse
import dataclasses
import tempfile
import time

import numpy as np

from benchmarks import common
from repro.core import engine, types
from repro.core import world_state as ws
from repro.obs import health as obs_health
from repro.storage import recovery, snapshot


def _mk_engine(policy, n_buckets, slots, block_size, *, n_shards=1,
               snapshot_every=0, snapshot_dir=None, journal_dir=None):
    cfg = engine.EngineConfig(
        dims=types.TEST_DIMS,
        orderer=dataclasses.replace(
            engine.FASTFABRIC.orderer, block_size=block_size
        ),
        n_buckets=n_buckets,
        slots=slots,
        resize_policy=policy,
        snapshot_shards=n_shards,
        snapshot_every_blocks=snapshot_every,
        snapshot_dir=snapshot_dir,
        journal_dir=journal_dir,
        obs=True,  # per-engine registry: commit latency + resize events
        # Health verdicts here contract on overflow/occupancy, not wall
        # clock: a compile-noise-proof latency objective keeps the
        # static-critical / elastic-healthy contrast deterministic.
        slo=obs_health.SLOConfig(commit_p95_s=60.0),
    )
    return engine.FabricEngine(cfg)


def run(rounds: int, round_txs: int, n_buckets: int, slots: int,
        n_shards: int, grow_free_slots: int) -> None:
    policy = engine.ResizePolicy(grow_free_slots=grow_free_slots)
    block_size = round_txs
    static = _mk_engine(None, n_buckets, slots, block_size)
    with tempfile.TemporaryDirectory() as snapd, \
            tempfile.TemporaryDirectory() as jrnd:
        elastic = _mk_engine(
            policy, n_buckets, slots, block_size, n_shards=n_shards,
            snapshot_every=max(rounds // 2, 1), snapshot_dir=snapd,
            journal_dir=jrnd,
        )
        for label, eng in (("static", static), ("elastic", elastic)):
            for i in range(rounds):
                nb_before = eng.n_buckets
                stats = eng.run_round(eng.make_proposals(round_txs, seed=i))
                common.row(
                    "fig12", f"{label}/round={i}", tps=stats.tps,
                    n_buckets=eng.n_buckets,
                    resized=int(eng.n_buckets != nb_before),
                    overflow=int(eng.overflowed()),
                )
            out = eng.verify()
            m = eng.metrics()
            # Health/SLO rollup on the same sweep: the static table that
            # latched overflow MUST read critical with a per-shard
            # reason; the elastic peer that absorbed the identical load
            # must stay healthy. (The degraded band covers a static run
            # that filled past headroom without overflowing yet.)
            v = eng.health()
            if eng.overflowed():
                assert v.status == "critical", (label, v)
                assert any("shard" in r and "overflow" in r
                           for r in v.reasons), v
            elif label == "elastic":
                assert v.status == "healthy", v
            common.row(
                "fig12", f"{label}/final", overflow_ok=out["overflow_ok"],
                n_buckets=eng.n_buckets,
                n_resizes=len(eng.reanchor_log),
                verify_ok=all(out.values()) if label == "elastic"
                else all(v2 for k, v2 in out.items() if k != "overflow_ok"),
                health=v.status,
                health_reason=(v.reasons[0] if v.reasons else ""),
                resize_grows=m.get("resize.grow", 0),
                overflow_latches=m.get("overflow.latches", 0),
                **common.metrics_cols(m),
            )

        # Equivalence: whole workload replayed on the FINAL layout == the
        # elastic peer that split mid-run (content digest compare). Only
        # meaningful while the elastic run never overflowed — a dropped
        # insert is not derivable from the table, so an unhealthy elastic
        # run legitimately differs from the never-overflowing oracle.
        oracle = _mk_engine(None, elastic.n_buckets, slots, block_size)
        for i in range(rounds):
            oracle.run_round(oracle.make_proposals(round_txs, seed=i))
        identical = bool(np.array_equal(
            oracle._peer_digest(), elastic._peer_digest()
        ))
        if not elastic.overflowed():
            assert identical, "elastic run diverged from post-split oracle"
        common.row("fig12", "equivalence/elastic", identical=identical,
                   meaningful=int(not elastic.overflowed()))

        # Recovery from the per-shard snapshot + journal suffix (the
        # suffix crosses any re-anchors after the last snapshot).
        elastic.store.drain()
        t0 = time.perf_counter()
        rec = elastic.recover()
        t_full = time.perf_counter() - t0
        ok = bool(np.array_equal(rec.state_digest, elastic._peer_digest()))
        common.row(
            "fig12", "recovery/full", recovery_s=t_full,
            replayed=rec.replayed_records,
            reanchors=rec.crossed_reanchors, match=ok,
        )
        man = snapshot.latest_manifest(snapd)
        if man is not None and man.n_shards == n_shards:
            sk, sv, sva = ws.split_table(
                *elastic._state_view()[:3], n_shards
            )
            for m in range(n_shards):
                t0 = time.perf_counter()
                try:
                    sres = recovery.recover_shard(
                        elastic.journal, snapshot_dir=snapd, shard=m
                    )
                except recovery.RecoveryError as e:
                    common.row("fig12", f"recovery/shard={m}", error=str(e))
                    continue
                t_s = time.perf_counter() - t0
                match = bool(np.array_equal(
                    np.asarray(sres.state.keys), np.asarray(sk[m])
                ))
                common.row(
                    "fig12", f"recovery/shard={m}", recovery_s=t_s,
                    parts=sres.loaded_parts, match=match,
                )
        static.store.close()
        elastic.store.close()
        oracle.store.close()


def main(argv: list[str] | None = None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--rounds", type=int, default=14)
    p.add_argument("--round-txs", type=int, default=50)
    # Start small enough that the fill workload overflows a static table
    # well inside the sweep; the elastic run must absorb the same load.
    p.add_argument("--n-buckets", type=int, default=256)
    p.add_argument("--slots", type=int, default=8)
    p.add_argument("--n-shards", type=int, default=4)
    p.add_argument("--grow-free-slots", type=int, default=4)
    p.add_argument("--json", default=None)
    args = p.parse_args(argv)
    run(args.rounds, args.round_txs, args.n_buckets, args.slots,
        args.n_shards, args.grow_free_slots)
    if args.json:
        common.dump_json(args.json)


if __name__ == "__main__":
    main()
    common.print_csv()
