"""Fig 10 (beyond-paper) — model-axis sharded world state scaling.

FastFabric's P-I table is capped by one device's fast-memory budget when it
is replicated over the ``model`` axis (kernels/hash_table/ops.py enforces
8 MiB of VMEM per shard). launch/state_sharding partitions the buckets
across ``model`` ranks by high bucket bits, so the aggregate table grows
``model_size``x beyond the single-shard budget while every slice stays
VMEM-resident.

Measured here, per shard count m (powers of two up to the host's devices):
  * ``shard/m=..``  — fabric-step TPS with the state sharded over m ranks,
    on a table whose TOTAL size exceeds the single-shard VMEM budget
    (``fits_budget`` reports whether the per-shard slice fits);
  * ``repl/m=..``   — the replicated oracle on the same mesh/table for
    comparison (every rank carries the full table);
plus an equivalence row: sharded and replicated configs on the same round
must produce byte-identical validity bits and ledger/log heads.

Run with spare host devices to see >1 shard, e.g.:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m benchmarks.fig10_state_scaling
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from benchmarks import common
from repro.core import endorser, engine, types, unmarshal
from repro.kernels.hash_table import ops as ht_ops
from repro.launch import fabric_step as fs


def _round_inputs(dims: types.FabricDims, n: int, seed: int = 0):
    eng = engine.FabricEngine(engine.EngineConfig(dims=dims,
                                                  store_blocks=False))
    props = eng.make_proposals(n, seed=seed)
    txb = endorser.execute_and_endorse(eng.endorser_state, props, dims)
    wire = unmarshal.marshal(txb, dims)
    return wire[None], txb.tx_id[None]  # (C=1, B, ...)


def _shard_counts(max_shards: int) -> list[int]:
    out, m = [], 1
    while m <= max_shards:
        out.append(m)
        m *= 2
    return out


def run(n_buckets: int, slots: int, b_round: int, iters: int,
        check_equivalence: bool = True) -> None:
    dims = types.TEST_DIMS
    n_dev = len(jax.devices())
    max_m = 1 << (n_dev.bit_length() - 1)  # largest power of two <= n_dev
    bucket_bytes = slots * (3 + dims.vw) * 4
    total_bytes = n_buckets * bucket_bytes
    common.row(
        "fig10", "table", table_mib=total_bytes / 2**20,
        vmem_budget_mib=ht_ops.VMEM_BUDGET_BYTES / 2**20,
        over_budget=total_bytes > ht_ops.VMEM_BUDGET_BYTES,
    )

    for m in _shard_counts(max_m):
        if b_round % m or n_buckets % m:
            continue
        mesh = jax.make_mesh((1, m), ("data", "model"))
        wire, ids = _round_inputs(dims, b_round)
        for label, cfg in (
            ("shard", fs.FASTFABRIC_SHARDED_STEP),
            ("repl", fs.FASTFABRIC_STEP),
        ):
            state = fs.create_mesh_state(1, dims, n_buckets=n_buckets,
                                         slots=slots)
            step = jax.jit(fs.make_fabric_step(dims, cfg, mesh))
            t = common.timed(lambda: step(state, wire, ids), iters=iters)
            per_rank = total_bytes // m if label == "shard" else total_bytes
            common.row(
                "fig10", f"{label}/m={m}", tps=b_round / t,
                step_ms=1e3 * t, bytes_per_rank_mib=per_rank / 2**20,
                fits_budget=per_rank <= ht_ops.VMEM_BUDGET_BYTES,
            )

    if check_equivalence:
        # Acceptance: byte-identical validity bits and ledger/log heads.
        mesh = jax.make_mesh((1, max_m), ("data", "model"))
        wire, ids = _round_inputs(dims, b_round, seed=1)
        outs = {}
        for label, cfg in (("shard", fs.FASTFABRIC_SHARDED_STEP),
                           ("repl", fs.FASTFABRIC_STEP)):
            state = fs.create_mesh_state(1, dims, n_buckets=n_buckets,
                                         slots=slots)
            step = jax.jit(fs.make_fabric_step(dims, cfg, mesh))
            st2, valid = step(state, wire, ids)
            outs[label] = (np.asarray(valid), np.asarray(st2.ledger_head),
                           np.asarray(st2.log_head))
        same = all(
            np.array_equal(a, b) for a, b in zip(outs["shard"], outs["repl"])
        )
        assert same, "sharded and replicated step outputs diverged"
        common.row("fig10", f"equivalence/m={max_m}", identical=same)


def main(argv: list[str] | None = None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    # Default table: 64 Ki buckets x 8 slots x (3+4) words = 14 MiB total,
    # beyond the 8 MiB single-shard budget; 2+ shards bring each slice under.
    p.add_argument("--n-buckets", type=int, default=1 << 16)
    p.add_argument("--slots", type=int, default=8)
    p.add_argument("--b-round", type=int, default=256)
    p.add_argument("--iters", type=int, default=3)
    args = p.parse_args(argv)
    run(args.n_buckets, args.slots, args.b_round, args.iters)


if __name__ == "__main__":
    main()
    common.print_csv()
