"""Fig 9 (beyond-paper) — crash-recovery time vs chain length.

The durability argument of P-I/P-II (drop the database, store blocks off
the critical path) makes restart cost the new bottleneck: rebuilding world
state by full chain replay is O(chain length). The storage/ subsystem's
snapshot + journal-suffix path is O(blocks since last snapshot).

Measured here, per chain length:
  * ``full_replay``   — verify + replay the whole block chain (BlockStore);
  * ``snap+journal``  — verify snapshot digest + journal chain, replay only
    the suffix (storage/recovery.recover).
Plus the commit-path cost of carrying the journal head at all:
  * ``journal on/off`` — engine TPS with PeerConfig.journal toggled.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from benchmarks import common
from repro.core import committer, engine
from repro.core import world_state as ws
from repro.storage import recovery


def _timed_once(fn):
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(out.state.keys)
    return out, time.perf_counter() - t0


def run_recovery(round_txs: int, rounds_list: list[int],
                 snapshot_every: int) -> None:
    for n_rounds in rounds_list:
        # prune_chain=False keeps the full chain so both paths are
        # measurable on the same engine.
        cfg = engine.EngineConfig(
            snapshot_every_blocks=snapshot_every, prune_chain=False
        )
        eng = engine.FabricEngine(cfg)
        for i in range(n_rounds):
            eng.run_round(eng.make_proposals(round_txs, seed=i))
        eng.store.drain()
        n_blocks = len(eng.store.chain)
        live = np.asarray(ws.state_digest(eng.peer_state.hash_state))

        full, t_full = _timed_once(
            lambda: recovery.full_replay(
                eng.store, cfg.dims, n_buckets=cfg.n_buckets, slots=cfg.slots
            )
        )
        fast, t_fast = _timed_once(eng.recover)
        assert np.array_equal(full.state_digest, live)
        assert np.array_equal(fast.state_digest, live)

        common.row(
            "fig9", f"full_replay/blocks={n_blocks}", recovery_s=t_full,
            blocks_replayed=full.replayed_records,
        )
        common.row(
            "fig9", f"snap+journal/blocks={n_blocks}", recovery_s=t_fast,
            blocks_replayed=fast.replayed_records, speedup=t_full / t_fast,
        )
        eng.store.close()


def run_journal_overhead(round_txs: int, iters: int) -> None:
    tps = {}
    for on in (True, False):
        cfg = engine.EngineConfig(
            peer=dataclasses.replace(committer.FASTFABRIC_PEER, journal=on),
            store_blocks=False,  # isolate the commit path
        )
        eng = engine.FabricEngine(cfg)
        eng.run_round(eng.make_proposals(round_txs, seed=99))  # compile
        samples = [
            eng.run_round(eng.make_proposals(round_txs, seed=i)).tps
            for i in range(iters)
        ]
        tps[on] = float(np.median(samples))
        common.row("fig9", f"journal={'on' if on else 'off'}", tps=tps[on])
    common.row("fig9", "journal_overhead", ratio=tps[False] / tps[True])


def main(argv: list[str] | None = None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--round-txs", type=int, default=500)
    p.add_argument("--rounds-list", type=int, nargs="+", default=[2, 4, 8])
    p.add_argument("--snapshot-every", type=int, default=4)
    p.add_argument("--overhead-iters", type=int, default=5)
    args = p.parse_args(argv)
    run_recovery(args.round_txs, args.rounds_list, args.snapshot_every)
    run_journal_overhead(args.round_txs, args.overhead_iters)


if __name__ == "__main__":
    main()
    common.print_csv()
