"""Fig 8 — throughput vs block size (optimally tuned peer).

Paper: logarithmic scan, optimum around 100 tx/block (small blocks pay
per-block overhead, huge blocks lose pipelining), 50..500 within noise.
"""

from __future__ import annotations

import time

import jax

from benchmarks import common
from repro.core import committer, types

DIMS = types.PAPER_DIMS
TOTAL = 2_000


def run() -> None:
    for bs in (10, 25, 50, 100, 250, 500):
        n_blocks = max(TOTAL // bs, 2)
        blocks = []
        for i in range(n_blocks):
            wire, _, _ = common.make_endorsed_wire(DIMS, bs, seed=300 + i)
            blocks.append(wire)
        pcfg = committer.OPT_P3
        state = committer.create_peer_state(DIMS, n_buckets=1 << 13)
        r = committer.commit_block(state, blocks[0], DIMS, pcfg)
        jax.block_until_ready(r.block_hash)
        state = r.state
        t0 = time.perf_counter()
        hashes = []
        for b in blocks[1:]:
            r = committer.commit_block(state, b, DIMS, pcfg)
            state = r.state
            hashes.append(r.block_hash)
            if len(hashes) > pcfg.pipeline_depth:
                jax.block_until_ready(hashes.pop(0))
        jax.block_until_ready(hashes)
        dt = time.perf_counter() - t0
        common.row("fig8", f"block_size={bs}",
                   tps=(n_blocks - 1) * bs / dt)


if __name__ == "__main__":
    run()
    common.print_csv()
