"""Fig 3 — block transfer throughput (the 'gRPC' path).

Paper: pre-created blocks are pushed orderer->peer and immediately
discarded; >40k tx/s for 10..250-tx blocks shows the network is not the
bottleneck. TPU analogue: wire blocks are shipped host->device and pass
only the syntax pre-check (decode+checksum, no validation/commit). If this
rate comfortably exceeds the end-to-end Table-1 rate, transfer is not the
bottleneck in our environment either — same claim, same shape.
"""

from __future__ import annotations

import jax
import numpy as np

from benchmarks import common
from repro.core import committer, types

DIMS = types.PAPER_DIMS  # 2.9 KB transactions
TOTAL = 2_000


def run() -> None:
    for bs in (10, 50, 100, 250):
        n = (TOTAL // bs) * bs
        wire, _, _ = common.make_endorsed_wire(DIMS, bs, seed=bs)
        wire_host = np.asarray(wire)  # block starts host-side ("network")
        blocks = n // bs

        def ship_all():
            outs = []
            for _ in range(blocks):
                dev = jax.device_put(wire_host)  # transfer
                outs.append(committer.stage_syntax(dev, DIMS))  # discard
            return outs

        dt = common.timed(ship_all, warmup=1, iters=3)
        common.row("fig3", f"block_size={bs}", tps=n / dt,
                   block_ms=1e3 * dt / blocks)


if __name__ == "__main__":
    run()
    common.print_csv()
