"""CI perf-trajectory gate: compare a benchmark JSON against the baseline.

    PYTHONPATH=src python -m benchmarks.perf_gate BENCH_pr6.json out/bench.json

Joins rows on ``(bench, name)`` and fails (exit 1) when:

  * TPS regresses by more than ``--tps-tolerance`` (default 25%) on any
    row both runs measured — the per-PR throughput trajectory;
  * a CONTRACT column flips: ``overflow_ok`` (fig12's static-overflows /
    elastic-stays-healthy contrast), ``commit_scatters`` (fig11's fused
    ONE-scatter-per-window commit), ``identical`` (the pipelined ==
    depth-1-oracle equivalence rows);
  * a baseline row carrying a contract column is missing from the current
    run (a silently skipped check must not pass the gate).

TPS *improvements* and new rows never fail. Latency columns — any
``*_ms`` column both runs carry: ``commit_p50_ms``..., the per-tx phase
decomposition (``tx_queue/tx_order/tx_validate/tx_commit/tx_e2e``
percentiles), ``window_ms`` — are reported for drift but not gated:
wall-clock noise across CI hosts would make a hard latency gate flaky;
the TPS tolerance already bounds sustained regressions.

Multi-channel table1 rows (``channel<i>`` / ``channels_x_tps`` /
``fairness/*``) ride the same rules: their ``identical`` column is a
contract column once a baseline carries it; their informational columns
(``fairness``, ``load``, ``n_channels``, ``data_ranks``, ``n_buckets``,
``skew``) are intentionally NOT gated — the comparison only reads
``tps``, the contract columns, and the latency percentiles, so new
columns added by later PRs pass through untouched. The multi-channel
``identical`` contract is additionally asserted directly from the CI
artifact (see .github/workflows/ci.yml), baseline or not.
"""

from __future__ import annotations

import argparse
import json
import sys

CONTRACT_COLS = ("overflow_ok", "commit_scatters", "identical")


def _index(rows: list[dict]) -> dict:
    return {(r.get("bench"), r.get("name")): r for r in rows
            if r.get("bench") and r.get("name")}


def compare(baseline: list[dict], current: list[dict],
            tps_tolerance: float = 0.25) -> tuple[list[str], list[str]]:
    """(failures, notes) of current vs baseline."""
    base, cur = _index(baseline), _index(current)
    failures, notes = [], []
    for key, brow in sorted(base.items()):
        crow = cur.get(key)
        label = f"{key[0]}/{key[1]}"
        has_contract = any(c in brow for c in CONTRACT_COLS)
        if crow is None:
            if has_contract:
                failures.append(f"{label}: contract row missing from "
                                "current run")
            else:
                notes.append(f"{label}: row missing from current run")
            continue
        for col in CONTRACT_COLS:
            if col in brow:
                if col not in crow:
                    failures.append(f"{label}: contract column {col} "
                                    "missing from current run")
                elif bool(crow[col]) != bool(brow[col]):
                    failures.append(
                        f"{label}: {col} flipped "
                        f"{brow[col]} -> {crow[col]}"
                    )
        btps, ctps = brow.get("tps"), crow.get("tps")
        if isinstance(btps, (int, float)) and isinstance(ctps, (int, float)) \
                and btps > 0:
            ratio = ctps / btps
            if ratio < 1.0 - tps_tolerance:
                failures.append(
                    f"{label}: tps {btps:,.0f} -> {ctps:,.0f} "
                    f"({100 * (1 - ratio):.1f}% regression, tolerance "
                    f"{100 * tps_tolerance:.0f}%)"
                )
            elif ratio < 1.0:
                notes.append(f"{label}: tps {100 * (1 - ratio):.1f}% down "
                             "(within tolerance)")
        # Every latency column the two runs share (commit_p*_ms, the
        # tx-phase decomposition tx_queue/..._p*_ms and tx_e2e_p*_ms,
        # window_ms, ...) is drift-reported the same way: wall-clock
        # noise keeps them out of the hard gate.
        for col in sorted(k for k in brow
                          if k.endswith("_ms") and k in crow):
            b, c = brow.get(col), crow.get(col)
            if isinstance(b, (int, float)) and isinstance(c, (int, float)) \
                    and b > 0 and c > 2 * b:
                notes.append(f"{label}: {col} {b:.3g} -> {c:.3g} ms "
                             "(reported, not gated)")
    return failures, notes


def main(argv: list[str] | None = None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("baseline", help="committed baseline rows (JSON)")
    p.add_argument("current", help="this run's rows (JSON)")
    p.add_argument("--tps-tolerance", type=float, default=0.25,
                   help="allowed fractional TPS regression (default 0.25)")
    args = p.parse_args(argv)
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.current) as f:
        current = json.load(f)
    failures, notes = compare(baseline, current, args.tps_tolerance)
    for n in notes:
        print(f"  note: {n}")
    for fmsg in failures:
        print(f"  FAIL: {fmsg}")
    print(f"perf gate: {len(failures)} failure(s), {len(notes)} note(s) "
          f"over {len(_index(baseline))} baseline rows")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
