"""Flight-recorder fault smoke: induce a durability fault, assert the dump.

    PYTHONPATH=src python -m benchmarks.fault_smoke --out out/fault_dump

Runs an obs-on engine with ``recorder_dir`` set, commits a few rounds,
then tampers one journal record in the post-snapshot suffix (flips one
word of a write set) and calls ``verify()``. The broken durability
contract trips the flight recorder on its ``verify_contract`` fault edge,
which auto-dumps the recorder's whole window to ``--out``:

  * ``trace.jsonl`` / ``trace_chrome.json`` — the last-N span records;
  * ``metrics.json`` — the freshest registry snapshot + the per-round
    periodic snapshot ring;
  * ``lifecycles.json`` — the last-N complete tx lifecycles (tx-id,
    phase breakdown, outcome);
  * ``meta.json`` — trip reasons (including the journal's own failure
    reason naming WHICH record broke) + ring drop counters.

Exit status is the smoke contract CI keys on: the dump must exist and
contain at least one complete tx lifecycle, a populated metrics
snapshot, and the ``verify_contract`` trip with a journal reason. The
uploaded artifact is a real post-mortem a human can open.
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile

from repro.core import engine, types
from repro.obs import SLOConfig


def main(argv: list[str] | None = None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", required=True,
                   help="flight-recorder dump directory (the CI artifact)")
    p.add_argument("--rounds", type=int, default=3,
                   help="rounds before the induced fault (>= 3 so the "
                        "tampered record lands after the snapshot)")
    args = p.parse_args(argv)

    with tempfile.TemporaryDirectory() as td:
        eng = engine.FabricEngine(engine.EngineConfig(
            dims=types.TEST_DIMS, obs=True,
            snapshot_every_blocks=4, prune_chain=False,
            snapshot_dir=os.path.join(td, "snap"),
            journal_dir=os.path.join(td, "jrnl"),
            recorder_dir=args.out,
            slo=SLOConfig(commit_p95_s=60.0),
        ))
        bs = eng.cfg.orderer.block_size
        for seed in range(max(args.rounds, 3)):
            eng.run_round(eng.make_proposals(2 * bs, seed=seed))
        eng.store.drain()
        assert not eng.recorder.tripped, eng.recorder.trips

        # Induced fault: one flipped word in a post-snapshot journal
        # record — recovery can no longer authenticate the suffix.
        rec = eng.journal.records[-1]
        vals = rec.write_vals.copy()
        vals[0, 0, 0] ^= 1
        eng.journal.records[-1] = rec._replace(write_vals=vals)

        verdict = eng.verify()
        assert not all(verdict.values()), verdict
        assert eng.recorder.tripped
        eng.store.close()

    # The smoke contract on the dump itself.
    lcs = json.load(open(os.path.join(args.out, "lifecycles.json")))
    assert len(lcs) >= 1, "dump holds no complete tx lifecycle"
    assert all({"tx_id", "phases", "outcome", "e2e"} <= set(lc)
               for lc in lcs), lcs[:1]
    metrics = json.load(open(os.path.join(args.out, "metrics.json")))
    assert metrics["latest"].get("txs.valid"), "metrics snapshot is empty"
    assert len(metrics["periodic"]) >= 1, "no periodic registry snapshots"
    meta = json.load(open(os.path.join(args.out, "meta.json")))
    trip = meta["trips"][-1]
    assert trip["reason"] == "verify_contract", meta["trips"]
    assert "journal_reason" in trip["ctx"], trip
    n_spans = sum(1 for _ in open(os.path.join(args.out, "trace.jsonl")))
    print(f"fault dump OK: {args.out} — {n_spans} spans, "
          f"{len(lcs)} lifecycles, trip={trip['reason']} "
          f"({trip['ctx']['journal_reason']})")


if __name__ == "__main__":
    main()
