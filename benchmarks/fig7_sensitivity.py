"""Fig 7 — two-axis parallelism sensitivity.

Paper: throughput vs (#tx-validation goroutines) x (#blocks in the
pipeline); starving either axis is catastrophic, oversubscribing is mildly
bad. TPU adaptation: the goroutine pool maps to the vector width used per
validation tile (``tx_par``: 0 = whole block at once) and the block
pipeline to JAX async dispatch depth. We sweep both.
"""

from __future__ import annotations

import dataclasses
import time

import jax

from benchmarks import common
from repro.core import committer, types

DIMS = types.PAPER_DIMS
BS = 100
N_BLOCKS = 8


def run() -> None:
    blocks = []
    for i in range(N_BLOCKS):
        wire, _, _ = common.make_endorsed_wire(DIMS, BS, seed=200 + i)
        blocks.append(wire)

    for tx_par in (1, 10, 25, 0):  # 0 == whole-block vectorization
        for depth in (1, 4, 8):
            pcfg = dataclasses.replace(
                committer.OPT_P3, tx_par=tx_par, pipeline_depth=depth
            )
            state = committer.create_peer_state(DIMS, n_buckets=1 << 12)
            r = committer.commit_block(state, blocks[0], DIMS, pcfg)
            jax.block_until_ready(r.block_hash)
            state = r.state
            t0 = time.perf_counter()
            hashes = []
            for b in blocks[1:]:
                r = committer.commit_block(state, b, DIMS, pcfg)
                state = r.state
                hashes.append(r.block_hash)
                if len(hashes) > depth:
                    jax.block_until_ready(hashes.pop(0))
            jax.block_until_ready(hashes)
            dt = time.perf_counter() - t0
            n = (N_BLOCKS - 1) * BS
            label = "block" if tx_par == 0 else str(tx_par)
            common.row("fig7", f"tx_par={label}/depth={depth}", tps=n / dt)


if __name__ == "__main__":
    run()
    common.print_csv()
