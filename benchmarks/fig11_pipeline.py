"""Fig 11 (beyond-paper) — device-side block pipeline scaling.

FastFabric's P-II peer keeps many blocks in flight; the mesh step's
``pipeline_depth`` (repro/pipeline) takes a window of D blocks per
invocation, batching the consensus all-gather and the routed cross-shard
MVCC read-version gather to ONE collective each per window instead of one
per block, while commits still apply in block order (byte-identical to the
depth-1 oracle).

Measured per depth D in {1, 2, 4, 8} on replicated and sharded state:
  * ``repl/d=..`` / ``shard/d=..`` — TPS over a D-block window (depth 1
    commits the same blocks through D sequential step invocations);
  * ``coll_per_block`` / ``allreduce_per_block`` / ``allgather_per_block``
    — collective-instruction counts per block, read from the compiled
    dry-run HLO with trip counts multiplied out (launch/hlo_cost, the same
    analyzer roofline.py consumes). The sharded path must show the routed
    gather amortizing: one all-reduce per *window*, not per block;
  * ``commit_scatters`` — state-commit scatter passes in the compiled
    program (scatter instructions / 3 planes, trip-count corrected). The
    fused window commit means exactly ONE per window at any depth — this
    is asserted, not just reported (the pre-fusion schedule paid D);
  * ``repl-ovf/..`` / ``shard-ovf/..`` — the same sweep on a deliberately
    OVERFLOWING table (capacity far below the window's write set), where
    the planner must poison dropped-insert repairs; equivalence to the
    depth-1 oracle is asserted there too and the ``overflow`` column
    records the latched sticky flag;
plus equivalence rows: the deepest pipelined config must be
byte-identical to the depth-1 oracle on validity bits, log/ledger/journal
heads, the sticky overflow flag, and state arrays.

Run with spare host devices to see real routed collectives, e.g.:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m benchmarks.fig11_pipeline
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.analysis import checks as contract_checks
from repro.analysis import contracts
from repro.core import endorser, engine, types, unmarshal
from repro.launch import fabric_step as fs
from repro.launch import hlo_cost

# The fused-commit budget comes from the committed program contracts
# (src/repro/analysis/contracts.json) — the same clause the analysis
# gate enforces on every fabric_step program, so an intentional change
# is amended in ONE reviewed file, not here and there.
COMMIT_SCATTER_PASSES = contracts.commit_scatter_passes()


def _window_inputs(dims: types.FabricDims, depth: int, b_round: int,
                   seed: int = 0):
    """A window of ``depth`` blocks of ``b_round`` endorsed transfers each,
    endorsed against a shared replica so later blocks are consistent."""
    eng = engine.FabricEngine(engine.EngineConfig(dims=dims,
                                                  store_blocks=False))
    wires, idss = [], []
    for k in range(depth):
        props = eng.make_proposals(b_round, seed=seed + 7 * k)
        txb = endorser.execute_and_endorse(eng.endorser_state, props, dims)
        wires.append(unmarshal.marshal(txb, dims))
        idss.append(txb.tx_id)
    return jnp.stack(wires), jnp.stack(idss)  # (D, B, WB), (D, B, 2)


def _hlo_counts(jstep, state, wire, ids, nb_local: int, slots: int
                ) -> tuple[dict, float, int]:
    """(collective counts, compiled-HLO scatter count, commit scatter
    passes) of the compiled step. Collectives are trip-count corrected
    (instructions inside scans multiplied out). Commit passes come from
    repro.analysis.checks.table_scatter_passes — the same StableHLO
    counter the contracts gate runs (counted there because CPU XLA
    expands scatters into loops before the final HLO; TPU keeps them,
    and hlo_cost's compiled-HLO ``scatter_count`` is reported
    alongside). Lowering through the same jit wrapper the timing loop
    uses, so each depth compiles exactly once."""
    lowered = jstep.lower(state, wire, ids)
    an = hlo_cost.analyze(lowered.compile().as_text())
    commit_passes = contract_checks.table_scatter_passes(
        lowered.as_text(), nb_local, slots)
    return ({op: v["count"] for op, v in an["collectives"].items()},
            an["scatter_count"], commit_passes)


def _run_depth(dims, mesh, label: str, cfg, depth: int, b_round: int,
               n_buckets: int, iters: int, slots: int = 8):
    wire, ids = _window_inputs(dims, depth, b_round)
    state = fs.create_mesh_state(1, dims, n_buckets=n_buckets, slots=slots)
    dcfg = dataclasses.replace(cfg, pipeline_depth=depth)
    jstep = jax.jit(fs.make_fabric_step(dims, dcfg, mesh))
    nb_local = n_buckets // (mesh.shape["model"] if cfg.shard_state else 1)
    if depth == 1:
        def run():
            # Chain the state block-to-block: this is the real sequential
            # depth-1 path (unchained invocations would be data-independent
            # and async dispatch could overlap them, flattering the
            # baseline the pipeline is measured against).
            st, outs = state, []
            for k in range(wire.shape[0]):
                st, v = jstep(st, wire[k][None], ids[k][None])
                outs.append(v)
            return st, outs

        colls, scat, commits = _hlo_counts(
            jstep, state, wire[0][None], ids[0][None], nb_local, slots)
        n_blocks_compiled = 1
    else:
        def run():
            return jstep(state, wire[None], ids[None])

        colls, scat, commits = _hlo_counts(
            jstep, state, wire[None], ids[None], nb_local, slots)
        n_blocks_compiled = depth
    # The warmup execution doubles as the overflow-flag read (an extra
    # post-timing window run just for one scalar would lengthen the sweep).
    # The field is per-channel lane words ((C, LANES) u32); the row keeps
    # a 0/1 health flag.
    ovf = int(np.asarray(
        jax.block_until_ready(run())[0].overflow)[0].any())
    samples = common.timed_samples(run, warmup=0, iters=iters)
    t = float(np.median(samples))
    # Per-block commit latency percentiles: a window's blocks retire
    # together, so each iteration contributes its amortized wall/D once
    # per block — the same accounting the engine's commit.latency uses.
    lat = common.latency_hist(
        [s / depth for s in samples for _ in range(depth)])
    total = sum(colls.values())
    # Acceptance: the fused window commit issues exactly the contracted
    # scatter passes (3 planes: keys/versions/values = 1 pass) per
    # compiled program — the pre-fusion schedule paid one per block,
    # i.e. D per window. Budget from contracts.json, clause
    # [programs.fabric_step/*.commit_scatter_passes].
    assert commits == COMMIT_SCATTER_PASSES, (
        f"{label}/d={depth}: expected {COMMIT_SCATTER_PASSES} fused "
        f"commit scatter pass(es) per "
        f"{'window' if depth > 1 else 'block'}, compiled program has "
        f"{commits}"
    )
    common.row(
        "fig11", f"{label}/d={depth}",
        tps=depth * b_round / t, window_ms=1e3 * t,
        coll_per_block=total / n_blocks_compiled,
        allreduce_per_block=colls.get("all-reduce", 0) / n_blocks_compiled,
        allgather_per_block=colls.get("all-gather", 0) / n_blocks_compiled,
        commit_scatters=commits,
        scatter_count_hlo=scat,
        overflow=ovf,
        **common.percentile_cols(lat),
    )


def _check_equivalence(dims, mesh, cfg, depth: int, b_round: int,
                       n_buckets: int, label: str, slots: int = 8) -> None:
    """Acceptance: pipelined == D sequential depth-1 invocations, byte for
    byte (validity bits, log/ledger/journal heads, block_no, the sticky
    overflow flag, and state) — including on overflowing tables."""
    wire, ids = _window_inputs(dims, depth, b_round, seed=3)
    st1 = fs.create_mesh_state(1, dims, n_buckets=n_buckets, slots=slots)
    step1 = jax.jit(fs.make_fabric_step(
        dims, dataclasses.replace(cfg, pipeline_depth=1), mesh))
    valids = []
    for k in range(depth):
        st1, v = step1(st1, wire[k][None], ids[k][None])
        valids.append(np.asarray(v)[0])
    std = fs.create_mesh_state(1, dims, n_buckets=n_buckets, slots=slots)
    stepd = jax.jit(fs.make_fabric_step(
        dims, dataclasses.replace(cfg, pipeline_depth=depth), mesh))
    std, vd = stepd(std, wire[None], ids[None])
    same = np.array_equal(np.stack(valids), np.asarray(vd)[0]) and all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(st1, std)
    )
    assert same, f"pipelined {label} d={depth} diverged from depth-1 oracle"
    common.row("fig11", f"equivalence/{label}/d={depth}", identical=same,
               overflow=int(np.asarray(std.overflow)[0].any()))


def _obs_overhead(dims, mesh, cfg, depth: int, b_round: int,
                  n_buckets: int, iters: int,
                  obs_dir: str | None = None) -> None:
    """Instrumentation cost at the deepest pipeline: the SAME window
    committed through MeshWindowCommitter with obs detached vs attached
    (window spans + commit.latency + counters on the hot path; the HLO
    cost gauges record during warmup, outside the timed loop). The
    acceptance bar is <= 2% TPS — spans sync only at edges the un-instru-
    mented path already syncs (commit_window materializes the chain
    hashes), so the delta is null-call + histogram-bucket arithmetic.

    With ``obs_dir`` the obs-on run dumps trace.jsonl, trace_chrome.json
    and metrics.json there (the CI smoke artifact)."""
    import os

    from repro import obs as obs_mod
    from repro.pipeline.engine_bridge import MeshWindowCommitter

    wire, ids = _window_inputs(dims, depth, b_round)
    dcfg = dataclasses.replace(cfg, pipeline_depth=depth)
    tps, samples = {}, {}
    handles = {"off": obs_mod.Obs.disabled(), "on": obs_mod.Obs.enabled()}
    for mode, obs in handles.items():
        wc = MeshWindowCommitter(dims, dcfg, mesh, n_buckets=n_buckets)
        if obs.on:
            wc.attach_obs(obs)

        def run_once():
            wc.commit_window(wire, ids)
            return wc.state.ledger_head

        # warmup=2: the first call compiles for the freshly created
        # (unsharded) state, the second for the step's mesh-sharded output
        # layout; steady state starts at the third. The obs-on warmup also
        # absorbs the one-time HLO cost-gauge lowering.
        samples[mode] = common.timed_samples(
            run_once, warmup=2, iters=max(iters, 9))
        tps[mode] = depth * b_round / float(np.median(samples[mode]))
    overhead = 100.0 * (1.0 - tps["on"] / tps["off"])
    on = handles["on"]
    m = on.registry.collect()
    # Percentiles over the TIMED windows only (the registry histogram also
    # holds the warmup/compile windows — right for a live engine, noise
    # for an overhead row).
    lat = common.latency_hist(
        [s / depth for s in samples["on"] for _ in range(depth)])
    # CI keys the fused-commit contract on every non-equivalence /d= row,
    # so this row measures it too — same counting as the depth sweep (one
    # scatter pass per compiled window program).
    nb_local = (n_buckets // mesh.shape["model"] if dcfg.shard_state
                else n_buckets)
    hlo_args = ((wc.state, wire[0][None], ids[0][None]) if depth == 1
                else (wc.state, wire[None], ids[None]))
    _, _, commits = _hlo_counts(wc._step_for(depth, (0,)), *hlo_args,
                                nb_local, 8)
    assert commits == COMMIT_SCATTER_PASSES, (
        f"obs-overhead/d={depth}: expected {COMMIT_SCATTER_PASSES} fused "
        f"commit scatter pass(es), compiled program has {commits}"
    )
    common.row(
        "fig11", f"obs-overhead/d={depth}",
        tps=tps["on"], tps_obs_off=tps["off"],
        overhead_pct=overhead,
        window_commits=m.get("window.commits", 0),
        commit_scatters=commits,
        **common.percentile_cols(lat),
    )
    if obs_dir is not None:
        os.makedirs(obs_dir, exist_ok=True)
        on.tracer.dump_jsonl(os.path.join(obs_dir, "trace.jsonl"))
        on.tracer.dump_chrome(os.path.join(obs_dir, "trace_chrome.json"))
        import json

        with open(os.path.join(obs_dir, "metrics.json"), "w") as f:
            json.dump(m, f, indent=1)
        # The CI smoke contract: the trace holds steady-phase spans and
        # the registry a populated commit-latency histogram.
        steady = [r for r in on.tracer.records()
                  if r["name"] == "window.steady"]
        assert len(steady) >= 1, "no window.steady span in the obs trace"
        assert m["commit.latency"]["count"] > 0, "commit.latency is empty"


def _txtrace_overhead(dims, mesh, cfg, depth: int, b_round: int,
                      n_buckets: int, iters: int) -> None:
    """Tx-lifecycle tracing cost on the ENGINE round path at the deepest
    pipeline: the same proposal stream through two engines sharing one
    window-committer shape — obs off (NullTxTracer: no sidecar, no
    stamps) vs obs on (tx-id sidecar + per-block phase stamps folded into
    the tx.phase.* histograms + outcome counters + lifecycle ring).
    Phase timestamps ride sync edges the PR 6 spans already forced, so
    the bar matches the obs-overhead row: the delta is host-side
    arithmetic, not new device syncs."""
    from repro.obs import SLOConfig
    from repro.pipeline.engine_bridge import MeshWindowCommitter

    dcfg = dataclasses.replace(cfg, pipeline_depth=depth)
    tps = {}
    m_on = {}
    wc_on = None
    for mode in ("off", "on"):
        wc = MeshWindowCommitter(dims, dcfg, mesh, n_buckets=n_buckets)
        eng = engine.FabricEngine(
            engine.EngineConfig(
                dims=dims,
                orderer=dataclasses.replace(engine.FASTFABRIC.orderer,
                                            block_size=b_round),
                obs=(mode == "on"), slo=SLOConfig(commit_p95_s=60.0),
                store_blocks=False,
            ),
            window_committer=wc,
        )
        n = depth * b_round  # one full window per round
        for w in range(2):  # compile: fresh state, then sharded layout
            eng.run_round(eng.make_proposals(n, seed=90 + w))
        samples = []
        for i in range(max(iters, 9)):
            samples.append(eng.run_round(
                eng.make_proposals(n, seed=i)).wall_s)
        tps[mode] = n / float(np.median(samples))
        if mode == "on":
            m_on = eng.metrics()
            wc_on = wc
    overhead = 100.0 * (1.0 - tps["on"] / tps["off"])
    # The fused-commit contract is keyed on every non-equivalence /d= row
    # (tests + CI artifact assert), so this row measures it too — same
    # counting as the depth sweep, on the committer the traced engine
    # actually drove.
    wire, ids = _window_inputs(dims, depth, b_round)
    nb_local = (n_buckets // mesh.shape["model"] if dcfg.shard_state
                else n_buckets)
    hlo_args = ((wc_on.state, wire[0][None], ids[0][None]) if depth == 1
                else (wc_on.state, wire[None], ids[None]))
    _, _, commits = _hlo_counts(wc_on._step_for(depth, (0,)), *hlo_args,
                                nb_local, 8)
    assert commits == COMMIT_SCATTER_PASSES, (
        f"txtrace-overhead/d={depth}: expected {COMMIT_SCATTER_PASSES} "
        f"fused commit scatter pass(es), compiled program has {commits}"
    )
    common.row(
        "fig11", f"txtrace-overhead/d={depth}",
        tps=tps["on"], tps_obs_off=tps["off"],
        overhead_pct=overhead,
        commit_scatters=commits,
        txs_valid=m_on.get("tx.outcome{outcome=valid}", 0),
        **common.txphase_cols(m_on),
    )


def run(depths: list[int], b_round: int, n_buckets: int, iters: int,
        ovf_buckets: int = 16, obs_dir: str | None = None) -> None:
    dims = types.TEST_DIMS
    n_dev = len(jax.devices())
    m = 1 << (n_dev.bit_length() - 1)  # largest power of two <= n_dev
    while b_round % m or n_buckets % m or ovf_buckets % m:
        m //= 2
    mesh = jax.make_mesh((1, m), ("data", "model"))
    common.row("fig11", "mesh", model_ranks=m, b_round=b_round)

    for label, cfg in (("repl", fs.FASTFABRIC_STEP),
                       ("shard", fs.FASTFABRIC_SHARDED_STEP)):
        for d in depths:
            _run_depth(dims, mesh, label, cfg, d, b_round, n_buckets, iters)
        _check_equivalence(dims, mesh, cfg, max(depths), b_round, n_buckets,
                           label)
        # Deliberately overflowing table: capacity ovf_buckets * 2 slots
        # is far below the window's 2 * b_round writes per block, so
        # inserts drop mid-window and the overflow-exact repair is on the
        # measured path (and its equivalence asserted).
        for d in depths:
            _run_depth(dims, mesh, f"{label}-ovf", cfg, d, b_round,
                       ovf_buckets, iters, slots=2)
        _check_equivalence(dims, mesh, cfg, max(depths), b_round,
                           ovf_buckets, f"{label}-ovf", slots=2)
    # Instrumentation overhead at the deepest pipeline (replicated state:
    # the highest-TPS configuration is where overhead shows first). Only
    # this obs-on run exports the trace/metrics artifacts.
    _obs_overhead(dims, mesh, fs.FASTFABRIC_STEP, max(depths), b_round,
                  n_buckets, iters, obs_dir=obs_dir)
    # Tx-lifecycle tracing cost on the engine round path, same depth —
    # the PR 8 counterpart of the span-overhead row above.
    _txtrace_overhead(dims, mesh, fs.FASTFABRIC_STEP, max(depths), b_round,
                      n_buckets, iters)


def main(argv: list[str] | None = None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--depths", type=int, nargs="+", default=[1, 2, 4, 8])
    p.add_argument("--b-round", type=int, default=128)
    p.add_argument("--n-buckets", type=int, default=1 << 12)
    p.add_argument("--ovf-buckets", type=int, default=16,
                   help="bucket count of the deliberately overflowing "
                        "table (2 slots each)")
    p.add_argument("--iters", type=int, default=3)
    p.add_argument("--json", default=None,
                   help="write the result rows as JSON to this path")
    p.add_argument("--obs-dir", default=None,
                   help="dump the obs-on run's trace.jsonl / "
                        "trace_chrome.json / metrics.json here")
    args = p.parse_args(argv)
    run(args.depths, args.b_round, args.n_buckets, args.iters,
        ovf_buckets=args.ovf_buckets, obs_dir=args.obs_dir)
    if args.json:
        common.dump_json(args.json)


if __name__ == "__main__":
    main()
    common.print_csv()
