"""Fig 11 (beyond-paper) — device-side block pipeline scaling.

FastFabric's P-II peer keeps many blocks in flight; the mesh step's
``pipeline_depth`` (repro/pipeline) takes a window of D blocks per
invocation, batching the consensus all-gather and the routed cross-shard
MVCC read-version gather to ONE collective each per window instead of one
per block, while commits still apply in block order (byte-identical to the
depth-1 oracle).

Measured per depth D in {1, 2, 4, 8} on replicated and sharded state:
  * ``repl/d=..`` / ``shard/d=..`` — TPS over a D-block window (depth 1
    commits the same blocks through D sequential step invocations);
  * ``coll_per_block`` / ``allreduce_per_block`` / ``allgather_per_block``
    — collective-instruction counts per block, read from the compiled
    dry-run HLO with trip counts multiplied out (launch/hlo_cost, the same
    analyzer roofline.py consumes). The sharded path must show the routed
    gather amortizing: one all-reduce per *window*, not per block;
plus an equivalence row: the deepest pipelined config must be
byte-identical to the depth-1 oracle on validity bits, log/ledger/journal
heads, and state arrays.

Run with spare host devices to see real routed collectives, e.g.:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m benchmarks.fig11_pipeline
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import endorser, engine, types, unmarshal
from repro.launch import fabric_step as fs
from repro.launch import hlo_cost


def _window_inputs(dims: types.FabricDims, depth: int, b_round: int,
                   seed: int = 0):
    """A window of ``depth`` blocks of ``b_round`` endorsed transfers each,
    endorsed against a shared replica so later blocks are consistent."""
    eng = engine.FabricEngine(engine.EngineConfig(dims=dims,
                                                  store_blocks=False))
    wires, idss = [], []
    for k in range(depth):
        props = eng.make_proposals(b_round, seed=seed + 7 * k)
        txb = endorser.execute_and_endorse(eng.endorser_state, props, dims)
        wires.append(unmarshal.marshal(txb, dims))
        idss.append(txb.tx_id)
    return jnp.stack(wires), jnp.stack(idss)  # (D, B, WB), (D, B, 2)


def _coll_counts(jstep, state, wire, ids) -> dict:
    """Collective-instruction counts of the compiled step (trip-count
    corrected, so collectives inside scans are multiplied out). Lowering
    through the same jit wrapper the timing loop uses, so each depth
    compiles exactly once."""
    hlo = jstep.lower(state, wire, ids).compile().as_text()
    colls = hlo_cost.analyze(hlo)["collectives"]
    return {op: v["count"] for op, v in colls.items()}


def _run_depth(dims, mesh, label: str, cfg, depth: int, b_round: int,
               n_buckets: int, iters: int):
    wire, ids = _window_inputs(dims, depth, b_round)
    state = fs.create_mesh_state(1, dims, n_buckets=n_buckets)
    dcfg = dataclasses.replace(cfg, pipeline_depth=depth)
    jstep = jax.jit(fs.make_fabric_step(dims, dcfg, mesh))
    if depth == 1:
        def run():
            # Chain the state block-to-block: this is the real sequential
            # depth-1 path (unchained invocations would be data-independent
            # and async dispatch could overlap them, flattering the
            # baseline the pipeline is measured against).
            st, outs = state, []
            for k in range(wire.shape[0]):
                st, v = jstep(st, wire[k][None], ids[k][None])
                outs.append(v)
            return st, outs

        colls = _coll_counts(jstep, state, wire[0][None], ids[0][None])
        n_blocks_compiled = 1
    else:
        def run():
            return jstep(state, wire[None], ids[None])

        colls = _coll_counts(jstep, state, wire[None], ids[None])
        n_blocks_compiled = depth
    t = common.timed(run, iters=iters)
    total = sum(colls.values())
    common.row(
        "fig11", f"{label}/d={depth}",
        tps=depth * b_round / t, window_ms=1e3 * t,
        coll_per_block=total / n_blocks_compiled,
        allreduce_per_block=colls.get("all-reduce", 0) / n_blocks_compiled,
        allgather_per_block=colls.get("all-gather", 0) / n_blocks_compiled,
    )


def _check_equivalence(dims, mesh, cfg, depth: int, b_round: int,
                       n_buckets: int, label: str) -> None:
    """Acceptance: pipelined == D sequential depth-1 invocations, byte for
    byte (validity bits, log/ledger/journal heads, block_no, state)."""
    wire, ids = _window_inputs(dims, depth, b_round, seed=3)
    st1 = fs.create_mesh_state(1, dims, n_buckets=n_buckets)
    step1 = jax.jit(fs.make_fabric_step(
        dims, dataclasses.replace(cfg, pipeline_depth=1), mesh))
    valids = []
    for k in range(depth):
        st1, v = step1(st1, wire[k][None], ids[k][None])
        valids.append(np.asarray(v)[0])
    std = fs.create_mesh_state(1, dims, n_buckets=n_buckets)
    stepd = jax.jit(fs.make_fabric_step(
        dims, dataclasses.replace(cfg, pipeline_depth=depth), mesh))
    std, vd = stepd(std, wire[None], ids[None])
    same = np.array_equal(np.stack(valids), np.asarray(vd)[0]) and all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(st1, std)
    )
    assert same, f"pipelined {label} d={depth} diverged from depth-1 oracle"
    common.row("fig11", f"equivalence/{label}/d={depth}", identical=same)


def run(depths: list[int], b_round: int, n_buckets: int, iters: int) -> None:
    dims = types.TEST_DIMS
    n_dev = len(jax.devices())
    m = 1 << (n_dev.bit_length() - 1)  # largest power of two <= n_dev
    while b_round % m or n_buckets % m:
        m //= 2
    mesh = jax.make_mesh((1, m), ("data", "model"))
    common.row("fig11", "mesh", model_ranks=m, b_round=b_round)

    for label, cfg in (("repl", fs.FASTFABRIC_STEP),
                       ("shard", fs.FASTFABRIC_SHARDED_STEP)):
        for d in depths:
            _run_depth(dims, mesh, label, cfg, d, b_round, n_buckets, iters)
        _check_equivalence(dims, mesh, cfg, max(depths), b_round, n_buckets,
                           label)


def main(argv: list[str] | None = None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--depths", type=int, nargs="+", default=[1, 2, 4, 8])
    p.add_argument("--b-round", type=int, default=128)
    p.add_argument("--n-buckets", type=int, default=1 << 12)
    p.add_argument("--iters", type=int, default=3)
    p.add_argument("--json", default=None,
                   help="write the result rows as JSON to this path")
    args = p.parse_args(argv)
    run(args.depths, args.b_round, args.n_buckets, args.iters)
    if args.json:
        common.dump_json(args.json)


if __name__ == "__main__":
    main()
    common.print_csv()
