"""MVCC validation: conflict-matrix scan vs Fabric's literal per-tx walk."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import hashing, mvcc, types, world_state as ws

DIMS = types.TEST_DIMS


def _batch_from_accounts(pairs, versions=None):
    """Build transfer txs touching given (src, dst) account pairs."""
    b = len(pairs)
    rk = np.zeros((b, DIMS.rk, 2), np.uint32)
    for i, (s, d) in enumerate(pairs):
        for j, acct in enumerate((s, d)):
            h1, h2 = hashing.hash_pair(jnp.uint32(acct))
            rk[i, j] = [int(hashing.nonzero_key(h1)), int(h2)]
    rv = (np.zeros((b, DIMS.rk), np.uint32) if versions is None
          else versions)
    return types.TxBatch(
        tx_id=jnp.asarray(np.arange(2 * b, dtype=np.uint32
                                    ).reshape(b, 2)),
        client=jnp.zeros((b,), jnp.uint32),
        channel=jnp.zeros((b,), jnp.uint32),
        read_keys=jnp.asarray(rk),
        read_vers=jnp.asarray(rv),
        write_keys=jnp.asarray(rk[:, : DIMS.wk]),
        write_vals=jnp.ones((b, DIMS.wk, DIMS.vw), jnp.uint32),
        endorse_tags=jnp.zeros((b, DIMS.ne), jnp.uint32),
    )


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 6), st.integers(0, 6)),
                min_size=1, max_size=16))
def test_scan_matches_sequential_walk(pairs):
    """Property: the vectorized conflict-matrix formulation equals the
    paper's literal sequential walk for arbitrary conflict patterns."""
    txb = _batch_from_accounts(pairs)
    state = ws.create(64, 8, DIMS.vw)
    cur = ws.lookup(state, txb.read_keys.reshape(-1, 2)
                    ).versions.reshape(len(pairs), -1)
    got = mvcc.validate(txb, cur).valid
    want = mvcc.validate_sequential_reference(txb, state)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_random_batches_with_duplicate_keys_match_reference(data):
    """Property: validate == the sequential reference on random batches
    whose read/write keys are drawn independently from a tiny account pool
    (duplicates within a tx, across txs, and read/write overlaps all
    occur), with random expected versions against a populated state."""
    b = data.draw(st.integers(1, 12))
    acct = lambda: st.integers(0, 4)  # 5 accounts: heavy duplication
    reads = data.draw(st.lists(st.tuples(acct(), acct()),
                               min_size=b, max_size=b))
    writes = data.draw(st.lists(st.tuples(acct(), acct()),
                                min_size=b, max_size=b))
    vers = data.draw(st.lists(st.tuples(st.integers(0, 2), st.integers(0, 2)),
                              min_size=b, max_size=b))

    def paired(accounts):
        out = np.zeros((b, 2, 2), np.uint32)
        for i, pair in enumerate(accounts):
            for j, a in enumerate(pair):
                h1, h2 = hashing.hash_pair(jnp.uint32(a))
                out[i, j] = [int(hashing.nonzero_key(h1)), int(h2)]
        return jnp.asarray(out)

    txb = types.TxBatch(
        tx_id=jnp.asarray(
            np.arange(2 * b, dtype=np.uint32).reshape(b, 2)),
        client=jnp.zeros((b,), jnp.uint32),
        channel=jnp.zeros((b,), jnp.uint32),
        read_keys=paired(reads),
        read_vers=jnp.asarray(np.asarray(vers, np.uint32)),
        write_keys=paired(writes),
        write_vals=jnp.ones((b, DIMS.wk, DIMS.vw), jnp.uint32),
        endorse_tags=jnp.zeros((b, DIMS.ne), jnp.uint32),
    )
    # Populate accounts 0 and 1 (version 1) so some reads are fresh at
    # version 1 and others stale.
    seed_txb = _batch_from_accounts([(0, 1)])
    state = ws.commit_vectorized(
        ws.create(64, 8, DIMS.vw), seed_txb.write_keys,
        jnp.ones((1, DIMS.wk, DIMS.vw), jnp.uint32), jnp.ones(1, bool),
    ).state
    cur = ws.lookup(
        state, txb.read_keys.reshape(-1, 2)
    ).versions.reshape(b, -1)
    got = mvcc.validate(txb, cur).valid
    want = mvcc.validate_sequential_reference(txb, state)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_double_spend_blocked():
    """Two txs spending the same account: only the first commits."""
    txb = _batch_from_accounts([(1, 2), (1, 3)])
    cur = jnp.zeros((2, DIMS.rk), jnp.uint32)
    valid = mvcc.validate(txb, cur).valid
    assert bool(valid[0]) and not bool(valid[1])


def test_stale_read_version_invalid():
    txb = _batch_from_accounts([(1, 2)],
                               versions=np.full((1, DIMS.rk), 5,
                                                np.uint32))
    cur = jnp.zeros((1, DIMS.rk), jnp.uint32)  # state says version 0
    res = mvcc.validate(txb, cur)
    assert not bool(res.valid[0]) and not bool(res.vers_ok[0])


def test_invalid_earlier_tx_does_not_block():
    """A conflicting but *invalid* earlier tx must not invalidate later
    ones (Fabric: invalid txs stay in the block but have no effect)."""
    txb = _batch_from_accounts([(1, 2), (1, 3)])
    cur = jnp.zeros((2, DIMS.rk), jnp.uint32)
    # Make tx0 fail endorsement: tx1 should then be valid.
    endorse_ok = jnp.asarray([False, True])
    valid = mvcc.validate(txb, cur, endorse_ok=endorse_ok).valid
    assert not bool(valid[0]) and bool(valid[1])


def test_chain_of_conflicts():
    """tx0 valid -> blocks tx1 -> tx2 (conflicts only with tx1) valid."""
    txb = _batch_from_accounts([(1, 2), (2, 3), (3, 4)])
    cur = jnp.zeros((3, DIMS.rk), jnp.uint32)
    valid = np.asarray(mvcc.validate(txb, cur).valid)
    # tx1 touches 2 (written by valid tx0) -> invalid; tx2 touches 3
    # (written only by invalid tx1) -> valid.
    np.testing.assert_array_equal(valid, [True, False, True])
