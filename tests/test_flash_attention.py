"""Flash-attention Pallas kernel vs naive oracle: shape/dtype sweeps."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import kernel, ops, ref

RNG = np.random.default_rng(11)


def _qkv(b, s, h, kv, d, dtype):
    q = jnp.asarray(RNG.normal(size=(b, s, h, d)), dtype)
    k = jnp.asarray(RNG.normal(size=(b, s, kv, d)), dtype)
    v = jnp.asarray(RNG.normal(size=(b, s, kv, d)), dtype)
    return q, k, v


@pytest.mark.parametrize("b,s,h,kv,d,qb,kb", [
    (2, 64, 4, 2, 16, 16, 16),   # GQA, square blocks
    (2, 64, 4, 4, 32, 32, 16),   # MHA, rectangular blocks
    (1, 128, 8, 2, 16, 32, 32),  # longer sequence
    (2, 64, 4, 1, 16, 16, 32),   # MQA, kv block > q block
])
@pytest.mark.parametrize("causal", [True, False])
def test_matches_naive(b, s, h, kv, d, qb, kb, causal):
    q, k, v = _qkv(b, s, h, kv, d, jnp.float32)
    got = kernel.flash_attention(q, k, v, causal=causal, q_block=qb,
                                 kv_block=kb, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=1e-5)


def test_bf16():
    q, k, v = _qkv(1, 64, 2, 1, 16, jnp.bfloat16)
    got = kernel.flash_attention(q, k, v, causal=True, q_block=16,
                                 kv_block=16, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=3e-2, rtol=3e-2,
    )
    assert got.dtype == jnp.bfloat16


def test_ops_dispatch_cpu_path():
    q, k, v = _qkv(1, 32, 2, 2, 16, jnp.float32)
    got = ops.attention(q, k, v, causal=True, use_pallas=False)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=1e-5)


def test_rejects_bad_blocks():
    q, k, v = _qkv(1, 60, 2, 2, 16, jnp.float32)
    with pytest.raises(ValueError):
        kernel.flash_attention(q, k, v, q_block=16, kv_block=16,
                               interpret=True)
