"""Model-axis sharded world state: the sharded fabric step must be
byte-identical to the replicated oracle, and the hash-table ops dispatch
must route over-budget tables through the sharded path.

Runs on whatever host devices exist: with 1 device the sharded path is
exercised degenerately (psum over one rank); the CI multi-device job
(XLA_FLAGS=--xla_force_host_platform_device_count=8) runs the >=2-rank
cases for real.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import endorser, engine, types, unmarshal
from repro.core import world_state as ws
from repro.kernels.hash_table import ops as ht_ops
from repro.kernels.hash_table import ref as ht_ref
from repro.launch import fabric_step as fs
from repro.launch import state_sharding

DIMS = types.TEST_DIMS
N_DEV = len(jax.devices())
MAX_M = 1 << (N_DEV.bit_length() - 1)  # largest power of two <= N_DEV

multi_device = pytest.mark.skipif(
    N_DEV < 2, reason="needs >=2 devices (CI multi-device job)"
)


def _round(n=32, seed=0):
    eng = engine.FabricEngine(engine.EngineConfig(dims=DIMS,
                                                  store_blocks=False))
    props = eng.make_proposals(n, seed=seed)
    txb = endorser.execute_and_endorse(eng.endorser_state, props, DIMS)
    wire = unmarshal.marshal(txb, DIMS)
    return wire[None], txb.tx_id[None]  # (C=1, B, ...)


def _run_step(cfg, mesh, wire, ids, n_buckets=256):
    state = fs.create_mesh_state(1, DIMS, n_buckets=n_buckets)
    step = jax.jit(fs.make_fabric_step(DIMS, cfg, mesh))
    st2, valid = step(state, wire, ids)
    return jax.tree.map(np.asarray, st2), np.asarray(valid)


# ------------------------------------------------------------ shard routing


def test_shard_of_high_bits_and_local_bucket_low_bits():
    nb, m = 64, 4
    rng = np.random.default_rng(0)
    keys = jnp.asarray(rng.integers(1, 1 << 32, (100, 2), dtype=np.uint32))
    owner = np.asarray(ws.shard_of(nb, m, keys))
    gb = np.asarray(ws.bucket_of(nb, keys))
    nb_loc = nb // m
    np.testing.assert_array_equal(owner, gb // nb_loc)
    # Local probe index (low bits) recombines with the owner to the global
    # bucket: the contiguous reshape IS the partition.
    lb = np.asarray(ws.bucket_of(nb_loc, keys))
    np.testing.assert_array_equal(owner * nb_loc + lb, gb)


def test_shard_buckets_validation():
    assert ws.shard_buckets(64, 4) == 16
    with pytest.raises(ValueError, match="power of two"):
        ws.shard_buckets(64, 3)
    with pytest.raises(ValueError, match="divisible"):
        ws.shard_buckets(64, 128)


def test_create_shard_local_table():
    """create(n_shards=M) yields one shard's local slice of the global
    table — same shapes as a split of the replicated creation."""
    local = ws.create(64, 4, DIMS.vw, n_shards=4)
    assert local.n_buckets == 16 and local.slots == 4
    full = ws.create(64, 4, DIMS.vw)
    sk, sv, sva = state_sharding.split_table(
        full.keys, full.versions, full.values, 4
    )
    assert sk.shape[1:] == local.keys.shape
    assert sva.shape[1:] == local.values.shape
    with pytest.raises(ValueError, match="power of two"):
        ws.create(64, 4, DIMS.vw, n_shards=3)


def test_split_merge_roundtrip_is_high_bit_partition():
    st = ws.create(16, 2, 1)
    keys = st.keys.at[:, 0, 0].set(jnp.arange(16, dtype=jnp.uint32))
    sk, sv, sva = state_sharding.split_table(keys, st.versions, st.values, 4)
    assert sk.shape == (4, 4, 2, 2)
    # Shard m holds buckets [m*4, (m+1)*4).
    np.testing.assert_array_equal(
        np.asarray(sk[2, :, 0, 0]), np.arange(8, 12)
    )
    mk, mv, mva = state_sharding.merge_table(sk, sv, sva)
    np.testing.assert_array_equal(np.asarray(mk), np.asarray(keys))


def test_shard_digest_tree_deterministic_and_xor_decomposition():
    rng = np.random.default_rng(1)
    txb = types.make_transfer_batch(DIMS, 32, seed=2)
    full = ws.commit_vectorized(
        ws.create(64, 8, DIMS.vw), txb.write_keys, txb.write_vals,
        jnp.ones(32, bool),
    ).state
    sk, sv, sva = state_sharding.split_table(
        full.keys, full.versions, full.values, 4
    )
    per_shard = jnp.stack(
        [ws.state_digest(ws.HashState(sk[m], sv[m], sva[m]))
         for m in range(4)]
    )
    # XOR of per-shard digests == full-table digest (shard-decomposable).
    np.testing.assert_array_equal(
        np.bitwise_xor.reduce(np.asarray(per_shard), axis=0),
        np.asarray(ws.state_digest(full)),
    )
    # The tree head is deterministic and shard-order-sensitive.
    t1 = np.asarray(ws.shard_digest_tree(per_shard))
    t2 = np.asarray(ws.shard_digest_tree(per_shard))
    np.testing.assert_array_equal(t1, t2)
    assert not np.array_equal(
        t1, np.asarray(ws.shard_digest_tree(per_shard[::-1]))
    )


# ----------------------------------------------- sharded step == replicated


def _assert_equivalent(m, n=32, seed=0):
    mesh = jax.make_mesh((1, m), ("data", "model"))
    wire, ids = _round(n=n, seed=seed)
    st_r, v_r = _run_step(fs.FASTFABRIC_STEP, mesh, wire, ids)
    st_s, v_s = _run_step(fs.FASTFABRIC_SHARDED_STEP, mesh, wire, ids)
    np.testing.assert_array_equal(v_r, v_s)
    for a, b in zip(st_r, st_s):
        np.testing.assert_array_equal(a, b)
    assert int(v_s.sum()) == n
    return v_s


def test_sharded_equals_replicated_degenerate():
    _assert_equivalent(1)


@multi_device
def test_sharded_equals_replicated_multi_rank():
    """Acceptance: identical validity bits, ledger/log heads, and state
    arrays (concatenated shards == replicated table) on >=2 model ranks."""
    _assert_equivalent(min(MAX_M, 4), n=32, seed=1)


@multi_device
def test_sharded_replay_round_invalidated():
    """Version checks still work when the versions live on remote shards."""
    mesh = jax.make_mesh((1, min(MAX_M, 4)), ("data", "model"))
    wire, ids = _round(seed=3)
    state = fs.create_mesh_state(1, DIMS, n_buckets=256)
    step = jax.jit(fs.make_fabric_step(DIMS, fs.FASTFABRIC_SHARDED_STEP,
                                       mesh))
    st1, v1 = step(state, wire, ids)
    st2, v2 = step(st1, wire, ids)
    assert int(np.asarray(v1).sum()) == 32
    assert int(np.asarray(v2).sum()) == 0  # stale versions everywhere


@multi_device
def test_sharded_digest_head_identical_on_all_ranks():
    from jax.sharding import PartitionSpec as P

    m = min(MAX_M, 4)
    mesh = jax.make_mesh((1, m), ("data", "model"))
    txb = types.make_transfer_batch(DIMS, 64, seed=4)
    full = ws.commit_vectorized(
        ws.create(256, 8, DIMS.vw), txb.write_keys, txb.write_vals,
        jnp.ones(64, bool),
    ).state

    def head(keys, vers, vals):
        local = ws.HashState(keys, vers, vals)
        return state_sharding.sharded_digest(local)[None]

    shard = fs._shard_map(
        head, mesh=mesh,
        in_specs=(P("model"), P("model"), P("model")),
        out_specs=P("model"), **fs._SHARD_MAP_NO_CHECK,
    )
    heads = np.asarray(
        shard(full.keys, full.versions, full.values)
    ).reshape(m, 2)
    # Same head on every rank, equal to the host-side tree computation.
    sk, sv, sva = state_sharding.split_table(
        full.keys, full.versions, full.values, m
    )
    want = np.asarray(ws.shard_digest_tree(jnp.stack(
        [ws.state_digest(ws.HashState(sk[i], sv[i], sva[i]))
         for i in range(m)]
    )))
    for h in heads:
        np.testing.assert_array_equal(h, want)


def test_shard_state_rejects_indivisible_buckets():
    if N_DEV < 2:
        pytest.skip("needs >=2 devices to build a >1 model axis")
    mesh = jax.make_mesh((1, 2), ("data", "model"))
    wire, ids = _round()
    state = fs.create_mesh_state(1, DIMS, n_buckets=256)
    odd = state._replace(keys=state.keys[:, :100])  # 100 % 2 == 0 but not
    step = fs.make_fabric_step(DIMS, fs.FASTFABRIC_SHARDED_STEP, mesh)
    with pytest.raises(ValueError, match="power of two"):
        step(odd, wire, ids)


# ------------------------------------------------- ops.py budget dispatch


def test_ops_dispatch_over_budget_lookup_and_commit(monkeypatch):
    """Tables above the VMEM budget are sharded, not rejected, and the
    sharded kernel path matches the reference exactly."""
    monkeypatch.setattr(ht_ops, "VMEM_BUDGET_BYTES", 2048)
    nb, s, vw = 64, 4, 2  # 5120 B > 2048 -> 4 shards
    rng = np.random.default_rng(5)
    tk = jnp.zeros((nb, s, 2), jnp.uint32)
    tv = jnp.zeros((nb, s), jnp.uint32)
    tva = jnp.zeros((nb, s, vw), jnp.uint32)
    assert ht_ops._n_shards(tk, tva) == 4
    wk = jnp.asarray(rng.integers(1, 1 << 32, (50, 2), dtype=np.uint32))
    wv = jnp.asarray(rng.integers(0, 1 << 32, (50, vw), dtype=np.uint32))
    act = jnp.asarray(rng.random(50) < 0.9)
    got = ht_ops.commit(tk, tv, tva, wk, wv, act, use_pallas=True)
    want = ht_ref.commit_ref(tk, tv, tva, wk, wv, act)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    queries = jnp.concatenate(
        [wk[:30],
         jnp.asarray(rng.integers(1, 1 << 32, (20, 2), dtype=np.uint32))]
    )
    got_l = ht_ops.lookup(got[0], got[1], got[2], queries, use_pallas=True)
    want_l = ht_ref.lookup_ref(want[0], want[1], want[2], queries)
    for g, w in zip(got_l, want_l):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_ops_dispatch_under_budget_unchanged():
    nb, s, vw = 16, 4, 1
    tk = jnp.zeros((nb, s, 2), jnp.uint32)
    tva = jnp.zeros((nb, s, vw), jnp.uint32)
    assert ht_ops._n_shards(tk, tva) == 1


def test_shards_for_budget():
    assert state_sharding.shards_for_budget(100, 200, 64) == 1
    assert state_sharding.shards_for_budget(1000, 200, 64) == 8
    # Cannot shard below one bucket.
    assert state_sharding.shards_for_budget(1 << 20, 1, 4) == 4


# -------------------------------------------------------------- benchmark


def test_fig10_benchmark_smoke(capsys):
    from benchmarks import common, fig10_state_scaling

    common.ROWS.clear()
    fig10_state_scaling.main(
        ["--n-buckets", "256", "--b-round", "32", "--iters", "1"]
    )
    names = [r["name"] for r in common.ROWS]
    assert any(n.startswith("shard/m=") for n in names)
    assert any(n.startswith("equivalence/") for n in names)
    assert all(
        r["tps"] > 0 for r in common.ROWS if r.get("tps") is not None
    )
