"""Per-architecture smoke tests + substrate oracles.

Each assigned arch instantiates its REDUCED config, runs one forward and
one train step on CPU, asserts output shapes and finiteness; decode paths
are checked for exact consistency with the full forward."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base
from repro.models import layers, moe as moe_mod, ssm
from repro.models.lm import LM, Batch
from repro.training import train_step as ts_lib

RNG = np.random.default_rng(0)


def _mk_batch(cfg, b=2, s=16, with_labels=True):
    kw = {}
    if cfg.frontend == "vision":
        kw["prefix_embeds"] = jnp.asarray(
            RNG.normal(size=(b, cfg.n_prefix, cfg.d_model)), jnp.float32)
    if cfg.family == "encdec":
        kw["enc_embeds"] = jnp.asarray(
            RNG.normal(size=(b, 8, cfg.d_model)), jnp.float32)
    toks = jnp.asarray(RNG.integers(0, cfg.vocab, (b, s), dtype=np.int32))
    labels = (jnp.asarray(RNG.integers(0, cfg.vocab, (b, s),
                                       dtype=np.int32))
              if with_labels else None)
    return Batch(tokens=toks, labels=labels, **kw)


@pytest.mark.parametrize("arch", base.ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = base.get_smoke(arch)
    model = LM(cfg, vocab_chunk=8, moe_capacity_factor=4.0)
    b, s = 2, 16
    batch = _mk_batch(cfg, b, s)
    state = ts_lib.init_state(model, jax.random.PRNGKey(0))
    lg = model.logits(state.params, batch)
    s_total = s + (cfg.n_prefix if cfg.frontend == "vision" else 0)
    assert lg.shape == (b, s_total, cfg.vocab)
    assert np.isfinite(np.asarray(lg)).all()
    step = ts_lib.make_train_step(model, ts_lib.TrainConfig())
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state2.opt.step) == 1
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, l: a + float(jnp.abs(l.astype(jnp.float32)).sum()),
        jax.tree.map(lambda a, b_: a.astype(jnp.float32)
                     - b_.astype(jnp.float32), state.params, state2.params),
        0.0,
    )
    assert delta != 0.0


@pytest.mark.parametrize("arch", ["qwen2-7b", "qwen3-4b", "mamba2-2.7b",
                                  "zamba2-1.2b", "seamless-m4t-medium",
                                  "llava-next-34b"])
def test_prefill_decode_consistency(arch):
    cfg = base.get_smoke(arch)
    model = LM(cfg, vocab_chunk=8)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 12
    toks = RNG.integers(0, cfg.vocab, (b, s), dtype=np.int32)
    kw, enc_len, n_prefix = {}, 0, 0
    if cfg.frontend == "vision":
        n_prefix = cfg.n_prefix
        kw["prefix_embeds"] = jnp.asarray(
            RNG.normal(size=(b, n_prefix, cfg.d_model)), jnp.float32)
    if cfg.family == "encdec":
        enc_len = 8
        kw["enc_embeds"] = jnp.asarray(
            RNG.normal(size=(b, enc_len, cfg.d_model)), jnp.float32)
    full = model.logits(params, Batch(tokens=jnp.asarray(toks), **kw))
    cache = model.init_cache(b, s + n_prefix + 4, enc_len=enc_len)
    lg_pre, cache = model.prefill(
        params, Batch(tokens=jnp.asarray(toks[:, : s - 1]), **kw), cache)
    lg_dec, cache = model.decode_step(
        params, cache, jnp.asarray(toks[:, s - 1]),
        jnp.int32(s - 1 + n_prefix))
    np.testing.assert_allclose(np.asarray(lg_pre), np.asarray(full[:, -2]),
                               atol=2e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(lg_dec), np.asarray(full[:, -1]),
                               atol=2e-4, rtol=1e-4)


@pytest.mark.parametrize("qc,kc", [(16, 16), (32, 16), (16, 32), (8, 8)])
def test_attn_chunked_matches_naive(qc, kc):
    b, s, h, kv, d = 2, 64, 4, 2, 16
    q = jnp.asarray(RNG.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, s, kv, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, s, kv, d)), jnp.float32)
    for causal in (True, False):
        a = layers.attn_chunked(q, k, v, causal=causal, q_chunk=qc,
                                kv_chunk=kc)
        ref = layers.attn_naive(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(a), np.asarray(ref),
                                   atol=2e-5, rtol=1e-5)


def test_attn_grouped_matches_naive():
    b, s, h, kv, d = 2, 32, 8, 2, 16
    q = jnp.asarray(RNG.normal(size=(b, 1, h, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, s, kv, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, s, kv, d)), jnp.float32)
    a = layers.attn_grouped(q, k, v, causal=True, q_offset=s - 1)
    ref = layers.attn_naive(q, k, v, causal=True, q_offset=s - 1)
    np.testing.assert_allclose(np.asarray(a), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_ssd_chunked_matches_sequential(chunk):
    b, s, h, p, n = 2, 64, 3, 8, 16
    x = jnp.asarray(RNG.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(1e-3, 0.1, size=(b, s, h)), jnp.float32)
    a_neg = -jnp.asarray(RNG.uniform(0.5, 2.0, size=(h,)), jnp.float32)
    bm = jnp.asarray(RNG.normal(size=(b, s, n)), jnp.float32)
    cm = jnp.asarray(RNG.normal(size=(b, s, n)), jnp.float32)
    y1, s1 = ssm.ssd_chunked(x, dt, a_neg, bm, cm, chunk=chunk)
    y2, s2 = ssm.ssd_sequential_reference(x, dt, a_neg, bm, cm)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4,
                               rtol=1e-4)


def test_moe_dispatch_matches_dense_oracle():
    cfg = base.get_smoke("qwen2-moe-a2.7b")
    p = moe_mod.init_moe(jax.random.PRNGKey(1), cfg)
    x = jnp.asarray(RNG.normal(size=(2, 8, cfg.d_model)), jnp.float32)
    y1, aux1 = moe_mod.moe_mlp(p, cfg, x,
                               capacity_factor=float(cfg.n_experts))
    y2, aux2 = moe_mod.moe_mlp_dense_oracle(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
    assert float(aux1) == pytest.approx(float(aux2))


@pytest.mark.parametrize("cf", [0.5, 1.0, 2.0])
def test_moe_cumsum_dispatch_identical_to_sort(cf):
    """The sort-free dispatch (§Perf MoE iteration) must match the sorted
    baseline bit-for-bit, including which tokens drop at capacity."""
    cfg = base.get_smoke("qwen2-moe-a2.7b")
    p = moe_mod.init_moe(jax.random.PRNGKey(1), cfg)
    x = jnp.asarray(RNG.normal(size=(2, 16, cfg.d_model)), jnp.float32)
    y1, a1 = moe_mod.moe_mlp(p, cfg, x, capacity_factor=cf,
                             dispatch="sort")
    y2, a2 = moe_mod.moe_mlp(p, cfg, x, capacity_factor=cf,
                             dispatch="cumsum")
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    assert float(a1) == float(a2)


def test_moe_drop_does_not_clobber_slot_zero():
    """Regression: dropped assignments must not scatter zeros over the
    first occupant of an expert's buffer (mode=drop + OOB position)."""
    cfg = base.get_smoke("qwen2-moe-a2.7b")
    p = moe_mod.init_moe(jax.random.PRNGKey(1), cfg)
    x = jnp.asarray(RNG.normal(size=(1, 8, cfg.d_model)), jnp.float32)
    # cap=1 per expert: at most one token per expert survives, but that
    # token's output must match its dense-oracle contribution.
    y, _ = moe_mod.moe_mlp(p, cfg, x, capacity_factor=1e-9)
    assert np.isfinite(np.asarray(y)).all()
    # Kept-token outputs are nonzero wherever some assignment survived.
    assert float(jnp.abs(y).sum()) > 0


def test_moe_capacity_drops_are_passthrough():
    """With cap=1 most assignments drop; output must stay finite and the
    shared-expert path still contributes."""
    cfg = base.get_smoke("qwen2-moe-a2.7b")
    p = moe_mod.init_moe(jax.random.PRNGKey(1), cfg)
    x = jnp.asarray(RNG.normal(size=(2, 16, cfg.d_model)), jnp.float32)
    y, _ = moe_mod.moe_mlp(p, cfg, x, capacity_factor=0.01)
    assert np.isfinite(np.asarray(y)).all()


def test_rope_relative_shift_invariance():
    """RoPE attention scores depend only on relative positions."""
    d = 32
    q = jnp.asarray(RNG.normal(size=(1, 4, 1, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 4, 1, d)), jnp.float32)

    def scores(offset):
        pos = jnp.arange(4) + offset
        qr = layers.apply_rope(q, pos, 10_000.0)
        kr = layers.apply_rope(k, pos, 10_000.0)
        return jnp.einsum("bqhd,bkhd->bqk", qr, kr)

    np.testing.assert_allclose(np.asarray(scores(0)),
                               np.asarray(scores(1000)), atol=1e-3)


def test_param_count_matches_init():
    """cfg.n_params() must equal the actual initialized leaf count."""
    for arch in ("qwen2-7b", "mamba2-2.7b", "qwen2-moe-a2.7b",
                 "zamba2-1.2b", "seamless-m4t-medium"):
        cfg = base.get_smoke(arch)
        model = LM(cfg)
        params = model.init(jax.random.PRNGKey(0))
        actual = sum(int(np.prod(l.shape))
                     for l in jax.tree.leaves(params))
        # vocab padding inflates the tables; compare against padded count.
        expect = cfg.n_params() + (cfg.vocab_padded - cfg.vocab) * (
            cfg.d_model * (1 if cfg.tie_embeddings else 2))
        assert actual == expect, arch
