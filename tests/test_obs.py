"""Observability layer: histogram math, span tracing, engine metrics.

Percentile properties run under hypothesis when it is installed and fall
back to a seeded random sweep otherwise (same property, fixed seeds).
"""

import json
import os
import time

import numpy as np
import pytest

from repro.launch import state_sharding
from repro.obs import Obs
from repro.obs.metrics import (
    Histogram,
    NULL_REGISTRY,
    Registry,
)
from repro.obs.trace import NULL_TRACER, Tracer

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# Histogram percentiles vs numpy (property)
# ---------------------------------------------------------------------------


def _check_percentiles(samples):
    """The pinned bound: nearest-rank numpy percentile <= histogram
    percentile <= 2x (one log2 bucket ratio), for samples inside the
    histogram's finite range."""
    h = Histogram()
    for v in samples:
        h.record(v)
    for q in (50.0, 90.0, 95.0, 99.0):
        true = float(np.percentile(samples, q, method="inverted_cdf"))
        est = h.percentile(q)
        assert true <= est <= 2.0 * true, (q, true, est)


def _log_uniform(rng, n):
    # Strictly inside (lo, hi): the bound needs value > lo (bucket 0
    # reports lo itself) and value <= hi (overflow reports inf).
    return np.exp(rng.uniform(np.log(2e-7), np.log(5e2), size=n))


if HAVE_HYPOTHESIS:

    @settings(max_examples=50, deadline=None)
    @given(
        st.lists(
            st.floats(min_value=2e-7, max_value=5e2,
                      allow_nan=False, allow_infinity=False),
            min_size=1, max_size=300,
        )
    )
    def test_percentiles_within_bucket_ratio(samples):
        _check_percentiles(np.asarray(samples))

else:

    @pytest.mark.parametrize("seed", range(20))
    def test_percentiles_within_bucket_ratio(seed):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(1, 300))
        _check_percentiles(_log_uniform(rng, n))


def test_percentile_edges():
    h = Histogram()
    assert np.isnan(h.percentile(50))  # empty
    h.record(1e-9)  # below lo -> bucket 0, reported as lo
    assert h.percentile(50) == h.lo
    h2 = Histogram()
    h2.record(1e9)  # past hi -> overflow bucket, reported as inf
    assert h2.percentile(50) == float("inf")
    h3 = Histogram()
    h3.record(1.0)
    snap = h3.snapshot()
    assert snap["count"] == 1 and snap["sum"] == 1.0
    assert 1.0 <= snap["p50"] <= 2.0


def test_merge_is_exact():
    """Merged histogram == histogram of the pooled samples, bucket for
    bucket — the property that makes per-shard percentile merges exact."""
    rng = np.random.default_rng(7)
    a, b = _log_uniform(rng, 200), _log_uniform(rng, 133)
    ha, hb, hp = Histogram(), Histogram(), Histogram()
    for v in a:
        ha.record(v)
        hp.record(v)
    for v in b:
        hb.record(v)
        hp.record(v)
    ha.merge(hb)
    assert ha.counts == hp.counts
    assert ha.count == hp.count
    assert ha.percentile(95) == hp.percentile(95)
    with pytest.raises(ValueError):
        ha.merge(Histogram(lo=1e-6))  # different edges: not exact


def test_registry_semantics():
    reg = Registry()
    reg.counter("c").inc(3)
    reg.counter("c").inc()
    assert reg.collect()["c"] == 4
    with pytest.raises(ValueError):
        reg.counter("c").inc(-1)
    with pytest.raises(TypeError):
        reg.gauge("c")  # kind mismatch on the same name
    reg.gauge("g", shard=3).set(7)
    reg.gauge("g", shard=1).set(5)
    col = reg.collect()
    assert col["g{shard=3}"] == 7 and col["g{shard=1}"] == 5
    reg.histogram("h").record(0.5)
    text = reg.to_prometheus()
    assert "# TYPE c counter" in text
    assert 'g{shard="3"} 7' in text
    assert 'h_bucket{le="+Inf"} 1' in text
    # null registry absorbs everything and stays empty
    NULL_REGISTRY.counter("x").inc(10)
    assert NULL_REGISTRY.collect() == {}
    assert NULL_REGISTRY.to_prometheus() == ""


# ---------------------------------------------------------------------------
# Span tracer: nesting, ordering, export formats
# ---------------------------------------------------------------------------


def test_span_nesting_and_chrome_export(tmp_path):
    tr = Tracer()
    with tr.span("outer", kind="round"):
        with tr.span("inner_a"):
            time.sleep(0.002)
        tr.event("marker", n=3)
        with tr.span("inner_b"):
            time.sleep(0.001)
    recs = tr.records()
    by_name = {r["name"]: r for r in recs}
    assert by_name["outer"]["depth"] == 0
    assert by_name["outer"]["parent"] is None
    for child in ("inner_a", "inner_b", "marker"):
        assert by_name[child]["depth"] == 1
        assert by_name[child]["parent"] == "outer"
    # records() orders by start time: outer first, then children in order
    names = [r["name"] for r in recs]
    assert names == ["outer", "inner_a", "marker", "inner_b"]
    # children are contained in the parent's interval
    o = by_name["outer"]
    for child in ("inner_a", "inner_b"):
        c = by_name[child]
        assert o["ts"] <= c["ts"]
        assert c["ts"] + c["dur"] <= o["ts"] + o["dur"] + 1e-9
    # chrome export: spans are "X" complete events (us), events are "i"
    ev = {e["name"]: e for e in tr.chrome_events()}
    assert ev["outer"]["ph"] == "X"
    assert ev["outer"]["dur"] == pytest.approx(o["dur"] * 1e6)
    assert ev["marker"]["ph"] == "i"
    assert ev["marker"]["args"] == {"n": 3}
    # both dump formats round-trip as JSON
    jl, cj = tmp_path / "t.jsonl", tmp_path / "t.json"
    tr.dump_jsonl(str(jl))
    lines = [json.loads(x) for x in jl.read_text().splitlines()]
    assert [x["name"] for x in lines] == names
    tr.dump_chrome(str(cj))
    doc = json.loads(cj.read_text())
    assert len(doc["traceEvents"]) == 4


def test_span_sync_callable_and_set_sync():
    tr = Tracer()
    hit = []
    with tr.span("s", sync=lambda: hit.append("exit") or None):
        hit.append("body")
    assert hit == ["body", "exit"]  # sync resolved at exit, after the body
    with tr.span("s2") as sp:
        sp.set_sync(lambda: hit.append("late") or None)
    assert hit[-1] == "late"
    # an exception skips the sync but still pops/emits the span
    with pytest.raises(RuntimeError):
        with tr.span("s3", sync=lambda: hit.append("never")):
            raise RuntimeError("boom")
    assert "never" not in hit
    assert tr._stack() == []
    assert {r["name"] for r in tr.records()} == {"s", "s2", "s3"}


def test_null_tracer_never_syncs():
    hit = []
    with NULL_TRACER.span("x", sync=lambda: hit.append("sync")):
        pass
    assert hit == []  # obs-off must not add the span-edge device sync
    assert NULL_TRACER.records() == []


def test_obs_handle():
    off = Obs.disabled()
    assert not off.on
    on = Obs.enabled()
    assert on.on
    with on.tracer.span("a"):
        on.registry.counter("c").inc()
    assert on.registry.collect()["c"] == 1
    assert off.registry.collect() == {}


# ---------------------------------------------------------------------------
# Overflow bitmask lanes: >32 shards (the widened mask regression)
# ---------------------------------------------------------------------------


def test_overflow_bits_two_lanes():
    import jax.numpy as jnp

    for m, set_bits in ((40, [0, 5, 31, 32, 39]), (64, [33, 63])):
        flags = np.zeros(m, bool)
        flags[set_bits] = True
        lanes = np.asarray(state_sharding.overflow_bits(jnp.asarray(flags)))
        assert lanes.shape == (state_sharding.OVERFLOW_LANES,)
        assert lanes.dtype == np.uint32
        bits = state_sharding.bits_to_int(lanes)
        assert bits == sum(1 << b for b in set_bits)
        # bits above rank 31 live in lane 1, not truncated
        assert any(b >= 32 for b in set_bits) == (lanes[1] != 0)


def test_bits_int_lanes_roundtrip():
    rng = np.random.default_rng(11)
    for _ in range(50):
        bits = int(rng.integers(0, 1 << 63, dtype=np.uint64))
        lanes = state_sharding.int_to_lanes(bits)
        assert lanes.dtype == np.uint32
        assert state_sharding.bits_to_int(lanes) == bits


def test_overflow_bits_over_max_raises():
    import jax.numpy as jnp

    flags = jnp.zeros(state_sharding.MAX_OVERFLOW_SHARDS + 1, bool)
    with pytest.raises(ValueError):
        state_sharding.overflow_bits(flags)


def test_reanchor_head_binds_high_lane():
    """The re-anchor chain link must see shard bits past rank 31 — a
    lower-word-only fold would let two different overflow states share a
    head."""
    from repro.storage.journal import reanchor_head_update

    common = dict(
        prev_reanchor=np.zeros(2, np.uint32),
        prev_head=np.zeros(2, np.uint32),
        block_no=3, old_n_buckets=64, new_n_buckets=128, n_shards=40,
        tree_head=np.zeros(2, np.uint32),
    )
    lo = reanchor_head_update(overflow_bits=1 << 3, **common)
    hi = reanchor_head_update(overflow_bits=1 << 35, **common)
    none = reanchor_head_update(overflow_bits=0, **common)
    assert not np.array_equal(hi, none)  # high lane is bound
    assert not np.array_equal(hi, lo)
    # deterministic
    assert np.array_equal(hi, reanchor_head_update(
        overflow_bits=np.uint64(1 << 35), **common))


# ---------------------------------------------------------------------------
# Engine metrics: stability across snapshot / restore
# ---------------------------------------------------------------------------


def test_engine_metrics_stable_across_restore(tmp_path):
    """A restored engine starts a fresh registry: reloading the journal
    and snapshot must not replay appends/commits into the metrics (no
    double counting), and post-restore rounds count from zero."""
    from repro.core import engine as eng_mod
    from repro.core import types

    cfg = eng_mod.EngineConfig(
        dims=types.TEST_DIMS, n_buckets=1 << 12,
        snapshot_every_blocks=2,
        snapshot_dir=str(tmp_path / "snap"),
        journal_dir=str(tmp_path / "jrnl"),
        obs=True,
    )
    eng = eng_mod.FabricEngine(cfg)
    bs = cfg.orderer.block_size
    eng.run_round(eng.make_proposals(4 * bs, seed=1))
    m = eng.metrics()
    assert m["journal.appends"] == 4
    assert m["commit.latency"]["count"] == 4
    assert m["txs.valid"] == 4 * bs
    assert m["snapshot.saves"] == 1
    names = {r["name"] for r in eng.tracer.records()}
    assert {"round.order", "round.commit", "round.endorser_replay",
            "block.ship", "snapshot.take"} <= names
    eng.store.drain()
    eng.store.close()

    eng2 = eng_mod.FabricEngine.restore(cfg)
    m2 = eng2.metrics()
    assert m2.get("journal.appends", 0) == 0  # reload is not an append
    assert "commit.latency" not in m2
    eng2.run_round(eng2.make_proposals(2 * bs, seed=2))
    m3 = eng2.metrics()
    assert m3["journal.appends"] == 2
    assert m3["commit.latency"]["count"] == 2
    assert all(eng2.verify().values())


def test_engine_obs_off_is_empty():
    from repro.core import engine as eng_mod
    from repro.core import types

    eng = eng_mod.FabricEngine(eng_mod.EngineConfig(dims=types.TEST_DIMS))
    eng.run_round(eng.make_proposals(2 * eng.cfg.orderer.block_size))
    assert eng.metrics() == {}
    assert eng.tracer.records() == []


# ---------------------------------------------------------------------------
# benchmarks/perf_gate.py — the CI perf-trajectory gate's join semantics
# ---------------------------------------------------------------------------


def test_perf_gate_compare():
    from benchmarks.perf_gate import compare

    base = [
        {"bench": "fig11", "name": "pipe/d=8", "tps": 1000.0,
         "commit_scatters": 1, "commit_p95_ms": 2.0},
        {"bench": "fig12", "name": "elastic/final", "tps": 500.0,
         "overflow_ok": True},
        {"bench": "fig11", "name": "equivalence/d=8", "identical": True},
        {"bench": "fig4", "name": "O-I@512B", "tps": 200.0},
    ]
    # Self-compare: clean.
    failures, _ = compare(base, base)
    assert failures == []
    # Within-tolerance dip: note, not failure; improvements never fail.
    cur = [dict(r) for r in base]
    cur[0]["tps"] = 900.0
    cur[3]["tps"] = 400.0
    failures, notes = compare(base, cur)
    assert failures == []
    assert any("within tolerance" in n for n in notes)
    # Past-tolerance TPS regression fails.
    cur[0]["tps"] = 700.0
    failures, _ = compare(base, cur)
    assert any("pipe/d=8" in f and "regression" in f for f in failures)
    # Contract flip fails even with healthy TPS.
    cur[0]["tps"] = 1000.0
    cur[1]["overflow_ok"] = False
    cur[2]["identical"] = False
    failures, _ = compare(base, cur)
    assert any("overflow_ok flipped" in f for f in failures)
    assert any("identical flipped" in f for f in failures)
    # Missing contract row fails; missing plain row only notes.
    failures, notes = compare(base, [base[0], base[1], base[3]])
    assert any("equivalence/d=8" in f and "missing" in f for f in failures)
    failures, notes = compare(base, base[:3])
    assert failures == []
    assert any("O-I@512B" in n and "missing" in n for n in notes)
    # Latency drift >2x is reported, never gated.
    cur = [dict(r) for r in base]
    cur[1]["overflow_ok"] = True
    cur[0]["commit_p95_ms"] = 5.0
    failures, notes = compare(base, cur)
    assert failures == []
    assert any("commit_p95_ms" in n for n in notes)


def test_perf_gate_main(tmp_path):
    import json as _json

    from benchmarks.perf_gate import main

    rows = [{"bench": "fig11", "name": "pipe/d=8", "tps": 1000.0,
             "commit_scatters": 1}]
    bad = [{"bench": "fig11", "name": "pipe/d=8", "tps": 100.0,
            "commit_scatters": 1}]
    b, c = tmp_path / "base.json", tmp_path / "cur.json"
    b.write_text(_json.dumps(rows))
    c.write_text(_json.dumps(bad))
    assert main([str(b), str(b)]) == 0
    assert main([str(b), str(c)]) == 1
    assert main([str(b), str(c), "--tps-tolerance", "0.95"]) == 0


# ---------------------------------------------------------------------------
# PR 8: Ring / bounded tracer / exemplars / tx tracing / recorder / health
# ---------------------------------------------------------------------------


def test_ring_drop_oldest():
    from repro.obs.trace import Ring

    r = Ring(3)
    for i in range(7):
        r.push(i)
    assert r.items() == [4, 5, 6]  # newest kept, oldest dropped
    assert r.dropped == 4  # evictions counted exactly, never silent
    assert len(r) == 3
    r.clear()
    assert r.items() == [] and r.dropped == 0
    unbounded = Ring(None)
    for i in range(100):
        unbounded.push(i)
    assert len(unbounded) == 100 and unbounded.dropped == 0


def test_tracer_bounded_ring_and_drop_counter():
    from repro.obs.trace import NullTracer

    tr = Tracer(max_events=3)
    for i in range(5):
        tr.event(f"e{i}")
    recs = tr.records()
    assert [r["name"] for r in recs] == ["e2", "e3", "e4"]
    assert tr.dropped_events == 2
    # Obs.enabled(max_events=...) wires evictions to a registry counter.
    o = Obs.enabled(max_events=2)
    for i in range(5):
        o.tracer.event(f"x{i}")
    assert o.registry.collect()["trace.dropped_events"] == 3
    # Default enabled() keeps the unbounded complete trace (no counter).
    o2 = Obs.enabled()
    o2.tracer.event("y")
    assert "trace.dropped_events" not in o2.registry.collect()
    # NullTracer surface is unchanged: never syncs, never buffers.
    nt = NullTracer()
    assert nt.dropped_events == 0
    nt.add_sink(lambda rec: (_ for _ in ()).throw(AssertionError))
    nt.event("never")
    assert nt.records() == []


def test_recorder_sink_survives_tracer_eviction(tmp_path):
    """The flight recorder taps the tracer as a sink, so its window is
    independent of the tracer's own (possibly tighter) ring."""
    from repro.obs.recorder import FlightRecorder

    tr = Tracer(max_events=2)
    rec = FlightRecorder(capacity=64)
    rec.attach(tr)
    for i in range(6):
        tr.event(f"e{i}")
    assert len(tr.records()) == 2  # tracer ring is tight...
    names = [r["name"] for r in rec.spans.items()]
    assert names == [f"e{i}" for i in range(6)]  # ...recorder kept all


def test_histogram_exemplars_bounded_and_overflow_labeled():
    h = Histogram(max_exemplars=2)
    for i in range(5):
        h.record(0.5, exemplar={"tx_id": f"t{i}"})
    snap = h.exemplar_snapshot()
    (bucket,) = [k for k in snap if k != "overflow"]
    # Bounded per bucket: only the K most recent exemplars are retained.
    assert [e["tx_id"] for e in snap[bucket]] == ["t3", "t4"]
    # Clamp-bucket exemplars are labeled "overflow", not a bucket index.
    h.record(1e9, exemplar={"tx_id": "huge"})
    assert [e["tx_id"] for e in h.exemplar_snapshot()["overflow"]] == [
        "huge"]
    # exemplars_for(q) returns the payloads in the percentile's bucket.
    assert [e["tx_id"] for e in h.exemplars_for(50)] == ["t3", "t4"]
    assert "p99_exemplars" in h.snapshot()
    assert Histogram().exemplars_for(99) == []  # empty -> no exemplars


def test_txtrace_phase_accounting_and_outcomes():
    """Unit-level lifecycle: queue+order+validate+commit == e2e exactly,
    outcomes partition the round, lifecycles sample valid + invalid."""
    from repro.obs.txtrace import TxTracer

    reg = Registry()
    tt = TxTracer(reg, lifecycle_capacity=4)
    ids = np.arange(16, dtype=np.uint32).reshape(8, 2)
    rt = tt.begin_round(0, ids, 4, block_no0=10)
    rt.order_start()
    rt.ordered()
    rt.validated(0, 1)
    time.sleep(0.002)
    rt.validated(1, 2)
    rt.committed()
    valid = [np.array([True] * 4), np.array([True, False, True, True])]
    rt.finish(valid)
    m = reg.collect()
    for p in ("queue", "order", "validate", "commit"):
        assert m[f"tx.phase.{p}"]["count"] == 8  # weighted by block size
    s = sum(m[f"tx.phase.{p}"]["sum"]
            for p in ("queue", "order", "validate", "commit"))
    assert s == pytest.approx(m["tx.e2e"]["sum"], abs=1e-12)
    assert m["tx.outcome{outcome=valid}"] == 7
    assert m["tx.outcome{outcome=mvcc_conflict}"] == 1
    # Lifecycles: first tx per block + first invalid of block 1.
    lcs = tt.lifecycles.items()
    assert len(lcs) == 3
    assert {lc["outcome"] for lc in lcs} == {"valid", "mvcc_conflict"}
    assert {lc["block_no"] for lc in lcs} == {10, 11}
    assert all(len(lc["tx_id"]) == 16 for lc in lcs)
    # Overflow-tainted round: valid txs downgrade to overflow_dropped.
    rt2 = tt.begin_round(0, ids, 4, block_no0=12)
    rt2.order_start(); rt2.ordered(); rt2.committed()
    rt2.finish([np.ones(4, bool), np.ones(4, bool)],
               overflow_latched=True)
    m = reg.collect()
    assert m["tx.outcome{outcome=overflow_dropped}"] == 8
    assert m["tx.outcome{outcome=valid}"] == 7  # unchanged


def test_txtrace_null_is_inert():
    from repro.obs.txtrace import NULL_TXTRACER

    rt = NULL_TXTRACER.begin_round(0, None, 100, 0)
    rt.order_start(); rt.ordered(); rt.validated(0, 4); rt.committed()
    rt.finish(None)  # no registry, no sidecar, no stamps


def test_engine_tx_phase_decomposition():
    """Engine-level acceptance: per-tx phase histograms sum to e2e, the
    outcome counters match RoundStats, and the p99 commit bucket carries
    a concrete exemplar tx-id."""
    from repro.core import engine as eng_mod
    from repro.core import types

    eng = eng_mod.FabricEngine(
        eng_mod.EngineConfig(dims=types.TEST_DIMS, obs=True))
    bs = eng.cfg.orderer.block_size
    total = 0
    for seed in range(2):
        st = eng.run_round(eng.make_proposals(2 * bs, seed=seed))
        total += st.n_txs
    m = eng.metrics()
    for p in ("queue", "order", "validate", "commit"):
        assert m[f"tx.phase.{p}"]["count"] == total
    s = sum(m[f"tx.phase.{p}"]["sum"]
            for p in ("queue", "order", "validate", "commit"))
    assert s == pytest.approx(m["tx.e2e"]["sum"], rel=1e-9)
    valid = m.get("tx.outcome{outcome=valid}", 0)
    conflicts = m.get("tx.outcome{outcome=mvcc_conflict}", 0)
    assert valid == eng.total_valid
    assert valid + conflicts == eng.total_txs == total
    exemplars = m["tx.phase.commit"]["p99_exemplars"]
    assert exemplars and all(len(e["tx_id"]) == 16 for e in exemplars)
    assert len(eng.txtrace.lifecycles) >= 2
    eng.store.close()


def test_engine_obs_off_txtrace_inert():
    """Obs-off engines take the NullTxTracer path: no sidecar transfer,
    no lifecycle ring, empty registry — and health() still answers."""
    from repro.core import engine as eng_mod
    from repro.core import types
    from repro.obs.txtrace import NullTxTracer

    eng = eng_mod.FabricEngine(eng_mod.EngineConfig(dims=types.TEST_DIMS))
    assert isinstance(eng.txtrace, NullTxTracer)
    eng.run_round(eng.make_proposals(2 * eng.cfg.orderer.block_size))
    assert eng.metrics() == {}
    v = eng.health()
    assert v.status == "healthy"
    assert eng.metrics() == {}  # health() must not create gauges obs-off
    eng.store.close()


def test_recorder_auto_dump_on_verify_fault(tmp_path):
    """Fault-edge acceptance: tamper a journal record, verify() trips the
    flight recorder, and the auto-dump is a complete post-mortem (spans,
    metrics snapshot, >=1 full tx lifecycle, trip reason with the
    journal's failure reason)."""
    from repro.core import engine as eng_mod
    from repro.core import types

    dump = tmp_path / "dump"
    eng = eng_mod.FabricEngine(eng_mod.EngineConfig(
        dims=types.TEST_DIMS, obs=True,
        snapshot_every_blocks=4, prune_chain=False,
        snapshot_dir=str(tmp_path / "snap"),
        journal_dir=str(tmp_path / "jrnl"),
        recorder_dir=str(dump),
    ))
    bs = eng.cfg.orderer.block_size
    for seed in range(3):
        eng.run_round(eng.make_proposals(2 * bs, seed=seed))
    eng.store.drain()
    assert not eng.recorder.tripped
    # Tamper a record in the post-snapshot suffix (block 5 or 6): the
    # recovery path must re-authenticate it and fail.
    rec = eng.journal.records[-1]
    vals = rec.write_vals.copy()
    vals[0, 0, 0] ^= 1
    eng.journal.records[-1] = rec._replace(write_vals=vals)
    out = eng.verify()
    assert not all(out.values())
    assert eng.recorder.tripped
    trip = eng.recorder.trips[-1]
    assert trip["reason"] == "verify_contract"
    assert "recomputed head mismatch" in trip["ctx"]["journal_reason"]
    # The dump landed and is complete.
    for f in ("trace.jsonl", "trace_chrome.json", "metrics.json",
              "lifecycles.json", "meta.json"):
        assert (dump / f).exists(), f
    lcs = json.loads((dump / "lifecycles.json").read_text())
    assert len(lcs) >= 1
    assert all(
        {"tx_id", "phases", "outcome", "e2e"} <= set(lc) for lc in lcs)
    metrics = json.loads((dump / "metrics.json").read_text())
    assert metrics["latest"]["txs.valid"] == eng.total_valid
    assert len(metrics["periodic"]) >= 1  # per-round registry snapshots
    meta = json.loads((dump / "meta.json").read_text())
    assert meta["trips"][-1]["reason"] == "verify_contract"
    spans = [json.loads(x)
             for x in (dump / "trace.jsonl").read_text().splitlines()]
    assert any(r["name"] == "round.commit" for r in spans)
    assert any(
        r["name"] == "flightrec.trip.verify_contract" for r in spans)
    eng.store.close()


def test_recorder_trips_with_obs_off(tmp_path):
    """The recorder is ALWAYS on: an obs-off engine still records fault
    trips (notes + trip log + dump), just without span/metric content."""
    from repro.core import engine as eng_mod
    from repro.core import types

    dump = tmp_path / "dump"
    eng = eng_mod.FabricEngine(eng_mod.EngineConfig(
        dims=types.TEST_DIMS, snapshot_every_blocks=4, prune_chain=False,
        snapshot_dir=str(tmp_path / "snap"),
        journal_dir=str(tmp_path / "jrnl"),
        recorder_dir=str(dump),
    ))
    bs = eng.cfg.orderer.block_size
    for seed in range(3):
        eng.run_round(eng.make_proposals(2 * bs, seed=seed))
    eng.store.drain()
    rec = eng.journal.records[-1]
    vals = rec.write_vals.copy()
    vals[0, 0, 0] ^= 1
    eng.journal.records[-1] = rec._replace(write_vals=vals)
    assert not all(eng.verify().values())
    assert eng.recorder.tripped
    meta = json.loads((dump / "meta.json").read_text())
    assert meta["trips"][0]["reason"] == "verify_contract"
    assert eng.metrics() == {}  # still obs-off
    eng.store.close()


def test_engine_exception_fault_edge():
    from repro.core import engine as eng_mod
    from repro.core import types

    eng = eng_mod.FabricEngine(
        eng_mod.EngineConfig(dims=types.TEST_DIMS, obs=True))
    with pytest.raises(ValueError, match="multiple"):
        eng.run_round(eng.make_proposals(77))  # not a block multiple
    assert eng.recorder.tripped
    assert eng.recorder.trips[-1]["reason"] == "exception"
    assert "ValueError" in eng.recorder.trips[-1]["ctx"]["error"]
    eng.store.close()


def test_health_rollup_transitions():
    from repro.obs import CRITICAL, DEGRADED, HEALTHY, HealthRollup
    from repro.obs.health import SLOConfig

    slo = SLOConfig(commit_p95_s=0.1, min_validity_rate=0.9,
                    critical_validity_rate=0.5, max_occupancy=0.8,
                    window_rounds=4)
    hr = HealthRollup(slo, n_channels=2)
    for c in range(2):
        hr.push_round(c, n_txs=100, n_valid=100, wall_s=0.01, n_blocks=2)
    assert hr.evaluate().status == HEALTHY
    # Validity dips below the objective on channel 1 only.
    hr.push_round(1, n_txs=100, n_valid=70, wall_s=0.01, n_blocks=2)
    v = hr.evaluate()
    assert v.status == DEGRADED
    assert v.channels[0]["status"] == HEALTHY
    assert any("validity" in r for r in v.channels[1]["reasons"])
    # Sticky overflow: critical, with the per-shard reason.
    hr.set_overflow(1, 0b100)
    v = hr.evaluate()
    assert v.status == CRITICAL
    assert any("shard 2" in r and "overflow" in r
               for r in v.channels[1]["reasons"])
    hr.set_overflow(1, 0)
    # Latency over the window p95 objective.
    for _ in range(4):
        hr.push_round(0, n_txs=10, n_valid=10, wall_s=1.0, n_blocks=2)
    assert any("commit p95" in r for r in hr.evaluate().channels[0][
        "reasons"])
    # Occupancy headroom, per shard.
    hr.set_occupancy(0, [0.2, 0.95])
    assert any("shard 1" in r and "occupancy" in r
               for r in hr.evaluate().channels[0]["reasons"])


def test_engine_health_critical_on_overflow_healthy_when_elastic(
        tmp_path):
    """The fig12 scenario in miniature: a static undersized table latches
    overflow -> health() critical with a per-shard reason; the elastic
    twin repairs capacity and stays healthy."""
    import dataclasses as _dc

    from repro.core import engine as eng_mod
    from repro.core import types
    from repro.obs import SLOConfig

    base_cfg = eng_mod.EngineConfig(
        dims=types.TEST_DIMS, obs=True, n_buckets=8, slots=2,
        slo=SLOConfig(commit_p95_s=60.0),
    )
    static = eng_mod.FabricEngine(base_cfg)
    static.run_round(static.make_proposals(200, seed=0))
    assert static.overflowed()
    v = static.health()
    assert v.status == "critical"
    assert any("shard" in r and "overflow" in r for r in v.reasons)
    assert static.metrics()["health.status"] == 2
    assert static.recorder.tripped  # the latch is a fault edge
    assert any(t["reason"] == "overflow_latch"
               for t in static.recorder.trips)
    static.store.close()

    elastic = eng_mod.FabricEngine(_dc.replace(
        base_cfg, n_buckets=1 << 10, slots=8,
        resize_policy=eng_mod.ResizePolicy(
            grow_free_slots=2, grow_on_overflow=True),
    ))
    for seed in range(3):
        elastic.run_round(elastic.make_proposals(200, seed=seed))
    assert not elastic.overflowed()
    assert elastic.health().status == "healthy"
    assert elastic.metrics()["health.status"] == 0
    elastic.store.close()


def test_policy_pass_vectorized_multichannel():
    """Satellite: ONE policy pass covers every channel per round —
    resize.policy_checks counts channels, per-channel state.health /
    state.occupancy gauges come from the same pass, and resizes still
    fire per channel."""
    from repro.core import engine as eng_mod
    from repro.core import types

    eng = eng_mod.FabricEngine(eng_mod.EngineConfig(
        dims=types.TEST_DIMS, obs=True, n_channels=2, n_buckets=1 << 10,
        slots=8,
        resize_policy=eng_mod.ResizePolicy(grow_fill=0.04,
                                           max_buckets=1 << 14),
    ))
    bs = eng.cfg.orderer.block_size
    for r in range(2):
        eng.run_rounds([eng.make_proposals(2 * bs, seed=10 * r + c)
                        for c in range(2)])
    m = eng.metrics()
    assert m["resize.policy_checks"] == 4  # 2 channels x 2 rounds
    for c in range(2):
        assert f"state.health{{channel={c}}}" in m
        assert f"state.occupancy{{channel={c}}}" in m
    assert m.get("resize.grow", 0) >= 1  # the trigger still fires
    assert not eng.overflowed(0) and not eng.overflowed(1)
    eng.store.close()
