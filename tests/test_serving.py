"""Serving engine: continuous batching correctness + fabric bookkeeping."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base
from repro.models.lm import LM, Batch
from repro.serving.engine import Request, ServeEngine


@pytest.fixture(scope="module")
def setup():
    cfg = base.get_smoke("qwen2-7b")
    model = LM(cfg, vocab_chunk=8)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _reference_greedy(model, params, prompt, n_new):
    """Single-request greedy loop via the plain decode path."""
    cache = model.init_cache(1, len(prompt) + n_new + 1)
    logits, cache = model.prefill(
        params, Batch(tokens=jnp.asarray(prompt)[None]), cache)
    out = [int(jnp.argmax(logits[0]))]
    pos = len(prompt)
    for _ in range(n_new - 1):
        logits, cache = model.decode_step(
            params, cache, jnp.asarray([out[-1]], jnp.int32),
            jnp.int32(pos))
        out.append(int(jnp.argmax(logits[0])))
        pos += 1
    return out


def test_continuous_batching_matches_reference(setup):
    """Engine outputs (slot-batched, interleaved) == per-request greedy."""
    cfg, model, params = setup
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, 8).astype(np.int32)
               for _ in range(5)]
    reqs = [Request(rid=i, prompt=p, max_new=6)
            for i, p in enumerate(prompts)]
    eng = ServeEngine(model, params, slots=2, max_len=32)
    eng.run(reqs)
    for r in reqs:
        want = _reference_greedy(model, params, r.prompt, 6)
        assert r.out == want, f"req {r.rid}"


def test_slot_reuse_and_ledger(setup):
    cfg, model, params = setup
    rng = np.random.default_rng(4)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 4
                                               ).astype(np.int32),
                    max_new=3) for i in range(6)]
    eng = ServeEngine(model, params, slots=2, max_len=16)
    eng.run(reqs)
    assert all(r.done for r in reqs)
    # 6 requests through 2 slots -> slots reused; ledger has 2 commits per
    # request (assign + retire) => version 2, exactly-once semantics.
    for r in reqs:
        assert eng.request_version(r.rid) == 2
    assert eng.tokens_out == sum(len(r.out) - 1 for r in reqs)


def test_admission_order_deterministic(setup):
    cfg, model, params = setup
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab, 4).astype(np.int32)
               for _ in range(8)]

    def run_once():
        reqs = [Request(rid=i, prompt=prompts[i], max_new=2)
                for i in range(8)]
        eng = ServeEngine(model, params, slots=3, max_len=16)
        eng.submit(reqs)
        return [r.rid for r in eng.queue]

    assert run_once() == run_once()  # consensus order is deterministic


def test_serving_metrics_and_stats_text(setup):
    """Admission-queue depth gauge tracks submit/assign; stats_text is
    valid Prometheus exposition with the serving series present."""
    cfg, model, params = setup
    rng = np.random.default_rng(6)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 4
                                               ).astype(np.int32),
                    max_new=3) for i in range(5)]
    eng = ServeEngine(model, params, slots=2, max_len=16)
    eng.submit(reqs)
    m = eng.metrics()
    assert m["serving.queue.depth"] == 5
    assert m["serving.requests.submitted"] == 5
    eng.run([])  # drain (requests already queued)
    m = eng.metrics()
    assert m["serving.queue.depth"] == 0
    assert m["serving.requests.completed"] == 5
    assert m["serving.tokens.out"] == eng.tokens_out
    assert m["serving.decode.latency"]["count"] == eng.steps
    assert m["serving.decode.latency"]["p95"] > 0
    text = eng.stats_text()
    assert "# TYPE serving_queue_depth gauge" in text
    assert "serving_requests_completed 5" in text
    assert "serving_decode_latency_bucket" in text
    # every sample line is "name{labels} value" or "name value"
    for line in text.splitlines():
        if line.startswith("#") or not line:
            continue
        parts = line.rsplit(" ", 1)
        assert len(parts) == 2 and parts[1] != "", line
        float(parts[1])  # value parses


def test_serving_health_verdict(setup):
    """health() rolls decode p95 + queue depth into an SLO verdict and
    mirrors it on the serving.health gauge (same statuses as the peer
    engine's rollup)."""
    cfg, model, params = setup
    rng = np.random.default_rng(7)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 4
                                               ).astype(np.int32),
                    max_new=3) for i in range(3)]
    eng = ServeEngine(model, params, slots=2, max_len=16)
    eng.run(reqs)
    v = eng.health(decode_p95_s=60.0)  # compile-noise-proof objective
    assert v.status == "healthy" and v.reasons == []
    assert eng.metrics()["serving.health"] == 0
    # A tight latency objective degrades with the p95 in the reason.
    v = eng.health(decode_p95_s=1e-6)
    assert v.status == "degraded"
    assert any("decode p95" in r for r in v.reasons)
    assert eng.metrics()["serving.health"] == 1
    # A flooded admission queue is critical (past 2x the depth limit).
    eng.submit([Request(rid=100 + i,
                        prompt=rng.integers(0, cfg.vocab, 4
                                            ).astype(np.int32),
                        max_new=1) for i in range(5)])
    v = eng.health(decode_p95_s=60.0, max_queue_depth=2)
    assert v.status == "critical"
    assert any("queue depth" in r for r in v.reasons)
    assert eng.metrics()["serving.health"] == 2
