"""Training integration: learning, grad endorsement, checkpoint/restart."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import base
from repro.data import pipeline
from repro.models.lm import LM, Batch
from repro.training import optimizer, train_step as ts_lib


def _setup(arch="qwen2-7b", seq=32, batch=8, lr=1e-3, steps=60, mb=1,
           data_vocab=None):
    cfg = base.get_smoke(arch)
    model = LM(cfg, vocab_chunk=16, moe_capacity_factor=2.0)
    tcfg = ts_lib.TrainConfig(
        opt=optimizer.AdamWConfig(lr=lr, warmup_steps=5, total_steps=steps),
        microbatches=mb,
    )
    dcfg = pipeline.DataConfig(vocab=data_vocab or cfg.vocab, seq_len=seq,
                               global_batch=batch)
    step = jax.jit(ts_lib.make_train_step(model, tcfg), donate_argnums=(0,))
    return model, step, dcfg


def _batch(dcfg, step):
    b = pipeline.global_batch_for_step(dcfg, step)
    return jax.tree.map(lambda x: None if x is None else jnp.asarray(x), b,
                        is_leaf=lambda x: x is None)


def test_loss_decreases_on_affine_task():
    model, step, dcfg = _setup(steps=120, lr=3e-3, data_vocab=64)
    state = ts_lib.init_state(model, jax.random.PRNGKey(0))
    first = None
    for i in range(120):
        state, m = step(state, _batch(dcfg, i))
        if first is None:
            first = float(m["loss"])
    last = float(m["loss"])
    assert last < first - 1.0, (first, last)


def test_nan_microbatch_endorsement_skips_without_stall():
    """A poisoned microbatch (NaN tokens -> NaN grads analogue) must be
    flagged and excluded; the other microbatches still commit."""
    model, _, dcfg = _setup(mb=4)
    tcfg = ts_lib.TrainConfig(microbatches=4, endorse_grads=True)
    step = jax.jit(ts_lib.make_train_step(model, tcfg))
    state = ts_lib.init_state(model, jax.random.PRNGKey(0))
    batch = _batch(dcfg, 0)
    # Poison microbatch 0 via prefix embeds? Simplest: poison params copy
    # is global; instead poison one microbatch's labels to be valid but set
    # an embed row to inf so only sequences using that token blow up.
    # Deterministic poison: token 0 embedding = inf, microbatch 0 tokens=0.
    toks = np.asarray(batch.tokens).copy()
    toks = toks % 254 + 1  # keep the poisoned token id 0 out of all rows
    toks[0:2] = 0  # first microbatch (B=8, mb=4 -> 2 rows each)
    params = state.params
    poisoned = dict(params)
    poisoned["embed"] = params["embed"].at[0].set(jnp.inf)
    state = state._replace(params=poisoned)
    state2, m = step(state, ts_lib.Batch(
        tokens=jnp.asarray(toks), labels=batch.labels,
        prefix_embeds=None, enc_embeds=None,
    ))
    assert float(m["endorsed_mb"]) == 3.0  # one microbatch flagged
    assert int(m["skipped"]) == 0  # block still committed
    assert np.isfinite(float(m["loss"]))


def test_all_microbatches_bad_skips_commit():
    model, _, dcfg = _setup(mb=2)
    tcfg = ts_lib.TrainConfig(microbatches=2, endorse_grads=True)
    step = jax.jit(ts_lib.make_train_step(model, tcfg))
    state = ts_lib.init_state(model, jax.random.PRNGKey(0))
    batch = _batch(dcfg, 0)
    toks = np.zeros_like(np.asarray(batch.tokens))
    poisoned = dict(state.params)
    poisoned["embed"] = state.params["embed"].at[0].set(jnp.inf)
    state = state._replace(params=poisoned)
    m0 = jax.tree.map(lambda x: np.asarray(x), state.opt.m)
    state2, m = step(state, ts_lib.Batch(
        tokens=jnp.asarray(toks), labels=batch.labels,
        prefix_embeds=None, enc_embeds=None,
    ))
    assert int(m["skipped"]) == 1
    # Optimizer moments unchanged (commit skipped), step still advanced.
    for a, b in zip(jax.tree.leaves(m0), jax.tree.leaves(state2.opt.m)):
        np.testing.assert_array_equal(a, np.asarray(b))
    assert int(state2.opt.step) == 1


def test_checkpoint_restart_bit_exact(tmp_path):
    """Train 6 steps straight vs 3 + crash + restore + 3: identical state
    (the ledger/replay property from the paper applied to training)."""
    model, step, dcfg = _setup(steps=10)
    s_a = ts_lib.init_state(model, jax.random.PRNGKey(0))
    for i in range(6):
        s_a, _ = step(s_a, _batch(dcfg, i))

    ck = Checkpointer(str(tmp_path / "ck"))
    s_b = ts_lib.init_state(model, jax.random.PRNGKey(0))
    for i in range(3):
        s_b, _ = step(s_b, _batch(dcfg, i))
    ck.save(3, s_b, blocking=True)
    del s_b  # "crash"
    like = ts_lib.init_state(model, jax.random.PRNGKey(0))
    s_c, start = ck.restore(like)
    assert start == 3 and ck.verify_chain()
    for i in range(start, 6):
        s_c, _ = step(s_c, _batch(dcfg, i))
    for a, b in zip(jax.tree.leaves(s_a.params),
                    jax.tree.leaves(s_c.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(s_a.ledger_head),
                                  np.asarray(s_c.ledger_head))
    ck.close()


def test_checkpoint_corruption_detected(tmp_path):
    model, step, dcfg = _setup()
    state = ts_lib.init_state(model, jax.random.PRNGKey(0))
    ck = Checkpointer(str(tmp_path / "ck"))
    ck.save(1, state, blocking=True)
    # Corrupt the arrays file.
    path = tmp_path / "ck" / "step_00000001" / "arrays.npz"
    data = path.read_bytes()
    path.write_bytes(data[:-100] + bytes(100))
    with pytest.raises(Exception):
        ck.restore(state)
    ck.close()


def test_grad_accumulation_equivalence():
    """mb=2 accumulation == mb=1 on the same global batch (f32 accum,
    modulo bf16 rounding — smoke configs are f32 so exact-ish)."""
    model, _, dcfg = _setup()
    batch = _batch(dcfg, 0)
    s1 = ts_lib.init_state(model, jax.random.PRNGKey(0))
    s2 = ts_lib.init_state(model, jax.random.PRNGKey(0))
    step1 = jax.jit(ts_lib.make_train_step(
        model, ts_lib.TrainConfig(microbatches=1)))
    step2 = jax.jit(ts_lib.make_train_step(
        model, ts_lib.TrainConfig(microbatches=2)))
    s1, m1 = step1(s1, batch)
    s2, m2 = step2(s2, batch)
    # Losses match to accumulation rounding.
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-5, rtol=1e-4)
