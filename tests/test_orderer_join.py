"""Adversarial hash-join coverage: u32-prefix collision runs and misses.

Regression for the silent-wrong-row bug: ``argmax`` over an all-False hit
window used to select slot 0 and return an arbitrary store row, and a fixed
8-wide probe window could not reach a match behind a longer run of equal
``id[0]`` words (expected u32 birthday collisions at ~100k-tx rounds).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, hashing, orderer, types, unmarshal


def _join(queries, store):
    return orderer.hash_join(
        jnp.asarray(np.asarray(queries, np.uint32)),
        jnp.asarray(np.asarray(store, np.uint32)),
    )


def test_miss_is_reported_not_slot_zero():
    store = [[10, 1], [20, 2], [30, 3]]
    j = _join([[99, 99]], store)
    assert not bool(j.found[0])


def test_long_equal_hi_run_beyond_old_window():
    """>8 store ids share id[0]; every one of them must still be found."""
    n = 32
    store = np.stack(
        [np.full(n, 0xDEAD, np.uint32), np.arange(n, dtype=np.uint32)],
        axis=1,
    )
    j = _join(store, store)
    assert bool(np.asarray(j.found).all())
    np.testing.assert_array_equal(
        np.asarray(store)[np.asarray(j.idx)], store
    )
    # A missing pair inside the same run is a miss, not a neighbor's row.
    j2 = _join([[0xDEAD, n + 7]], store)
    assert not bool(j2.found[0])


def test_random_permutation_roundtrip():
    rng = np.random.default_rng(0)
    store = rng.integers(1, 1 << 32, (500, 2), dtype=np.uint32)
    perm = rng.permutation(500)
    j = _join(store[perm], store)
    assert bool(np.asarray(j.found).all())
    np.testing.assert_array_equal(np.asarray(j.idx), perm)
    absent = store.copy()
    absent[:, 1] ^= 0x80000000  # same hi words, different lo -> all misses
    j2 = _join(absent, store)
    assert not bool(np.asarray(j2.found).any())


@pytest.mark.parametrize("n", [1, 2, 7, 256])
def test_lex_searchsorted_matches_numpy_u64_oracle(n):
    rng = np.random.default_rng(n)
    s = rng.integers(0, max(n // 2, 2), size=(n, 2)).astype(np.uint32)
    order = np.lexsort((s[:, 1], s[:, 0]))
    sh, sl = s[order, 0], s[order, 1]
    q = rng.integers(0, max(n // 2, 2) + 3, size=(64, 2)).astype(np.uint32)
    got = np.asarray(
        hashing.lex_searchsorted(
            jnp.asarray(sh), jnp.asarray(sl),
            jnp.asarray(q[:, 0]), jnp.asarray(q[:, 1]),
        )
    )
    want = np.searchsorted(
        sh.astype(np.uint64) << 32 | sl,
        q[:, 0].astype(np.uint64) << 32 | q[:, 1],
        side="left",
    )
    np.testing.assert_array_equal(got, want)


def test_order_batch_poisons_unjoinable_rows(monkeypatch):
    """A reassembly miss surfaces as a checksum-invalid tx, never as a
    silently wrong payload in the block."""
    dims = types.TEST_DIMS
    eng = engine.FabricEngine(engine.EngineConfig(dims=dims,
                                                  store_blocks=False))
    props = eng.make_proposals(100, seed=0)
    from repro.core import endorser
    txb = endorser.execute_and_endorse(eng.endorser_state, props, dims)
    wire = unmarshal.marshal(txb, dims)
    cfg = orderer.OrdererConfig(block_size=50)

    blocks = orderer.order_batch(
        wire, txb.tx_id, txb.client, jnp.zeros((2,), jnp.uint32), cfg
    )
    assert bool(np.asarray(blocks.join_ok).all())
    assert bool(unmarshal.unmarshal(
        jnp.asarray(np.asarray(blocks.wire).reshape(100, -1)), dims
    ).checksum_ok.all())

    # Inject a local-store miss (an ordered ID whose payload never arrived
    # — unreachable through the API since IDs and payloads share a tensor,
    # so simulate the delivery failure at the join itself).
    real = orderer.hash_join

    def missing_17(query_ids, store_ids):
        j = real(query_ids, store_ids)
        drop = jnp.arange(query_ids.shape[0]) != 17
        return orderer.JoinResult(j.idx, j.found & drop)

    monkeypatch.setattr(orderer, "hash_join", missing_17)
    blocks2 = orderer.order_batch(
        wire, txb.tx_id, txb.client, jnp.zeros((2,), jnp.uint32), cfg
    )
    join_ok = np.asarray(blocks2.join_ok)
    assert join_ok.sum() == 99 and not join_ok[17]
    # The poisoned slot fails the syntactic checksum downstream — exactly
    # the missed slot, nothing else.
    dec = unmarshal.unmarshal(
        jnp.asarray(np.asarray(blocks2.wire).reshape(100, -1)), dims
    )
    np.testing.assert_array_equal(np.asarray(dec.checksum_ok), join_ok)
