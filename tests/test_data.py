"""Data pipeline: determinism, replay, sharding — the O-I ledger
properties applied to input data."""

import numpy as np

from repro.data import pipeline


CFG = pipeline.DataConfig(vocab=256, seq_len=32, global_batch=8,
                          dp_shards=4)


def test_step_determinism():
    a = pipeline.global_batch_for_step(CFG, 7, dp_rank=1)
    b = pipeline.global_batch_for_step(CFG, 7, dp_rank=1)
    np.testing.assert_array_equal(a.tokens, b.tokens)
    np.testing.assert_array_equal(a.labels, b.labels)


def test_shards_partition_global_batch():
    ids = pipeline.doc_ids_for_step(CFG, 3)
    shards = [pipeline.global_batch_for_step(CFG, 3, dp_rank=r).tokens
              for r in range(4)]
    full = np.concatenate(shards)
    direct = pipeline.tokens_for_ids(CFG, ids)[:, :-1].astype(np.int32)
    np.testing.assert_array_equal(full, direct)


def test_no_overlap_across_steps():
    i1 = set(pipeline.doc_ids_for_step(CFG, 1).tolist())
    i2 = set(pipeline.doc_ids_for_step(CFG, 2).tolist())
    assert not i1 & i2


def test_labels_are_shifted_inputs():
    b = pipeline.global_batch_for_step(CFG, 0)
    np.testing.assert_array_equal(b.tokens[:, 1:], b.labels[:, :-1])


def test_affine_structure():
    """Each row obeys token[t+1] = (m*token[t] + a) mod V for some (m,a)."""
    b = pipeline.global_batch_for_step(CFG, 5)
    toks = b.tokens.astype(np.int64)
    v = CFG.vocab
    for row in toks[:4]:
        # Solve (m, a) from the first two transitions, verify the rest.
        found = False
        for m in range(1, v, 2):
            a = (row[1] - m * row[0]) % v
            if (row[2] - (m * row[1] + a)) % v == 0:
                if np.all((row[1:] - (m * row[:-1] + a)) % v == 0):
                    found = True
                    break
        assert found


def test_elastic_reshard_same_global_stream():
    """Re-partitioning to a different dp count preserves the global batch
    (the rescale property: IDs move, payloads are regenerated)."""
    cfg2 = pipeline.DataConfig(vocab=256, seq_len=32, global_batch=8,
                               dp_shards=2)
    full4 = np.concatenate([
        pipeline.global_batch_for_step(CFG, 9, r).tokens for r in range(4)
    ])
    full2 = np.concatenate([
        pipeline.global_batch_for_step(cfg2, 9, r).tokens for r in range(2)
    ])
    np.testing.assert_array_equal(full4, full2)
