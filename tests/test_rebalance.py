"""Elastic sharded world state: overflow-driven resize with journal
re-anchoring and per-shard snapshot recovery.

The pins, mirroring the PR-2/PR-3 oracle discipline:

  * GROW is ARRAY-exact: a channel that splits mid-run ends byte-identical
    (state arrays, digest-tree head, ledger/journal heads, validity bits,
    store chain) to an oracle that ran the whole workload on the
    post-split layout from block 0 — at pipeline depths 1 and 4,
    replicated and sharded.
  * The butterfly neighbor-exchange resize inside shard_map equals the
    host-side ``world_state.resize`` of the merged table, shard by shard.
  * Journal re-anchor records make verify/replay cross resize epochs and
    survive spill + cold load; tampering with any re-anchor field breaks
    the chain.
  * Per-shard recovery rebuilds ONE bucket shard from 2^epochs snapshot
    parts (never the full table), array-exact, across grow re-anchors.
  * The engine's between-rounds policy absorbs a fill workload that
    overflows a static table, keeps every durability check green, and a
    peer that DID overflow, snapshotted and restarted still reports
    ``overflow_ok=False`` (the sticky bitmask is persisted).

Runs on whatever host devices exist; the >=2-rank cases need the CI
multi-device job (XLA_FLAGS=--xla_force_host_platform_device_count=8).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import endorser, engine, types, unmarshal
from repro.core import world_state as ws
from repro.launch import fabric_step as fs
from repro.launch import state_sharding
from repro.pipeline import engine_bridge
from repro.storage import journal as journal_mod
from repro.storage import recovery, snapshot

DIMS = types.TEST_DIMS
N_DEV = len(jax.devices())
MAX_M = 1 << (N_DEV.bit_length() - 1)  # largest power of two <= N_DEV

multi_device = pytest.mark.skipif(
    N_DEV < 2, reason="needs >=2 devices (CI multi-device job)"
)


def _filled(n_buckets=256, slots=8, blocks=4, seed=0):
    """A table populated by a block history, plus the history itself."""
    rng = np.random.default_rng(seed)
    st = ws.create(n_buckets, slots, DIMS.vw)
    history = []
    for _ in range(blocks):
        wk = jnp.asarray(
            rng.integers(1, 1 << 32, (16, DIMS.wk, 2), dtype=np.uint32))
        wv = jnp.asarray(
            rng.integers(0, 1 << 32, (16, DIMS.wk, DIMS.vw),
                         dtype=np.uint32))
        valid = jnp.asarray(rng.random(16) < 0.9)
        history.append((wk, wv, valid))
        r = ws.commit_vectorized(st, wk, wv, valid)
        assert not bool(r.overflow)
        st = r.state
    return st, history


# ------------------------------------------------------------ ws.resize


def test_resize_validates_bucket_count():
    st = ws.create(64, 4, DIMS.vw)
    with pytest.raises(ValueError, match="power of two"):
        ws.resize(st, 48)


def test_resize_grow_is_array_exact_vs_post_split_history():
    """Splitting mid-history == running the whole history on the big
    table from the start, byte for byte (the insertion-order compaction
    theorem in the resize docstring)."""
    st, history = _filled(blocks=6)
    small = ws.create(256, 8, DIMS.vw)
    for wk, wv, valid in history[:3]:
        small = ws.commit_vectorized(small, wk, wv, valid).state
    res = ws.resize(small, 512)
    assert not bool(res.overflow)
    grown = res.state
    for wk, wv, valid in history[3:]:
        grown = ws.commit_vectorized(grown, wk, wv, valid).state
    oracle = ws.create(512, 8, DIMS.vw)
    for wk, wv, valid in history:
        oracle = ws.commit_vectorized(oracle, wk, wv, valid).state
    for name, a, b in zip(ws.HashState._fields, grown, oracle):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b), err_msg=name)


def test_resize_shrink_content_exact_and_overflow_flag():
    st, history = _filled()
    keys = jnp.concatenate([h[0].reshape(-1, 2) for h in history])
    res = ws.resize(st, 128)
    assert not bool(res.overflow)
    before, after = ws.lookup(st, keys), ws.lookup(res.state, keys)
    np.testing.assert_array_equal(
        np.asarray(before.found), np.asarray(after.found))
    np.testing.assert_array_equal(
        np.asarray(before.versions), np.asarray(after.versions))
    np.testing.assert_array_equal(
        np.asarray(before.values), np.asarray(after.values))
    # Content digest is layout-invariant across the resize.
    np.testing.assert_array_equal(
        np.asarray(ws.state_digest(st)),
        np.asarray(ws.state_digest(res.state)))
    # Shrinking far below the live entry count must raise the flag.
    tiny = ws.resize(st, 4)
    assert bool(tiny.overflow)


def test_shard_pressure_stats():
    st, _ = _filled()
    occ = np.asarray(ws.shard_occupancy(st, 4))
    assert occ.sum() == int(ws.occupancy(st))
    free = np.asarray(ws.shard_min_free(st, 4))
    assert ((0 <= free) & (free <= st.slots)).all()


def test_resize_property_partition_bijection_and_lookups():
    """Satellite: halve/double of nb_loc is a partition bijection
    (shard_of/owned_mask cover every bucket exactly once before and
    after) and lookups of all pre-resize keys return identical
    (version, value) after the resize."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st_

    @settings(max_examples=20, deadline=None)
    @given(
        nb_pow=st_.integers(min_value=4, max_value=7),
        m_pow=st_.integers(min_value=0, max_value=3),
        grow=st_.booleans(),
        seed=st_.integers(min_value=0, max_value=2**16),
    )
    def prop(nb_pow, m_pow, grow, seed):
        nb, m = 1 << nb_pow, 1 << m_pow
        new_nb = nb * 2 if grow else max(nb // 2, m)
        # Partition bijection before AND after: synthesize one key per
        # global bucket; every bucket has exactly one owner shard and
        # each shard owns exactly nb/M contiguous buckets.
        for n in (nb, new_nb):
            bkeys = jnp.stack(
                [jnp.arange(n, dtype=jnp.uint32),
                 jnp.ones(n, jnp.uint32)], axis=-1)
            owners = np.asarray(ws.shard_of(n, m, bkeys))
            counts = np.bincount(owners, minlength=m)
            assert (counts == n // m).all()
            # Contiguous high-bit ranges: owner of bucket b is b//(n/m).
            np.testing.assert_array_equal(
                owners, np.arange(n) // (n // m))
        rng = np.random.default_rng(seed)
        st = ws.create(nb, 8, DIMS.vw)
        wk = jnp.asarray(
            rng.integers(1, 1 << 32, (12, DIMS.wk, 2), dtype=np.uint32))
        wv = jnp.asarray(
            rng.integers(0, 1 << 32, (12, DIMS.wk, DIMS.vw),
                         dtype=np.uint32))
        st = ws.commit_vectorized(st, wk, wv, jnp.ones(12, bool)).state
        res = ws.resize(st, new_nb)
        if bool(res.overflow):
            return  # dropped entries: lookup identity does not apply
        keys = wk.reshape(-1, 2)
        a, b = ws.lookup(st, keys), ws.lookup(res.state, keys)
        np.testing.assert_array_equal(
            np.asarray(a.versions), np.asarray(b.versions))
        np.testing.assert_array_equal(
            np.asarray(a.values), np.asarray(b.values))

    prop()


# ------------------------------------------ sharded butterfly exchange


def _mesh_resize(full, m, new_nb_loc, nb_glob):
    from jax.sharding import PartitionSpec as P

    mesh = jax.make_mesh((1, m), ("data", "model"))

    def body(keys, vers, vals):
        local = ws.HashState(keys, vers, vals)
        res = state_sharding.resize_sharded(local, new_nb_loc, nb_glob, m)
        return (res.state.keys, res.state.versions, res.state.values,
                res.shard_overflow.astype(jnp.uint32)[None])

    prog = fs._shard_map(
        body, mesh=mesh,
        in_specs=(P("model"), P("model"), P("model")),
        out_specs=(P("model"), P("model"), P("model"), P("model")),
        **fs._SHARD_MAP_NO_CHECK,
    )
    k, v, va, ovf = jax.jit(prog)(full.keys, full.versions, full.values)
    return ws.HashState(np.asarray(k), np.asarray(v), np.asarray(va)), ovf


@multi_device
@pytest.mark.parametrize("direction", ["grow", "shrink"])
def test_resize_sharded_equals_host_resize(direction):
    """The two-ppermute butterfly exchange rebuilds exactly the table the
    host-side resize of the merged arrays produces — per shard, array for
    array — and the post-resize digest tree equals a fresh tree of the
    rebuilt table."""
    m = min(MAX_M, 4)
    nb = 256
    full, _ = _filled(n_buckets=nb, seed=3)
    nb_loc = nb // m
    new_nb_loc = nb_loc * 2 if direction == "grow" else nb_loc // 2
    got, ovf = _mesh_resize(full, m, new_nb_loc, nb)
    want = ws.resize(full, new_nb_loc * m)
    assert not np.asarray(ovf).any() and not bool(want.overflow)
    for name, a, b in zip(ws.HashState._fields, got, want.state):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b), err_msg=name)
    # Tree head of the resized table == fresh tree of the rebuilt table.
    def tree(state):
        sk, sv, sva = ws.split_table(
            jnp.asarray(state.keys), jnp.asarray(state.versions),
            jnp.asarray(state.values), m)
        return np.asarray(ws.shard_digest_tree(jnp.stack([
            ws.state_digest(ws.HashState(sk[i], sv[i], sva[i]))
            for i in range(m)
        ])))

    np.testing.assert_array_equal(tree(got), tree(want.state))


def test_resize_sharded_rejects_non_step():
    st = ws.create(64, 4, DIMS.vw)
    with pytest.raises(ValueError, match="2x only"):
        state_sharding.resize_sharded(st, 64, 64, 1)


# ------------------------- acceptance: mid-run split == post-split oracle


def _windows(n_windows, depth, n=16, seed=0):
    eng = engine.FabricEngine(
        engine.EngineConfig(dims=DIMS, store_blocks=False))
    outs = []
    for w in range(n_windows):
        wires, idss = [], []
        for k in range(depth):
            props = eng.make_proposals(n, seed=seed + 31 * (w * depth + k))
            txb = endorser.execute_and_endorse(
                eng.endorser_state, props, DIMS)
            wires.append(unmarshal.marshal(txb, DIMS))
            idss.append(txb.tx_id)
            eng.endorser_state = endorser.apply_validated(
                eng.endorser_state, txb, jnp.ones(n, bool))
        outs.append((jnp.stack(wires), jnp.stack(idss)))
    return outs


def _split_mid_run(shard_state, depth, m):
    """Live: 2 windows at 128 buckets, split to 256, 2 windows. Oracle:
    all 4 windows on 256 from block 0. Everything must match."""
    mesh = jax.make_mesh((1, m), ("data", "model"))
    cfg = fs.FabricStepConfig(shard_state=shard_state, pipeline_depth=depth)
    wins = _windows(4, depth, seed=5)
    live = engine_bridge.MeshWindowCommitter(
        DIMS, cfg, mesh, n_buckets=128, slots=8)
    valid_live = []
    for w in range(2):
        valid_live.append(live.commit_window(*wins[w]).valid)
    info = live.resize(256)
    assert (info.old_n_buckets, info.new_n_buckets) == (128, 256)
    assert info.block_no == 2 * depth - 1  # the drained window boundary
    for w in range(2, 4):
        valid_live.append(live.commit_window(*wins[w]).valid)
    oracle = engine_bridge.MeshWindowCommitter(
        DIMS, cfg, mesh, n_buckets=256, slots=8)
    valid_oracle = [oracle.commit_window(*wins[w]).valid for w in range(4)]
    for a, b in zip(valid_live, valid_oracle):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for name, a, b in zip(fs.FabricMeshState._fields, live.state,
                          oracle.state):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b), err_msg=name)
    np.testing.assert_array_equal(live.tree_head(), oracle.tree_head())
    np.testing.assert_array_equal(
        np.asarray(live.prev_hash), np.asarray(oracle.prev_hash))


@pytest.mark.parametrize("depth", [1, 4])
def test_split_mid_run_equals_post_split_oracle_replicated(depth):
    _split_mid_run(False, depth, 1)


@pytest.mark.parametrize("depth", [1, 4])
def test_split_mid_run_equals_post_split_oracle_sharded_degenerate(depth):
    _split_mid_run(True, depth, 1)


@multi_device
@pytest.mark.parametrize("depth", [1, 4])
def test_split_mid_run_equals_post_split_oracle_sharded_multi_rank(depth):
    """Acceptance: the butterfly resize under a live pipeline, on real
    model ranks, at depth 1 and 4 — state arrays, digest tree head,
    ledger/journal heads and validity bits all byte-identical to the
    post-split-layout oracle."""
    _split_mid_run(True, depth, min(MAX_M, 4))


# --------------------------------------------- journal re-anchor records


def _journal_with_resize(seed=0):
    rng = np.random.default_rng(seed)
    j = journal_mod.StateJournal(DIMS)
    st = ws.create(256, 8, DIMS.vw)

    def block(b, st):
        wk = jnp.asarray(
            rng.integers(1, 1 << 30, (8, DIMS.wk, 2), dtype=np.uint32))
        wv = jnp.asarray(
            rng.integers(0, 1 << 30, (8, DIMS.wk, DIMS.vw),
                         dtype=np.uint32))
        valid = jnp.asarray(rng.random(8) < 0.8)
        j.append_writes(b, wk, wv, valid)
        return ws.commit_vectorized(st, wk, wv, valid).state

    def reanchor(st, new_nb, bno):
        st2 = ws.resize(st, new_nb).state
        sk, sv, sva = ws.split_table(st2.keys, st2.versions, st2.values, 4)
        tree = ws.shard_digest_tree(jnp.stack([
            ws.state_digest(ws.HashState(sk[i], sv[i], sva[i]))
            for i in range(4)
        ]))
        j.append_reanchor(bno, old_n_buckets=st.n_buckets,
                          new_n_buckets=new_nb, n_shards=4,
                          tree_head=np.asarray(tree))
        return st2

    for b in range(3):
        st = block(b, st)
    st = reanchor(st, 512, 2)
    for b in range(3, 5):
        st = block(b, st)
    return j, st


def test_journal_replay_and_verify_cross_reanchor():
    j, live = _journal_with_resize()
    assert j.verify_chain()
    rep = j.replay(ws.create(256, 8, DIMS.vw), check_reanchors=True)
    assert rep.overflow is False  # amply sized: no replayed drop
    replayed = rep.state
    for name, a, b in zip(ws.HashState._fields, replayed, live):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b), err_msg=name)


@pytest.mark.parametrize("field,value", [
    ("new_n_buckets", 1024),
    ("block_no", 1),
    ("overflow_bits", 1),
    ("tree_head", np.ones(2, np.uint32)),
])
def test_journal_reanchor_tamper_detected(field, value):
    j, _ = _journal_with_resize()
    j.reanchors[0] = j.reanchors[0]._replace(**{field: value})
    assert not j.verify_chain()


def test_journal_reanchor_requires_drained_tip():
    j, _ = _journal_with_resize()
    with pytest.raises(ValueError, match="tip"):
        j.append_reanchor(2, old_n_buckets=512, new_n_buckets=1024,
                          n_shards=4, tree_head=np.zeros(2, np.uint32))


def test_journal_reanchor_spill_load_and_prune(tmp_path):
    spill = tmp_path / "journal"
    spill.mkdir()
    rng = np.random.default_rng(4)
    j = journal_mod.StateJournal(DIMS, spill_dir=str(spill))
    st = ws.create(64, 8, DIMS.vw)
    for b in range(3):
        wk = jnp.asarray(
            rng.integers(1, 1 << 30, (4, DIMS.wk, 2), dtype=np.uint32))
        wv = jnp.asarray(
            rng.integers(0, 1 << 30, (4, DIMS.wk, DIMS.vw),
                         dtype=np.uint32))
        j.append_writes(b, wk, wv, jnp.ones(4, bool))
        st = ws.commit_vectorized(st, wk, wv, jnp.ones(4, bool)).state
        if b == 1:
            st = ws.resize(st, 128).state
            j.append_reanchor(1, old_n_buckets=64, new_n_buckets=128,
                              n_shards=1,
                              tree_head=np.asarray(ws.state_digest(st)),
                              overflow_bits=1)
    j2 = journal_mod.StateJournal.load(DIMS, str(spill))
    assert j2.verify_chain()
    assert len(j2.reanchors) == 1
    assert j2.reanchors[0].overflow_bits == 1
    np.testing.assert_array_equal(j2.reanchor_head, j.reanchor_head)
    replayed = j2.replay(ws.create(64, 8, DIMS.vw)).state
    np.testing.assert_array_equal(
        np.asarray(ws.state_digest(replayed)),
        np.asarray(ws.state_digest(st)))
    # Pruning drops covered re-anchors (and their spill files) with the
    # block records; the chains re-anchor at the stored bases.
    j2.prune_upto(1)
    assert not j2.reanchors
    assert j2.verify_chain()
    names = sorted(p.name for p in spill.iterdir())
    assert names == ["journal_00000002.npz"]
    j3 = journal_mod.StateJournal.load(DIMS, str(spill))
    assert [r.block_no for r in j3.records] == [2]


def test_journal_pre_genesis_reanchor_replayed_and_verified():
    """Regression: a resize BEFORE the first block (boundary -1) must be
    part of the from-genesis suffix — replayed, authenticated, and
    tamper-detected — not silently skipped (genesis is not a snapshot)."""
    rng = np.random.default_rng(13)
    j = journal_mod.StateJournal(DIMS)
    grown = ws.create(128, 8, DIMS.vw)
    j.append_reanchor(-1, old_n_buckets=64, new_n_buckets=128, n_shards=1,
                      tree_head=np.asarray(ws.tree_head(grown, 1)))
    wk = jnp.asarray(
        rng.integers(1, 1 << 30, (8, DIMS.wk, 2), dtype=np.uint32))
    wv = jnp.asarray(
        rng.integers(0, 1 << 30, (8, DIMS.wk, DIMS.vw), dtype=np.uint32))
    j.append_writes(0, wk, wv, jnp.ones(8, bool))
    live = ws.commit_vectorized(grown, wk, wv, jnp.ones(8, bool)).state
    assert j.verify_chain()
    rep = j.replay(ws.create(64, 8, DIMS.vw), check_reanchors=True)
    assert rep.state.n_buckets == 128
    for name, a, b in zip(ws.HashState._fields, rep.state, live):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b), err_msg=name)
    rec = recovery.recover(j, n_buckets=64, slots=8, value_width=DIMS.vw)
    assert rec.n_buckets == 128 and rec.crossed_reanchors == 1
    j.reanchors[0] = j.reanchors[0]._replace(new_n_buckets=256)
    assert not j.verify_chain()


def test_recovery_relatches_overflow_from_replayed_suffix():
    """Regression: overflow that strikes AFTER the last snapshot persisted
    its mask is re-derived by the suffix replay — the recovered peer must
    not report healthy while its replay reproduced a dropped insert."""
    rng = np.random.default_rng(17)
    j = journal_mod.StateJournal(DIMS)
    st = ws.create(8, 2, DIMS.vw)  # 16 slots: one block overflows it
    wk = jnp.asarray(
        rng.integers(1, 1 << 30, (16, DIMS.wk, 2), dtype=np.uint32))
    wv = jnp.asarray(
        rng.integers(0, 1 << 30, (16, DIMS.wk, DIMS.vw), dtype=np.uint32))
    j.append_writes(0, wk, wv, jnp.ones(16, bool))
    res = ws.commit_vectorized(st, wk, wv, jnp.ones(16, bool))
    assert bool(res.overflow)
    rec = recovery.recover(j, n_buckets=8, slots=2, value_width=DIMS.vw)
    assert rec.overflow_bits != 0


# ----------------------------------------------- per-shard recovery


def test_recover_shard_across_grow_reanchor(tmp_path):
    """Acceptance: per-shard snapshot + journal suffix across a re-anchor
    reproduces the live shard WITHOUT materializing the full table — a
    shard rebuilds from 2^epochs parts of the M on disk."""
    m = 8
    rng = np.random.default_rng(9)
    j = journal_mod.StateJournal(DIMS)
    st = ws.create(256, 8, DIMS.vw)

    def block(b, st):
        wk = jnp.asarray(
            rng.integers(1, 1 << 30, (8, DIMS.wk, 2), dtype=np.uint32))
        wv = jnp.asarray(
            rng.integers(0, 1 << 30, (8, DIMS.wk, DIMS.vw),
                         dtype=np.uint32))
        valid = jnp.asarray(rng.random(8) < 0.8)
        j.append_writes(b, wk, wv, valid)
        return ws.commit_vectorized(st, wk, wv, valid).state

    for b in range(2):
        st = block(b, st)
    snap = snapshot.take(
        st, block_no=1, journal_head=j.head,
        ledger_head=np.zeros(2, np.uint32), n_shards=m,
        reanchor_head=j.reanchor_head,
    )
    snapshot.save(str(tmp_path), snap)
    for b in (2, 3):
        st = block(b, st)
    st2 = ws.resize(st, 512).state
    sk, sv, sva = ws.split_table(st2.keys, st2.versions, st2.values, m)
    tree = ws.shard_digest_tree(jnp.stack([
        ws.state_digest(ws.HashState(sk[i], sv[i], sva[i]))
        for i in range(m)
    ]))
    j.append_reanchor(3, old_n_buckets=256, new_n_buckets=512, n_shards=m,
                      tree_head=np.asarray(tree))
    st = st2
    for b in (4, 5):
        st = block(b, st)

    sk, sv, sva = ws.split_table(st.keys, st.versions, st.values, m)
    for shard in range(m):
        res = recovery.recover_shard(
            j, snapshot_dir=str(tmp_path), shard=shard)
        assert res.loaded_parts == 2  # one grow epoch: 2 of 8 parts
        assert res.crossed_reanchors == 1 and res.block_no == 5
        np.testing.assert_array_equal(
            np.asarray(res.state.keys), np.asarray(sk[shard]))
        np.testing.assert_array_equal(
            np.asarray(res.state.versions), np.asarray(sv[shard]))
        np.testing.assert_array_equal(
            np.asarray(res.state.values), np.asarray(sva[shard]))
        np.testing.assert_array_equal(res.journal_head, j.head)


def test_recover_shard_across_shrink_reanchor(tmp_path):
    """Per-shard recovery across a SHRINK epoch: the post-shrink shard's
    preimage is TWO sibling ranges of the pre-shrink table; recovery
    loads both parts, folds them exactly like the full-table halve
    (low fragment first — the flat rehash order), and replays the
    suffix byte-identically, lossy drops included."""
    m = 8
    rng = np.random.default_rng(13)
    j = journal_mod.StateJournal(DIMS)
    st = ws.create(512, 8, DIMS.vw)

    def block(b, st):
        wk = jnp.asarray(
            rng.integers(1, 1 << 30, (8, DIMS.wk, 2), dtype=np.uint32))
        wv = jnp.asarray(
            rng.integers(0, 1 << 30, (8, DIMS.wk, DIMS.vw),
                         dtype=np.uint32))
        valid = jnp.asarray(rng.random(8) < 0.8)
        j.append_writes(b, wk, wv, valid)
        return ws.commit_vectorized(st, wk, wv, valid).state

    for b in range(2):
        st = block(b, st)
    snap = snapshot.take(
        st, block_no=1, journal_head=j.head,
        ledger_head=np.zeros(2, np.uint32), n_shards=m,
        reanchor_head=j.reanchor_head,
    )
    snapshot.save(str(tmp_path), snap)
    for b in (2, 3):
        st = block(b, st)
    st2 = ws.resize(st, 256).state  # SHRINK: 512 -> 256
    sk, sv, sva = ws.split_table(st2.keys, st2.versions, st2.values, m)
    tree = ws.shard_digest_tree(jnp.stack([
        ws.state_digest(ws.HashState(sk[i], sv[i], sva[i]))
        for i in range(m)
    ]))
    j.append_reanchor(3, old_n_buckets=512, new_n_buckets=256, n_shards=m,
                      tree_head=np.asarray(tree))
    st = st2
    for b in (4, 5):
        st = block(b, st)

    sk, sv, sva = ws.split_table(st.keys, st.versions, st.values, m)
    for shard in range(m):
        res = recovery.recover_shard(
            j, snapshot_dir=str(tmp_path), shard=shard)
        assert res.loaded_parts == 2  # one shrink epoch: the 2 siblings
        assert res.crossed_reanchors == 1 and res.block_no == 5
        np.testing.assert_array_equal(
            np.asarray(res.state.keys), np.asarray(sk[shard]))
        np.testing.assert_array_equal(
            np.asarray(res.state.versions), np.asarray(sv[shard]))
        np.testing.assert_array_equal(
            np.asarray(res.state.values), np.asarray(sva[shard]))
        np.testing.assert_array_equal(res.journal_head, j.head)


def test_recover_shard_refuses_inconsistent_reanchor_epochs(tmp_path):
    """A re-anchor whose old_n_buckets contradicts the epoch it follows
    (rewritten history) must be refused, not silently recovered."""
    j, _ = _journal_with_resize(seed=11)
    snapshot.save(str(tmp_path), snapshot.take(
        ws.create(256, 8, DIMS.vw), block_no=-1,
        journal_head=journal_mod.GENESIS_HEAD,
        ledger_head=np.zeros(2, np.uint32), n_shards=4,
    ))
    forged = journal_mod.StateJournal(DIMS)
    forged.records = j.records
    forged.reanchors = [
        j.reanchors[0]._replace(old_n_buckets=512, new_n_buckets=256)
    ]
    with pytest.raises(recovery.RecoveryError):
        recovery.recover_shard(forged, snapshot_dir=str(tmp_path), shard=0)


# ------------------------------------------------- engine policy + restart


def _engine_cfg(**kw):
    return engine.EngineConfig(
        dims=DIMS,
        orderer=dataclasses.replace(
            engine.FASTFABRIC.orderer, block_size=50),
        **kw,
    )


def test_engine_policy_absorbs_fill_that_overflows_static():
    """Acceptance (engine layer): the same fill workload overflows the
    static peer but the elastic peer splits ahead of pressure, stays
    healthy, and every durability check — including chain replay ACROSS
    the re-anchors — holds."""
    static = engine.FabricEngine(_engine_cfg(n_buckets=128, slots=8))
    elastic = engine.FabricEngine(_engine_cfg(
        n_buckets=128, slots=8,
        resize_policy=engine.ResizePolicy(grow_free_slots=4),
    ))
    for i in range(10):
        static.run_round(static.make_proposals(50, seed=i))
        elastic.run_round(elastic.make_proposals(50, seed=i))
    assert static.verify()["overflow_ok"] is False
    out = elastic.verify()
    assert all(out.values()), out
    assert elastic.n_buckets > 128
    assert len(elastic.reanchor_log) == len(elastic.journal.reanchors) \
        if elastic.journal else True
    static.store.close()
    elastic.store.close()


def test_engine_manual_resize_shrink_and_verify():
    eng = engine.FabricEngine(_engine_cfg(n_buckets=1 << 10))
    eng.run_round(eng.make_proposals(100, seed=0))
    eng.resize(1 << 11)
    eng.run_round(eng.make_proposals(100, seed=1))
    eng.resize(1 << 10)  # shrink back: still plenty of room
    # Second resize at the SAME boundary: verify()'s chain replay must
    # apply both steps in order, not their net composition.
    eng.resize(1 << 11)
    eng.run_round(eng.make_proposals(100, seed=2))
    out = eng.verify()
    assert all(out.values()), out
    assert eng.n_buckets == 1 << 11
    assert [r["new_n_buckets"] for r in eng.reanchor_log] == [
        2048, 1024, 2048]
    assert eng.reanchor_log[0]["block_no"] == eng.reanchor_log[1][
        "block_no"] - 2  # two resizes share the later boundary
    assert all("hot_shard" in r for r in eng.reanchor_log)
    eng.store.close()


def test_engine_restart_keeps_sticky_overflow(tmp_path):
    """Satellite: overflow -> snapshot -> restart must still report
    overflow_ok=False (the flag rides the snapshot manifest + re-anchor
    records instead of host memory)."""
    cfg = _engine_cfg(
        n_buckets=8, slots=2, snapshot_every_blocks=3,
        snapshot_dir=str(tmp_path / "snap"),
        journal_dir=str(tmp_path / "jrnl"),
        resize_policy=engine.ResizePolicy(
            grow_free_slots=0, grow_on_overflow=True),
    )
    eng = engine.FabricEngine(cfg)
    eng.run_round(eng.make_proposals(150, seed=0))
    assert eng.verify()["overflow_ok"] is False
    nb_repaired = eng.n_buckets
    assert nb_repaired == 16  # one overflow-triggered repair, not per-round
    eng.run_round(eng.make_proposals(150, seed=5))
    eng.run_round(eng.make_proposals(150, seed=6))
    assert eng.n_buckets == nb_repaired  # the sticky flag fires ONCE
    man = snapshot.latest_manifest(str(tmp_path / "snap"))
    assert man.overflow is True  # persisted, not host memory
    bits = man.overflow_bits
    eng.store.drain()
    eng.store.close()

    restored = engine.FabricEngine.restore(cfg)
    out = restored.verify()
    assert out["overflow_ok"] is False
    assert out["recovery_ok"] and out["replica_ok"]
    # The persisted mask keeps its which-shard bits across the restart,
    # and the restored flag counts as already repaired: restarting an
    # overflowed peer must NOT double the table once per boot.
    assert restored.overflow_bits() == bits
    nb = restored.n_buckets
    restored.run_round(restored.make_proposals(150, seed=1))
    assert restored.n_buckets == nb
    restored.store.drain()
    restored.store.close()


def test_engine_restart_resumes_post_resize_layout(tmp_path):
    cfg = _engine_cfg(
        n_buckets=128, slots=8, snapshot_every_blocks=3,
        snapshot_dir=str(tmp_path / "snap"),
        journal_dir=str(tmp_path / "jrnl"),
        resize_policy=engine.ResizePolicy(grow_free_slots=4),
    )
    eng = engine.FabricEngine(cfg)
    for i in range(6):
        eng.run_round(eng.make_proposals(50, seed=i))
    assert eng.n_buckets > 128
    nb, digest = eng.n_buckets, eng._peer_digest()
    bno = eng._next_block_no
    eng.store.drain()
    eng.store.close()
    restored = engine.FabricEngine.restore(cfg)
    assert restored.n_buckets == nb
    assert restored._next_block_no == bno
    np.testing.assert_array_equal(restored._peer_digest(), digest)
    assert all(restored.verify().values())
    restored.store.close()


def test_engine_window_committer_snapshots_and_recovers(tmp_path):
    """The window-committer engine now supports the durability layer: the
    manifest covers the mesh-backed state (per-shard for sharded configs)
    and recovery reproduces the committer's digest + journal head."""
    wc = engine_bridge.MeshWindowCommitter(
        DIMS, fs.FabricStepConfig(pipeline_depth=4), n_buckets=1 << 10)
    eng = engine.FabricEngine(
        _engine_cfg(
            n_buckets=1 << 10, snapshot_every_blocks=3,
            snapshot_dir=str(tmp_path), journal_dir=str(tmp_path / "j"),
        ),
        window_committer=wc,
    )
    for i in range(2):
        eng.run_round(eng.make_proposals(200, seed=i))
    out = eng.verify()
    assert all(out.values()), out
    assert eng.snapshots
    rec = eng.recover()
    np.testing.assert_array_equal(rec.state_digest, wc.state_digest())
    np.testing.assert_array_equal(rec.journal_head, wc.journal_head)
    eng.store.close()


def test_engine_policy_resizes_through_window_committer():
    wc = engine_bridge.MeshWindowCommitter(
        DIMS, fs.FabricStepConfig(pipeline_depth=4), n_buckets=128)
    eng = engine.FabricEngine(
        _engine_cfg(
            n_buckets=128,
            resize_policy=engine.ResizePolicy(grow_free_slots=4),
        ),
        window_committer=wc,
    )
    for i in range(8):
        eng.run_round(eng.make_proposals(50, seed=i))
    out = eng.verify()
    assert all(out.values()), out
    assert wc.n_buckets > 128 and eng.n_buckets == wc.n_buckets
    assert eng.reanchor_log
    eng.store.close()


# -------------------------------------------------------------- benchmark


def test_fig12_benchmark_smoke(capsys):
    from benchmarks import common, fig12_rebalance

    common.ROWS.clear()
    fig12_rebalance.main(
        ["--rounds", "6", "--round-txs", "30", "--n-buckets", "64",
         "--slots", "8", "--n-shards", "2", "--grow-free-slots", "4"]
    )
    by = {r["name"]: r for r in common.ROWS}
    assert by["elastic/final"]["n_resizes"] >= 1
    assert by["elastic/final"]["overflow_ok"]
    assert by["equivalence/elastic"]["identical"]
    assert any(n.startswith("recovery/shard=") for n in by)
