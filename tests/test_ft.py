"""Fault tolerance: failure detection, elastic membership, stragglers."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.ft.membership import (ElasticPlan, HeartbeatMonitor,
                                 StragglerPolicy, rendezvous_assign)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_heartbeat_detects_silence():
    clk = FakeClock()
    mon = HeartbeatMonitor(range(4), timeout_s=10, clock=clk)
    clk.t = 5
    mon.beat(0)
    mon.beat(1)
    mon.beat(2)  # worker 3 silent
    clk.t = 12
    assert mon.check() == {3}
    assert mon.live == [0, 1, 2]
    # Dead workers' late beats are ignored until rejoin.
    mon.beat(3)
    clk.t = 30
    assert 3 not in mon.live
    mon.rejoin(3)
    assert 3 in mon.live


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 16), st.integers(8, 64))
def test_rendezvous_minimal_churn(n_workers, n_shards):
    """Removing one worker moves ONLY that worker's shards (HRW)."""
    workers = list(range(n_workers))
    before = rendezvous_assign(range(n_shards), workers)
    after = rendezvous_assign(range(n_shards), workers[:-1])
    for s in range(n_shards):
        if before[s] != workers[-1]:
            assert after[s] == before[s]


def test_rendezvous_deterministic_and_balanced():
    a = rendezvous_assign(range(256), range(8))
    b = rendezvous_assign(range(256), range(8))
    assert a == b
    counts = np.bincount(list(a.values()), minlength=8)
    assert counts.min() > 0  # every worker gets work


def test_straggler_policy():
    pol = StragglerPolicy(beta=2.0, window=8)
    for _ in range(8):
        pol.observe(1.0)
    assert not pol.should_backup(1.5)
    assert pol.should_backup(2.5)
    # Window rolls: a regime change updates the median.
    for _ in range(8):
        pol.observe(4.0)
    assert not pol.should_backup(6.0)


def test_elastic_plan():
    clk = FakeClock()
    mon = HeartbeatMonitor(range(4), timeout_s=10, clock=clk)
    clk.t = 20
    mon.beat(0)
    clk.t = 25
    mon.check()
    plan = ElasticPlan.make(mon, n_shards=16, resume_step=42)
    assert plan.survivors == [0]
    assert set(plan.assignment.values()) == {0}
    assert plan.resume_step == 42


def test_rendezvous_no_workers_raises():
    with pytest.raises(ValueError):
        rendezvous_assign(range(4), [])
