"""Distributed fabric step (degenerate 1x1 mesh): semantics must match the
single-host engine. The multi-device sharding itself is proven by the
production-mesh dry-run (launch/dryrun.py --fabric)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import endorser, engine, types, unmarshal
from repro.core import world_state as ws
from repro.launch import fabric_step as fs

DIMS = types.TEST_DIMS


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def _round(n=32, seed=0):
    eng = engine.FabricEngine(engine.EngineConfig(dims=DIMS,
                                                  store_blocks=False))
    props = eng.make_proposals(n, seed=seed)
    txb = endorser.execute_and_endorse(eng.endorser_state, props, DIMS)
    wire = unmarshal.marshal(txb, DIMS)
    return wire[None], txb.tx_id[None]  # (C=1, B, ...)


def test_configs_agree_on_state(mesh):
    wire, ids = _round()
    digests = []
    for cfg in (fs.FASTFABRIC_STEP, fs.FABRIC_V12_STEP):
        state = fs.create_mesh_state(1, DIMS, n_buckets=256)
        step = jax.jit(fs.make_fabric_step(DIMS, cfg, mesh))
        st2, valid = step(state, wire, ids)
        assert int(np.asarray(valid).sum()) == 32
        digests.append(np.asarray(ws.state_digest(
            ws.HashState(st2.keys[0], st2.versions[0], st2.values[0]))))
    np.testing.assert_array_equal(digests[0], digests[1])


def test_matches_single_host_committer(mesh):
    """Mesh-step world state == engine commit of the same ordered round."""
    wire, ids = _round(seed=1)
    state = fs.create_mesh_state(1, DIMS, n_buckets=256)
    step = jax.jit(fs.make_fabric_step(DIMS, fs.FASTFABRIC_STEP, mesh))
    st2, valid = step(state, wire, ids)

    from repro.core import committer, orderer
    order = orderer.consensus_order(ids[0])
    pstate = committer.create_peer_state(DIMS, n_buckets=256)
    res = committer.commit_block(pstate, wire[0][order], DIMS,
                                 committer.FASTFABRIC_PEER)
    d_mesh = np.asarray(ws.state_digest(
        ws.HashState(st2.keys[0], st2.versions[0], st2.values[0])))
    d_eng = np.asarray(ws.state_digest(res.state.hash_state))
    np.testing.assert_array_equal(d_mesh, d_eng)
    assert int(np.asarray(valid).sum()) == int(res.valid.sum())


def test_corrupt_payload_flagged(mesh):
    wire, ids = _round(seed=2)
    wire_np = np.asarray(wire).copy()
    wire_np[0, 5, 60] ^= 0xFF  # flip a byte in tx 5's opaque body
    state = fs.create_mesh_state(1, DIMS, n_buckets=256)
    step = jax.jit(fs.make_fabric_step(DIMS, fs.FASTFABRIC_STEP, mesh))
    _, valid = step(state, jnp.asarray(wire_np), ids)
    assert int(np.asarray(valid).sum()) == 31  # exactly the corrupt tx


def test_replay_round_invalidated(mesh):
    wire, ids = _round(seed=3)
    state = fs.create_mesh_state(1, DIMS, n_buckets=256)
    step = jax.jit(fs.make_fabric_step(DIMS, fs.FASTFABRIC_STEP, mesh))
    st1, v1 = step(state, wire, ids)
    st2, v2 = step(st1, wire, ids)  # identical round replayed
    assert int(np.asarray(v1).sum()) == 32
    assert int(np.asarray(v2).sum()) == 0  # stale versions everywhere
