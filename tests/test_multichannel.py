"""Multi-channel scale-out: N independent channels vmapped over the
`data` axis, per-channel journals/snapshots/resize epochs, ONE
BlockStore writer multiplexing every channel's chain.

The pins, extending the PR-2..PR-5 oracle discipline across channels:

  * An N-channel committer run (N >= 2, sharded over >= 2 `data` ranks,
    pipeline depth >= 2, with a mid-run resize on ONE channel) is
    byte-identical PER CHANNEL to N single-channel oracle runs — state
    arrays, ledger/journal heads, validity bits, digest-tree heads and
    sticky overflow bitmasks all match, and the resized channel's
    epoch never perturbs its neighbors.
  * Channels are failure-isolated end to end: tampering with channel
    i's journal (or store chain) flips channel i's verify() verdicts
    ONLY; every other channel stays green.
  * One BlockStore writer thread serves every channel: channel-tagged
    submits land on per-channel chains, spill into per-channel
    directories (``ledger.channel_dir``), and verify/replay/resume are
    strictly per channel.
  * ``FabricEngine.restore`` rebuilds a channel whose latest snapshot
    TRAILS the journal tip: the suffix's ledger head is recomputed from
    the block spill and re-verified against the chain rule.

Runs on whatever host devices exist; the >=2-data-rank acceptance case
needs the CI multi-device job (XLA_FLAGS=--xla_force_host_platform_device_count=8).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import endorser, engine, ledger, types, unmarshal
from repro.launch import fabric_step as fs
from repro.pipeline import engine_bridge

DIMS = types.TEST_DIMS
N_DEV = len(jax.devices())

needs_4_devices = pytest.mark.skipif(
    N_DEV < 4, reason="needs >=4 devices (CI multi-device job)"
)


def _engine_cfg(**kw):
    return engine.EngineConfig(
        dims=DIMS,
        orderer=dataclasses.replace(
            engine.FASTFABRIC.orderer, block_size=32),
        **kw,
    )


def _windows(n_windows, depth, n=16, seed=0):
    """Pre-endorsed wire windows, shaped (depth, n, wire) per window."""
    eng = engine.FabricEngine(
        engine.EngineConfig(dims=DIMS, store_blocks=False))
    outs = []
    for w in range(n_windows):
        wires, idss = [], []
        for k in range(depth):
            props = eng.make_proposals(n, seed=seed + 31 * (w * depth + k))
            txb = endorser.execute_and_endorse(
                eng.endorser_state, props, DIMS)
            wires.append(unmarshal.marshal(txb, DIMS))
            idss.append(txb.tx_id)
            eng.endorser_state = endorser.apply_validated(
                eng.endorser_state, txb, jnp.ones(n, bool))
        outs.append((jnp.stack(wires), jnp.stack(idss)))
    return outs


# --------------- acceptance: N channels == N oracles, mid-run resize


def _multichannel_vs_oracles(shard_state, depth, data, model):
    """Live: C=2 channels lockstep, channel 1 resizes 128->256 after two
    windows. Oracles: each channel's exact per-channel history replayed
    on a single-channel committer. Everything must match, per channel."""
    mesh = jax.make_mesh((data, model), ("data", "model"))
    cfg = fs.FabricStepConfig(shard_state=shard_state, pipeline_depth=depth)
    streams = [_windows(4, depth, seed=5), _windows(4, depth, seed=77)]

    live = engine_bridge.MeshWindowCommitter(
        DIMS, cfg, mesh, n_buckets=128, slots=8, n_channels=2)
    valid_live = []
    for w in range(2):
        wires = jnp.stack([s[w][0] for s in streams])
        ids = jnp.stack([s[w][1] for s in streams])
        valid_live.append(live.commit_windows(wires, ids).valid)
    info = live.resize(256, channel=1)
    assert (info.channel, info.old_n_buckets, info.new_n_buckets) == (
        1, 128, 256)
    assert info.block_no == 2 * depth - 1  # the drained window boundary
    assert live.n_buckets_for(0) == 128 and live.n_buckets_for(1) == 256
    for w in range(2, 4):
        wires = jnp.stack([s[w][0] for s in streams])
        ids = jnp.stack([s[w][1] for s in streams])
        valid_live.append(live.commit_windows(wires, ids).valid)

    for c, wins in enumerate(streams):
        oracle = engine_bridge.MeshWindowCommitter(
            DIMS, cfg, mesh, n_buckets=128, slots=8)
        for w in range(4):
            if c == 1 and w == 2:  # channel 1's mid-run epoch, replayed
                oracle.resize(256)
            v = oracle.commit_window(*wins[w]).valid
            np.testing.assert_array_equal(
                np.asarray(v), np.asarray(valid_live[w][c]),
                err_msg=f"ch{c} window{w} validity")
        lc = live.channel_state(c)
        for name, a, b in zip(fs.FabricMeshState._fields, lc,
                              oracle.state):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b), err_msg=f"ch{c}:{name}")
        np.testing.assert_array_equal(
            live.tree_head(c), oracle.tree_head(), err_msg=f"ch{c} tree")
        np.testing.assert_array_equal(
            live.journal_head_for(c), np.asarray(oracle.journal_head),
            err_msg=f"ch{c} journal head")
        np.testing.assert_array_equal(
            live.ledger_head_for(c), oracle.ledger_head_for(0),
            err_msg=f"ch{c} ledger head")
        assert live.overflow_bits_for(c) == oracle.overflow_bits


def test_multichannel_equals_oracles_replicated():
    _multichannel_vs_oracles(False, 2, 1, 1)


def test_multichannel_equals_oracles_sharded_degenerate():
    _multichannel_vs_oracles(True, 2, 1, 1)


@needs_4_devices
def test_multichannel_equals_oracles_sharded_data_ranks():
    """ACCEPTANCE: 2 channels sharded over 2 `data` ranks x 2 model
    ranks, pipeline depth 2, channel 1 resizes mid-run — byte-identical
    per channel to the single-channel oracles."""
    _multichannel_vs_oracles(True, 2, 2, 2)


@needs_4_devices
def test_multichannel_four_channels_two_data_ranks():
    """4 channels over 2 data ranks (2 local channels per rank): the
    vmap-inside-shard_map layout, no resize — quick layout pin."""
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    cfg = fs.FabricStepConfig(shard_state=True, pipeline_depth=2)
    streams = [_windows(2, 2, seed=11 * (c + 1)) for c in range(4)]
    live = engine_bridge.MeshWindowCommitter(
        DIMS, cfg, mesh, n_buckets=128, slots=8, n_channels=4)
    for w in range(2):
        live.commit_windows(
            jnp.stack([s[w][0] for s in streams]),
            jnp.stack([s[w][1] for s in streams]))
    for c, wins in enumerate(streams):
        oracle = engine_bridge.MeshWindowCommitter(
            DIMS, cfg, mesh, n_buckets=128, slots=8)
        for w in range(2):
            oracle.commit_window(*wins[w])
        np.testing.assert_array_equal(
            live.tree_head(c), oracle.tree_head(), err_msg=f"ch{c}")
        for name, a, b in zip(fs.FabricMeshState._fields,
                              live.channel_state(c), oracle.state):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b), err_msg=f"ch{c}:{name}")


# ------------------------------- engine: lockstep rounds + isolation


def test_engine_multichannel_meshed_rounds_verify_all(tmp_path):
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    wc = engine_bridge.MeshWindowCommitter(
        DIMS, fs.FabricStepConfig(pipeline_depth=2), mesh,
        n_buckets=256, slots=8, n_channels=2)
    eng = engine.FabricEngine(
        _engine_cfg(
            n_channels=2, n_buckets=256,
            journal_dir=str(tmp_path / "j"),
            snapshot_dir=str(tmp_path / "s"),
            block_dir=str(tmp_path / "b"),
        ),
        window_committer=wc,
    )
    for r in range(2):
        props = [eng.make_proposals(64, seed=100 + 7 * r + c)
                 for c in range(2)]
        stats = eng.run_rounds(props)
        assert [s.n_txs for s in stats] == [64, 64]
        # Lockstep rounds share ONE wall clock across channels.
        assert stats[0].wall_s == stats[1].wall_s
    out = eng.verify_all()
    assert set(out) == {0, 1}
    for c, verdicts in out.items():
        assert all(verdicts.values()), (c, verdicts)
    # Per-channel block spill directories exist (channel 0 = base dir).
    assert (tmp_path / "b" / "block_00000000.npz").exists()
    assert (tmp_path / "b" / "channel_0001" / "block_00000000.npz").exists()
    eng.store.close()


def test_engine_multichannel_mismatched_committer_raises():
    wc = engine_bridge.MeshWindowCommitter(
        DIMS, fs.FabricStepConfig(pipeline_depth=1), n_buckets=128,
        n_channels=1)
    with pytest.raises(ValueError, match="channels"):
        engine.FabricEngine(
            _engine_cfg(n_channels=2, n_buckets=128), window_committer=wc)


def test_engine_journal_tamper_flips_only_that_channel(tmp_path):
    """Cross-channel isolation: corrupt channel 1's journal; channel 1's
    verify fails, channel 0 stays green — and vice versa for the store
    chain."""
    eng = engine.FabricEngine(
        _engine_cfg(n_channels=2, journal_dir=str(tmp_path / "j")))
    for r in range(2):
        eng.run_rounds([eng.make_proposals(64, seed=200 + 3 * r + c)
                        for c in range(2)])
    assert all(all(v.values()) for v in eng.verify_all().values())
    rec = eng.chans[1].journal.records[2]
    eng.chans[1].journal.records[2] = rec._replace(
        write_vals=rec.write_vals + 1)
    v0, v1 = eng.verify(0), eng.verify(1)
    assert all(v0.values()), v0
    assert not all(v1.values()), v1
    # Restore channel 1's record; now tamper channel 0's store chain.
    eng.chans[1].journal.records[2] = rec
    assert all(eng.verify(1).values())
    sb = eng.store.chains[0][1]
    eng.store.chains[0][1] = sb._replace(
        block_hash=sb.block_hash ^ np.uint32(1))
    v0, v1 = eng.verify(0), eng.verify(1)
    assert not all(v0.values()), v0
    assert all(v1.values()), v1
    eng.store.close()


def test_engine_multichannel_per_channel_resize(tmp_path):
    """A between-rounds resize of ONE channel re-anchors only that
    channel's journal; both channels keep verifying and the bucket
    counts diverge."""
    eng = engine.FabricEngine(
        _engine_cfg(n_channels=2, n_buckets=128,
                    journal_dir=str(tmp_path / "j")))
    eng.run_rounds([eng.make_proposals(64, seed=c) for c in range(2)])
    info = eng.resize(256, channel=1)
    assert info["channel"] == 1
    eng.run_rounds([eng.make_proposals(64, seed=10 + c) for c in range(2)])
    assert eng.chans[0].n_buckets == 128
    assert eng.chans[1].n_buckets == 256
    assert len(eng.chans[0].journal.reanchors) == 0
    assert len(eng.chans[1].journal.reanchors) == 1
    for c, verdicts in eng.verify_all().items():
        assert all(verdicts.values()), (c, verdicts)
    eng.store.close()


def test_overflow_cap_raise_names_channels():
    """>64 model ranks is a hard cap; in a multi-channel mesh the raise
    must say WHICH channels' state hit it."""
    from repro.launch import state_sharding

    flags = jnp.zeros(state_sharding.MAX_OVERFLOW_SHARDS + 1, bool)
    with pytest.raises(ValueError, match=r"channel \(1, 3\)"):
        state_sharding.overflow_bits(flags, channel=(1, 3))


# --------------------------- storage: ONE writer, N channel chains


def _chain_blocks(n_blocks, batch=8, seed=0):
    prev = jnp.zeros((2,), jnp.uint32)
    out = []
    for b in range(n_blocks):
        txb = types.make_transfer_batch(DIMS, batch, seed=seed + b)
        wire = unmarshal.marshal(txb, DIMS)
        valid = jnp.ones(batch, bool)
        digest = ledger.block_body_digest(wire, valid)
        bh = ledger.append_hash(prev, jnp.uint32(b), digest)
        out.append((b, prev, bh, wire, valid))
        prev = bh
    return out


def test_blockstore_multiplexes_channels(tmp_path):
    store = ledger.BlockStore(spill_dir=str(tmp_path))
    chans = {c: _chain_blocks(3, seed=40 * (c + 1)) for c in range(3)}
    # Interleave submits across channels through the one writer thread.
    for b in range(3):
        for c, blocks in chans.items():
            store.submit(*blocks[b], channel=c)
    store.drain()
    for c, blocks in chans.items():
        assert store.verify_chain(c)
        assert [sb.block_no for sb in store.chains[c]] == [0, 1, 2]
        loaded = ledger.load_spilled_blocks(str(tmp_path), 0, channel=c)
        assert [sb.block_no for sb in loaded] == [0, 1, 2]
        for sb, (bno, prev, bh, wire, valid) in zip(loaded, blocks):
            np.testing.assert_array_equal(sb.block_hash, np.asarray(bh))
    # Pruning channel 1 re-anchors channel 1 only.
    store.prune_upto(1, channel=1)
    assert store.base_block_nos[1] == 1
    assert store.base_block_nos[0] == -1 and store.base_block_nos[2] == -1
    assert all(store.verify_chain(c) for c in range(3))
    # A bad cross-channel splice fails that channel's verify only.
    store.chains[2][1] = store.chains[0][1]
    assert store.verify_chain(0) and store.verify_chain(1)
    assert not store.verify_chain(2)
    store.close()


# ----------------- restore: snapshot TRAILING the journal tip


def test_restore_from_snapshot_trailing_journal_tip(tmp_path):
    """5 rounds with a snapshot cadence that leaves blocks AFTER the last
    snapshot: restore must rebuild the suffix's ledger head from the
    block spill and end at the live digest + block number."""
    cfg = _engine_cfg(
        n_buckets=256, snapshot_every_blocks=4,
        snapshot_dir=str(tmp_path / "s"),
        journal_dir=str(tmp_path / "j"),
        block_dir=str(tmp_path / "b"),
    )
    eng = engine.FabricEngine(cfg)
    for i in range(5):
        eng.run_rounds([eng.make_proposals(64, seed=i)])
    digest, bno = eng._peer_digest(), eng._next_block_no
    head = eng._ledger_head()
    snap_bno = eng.snapshots[-1].block_no
    assert snap_bno < bno - 1  # the journal tip really trails
    eng.store.drain()
    eng.store.close()

    restored = engine.FabricEngine.restore(cfg)
    assert restored._next_block_no == bno
    np.testing.assert_array_equal(restored._peer_digest(), digest)
    np.testing.assert_array_equal(restored._ledger_head(), head)
    assert all(restored.verify().values())
    restored.store.close()


def test_restore_trailing_snapshot_requires_block_spill(tmp_path):
    cfg = _engine_cfg(
        n_buckets=256, snapshot_every_blocks=4,
        snapshot_dir=str(tmp_path / "s"),
        journal_dir=str(tmp_path / "j"),
    )
    eng = engine.FabricEngine(cfg)
    for i in range(5):
        eng.run_rounds([eng.make_proposals(64, seed=i)])
    assert eng.snapshots[-1].block_no < eng._next_block_no - 1
    eng.store.drain()
    eng.store.close()
    with pytest.raises(RuntimeError, match="block_dir"):
        engine.FabricEngine.restore(cfg)


def test_restore_multichannel_with_divergent_epochs(tmp_path):
    """2 channels, channel 1 resized mid-history: restore brings BOTH
    back (per-channel snapshots + journals + block spill), with the
    divergent bucket counts intact and every verdict green."""
    cfg = _engine_cfg(
        n_channels=2, n_buckets=128, snapshot_every_blocks=4,
        snapshot_dir=str(tmp_path / "s"),
        journal_dir=str(tmp_path / "j"),
        block_dir=str(tmp_path / "b"),
    )
    eng = engine.FabricEngine(cfg)
    eng.run_rounds([eng.make_proposals(64, seed=c) for c in range(2)])
    eng.resize(256, channel=1)
    for i in range(2):
        eng.run_rounds([eng.make_proposals(64, seed=10 + 2 * i + c)
                        for c in range(2)])
    digests = [eng._peer_digest(c) for c in range(2)]
    bnos = [eng.chans[c].next_block_no for c in range(2)]
    eng.store.drain()
    eng.store.close()

    restored = engine.FabricEngine.restore(cfg)
    assert restored.chans[0].n_buckets == 128
    assert restored.chans[1].n_buckets == 256
    for c in range(2):
        assert restored.chans[c].next_block_no == bnos[c]
        np.testing.assert_array_equal(
            restored._peer_digest(c), digests[c], err_msg=f"ch{c}")
    for c, verdicts in restored.verify_all().items():
        assert all(verdicts.values()), (c, verdicts)
    restored.store.close()
