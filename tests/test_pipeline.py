"""Device-side block pipeline: the depth-D windowed step must be
byte-identical to D sequential invocations of the depth-1 oracle —
validity bits, log/ledger/journal heads, block numbers, and state arrays —
on replicated AND sharded state, including windows with cross-block
read-your-write dependencies (block k reads a key block k-1 wrote) and
windows whose blocks OVERFLOW their buckets (a dropped insert must not be
counted as a version bump, and the sticky overflow flag must latch
identically on both paths).

Runs on whatever host devices exist: with 1 device the sharded path is
exercised degenerately; the CI multi-device job
(XLA_FLAGS=--xla_force_host_platform_device_count=8) runs the >=2-rank
cases for real.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import endorser, engine, types, unmarshal
from repro.launch import fabric_step as fs
from repro.pipeline import engine_bridge

DIMS = types.TEST_DIMS
N_DEV = len(jax.devices())
MAX_M = 1 << (N_DEV.bit_length() - 1)  # largest power of two <= N_DEV

multi_device = pytest.mark.skipif(
    N_DEV < 2, reason="needs >=2 devices (CI multi-device job)"
)


def _window(depth, n=32, seed=0, *, read_your_write=False,
            endorser_buckets=None, endorser_slots=8):
    """A (D, B, ...) window of endorsed blocks. With ``read_your_write``
    every block touches the SAME accounts, so block k's reads expect the
    versions block k-1's commits produced — valid only if the pipeline
    preserves commit order. ``endorser_buckets``/``endorser_slots`` shrink
    the endorser replica (overflow tests pair it with an equally tiny peer
    table so both drop the same inserts)."""
    eng = engine.FabricEngine(engine.EngineConfig(
        dims=DIMS, store_blocks=False,
        n_buckets=endorser_buckets or (1 << 12),
        slots=endorser_slots,
    ))
    wires, idss = [], []
    for k in range(depth):
        props = eng.make_proposals(
            n, seed=seed if read_your_write else seed + 11 * k
        )
        if read_your_write:
            props = props._replace(
                nonce=props.nonce + jnp.uint32(k * 100003)
            )
        txb = endorser.execute_and_endorse(eng.endorser_state, props, DIMS)
        wires.append(unmarshal.marshal(txb, DIMS))
        idss.append(txb.tx_id)
        if read_your_write:
            eng.endorser_state = endorser.apply_validated(
                eng.endorser_state, txb, jnp.ones(n, bool)
            )
    return jnp.stack(wires), jnp.stack(idss)


def _oracle(cfg, mesh, wire, ids, n_buckets=256, slots=8):
    """Depth-1 reference: one invocation per block, sequentially."""
    st = fs.create_mesh_state(1, DIMS, n_buckets=n_buckets, slots=slots)
    step = jax.jit(fs.make_fabric_step(
        DIMS, dataclasses.replace(cfg, pipeline_depth=1), mesh))
    valids = []
    for k in range(wire.shape[0]):
        st, v = step(st, wire[k][None], ids[k][None])
        valids.append(np.asarray(v)[0])
    return jax.tree.map(np.asarray, st), np.stack(valids)


def _pipelined(cfg, mesh, wire, ids, depth, n_buckets=256, slots=8):
    st = fs.create_mesh_state(1, DIMS, n_buckets=n_buckets, slots=slots)
    step = jax.jit(fs.make_fabric_step(
        DIMS, dataclasses.replace(cfg, pipeline_depth=depth), mesh))
    st, v = step(st, wire[None], ids[None])
    return jax.tree.map(np.asarray, st), np.asarray(v)[0]


def _assert_identical(cfg, mesh, wire, ids, depth, n_buckets=256, slots=8):
    st1, v1 = _oracle(cfg, mesh, wire, ids, n_buckets, slots)
    st2, v2 = _pipelined(cfg, mesh, wire, ids, depth, n_buckets, slots)
    np.testing.assert_array_equal(v1, v2)
    for name, a, b in zip(fs.FabricMeshState._fields, st1, st2):
        np.testing.assert_array_equal(a, b, err_msg=name)
    return v2, st2


# ------------------------------------------------------- oracle equivalence


@pytest.mark.parametrize("depth", [2, 8])
def test_pipelined_equals_oracle_replicated(depth):
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    wire, ids = _window(depth, n=16, seed=depth)
    v, st = _assert_identical(fs.FASTFABRIC_STEP, mesh, wire, ids, depth)
    assert int(v.sum()) == v.size  # disjoint accounts: all valid
    assert not np.asarray(st.overflow[0]).any()  # amply sized: flag clear


def test_pipelined_equals_oracle_sharded_degenerate():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    wire, ids = _window(2, n=16, seed=9)
    _assert_identical(fs.FASTFABRIC_SHARDED_STEP, mesh, wire, ids, 2)


@multi_device
@pytest.mark.parametrize("depth", [2, 4])
def test_pipelined_equals_oracle_sharded_multi_rank(depth):
    """Acceptance: depth-D window on >=2 model ranks with sharded state is
    byte-identical to the depth-1 oracle — one routed gather per window."""
    mesh = jax.make_mesh((1, min(MAX_M, 4)), ("data", "model"))
    wire, ids = _window(depth, n=32, seed=depth)
    _assert_identical(fs.FASTFABRIC_SHARDED_STEP, mesh, wire, ids, depth)


def test_pipelined_equals_oracle_baseline_config():
    """The serial fabric-1.2 folds (non-pipelined consensus, sequential
    commit) pipeline too: the schedule reuses the exact per-block math."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    wire, ids = _window(2, n=16, seed=5)
    _assert_identical(fs.FABRIC_V12_STEP, mesh, wire, ids, 2)


# ------------------------------------------- cross-block read-your-write


@pytest.mark.parametrize("depth", [2, 4])
def test_cross_block_read_your_write_commit_order(depth):
    """Block k reads keys block k-1 wrote (expecting the bumped version):
    every transaction is valid ONLY if commits apply in block order and
    the batched fill-time gather is repaired with in-window writes."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    wire, ids = _window(depth, n=16, seed=1, read_your_write=True)
    v, _ = _assert_identical(fs.FASTFABRIC_STEP, mesh, wire, ids, depth)
    assert int(v.sum()) == v.size  # stale fill-time versions would zero
    # the later blocks; all-valid proves the in-window repair is exact.


@multi_device
def test_cross_block_read_your_write_sharded_multi_rank():
    mesh = jax.make_mesh((1, min(MAX_M, 4)), ("data", "model"))
    wire, ids = _window(4, n=32, seed=2, read_your_write=True)
    v, _ = _assert_identical(fs.FASTFABRIC_SHARDED_STEP, mesh, wire, ids, 4)
    assert int(v.sum()) == v.size


def test_replayed_window_invalidated():
    """Replaying the same window leaves every version stale (the pipeline
    does not leak fill-time versions into the second window)."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    wire, ids = _window(2, n=16, seed=7)
    st = fs.create_mesh_state(1, DIMS, n_buckets=256)
    step = jax.jit(fs.make_fabric_step(
        DIMS, dataclasses.replace(fs.FASTFABRIC_STEP, pipeline_depth=2),
        mesh))
    st, v1 = step(st, wire[None], ids[None])
    st, v2 = step(st, wire[None], ids[None])
    assert int(np.asarray(v1).sum()) == 32
    assert int(np.asarray(v2).sum()) == 0


# ------------------------------- overflow windows (fused commit, exact)


def _overflow_window(depth, n=16, seed=1):
    """Read-your-write blocks against an endorser replica as tiny as the
    peer table below (8 buckets x 2 slots): each block's 2*n writes exceed
    the 16 slots, so inserts drop mid-window and later blocks read keys
    whose source insert was dropped — the repairs that must be poisoned."""
    return _window(depth, n=n, seed=seed, read_your_write=True,
                   endorser_buckets=8, endorser_slots=2)


@pytest.mark.parametrize("depth", [2, 4, 8])
def test_overflow_window_equals_oracle_replicated(depth):
    """Acceptance: overflowing windows stay byte-identical to the depth-1
    oracle (the old window write log counted dropped inserts as version
    bumps, so any in-window read of a dropped key diverged)."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    wire, ids = _overflow_window(depth)
    v, st = _assert_identical(fs.FASTFABRIC_STEP, mesh, wire, ids, depth,
                              n_buckets=8, slots=2)
    assert np.asarray(st.overflow[0]).any()  # sticky bitmask latched on both paths
    assert 0 < int(v.sum()) < v.size  # poisoned repairs invalidate SOME
    # transactions (all-valid would mean the drop was never observed,
    # all-invalid that the window never committed anything)


@pytest.mark.parametrize("depth", [2, 4])
def test_overflow_window_equals_oracle_sharded_degenerate(depth):
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    wire, ids = _overflow_window(depth)
    _, st = _assert_identical(fs.FASTFABRIC_SHARDED_STEP, mesh, wire, ids,
                              depth, n_buckets=8, slots=2)
    assert np.asarray(st.overflow[0]).any()


@multi_device
@pytest.mark.parametrize("depth", [2, 4])
def test_overflow_window_equals_oracle_sharded_multi_rank(depth):
    """Overflow accounting must survive the routed path: free-slot counts
    gather from the owner shards and the fused commit applies owner-side,
    yet the validity bits and state stay byte-identical to the oracle —
    including the per-shard overflow BITMASK (bit m == shard m filled),
    which the depth-1 routed commit and the pipelined planner must agree
    on without an extra collective."""
    mesh = jax.make_mesh((1, min(MAX_M, 4)), ("data", "model"))
    wire, ids = _overflow_window(depth, n=16)
    _, st = _assert_identical(fs.FASTFABRIC_SHARDED_STEP, mesh, wire, ids,
                              depth, n_buckets=8, slots=2)
    assert np.asarray(st.overflow[0]).any()


def test_overflow_window_equals_oracle_sequential_baseline():
    """The sequential-commit baseline bumps every duplicate occurrence and
    fills slots in write order; the planner must mirror that flavor too."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    wire, ids = _overflow_window(4)
    _, st = _assert_identical(fs.FABRIC_V12_STEP, mesh, wire, ids, 4,
                              n_buckets=8, slots=2)
    assert np.asarray(st.overflow[0]).any()


def test_overflow_window_store_chain_and_journal():
    """Poisoned repairs must never advance heads incorrectly: the store
    chain and the mesh journal head of an overflowing round are identical
    whether blocks commit one at a time or as one fused window."""
    from repro.core import ledger

    wire, ids = _overflow_window(4)
    results = {}
    for depth in (1, 4):
        wc = engine_bridge.MeshWindowCommitter(
            DIMS, fs.FabricStepConfig(pipeline_depth=depth),
            n_buckets=8, slots=2,
        )
        outs = []
        if depth == 1:
            for k in range(4):
                outs.append(wc.commit_window(wire[k][None], ids[k][None]))
        else:
            outs.append(wc.commit_window(wire, ids))
        store = ledger.BlockStore()
        bno = 0
        for out in outs:
            for k in range(out.valid.shape[0]):
                store.submit(bno, out.prev_hash[k], out.block_hash[k],
                             wire[bno], out.valid[k])
                bno += 1
        store.drain()
        assert store.verify_chain()
        results[depth] = (store, wc)
    s1, wc1 = results[1]
    s4, wc4 = results[4]
    assert wc1.overflow and wc4.overflow
    np.testing.assert_array_equal(wc1.journal_head, wc4.journal_head)
    np.testing.assert_array_equal(wc1.state_digest(), wc4.state_digest())
    for a, b in zip(s1.chain, s4.chain):
        assert a.block_no == b.block_no
        np.testing.assert_array_equal(a.block_hash, b.block_hash)
        np.testing.assert_array_equal(a.valid, b.valid)


def test_engine_overflow_reports_unhealthy(tmp_path):
    """Satellite: an overflowed peer must say so. Both engine paths — the
    per-block committer and the mesh window committer — latch the sticky
    flag and verify() reports overflow_ok=False while the chain itself
    still verifies (the ledger is consistent; the STATE capacity is not)."""
    cfg = engine.EngineConfig(dims=DIMS, n_buckets=8, slots=2)
    e = engine.FabricEngine(cfg)
    e.run_round(e.make_proposals(200, seed=0))
    out = e.verify()
    assert out["overflow_ok"] is False
    assert out["chain_ok"] is True

    wc = engine_bridge.MeshWindowCommitter(
        DIMS, fs.FabricStepConfig(pipeline_depth=4), n_buckets=8, slots=2)
    e_win = engine.FabricEngine(cfg, window_committer=wc)
    e_win.run_round(e_win.make_proposals(200, seed=0))
    out = e_win.verify()
    assert out["overflow_ok"] is False
    assert out["chain_ok"] is True
    # An amply sized engine keeps the bill of health.
    e_ok = engine.FabricEngine(engine.EngineConfig(dims=DIMS))
    e_ok.run_round(e_ok.make_proposals(200, seed=0))
    assert e_ok.verify()["overflow_ok"] is True


# ------------------------------------------------------------ input guards


def test_pipelined_rejects_wrong_window_shape():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    wire, ids = _window(2, n=16)
    step = fs.make_fabric_step(
        DIMS, dataclasses.replace(fs.FASTFABRIC_STEP, pipeline_depth=4),
        mesh)
    st = fs.create_mesh_state(1, DIMS, n_buckets=256)
    with pytest.raises(ValueError, match="pipeline_depth=4"):
        step(st, wire[None], ids[None])


# -------------------------------------------------- engine window committer


def test_engine_window_committer_matches_per_block_engine(tmp_path):
    """core/engine.py handing the mesh step a window of blocks per round
    must retire the same blocks as the per-block committer path: same
    valid bits, same store chain, and all durability checks green."""
    cfg = engine.EngineConfig(dims=DIMS, journal_dir=str(tmp_path))
    e_ref = engine.FabricEngine(cfg)
    wc = engine_bridge.MeshWindowCommitter(
        DIMS, fs.FabricStepConfig(pipeline_depth=4),
        n_buckets=cfg.n_buckets, slots=cfg.slots,
    )
    e_win = engine.FabricEngine(
        dataclasses.replace(cfg, journal_dir=str(tmp_path / "win")),
        window_committer=wc,
    )
    for rnd in range(2):
        # 600 txs / block_size 100 = 6 blocks: one full depth-4 window plus
        # a shallower 2-block remainder window.
        s_ref = e_ref.run_round(e_ref.make_proposals(600, seed=rnd))
        s_win = e_win.run_round(e_win.make_proposals(600, seed=rnd))
        assert s_ref.n_valid == s_win.n_valid == 600
        assert s_ref.n_blocks == s_win.n_blocks == 6
    out = e_win.verify()
    assert all(out.values()), out
    e_ref.store.drain()
    e_win.store.drain()
    for a, b in zip(e_ref.store.chain, e_win.store.chain):
        assert a.block_no == b.block_no
        np.testing.assert_array_equal(a.block_hash, b.block_hash)
        np.testing.assert_array_equal(a.valid, b.valid)
    # Journal heads agree between the off-path journal and the mesh state.
    np.testing.assert_array_equal(
        e_win.journal.head, wc.journal_head
    )


def test_engine_window_committer_supports_snapshots(tmp_path):
    """Snapshots used to be rejected with a window committer; the elastic
    refactor made the manifest cover the mesh-backed state instead (full
    durability coverage lives in tests/test_rebalance.py)."""
    wc = engine_bridge.MeshWindowCommitter(
        DIMS, fs.FabricStepConfig(pipeline_depth=2))
    eng = engine.FabricEngine(
        engine.EngineConfig(dims=DIMS, snapshot_every_blocks=4,
                            snapshot_dir=str(tmp_path)),
        window_committer=wc,
    )
    eng.run_round(eng.make_proposals(600, seed=0))
    assert eng.snapshots and eng.snapshots[-1].block_no >= 4
    assert eng.verify()["recovery_ok"]
    eng.store.close()


# -------------------------------------------------------------- benchmark


def test_fig11_benchmark_smoke(capsys, tmp_path):
    from benchmarks import common, fig11_pipeline

    common.ROWS.clear()
    out = tmp_path / "fig11.json"
    fig11_pipeline.main(
        ["--depths", "1", "2", "--b-round", "16", "--n-buckets", "256",
         "--iters", "1", "--json", str(out)]
    )
    names = [r["name"] for r in common.ROWS]
    assert any(n.startswith("repl/d=") for n in names)
    assert any(n.startswith("shard/d=") for n in names)
    assert any(n.startswith("equivalence/") for n in names)
    assert out.exists()
    by_name = {r["name"]: r for r in common.ROWS}
    # The deliberately overflowing rows must latch the sticky flag and
    # still pass their (internally asserted) oracle equivalence.
    assert by_name["shard-ovf/d=2"]["overflow"] == 1
    assert by_name["equivalence/shard-ovf/d=2"]["identical"]
    # Exactly ONE fused commit scatter pass per compiled program at every
    # depth (asserted inside _run_depth too; pinned here for the artifact).
    for n, r in by_name.items():
        if "/d=" in n and "equivalence" not in n:
            assert r["commit_scatters"] == 1, (n, r)
    # Depth 2 halves the collective instructions per block (one window
    # gather instead of one per block) — visible even degenerately as the
    # compiled-program count, and as real collectives on the CI
    # multi-device job.
    if N_DEV >= 2:
        assert (by_name["shard/d=2"]["coll_per_block"]
                < by_name["shard/d=1"]["coll_per_block"])
