"""World-state stores: hash table vs sorted (LevelDB-analogue) semantics."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import hashing, world_state as ws

VW = 2


def _mk_writes(keys, vals=None):
    k = len(keys)
    wk = np.zeros((k, 1, 2), np.uint32)
    for i, key in enumerate(keys):
        h1, h2 = hashing.hash_pair(jnp.uint32(key))
        wk[i, 0] = [int(hashing.nonzero_key(h1)), int(h2)]
    wv = np.zeros((k, 1, VW), np.uint32)
    wv[:, 0, 0] = vals if vals is not None else np.arange(k) + 1
    return jnp.asarray(wk), jnp.asarray(wv)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 60), min_size=1, max_size=24, unique=True),
       st.lists(st.booleans(), min_size=24, max_size=24))
def test_sequential_equals_vectorized(keys, act_bits):
    """The beyond-paper vectorized commit must preserve the paper's
    sequential semantics for any write batch with pairwise-distinct active
    keys — the precondition MVCC guarantees (valid txs in a block have
    disjoint write sets; see test_mvcc.py::test_double_spend_blocked)."""
    st0 = ws.create(16, 4, VW)
    wk, wv = _mk_writes(keys)
    act = jnp.asarray(act_bits[: len(keys)])
    r_seq = ws.commit_sequential(st0, wk, wv, act)
    r_vec = ws.commit_vectorized(st0, wk, wv, act)
    assert bool(r_seq.overflow) == bool(r_vec.overflow)
    if not bool(r_seq.overflow):
        d1 = np.asarray(ws.state_digest(r_seq.state))
        d2 = np.asarray(ws.state_digest(r_vec.state))
        np.testing.assert_array_equal(d1, d2)


def test_duplicate_active_keys_documented_divergence():
    """Outside the MVCC precondition the two commits differ BY DESIGN:
    sequential applies duplicates in order (last value, version bumped),
    vectorized keeps the first and drops later duplicates. Pinned here so
    the contract stays visible; the engine never hits this (MVCC filters
    duplicate writers first)."""
    st0 = ws.create(16, 4, VW)
    wk, wv = _mk_writes([5, 5], vals=np.asarray([10, 20]))
    act = jnp.ones((2,), bool)
    r_seq = ws.commit_sequential(st0, wk, wv, act)
    r_vec = ws.commit_vectorized(st0, wk, wv, act)
    lseq = ws.lookup(r_seq.state, wk[:1, 0, :])
    lvec = ws.lookup(r_vec.state, wk[:1, 0, :])
    assert int(lseq.versions[0]) == 2 and int(lseq.values[0, 0]) == 20
    assert int(lvec.versions[0]) == 1 and int(lvec.values[0, 0]) == 10


def test_lookup_after_commit_roundtrip():
    st0 = ws.create(32, 4, VW)
    wk, wv = _mk_writes(list(range(10)), vals=np.arange(10) + 100)
    res = ws.commit_vectorized(st0, wk, wv, jnp.ones((10,), bool))
    look = ws.lookup(res.state, wk[:, 0, :])
    assert bool(look.found.all())
    np.testing.assert_array_equal(np.asarray(look.versions), np.ones(10))
    np.testing.assert_array_equal(np.asarray(look.values[:, 0]),
                                  np.arange(10) + 100)
    # Second commit bumps versions.
    res2 = ws.commit_vectorized(res.state, wk, wv, jnp.ones((10,), bool))
    look2 = ws.lookup(res2.state, wk[:, 0, :])
    np.testing.assert_array_equal(np.asarray(look2.versions),
                                  2 * np.ones(10))


def test_absent_key_version_zero():
    st0 = ws.create(16, 4, VW)
    wk, _ = _mk_writes([99])
    look = ws.lookup(st0, wk[:, 0, :])
    assert not bool(look.found.any())
    assert int(look.versions[0]) == 0


@settings(max_examples=15, deadline=None)
@given(st.lists(st.integers(0, 40), min_size=1, max_size=20))
def test_sorted_store_matches_hash_store(keys):
    """The Fabric-1.2 baseline store and the P-I hash table must agree on
    (found, version, value) for every probe after the same history."""
    hst = ws.create(32, 8, VW)
    sst = ws.sorted_create(256, VW)
    wk, wv = _mk_writes(keys)
    act = jnp.ones((len(keys),), bool)
    hst = ws.commit_vectorized(hst, wk, wv, act).state
    sst = ws.sorted_commit(sst, wk, wv, act)
    probes_np = np.concatenate(
        [np.asarray(wk[:, 0, :]),
         np.asarray(_mk_writes([1000 + k for k in keys])[0][:, 0, :])]
    )
    probes = jnp.asarray(probes_np)
    lh = ws.lookup(hst, probes)
    ls = ws.sorted_lookup(sst, probes)
    np.testing.assert_array_equal(np.asarray(lh.found), np.asarray(ls.found))
    np.testing.assert_array_equal(np.asarray(lh.versions),
                                  np.asarray(ls.versions))
    np.testing.assert_array_equal(np.asarray(lh.values),
                                  np.asarray(ls.values))


def test_digest_layout_invariance():
    """Digest must not depend on commit order (bucket/slot layout)."""
    st0 = ws.create(16, 8, VW)
    wk, wv = _mk_writes(list(range(12)))
    act = jnp.ones((12,), bool)
    perm = np.random.default_rng(1).permutation(12)
    a = ws.commit_sequential(st0, wk, wv, act).state
    b = ws.commit_sequential(st0, wk[perm], wv[perm], act).state
    np.testing.assert_array_equal(np.asarray(ws.state_digest(a)),
                                  np.asarray(ws.state_digest(b)))
