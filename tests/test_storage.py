"""Storage subsystem: journal digest chain, snapshots, crash recovery, and
the BlockStore spill path the snapshot persistence builds on."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, ledger, types, unmarshal
from repro.core import world_state as ws
from repro.storage import journal as journal_mod
from repro.storage import recovery, snapshot

DIMS = types.TEST_DIMS


def _journal_with_blocks(n_blocks=3, batch=8, seed=0):
    j = journal_mod.StateJournal(DIMS)
    rng = np.random.default_rng(seed)
    for b in range(n_blocks):
        wk = jnp.asarray(
            rng.integers(1, 1 << 30, size=(batch, DIMS.wk, 2), dtype=np.uint32)
        )
        wv = jnp.asarray(
            rng.integers(0, 1 << 30, size=(batch, DIMS.wk, DIMS.vw),
                         dtype=np.uint32)
        )
        valid = jnp.asarray(rng.integers(0, 2, size=batch).astype(bool))
        j.append_writes(b, wk, wv, valid)
    return j


# --------------------------------------------------------------------- journal


def test_journal_chain_verifies_and_heads_link():
    j = _journal_with_blocks(4)
    assert j.verify_chain()
    for prev, rec in zip(j.records, j.records[1:]):
        np.testing.assert_array_equal(rec.prev_head, prev.head)
    np.testing.assert_array_equal(j.head, j.records[-1].head)


@pytest.mark.parametrize("field", ["write_keys", "write_vals", "valid"])
def test_journal_tamper_detected(field):
    j = _journal_with_blocks(4)
    rec = j.records[2]
    arr = getattr(rec, field).copy()
    arr.flat[0] = not arr.flat[0] if field == "valid" else arr.flat[0] ^ 1
    j.records[2] = rec._replace(**{field: arr})
    assert not j.verify_chain()


def test_journal_missing_record_detected():
    j = _journal_with_blocks(4)
    del j.records[1]  # gap in block numbers
    assert not j.verify_chain()


def test_journal_prune_reanchors_chain():
    j = _journal_with_blocks(5)
    head = j.head.copy()
    assert j.prune_upto(2) == 3
    assert j.base_block_no == 2
    assert [r.block_no for r in j.records] == [3, 4]
    assert j.verify_chain()  # re-anchored at base_head
    np.testing.assert_array_equal(j.head, head)


def test_journal_spill_and_cold_load(tmp_path):
    spill = tmp_path / "journal"
    spill.mkdir()
    j = journal_mod.StateJournal(DIMS, spill_dir=str(spill))
    rng = np.random.default_rng(3)
    for b in range(3):
        wk = jnp.asarray(
            rng.integers(1, 1 << 30, size=(4, DIMS.wk, 2), dtype=np.uint32))
        wv = jnp.asarray(
            rng.integers(0, 1 << 30, size=(4, DIMS.wk, DIMS.vw),
                         dtype=np.uint32))
        j.append_writes(b, wk, wv, jnp.ones(4, bool))
    j2 = journal_mod.StateJournal.load(DIMS, str(spill))
    assert len(j2.records) == 3
    assert j2.verify_chain()
    np.testing.assert_array_equal(j2.head, j.head)
    # Pruning also compacts the spill directory.
    j2.prune_upto(1)
    assert sorted(p.name for p in spill.iterdir()) == ["journal_00000002.npz"]
    j3 = journal_mod.StateJournal.load(DIMS, str(spill))
    assert [r.block_no for r in j3.records] == [2]
    assert j3.verify_chain()


def test_journal_replay_matches_direct_commits():
    j = _journal_with_blocks(3)
    direct = ws.create(256, 8, DIMS.vw)
    for rec in j.records:
        direct = ws.commit_vectorized(
            direct, jnp.asarray(rec.write_keys), jnp.asarray(rec.write_vals),
            jnp.asarray(rec.valid),
        ).state
    replayed = j.replay(ws.create(256, 8, DIMS.vw)).state
    np.testing.assert_array_equal(
        np.asarray(ws.state_digest(replayed)),
        np.asarray(ws.state_digest(direct)),
    )


# -------------------------------------------------------------------- snapshot


def _populated_state(n_buckets=64, slots=4, n=16, seed=7):
    st = ws.create(n_buckets, slots, DIMS.vw)
    txb = types.make_transfer_batch(DIMS, n, seed=seed)
    return ws.commit_vectorized(
        st, txb.write_keys, txb.write_vals, jnp.ones(n, bool)
    ).state


def test_snapshot_roundtrip_and_tamper(tmp_path):
    st = _populated_state()
    snap = snapshot.take(
        st, block_no=5, journal_head=np.arange(2, dtype=np.uint32),
        ledger_head=np.zeros(2, np.uint32), n_shards=4,
    )
    assert snapshot.verify(snap)
    assert len(snap.shards) == 4
    snapshot.save(str(tmp_path), snap)
    loaded = snapshot.load(str(tmp_path), 5)
    assert loaded.block_no == 5
    assert snapshot.verify(loaded)
    np.testing.assert_array_equal(
        np.asarray(ws.state_digest(snapshot.to_state(loaded))),
        np.asarray(ws.state_digest(st)),
    )
    # latest() picks the highest block number.
    older = snapshot.take(
        st, block_no=2, journal_head=np.arange(2, dtype=np.uint32),
        ledger_head=np.zeros(2, np.uint32), n_shards=4,
    )
    snapshot.save(str(tmp_path), older)
    assert snapshot.latest(str(tmp_path)).block_no == 5
    # Tampering with a persisted shard breaks its digest (and the manifest
    # tree head binds the shard layout).
    part = loaded.shards[1]
    bad_part = part._replace(versions=part.versions + 1)
    assert not snapshot.verify_shard(loaded.manifest, bad_part)
    bad = loaded._replace(
        shards=tuple(bad_part if p.shard == 1 else p for p in loaded.shards)
    )
    assert not snapshot.verify(bad)
    with pytest.raises(recovery.RecoveryError, match="mismatch"):
        recovery.recover(
            journal_mod.StateJournal(DIMS), snapshot=bad,
            n_buckets=64, slots=4, value_width=DIMS.vw,
        )


def test_snapshot_manifest_persists_overflow_and_layout(tmp_path):
    st = _populated_state()
    snap = snapshot.take(
        st, block_no=3, journal_head=np.zeros(2, np.uint32),
        ledger_head=np.zeros(2, np.uint32), n_shards=2, overflow_bits=0b10,
    )
    snapshot.save(str(tmp_path), snap)
    man = snapshot.load_manifest(snapshot.path_for(str(tmp_path), 3))
    assert man.overflow is True and man.overflow_bits == 0b10
    assert man.n_buckets == 64 and man.n_shards == 2 and man.slots == 4
    # Per-shard loading never touches the other shard's file.
    part = snapshot.load_shard(str(tmp_path), 3, 1)
    assert snapshot.verify_shard(man, part)


def test_snapshot_listing_ignores_foreign_files(tmp_path):
    """Satellite: list_blocks/latest/gc must skip files they do not own,
    and a torn manifest (missing shard files, or an unreadable manifest)
    must never be selected by latest()."""
    st = _populated_state()
    for bno in (2, 5):
        snapshot.save(str(tmp_path), snapshot.take(
            st, block_no=bno, journal_head=np.zeros(2, np.uint32),
            ledger_head=np.zeros(2, np.uint32), n_shards=2,
        ))
    # Foreign files of every flavor.
    (tmp_path / "notes.txt").write_text("keep me")
    (tmp_path / "manifest_bogus.npz").write_text("not a number")
    (tmp_path / "manifest_00000009.npz").write_text("torn write")
    (tmp_path / "shard_00000009_0000.npz").write_text("torn write")
    assert snapshot.list_blocks(str(tmp_path)) == [2, 5]
    assert snapshot.latest(str(tmp_path)).block_no == 5
    # A manifest whose shard file vanished is torn: never selected.
    import os

    os.remove(snapshot.shard_path_for(str(tmp_path), 5, 1))
    assert snapshot.list_blocks(str(tmp_path)) == [2]
    assert snapshot.latest(str(tmp_path)).block_no == 2
    # Foreign files survive gc untouched.
    snapshot.gc(str(tmp_path), keep=1)
    assert (tmp_path / "notes.txt").read_text() == "keep me"
    assert (tmp_path / "manifest_bogus.npz").exists()


def test_snapshot_gc_drops_manifest_and_shards_as_unit(tmp_path):
    st = _populated_state()
    for bno in (1, 2, 3):
        snapshot.save(str(tmp_path), snapshot.take(
            st, block_no=bno, journal_head=np.zeros(2, np.uint32),
            ledger_head=np.zeros(2, np.uint32), n_shards=2,
        ))
    snapshot.gc(str(tmp_path), keep=2)
    assert snapshot.list_blocks(str(tmp_path)) == [2, 3]
    names = sorted(p.name for p in tmp_path.iterdir())
    # Block 1's manifest AND shard files are gone (GC'd as a unit).
    assert not any("00000001" in n for n in names)
    # Blocks 2/3 keep manifest + both shards each.
    assert len(names) == 2 * 3


# ------------------------------------------------------- end-to-end recovery


def _engine(**kw):
    cfg = engine.EngineConfig(
        orderer=dataclasses.replace(
            engine.FASTFABRIC.orderer, block_size=50
        ),
        n_buckets=1 << 10,
        **kw,
    )
    return engine.FabricEngine(cfg)


def test_engine_recovery_matches_live_and_full_replay():
    """Acceptance: >=3 rounds with a snapshot cadence -> recovery from the
    latest snapshot + journal suffix == live digest == full chain replay."""
    eng = _engine(snapshot_every_blocks=4, prune_chain=False)
    for i in range(3):
        eng.run_round(eng.make_proposals(150, seed=i))  # 3 blocks per round
    eng.store.drain()
    assert eng.snapshots, "cadence should have produced a snapshot"

    live = np.asarray(ws.state_digest(eng.peer_state.hash_state))
    rec = eng.recover()
    assert rec.snapshot_block_no == eng.snapshots[-1].block_no
    assert 0 < rec.replayed_records < len(eng.store.chain)
    np.testing.assert_array_equal(rec.state_digest, live)
    np.testing.assert_array_equal(
        rec.journal_head, np.asarray(eng.peer_state.journal_head)
    )

    full = recovery.full_replay(
        eng.store, eng.cfg.dims, n_buckets=eng.cfg.n_buckets,
        slots=eng.cfg.slots,
    )
    np.testing.assert_array_equal(full.state_digest, live)
    assert eng.verify() == {
        "chain_ok": True, "replica_ok": True, "replay_ok": True,
        "recovery_ok": True, "overflow_ok": True,
    }
    eng.store.close()


def test_engine_pruned_chain_still_verifies():
    eng = _engine(snapshot_every_blocks=3)  # prune_chain defaults True
    for i in range(3):
        eng.run_round(eng.make_proposals(150, seed=10 + i))
    eng.store.drain()
    assert eng.store.base_block_no >= 0  # prefix was compacted
    assert eng.journal.base_block_no == eng.store.base_block_no
    # Lag-one pruning: the previous snapshot anchors the compacted prefix.
    assert eng.store.base_block_no == eng.snapshots[-2].block_no
    assert len(eng.store.chain) < eng._next_block_no
    assert all(eng.verify().values())
    # Full replay from genesis is impossible on a pruned chain — refused,
    # never silently wrong.
    with pytest.raises(recovery.RecoveryError, match="pruned"):
        recovery.full_replay(
            eng.store, eng.cfg.dims, n_buckets=eng.cfg.n_buckets,
            slots=eng.cfg.slots,
        )
    eng.store.close()


def test_engine_rejects_snapshots_without_journal():
    with pytest.raises(ValueError, match="snapshot_every_blocks"):
        engine.FabricEngine(
            engine.EngineConfig(
                peer=dataclasses.replace(
                    engine.FASTFABRIC.peer, journal=False
                ),
                snapshot_every_blocks=4,
            )
        )
    with pytest.raises(ValueError, match="snapshot_every_blocks"):
        engine.FabricEngine(
            dataclasses.replace(engine.FABRIC_V12, snapshot_every_blocks=4)
        )


def test_engine_snapshot_persisted_to_dir(tmp_path):
    eng = _engine(snapshot_every_blocks=2, snapshot_dir=str(tmp_path))
    for i in range(2):
        eng.run_round(eng.make_proposals(100, seed=20 + i))
    eng.store.drain()
    blocks = snapshot.list_blocks(str(tmp_path))
    assert blocks and blocks[-1] == eng.snapshots[-1].block_no
    loaded = snapshot.latest(str(tmp_path))
    assert snapshot.verify(loaded)
    eng.store.close()


def test_engine_recovery_detects_journal_tamper():
    eng = _engine(snapshot_every_blocks=4, prune_chain=False)
    for i in range(3):
        eng.run_round(eng.make_proposals(150, seed=30 + i))
    eng.store.drain()
    idx = -1  # a record in the post-snapshot suffix
    rec = eng.journal.records[idx]
    vals = rec.write_vals.copy()
    vals[0, 0, 0] ^= 1
    eng.journal.records[idx] = rec._replace(write_vals=vals)
    with pytest.raises(recovery.RecoveryError, match="authenticate"):
        eng.recover()
    assert eng.verify()["recovery_ok"] is False
    eng.store.close()


def test_engine_recovery_detects_snapshot_tamper():
    eng = _engine(snapshot_every_blocks=4, prune_chain=False)
    for i in range(3):
        eng.run_round(eng.make_proposals(150, seed=40 + i))
    eng.store.drain()
    snap = eng.snapshots[-1]
    part = snap.shards[0]
    keys = part.keys.copy()
    keys[0, 0, 0] ^= 1
    eng.snapshots[-1] = snap._replace(
        shards=(part._replace(keys=keys),) + snap.shards[1:]
    )
    with pytest.raises(recovery.RecoveryError, match="mismatch"):
        eng.recover()
    assert eng.verify()["recovery_ok"] is False
    eng.store.close()


def test_verify_after_snapshot_list_loss_reports_false_not_crash():
    """Regression: a pruned chain whose covering snapshot is gone (pruned
    list, reloaded dir) used to raise StopIteration out of verify()."""
    eng = _engine(snapshot_every_blocks=3)  # prune_chain defaults True
    for i in range(3):
        eng.run_round(eng.make_proposals(150, seed=70 + i))
    eng.store.drain()
    assert eng.store.base_block_no >= 0  # prefix was compacted
    eng.snapshots.clear()  # simulate snapshot loss
    out = eng.verify()  # must not raise
    assert out["chain_ok"] is False
    assert out["replay_ok"] is False
    assert out["recovery_ok"] is False  # journal pruned, no snapshot
    eng.store.close()


def test_recovery_refuses_overpruned_journal():
    eng = _engine(snapshot_every_blocks=4, prune_chain=False)
    for i in range(2):
        eng.run_round(eng.make_proposals(150, seed=50 + i))
    eng.store.drain()
    eng.journal.prune_upto(eng.journal.records[-1].block_no)
    eng.snapshots.clear()  # no snapshot covers the pruned prefix
    with pytest.raises(recovery.RecoveryError, match="pruned"):
        eng.recover()
    eng.store.close()


# ------------------------------------------------- BlockStore spill coverage


def _chain_blocks(n_blocks=2, batch=8):
    """Consistently hash-chained (wire, valid, prev, hash) tuples."""
    prev = jnp.zeros((2,), jnp.uint32)
    out = []
    for b in range(n_blocks):
        txb = types.make_transfer_batch(DIMS, batch, seed=60 + b)
        wire = unmarshal.marshal(txb, DIMS)
        valid = jnp.ones(batch, bool)
        digest = ledger.block_body_digest(wire, valid)
        bh = ledger.append_hash(prev, jnp.uint32(b), digest)
        out.append((b, prev, bh, wire, valid))
        prev = bh
    return out


def test_blockstore_spill_writes_npz(tmp_path):
    store = ledger.BlockStore(spill_dir=str(tmp_path))
    blocks = _chain_blocks(3)
    for bno, prev, bh, wire, valid in blocks:
        store.submit(bno, prev, bh, wire, valid)
    store.drain()
    assert store.verify_chain()
    for bno, prev, bh, wire, valid in blocks:
        with np.load(tmp_path / f"block_{bno:08d}.npz") as z:
            np.testing.assert_array_equal(z["prev_hash"], np.asarray(prev))
            np.testing.assert_array_equal(z["block_hash"], np.asarray(bh))
            np.testing.assert_array_equal(z["wire"], np.asarray(wire))
            np.testing.assert_array_equal(z["valid"], np.asarray(valid))
    # Pruning compacts the spill directory too; the chain re-anchors.
    store.prune_upto(1)
    assert sorted(p.name for p in tmp_path.iterdir()) == [
        "block_00000002.npz"
    ]
    assert store.verify_chain()
    store.close()


def test_blockstore_close_surfaces_spill_error(tmp_path):
    store = ledger.BlockStore(spill_dir=str(tmp_path / "does_not_exist"))
    bno, prev, bh, wire, valid = _chain_blocks(1)[0]
    store.submit(bno, prev, bh, wire, valid)
    with pytest.raises(FileNotFoundError):
        store.close()


def test_blockstore_drain_surfaces_journal_error():
    class Boom:
        def append_block(self, *a):
            raise RuntimeError("journal sink failed")

    store = ledger.BlockStore(journal=Boom())
    bno, prev, bh, wire, valid = _chain_blocks(1)[0]
    store.submit(bno, prev, bh, wire, valid)
    with pytest.raises(RuntimeError, match="journal sink failed"):
        store.drain()


def test_blockstore_resume_resubmits_dropped_suffix(tmp_path):
    """Supervised restart: after a writer failure, resume() reopens from
    the last durably stored block and the supervisor resubmits the dropped
    suffix — the chain continues gap-free (contrast with the drain() path,
    where the hole is only *detected* by verify_chain)."""

    class FlakyJournal:
        def __init__(self):
            self.blocks = []
            self.fail_once = True

        def append_block(self, bno, wire, valid):
            if bno == 1 and self.fail_once:
                self.fail_once = False
                raise RuntimeError("disk full")
            self.blocks.append(bno)

    j = FlakyJournal()
    store = ledger.BlockStore(spill_dir=str(tmp_path), journal=j)
    blocks = _chain_blocks(4)
    for b in blocks:
        store.submit(*b)
    # Writer fail-stopped at block 1: blocks 1..3 were dropped, no error
    # raised — resume() is the handled-error path.
    nxt = store.resume()
    assert nxt == 1
    assert [sb.block_no for sb in store.chain] == [0]
    for b in blocks[nxt:]:
        store.submit(*b)
    store.drain()  # no latched error left behind by resume()
    assert [sb.block_no for sb in store.chain] == [0, 1, 2, 3]
    assert j.blocks == [0, 1, 2, 3]
    assert store.verify_chain()
    assert sorted(p.name for p in tmp_path.iterdir()) == [
        f"block_{n:08d}.npz" for n in range(4)
    ]
    store.close()


def test_blockstore_resume_without_failure_reports_next_block():
    store = ledger.BlockStore()
    assert store.resume() == 0
    for b in _chain_blocks(2):
        store.submit(*b)
    assert store.resume() == 2
    store.close()


def test_blockstore_writer_failure_fail_stop_and_err_cleared(tmp_path):
    """Regression for error latching: one writer failure used to re-raise
    from every later drain()/close() forever, while blocks kept flowing
    into the chain past the failed journal append (silent divergence)."""

    class FlakyJournal:
        def __init__(self):
            self.blocks = []

        def append_block(self, bno, wire, valid):
            if bno == 1:
                raise RuntimeError("disk full")
            self.blocks.append(bno)

    j = FlakyJournal()
    store = ledger.BlockStore(spill_dir=str(tmp_path), journal=j)
    blocks = _chain_blocks(4)
    for bno, prev, bh, wire, valid in blocks[:3]:
        store.submit(bno, prev, bh, wire, valid)
    with pytest.raises(RuntimeError, match="disk full"):
        store.drain()
    # Fail-stop: neither the failed block nor anything behind it was
    # appended anywhere — chain, journal, AND the spill directory agree
    # on the tail (the failed block's .npz is unlinked, not orphaned).
    assert [sb.block_no for sb in store.chain] == [0]
    assert j.blocks == [0]
    assert sorted(p.name for p in tmp_path.iterdir()) == [
        "block_00000000.npz"
    ]
    # The error is surfaced exactly once, then cleared.
    store.drain()  # no raise
    # The store is usable again; the dropped gap is detectable, never
    # silent: resuming leaves a hole that fails chain verification.
    bno, prev, bh, wire, valid = blocks[3]
    store.submit(bno, prev, bh, wire, valid)
    store.drain()
    assert [sb.block_no for sb in store.chain] == [0, 3]
    assert j.blocks == [0, 3]
    assert not store.verify_chain()
    store.close()


# ----------------------------------------------------------------- benchmark


def test_fig9_benchmark_smoke(capsys):
    from benchmarks import common, fig9_recovery

    common.ROWS.clear()
    fig9_recovery.main(
        ["--round-txs", "100", "--rounds-list", "2", "--snapshot-every", "2",
         "--overhead-iters", "1"]
    )
    names = [r["name"] for r in common.ROWS]
    assert any(n.startswith("full_replay") for n in names)
    assert any(n.startswith("snap+journal") for n in names)
    assert any(n.startswith("journal=") for n in names)
    recs = [r for r in common.ROWS if "recovery_s" in r]
    assert all(r["recovery_s"] > 0 for r in recs)
