"""End-to-end fabric engine: the paper's plug-and-play invariant — every
optimization config must produce byte-identical ledger semantics."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import committer, engine, orderer, types, unmarshal
from repro.core import world_state as ws

CONFIGS = {
    "fabric-1.2": engine.FABRIC_V12,
    "O-I only": engine.EngineConfig(
        orderer=orderer.OrdererConfig(separate_metadata=True,
                                      pipelined=False, block_size=50),
        peer=committer.FABRIC_V12_PEER,
    ),
    "P-I only": engine.EngineConfig(
        orderer=orderer.OrdererConfig(separate_metadata=False,
                                      pipelined=False, block_size=50),
        peer=committer.OPT_P1,
    ),
    "fastfabric": engine.FASTFABRIC,
}


def _run(cfg, n=200, seed=0):
    cfg = dataclasses.replace(
        cfg, orderer=dataclasses.replace(cfg.orderer, block_size=50)
    )
    eng = engine.FabricEngine(cfg)
    stats = eng.run_round(eng.make_proposals(n, seed=seed))
    return eng, stats


def test_all_configs_agree():
    """Same proposals through every config -> same valid count and same
    world-state digest (the optimizations are semantics-preserving)."""
    digests, valids = {}, {}
    for name, cfg in CONFIGS.items():
        eng, stats = _run(cfg)
        assert stats.n_valid == 200, name
        if cfg.peer.hash_state:
            digests[name] = np.asarray(
                ws.state_digest(eng.peer_state.hash_state)
            )
        valids[name] = stats.n_valid
    assert len(set(valids.values())) == 1
    ds = list(digests.values())
    for d in ds[1:]:
        np.testing.assert_array_equal(ds[0], d)


def test_chain_verify_and_replay():
    eng, _ = _run(engine.FASTFABRIC, n=100)
    out = eng.verify()
    assert out == {"chain_ok": True, "replica_ok": True, "replay_ok": True,
                   "recovery_ok": True, "overflow_ok": True}
    eng.store.close()


def test_tampered_block_detected():
    eng, _ = _run(engine.FASTFABRIC, n=100)
    eng.store.drain()
    sb = eng.store.chain[1]
    tampered = sb._replace(wire=sb.wire.copy())
    tampered.wire[0, 8] ^= 0xFF
    eng.store.chain[1] = tampered
    assert not eng.store.verify_chain()


def test_conflicting_workload_flagged_not_dropped():
    """Conflicting txs are flagged invalid but stay in their block."""
    cfg = engine.FASTFABRIC
    eng = engine.FabricEngine(cfg)
    props = eng.make_proposals(100, seed=1)
    # Make 30 txs reuse tx0's source account -> intra-block conflicts.
    src = np.asarray(props.src).copy()
    src[1:31] = src[0]
    props = props._replace(src=jnp.asarray(src))
    stats = eng.run_round(props)
    assert stats.n_txs == 100  # all stayed in blocks
    assert stats.n_valid < 100  # conflicts flagged
    assert eng.verify()["chain_ok"]


def test_double_spend_across_blocks_via_versions():
    """A replayed (stale-version) round must be fully invalidated."""
    eng = engine.FabricEngine(engine.FASTFABRIC)
    props = eng.make_proposals(100, seed=2)
    s1 = eng.run_round(props)
    assert s1.n_valid == 100
    # Re-endorsing against the *updated* replica gives fresh versions ->
    # valid; replaying the identical old round must fail version checks.
    stale = eng.run_round(props)  # same proposals, stale read versions? No:
    # endorsement re-executes against the updated replica, so versions are
    # fresh and the transfer commits again.
    assert stale.n_valid == 100
    # Now simulate a truly stale client: reuse a pre-built wire block by
    # committing it twice at the peer.
    txb = eng.make_proposals(50, seed=3)
    from repro.core import endorser as endo
    endorsed = endo.execute_and_endorse(eng.endorser_state, txb, eng.cfg.dims)
    wire = unmarshal.marshal(endorsed, eng.cfg.dims)
    r1 = committer.commit_block(eng.peer_state, wire, eng.cfg.dims,
                                eng.cfg.peer)
    assert int(r1.valid.sum()) == 50
    r2 = committer.commit_block(r1.state, wire, eng.cfg.dims, eng.cfg.peer)
    assert int(r2.valid.sum()) == 0  # every replayed tx is stale


def test_unmarshal_roundtrip_and_cache():
    dims = types.TEST_DIMS
    txb = types.make_transfer_batch(dims, 32, seed=5)
    wire = unmarshal.marshal(txb, dims)
    dec = unmarshal.unmarshal(wire, dims)
    assert bool(dec.checksum_ok.all())
    for a, b in zip(dec.txb, txb):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # Corruption flips the checksum flag.
    bad = wire.at[3, 40].add(1)
    assert not bool(unmarshal.unmarshal(bad, dims).checksum_ok[3])
    # The P-III cyclic cache: hit on same block_no, evict on reuse.
    cache = unmarshal.UnmarshalCache(depth=2)
    d0 = cache.get(0, wire, dims)
    assert cache.get(0, wire, dims) is d0 and cache.hits == 1
    cache.get(2, wire, dims)  # same slot as 0 -> overwritten
    cache.get(0, wire, dims)
    assert cache.misses == 3


def test_prefix_unmarshal_matches_struct_fields():
    dims = types.TEST_DIMS
    txb = types.make_transfer_batch(dims, 8, seed=6)
    wire = unmarshal.marshal(txb, dims)
    words = jnp.asarray(
        np.frombuffer(np.asarray(wire).tobytes(), dtype=np.uint32)
    ).reshape(8, dims.payload_words)
    spw = unmarshal.struct_prefix_words(dims)
    got = unmarshal.unmarshal_prefix(words[:, :spw], dims)
    for a, b in zip(got, txb):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
