"""Tests for the program-contract analyzer (repro.analysis).

Known-good programs must pass their committed contracts with zero
violations, and each SEEDED defect (an over-budget collective, a dropped
donation, a host callback in a step body, a forced retrace, signature
churn, dtype widening, an unlisted host sync) must flip exactly the
check it targets — the gate's failure messages name the program and the
contracts.json clause to amend.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import checks, contracts, gate, lint, registry
from repro.analysis.retrace import RetraceAuditor
from repro.core import types
from repro.launch import fabric_step as fs
from repro.launch import hlo_cost

EXPECTED_PROGRAMS = {
    "fabric_step/repl/d1",
    "fabric_step/shard/d1",
    "fabric_step/shard/d8",
    "fabric_step/shard/d4/c2",
    "pipeline/stats_pass",
    "pipeline/resize_exchange",
    "serving/decode_step",
}


def test_registry_discovers_all_hot_paths():
    progs = registry.discover()
    assert EXPECTED_PROGRAMS <= set(progs)
    for reg in progs.values():
        assert reg.description  # every program says what it is


# ---------------------------------------------------------------------------
# Known-good artifacts (one compile, shared across tests)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def repl_d1():
    """(BuiltProgram, stablehlo, compiled hlo) of the depth-1 oracle."""
    ctx = gate.build_context()
    built = registry.discover()["fabric_step/repl/d1"].builder(ctx)
    lowered = built.fn.lower(*built.args)
    return built, lowered.as_text(), lowered.compile().as_text()


def test_known_good_programs_pass(repl_d1):
    built, stablehlo, hlo = repl_d1
    art = checks.Artifact(
        name=built.name, hlo_text=hlo, stablehlo_text=stablehlo,
        donated=checks.donated_param_ids(built.args, built.donate_argnums),
        nb_local=built.nb_local, slots=built.slots,
    )
    measured, viols = checks.check_artifact(
        art, contracts.for_program(built.name))
    assert viols == []
    assert measured["commit_scatter_passes"] == \
        contracts.commit_scatter_passes()
    # Donation really aliases: every donated state leaf appears in the
    # compiled module's input_output_alias table.
    assert measured["aliased_params"] == measured["donated_params"]


def test_gate_cli_smoke(tmp_path):
    out = tmp_path / "report.json"
    rc = gate.main(["--only", "pipeline/stats_pass", "--skip-retrace",
                    "--skip-lint", "--json", str(out)])
    assert rc == 0
    import json

    rep = json.loads(out.read_text())
    assert rep["ok"] and "pipeline/stats_pass" in rep["programs"]


# ---------------------------------------------------------------------------
# Seeded violations: each flips exactly the intended clause
# ---------------------------------------------------------------------------


def test_seeded_extra_collective_flags_budget(repl_d1):
    built, _, hlo = repl_d1
    analysis = hlo_cost.analyze(hlo)
    n_ag = analysis["collectives"].get("all-gather", {}).get("count", 0)
    # Tighten the budget below what the program actually issues — the
    # same failure an extra all-gather sneaking into the step produces.
    tight = {"collectives": {"all-gather": max(n_ag - 1, 0)}}
    viols = checks.check_collectives(built.name, tight, analysis)
    if n_ag:  # single-device lowerings may elide collectives entirely
        assert [v.clause for v in viols] == ["collectives.all-gather"]
        assert built.name in str(viols[0])
        assert "contracts.json" in viols[0].message
    # A collective type NOT named in the budget is budget 0.
    viols = checks.check_collectives(
        built.name, {"collectives": {}},
        {"collectives": {"all-to-all": {"count": 2, "wire_bytes": 1.0}}},
    )
    assert [v.clause for v in viols] == ["collectives.all-to-all"]


def test_seeded_dropped_donation_flags_aliasing(repl_d1):
    built, _, _ = repl_d1
    ctx = gate.build_context()
    # Re-jit the SAME step WITHOUT donate_argnums: XLA gets no aliasing
    # hint, the alias table stays empty, and every "donated" parameter
    # reads as silently copied.
    undonated = jax.jit(
        fs.make_fabric_step(ctx.dims, fs.FASTFABRIC_STEP, ctx.mesh)
    )
    hlo = undonated.lower(*built.args).compile().as_text()
    donated = checks.donated_param_ids(built.args, (0,))
    viols = checks.check_donation(
        built.name, {"donation": {"min_aliased_fraction": 1.0}},
        hlo, donated)
    assert [v.clause for v in viols] == ["donation.aliasing"]
    # ... and a program that donates NOTHING against a contract that
    # expects donation is its own clause.
    viols = checks.check_donation(
        built.name, {"donation": {"min_aliased_fraction": 1.0}}, hlo, [])
    assert [v.clause for v in viols] == ["donation.missing"]


def test_seeded_unfused_commit_flags_scatter_passes():
    # Two fused passes (6 table-shaped scatters) where the contract
    # requires one — what a de-fused window commit looks like.
    plane = "tensor<8x4x2xui32>"
    scat = (f'  %s = "stablehlo.scatter"(%a, %b, %c) ({{\n  }}) : '
            f"(...) -> {plane}\n")
    text = scat * 6
    assert checks.table_scatter_passes(text, 8, 4) == 2
    viols = checks.check_commit_scatters(
        "fabric_step/test", {"commit_scatter_passes": 1}, text, 8, 4)
    assert [v.clause for v in viols] == ["commit_scatter_passes"]
    # Channel-batched planes ((C, nb, slots) leading dims) count too.
    text3 = ('  %s = "stablehlo.scatter"(%a) ({\n  }) : '
             "(...) -> tensor<2x8x4x2xui32>\n") * 3
    assert checks.table_scatter_passes(text3, 8, 4) == 1


def test_seeded_host_callback_in_step_body():
    def f(x):
        y = jax.pure_callback(
            lambda a: np.asarray(a),
            jax.ShapeDtypeStruct((4,), jnp.float32), x)
        return y + 1.0

    hlo = jax.jit(f).lower(jnp.zeros(4, jnp.float32)).compile().as_text()
    viols = checks.check_forbidden_ops(
        "t/cb", {"forbid_host_callbacks": True}, hlo)
    assert [v.clause for v in viols] == ["forbidden_ops.host_callback"]
    # The same artifact passes when the callback target is allowlisted.
    target = viols[0].message.split('target="')[1].split('"')[0]
    assert not checks.check_forbidden_ops(
        "t/cb", {"forbid_host_callbacks": True,
                 "allowed_custom_calls": [target]}, hlo)


def test_seeded_dtype_widening():
    hlo = ("  %w = f64[128]{0} add(f64[128]{0} %a, f64[128]{0} %b)\n"
           "  %c = s64[] constant(3)\n")  # scalar bookkeeping: benign
    viols = checks.check_dtypes(
        "t/dt", {"forbidden_dtypes": ["f64", "s64", "u64"]}, hlo)
    assert [v.clause for v in viols] == ["forbidden_dtypes.f64"]


# ---------------------------------------------------------------------------
# Donation plumbing units
# ---------------------------------------------------------------------------


def test_parse_aliased_params_nested_braces():
    hdr = ("HloModule jit_apply, input_output_alias={ {0}: (0, {}, "
           "may-alias), {1}: (2, {}, must-alias) }, "
           "entry_computation_layout={(f32[4]{0})->f32[4]{0}}\nbody\n")
    assert checks.parse_aliased_params(hdr) == {0, 2}
    assert checks.parse_aliased_params("HloModule plain\n") == set()


def test_donated_param_ids_flattens_pytrees():
    args = ({"a": jnp.zeros(2), "b": (jnp.zeros(3), jnp.zeros(4))},
            jnp.zeros(5), jnp.zeros(6))
    assert checks.donated_param_ids(args, (0, 2)) == [0, 1, 2, 4]


# ---------------------------------------------------------------------------
# Retrace auditing
# ---------------------------------------------------------------------------


def test_retrace_new_signatures_within_budget_ok():
    aud = RetraceAuditor(max_signatures=4)
    f = aud.wrap("t/ok", lambda x: x * 2)
    f(jnp.zeros(4))
    f(jnp.zeros(4))  # cache hit
    f(jnp.zeros(8))  # legitimately new shape
    rec = aud.programs["t/ok"]
    assert (rec.calls, rec.traces, len(rec.seen)) == (3, 2, 2)
    assert not aud.violations


def test_seeded_forced_retrace_flagged():
    aud = RetraceAuditor(max_signatures=4)
    f = aud.wrap("t/evict", lambda x: x + 1)
    x = jnp.arange(8)
    f(x)
    f(x)
    assert not aud.violations
    jax.clear_caches()  # simulate cache eviction / key churn
    f(x)
    viols = [v for v in aud.violations if v.clause == "retrace.recompiled"]
    assert len(viols) == 1 and "t/evict" in str(viols[0])


def test_seeded_signature_churn_flagged():
    aud = RetraceAuditor(max_signatures=2)
    f = aud.wrap("t/churn", lambda x: x + 1)
    for n in (4, 8, 16):  # a shape varying every round
        f(jnp.zeros(n))
    assert any(v.clause == "retrace.signature_churn"
               for v in aud.violations)


def test_committer_audited_workload_clean():
    # The gate's live workload (windows, stats reads, a resize epoch,
    # more windows) through an audited MeshWindowCommitter: every trace
    # stays inside the allowed key set.
    auditor = gate.run_retrace(gate.make_mesh(), types.TEST_DIMS)
    assert not auditor.violations
    steps = auditor.programs["pipeline/window_step/d2"]
    assert steps.calls == 5
    assert steps.traces < steps.calls  # steady state hits the cache
    stats = auditor.programs["pipeline/stats_pass"]
    assert (stats.calls, stats.traces) == (2, 1)


def test_audited_wrapper_exposes_lower():
    aud = RetraceAuditor(max_signatures=4)
    f = aud.wrap("t/lower", lambda x: x * 3)
    hlo = f.lower(jnp.zeros(4)).compile().as_text()
    assert "HloModule" in hlo


# ---------------------------------------------------------------------------
# Source lint
# ---------------------------------------------------------------------------

_LINT_SRC = """\
import jax

def hot_loop(x):
    return jax.block_until_ready(x)

class Edge:
    def drain(self, x):
        return jax.device_get(x)
"""


def test_lint_flags_and_allowlist(tmp_path):
    (tmp_path / "mod.py").write_text(_LINT_SRC)
    viols = lint.lint_tree(str(tmp_path), allow=["mod.py:Edge.drain"])
    assert [v.clause for v in viols] == ["lint.block_until_ready"]
    assert "hot_loop" in viols[0].message
    # Widening the allowlist clears it.
    assert not lint.lint_tree(str(tmp_path), allow=["mod.py:*"])


def test_lint_repo_is_clean():
    assert gate.run_lint() == []


# ---------------------------------------------------------------------------
# Contracts file + deduplicated HLO parser
# ---------------------------------------------------------------------------


def test_contracts_single_source_of_truth():
    # fig11 and CI consume this value; the fabric_step contracts must
    # agree on it.
    assert contracts.commit_scatter_passes() == 1
    # Defaults overlay: unknown programs still get the baseline rules.
    c = contracts.for_program("not/registered")
    assert c["forbid_host_callbacks"] and "f64" in c["forbidden_dtypes"]
    # Per-program clauses override defaults ("null" disables a clause).
    assert contracts.for_program("pipeline/stats_pass")["donation"] is None


def test_dryrun_delegates_to_hlo_cost_parser():
    from repro.launch import dryrun

    assert dryrun.parse_collectives is hlo_cost.parse_collectives


def test_parse_collectives_counts_new_dtypes():
    # The dryrun's private copy missed f8e3m4 / s4 — the shared parser
    # prices them.
    hlo = ("  %ag = f8e3m4[16]{0} all-gather(f8e3m4[2]{0} %p), "
           "replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}\n"
           "  %rs = s4[32]{0} reduce-scatter(s4[256]{0} %q), "
           "replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}\n")
    out = hlo_cost.parse_collectives(hlo)
    assert out["all-gather"]["count"] == 1
    assert out["all-gather"]["buffer_bytes"] == 16.0
    assert out["reduce-scatter"]["count"] == 1
    assert out["total_wire_bytes"] > 0
