"""Sharding rules: divisibility fallbacks and spec structure (no multi-
device runtime needed — specs are pure functions of shapes)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import base
from repro.launch import sharding, specs as specs_lib
from repro.models.lm import LM


def _full_param_shapes(arch):
    cfg = base.get(arch)
    model = LM(cfg)
    return cfg, jax.eval_shape(model.init, jax.random.PRNGKey(0))


@pytest.mark.parametrize("arch", base.ARCH_IDS)
def test_specs_divisible_everywhere(arch):
    """Every sharded dim must divide by the model-axis width (16)."""
    cfg, shapes = _full_param_shapes(arch)
    pspecs = sharding.param_specs(shapes)  # default msize=16

    def check(path, leaf, spec):
        for dim, entry in zip(leaf.shape, tuple(spec) + (None,) * leaf.ndim):
            if entry == "model":
                assert dim % 16 == 0, (path, leaf.shape, spec)

    jax.tree_util.tree_map_with_path(
        lambda p, l, s: check(p, l, s), shapes, pspecs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )


def test_vocab_padded_shards():
    for arch in ("mamba2-2.7b", "seamless-m4t-medium"):
        cfg, shapes = _full_param_shapes(arch)
        assert shapes["embed"].shape[0] % 256 == 0
        pspecs = sharding.param_specs(shapes)
        assert pspecs["embed"] == P("model", None)


def test_moe_ep_vs_tp_fallback():
    # moonshot: 64 experts % 16 == 0 -> EP on the expert dim.
    _, shapes = _full_param_shapes("moonshot-v1-16b-a3b")
    sp = sharding.param_specs(shapes)
    assert sp["layers"]["moe"]["w_gate"] == P(None, "model", None, None)
    # qwen2-moe: 60 experts % 16 != 0 -> TP inside experts.
    _, shapes = _full_param_shapes("qwen2-moe-a2.7b")
    sp = sharding.param_specs(shapes)
    assert sp["layers"]["moe"]["w_gate"] == P(None, None, None, "model")
    assert sp["layers"]["moe"]["w_down"] == P(None, None, "model", None)


def test_attention_col_row_split():
    _, shapes = _full_param_shapes("qwen2-7b")
    sp = sharding.param_specs(shapes)
    att = sp["layers"]["attn"]
    assert att["wq"] == P(None, None, "model")
    assert att["wo"] == P(None, "model", None)
    assert att["bq"] == P(None, "model")
    assert sp["layers"]["mlp"]["w_down"] == P(None, "model", None)
    assert sp["final_norm"]["scale"] == P(None)


def test_zero1_adds_data_axis():
    spec = sharding.zero1_pspec(
        P(None, None, "model"), (28, 3584, 18944), ("data",), 16
    )
    assert spec == P(None, "data", "model")
    # No divisible replicated dim -> unchanged.
    spec2 = sharding.zero1_pspec(P("model"), (80,), ("data",), 16)
    assert spec2 == P("model")


def test_input_specs_shapes():
    cfg = base.get("llava-next-34b")
    b = specs_lib.batch_specs(cfg, 4096, 256, with_labels=True)
    assert b.tokens.shape == (256, 4096 - cfg.n_prefix)
    assert b.prefix_embeds.shape == (256, cfg.n_prefix, cfg.d_model)
    cfg2 = base.get("seamless-m4t-medium")
    b2 = specs_lib.batch_specs(cfg2, 4096, 256, with_labels=True)
    assert b2.enc_embeds.shape == (256, 1024, cfg2.d_model)
    assert b2.tokens.shape == (256, 4096)
