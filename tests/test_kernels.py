"""Per-kernel allclose sweeps: Pallas (interpret=True) vs pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import crypto, types
from repro.kernels.hash_table import kernel as htk, ref as htr
from repro.kernels.mvcc_validate import kernel as mvk, ref as mvr
from repro.kernels.sig_mac import kernel as smk, ref as smr

RNG = np.random.default_rng(7)


def _rand_table(nb, s, vw, fill: float):
    """A table pre-filled via the oracle so contents are consistent."""
    tkeys = jnp.zeros((nb, s, 2), jnp.uint32)
    tvers = jnp.zeros((nb, s), jnp.uint32)
    tvals = jnp.zeros((nb, s, vw), jnp.uint32)
    n = int(nb * s * fill)
    if n:
        wk = jnp.asarray(RNG.integers(1, 1 << 32, (n, 2), dtype=np.uint32))
        wv = jnp.asarray(RNG.integers(0, 1 << 32, (n, vw), dtype=np.uint32))
        tkeys, tvers, tvals, _ = htr.commit_ref(
            tkeys, tvers, tvals, wk, wv, jnp.ones((n,), bool)
        )
    return tkeys, tvers, tvals


class TestHashTableKernel:
    @pytest.mark.parametrize("nb,s,vw,q", [
        (16, 4, 1, 8), (64, 8, 4, 100), (128, 8, 2, 257), (32, 16, 8, 64),
    ])
    def test_lookup_matches_ref(self, nb, s, vw, q):
        tkeys, tvers, tvals = _rand_table(nb, s, vw, 0.3)
        # Half hits (existing keys), half random probes.
        occ = np.argwhere(np.asarray(tkeys[..., 0]) != 0)
        hits = occ[RNG.integers(0, len(occ), q // 2)]
        qk_hit = np.asarray(tkeys)[hits[:, 0], hits[:, 1]]
        qk_miss = RNG.integers(1, 1 << 32, (q - q // 2, 2), dtype=np.uint32)
        queries = jnp.asarray(np.concatenate([qk_hit, qk_miss]))
        got = htk.lookup(tkeys, tvers, tvals, queries, interpret=True,
                         q_tile=32)
        want = htr.lookup_ref(tkeys, tvers, tvals, queries)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))

    @pytest.mark.parametrize("nb,s,vw,k", [
        (16, 4, 1, 10), (64, 8, 4, 128), (32, 8, 2, 77),
    ])
    def test_commit_matches_ref(self, nb, s, vw, k):
        tkeys, tvers, tvals = _rand_table(nb, s, vw, 0.2)
        wk = jnp.asarray(RNG.integers(1, 1 << 32, (k, 2), dtype=np.uint32))
        # include updates to existing keys
        occ = np.argwhere(np.asarray(tkeys[..., 0]) != 0)
        if len(occ):
            upd = occ[RNG.integers(0, len(occ), k // 4)]
            wk_np = np.asarray(wk).copy()
            wk_np[: len(upd)] = np.asarray(tkeys)[upd[:, 0], upd[:, 1]]
            wk = jnp.asarray(wk_np)
        wv = jnp.asarray(RNG.integers(0, 1 << 32, (k, vw), dtype=np.uint32))
        act = jnp.asarray(RNG.random(k) < 0.85)
        got = htk.commit(tkeys, tvers, tvals, wk, wv, act, interpret=True)
        want = htr.commit_ref(tkeys, tvers, tvals, wk, wv, act)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))

    def test_overflow_flag(self):
        nb, s, vw = 2, 2, 1
        tkeys = jnp.zeros((nb, s, 2), jnp.uint32)
        tvers = jnp.zeros((nb, s), jnp.uint32)
        tvals = jnp.zeros((nb, s, vw), jnp.uint32)
        # 5 distinct keys into 2 buckets x 2 slots must overflow.
        wk = jnp.asarray([[2 * i + 2, i + 1] for i in range(5)],
                         jnp.uint32)
        wv = jnp.ones((5, vw), jnp.uint32)
        *_, ovf_k = htk.commit(tkeys, tvers, tvals, wk, wv,
                               jnp.ones((5,), bool), interpret=True)
        *_, ovf_r = htr.commit_ref(tkeys, tvers, tvals, wk, wv,
                                   jnp.ones((5,), bool))
        assert bool(ovf_k) == bool(ovf_r) is True

    @settings(deadline=None, max_examples=40)
    @given(
        nb=st.sampled_from([1, 2, 4]),
        s=st.sampled_from([1, 2]),
        keys=st.lists(
            st.tuples(st.integers(1, 8), st.integers(1, 4)),
            min_size=1, max_size=12,
        ),
        act_bits=st.lists(st.booleans(), min_size=12, max_size=12),
    )
    def test_commit_overflow_parity_to_saturation(self, nb, s, keys,
                                                  act_bits):
        """Satellite: drive both commit implementations to bucket
        saturation (keys drawn from a tiny pool into <= 8 slots, so most
        cases overflow) and pin identical (state, overflow) outputs —
        duplicate keys, interleaved drops, partial bucket fills and all."""
        k = len(keys)
        wk = jnp.asarray(np.array(keys, dtype=np.uint32))
        wv = jnp.asarray(
            (np.arange(k, dtype=np.uint32) + 1)[:, None].repeat(2, axis=1)
        )
        act = jnp.asarray(np.array(act_bits[:k], dtype=bool))
        tk = jnp.zeros((nb, s, 2), jnp.uint32)
        tv = jnp.zeros((nb, s), jnp.uint32)
        tva = jnp.zeros((nb, s, 2), jnp.uint32)
        got = htk.commit(tk, tv, tva, wk, wv, act, interpret=True)
        want = htr.commit_ref(tk, tv, tva, wk, wv, act)
        for name, g, w in zip(("keys", "versions", "values", "overflow"),
                              got, want):
            np.testing.assert_array_equal(
                np.asarray(g), np.asarray(w), err_msg=name
            )

    def test_ops_commit_window_sharded_dispatch(self, monkeypatch):
        """ops.commit_window routes over-budget tables through the owner-
        shard partition; the sharded sweep must equal the full-table fused
        commit (world_state.commit_window) exactly."""
        from repro.core import world_state as ws
        from repro.kernels.hash_table import ops as ht_ops

        monkeypatch.setattr(ht_ops, "VMEM_BUDGET_BYTES", 2048)
        nb, s, vw = 64, 2, 2
        tk = jnp.zeros((nb, s, 2), jnp.uint32)
        tv = jnp.zeros((nb, s), jnp.uint32)
        tva = jnp.zeros((nb, s, vw), jnp.uint32)
        assert ht_ops._n_shards(tk, tva) > 1
        # A two-block window log: block 0 inserts 40 keys, block 1 updates
        # the first 20 of them (bump, not new) and inserts 20 more. One
        # key per bucket (low bits = bucket) keeps the hand-built log
        # consistent: every claimed insert really fits.
        mk = lambda lo, hi: np.stack(
            [np.arange(lo, hi, dtype=np.uint32)
             | (RNG.integers(1, 1 << 24, hi - lo).astype(np.uint32) << 6),
             RNG.integers(1, 1 << 32, hi - lo, dtype=np.uint32)], axis=1)
        k0 = mk(0, 40)
        k1 = np.concatenate([k0[:20], mk(40, 60)])
        log_keys = jnp.asarray(np.concatenate([k0, k1]))
        log_vals = jnp.asarray(
            RNG.integers(0, 1 << 32, (80, vw), dtype=np.uint32)
        )
        bumps = jnp.ones((80,), bool)
        new = jnp.asarray(
            np.concatenate([np.ones(40), np.zeros(20), np.ones(20)]) > 0
        )
        got = ht_ops.commit_window(
            tk, tv, tva, log_keys, log_vals, bumps, new
        )
        want = ws.commit_window(
            ws.HashState(tk, tv, tva), log_keys, log_vals, bumps, new
        )
        for name, g, w in zip(("keys", "versions", "values"), got, want):
            np.testing.assert_array_equal(
                np.asarray(g), np.asarray(w), err_msg=name
            )
        # LWW semantics: twice-written keys end at version 2 with block 1's
        # value; once-written keys at version 1.
        look = ws.lookup(ws.HashState(*got), jnp.asarray(k1))
        assert bool(look.found.all())
        np.testing.assert_array_equal(
            np.asarray(look.versions),
            np.concatenate([np.full(20, 2), np.ones(20)]).astype(np.uint32),
        )
        np.testing.assert_array_equal(
            np.asarray(look.values), np.asarray(log_vals[40:])
        )


class TestMvccKernel:
    @pytest.mark.parametrize("b,conflict", [(8, 0.0), (32, 0.3), (64, 0.8),
                                            (16, 1.0)])
    def test_matches_ref(self, b, conflict):
        txb = types.make_transfer_batch(
            types.TEST_DIMS, b, conflict_rate=conflict, seed=b
        )
        cur = jnp.zeros((b, types.TEST_DIMS.rk), jnp.uint32)
        ok0 = jnp.asarray(RNG.random(b) < 0.9)
        got = mvk.validate_blocks(
            txb.read_keys[None], txb.read_vers[None], txb.write_keys[None],
            cur[None], ok0[None], interpret=True,
        )[0]
        want = mvr.validate_ref(
            txb.read_keys, txb.read_vers, txb.write_keys, cur, ok0
        )
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_multi_block_grid(self):
        nb, b = 3, 16
        txbs = [types.make_transfer_batch(types.TEST_DIMS, b,
                                          conflict_rate=0.5, seed=i)
                for i in range(nb)]
        rk = jnp.stack([t.read_keys for t in txbs])
        rv = jnp.stack([t.read_vers for t in txbs])
        wk = jnp.stack([t.write_keys for t in txbs])
        cur = jnp.zeros((nb, b, types.TEST_DIMS.rk), jnp.uint32)
        ok0 = jnp.ones((nb, b), bool)
        got = mvk.validate_blocks(rk, rv, wk, cur, ok0, interpret=True)
        for i in range(nb):
            want = mvr.validate_ref(rk[i], rv[i], wk[i], cur[i], ok0[i])
            np.testing.assert_array_equal(np.asarray(got[i]),
                                          np.asarray(want))


class TestSigMacKernel:
    @pytest.mark.parametrize("b,w,ne,tile", [
        (8, 4, 1, 8), (100, 21, 3, 32), (257, 16, 5, 64), (64, 64, 2, 64),
    ])
    def test_matches_ref(self, b, w, ne, tile):
        msg = jnp.asarray(RNG.integers(0, 1 << 32, (b, w), dtype=np.uint32))
        rs, ss = crypto.endorser_keys(ne)
        got = smk.mac_many(msg, rs, ss, tx_tile=tile, interpret=True)
        want = smr.mac_many_ref(msg, rs, ss)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2**32 - 1), st.integers(0, 2**32 - 1))
    def test_mulmod31_property(self, a, b):
        p = (1 << 31) - 1
        am, bm = a % p, b % p
        got = crypto.mulmod31(jnp.uint32(am), jnp.uint32(bm))
        assert int(got) == (am * bm) % p

    def test_forgery_fails(self):
        """Flipping any message word must change the tag (w.h.p.)."""
        msg = jnp.asarray(RNG.integers(0, 1 << 32, (4, 8), dtype=np.uint32))
        rs, ss = crypto.endorser_keys(1)
        tag = smr.mac_many_ref(msg, rs, ss)
        forged = msg.at[:, 3].add(1)
        tag2 = smr.mac_many_ref(forged, rs, ss)
        assert not np.any(np.asarray(tag) == np.asarray(tag2))
