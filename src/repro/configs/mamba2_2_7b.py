"""mamba2-2.7b — attention-free SSD (state-space duality) stack.
[arXiv:2405.21060; unverified]

d_inner = 2*d_model = 5120, head_dim 64 -> 80 SSD heads, state N=128.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_head_dim=64,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke",
        family="ssm",
        n_layers=2,
        d_model=64,
        d_ff=0,
        vocab=256,
        ssm_state=16,
        ssm_head_dim=16,
        dtype="float32",
    )
