"""qwen2-moe-a2.7b — MoE, 60 routed experts top-4 + 4 shared.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv=16,
    d_head=128,
    d_ff=1408,  # per-expert width
    vocab=151936,
    n_experts=60,
    top_k=4,
    n_shared=4,
    rope_theta=1_000_000.0,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv=4,
        d_head=16,
        d_ff=32,
        vocab=256,
        n_experts=6,
        top_k=2,
        n_shared=2,
        dtype="float32",
    )
