"""qwen3-4b — dense GQA decoder with qk_norm, explicit head_dim=128.
[hf:Qwen/Qwen3-8B; hf]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b",
    family="dense",
    n_layers=36,
    d_model=2560,
    n_heads=32,
    n_kv=8,
    d_head=128,
    d_ff=9728,
    vocab=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-4b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv=2,
        d_head=16,
        d_ff=128,
        vocab=256,
        qk_norm=True,
        dtype="float32",
    )
