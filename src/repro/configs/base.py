"""Architecture config schema + registry.

Every assigned architecture is a ``ModelConfig`` in its own module
(``src/repro/configs/<id>.py``) exposing ``CONFIG`` (the exact published
shape) and ``smoke_config()`` (a reduced same-family config for CPU tests).
``get(name)`` resolves either by registry id.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static architecture description (one per assigned arch).

    ``family`` selects the block stack:
      dense   — pre-norm GQA transformer decoder
      moe     — dense attention + (shared + routed top-k) MoE MLPs
      ssm     — attention-free Mamba2 (SSD) stack
      hybrid  — Mamba2 stack with a weight-shared attention block every
                ``attn_every`` layers (zamba2)
      encdec  — encoder/decoder with cross attention (seamless)
    ``frontend`` (audio/vision) prepends precomputed embeddings — the
    modality encoder itself is a stub per the assignment.
    """

    name: str
    family: str  # dense | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    vocab: int
    # Attention (ignored for family == "ssm").
    n_heads: int = 0
    n_kv: int = 0
    d_head: int = 0
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1_000_000.0
    # Dense MLP width (per-expert width for MoE).
    d_ff: int = 0
    # MoE.
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0
    # SSM (Mamba2 / SSD).
    ssm_state: int = 0
    ssm_head_dim: int = 64
    d_conv: int = 4
    expand: int = 2
    # Hybrid: shared attention block cadence (zamba2).
    attn_every: int = 0
    # Encoder-decoder.
    enc_layers: int = 0
    # Modality frontend stub: number of prefix embedding positions.
    frontend: Optional[str] = None  # "audio" | "vision"
    n_prefix: int = 0
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"  # parameter/activation dtype for the big runs

    # ---- derived ----
    @property
    def vocab_padded(self) -> int:
        """Embedding rows padded to a multiple of 256 so the vocab dim
        shards over any production model-axis width (Megatron-style vocab
        padding). Padded logits are masked to -inf in the loss/sampler."""
        return ((self.vocab + 255) // 256) * 256

    @property
    def head_dim(self) -> int:
        if self.d_head:
            return self.d_head
        return self.d_model // max(self.n_heads, 1)

    @property
    def d_inner(self) -> int:
        """Mamba2 inner width."""
        return self.expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    @property
    def jnp_dtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]

    @property
    def is_subquadratic(self) -> bool:
        """True iff long-context decode (500k) is runnable: attention-free
        or attention applied only at a fixed cadence with bounded state."""
        return self.family in ("ssm", "hybrid")

    def n_params(self) -> int:
        """Exact parameter count (embeddings included once if tied)."""
        d, v = self.d_model, self.vocab
        n = v * d  # embedding
        if not self.tie_embeddings:
            n += v * d  # lm head
        hd = self.head_dim

        def attn_params() -> int:
            qkv = d * (self.n_heads + 2 * self.n_kv) * hd
            if self.qkv_bias:
                qkv += (self.n_heads + 2 * self.n_kv) * hd
            o = self.n_heads * hd * d
            qknorm = 2 * hd if self.qk_norm else 0
            return qkv + o + qknorm

        def dense_mlp(width: int) -> int:
            return 3 * d * width  # SwiGLU: gate, up, down

        def mamba_params() -> int:
            di, ns, nh = self.d_inner, self.ssm_state, self.ssm_heads
            in_proj = d * (2 * di + 2 * ns + nh)
            conv = (di + 2 * ns) * (self.d_conv + 1)  # conv_w + conv_b
            out = di * d
            extra = nh * 3 + di  # A_log, D, dt_bias, gated-norm scale
            return in_proj + conv + out + extra

        per_layer_norms = 2 * d
        if self.family == "dense":
            n += self.n_layers * (attn_params() + dense_mlp(self.d_ff)
                                  + per_layer_norms)
        elif self.family == "moe":
            router = d * self.n_experts
            experts = (self.n_experts + self.n_shared) * dense_mlp(self.d_ff)
            n += self.n_layers * (attn_params() + router + experts
                                  + per_layer_norms)
        elif self.family == "ssm":
            n += self.n_layers * (mamba_params() + d)
        elif self.family == "hybrid":
            n += self.n_layers * (mamba_params() + d)
            n += attn_params() + dense_mlp(self.d_ff) + per_layer_norms  # shared
        elif self.family == "encdec":
            # Encoder self-attn + MLP; decoder self-attn + cross-attn + MLP.
            n += self.enc_layers * (attn_params() + dense_mlp(self.d_ff)
                                    + per_layer_norms)
            n += self.n_layers * (2 * attn_params() + dense_mlp(self.d_ff)
                                  + 3 * d)
            n += d  # enc_final_norm
        n += d  # final norm
        return n

    def n_active_params(self) -> int:
        """Active params per token (MoE: routed top-k + shared only)."""
        if self.family != "moe":
            return self.n_params()
        d = self.d_model
        all_experts = self.n_experts * 3 * d * self.d_ff
        active_experts = self.top_k * 3 * d * self.d_ff
        return self.n_params() - self.n_layers * (all_experts - active_experts)


ARCH_IDS = (
    "qwen2-7b",
    "phi3-mini-3.8b",
    "qwen3-4b",
    "qwen2.5-14b",
    "seamless-m4t-medium",
    "zamba2-1.2b",
    "mamba2-2.7b",
    "moonshot-v1-16b-a3b",
    "qwen2-moe-a2.7b",
    "llava-next-34b",
)

_MOD = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get(name: str) -> ModelConfig:
    """Resolve an architecture id to its full published config."""
    if name not in _MOD:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MOD)}")
    return importlib.import_module(f"repro.configs.{_MOD[name]}").CONFIG


def get_smoke(name: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    if name not in _MOD:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MOD)}")
    return importlib.import_module(f"repro.configs.{_MOD[name]}").smoke_config()
