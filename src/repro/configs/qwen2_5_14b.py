"""qwen2.5-14b — dense GQA decoder, QKV bias. [hf:Qwen/Qwen2.5; hf]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    family="dense",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv=8,
    d_head=128,
    d_ff=13824,
    vocab=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-14b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv=2,
        d_head=16,
        d_ff=128,
        vocab=256,
        qkv_bias=True,
        dtype="float32",
    )
