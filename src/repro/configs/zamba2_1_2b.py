"""zamba2-1.2b — hybrid: Mamba2 backbone + weight-shared attention block
applied every 6 layers. [arXiv:2411.15242; hf]

The published model interleaves a single shared transformer block (attention
+ MLP, one parameter set) at a fixed cadence over the Mamba2 stack; we
reproduce that structure (cadence ``attn_every=6`` -> ceil(38/6)=7
applications) with the shared block's own KV caches per application site.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv=32,
    d_head=64,
    d_ff=8192,
    vocab=32000,
    ssm_state=64,
    ssm_head_dim=64,
    attn_every=6,
    rope_theta=10_000.0,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke",
        family="hybrid",
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv=4,
        d_head=16,
        d_ff=128,
        vocab=256,
        ssm_state=16,
        ssm_head_dim=16,
        attn_every=2,
        dtype="float32",
    )
