"""llava-next-34b — VLM: dense GQA decoder backbone + anyres patch-embedding
frontend STUB. [hf:llava-hf/llava-v1.6; unverified]

The vision tower is a stub per the assignment: ``input_specs()`` provides
``n_prefix`` precomputed patch embeddings (anyres tiling is metadata only);
the backbone sees [patch embeds | token embeds].
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="dense",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv=8,
    d_head=128,
    d_ff=20480,
    vocab=64000,
    frontend="vision",
    n_prefix=576,  # one 24x24 patch grid (anyres base tile)
    rope_theta=5_000_000.0,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llava-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv=2,
        d_head=16,
        d_ff=128,
        vocab=256,
        frontend="vision",
        n_prefix=16,
        dtype="float32",
    )
