"""seamless-m4t-medium — encoder-decoder, multimodal (audio frontend stub).
[arXiv:2308.11596; hf]

12L is interpreted as 12 encoder + 12 decoder layers (the m4t-medium text
model is 12/12). The speech frontend is a STUB per the assignment:
``input_specs()`` provides precomputed audio-frame embeddings of length
seq_len//4 (≈20ms frames after the conformer downsampling) as encoder
input; the decoder consumes tokens.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,  # decoder layers
    enc_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv=16,
    d_head=64,
    d_ff=4096,
    vocab=256206,
    frontend="audio",
    rope_theta=10_000.0,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="seamless-smoke",
        family="encdec",
        n_layers=2,
        enc_layers=2,
        d_model=64,
        n_heads=4,
        n_kv=4,
        d_head=16,
        d_ff=128,
        vocab=256,
        frontend="audio",
        dtype="float32",
    )
