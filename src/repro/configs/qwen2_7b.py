"""qwen2-7b — dense GQA decoder, QKV bias. [arXiv:2407.10671; hf]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b",
    family="dense",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv=4,
    d_head=128,
    d_ff=18944,
    vocab=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-7b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv=2,
        d_head=16,
        d_ff=128,
        vocab=256,
        qkv_bias=True,
        dtype="float32",
    )
