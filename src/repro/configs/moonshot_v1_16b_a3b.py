"""moonshot-v1-16b-a3b — MoE, 64 routed experts top-6 (+2 shared, per the
Moonlight reference config). [hf:moonshotai/Moonlight-16B-A3B; hf]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv=16,
    d_head=128,
    d_ff=1408,  # per-expert width
    vocab=163840,
    n_experts=64,
    top_k=6,
    n_shared=2,
    rope_theta=50_000.0,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="moonshot-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv=4,
        d_head=16,
        d_ff=32,
        vocab=256,
        n_experts=8,
        top_k=2,
        n_shared=1,
        dtype="float32",
    )
