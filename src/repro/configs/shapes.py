"""Assigned input-shape set for the LM-family architectures.

Each shape names the step it lowers:
  train_4k    -> train_step   (seq 4,096  x global_batch 256)
  prefill_32k -> serve_prefill (seq 32,768 x global_batch 32)
  decode_32k  -> serve_decode  (one new token, KV cache of 32,768, batch 128)
  long_500k   -> serve_decode  (one new token, context 524,288, batch 1) —
                 sub-quadratic archs only (ssm/hybrid); skipped for pure
                 full-attention archs per the assignment (see DESIGN.md).
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    step: str  # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = (
    ShapeSpec("train_4k", "train", 4_096, 256),
    ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    ShapeSpec("decode_32k", "decode", 32_768, 128),
    ShapeSpec("long_500k", "decode", 524_288, 1),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runnable, reason). Encodes the assignment's skip rules."""
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, (
            "long_500k requires sub-quadratic attention; "
            f"{cfg.name} is pure full-attention (skip per assignment)"
        )
    return True, ""


def cells(cfg: ModelConfig):
    """All (shape, runnable, reason) cells for one arch."""
    return [(s, *applicable(cfg, s)) for s in SHAPES]
