"""phi3-mini-3.8b — dense decoder, RoPE SwiGLU, MHA-style GQA (kv=32).
[arXiv:2404.14219; unverified]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv=32,
    d_head=96,
    d_ff=8192,
    vocab=32064,
    rope_theta=10_000.0,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="phi3-mini-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv=4,
        d_head=16,
        d_ff=128,
        vocab=256,
        dtype="float32",
    )
