"""Committer peer: the validation/commit pipeline (Opt P-I .. P-III).

Paper mapping (§III-D..I). A Fabric 1.2 peer runs, per block:
  1. syntactic verification        (re-unmarshals the block)
  2. endorsement policy validation (re-unmarshals again, serial per tx)
  3. read/write-set MVCC validation (sequential; LevelDB lookups)
  4. commit: state DB update + blockchain log write

FastFabric keeps the stage semantics but
  P-I   swaps LevelDB for the in-memory hash table,
  P-II  parallelizes 1+2 and pipelines blocks; endorsement & storage move to
        separate hardware (mesh roles / BlockStore here),
  P-III caches unmarshaled blocks so each block is decoded exactly once.

TPU adaptation of P-III: Fabric's stages are separate modules exchanging
protobuf. We model the baseline the same way — each stage is its *own jit'd
program that re-decodes the wire* (no cross-program CSE, so the re-decode tax
is real). The optimized committer fuses all stages into one program around
the decoded block: the "cache" is the decoded SoA staying resident in
VMEM/registers across stages, plus the host-side UnmarshalCache between the
syntax pre-check and the main stage (cyclic, pipeline-deep, exactly the
paper's buffer).

Serial vs parallel (P-II): the baseline validates endorsements one
transaction at a time (lax.scan); the optimized path vmaps across the block
(the VPU-lane goroutine pool), and the engine keeps ``pipeline_depth`` blocks
in flight via JAX async dispatch.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import crypto, hashing, ledger, mvcc, types, unmarshal
from repro.core import world_state as ws
from repro.storage import journal as state_journal

U32 = jnp.uint32


@dataclasses.dataclass(frozen=True)
class PeerConfig:
    """Cumulative optimization flags (paper's Opt P-I/P-II/P-III)."""

    hash_state: bool = True  # P-I: hash table world state (else sorted store)
    parallel: bool = True  # P-II: vmapped validation (else per-tx scan)
    cache: bool = True  # P-III: decode once (else re-decode per stage)
    sequential_commit: bool = False  # paper-faithful serial state update
    pipeline_depth: int = 8  # blocks in flight (P-II)
    tx_par: int = 0  # 0 = whole block at once; else tile width (Fig 7 knob)
    # Authenticated state-journal head on the commit path (storage/journal).
    # Off for the paper-faithful baseline (its durability is the database);
    # on from P-I up, where dropping the database makes the journal the
    # restart story.
    journal: bool = True

    @property
    def name(self) -> str:
        if not (self.hash_state or self.parallel or self.cache):
            return "fabric-1.2"
        tags = []
        if self.hash_state:
            tags.append("P-I")
        if self.parallel:
            tags.append("P-II")
        if self.cache:
            tags.append("P-III")
        return "+".join(tags)


FABRIC_V12_PEER = PeerConfig(
    hash_state=False, parallel=False, cache=False, sequential_commit=True,
    pipeline_depth=1, journal=False,
)
OPT_P1 = dataclasses.replace(FABRIC_V12_PEER, hash_state=True, journal=True)
OPT_P2 = dataclasses.replace(OPT_P1, parallel=True, pipeline_depth=8)
OPT_P3 = dataclasses.replace(OPT_P2, cache=True, sequential_commit=False)
FASTFABRIC_PEER = OPT_P3


class PeerState(NamedTuple):
    """World state + authentication heads, threaded through block commits.

    ``journal_head`` is the state-journal's running digest (storage/journal):
    the commit path folds each block's validated write sets into it, so the
    peer always carries the head that the off-path journal must reproduce.
    """

    hash_state: ws.HashState
    sorted_state: ws.SortedState
    ledger_head: jnp.ndarray  # (2,) u32
    block_no: jnp.ndarray  # () u32
    journal_head: jnp.ndarray  # (2,) u32


def create_peer_state(
    dims: types.FabricDims,
    *,
    n_buckets: int = 1 << 12,
    slots: int = 8,
    sorted_capacity: int | None = None,
) -> PeerState:
    cap = sorted_capacity or n_buckets * slots
    return PeerState(
        hash_state=ws.create(n_buckets, slots, dims.vw),
        sorted_state=ws.sorted_create(cap, dims.vw),
        # Fresh buffer (not the shared GENESIS constant): commits donate the
        # peer state, and donating a shared module-level array would delete it.
        ledger_head=jnp.zeros((2,), U32),
        block_no=jnp.uint32(0),
        journal_head=jnp.zeros((2,), U32),
    )


class BlockResult(NamedTuple):
    state: PeerState
    valid: jnp.ndarray  # (B,) bool
    block_hash: jnp.ndarray  # (2,) u32
    overflow: jnp.ndarray  # () bool


# ---------------------------------------------------------------------------
# Stage functions. Each is its own jit so the baseline's per-stage re-decode
# is a real, separately-executed program (like Fabric modules).
# ---------------------------------------------------------------------------


def _verify_endorsements(txb: types.TxBatch, parallel: bool, tx_par: int
                         ) -> jnp.ndarray:
    if parallel and tx_par <= 0:
        return crypto.verify_tags(txb)
    if parallel:
        # Tiled validation: tx_par transactions at a time (Fig 7's knob).
        b = txb.batch
        pad = (-b) % tx_par
        idx = jnp.arange(b + pad).reshape(-1, tx_par)

        def tile(carry, ix):
            sub = jax.tree.map(lambda a: a[jnp.clip(ix, 0, b - 1)], txb)
            return carry, crypto.verify_tags(sub)

        _, oks = jax.lax.scan(tile, None, idx)
        return oks.reshape(-1)[:b]

    def step(_, i):
        sub = jax.tree.map(lambda a: a[i][None], txb)
        return None, crypto.verify_tags(sub)[0]

    _, ok = jax.lax.scan(step, None, jnp.arange(txb.batch))
    return ok


@functools.partial(jax.jit, static_argnames=("dims",))
def stage_syntax(wire, dims: types.FabricDims):
    """Stage 1: syntactic verification (decodes the block)."""
    dec = unmarshal.unmarshal(wire, dims)
    return dec.checksum_ok


@functools.partial(jax.jit, static_argnames=("dims", "parallel", "tx_par"))
def stage_endorse(wire, dims: types.FabricDims, parallel: bool, tx_par: int):
    """Stage 2: endorsement policy validation (baseline re-decodes)."""
    dec = unmarshal.unmarshal(wire, dims)
    return _verify_endorsements(dec.txb, parallel, tx_par)


@functools.partial(
    jax.jit,
    static_argnames=("dims", "hash_state", "sequential_commit", "journal"),
    donate_argnames=("state",),
)
def stage_mvcc_commit(
    state: PeerState,
    wire,
    checksum_ok,
    endorse_ok,
    dims: types.FabricDims,
    hash_state: bool,
    sequential_commit: bool,
    journal: bool,
):
    """Stages 3+4: MVCC validation + state commit + ledger append."""
    dec = unmarshal.unmarshal(wire, dims)  # baseline: third decode
    txb = dec.txb
    flat_reads = txb.read_keys.reshape(-1, 2)
    if hash_state:
        cur = ws.lookup(state.hash_state, flat_reads).versions
    else:
        cur = ws.sorted_lookup(state.sorted_state, flat_reads).versions
    cur = cur.reshape(txb.batch, -1)
    res = mvcc.validate(
        txb, cur, checksum_ok=checksum_ok, endorse_ok=endorse_ok
    )
    if hash_state:
        cres = ws.commit(
            state.hash_state, txb.write_keys, txb.write_vals, res.valid,
            sequential=sequential_commit,
        )
        hstate, overflow = cres.state, cres.overflow
        sstate = state.sorted_state
    else:
        sstate = ws.sorted_commit(
            state.sorted_state, txb.write_keys, txb.write_vals, res.valid
        )
        hstate, overflow = state.hash_state, jnp.asarray(False)

    digest = ledger.block_body_digest(wire, res.valid)
    bh = ledger.append_hash(state.ledger_head, state.block_no, digest)
    jh = _advance_journal_head(state, txb, res.valid, journal)
    new_state = PeerState(
        hash_state=hstate,
        sorted_state=sstate,
        ledger_head=bh,
        block_no=state.block_no + 1,
        journal_head=jh,
    )
    return new_state, res.valid, bh, overflow


def _advance_journal_head(state: PeerState, txb: types.TxBatch, valid,
                          journal: bool):
    """Fold this block's validated write sets into the journal head (the
    jit-able on-path half of storage/journal; overhead measured by fig9)."""
    if not journal:
        return state.journal_head
    return state_journal.update_head(
        state.journal_head,
        state.block_no,
        state_journal.write_set_digest(txb.write_keys, txb.write_vals, valid),
    )


@functools.partial(
    jax.jit,
    static_argnames=("dims", "cfg"),
    donate_argnames=("state",),
)
def commit_block_fused(
    state: PeerState, wire, dims: types.FabricDims, cfg: PeerConfig
):
    """P-III path: one program, one decode, stages share the decoded block."""
    dec = unmarshal.unmarshal(wire, dims)
    txb = dec.txb
    endorse_ok = _verify_endorsements(txb, cfg.parallel, cfg.tx_par)
    flat_reads = txb.read_keys.reshape(-1, 2)
    if cfg.hash_state:
        cur = ws.lookup(state.hash_state, flat_reads).versions
    else:
        cur = ws.sorted_lookup(state.sorted_state, flat_reads).versions
    cur = cur.reshape(txb.batch, -1)
    res = mvcc.validate(
        txb, cur, checksum_ok=dec.checksum_ok, endorse_ok=endorse_ok
    )
    if cfg.hash_state:
        cres = ws.commit(
            state.hash_state, txb.write_keys, txb.write_vals, res.valid,
            sequential=cfg.sequential_commit,
        )
        hstate, overflow = cres.state, cres.overflow
        sstate = state.sorted_state
    else:
        sstate = ws.sorted_commit(
            state.sorted_state, txb.write_keys, txb.write_vals, res.valid
        )
        hstate, overflow = state.hash_state, jnp.asarray(False)

    digest = ledger.block_body_digest(wire, res.valid)
    bh = ledger.append_hash(state.ledger_head, state.block_no, digest)
    jh = _advance_journal_head(state, txb, res.valid, cfg.journal)
    new_state = PeerState(
        hash_state=hstate,
        sorted_state=sstate,
        ledger_head=bh,
        block_no=state.block_no + 1,
        journal_head=jh,
    )
    return new_state, res.valid, bh, overflow


def commit_block(
    state: PeerState,
    wire: jnp.ndarray,
    dims: types.FabricDims,
    cfg: PeerConfig,
) -> BlockResult:
    """Run one block through the full validation pipeline under ``cfg``.

    P-III (cache=True) uses the fused single-decode program; otherwise each
    stage re-decodes, exactly like Fabric 1.2's module boundaries.
    """
    if cfg.cache:
        new_state, valid, bh, ovf = commit_block_fused(state, wire, dims, cfg)
    else:
        checksum_ok = stage_syntax(wire, dims)
        endorse_ok = stage_endorse(wire, dims, cfg.parallel, cfg.tx_par)
        new_state, valid, bh, ovf = stage_mvcc_commit(
            state, wire, checksum_ok, endorse_ok, dims,
            cfg.hash_state, cfg.sequential_commit, cfg.journal,
        )
    return BlockResult(state=new_state, valid=valid, block_hash=bh,
                       overflow=ovf)
