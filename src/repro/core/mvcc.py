"""MVCC read/write-set validation — the sequential heart of the commit path.

Paper mapping (§II-C2, §III-D): "the validation of the state changes through
transaction write sets must be done sequentially, blocking all other tasks".
A transaction is valid iff
  (a) every key in its read set still has the version the endorser observed
      (checked against the committed world state), and
  (b) no *earlier valid* transaction in the same block wrote any key in its
      read or write set (the in-block dependency the paper keeps serial).

TPU adaptation: (a) is embarrassingly parallel (batched hash-table lookups).
For (b) we precompute the pairwise conflict matrix conflict[j, i] = "tx j's
write set intersects tx i's read+write set" with vectorized u32 compares (VPU
work), after which the unavoidable sequential part collapses to a tiny
boolean scan:  valid_i = vers_ok_i  AND  NOT any_j<i (valid_j AND conflict[j,i]).
That scan is O(B) steps of an O(B) vector op instead of the paper's
per-transaction lock-step — the serial fraction shrinks from "walk every
read/write set" to "propagate one bit per transaction".

kernels/mvcc_validate is the Pallas version; this is the oracle/CPU path.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import hashing, types, world_state

U32 = jnp.uint32


def _keys_eq(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Pairwise paired-key equality. a (..., 2) vs b (..., 2) -> bool."""
    return (
        (a[..., 0] == b[..., 0])
        & (a[..., 1] == b[..., 1])
        & (a[..., 0] != hashing.EMPTY_KEY)
    )


def conflict_matrix(txb: types.TxBatch) -> jnp.ndarray:
    """conflict[j, i] = tx j's writes intersect tx i's (reads | writes).

    Shape (B, B) bool, computed fully in parallel. Only the strict lower
    triangle j < i is consulted by the scan.
    """
    wk = txb.write_keys  # (B, WK, 2)
    touched = jnp.concatenate([txb.read_keys, txb.write_keys], axis=1)  # (B,T,2)
    # (j, i, WK, T): does write w of tx j equal touched t of tx i?
    eq = _keys_eq(wk[:, None, :, None, :], touched[None, :, None, :, :])
    return eq.any(axis=(2, 3))


class MvccResult(NamedTuple):
    valid: jnp.ndarray  # (B,) bool
    vers_ok: jnp.ndarray  # (B,) bool — read-set freshness alone


def validate(
    txb: types.TxBatch,
    current_versions: jnp.ndarray,
    *,
    checksum_ok: jnp.ndarray | None = None,
    endorse_ok: jnp.ndarray | None = None,
    conflict: jnp.ndarray | None = None,
) -> MvccResult:
    """Full MVCC validation of one block.

    ``current_versions``: (B, RK) committed version of each read key (0 if
    absent), from a world-state lookup. ``checksum_ok``/``endorse_ok`` fold
    the earlier pipeline stages' flags into validity (invalid txs stay in the
    block, flagged — Fabric semantics). ``conflict``: optional precomputed
    ``conflict_matrix(txb)`` — the block pipeline's prepare stage computes
    it one step ahead of the commit stage (repro/pipeline/schedule.py).
    """
    active_read = txb.read_keys[..., 0] != hashing.EMPTY_KEY
    vers_ok = jnp.where(
        active_read, current_versions == txb.read_vers, True
    ).all(axis=1)
    ok0 = vers_ok
    if checksum_ok is not None:
        ok0 = ok0 & checksum_ok
    if endorse_ok is not None:
        ok0 = ok0 & endorse_ok

    conf = conflict_matrix(txb) if conflict is None else conflict  # (B, B)
    bsz = txb.batch

    def step(valid_so_far, i):
        # Conflicts of tx i with all earlier txs, masked by their validity.
        mask = jnp.arange(bsz) < i
        blocked = (conf[:, i] & valid_so_far & mask).any()
        v_i = ok0[i] & ~blocked
        return valid_so_far.at[i].set(v_i), None

    valid0 = jnp.zeros((bsz,), bool)
    valid, _ = jax.lax.scan(step, valid0, jnp.arange(bsz))
    return MvccResult(valid=valid, vers_ok=vers_ok)


def validate_sequential_reference(
    txb: types.TxBatch,
    state: world_state.HashState,
    *,
    checksum_ok: jnp.ndarray | None = None,
    endorse_ok: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Oracle: Fabric's literal per-tx walk with an explicit update map.

    Replays §II-C2 one transaction at a time: check read-set freshness
    against the block-start state, check the block's growing update map
    (keys written by earlier *valid* txs) against this tx's read+write keys,
    then add this tx's writes to the map if valid. Used by property tests to
    pin down :func:`validate`'s conflict-matrix formulation. (B,) bool.
    """
    bsz = txb.batch
    wk = txb.write_keys.shape[1]
    ok0 = jnp.ones((bsz,), bool)
    if checksum_ok is not None:
        ok0 = ok0 & checksum_ok
    if endorse_ok is not None:
        ok0 = ok0 & endorse_ok

    look = world_state.lookup(
        state, txb.read_keys.reshape(-1, 2)
    ).versions.reshape(bsz, -1)
    active_read = txb.read_keys[..., 0] != hashing.EMPTY_KEY
    fresh = jnp.where(active_read, look == txb.read_vers, True).all(axis=1)

    def step(carry, i):
        dirty = carry  # (B*WK, 2) keys written by earlier valid txs
        touched = jnp.concatenate(
            [txb.read_keys[i], txb.write_keys[i]], axis=0
        )  # (RK+WK, 2)
        conflict = _keys_eq(dirty[:, None, :], touched[None, :, :]).any()
        v_i = fresh[i] & ok0[i] & ~conflict
        upd = jnp.where(v_i, txb.write_keys[i], jnp.uint32(0))
        dirty = jax.lax.dynamic_update_slice(dirty, upd, (i * wk, 0))
        return dirty, v_i

    dirty0 = jnp.zeros((bsz * wk, 2), U32)
    _, valid = jax.lax.scan(step, dirty0, jnp.arange(bsz))
    return valid
