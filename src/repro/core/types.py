"""Fixed-shape transaction / block types.

FastFabric's unit of work is a *transaction*: a header (TransactionID,
client, channel), a read set (keys + expected versions), a write set
(keys + values), and a list of endorsement signatures. Hyperledger Fabric
carries these as variable-length protobuf messages; the TPU adaptation is a
fixed-arity struct-of-arrays layout (sentinel keys mark unused slots), so a
*block* of transactions is a small pytree of rectangular u32 tensors that
vmap/pjit/Pallas can chew through.

Sizes are collected in :class:`FabricDims`. The wire format (the thing the
network moves and the committer "unmarshals") lives in
:mod:`repro.core.unmarshal`.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing

U32 = jnp.uint32


@dataclasses.dataclass(frozen=True)
class FabricDims:
    """Static shape parameters of the transaction format.

    Attributes:
      rk: read-set slots per transaction.
      wk: write-set slots per transaction.
      vw: u32 value words per write (value width).
      ne: endorsement slots per transaction.
      payload_words: total u32 words per marshaled transaction on the wire,
        including opaque application payload padding. The paper's typical
        transaction carries ~2.9 KB (=> payload_words≈736); tests use small
        values.
    """

    rk: int = 2
    wk: int = 2
    vw: int = 4
    ne: int = 3
    payload_words: int = 64

    @property
    def struct_words(self) -> int:
        """Words of *structured* data per tx (header + rw sets + tags)."""
        return 4 + 3 * self.rk + (2 + self.vw) * self.wk + self.ne

    @property
    def payload_bytes(self) -> int:
        return 4 * self.payload_words

    def __post_init__(self):
        if self.payload_words < self.struct_words:
            raise ValueError(
                f"payload_words={self.payload_words} < struct_words="
                f"{self.struct_words}; the wire must hold the structured part"
            )


# The paper's experiments use 2.9 KB payloads.
PAPER_DIMS = FabricDims(rk=2, wk=2, vw=4, ne=3, payload_words=736)
# Small dims for tests / CPU benchmarks.
TEST_DIMS = FabricDims(rk=2, wk=2, vw=4, ne=3, payload_words=32)


class TxBatch(NamedTuple):
    """A batch of B structured (unmarshaled) transactions. All u32.

    Key slots hold *paired hashes* (see core.hashing); a key of (0, _) is an
    empty slot. ``read_vers`` is the version the endorser observed — MVCC
    validation recomputes it against the committed world state.
    """

    tx_id: jnp.ndarray  # (B, 2)
    client: jnp.ndarray  # (B,)
    channel: jnp.ndarray  # (B,)
    read_keys: jnp.ndarray  # (B, RK, 2)
    read_vers: jnp.ndarray  # (B, RK)
    write_keys: jnp.ndarray  # (B, WK, 2)
    write_vals: jnp.ndarray  # (B, WK, VW)
    endorse_tags: jnp.ndarray  # (B, NE)

    @property
    def batch(self) -> int:
        return self.tx_id.shape[0]


def tx_id_hex(pair) -> str:
    """(2,) u32 paired-hash tx-id -> the canonical 16-char hex string —
    the identity that tx-lifecycle traces, histogram exemplars and flight-
    recorder dumps all print (repro.obs.txtrace uses the same encoding)."""
    return f"{int(pair[0]):08x}{int(pair[1]):08x}"


class Block(NamedTuple):
    """A block as delivered by the ordering service: marshaled bytes only.

    ``wire`` is (B, 4*payload_words) u8 — the serialized transactions. The
    committer must unmarshal it (that is the P-III cache's whole point).
    """

    block_no: jnp.ndarray  # () u32
    prev_hash: jnp.ndarray  # (2,) u32 chain hash of previous block
    wire: jnp.ndarray  # (B, 4*P) u8

    @property
    def num_txs(self) -> int:
        return self.wire.shape[0]


class ValidatedBlock(NamedTuple):
    """A block after the validation pipeline, ready for ledger append."""

    block_no: jnp.ndarray  # () u32
    prev_hash: jnp.ndarray  # (2,) u32
    block_hash: jnp.ndarray  # (2,) u32 chain hash including validity flags
    wire: jnp.ndarray  # (B, 4*P) u8
    valid: jnp.ndarray  # (B,) bool — per-tx validation flag (kept in block!)


def message_words(txb: TxBatch) -> jnp.ndarray:
    """The per-tx words covered by endorsement MACs: header + rw sets.

    Returns (B, 4 + 3*RK + (2+VW)*WK) u32. Endorse tags are excluded
    (they sign this message).
    """
    b = txb.batch
    parts = [
        txb.tx_id.reshape(b, -1),
        txb.client.reshape(b, 1),
        txb.channel.reshape(b, 1),
        txb.read_keys.reshape(b, -1),
        txb.read_vers.reshape(b, -1),
        txb.write_keys.reshape(b, -1),
        txb.write_vals.reshape(b, -1),
    ]
    return jnp.concatenate([p.astype(U32) for p in parts], axis=1)


def tx_body_hash(txb: TxBatch) -> jnp.ndarray:
    """Content hash of a transaction batch, (B, 2) u32 (paired)."""
    msg = message_words(txb)
    h1 = hashing.hash_words(msg, seed=hashing.SEED_A)
    h2 = hashing.hash_words(msg, seed=hashing.SEED_B)
    return jnp.stack([h1, h2], axis=-1)


# ---------------------------------------------------------------------------
# Synthetic workload generation (the paper's "money transfer" chaincode).
# ---------------------------------------------------------------------------


def make_transfer_batch(
    dims: FabricDims,
    batch: int,
    *,
    seed: int = 0,
    n_accounts: int = 1 << 16,
    conflict_rate: float = 0.0,
    versions: jnp.ndarray | None = None,
) -> TxBatch:
    """Build B money-transfer transactions (read 2 accounts, write both).

    This mirrors the paper's benchmark chaincode: every transaction touches
    two keys in the state database, "simulating a money transfer from one
    account to another". With ``conflict_rate=0`` all account pairs are
    disjoint within the batch (the paper's non-conflicting worst case — all
    txs pass every check and commit).

    ``versions``: optional (B, RK) expected versions; defaults to zeros
    (fresh state).
    """
    if dims.rk < 2 or dims.wk < 2:
        raise ValueError("transfer workload needs rk>=2 and wk>=2")
    rng = np.random.default_rng(seed)
    if conflict_rate > 0.0:
        src = rng.integers(0, n_accounts, size=batch, dtype=np.uint32)
        dst = rng.integers(0, n_accounts, size=batch, dtype=np.uint32)
        n_conf = int(batch * conflict_rate)
        if n_conf:
            # Force the first n_conf txs to touch the same hot account.
            src[:n_conf] = 7
    else:
        # Disjoint accounts: tx i touches accounts (2i, 2i+1) + offset.
        base = rng.integers(0, 1 << 20, dtype=np.uint32)
        src = (np.arange(batch, dtype=np.uint32) * 2 + base).astype(np.uint32)
        dst = src + 1
    src = jnp.asarray(src, dtype=U32)
    dst = jnp.asarray(dst, dtype=U32)

    def paired(a):
        h1, h2 = hashing.hash_pair(a)
        return jnp.stack([hashing.nonzero_key(h1), h2], axis=-1)  # (B, 2)

    kp_src = paired(src)
    kp_dst = paired(dst)
    read_keys = jnp.zeros((batch, dims.rk, 2), U32)
    read_keys = read_keys.at[:, 0].set(kp_src).at[:, 1].set(kp_dst)
    write_keys = jnp.zeros((batch, dims.wk, 2), U32)
    write_keys = write_keys.at[:, 0].set(kp_src).at[:, 1].set(kp_dst)

    if versions is None:
        read_vers = jnp.zeros((batch, dims.rk), U32)
        # Unused read slots must also "match" — version 0 == absent key.
    else:
        read_vers = versions.astype(U32)

    amounts = jnp.asarray(
        rng.integers(1, 1000, size=(batch, dims.wk, dims.vw), dtype=np.uint32)
    )
    tx_id = jnp.stack(
        hashing.hash_pair(jnp.arange(batch, dtype=U32) + jnp.uint32(seed * 7919)),
        axis=-1,
    )
    client = jnp.asarray(rng.integers(0, 64, size=batch, dtype=np.uint32))
    channel = jnp.zeros((batch,), U32)
    tags = jnp.zeros((batch, dims.ne), U32)  # filled in by endorse()
    return TxBatch(
        tx_id=tx_id,
        client=client,
        channel=channel,
        read_keys=read_keys,
        read_vers=read_vers,
        write_keys=write_keys,
        write_vals=amounts,
        endorse_tags=tags,
    )


def tx_batch_specs(dims: FabricDims, batch: int) -> TxBatch:
    """ShapeDtypeStruct stand-ins for a TxBatch (dry-run input specs)."""
    s = lambda *shape: jax.ShapeDtypeStruct(shape, jnp.uint32)
    return TxBatch(
        tx_id=s(batch, 2),
        client=s(batch),
        channel=s(batch),
        read_keys=s(batch, dims.rk, 2),
        read_vers=s(batch, dims.rk),
        write_keys=s(batch, dims.wk, 2),
        write_vals=s(batch, dims.wk, dims.vw),
        endorse_tags=s(batch, dims.ne),
    )
