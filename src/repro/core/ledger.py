"""Hash-chained ledger + the decoupled block store (Opt P-II storage role).

Paper mapping: every peer appends validated blocks (with per-tx validity
flags kept *in* the block — Fabric semantics) to the blockchain log.
FastFabric moves that log off the critical path to a storage cluster
(§III-F); the committer only computes the chain hash and ships the block.

``append_hash`` is the on-critical-path part (jit-able, tiny); ``BlockStore``
is the off-path storage role: it receives validated blocks asynchronously
(host callback / separate mesh role in the distributed runtime), keeps the
full chain, and can rebuild world state by replay — which is exactly the
durability argument that lets P-I drop the database (§III-E).
"""

from __future__ import annotations

import os
import queue
import threading
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing, types, unmarshal, world_state

U32 = jnp.uint32

GENESIS = jnp.zeros((2,), U32)


def channel_dir(base: str, channel: int) -> str:
    """Where channel ``channel``'s files live under ``base``.

    Channel 0 IS ``base`` — every pre-multi-channel directory layout
    (spill dirs, journal segment dirs, snapshot dirs) is exactly channel
    0's layout, so single-channel deployments keep their paths and old
    directories restore as channel 0. Other channels nest one level down.
    """
    if channel == 0:
        return base
    return os.path.join(base, f"channel_{channel:04d}")


def load_spilled_blocks(spill_dir: str, start_block: int,
                        channel: int = 0) -> list["StoredBlock"]:
    """Read a channel's spilled blocks from ``start_block`` upward until
    the first gap. The restore path uses this to rebuild the suffix a
    snapshot doesn't cover (FabricEngine.restore with a snapshot trailing
    the journal tip)."""
    d = channel_dir(spill_dir, channel)
    out: list[StoredBlock] = []
    bno = start_block
    while True:
        path = os.path.join(d, f"block_{bno:08d}.npz")
        if not os.path.exists(path):
            return out
        with np.load(path) as z:
            out.append(StoredBlock(
                block_no=bno,
                prev_hash=z["prev_hash"], block_hash=z["block_hash"],
                wire=z["wire"], valid=z["valid"],
            ))
        bno += 1


def block_body_digest(wire: jnp.ndarray, valid: jnp.ndarray) -> jnp.ndarray:
    """Content digest of a block body: per-tx digests + validity flags,
    folded order-dependently. (2,) u32."""
    n, wb = wire.shape
    words = jax.lax.bitcast_convert_type(
        wire.reshape(n, wb // 4, 4), U32
    ).reshape(n, wb // 4)
    d1 = hashing.hash_words(words, seed=hashing.SEED_A)  # (N,)
    d2 = hashing.hash_words(words, seed=hashing.SEED_B)
    v = valid.astype(U32)
    h1 = hashing.hash_words((d1 ^ v)[None, :], seed=hashing.SEED_A)[0]
    h2 = hashing.hash_words((d2 ^ (v << 1))[None, :], seed=hashing.SEED_B)[0]
    return jnp.stack([h1, h2])


def append_hash(prev_hash: jnp.ndarray, block_no: jnp.ndarray,
                body_digest: jnp.ndarray) -> jnp.ndarray:
    """Chain: H(prev || block_no || body). (2,) u32."""
    words = jnp.concatenate(
        [prev_hash, jnp.atleast_1d(block_no).astype(U32), body_digest]
    )[None, :]
    return jnp.stack(
        [
            hashing.hash_words(words, seed=hashing.SEED_A)[0],
            hashing.hash_words(words, seed=hashing.SEED_B)[0],
        ]
    )


class StoredBlock(NamedTuple):
    block_no: int
    prev_hash: np.ndarray
    block_hash: np.ndarray
    wire: np.ndarray
    valid: np.ndarray


class BlockStore:
    """The storage-cluster role: async, append-only, off the critical path.

    A writer thread drains a queue of device blocks, copies them to host
    (the 'remote gRPC call' of §III-F) and appends to an in-memory chain
    [+ optional directory spill]. ``verify_chain`` / ``replay_state`` give
    the durability guarantee that justifies P-I.

    When a ``journal`` (storage/journal.StateJournal) is attached, the same
    writer thread also emits each block's validated write sets into it —
    journal materialization rides the storage role, off the commit path.
    ``prune_upto`` compacts the chain up to the last snapshot: pruned
    history stays authenticated because the chain re-anchors at the hash of
    the last pruned block (``base_hash``), which the covering snapshot's
    recovery path cross-checks.

    ONE store (one writer thread, one queue) multiplexes every channel of a
    multi-channel engine: submitted blocks are channel-tagged, and the
    store keeps per-channel chains, re-anchor bases and journals — the
    paper's storage cluster serves all channels, but each channel's chain
    verifies independently (cross-channel isolation: a corrupted record in
    channel i's chain or journal fails only channel i's checks). The
    channel-0 surface (``.chain``, ``.base_block_no``, ``.base_hash``,
    channel-less method calls) is the pre-multi-channel API unchanged.
    """

    def __init__(self, spill_dir: str | None = None, *, journal=None):
        self._q: "queue.Queue" = queue.Queue()
        self.chains: dict[int, list[StoredBlock]] = {0: []}
        self.base_block_nos: dict[int, int] = {0: -1}
        self.base_hashes: dict[int, np.ndarray] = {
            0: np.zeros(2, np.uint32)
        }
        self._spill_dir = spill_dir
        self._journals: dict[int, object] = {}
        if journal is not None:
            self._journals[0] = journal
        self._err: Exception | None = None
        self._t = threading.Thread(target=self._run, daemon=True)
        self._t.start()

    # -- channel plumbing --------------------------------------------------

    def _chan(self, channel: int) -> list[StoredBlock]:
        if channel not in self.chains:
            self.chains[channel] = []
            self.base_block_nos[channel] = -1
            self.base_hashes[channel] = np.zeros(2, np.uint32)
        return self.chains[channel]

    def set_journal(self, channel: int, journal) -> None:
        """Attach channel ``channel``'s state journal to the writer."""
        self._journals[channel] = journal

    @property
    def chain(self) -> list[StoredBlock]:
        """Channel 0's chain (single-channel compat; the returned list is
        live — callers may index/mutate it, as the tamper tests do)."""
        return self._chan(0)

    @chain.setter
    def chain(self, value: list[StoredBlock]) -> None:
        self.chains[0] = value

    @property
    def base_block_no(self) -> int:
        return self.base_block_nos[0]

    @base_block_no.setter
    def base_block_no(self, value: int) -> None:
        self.base_block_nos[0] = value

    @property
    def base_hash(self) -> np.ndarray:
        return self.base_hashes[0]

    @base_hash.setter
    def base_hash(self, value: np.ndarray) -> None:
        self.base_hashes[0] = value

    @property
    def _journal(self):
        return self._journals.get(0)

    @_journal.setter
    def _journal(self, value) -> None:
        if value is None:
            self._journals.pop(0, None)
        else:
            self._journals[0] = value

    def _spill_path(self, channel: int, bno: int) -> str:
        d = channel_dir(self._spill_dir, channel)
        # Channel subdirs are created on demand; the BASE dir must already
        # exist — a missing base is a misconfiguration the writer fail-stops
        # on (and a contract the storage tests pin).
        if channel != 0:
            os.makedirs(d, exist_ok=True)
        return os.path.join(d, f"block_{bno:08d}.npz")

    # -- the writer --------------------------------------------------------

    def submit(self, block_no, prev_hash, block_hash, wire, valid,
               channel: int = 0) -> None:
        self._chan(channel)  # channel registered caller-side: the writer
        # thread then only appends to an existing list (no dict mutation
        # races between submit and the drain thread).
        self._q.put((block_no, prev_hash, block_hash, wire, valid, channel))

    def _run(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                self._q.task_done()
                return
            if self._err is not None:
                # Fail-stop: once an append failed, drop everything behind
                # it. Appending past the failure would leave a silent gap
                # in whichever sink raised while the others kept growing;
                # dropping keeps chain and journal consistent up to the
                # failure point, and the next drain()/close() surfaces the
                # error (and the gap fails verify_chain if writing resumes).
                self._q.task_done()
                continue
            spill_path = None
            try:
                channel = item[-1]
                bno, prev, bh, wire, valid = jax.device_get(item[:-1])
                sb = StoredBlock(int(bno), prev, bh, wire, valid)
                if self._spill_dir is not None:
                    spill_path = self._spill_path(channel, int(bno))
                    np.savez(
                        spill_path,
                        prev_hash=prev, block_hash=bh, wire=wire, valid=valid,
                    )
                jrnl = self._journals.get(channel)
                if jrnl is not None:
                    jrnl.append_block(int(bno), wire, valid)
                # Chain append last: a block is in the chain only if every
                # sink (spill, journal) accepted it, so the sinks can never
                # silently trail the chain.
                self._chan(channel).append(sb)
            except Exception as e:  # surfaced on drain()/close()
                self._err = e
                # Un-spill this block so no sink leads the chain: a reader
                # of the spill directory must never see a block the chain
                # and journal fail-stopped before.
                if spill_path is not None:
                    try:
                        os.remove(spill_path)
                    except OSError:
                        pass
            finally:
                self._q.task_done()

    def _surface_err(self) -> None:
        """Raise a latched writer error exactly once, then clear it so the
        store is usable again (the dropped tail is detectable: replays of
        the gap fail verify_chain)."""
        if self._err is not None:
            err, self._err = self._err, None
            raise err

    def close(self) -> None:
        self._q.put(None)
        self._t.join()
        self._surface_err()

    def drain(self) -> None:
        """Block until everything submitted so far is stored."""
        self._q.join()
        self._surface_err()

    def resume(self, channel: int = 0) -> int:
        """Supervised restart after a writer failure.

        The writer fail-stops on the first sink error: the failed block and
        everything submitted behind it are dropped (never silently
        appended). ``resume`` reopens the store from the last durably
        stored block: it waits for the writer to finish discarding the
        in-flight suffix, clears the latched error, and returns the next
        block number expected on ``channel``. The supervisor resubmits the
        dropped suffix from there and the chain continues gap-free —
        instead of relying on ``verify_chain`` to flag the hole after the
        fact. Safe to call with no failure latched (it is then just "where
        do I resume from"). The error is NOT surfaced: resuming is the
        handled-error path.
        """
        self._q.join()
        self._err = None
        ch = self._chan(channel)
        last = ch[-1].block_no if ch else self.base_block_nos[channel]
        return last + 1

    # --- Compaction (snapshot-covered prefix) ----------------------------

    def prune_upto(self, block_no: int, channel: int = 0) -> int:
        """Drop ``channel``'s blocks <= ``block_no`` (covered by a
        snapshot) from memory and from the spill directory. Returns the
        number dropped. Call only with the writer drained."""
        ch = self._chan(channel)
        dropped = [sb for sb in ch if sb.block_no <= block_no]
        if dropped:
            self.chains[channel] = [
                sb for sb in ch if sb.block_no > block_no
            ]
            self.base_block_nos[channel] = dropped[-1].block_no
            self.base_hashes[channel] = dropped[-1].block_hash
            if self._spill_dir is not None:
                for sb in dropped:
                    path = self._spill_path(channel, sb.block_no)
                    if os.path.exists(path):
                        os.remove(path)
        return len(dropped)

    # --- Durability guarantees -------------------------------------------

    def verify_chain(self, channel: int = 0) -> bool:
        prev = self.base_hashes.get(channel, np.zeros(2, np.uint32))
        for sb in self.chains.get(channel, ()):
            if not np.array_equal(sb.prev_hash, prev):
                return False
            digest = block_body_digest(
                jnp.asarray(sb.wire), jnp.asarray(sb.valid)
            )
            expect = append_hash(
                jnp.asarray(prev), jnp.uint32(sb.block_no), digest
            )
            if not np.array_equal(np.asarray(expect), sb.block_hash):
                return False
            prev = sb.block_hash
        return True

    def replay_state(
        self, dims: types.FabricDims, n_buckets: int, slots: int,
        start_state: world_state.HashState | None = None,
        resize_at: dict[int, int] | None = None,
        channel: int = 0,
    ) -> world_state.HashState:
        """Rebuild ``channel``'s world state from its chain (crash
        recovery for P-I).

        ``start_state``: when the prefix was pruned, replay resumes from the
        covering snapshot's state instead of genesis. ``resize_at`` maps a
        boundary block number to the GLOBAL bucket count(s) the elastic
        state resized to right after that block — an int, or a list of
        ints applied in order when several resizes landed at the same
        boundary (a lossy shrink between two grows must replay lossy, so
        the steps cannot be collapsed into their composition). Sourced
        from the engine re-anchor log / journal re-anchor records; replay
        crosses the resize epochs and lands on the live layout.
        """
        st = (world_state.create(n_buckets, slots, dims.vw)
              if start_state is None else start_state)
        resize_at = {
            b: list(nb) if isinstance(nb, (list, tuple)) else [nb]
            for b, nb in (resize_at or {}).items()
        }

        def cross(st, boundary):
            for nb in resize_at.pop(boundary, ()):
                st = world_state.resize(st, nb).state
            return st

        for sb in self.chains.get(channel, ()):
            st = cross(st, sb.block_no - 1)
            dec = unmarshal.unmarshal(jnp.asarray(sb.wire), dims)
            st = world_state.commit_vectorized(
                st,
                dec.txb.write_keys,
                dec.txb.write_vals,
                jnp.asarray(sb.valid),
            ).state
            st = cross(st, sb.block_no)
        for boundary in sorted(resize_at):
            st = cross(st, boundary)
        return st
