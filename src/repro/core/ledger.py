"""Hash-chained ledger + the decoupled block store (Opt P-II storage role).

Paper mapping: every peer appends validated blocks (with per-tx validity
flags kept *in* the block — Fabric semantics) to the blockchain log.
FastFabric moves that log off the critical path to a storage cluster
(§III-F); the committer only computes the chain hash and ships the block.

``append_hash`` is the on-critical-path part (jit-able, tiny); ``BlockStore``
is the off-path storage role: it receives validated blocks asynchronously
(host callback / separate mesh role in the distributed runtime), keeps the
full chain, and can rebuild world state by replay — which is exactly the
durability argument that lets P-I drop the database (§III-E).
"""

from __future__ import annotations

import queue
import threading
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing, types, unmarshal, world_state

U32 = jnp.uint32

GENESIS = jnp.zeros((2,), U32)


def block_body_digest(wire: jnp.ndarray, valid: jnp.ndarray) -> jnp.ndarray:
    """Content digest of a block body: per-tx digests + validity flags,
    folded order-dependently. (2,) u32."""
    n, wb = wire.shape
    words = jax.lax.bitcast_convert_type(
        wire.reshape(n, wb // 4, 4), U32
    ).reshape(n, wb // 4)
    d1 = hashing.hash_words(words, seed=hashing.SEED_A)  # (N,)
    d2 = hashing.hash_words(words, seed=hashing.SEED_B)
    v = valid.astype(U32)
    h1 = hashing.hash_words((d1 ^ v)[None, :], seed=hashing.SEED_A)[0]
    h2 = hashing.hash_words((d2 ^ (v << 1))[None, :], seed=hashing.SEED_B)[0]
    return jnp.stack([h1, h2])


def append_hash(prev_hash: jnp.ndarray, block_no: jnp.ndarray,
                body_digest: jnp.ndarray) -> jnp.ndarray:
    """Chain: H(prev || block_no || body). (2,) u32."""
    words = jnp.concatenate(
        [prev_hash, jnp.atleast_1d(block_no).astype(U32), body_digest]
    )[None, :]
    return jnp.stack(
        [
            hashing.hash_words(words, seed=hashing.SEED_A)[0],
            hashing.hash_words(words, seed=hashing.SEED_B)[0],
        ]
    )


class StoredBlock(NamedTuple):
    block_no: int
    prev_hash: np.ndarray
    block_hash: np.ndarray
    wire: np.ndarray
    valid: np.ndarray


class BlockStore:
    """The storage-cluster role: async, append-only, off the critical path.

    A writer thread drains a queue of device blocks, copies them to host
    (the 'remote gRPC call' of §III-F) and appends to an in-memory chain
    [+ optional directory spill]. ``verify_chain`` / ``replay_state`` give
    the durability guarantee that justifies P-I.

    When a ``journal`` (storage/journal.StateJournal) is attached, the same
    writer thread also emits each block's validated write sets into it —
    journal materialization rides the storage role, off the commit path.
    ``prune_upto`` compacts the chain up to the last snapshot: pruned
    history stays authenticated because the chain re-anchors at the hash of
    the last pruned block (``base_hash``), which the covering snapshot's
    recovery path cross-checks.
    """

    def __init__(self, spill_dir: str | None = None, *, journal=None):
        self._q: "queue.Queue" = queue.Queue()
        self.chain: list[StoredBlock] = []
        self.base_block_no = -1
        self.base_hash = np.zeros(2, np.uint32)
        self._spill_dir = spill_dir
        self._journal = journal
        self._err: Exception | None = None
        self._t = threading.Thread(target=self._run, daemon=True)
        self._t.start()

    def submit(self, block_no, prev_hash, block_hash, wire, valid) -> None:
        self._q.put((block_no, prev_hash, block_hash, wire, valid))

    def _run(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                self._q.task_done()
                return
            if self._err is not None:
                # Fail-stop: once an append failed, drop everything behind
                # it. Appending past the failure would leave a silent gap
                # in whichever sink raised while the others kept growing;
                # dropping keeps chain and journal consistent up to the
                # failure point, and the next drain()/close() surfaces the
                # error (and the gap fails verify_chain if writing resumes).
                self._q.task_done()
                continue
            spill_path = None
            try:
                bno, prev, bh, wire, valid = jax.device_get(item)
                sb = StoredBlock(int(bno), prev, bh, wire, valid)
                if self._spill_dir is not None:
                    spill_path = (
                        f"{self._spill_dir}/block_{int(bno):08d}.npz"
                    )
                    np.savez(
                        spill_path,
                        prev_hash=prev, block_hash=bh, wire=wire, valid=valid,
                    )
                if self._journal is not None:
                    self._journal.append_block(int(bno), wire, valid)
                # Chain append last: a block is in the chain only if every
                # sink (spill, journal) accepted it, so the sinks can never
                # silently trail the chain.
                self.chain.append(sb)
            except Exception as e:  # surfaced on drain()/close()
                self._err = e
                # Un-spill this block so no sink leads the chain: a reader
                # of the spill directory must never see a block the chain
                # and journal fail-stopped before.
                if spill_path is not None:
                    import os

                    try:
                        os.remove(spill_path)
                    except OSError:
                        pass
            finally:
                self._q.task_done()

    def _surface_err(self) -> None:
        """Raise a latched writer error exactly once, then clear it so the
        store is usable again (the dropped tail is detectable: replays of
        the gap fail verify_chain)."""
        if self._err is not None:
            err, self._err = self._err, None
            raise err

    def close(self) -> None:
        self._q.put(None)
        self._t.join()
        self._surface_err()

    def drain(self) -> None:
        """Block until everything submitted so far is stored."""
        self._q.join()
        self._surface_err()

    def resume(self) -> int:
        """Supervised restart after a writer failure.

        The writer fail-stops on the first sink error: the failed block and
        everything submitted behind it are dropped (never silently
        appended). ``resume`` reopens the store from the last durably
        stored block: it waits for the writer to finish discarding the
        in-flight suffix, clears the latched error, and returns the next
        block number expected. The supervisor resubmits the dropped suffix
        from there and the chain continues gap-free — instead of relying
        on ``verify_chain`` to flag the hole after the fact. Safe to call
        with no failure latched (it is then just "where do I resume
        from"). The error is NOT surfaced: resuming is the handled-error
        path.
        """
        self._q.join()
        self._err = None
        last = self.chain[-1].block_no if self.chain else self.base_block_no
        return last + 1

    # --- Compaction (snapshot-covered prefix) ----------------------------

    def prune_upto(self, block_no: int) -> int:
        """Drop blocks <= ``block_no`` (covered by a snapshot) from memory
        and from the spill directory. Returns the number dropped. Call only
        with the writer drained."""
        import os

        dropped = [sb for sb in self.chain if sb.block_no <= block_no]
        if dropped:
            self.chain = [sb for sb in self.chain if sb.block_no > block_no]
            self.base_block_no = dropped[-1].block_no
            self.base_hash = dropped[-1].block_hash
            if self._spill_dir is not None:
                for sb in dropped:
                    path = os.path.join(
                        self._spill_dir, f"block_{sb.block_no:08d}.npz"
                    )
                    if os.path.exists(path):
                        os.remove(path)
        return len(dropped)

    # --- Durability guarantees -------------------------------------------

    def verify_chain(self) -> bool:
        prev = self.base_hash
        for sb in self.chain:
            if not np.array_equal(sb.prev_hash, prev):
                return False
            digest = block_body_digest(
                jnp.asarray(sb.wire), jnp.asarray(sb.valid)
            )
            expect = append_hash(
                jnp.asarray(prev), jnp.uint32(sb.block_no), digest
            )
            if not np.array_equal(np.asarray(expect), sb.block_hash):
                return False
            prev = sb.block_hash
        return True

    def replay_state(
        self, dims: types.FabricDims, n_buckets: int, slots: int,
        start_state: world_state.HashState | None = None,
        resize_at: dict[int, int] | None = None,
    ) -> world_state.HashState:
        """Rebuild world state from the chain (crash recovery for P-I).

        ``start_state``: when the prefix was pruned, replay resumes from the
        covering snapshot's state instead of genesis. ``resize_at`` maps a
        boundary block number to the GLOBAL bucket count(s) the elastic
        state resized to right after that block — an int, or a list of
        ints applied in order when several resizes landed at the same
        boundary (a lossy shrink between two grows must replay lossy, so
        the steps cannot be collapsed into their composition). Sourced
        from the engine re-anchor log / journal re-anchor records; replay
        crosses the resize epochs and lands on the live layout.
        """
        st = (world_state.create(n_buckets, slots, dims.vw)
              if start_state is None else start_state)
        resize_at = {
            b: list(nb) if isinstance(nb, (list, tuple)) else [nb]
            for b, nb in (resize_at or {}).items()
        }

        def cross(st, boundary):
            for nb in resize_at.pop(boundary, ()):
                st = world_state.resize(st, nb).state
            return st

        for sb in self.chain:
            st = cross(st, sb.block_no - 1)
            dec = unmarshal.unmarshal(jnp.asarray(sb.wire), dims)
            st = world_state.commit_vectorized(
                st,
                dec.txb.write_keys,
                dec.txb.write_vals,
                jnp.asarray(sb.valid),
            ).state
            st = cross(st, sb.block_no)
        for boundary in sorted(resize_at):
            st = cross(st, boundary)
        return st
