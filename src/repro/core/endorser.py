"""Endorser role: speculative chaincode execution + endorsement tags.

Paper mapping (§II-B, §III-G): endorsers execute a client's transaction in a
sandbox against their *replica* of world state, record the read/write sets
with observed versions, and sign the result. FastFabric splits endorsers onto
dedicated hardware; they no longer validate — they receive validated blocks
from the committer and just apply the deltas to their state replica.

The benchmark chaincode is the paper's money transfer: read two accounts,
write both (amount moves from src to dst; word 0 of the value is the
balance, remaining value words carry an asset tag).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import crypto, hashing, types
from repro.core import world_state as ws

U32 = jnp.uint32


class Proposal(NamedTuple):
    """Client proposal for the transfer chaincode."""

    src: jnp.ndarray  # (B,) u32 account ids
    dst: jnp.ndarray  # (B,) u32
    amount: jnp.ndarray  # (B,) u32
    client: jnp.ndarray  # (B,) u32
    nonce: jnp.ndarray  # (B,) u32 — makes tx ids unique


def _account_key(acct: jnp.ndarray) -> jnp.ndarray:
    h1, h2 = hashing.hash_pair(acct)
    return jnp.stack([hashing.nonzero_key(h1), h2], axis=-1)  # (B, 2)


def execute_and_endorse(
    state: ws.HashState,
    prop: Proposal,
    dims: types.FabricDims,
    *,
    n_endorsers: int | None = None,
) -> types.TxBatch:
    """Sandbox-execute the transfer chaincode and endorse the result.

    Reads src/dst balances from the endorser's replica, computes the
    post-transfer balances, and records read versions as observed. The
    returned TxBatch carries valid endorsement tags from ``ne`` endorsers.
    """
    if dims.rk < 2 or dims.wk < 2:
        raise ValueError("transfer chaincode needs rk>=2, wk>=2")
    b = prop.src.shape[0]
    k_src = _account_key(prop.src)
    k_dst = _account_key(prop.dst)

    look_src = ws.lookup(state, k_src)
    look_dst = ws.lookup(state, k_dst)
    bal_src = look_src.values[:, 0]
    bal_dst = look_dst.values[:, 0]
    # Transfer executes even from empty accounts (balance wraps) — validity
    # here is about *state versions*, not business rules, matching the
    # paper's all-valid workload.
    new_src = bal_src - prop.amount
    new_dst = bal_dst + prop.amount

    read_keys = jnp.zeros((b, dims.rk, 2), U32)
    read_keys = read_keys.at[:, 0].set(k_src).at[:, 1].set(k_dst)
    read_vers = jnp.zeros((b, dims.rk), U32)
    read_vers = read_vers.at[:, 0].set(look_src.versions)
    read_vers = read_vers.at[:, 1].set(look_dst.versions)

    write_keys = read_keys[:, : dims.wk]
    write_vals = jnp.zeros((b, dims.wk, dims.vw), U32)
    write_vals = write_vals.at[:, 0, 0].set(new_src)
    write_vals = write_vals.at[:, 1, 0].set(new_dst)
    # Asset tag: carried through value words 1+ (content the store must keep).
    if dims.vw > 1:
        write_vals = write_vals.at[:, 0, 1].set(prop.src)
        write_vals = write_vals.at[:, 1, 1].set(prop.dst)

    tx_id = jnp.stack(
        hashing.hash_pair(
            hashing.hash_u32(prop.nonce) ^ prop.src ^ (prop.dst * jnp.uint32(3))
        ),
        axis=-1,
    )
    txb = types.TxBatch(
        tx_id=tx_id,
        client=prop.client,
        channel=jnp.zeros((b,), U32),
        read_keys=read_keys,
        read_vers=read_vers,
        write_keys=write_keys,
        write_vals=write_vals,
        endorse_tags=jnp.zeros((b, dims.ne), U32),
    )
    tags = crypto.endorse_batch(txb, n_endorsers or dims.ne)
    return txb._replace(endorse_tags=tags)


def apply_validated(
    state: ws.HashState, txb: types.TxBatch, valid: jnp.ndarray
) -> ws.HashState:
    """Endorser-cluster replica update: apply a validated block's deltas
    without re-validating (§III-G)."""
    return ws.commit_vectorized(
        state, txb.write_keys, txb.write_vals, valid
    ).state


endorse_jit = jax.jit(
    execute_and_endorse, static_argnames=("dims", "n_endorsers")
)
apply_validated_jit = jax.jit(apply_validated)
