"""World state stores: the FastFabric in-memory hash table (Opt P-I) and the
LevelDB-like sorted store used by the Fabric 1.2 baseline.

Paper mapping (§III-E): Fabric keeps world state in LevelDB/CouchDB; FastFabric
replaces it with an in-memory hash table because the blockchain itself provides
durability. The TPU adaptation moves the same idea one level up the memory
hierarchy: the hot state shard lives in device arrays laid out bucket-major so
a bucket row is one VMEM tile (see kernels/hash_table for the Pallas probe /
commit kernels; this module is the pure-JAX implementation and oracle).

Keys are paired u32 hashes (see core.hashing): (0, *) marks an empty slot.
Versions: 0 == absent, first commit writes version 1 (MVCC bumps thereafter).

Two commit implementations with identical semantics:
  * ``commit_sequential`` — lax.scan, one write at a time. This is the
    paper-faithful shape ("the world state database must be looked up and
    updated sequentially for each transaction").
  * ``commit_vectorized`` — beyond-paper: MVCC guarantees valid transactions
    in a block have pairwise-disjoint write sets, so the whole block's writes
    can be committed with one conflict-free scatter. Slot assignment for
    *new* keys routed to the same bucket is resolved with an intra-batch
    ranking (counting sort by bucket), keeping the scatter race-free.
Tests assert the two agree on random workloads (tests/test_world_state.py).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import hashing

U32 = jnp.uint32


class HashState(NamedTuple):
    """Bucketed open-addressing hash table, struct-of-arrays.

    Shapes: ``keys`` (NB, S, 2), ``versions`` (NB, S), ``values`` (NB, S, VW).
    Bucket-major: one bucket row is contiguous, sized to a VMEM tile.
    """

    keys: jnp.ndarray
    versions: jnp.ndarray
    values: jnp.ndarray

    @property
    def n_buckets(self) -> int:
        return self.keys.shape[0]

    @property
    def slots(self) -> int:
        return self.keys.shape[1]

    @property
    def value_width(self) -> int:
        return self.values.shape[2]


def create(n_buckets: int, slots: int, value_width: int,
           *, n_shards: int = 1) -> HashState:
    """Empty table. With ``n_shards > 1``, ``n_buckets`` is the GLOBAL
    bucket count and the returned table is ONE shard's local slice
    (n_buckets/n_shards buckets — the high-bit partition, see shard_of)."""
    if n_buckets & (n_buckets - 1):
        raise ValueError("n_buckets must be a power of two")
    nb = shard_buckets(n_buckets, n_shards)
    return HashState(
        keys=jnp.zeros((nb, slots, 2), U32),
        versions=jnp.zeros((nb, slots), U32),
        values=jnp.zeros((nb, slots, value_width), U32),
    )


def bucket_of(state_or_nb, keys: jnp.ndarray) -> jnp.ndarray:
    """Bucket index of paired keys (..., 2) -> (...,). Power-of-2 mask."""
    nb = state_or_nb if isinstance(state_or_nb, int) else state_or_nb.n_buckets
    return keys[..., 0] & jnp.uint32(nb - 1)


# ---------------------------------------------------------------------------
# Model-axis sharding: buckets are partitioned across shards by the HIGH
# bits of the global bucket index. Shard m owns the contiguous bucket range
# [m * nb_loc, (m+1) * nb_loc), so a global table reshaped to
# (n_shards, nb_loc, ...) — or split over the mesh `model` axis — is exactly
# the high-bit partition, and a shard-local probe with nb_loc buckets
# (bucket_of masks to the LOW bits) lands on the right local bucket.
# ---------------------------------------------------------------------------


def shard_buckets(n_buckets: int, n_shards: int) -> int:
    """Buckets per shard; validates the (power-of-two) partition."""
    if n_shards < 1 or n_shards & (n_shards - 1):
        raise ValueError(f"n_shards={n_shards} must be a power of two")
    if n_buckets % n_shards:
        raise ValueError(
            f"n_buckets={n_buckets} not divisible by n_shards={n_shards}"
        )
    nb_loc = n_buckets // n_shards
    if nb_loc & (nb_loc - 1):
        raise ValueError("buckets per shard must stay a power of two")
    return nb_loc


def shard_of(n_buckets: int, n_shards: int, keys: jnp.ndarray) -> jnp.ndarray:
    """Owner shard of paired keys (..., 2) -> (...,) i32: high bucket bits."""
    nb_loc = shard_buckets(n_buckets, n_shards)
    gb = bucket_of(n_buckets, keys)
    return (gb // jnp.uint32(nb_loc)).astype(jnp.int32)


def split_table(tkeys, tvers, tvals, n_shards: int):
    """(NB, ...) table arrays -> (M, NB/M, ...) shard-major views.

    A contiguous reshape IS the high-bit bucket partition: shard m holds
    buckets [m*nb_loc, (m+1)*nb_loc). Host-side analogue of splitting the
    bucket dim over the mesh ``model`` axis (launch/state_sharding)."""
    nb = tkeys.shape[0]
    nb_loc = shard_buckets(nb, n_shards)
    return (
        tkeys.reshape(n_shards, nb_loc, *tkeys.shape[1:]),
        tvers.reshape(n_shards, nb_loc, *tvers.shape[1:]),
        tvals.reshape(n_shards, nb_loc, *tvals.shape[1:]),
    )


def merge_table(skeys, svers, svals):
    """Inverse of split_table: (M, NB/M, ...) -> (NB, ...)."""
    return (
        skeys.reshape(-1, *skeys.shape[2:]),
        svers.reshape(-1, *svers.shape[2:]),
        svals.reshape(-1, *svals.shape[2:]),
    )


class ResizeResult(NamedTuple):
    state: HashState
    overflow: jnp.ndarray  # () bool — a merged bucket exceeded its slots
    # (only possible when SHRINKING; the extra entries are dropped and the
    # caller must latch its sticky overflow flag)


def resize(state: HashState, new_n_buckets: int) -> ResizeResult:
    """Rehash the table into ``new_n_buckets`` buckets (power of two).

    The elastic-state primitive: growing doubles the bucket space a key
    hashes into (one more low bit of the key selects the bucket), shrinking
    halves it. Entries are regrouped by their new bucket and compacted in
    *flat order* (old global bucket ascending, slot ascending) — which for a
    GROW is exactly the insertion order a fresh run on the bigger table
    would have used: new bucket g' draws only from old bucket
    g' & (nb_old - 1), whose slot order IS first-insert order (updates keep
    their slot; there are no deletes). Growing a table that never
    overflowed is therefore ARRAY-exact: byte-identical keys/versions/
    values to replaying the whole history on the big layout from block 0
    (tests/test_rebalance.py pins this through the live pipeline).

    Shrinking merges bucket pairs (old buckets g and g + nb_new land in g)
    in old-bucket order; a merged bucket may exceed ``slots``, in which
    case the extras are DROPPED and ``overflow`` reports it — shrink is
    content-exact only while the merged table still fits.
    """
    if new_n_buckets < 1 or new_n_buckets & (new_n_buckets - 1):
        raise ValueError("n_buckets must be a power of two")
    nb, s, vw = state.n_buckets, state.slots, state.value_width
    k = nb * s
    fk = state.keys.reshape(k, 2)
    fv = state.versions.reshape(k)
    fval = state.values.reshape(k, vw)
    occ = fk[:, 0] != hashing.EMPTY_KEY
    newb = jnp.where(
        occ, fk[:, 0] & jnp.uint32(new_n_buckets - 1),
        jnp.uint32(new_n_buckets),
    ).astype(jnp.int32)

    # Group by destination bucket, stable in flat order; rank within the
    # group is the destination slot.
    order = jnp.lexsort((jnp.arange(k), newb))
    sb = newb[order]
    rank = jnp.arange(k) - jnp.searchsorted(sb, sb, side="left")
    live = sb < new_n_buckets
    overflow = (live & (rank >= s)).any()
    dest_b = jnp.where(live & (rank < s), sb, jnp.int32(new_n_buckets))

    def scat(arr, width_shape):
        out = jnp.zeros((new_n_buckets, s, *width_shape), U32)
        return out.at[dest_b, rank].set(arr[order], mode="drop")

    return ResizeResult(
        HashState(
            keys=scat(fk, (2,)),
            versions=scat(fv, ()),
            values=scat(fval, (vw,)),
        ),
        overflow,
    )


def shard_occupancy(state: HashState, n_shards: int) -> jnp.ndarray:
    """Occupied entries per high-bit bucket shard, (M,) i32 — the resize
    policy's fill signal (the table arrays may be a host-side global view
    or a concatenation of shard slices; the reshape IS the partition)."""
    shard_buckets(state.n_buckets, n_shards)
    occ = (state.keys[..., 0] != hashing.EMPTY_KEY).sum(axis=1)  # (NB,)
    return occ.reshape(n_shards, -1).sum(axis=1).astype(jnp.int32)


def shard_min_free(state: HashState, n_shards: int) -> jnp.ndarray:
    """Fewest empty slots of any bucket, per shard, (M,) i32. Overflow
    strikes when a single bucket fills, so this (not mean occupancy) is
    the early-warning signal a grow policy should watch."""
    shard_buckets(state.n_buckets, n_shards)
    free = (state.keys[..., 0] == hashing.EMPTY_KEY).sum(axis=1)  # (NB,)
    return free.reshape(n_shards, -1).min(axis=1).astype(jnp.int32)


def hot_shard(overflow_bits: int, occupancy) -> int:
    """The shard a grow should relieve: the first latched overflow bit if
    any, else the fullest shard by occupancy ((M,) counts). THE one
    definition — the engine's host path and the mesh committer must
    record the same hot shard for the same state."""
    if overflow_bits:
        return (overflow_bits & -overflow_bits).bit_length() - 1
    return int(jnp.argmax(jnp.asarray(occupancy)))


def tree_head(state: HashState, n_shards: int) -> jnp.ndarray:
    """(2,) u32 digest-tree head of a (merged/global) table under the
    ``n_shards`` high-bit partition: per-shard state_digest folded by
    shard_digest_tree. THE layout-binding commitment — snapshot manifests,
    journal re-anchor records and their verifiers must all compute it
    through this one helper or re-anchor verification silently breaks."""
    sk, sv, sva = split_table(state.keys, state.versions, state.values,
                              n_shards)
    return shard_digest_tree(jnp.stack([
        state_digest(HashState(sk[m], sv[m], sva[m]))
        for m in range(n_shards)
    ]))


def shards_for_budget(table_bytes: int, budget_bytes: int, n_buckets: int
                      ) -> int:
    """Fewest power-of-two shards that bring a table slice under budget."""
    n = 1
    while table_bytes > n * budget_bytes and n < n_buckets:
        n *= 2
    return n


def shard_digest_tree(digests: jnp.ndarray) -> jnp.ndarray:
    """Deterministic Merkle-style fold of per-shard digests (M, 2) -> (2,).

    The sharded world state's commitment: each shard digests its own bucket
    range (state_digest), and the tree combines them in shard order. Note
    the *XOR-fold* state_digest is itself shard-decomposable (XOR of the
    per-shard digests equals the full-table digest) — tests use that to tie
    sharded and replicated states together; this tree is the canonical head
    because it also binds the shard *layout*.
    """
    d = digests
    while d.shape[0] > 1:
        if d.shape[0] % 2:
            d = jnp.concatenate([d, d[-1:]])
        d = jnp.stack(
            [
                hashing.combine(d[0::2, 0], d[1::2, 0]),
                hashing.combine(d[0::2, 1], d[1::2, 1]),
            ],
            axis=-1,
        )
    return d[0]


class Lookup(NamedTuple):
    found: jnp.ndarray  # (B,) bool
    versions: jnp.ndarray  # (B,) u32; 0 if absent
    values: jnp.ndarray  # (B, VW) u32; 0 if absent
    slots: jnp.ndarray  # (B,) i32 slot within bucket (valid only if found)


def lookup(state: HashState, keys: jnp.ndarray) -> Lookup:
    """Batched probe. ``keys`` (B, 2) paired hashes; key (0,*) never matches."""
    b = bucket_of(state, keys)  # (B,)
    rows_k = state.keys[b]  # (B, S, 2)
    rows_v = state.versions[b]  # (B, S)
    rows_val = state.values[b]  # (B, S, VW)
    nonempty = rows_k[..., 0] != hashing.EMPTY_KEY
    match = (
        (rows_k[..., 0] == keys[:, None, 0])
        & (rows_k[..., 1] == keys[:, None, 1])
        & nonempty
        & (keys[:, None, 0] != hashing.EMPTY_KEY)
    )  # (B, S)
    found = match.any(axis=1)
    slot = jnp.argmax(match, axis=1)
    take = lambda rows: jnp.take_along_axis(
        rows, slot[:, None].astype(jnp.int32), axis=1
    )[:, 0]
    vers = jnp.where(found, take(rows_v), jnp.uint32(0))
    vals = jnp.where(
        found[:, None],
        jnp.take_along_axis(rows_val, slot[:, None, None], axis=1)[:, 0],
        jnp.uint32(0),
    )
    return Lookup(found=found, versions=vers, values=vals, slots=slot)


def same_key_matrix(fk: jnp.ndarray) -> jnp.ndarray:
    """same[i, j] = flat writes i and j carry the same paired key.

    (K, 2) -> (K, K) bool. THE canonical pairwise-key compare: the
    vectorized commit's dedup, the fused window commit's LWW reduction and
    the pipeline's write planner (pipeline/batched_mvcc.plan_block_writes)
    must all agree on it byte-for-byte, so they share this one definition
    (callers add their own EMPTY/active masking).
    """
    return (fk[:, 0][None, :] == fk[:, 0][:, None]) & (
        fk[:, 1][None, :] == fk[:, 1][:, None]
    )


def earlier_mask(k: int) -> jnp.ndarray:
    """Strict lower triangle: earlier[i, j] = j precedes i in flat write
    order — the shared tie-break for first-wins dedup and insert ranking."""
    return jnp.tril(jnp.ones((k, k), bool), k=-1)


def bucket_free_slots(state: HashState, keys: jnp.ndarray) -> jnp.ndarray:
    """Empty-slot count of each key's bucket, (..., 2) -> (...,) u32.

    The overflow planner's slot budget (pipeline/batched_mvcc): replicated
    and routed fills (state_sharding.sharded_window_fill) must compute it
    identically or the two paths diverge on which inserts drop.
    """
    per_bucket = (state.keys[..., 0] == hashing.EMPTY_KEY).sum(
        axis=1
    ).astype(U32)
    return per_bucket[bucket_of(state, keys)]


class CommitResult(NamedTuple):
    state: HashState
    overflow: jnp.ndarray  # () bool — any bucket ran out of slots


def _flatten_writes(write_keys, write_vals, active):
    """(B, WK, 2)/(B, WK, VW)/(B,) -> flat (K, 2)/(K, VW)/(K,) arrays."""
    bsz, wk, _ = write_keys.shape
    k = bsz * wk
    fk = write_keys.reshape(k, 2)
    fv = write_vals.reshape(k, -1)
    act = jnp.repeat(active, wk) & (fk[:, 0] != hashing.EMPTY_KEY)
    return fk, fv, act


def commit_sequential(
    state: HashState, write_keys, write_vals, active
) -> CommitResult:
    """Paper-faithful sequential insert-or-update (one write at a time)."""
    fk, fv, act = _flatten_writes(write_keys, write_vals, active)
    nb_mask = jnp.uint32(state.n_buckets - 1)

    def step(carry, xs):
        keys, vers, vals, ovf = carry
        key, val, a = xs
        b = (key[0] & nb_mask).astype(jnp.int32)
        row_k = keys[b]  # (S, 2)
        row_nonempty = row_k[:, 0] != hashing.EMPTY_KEY
        match = (row_k[:, 0] == key[0]) & (row_k[:, 1] == key[1]) & row_nonempty
        exists = match.any()
        empty = ~row_nonempty
        has_empty = empty.any()
        slot = jnp.where(exists, jnp.argmax(match), jnp.argmax(empty))
        ok = a & (exists | has_empty)
        ovf = ovf | (a & ~exists & ~has_empty)
        new_ver = jnp.where(exists, vers[b, slot] + 1, jnp.uint32(1))
        keys = keys.at[b, slot].set(jnp.where(ok, key, keys[b, slot]))
        vers = vers.at[b, slot].set(jnp.where(ok, new_ver, vers[b, slot]))
        vals = vals.at[b, slot].set(jnp.where(ok, val, vals[b, slot]))
        return (keys, vers, vals, ovf), None

    (keys, vers, vals, ovf), _ = jax.lax.scan(
        step,
        (state.keys, state.versions, state.values, jnp.asarray(False)),
        (fk, fv, act),
    )
    return CommitResult(HashState(keys, vers, vals), ovf)


def commit_vectorized(
    state: HashState, write_keys, write_vals, active
) -> CommitResult:
    """Conflict-free block commit via intra-batch slot ranking.

    Requires active writes to have pairwise-distinct keys (guaranteed by MVCC
    for valid transactions). Duplicate-key active writes: the first wins and
    later duplicates are dropped (never triggered after MVCC; property-tested).
    """
    fk, fv, act = _flatten_writes(write_keys, write_vals, active)
    k = fk.shape[0]
    look = lookup(state, fk)
    b = bucket_of(state, fk).astype(jnp.int32)  # (K,)

    # Drop duplicate active keys (keep first occurrence).
    same_key = same_key_matrix(fk)
    earlier = earlier_mask(k)
    dup = (same_key & earlier & act[None, :]).any(axis=1) & act
    act = act & ~dup

    is_update = look.found & act
    is_new = act & ~look.found
    # Rank of each new write among new writes to the same bucket.
    same_bucket = b[None, :] == b[:, None]
    rank = (same_bucket & earlier & is_new[None, :]).sum(axis=1)  # (K,)

    # The rank-th empty slot of the destination bucket.
    rows_k = state.keys[b]  # (K, S, 2)
    empty = rows_k[..., 0] == hashing.EMPTY_KEY  # (K, S)
    cum = jnp.cumsum(empty.astype(jnp.int32), axis=1)
    want = rank[:, None] + 1
    new_slot = jnp.argmax(cum == want, axis=1)
    fits = (cum[:, -1] >= want[:, 0]) if k else jnp.zeros((0,), bool)
    overflow = (is_new & ~fits).any()

    slot = jnp.where(is_update, look.slots, new_slot)
    do = is_update | (is_new & fits)
    new_ver = jnp.where(is_update, look.versions + 1, jnp.uint32(1))

    # Conflict-free scatter: all (bucket, slot) pairs distinct among `do`.
    # Non-applied writes are routed out of range and dropped — a write-back
    # of the stale original at a guessed slot (argmax of an all-false mask
    # is 0) would clobber a same-bucket insert once the bucket fills.
    b_do = jnp.where(do, b, jnp.int32(state.n_buckets))

    def scat(arr, upd):
        return arr.at[b_do, slot].set(upd, mode="drop")

    keys = scat(state.keys, fk)
    vers = scat(state.versions, new_ver)
    vals = scat(state.values, fv)
    return CommitResult(HashState(keys, vers, vals), overflow)


def commit(state, write_keys, write_vals, active, *, sequential=False):
    fn = commit_sequential if sequential else commit_vectorized
    return fn(state, write_keys, write_vals, active)


def commit_window(state: HashState, log_keys: jnp.ndarray,
                  log_vals: jnp.ndarray, log_bumps: jnp.ndarray,
                  log_new: jnp.ndarray) -> HashState:
    """Apply a whole window's write log with ONE fused scatter.

    The block pipeline (repro/pipeline) commits D blocks per step; instead
    of D per-block commit scatters it accumulates a *window write log* and
    applies it here in one pass. Inputs are flat, block-major (block order
    == apply order; within a block, flat write order):

      ``log_keys``  (L, 2)  paired write keys;
      ``log_vals``  (L, VW) write values;
      ``log_bumps`` (L,) bool — writes that ADVANCED their key's version
        (valid, non-empty, not dedup-dropped, and NOT dropped by bucket
        overflow — the planner, pipeline/batched_mvcc.plan_block_writes,
        mirrors the per-block commit's overflow decisions exactly);
      ``log_new``   (L,) bool — the subset of bumps that consumed a NEW
        slot (the first applied insert of a key absent at window start;
        at most one per key).

    Valid write sets are disjoint *within* a block but not *across* blocks
    (read-your-write), so the scatter is preceded by a last-writer-wins
    reduction keyed by (key, block): each key's final version is its
    window-start version plus its total bump count, its final value is the
    last bumping write's value, and its slot is the fill-time slot (keys
    present at window start) or the rank-th empty slot consumed in
    ``log_new`` order (keys inserted in-window) — exactly the slot the
    per-block commit sequence would have assigned. Result is byte-identical
    to applying the blocks one commit at a time, including overflow.
    """
    lk = log_keys
    nonempty = lk[:, 0] != hashing.EMPTY_KEY
    bumps = log_bumps & nonempty
    new = log_new & nonempty
    look = lookup(state, lk)
    b = bucket_of(state, lk).astype(jnp.int32)  # (L,)

    same_key = same_key_matrix(lk) & nonempty[None, :]
    l = lk.shape[0]
    earlier = earlier_mask(l)
    later = jnp.triu(jnp.ones((l, l), bool), k=1)

    # Per-entry: total bumps of its key over the window, and whether this
    # entry is the key's LAST bumping write (the LWW survivor).
    total = (same_key & bumps[None, :]).sum(axis=1).astype(U32)
    lww = bumps & ~(same_key & later & bumps[None, :]).any(axis=1)

    # Slot of each in-window insert: inserts consume the fill-time empty
    # slots of their bucket in log order (rank among earlier log_new).
    same_bucket = b[None, :] == b[:, None]
    rank = (same_bucket & earlier & new[None, :]).sum(axis=1)
    empty = state.keys[b][..., 0] == hashing.EMPTY_KEY  # (L, S)
    cum = jnp.cumsum(empty.astype(jnp.int32), axis=1)
    slot_new = jnp.argmax(cum == rank[:, None] + 1, axis=1)
    # Propagate the insert slot to every entry of the same key (<=1 new
    # entry per key, so a masked max extracts it).
    ins_slot = jnp.max(
        jnp.where(same_key & new[None, :], slot_new[None, :], 0), axis=1
    )
    slot = jnp.where(look.found, look.slots, ins_slot)
    new_ver = look.versions + total

    # Non-survivor entries route out of range and are dropped (same
    # guessed-slot clobbering hazard as commit_vectorized's scatter).
    b_lww = jnp.where(lww, b, jnp.int32(state.n_buckets))

    def scat(arr, upd):
        return arr.at[b_lww, slot].set(upd, mode="drop")

    keys = scat(state.keys, lk)
    vers = scat(state.versions, new_ver)
    vals = scat(state.values, log_vals)
    return HashState(keys, vers, vals)


def occupancy(state: HashState) -> jnp.ndarray:
    return (state.keys[..., 0] != hashing.EMPTY_KEY).sum()


def state_digest(state: HashState) -> jnp.ndarray:
    """Order-independent digest of the occupied entries, (2,) u32.

    XOR-fold of per-entry content hashes: invariant to bucket/slot layout, so
    sequential and vectorized commits (and resharded checkpoints) agree.
    """
    occ = state.keys[..., 0] != hashing.EMPTY_KEY  # (NB, S)
    entry = jnp.concatenate(
        [
            state.keys.reshape(*occ.shape, 2),
            state.versions[..., None],
            state.values,
        ],
        axis=-1,
    )  # (NB, S, 3+VW)
    h1 = hashing.hash_words(entry, seed=hashing.SEED_A)
    h2 = hashing.hash_words(entry, seed=hashing.SEED_B)
    z = jnp.uint32(0)
    xor_fold = lambda x: jax.lax.reduce(
        x.ravel(), jnp.uint32(0), jax.lax.bitwise_xor, (0,)
    )
    d1 = xor_fold(jnp.where(occ, h1, z))
    d2 = xor_fold(jnp.where(occ, h2, z))
    return jnp.stack([d1, d2])


# ---------------------------------------------------------------------------
# LevelDB-like sorted store — the Fabric 1.2 baseline state database.
# ---------------------------------------------------------------------------


class SortedState(NamedTuple):
    """Log-structured sorted store (LevelDB analogue) for the baseline.

    Entries sorted by key64 = (k1 << 32 | k2), represented as two u32 planes
    plus a validity plane (capacity N with ``count`` live entries; dead slots
    sort to the end with key = MAX). Reads are binary searches; commits merge
    the write batch into the sorted run (memtable compaction analogue) and
    pay a WAL chain-hash over the batch (durability analogue).
    """

    key_hi: jnp.ndarray  # (N,) u32, sorted (lexicographic with key_lo)
    key_lo: jnp.ndarray  # (N,) u32
    versions: jnp.ndarray  # (N,) u32
    values: jnp.ndarray  # (N, VW) u32
    count: jnp.ndarray  # () i32
    wal_head: jnp.ndarray  # (2,) u32 — write-ahead-log chain hash

    @property
    def capacity(self) -> int:
        return self.key_hi.shape[0]


_DEAD = jnp.uint32(0xFFFFFFFF)


def sorted_create(capacity: int, value_width: int) -> SortedState:
    return SortedState(
        key_hi=jnp.full((capacity,), _DEAD, U32),
        key_lo=jnp.full((capacity,), _DEAD, U32),
        versions=jnp.zeros((capacity,), U32),
        values=jnp.zeros((capacity, value_width), U32),
        count=jnp.asarray(0, jnp.int32),
        wal_head=jnp.zeros((2,), U32),
    )


def sorted_lookup(state: SortedState, keys: jnp.ndarray) -> Lookup:
    """Exact lexicographic binary search for the (hi, lo) pair.

    x64 is disabled, so there is no native u64 composite key; the store is
    lexsorted by (hi, lo) and hashing.lex_searchsorted bisects on the pair
    directly. The position is exact, so arbitrarily long runs of equal
    key_hi (u32 birthday collisions) cannot hide a present key — no bounded
    probe window to fall out of.
    """
    pos = hashing.lex_searchsorted(
        state.key_hi, state.key_lo, keys[:, 0], keys[:, 1]
    )
    idx = jnp.clip(pos, 0, state.capacity - 1)
    hit = (
        (state.key_hi[idx] == keys[:, 0])
        & (state.key_lo[idx] == keys[:, 1])
        & (pos < state.capacity)
        & (keys[:, 0] != _DEAD)
        & (keys[:, 0] != hashing.EMPTY_KEY)
    )
    vers = jnp.where(hit, state.versions[idx], jnp.uint32(0))
    vals = jnp.where(hit[:, None], state.values[idx], jnp.uint32(0))
    return Lookup(found=hit, versions=vers, values=vals, slots=idx.astype(jnp.int32))


def sorted_commit(
    state: SortedState, write_keys, write_vals, active
) -> SortedState:
    """Merge the write batch into the sorted run + WAL chain hash."""
    fk, fv, act = _flatten_writes(write_keys, write_vals, active)

    # Dedup within batch (first wins, matching hash-store semantics).
    k = fk.shape[0]
    act = act & ~(
        (same_key_matrix(fk) & earlier_mask(k) & act[None, :]).any(axis=1)
    )

    # WAL: serialize the batch through a chain hash (durability barrier).
    wal_words = jnp.concatenate([fk, fv], axis=1)
    w1 = hashing.hash_words(wal_words.ravel()[None, :], seed=state.wal_head[0])[0]
    w2 = hashing.hash_words(wal_words.ravel()[None, :], seed=state.wal_head[1])[0]
    wal_head = jnp.stack([w1, w2])

    look = sorted_lookup(state, fk)
    is_update = look.found & act
    # In-place updates for existing keys.
    vers = state.versions.at[look.slots].set(
        jnp.where(is_update, look.versions + 1, state.versions[look.slots])
    )
    vals = state.values.at[look.slots].set(
        jnp.where(is_update[:, None], fv, state.values[look.slots])
    )

    # Inserts: append new keys then full re-sort (compaction analogue).
    is_new = act & ~look.found
    kh = jnp.where(is_new, fk[:, 0], _DEAD)
    kl = jnp.where(is_new, fk[:, 1], _DEAD)
    nv = jnp.where(is_new, jnp.uint32(1), jnp.uint32(0))
    nvals = jnp.where(is_new[:, None], fv, jnp.uint32(0))

    all_hi = jnp.concatenate([state.key_hi, kh])
    all_lo = jnp.concatenate([state.key_lo, kl])
    all_vers = jnp.concatenate([vers, nv])
    all_vals = jnp.concatenate([vals, nvals])
    order = jnp.lexsort((all_lo, all_hi))[: state.capacity]
    return SortedState(
        key_hi=all_hi[order],
        key_lo=all_lo[order],
        versions=all_vers[order],
        values=all_vals[order],
        count=state.count + is_new.sum(dtype=jnp.int32),
        wal_head=wal_head,
    )
