"""End-to-end FastFabric engine: client -> endorse -> order -> commit -> store.

This is the single-host engine used by examples and the Table I end-to-end
benchmark. It wires the roles exactly like the paper's §IV-D setup:

  client (synthetic proposals)
    -> endorser cluster (execute transfer chaincode on the state replica)
    -> orderer (O-I/O-II per config; blocks of ``block_size``)
    -> committer peer (P-I/II/III validation pipeline)
    -> block store (async, off the critical path)  +  endorser replica update

The distributed (mesh-role) version used by the dry-run lives in
launch/fabric_step.py; semantics are identical.
"""

from __future__ import annotations

import dataclasses
import time
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs as obs_mod
from repro.core import (
    committer,
    endorser,
    ledger,
    orderer,
    types,
    unmarshal,
)
from repro.core import world_state as ws
from repro.storage import journal as state_journal
from repro.storage import recovery, snapshot

U32 = jnp.uint32


@dataclasses.dataclass(frozen=True)
class ResizePolicy:
    """Between-rounds elastic-state policy: when to halve/double the table.

    Checked after every round (and so, with a window committer, always on
    a window boundary — the window write log assumes one partition per
    window). Overflow strikes when a single BUCKET fills, so the grow
    triggers watch per-shard minimum free slots (the early-warning signal)
    and the sticky overflow bitmask (the repair signal: migrate the hot
    shard's bucket range into a bigger table instead of fail-stopping the
    channel), not just mean occupancy.
    """

    grow_free_slots: int = 1  # double when any shard's fullest bucket has
    # <= this many empty slots left (0 disables the pressure trigger)
    grow_fill: float = 0.0  # ... or when any shard's occupancy fraction
    # exceeds this (0 disables)
    grow_on_overflow: bool = True  # ... or when the sticky bitmask sets
    # (capacity repair; the flag itself stays latched — health is honest)
    shrink_fill: float = 0.0  # halve when TOTAL occupancy drops below this
    # fraction of the halved table (0 disables shrinking)
    max_buckets: int = 1 << 24
    min_buckets: int = 8


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    dims: types.FabricDims = types.TEST_DIMS
    orderer: orderer.OrdererConfig = orderer.OrdererConfig()
    peer: committer.PeerConfig = committer.FASTFABRIC_PEER
    n_buckets: int = 1 << 12
    slots: int = 8
    n_endorsers: int = 3
    store_blocks: bool = True
    # Durability layer (storage/): snapshot every N committed blocks
    # (0 = off), optionally persisted to snapshot_dir; journal_dir spills
    # journal records for cold-start recovery (StateJournal.load);
    # prune_chain compacts the block chain + journal up to each snapshot
    # (the statejournal storage win — history before a snapshot is no
    # longer replayed).
    snapshot_every_blocks: int = 0
    snapshot_dir: str | None = None
    journal_dir: str | None = None
    prune_chain: bool = True
    # Elastic state: between-rounds halve/double of the world-state table,
    # journaled as re-anchor records (None = static table, the old
    # fail-stop-on-overflow behavior). snapshot_shards partitions each
    # snapshot into per-shard files (a mesh-backed committer overrides it
    # with its own shard count).
    resize_policy: ResizePolicy | None = None
    snapshot_shards: int = 1
    # Observability (repro/obs): True builds a per-engine tracer + metrics
    # registry and instruments the round path (per-stage spans, the
    # commit.latency histogram, tx/overflow/journal counters, resize
    # events). False routes every probe to the shared no-op sinks — the
    # hot path gains only null calls, no device syncs. An obs.Obs instance
    # is also accepted (benchmarks sharing one registry across engines).
    obs: bool | object = False

    @property
    def name(self) -> str:
        return f"{self.orderer.name}/{self.peer.name}"


FASTFABRIC = EngineConfig()
FABRIC_V12 = EngineConfig(
    orderer=orderer.OrdererConfig(
        separate_metadata=False, pipelined=False, block_size=100
    ),
    peer=committer.FABRIC_V12_PEER,
)


class RoundStats(NamedTuple):
    n_txs: int
    n_blocks: int
    n_valid: int
    wall_s: float

    @property
    def tps(self) -> float:
        return self.n_txs / self.wall_s if self.wall_s else float("inf")


class FabricEngine:
    """Single-host engine holding all roles (the paper's 15-server testbed
    collapsed onto one device; role separation is preserved logically and
    exercised at scale by the mesh-role dry-run)."""

    def __init__(self, cfg: EngineConfig, *, window_committer=None):
        if cfg.snapshot_every_blocks and not (
            cfg.store_blocks and cfg.peer.journal and cfg.peer.hash_state
        ):
            raise ValueError(
                "snapshot_every_blocks requires store_blocks=True and a "
                "peer config with journal=True and hash_state=True (P-I): "
                "snapshots cover the hash-table state and recovery replays "
                "the journal the storage role materializes"
            )
        self.cfg = cfg
        # Observability handle: per-engine tracer + registry, or the shared
        # no-op pair. The window committer (if any) reports through the
        # same handle, so one collect() covers the whole engine.
        if isinstance(cfg.obs, obs_mod.Obs):
            self.obs = cfg.obs
        else:
            self.obs = (obs_mod.Obs.enabled() if cfg.obs
                        else obs_mod.Obs.disabled())
        if window_committer is not None and self.obs.on:
            window_committer.attach_obs(self.obs)
        # Overflow bits already reported through the labeled shard gauge /
        # latch counter (obs): gauges re-set each round, the counter fires
        # once per newly latched bit.
        self._obs_seen_bits = 0
        # Optional device-side block pipeline: an adapter (see
        # repro/pipeline/engine_bridge.MeshWindowCommitter) that commits a
        # WINDOW of pipeline-depth blocks per mesh-step invocation instead
        # of one block per commit_block call. The engine still orders the
        # round and ships every retired block to the storage role.
        self.window_committer = window_committer
        self.peer_state = committer.create_peer_state(
            cfg.dims, n_buckets=cfg.n_buckets, slots=cfg.slots
        )
        self.endorser_state = ws.create(cfg.n_buckets, cfg.slots, cfg.dims.vw)
        self.log_head = jnp.zeros((2,), U32)
        # Journal materialization rides the storage role's writer thread —
        # attached only when the durability layer is configured (a snapshot
        # cadence or an on-disk journal), so engines that never asked for a
        # restart story keep the seed's storage-role cost and memory profile.
        # The commit-path head (PeerConfig.journal) is independent and cheap.
        self.journal = (
            state_journal.StateJournal(cfg.dims, spill_dir=cfg.journal_dir,
                                       metrics=self.obs.registry)
            if (cfg.store_blocks and cfg.peer.journal
                and (cfg.snapshot_every_blocks > 0
                     or cfg.journal_dir is not None))
            else None
        )
        self.store = (
            ledger.BlockStore(journal=self.journal)
            if cfg.store_blocks else None
        )
        self.snapshots: list[snapshot.Snapshot] = []
        self.total_valid = 0
        self.total_txs = 0
        self._next_block_no = 0
        # Sticky commit-overflow flag (device scalar, ORed lazily so block
        # commits stay async; materialized by verify()). A dropped insert
        # never bumped its key's version, so an overflowed peer must report
        # unhealthy instead of silently miscounting — and the flag is
        # PERSISTED via the snapshot manifest / re-anchor records, so a
        # peer that overflows, snapshots and restarts stays unhealthy.
        self._overflow = jnp.asarray(False)
        # Elastic state: current layout (resize epochs move it away from
        # cfg.n_buckets) and the resize history of this process.
        self.n_buckets = (window_committer.n_buckets
                          if window_committer is not None else cfg.n_buckets)
        self.reanchor_log: list = []
        # Overflow bits an overflow-triggered grow already repaired: the
        # sticky mask never un-latches, so the repair trigger compares
        # against this to fire once per NEWLY overflowed shard (not once
        # per process, and not once per round).
        self._repaired_bits = 0
        self._restored_overflow_bits = 0

    # -- client --------------------------------------------------------------

    def make_proposals(self, n: int, *, seed: int = 0,
                       n_accounts: int = 1 << 16) -> endorser.Proposal:
        """Synthetic transfer proposals with disjoint account pairs (the
        paper's all-valid, non-conflicting worst case)."""
        rng = np.random.default_rng(seed)
        perm = rng.permutation(max(n_accounts, 2 * n))[: 2 * n].astype(
            np.uint32
        )
        return endorser.Proposal(
            src=jnp.asarray(perm[:n]),
            dst=jnp.asarray(perm[n:]),
            amount=jnp.asarray(
                rng.integers(1, 1000, size=n, dtype=np.uint32)
            ),
            client=jnp.asarray(rng.integers(0, 64, size=n, dtype=np.uint32)),
            nonce=jnp.arange(n, dtype=jnp.uint32) + jnp.uint32(seed << 16),
        )

    # -- one full round --------------------------------------------------------

    def run_round(self, proposals: endorser.Proposal) -> RoundStats:
        """One round: endorse (untimed) -> order -> commit -> retire.

        Timing boundary follows the paper's §IV-D measurement: the client
        sends *pre-endorsed* transactions, so endorsement/marshaling is
        client/endorser-cluster work outside the peer-throughput window;
        the endorser-replica updates after validation run on the endorser
        cluster's hardware (P-II role separation) and are applied after
        the timed window here (block handoff itself is async).
        """
        cfg = self.cfg
        n = int(proposals.src.shape[0])
        bs = cfg.orderer.block_size
        if n % bs:
            raise ValueError(f"round of {n} txs not a multiple of {bs}")

        # Endorse (endorser cluster; separate hardware under P-II). The
        # replica must reflect all previously retired blocks first.
        txb = endorser.endorse_jit(
            self.endorser_state, proposals, cfg.dims,
            n_endorsers=cfg.n_endorsers,
        )
        wire = jax.block_until_ready(unmarshal.marshal(txb, cfg.dims))
        tracer, reg = self.obs.tracer, self.obs.registry
        t0 = time.perf_counter()

        # Order.
        with tracer.span("round.order",
                         sync=lambda: blocks.log_head):
            blocks = orderer.order_batch_jit(
                wire, txb.tx_id, txb.client, self.log_head, cfg.orderer
            )
            self.log_head = blocks.log_head

        if self.window_committer is not None:
            # Device-side block pipeline: hand the mesh step a window of
            # blocks per invocation (depth blocks in flight ON device,
            # batched consensus + MVCC gathers) instead of per-block
            # dispatch.
            with tracer.span("round.commit", n_blocks=blocks.wire.shape[0]):
                retired = self._commit_windows(blocks)
                self.window_committer.block_until_ready()
        else:
            # Commit block by block; up to pipeline_depth blocks in flight
            # (JAX async dispatch = the paper's block-shepherd goroutines).
            # Note: commits donate the previous peer state, so anything a
            # block needs after retirement (its number, the pre-commit
            # head) is carried host-side / copied — the in-flight tuple
            # never references donated buffers.
            n_blocks = blocks.wire.shape[0]
            with tracer.span("round.commit", n_blocks=n_blocks,
                             sync=lambda: self.peer_state.ledger_head):
                in_flight = []
                retired = []
                for b in range(n_blocks):
                    bno = int(self._next_block_no)
                    self._next_block_no += 1
                    prev_head = jnp.array(self.peer_state.ledger_head,
                                          copy=True)
                    res = committer.commit_block(
                        self.peer_state, blocks.wire[b], cfg.dims, cfg.peer
                    )
                    self.peer_state = res.state
                    self._overflow = self._overflow | res.overflow
                    in_flight.append((blocks.wire[b], bno, prev_head,
                                      res.block_hash, res.valid))
                    if len(in_flight) >= max(cfg.peer.pipeline_depth, 1):
                        retired.append(self._ship(*in_flight.pop(0)))
                while in_flight:
                    retired.append(self._ship(*in_flight.pop(0)))

                jax.block_until_ready(self.peer_state.ledger_head)
            # Per-block commit latency: blocks stay in flight async (the
            # paper's block shepherds), so individual block walls don't
            # exist — amortize the round's order+commit wall over its
            # blocks (the window path amortizes per window the same way).
            dt = (time.perf_counter() - t0) / n_blocks
            hist = reg.histogram("commit.latency")
            for _ in range(n_blocks):
                hist.record(dt)
        wall = time.perf_counter() - t0

        # Post-window: endorser-cluster replica updates (their hardware).
        n_valid = 0
        with tracer.span("round.endorser_replay",
                         sync=lambda: self.endorser_state.versions):
            for wire_b, valid in retired:
                dec = unmarshal.unmarshal(wire_b, self.cfg.dims)
                self.endorser_state = endorser.apply_validated_jit(
                    self.endorser_state, dec.txb, valid
                )
                n_valid += int(valid.sum())

        self._maybe_resize()
        self._maybe_snapshot()
        self.total_valid += n_valid
        self.total_txs += n
        reg.counter("txs.valid").inc(n_valid)
        reg.counter("txs.invalid").inc(n - n_valid)
        if self.obs.on:
            self._record_overflow_metrics()
        return RoundStats(
            n_txs=n, n_blocks=blocks.wire.shape[0], n_valid=n_valid,
            wall_s=wall,
        )

    def _commit_windows(self, blocks) -> list:
        """Slice the ordered round into pipeline-depth windows and hand
        each to the window committer; ship every block to the store with
        the committer's chain hashes. A round tail shorter than the depth
        becomes one shallower window (compiled once, reused)."""
        wc = self.window_committer
        retired = []
        n_blocks = blocks.wire.shape[0]
        for lo in range(0, n_blocks, wc.depth):
            hi = min(lo + wc.depth, n_blocks)
            res = wc.commit_window(blocks.wire[lo:hi], blocks.tx_ids[lo:hi])
            for k in range(hi - lo):
                bno = int(self._next_block_no)
                self._next_block_no += 1
                retired.append(self._ship(
                    blocks.wire[lo + k], bno, res.prev_hash[k],
                    res.block_hash[k], res.valid[k],
                ))
        return retired

    def _ship(self, wire_b, bno: int, prev_head, block_hash, valid):
        """Block leaves the pipeline: async handoff to the storage role."""
        if self.store is not None:
            with self.obs.tracer.span("block.ship", block_no=bno):
                self.store.submit(bno, prev_head, block_hash, wire_b, valid)
        return wire_b, valid

    # -- observability ---------------------------------------------------------

    def metrics(self) -> dict:
        """One-call snapshot of every engine metric (repro.obs Registry
        collect): counters/gauges as numbers, histograms as
        count/sum/mean/p50/p95/p99 dicts. Empty when obs is off."""
        return self.obs.registry.collect()

    @property
    def tracer(self):
        return self.obs.tracer

    def _record_overflow_metrics(self) -> None:
        """Per-shard overflow bits as a labeled gauge + a latch counter
        that fires once per NEWLY set bit. One tiny host transfer per
        round; only runs with obs on."""
        bits = self.overflow_bits()
        reg = self.obs.registry
        new = bits & ~self._obs_seen_bits
        if new:
            reg.counter("overflow.latches").inc(bin(new).count("1"))
            self._obs_seen_bits |= bits
        for m in range(self.n_shards):
            reg.gauge("state.shard_overflow", shard=m).set((bits >> m) & 1)

    # -- elastic state (resize epochs) -----------------------------------------

    @property
    def n_shards(self) -> int:
        """Bucket shards of snapshot manifests / digest trees: the mesh
        committer's shard count when one is attached, else the configured
        host-side partition."""
        if self.window_committer is not None:
            return self.window_committer.n_shards
        return self.cfg.snapshot_shards

    def _state_view(self) -> ws.HashState:
        return (self.window_committer.hash_state()
                if self.window_committer is not None
                else self.peer_state.hash_state)

    def _tree_head(self, state: ws.HashState | None = None) -> np.ndarray:
        st = self._state_view() if state is None else state
        return np.asarray(ws.tree_head(st, self.n_shards))

    def overflow_bits(self) -> int:
        """Sticky per-shard overflow bitmask (bit m == shard m filled).
        Restored bits (a restart re-latching a persisted mask) OR in, so a
        mesh peer's which-shard information survives a host-side restore."""
        if self.window_committer is not None:
            bits = self.window_committer.overflow_bits
        else:
            bits = int(bool(np.asarray(self._overflow)))
        return bits | self._restored_overflow_bits

    def _maybe_resize(self) -> dict | None:
        """The between-rounds policy hook: grow under bucket pressure or
        after an overflow (capacity repair instead of fail-stop), shrink a
        mostly-empty table. Rounds are window boundaries, so a window
        committer is always drained here."""
        pol = self.cfg.resize_policy
        if pol is None:
            return None
        st = self._state_view()
        m = self.n_shards
        occ = np.asarray(ws.shard_occupancy(st, m))
        cap = st.n_buckets // m * st.slots
        min_free = int(np.asarray(ws.shard_min_free(st, m)).min())
        grow = (
            (pol.grow_free_slots and min_free <= pol.grow_free_slots)
            or (pol.grow_fill and occ.max() / cap >= pol.grow_fill)
            # Capacity repair: one overflow-triggered grow per NEWLY
            # latched shard bit (the bitmask is sticky, so comparing
            # against the repaired mask keeps a later overflow of a
            # different shard repairable without re-firing every round).
            or (pol.grow_on_overflow
                and self.overflow_bits() & ~self._repaired_bits)
        )
        if grow and self.n_buckets * 2 <= pol.max_buckets:
            self.obs.tracer.event(
                "resize.decision", action="grow", min_free=min_free,
                overflow_bits=self.overflow_bits(),
                n_buckets=self.n_buckets,
            )
            self._repaired_bits |= self.overflow_bits()
            return self.resize(self.n_buckets * 2)
        if (pol.shrink_fill and self.n_buckets // 2 >= pol.min_buckets
                and occ.sum() < pol.shrink_fill
                * (self.n_buckets // 2) * st.slots):
            self.obs.tracer.event(
                "resize.decision", action="shrink",
                occupancy=int(occ.sum()), n_buckets=self.n_buckets,
            )
            return self.resize(self.n_buckets // 2)
        return None

    def resize(self, new_n_buckets: int) -> dict:
        """Halve/double the world state NOW (between rounds) and commit a
        re-anchor record for the epoch. The endorser replica follows (its
        capacity must track the peer's or the replicas diverge on which
        inserts drop), and the journal is re-anchored at the drained
        boundary so verify/replay cross the resize."""
        if self.store is not None:
            self.store.drain()  # journal tip must be at the boundary
        old_nb = self.n_buckets
        hot = (self.window_committer.hot_shard()
               if self.window_committer is not None else self._hot_shard())
        if self.window_committer is not None:
            info = self.window_committer.resize(new_n_buckets)
            tree, bits = info.tree_head, info.overflow_bits
        else:
            res = ws.resize(self.peer_state.hash_state, new_n_buckets)
            self.peer_state = self.peer_state._replace(hash_state=res.state)
            self._overflow = self._overflow | res.overflow
            tree, bits = None, None
        eres = ws.resize(self.endorser_state, new_n_buckets)
        self.endorser_state = eres.state
        self.n_buckets = new_n_buckets
        if tree is None:
            tree, bits = self._tree_head(), self.overflow_bits()
        if self.journal is not None:
            self.journal.append_reanchor(
                self._next_block_no - 1,
                old_n_buckets=old_nb, new_n_buckets=new_n_buckets,
                n_shards=self.n_shards, tree_head=tree, overflow_bits=bits,
            )
        info = {
            "block_no": self._next_block_no - 1, "old_n_buckets": old_nb,
            "new_n_buckets": new_n_buckets, "overflow_bits": bits,
            "hot_shard": hot,
        }
        self.reanchor_log.append(info)
        self.obs.registry.counter(
            "resize.grow" if new_n_buckets > old_nb else "resize.shrink"
        ).inc()
        self.obs.tracer.event("resize.epoch", **info)
        return info

    def _hot_shard(self) -> int:
        return ws.hot_shard(
            self.overflow_bits(),
            ws.shard_occupancy(self._state_view(), self.n_shards),
        )

    # -- durability layer (storage/) -------------------------------------------

    def _maybe_snapshot(self) -> None:
        """Snapshot cadence: dump world state every ``snapshot_every_blocks``
        committed blocks; prune chain + journal with a one-snapshot lag (the
        previous snapshot stays fully recoverable even if the newest one is
        lost or torn). Snapshots are per-shard files + manifest, and the
        manifest persists the sticky overflow bitmask + re-anchor head."""
        cfg = self.cfg
        if not cfg.snapshot_every_blocks:
            return
        last = self.snapshots[-1].block_no if self.snapshots else -1
        tip = self._next_block_no - 1  # last committed block
        if tip - last < cfg.snapshot_every_blocks:
            return
        self.store.drain()  # journal must cover every shipped block
        with self.obs.tracer.span("snapshot.take", block_no=tip):
            snap = snapshot.take(
                self._state_view(),
                block_no=tip,
                journal_head=self._peer_journal_head(),
                ledger_head=self._ledger_head(),
                n_shards=self.n_shards,
                overflow_bits=self.overflow_bits(),
                reanchor_head=(self.journal.reanchor_head
                               if self.journal is not None else None),
            )
        self.snapshots.append(snap)
        if cfg.snapshot_dir is not None:
            snapshot.save(cfg.snapshot_dir, snap,
                          registry=self.obs.registry)
            snapshot.gc(cfg.snapshot_dir, keep=2,
                        registry=self.obs.registry)
        if cfg.prune_chain and len(self.snapshots) >= 2:
            base = self.snapshots[-2].block_no
            self.store.prune_upto(base)
            self.journal.prune_upto(base)
            self.snapshots = self.snapshots[-2:]

    def recover(self) -> recovery.RecoveryResult:
        """Cold-start recovery from the latest snapshot + journal suffix
        (crossing any resize re-anchors in it)."""
        if self.journal is None:
            raise recovery.RecoveryError("engine has no journal")
        self.store.drain()
        return recovery.recover(
            self.journal,
            snapshot=self.snapshots[-1] if self.snapshots else None,
            n_buckets=self.cfg.n_buckets,
            slots=self.cfg.slots,
            value_width=self.cfg.dims.vw,
        )

    @classmethod
    def restore(cls, cfg: EngineConfig) -> "FabricEngine":
        """Restart a peer from its persisted snapshot + journal spill.

        Requires ``journal_dir`` and ``snapshot_dir``; the latest complete
        snapshot must cover the journal tip (the engine snapshots after the
        round that produced the tip, so a crash between rounds restores
        exactly). The restored peer re-latches the persisted sticky
        overflow bitmask — overflowing, snapshotting and restarting no
        longer launders the health flag — and resumes on the persisted
        (post-resize) layout.
        """
        if cfg.journal_dir is None or cfg.snapshot_dir is None:
            raise recovery.RecoveryError(
                "restore requires journal_dir and snapshot_dir"
            )
        eng = cls(cfg)
        jrnl = state_journal.StateJournal.load(
            cfg.dims, cfg.journal_dir, metrics=eng.obs.registry
        )
        eng.journal = jrnl
        if eng.store is not None:
            eng.store.close()
            eng.store = ledger.BlockStore(journal=jrnl)
        snap = snapshot.latest(cfg.snapshot_dir)
        if snap is None:
            raise recovery.RecoveryError(
                f"no complete snapshot in {cfg.snapshot_dir}"
            )
        rec = recovery.recover(
            jrnl, snapshot=snap, n_buckets=cfg.n_buckets, slots=cfg.slots,
            value_width=cfg.dims.vw,
        )
        if rec.block_no != snap.block_no:
            raise recovery.RecoveryError(
                f"journal tip {rec.block_no} past the latest snapshot "
                f"{snap.block_no}: the suffix's ledger head is not "
                "recoverable without the block spill"
            )
        eng.snapshots = [snap]
        eng.peer_state = eng.peer_state._replace(
            hash_state=rec.state,
            ledger_head=jnp.asarray(snap.ledger_head),
            journal_head=jnp.asarray(rec.journal_head),
            block_no=jnp.uint32(rec.block_no + 1),
        )
        eng.endorser_state = ws.HashState(
            keys=jnp.array(rec.state.keys, copy=True),
            versions=jnp.array(rec.state.versions, copy=True),
            values=jnp.array(rec.state.values, copy=True),
        )
        eng.n_buckets = rec.n_buckets
        # Re-latch the persisted mask WITH its which-shard bits, and mark
        # those bits as already repaired: the pre-crash policy (or its
        # operator) had its chance — a restart must not trigger one more
        # doubling per boot on bits that can never un-latch. A shard that
        # newly overflows AFTER the restart still fires the repair.
        eng._restored_overflow_bits = rec.overflow_bits
        eng._repaired_bits = rec.overflow_bits
        eng._next_block_no = rec.block_no + 1
        if eng.store is not None:
            eng.store.base_block_no = snap.block_no
            eng.store.base_hash = np.asarray(snap.ledger_head)
        return eng

    # -- durability checks (used by tests/examples) ----------------------------

    def _peer_digest(self) -> np.ndarray:
        """Digest of the committed world state — from the mesh-backed
        window committer when one is attached, else the peer state."""
        if self.window_committer is not None:
            return self.window_committer.state_digest()
        return np.asarray(ws.state_digest(self.peer_state.hash_state))

    def _peer_journal_head(self) -> np.ndarray:
        if self.window_committer is not None:
            return self.window_committer.journal_head
        return np.asarray(self.peer_state.journal_head)

    def _ledger_head(self) -> np.ndarray:
        if self.window_committer is not None:
            return np.asarray(self.window_committer.state.ledger_head[0])
        return np.asarray(self.peer_state.ledger_head)

    def overflowed(self) -> bool:
        """Sticky: any committed block ever dropped a write on a full
        bucket (mesh-backed committer or the single-host peer path)."""
        return bool(self.overflow_bits())

    def verify(self) -> dict:
        """Drain storage, verify the chain, check replica consistency,
        check that no commit ever overflowed a bucket, and prove the
        recovery path reproduces the live peer."""
        out = {"chain_ok": True, "replica_ok": True, "replay_ok": True,
               "recovery_ok": True, "overflow_ok": not self.overflowed()}
        if self.store is not None:
            self.store.drain()
            out["chain_ok"] = self.store.verify_chain()
            start = None
            missing_base = False
            if self.store.base_block_no >= 0:
                # Chain pruned at a snapshot boundary: replay resumes from
                # the snapshot that covers the compacted prefix. The list
                # may no longer hold it (pruned snapshots, reloaded dir) —
                # that is a verification failure, not a crash: without the
                # covering snapshot the compacted prefix cannot be
                # re-authenticated or replayed.
                base = next(
                    (s for s in self.snapshots
                     if s.block_no == self.store.base_block_no),
                    None,
                )
                if base is None:
                    missing_base = True
                else:
                    start = snapshot.to_state(base)
            if missing_base:
                out["chain_ok"] = False
                out["replay_ok"] = False
            else:
                # Replay crosses resize epochs: the recorded halve/doubles
                # apply at their boundaries, so the replayed table lands on
                # the live (post-resize) layout.
                replay_from = (self.store.base_block_no
                               if start is not None else -1)
                resize_at: dict = {}
                for r in self.reanchor_log:
                    if r["block_no"] > replay_from:
                        resize_at.setdefault(r["block_no"], []).append(
                            r["new_n_buckets"])
                replayed = self.store.replay_state(
                    self.cfg.dims, self.cfg.n_buckets, self.cfg.slots,
                    start_state=start, resize_at=resize_at,
                )
                out["replay_ok"] = bool(
                    np.array_equal(
                        np.asarray(ws.state_digest(replayed)),
                        self._peer_digest(),
                    )
                ) if self.cfg.peer.hash_state else True
        if self.journal is not None and self.cfg.peer.hash_state:
            try:
                rec = self.recover()
                out["recovery_ok"] = bool(
                    np.array_equal(rec.state_digest, self._peer_digest())
                    and np.array_equal(
                        rec.journal_head, self._peer_journal_head()
                    )
                )
            except recovery.RecoveryError:
                out["recovery_ok"] = False
        if self.cfg.peer.hash_state:
            out["replica_ok"] = bool(
                np.array_equal(
                    np.asarray(ws.state_digest(self.endorser_state)),
                    self._peer_digest(),
                )
            )
        return out
