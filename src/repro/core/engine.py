"""End-to-end FastFabric engine: client -> endorse -> order -> commit -> store.

This is the single-host engine used by examples and the Table I end-to-end
benchmark. It wires the roles exactly like the paper's §IV-D setup:

  client (synthetic proposals)
    -> endorser cluster (execute transfer chaincode on the state replica)
    -> orderer (O-I/O-II per config; blocks of ``block_size``)
    -> committer peer (P-I/II/III validation pipeline)
    -> block store (async, off the critical path)  +  endorser replica update

The distributed (mesh-role) version used by the dry-run lives in
launch/fabric_step.py; semantics are identical.
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs as obs_mod
from repro.core import (
    committer,
    endorser,
    ledger,
    orderer,
    types,
    unmarshal,
)
from repro.core import world_state as ws
from repro.storage import journal as state_journal
from repro.storage import recovery, snapshot

U32 = jnp.uint32


@dataclasses.dataclass(frozen=True)
class ResizePolicy:
    """Between-rounds elastic-state policy: when to halve/double the table.

    Checked after every round (and so, with a window committer, always on
    a window boundary — the window write log assumes one partition per
    window). Overflow strikes when a single BUCKET fills, so the grow
    triggers watch per-shard minimum free slots (the early-warning signal)
    and the sticky overflow bitmask (the repair signal: migrate the hot
    shard's bucket range into a bigger table instead of fail-stopping the
    channel), not just mean occupancy.
    """

    grow_free_slots: int = 1  # double when any shard's fullest bucket has
    # <= this many empty slots left (0 disables the pressure trigger)
    grow_fill: float = 0.0  # ... or when any shard's occupancy fraction
    # exceeds this (0 disables)
    grow_on_overflow: bool = True  # ... or when the sticky bitmask sets
    # (capacity repair; the flag itself stays latched — health is honest)
    shrink_fill: float = 0.0  # halve when TOTAL occupancy drops below this
    # fraction of the halved table (0 disables shrinking)
    max_buckets: int = 1 << 24
    min_buckets: int = 8


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    dims: types.FabricDims = types.TEST_DIMS
    orderer: orderer.OrdererConfig = orderer.OrdererConfig()
    peer: committer.PeerConfig = committer.FASTFABRIC_PEER
    n_buckets: int = 1 << 12
    slots: int = 8
    n_endorsers: int = 3
    store_blocks: bool = True
    # Multi-channel scale-out: N independent Fabric channels (the paper's
    # numbers are per channel; production deployments multiply throughput
    # by running many). Every channel gets its own world state, heads,
    # journal, snapshots and resize epochs; ONE BlockStore writer
    # multiplexes their chains, and a mesh window committer vmaps their
    # validation over the `data` axis. Channel 0 is the implicit channel
    # of the whole single-channel API.
    n_channels: int = 1
    # Block spill directory for the storage role (per-channel subdirs via
    # ledger.channel_dir): enables restore() from a snapshot that TRAILS
    # the journal tip by rebuilding the suffix's ledger head from the
    # spilled blocks.
    block_dir: str | None = None
    # Durability layer (storage/): snapshot every N committed blocks
    # (0 = off), optionally persisted to snapshot_dir; journal_dir spills
    # journal records for cold-start recovery (StateJournal.load);
    # prune_chain compacts the block chain + journal up to each snapshot
    # (the statejournal storage win — history before a snapshot is no
    # longer replayed).
    snapshot_every_blocks: int = 0
    snapshot_dir: str | None = None
    journal_dir: str | None = None
    prune_chain: bool = True
    # Elastic state: between-rounds halve/double of the world-state table,
    # journaled as re-anchor records (None = static table, the old
    # fail-stop-on-overflow behavior). snapshot_shards partitions each
    # snapshot into per-shard files (a mesh-backed committer overrides it
    # with its own shard count).
    resize_policy: ResizePolicy | None = None
    snapshot_shards: int = 1
    # Observability (repro/obs): True builds a per-engine tracer + metrics
    # registry and instruments the round path (per-stage spans, the
    # commit.latency histogram, tx/overflow/journal counters, resize
    # events, per-tx lifecycle tracing). False routes every probe to the
    # shared no-op sinks — the hot path gains only null calls, no device
    # syncs. An obs.Obs instance is also accepted (benchmarks sharing one
    # registry across engines).
    obs: bool | object = False
    # Tracer memory bound when the engine builds its own tracer
    # (obs=True): drop-oldest past this many records, evictions counted
    # in trace.dropped_events. None = unbounded (short runs export their
    # complete trace; soak runs should bound it).
    trace_max_events: int | None = None
    # Flight recorder (repro/obs/recorder): always on, fixed memory. A
    # fault edge (verify() contract failure, new sticky overflow latch,
    # resize refusal, exception escaping run_rounds) auto-dumps the
    # recorder's window — trace JSONL + Chrome trace + metrics snapshot +
    # last-N tx lifecycles — into recorder_dir (None: trip is logged,
    # dump stays manual via engine.recorder.dump(dir)).
    recorder_dir: str | None = None
    # Health/SLO rollup objectives (repro/obs/health.SLOConfig); None
    # uses the loose defaults. FabricEngine.health() evaluates them.
    slo: object | None = None

    @property
    def name(self) -> str:
        return f"{self.orderer.name}/{self.peer.name}"


FASTFABRIC = EngineConfig()
FABRIC_V12 = EngineConfig(
    orderer=orderer.OrdererConfig(
        separate_metadata=False, pipelined=False, block_size=100
    ),
    peer=committer.FABRIC_V12_PEER,
)


class RoundStats(NamedTuple):
    n_txs: int
    n_blocks: int
    n_valid: int
    wall_s: float

    @property
    def tps(self) -> float:
        return self.n_txs / self.wall_s if self.wall_s else float("inf")


class _Channel:
    """One channel's mutable engine-side state (world state replicas,
    heads, durability layer, resize history). ``FabricEngine`` holds one
    per configured channel; channel 0 doubles as the target of the whole
    single-channel API (property shims on the engine)."""

    __slots__ = (
        "peer_state", "endorser_state", "log_head", "journal", "snapshots",
        "next_block_no", "overflow", "n_buckets", "reanchor_log",
        "repaired_bits", "restored_overflow_bits", "obs_seen_bits",
        "total_valid", "total_txs",
    )

    def __init__(self, cfg: EngineConfig, journal):
        self.peer_state = committer.create_peer_state(
            cfg.dims, n_buckets=cfg.n_buckets, slots=cfg.slots
        )
        self.endorser_state = ws.create(
            cfg.n_buckets, cfg.slots, cfg.dims.vw
        )
        self.log_head = jnp.zeros((2,), U32)
        self.journal = journal
        self.snapshots: list[snapshot.Snapshot] = []
        self.next_block_no = 0
        self.overflow = jnp.asarray(False)
        self.n_buckets = cfg.n_buckets
        self.reanchor_log: list = []
        self.repaired_bits = 0
        self.restored_overflow_bits = 0
        self.obs_seen_bits = 0
        self.total_valid = 0
        self.total_txs = 0


class FabricEngine:
    """Single-host engine holding all roles (the paper's 15-server testbed
    collapsed onto one device; role separation is preserved logically and
    exercised at scale by the mesh-role dry-run)."""

    def __init__(self, cfg: EngineConfig, *, window_committer=None):
        if cfg.snapshot_every_blocks and not (
            cfg.store_blocks and cfg.peer.journal and cfg.peer.hash_state
        ):
            raise ValueError(
                "snapshot_every_blocks requires store_blocks=True and a "
                "peer config with journal=True and hash_state=True (P-I): "
                "snapshots cover the hash-table state and recovery replays "
                "the journal the storage role materializes"
            )
        self.cfg = cfg
        # Observability handle: per-engine tracer + registry, or the shared
        # no-op pair. The window committer (if any) reports through the
        # same handle, so one collect() covers the whole engine.
        if isinstance(cfg.obs, obs_mod.Obs):
            self.obs = cfg.obs
        else:
            self.obs = (obs_mod.Obs.enabled(max_events=cfg.trace_max_events)
                        if cfg.obs else obs_mod.Obs.disabled())
        if window_committer is not None and self.obs.on:
            window_committer.attach_obs(self.obs)
        # Always-on flight recorder: bounded rings of recent records,
        # tx lifecycles and periodic metric snapshots; fault edges trip
        # it (and auto-dump when cfg.recorder_dir is set). Taps the live
        # tracer as a sink; with obs off it still logs trips/notes.
        self.recorder = obs_mod.FlightRecorder(
            dump_dir=cfg.recorder_dir, registry=self.obs.registry
        )
        self.recorder.attach(self.obs.tracer)
        # Per-transaction lifecycle tracing rides the obs switch: the
        # sidecar stamps only existing sync edges, but materializing the
        # tx-id sidecar is a (small) host transfer obs-off should skip.
        self.txtrace = (
            obs_mod.TxTracer(self.obs.registry, recorder=self.recorder)
            if self.obs.on else obs_mod.NULL_TXTRACER
        )
        # Health/SLO rollup: host-side per-round buckets, works obs-off.
        self.health_rollup = obs_mod.HealthRollup(
            cfg.slo, n_channels=cfg.n_channels
        )
        # Optional device-side block pipeline: an adapter (see
        # repro/pipeline/engine_bridge.MeshWindowCommitter) that commits a
        # WINDOW of pipeline-depth blocks per mesh-step invocation instead
        # of one block per commit_block call — for multi-channel engines
        # it commits ALL channels' windows per invocation (vmapped over
        # the mesh `data` axis). The engine still orders each round and
        # ships every retired block to the storage role.
        self.window_committer = window_committer
        if (window_committer is not None
                and getattr(window_committer, "n_channels", 1)
                != cfg.n_channels):
            raise ValueError(
                f"window committer drives "
                f"{window_committer.n_channels} channels, engine is "
                f"configured for {cfg.n_channels}"
            )
        if cfg.n_channels < 1:
            raise ValueError(f"n_channels must be >= 1, got {cfg.n_channels}")
        # Journal materialization rides the storage role's writer thread —
        # attached only when the durability layer is configured (a snapshot
        # cadence or an on-disk journal), so engines that never asked for a
        # restart story keep the seed's storage-role cost and memory profile.
        # The commit-path head (PeerConfig.journal) is independent and cheap.
        # Each channel journals independently (spill namespaced per channel
        # via ledger.channel_dir).
        want_journal = (cfg.store_blocks and cfg.peer.journal
                        and (cfg.snapshot_every_blocks > 0
                             or cfg.journal_dir is not None))

        def make_journal(c: int):
            if not want_journal:
                return None
            spill = (ledger.channel_dir(cfg.journal_dir, c)
                     if cfg.journal_dir is not None else None)
            return state_journal.StateJournal(
                cfg.dims, spill_dir=spill, metrics=self.obs.registry
            )

        self.chans = [
            _Channel(cfg, make_journal(c)) for c in range(cfg.n_channels)
        ]
        if window_committer is not None:
            for ch in self.chans:
                ch.n_buckets = window_committer.n_buckets
        # ONE store multiplexes every channel (channel-tagged submits,
        # per-channel chains + journals — the paper's storage cluster).
        if cfg.store_blocks:
            if cfg.block_dir is not None:
                os.makedirs(cfg.block_dir, exist_ok=True)
            self.store = ledger.BlockStore(
                cfg.block_dir, journal=self.chans[0].journal
            )
            for c in range(1, cfg.n_channels):
                if self.chans[c].journal is not None:
                    self.store.set_journal(c, self.chans[c].journal)
        else:
            self.store = None
        self.total_valid = 0
        self.total_txs = 0

    # -- channel-0 shims: the single-channel API is channel 0's view ---------
    # (tests/examples predating multi-channel read AND write these).

    peer_state = property(
        lambda self: self.chans[0].peer_state,
        lambda self, v: setattr(self.chans[0], "peer_state", v),
        doc="Channel 0's committer-peer state.",
    )
    endorser_state = property(
        lambda self: self.chans[0].endorser_state,
        lambda self, v: setattr(self.chans[0], "endorser_state", v),
    )
    log_head = property(
        lambda self: self.chans[0].log_head,
        lambda self, v: setattr(self.chans[0], "log_head", v),
    )
    journal = property(
        lambda self: self.chans[0].journal,
        lambda self, v: setattr(self.chans[0], "journal", v),
    )
    snapshots = property(
        lambda self: self.chans[0].snapshots,
        lambda self, v: setattr(self.chans[0], "snapshots", v),
    )
    reanchor_log = property(
        lambda self: self.chans[0].reanchor_log,
        lambda self, v: setattr(self.chans[0], "reanchor_log", v),
    )
    n_buckets = property(
        lambda self: self.chans[0].n_buckets,
        lambda self, v: setattr(self.chans[0], "n_buckets", v),
        doc="Channel 0's CURRENT table layout (resize epochs move it).",
    )
    _next_block_no = property(
        lambda self: self.chans[0].next_block_no,
        lambda self, v: setattr(self.chans[0], "next_block_no", v),
    )
    # Sticky commit-overflow flag (device scalar, ORed lazily so block
    # commits stay async; materialized by verify()). A dropped insert
    # never bumped its key's version, so an overflowed peer must report
    # unhealthy instead of silently miscounting — and the flag is
    # PERSISTED via the snapshot manifest / re-anchor records, so a
    # peer that overflows, snapshots and restarts stays unhealthy.
    _overflow = property(
        lambda self: self.chans[0].overflow,
        lambda self, v: setattr(self.chans[0], "overflow", v),
    )
    # Overflow bits an overflow-triggered grow already repaired: the
    # sticky mask never un-latches, so the repair trigger compares
    # against this to fire once per NEWLY overflowed shard (not once
    # per process, and not once per round).
    _repaired_bits = property(
        lambda self: self.chans[0].repaired_bits,
        lambda self, v: setattr(self.chans[0], "repaired_bits", v),
    )
    _restored_overflow_bits = property(
        lambda self: self.chans[0].restored_overflow_bits,
        lambda self, v: setattr(self.chans[0], "restored_overflow_bits", v),
    )
    _obs_seen_bits = property(
        lambda self: self.chans[0].obs_seen_bits,
        lambda self, v: setattr(self.chans[0], "obs_seen_bits", v),
    )

    @property
    def n_channels(self) -> int:
        return self.cfg.n_channels

    # -- client --------------------------------------------------------------

    def make_proposals(self, n: int, *, seed: int = 0,
                       n_accounts: int = 1 << 16) -> endorser.Proposal:
        """Synthetic transfer proposals with disjoint account pairs (the
        paper's all-valid, non-conflicting worst case)."""
        rng = np.random.default_rng(seed)
        perm = rng.permutation(max(n_accounts, 2 * n))[: 2 * n].astype(
            np.uint32
        )
        return endorser.Proposal(
            src=jnp.asarray(perm[:n]),
            dst=jnp.asarray(perm[n:]),
            amount=jnp.asarray(
                rng.integers(1, 1000, size=n, dtype=np.uint32)
            ),
            client=jnp.asarray(rng.integers(0, 64, size=n, dtype=np.uint32)),
            nonce=jnp.arange(n, dtype=jnp.uint32) + jnp.uint32(seed << 16),
        )

    # -- one full round --------------------------------------------------------

    def run_round(self, proposals: endorser.Proposal,
                  channel: int = 0) -> RoundStats:
        """One round on ``channel``: endorse (untimed) -> order -> commit
        -> retire.

        Timing boundary follows the paper's §IV-D measurement: the client
        sends *pre-endorsed* transactions, so endorsement/marshaling is
        client/endorser-cluster work outside the peer-throughput window;
        the endorser-replica updates after validation run on the endorser
        cluster's hardware (P-II role separation) and are applied after
        the timed window here (block handoff itself is async).

        A multi-channel engine backed by a mesh window committer commits
        all channels per dispatch — drive it with :meth:`run_rounds`;
        per-channel rounds there would serialize the mesh per channel.
        Host-path engines (no committer) run any channel's round alone.
        """
        if self.window_committer is not None and self.cfg.n_channels > 1:
            raise ValueError(
                "multi-channel window committer commits all channels per "
                "dispatch: drive rounds with run_rounds(proposals_by_"
                "channel)"
            )
        try:
            return self._round(proposals, channel)
        except Exception as e:
            # Fault edge: an escaping exception mid-round is exactly the
            # moment the flight recorder's last window matters.
            self._fault("exception", where="run_round", channel=channel,
                        error=repr(e))
            raise

    def run_rounds(self, proposals_by_channel: list) -> list[RoundStats]:
        """One lockstep round on EVERY channel (entry c drives channel c).

        With a mesh window committer the channels' windows commit in ONE
        device dispatch per window (vmapped over the mesh `data` axis) —
        the multi-channel scale-out path; rounds must therefore be
        shape-uniform across channels (same tx count and block size — pad
        light channels with filler streams, as the fairness benchmark
        does). Without a committer this is just the per-channel host path
        run back to back under one wall clock. Returns per-channel
        :class:`RoundStats` whose ``wall_s`` is the SHARED round wall (the
        channels ran concurrently), so per-channel TPS = that channel's
        txs over the common wall."""
        if len(proposals_by_channel) != self.cfg.n_channels:
            raise ValueError(
                f"expected {self.cfg.n_channels} proposal batches, got "
                f"{len(proposals_by_channel)}"
            )
        try:
            if self.window_committer is None:
                t0 = time.perf_counter()
                stats = [self._round(p, c)
                         for c, p in enumerate(proposals_by_channel)]
                wall = time.perf_counter() - t0
                return [s._replace(wall_s=wall) for s in stats]
            return self._rounds_meshed(proposals_by_channel)
        except Exception as e:
            self._fault("exception", where="run_rounds", error=repr(e))
            raise

    def _round(self, proposals: endorser.Proposal, channel: int
               ) -> RoundStats:
        cfg = self.cfg
        ch = self.chans[channel]
        n = int(proposals.src.shape[0])
        bs = cfg.orderer.block_size
        if n % bs:
            raise ValueError(f"round of {n} txs not a multiple of {bs}")

        # Endorse (endorser cluster; separate hardware under P-II). The
        # replica must reflect all previously retired blocks first.
        txb = endorser.endorse_jit(
            ch.endorser_state, proposals, cfg.dims,
            n_endorsers=cfg.n_endorsers,
        )
        wire = jax.block_until_ready(unmarshal.marshal(txb, cfg.dims))
        tracer, reg = self.obs.tracer, self.obs.registry
        # Tx-lifecycle sidecar: tx-ids assigned at submission (the wire
        # is ready — the endorser's content hashes ARE the ids). The
        # sidecar transfer is the obs-on cost; obs-off passes None.
        txr = self.txtrace.begin_round(
            channel, np.asarray(txb.tx_id) if self.obs.on else None,
            bs, ch.next_block_no,
        )
        t0 = time.perf_counter()

        # Order.
        txr.order_start()
        with tracer.span("round.order", channel=channel,
                         sync=lambda: blocks.log_head):
            blocks = orderer.order_batch_jit(
                wire, txb.tx_id, txb.client, ch.log_head, cfg.orderer
            )
            ch.log_head = blocks.log_head
        txr.ordered()

        if self.window_committer is not None:
            # Device-side block pipeline: hand the mesh step a window of
            # blocks per invocation (depth blocks in flight ON device,
            # batched consensus + MVCC gathers) instead of per-block
            # dispatch.
            with tracer.span("round.commit", n_blocks=blocks.wire.shape[0],
                             channel=channel):
                retired = self._commit_windows(blocks, channel, txr)
                self.window_committer.block_until_ready()
        else:
            # Commit block by block; up to pipeline_depth blocks in flight
            # (JAX async dispatch = the paper's block-shepherd goroutines).
            # Note: commits donate the previous peer state, so anything a
            # block needs after retirement (its number, the pre-commit
            # head) is carried host-side / copied — the in-flight tuple
            # never references donated buffers.
            n_blocks = blocks.wire.shape[0]
            with tracer.span("round.commit", n_blocks=n_blocks,
                             channel=channel,
                             sync=lambda: ch.peer_state.ledger_head):
                in_flight = []
                retired = []
                for b in range(n_blocks):
                    bno = ch.next_block_no
                    ch.next_block_no += 1
                    prev_head = jnp.array(ch.peer_state.ledger_head,
                                          copy=True)
                    res = committer.commit_block(
                        ch.peer_state, blocks.wire[b], cfg.dims, cfg.peer
                    )
                    ch.peer_state = res.state
                    ch.overflow = ch.overflow | res.overflow
                    in_flight.append((blocks.wire[b], bno, prev_head,
                                      res.block_hash, res.valid))
                    if len(in_flight) >= max(cfg.peer.pipeline_depth, 1):
                        retired.append(
                            self._ship(*in_flight.pop(0), channel=channel))
                while in_flight:
                    retired.append(
                        self._ship(*in_flight.pop(0), channel=channel))

                jax.block_until_ready(ch.peer_state.ledger_head)
                txr.validated(0, n_blocks)
            # Per-block commit latency: blocks stay in flight async (the
            # paper's block shepherds), so individual block walls don't
            # exist — amortize the round's order+commit wall over its
            # blocks (the window path amortizes per window the same way).
            dt = (time.perf_counter() - t0) / n_blocks
            hist = reg.histogram("commit.latency")
            for _ in range(n_blocks):
                hist.record(dt)
        wall = time.perf_counter() - t0

        # Post-window: endorser-cluster replica updates (their hardware).
        n_valid, valids = self._endorser_replay(
            retired, channel, collect_valid=self.obs.on
        )
        txr.committed()
        self._policy_pass((channel,))
        self._maybe_snapshot(channel)
        new_bits = self._count_round(channel, n, n_valid, wall,
                                     blocks.wire.shape[0])
        txr.finish(valids, overflow_latched=bool(new_bits))
        return RoundStats(
            n_txs=n, n_blocks=blocks.wire.shape[0], n_valid=n_valid,
            wall_s=wall,
        )

    def _rounds_meshed(self, proposals_by_channel: list) -> list[RoundStats]:
        """The multi-channel mesh round: order every channel, then commit
        all channels' windows in lockstep — one ``commit_windows`` device
        dispatch per window position covers every channel."""
        cfg = self.cfg
        tracer, reg = self.obs.tracer, self.obs.registry
        wires, blocks_by_ch = [], []
        for c, proposals in enumerate(proposals_by_channel):
            ch = self.chans[c]
            n = int(proposals.src.shape[0])
            if n % cfg.orderer.block_size:
                raise ValueError(
                    f"channel {c}: round of {n} txs not a multiple of "
                    f"{cfg.orderer.block_size}"
                )
            txb = endorser.endorse_jit(
                ch.endorser_state, proposals, cfg.dims,
                n_endorsers=cfg.n_endorsers,
            )
            wires.append(
                jax.block_until_ready(unmarshal.marshal(txb, cfg.dims))
            )
            blocks_by_ch.append((txb, wires[-1]))
        shapes = {w.shape for w in wires}
        if len(shapes) > 1:
            raise ValueError(
                f"lockstep rounds need shape-uniform channels, got {shapes}"
            )
        txrs = [
            self.txtrace.begin_round(
                c,
                np.asarray(blocks_by_ch[c][0].tx_id) if self.obs.on
                else None,
                cfg.orderer.block_size, self.chans[c].next_block_no,
            )
            for c in range(cfg.n_channels)
        ]
        t0 = time.perf_counter()
        ordered = []
        for txr in txrs:
            txr.order_start()
        with tracer.span("round.order", channels=cfg.n_channels,
                         sync=lambda: [b.log_head for b in ordered]):
            for c, (txb, wire) in enumerate(blocks_by_ch):
                ch = self.chans[c]
                blocks = orderer.order_batch_jit(
                    wire, txb.tx_id, txb.client, ch.log_head, cfg.orderer
                )
                ch.log_head = blocks.log_head
                ordered.append(blocks)
        for txr in txrs:
            txr.ordered()

        wc = self.window_committer
        n_blocks = ordered[0].wire.shape[0]
        retired: list[list] = [[] for _ in range(cfg.n_channels)]
        with tracer.span("round.commit", n_blocks=n_blocks,
                         channels=cfg.n_channels):
            for lo in range(0, n_blocks, wc.depth):
                hi = min(lo + wc.depth, n_blocks)
                wire_w = jnp.stack([b.wire[lo:hi] for b in ordered])
                ids_w = jnp.stack([b.tx_ids[lo:hi] for b in ordered])
                res = wc.commit_windows(wire_w, ids_w)
                # commit_windows host-synced the window's chain hashes in
                # its drain span: blocks [lo, hi) cleared validation for
                # every channel on that existing edge.
                for txr in txrs:
                    txr.validated(lo, hi)
                for c in range(cfg.n_channels):
                    ch = self.chans[c]
                    for k in range(hi - lo):
                        bno = ch.next_block_no
                        ch.next_block_no += 1
                        retired[c].append(self._ship(
                            ordered[c].wire[lo + k], bno,
                            res.prev_hash[c, k], res.block_hash[c, k],
                            res.valid[c, k], channel=c,
                        ))
            wc.block_until_ready()
        wall = time.perf_counter() - t0

        replayed = []
        for c in range(cfg.n_channels):
            n_valid, valids = self._endorser_replay(
                retired[c], c, collect_valid=self.obs.on
            )
            txrs[c].committed()
            replayed.append((n_valid, valids))
        # ONE stacked stats read drives every channel's policy decision
        # (satellite: the old per-channel _maybe_resize loop synced the
        # host once per channel per round).
        self._policy_pass(range(cfg.n_channels))
        stats = []
        for c in range(cfg.n_channels):
            n = int(proposals_by_channel[c].src.shape[0])
            n_valid, valids = replayed[c]
            self._maybe_snapshot(c)
            new_bits = self._count_round(c, n, n_valid, wall, n_blocks)
            txrs[c].finish(valids, overflow_latched=bool(new_bits))
            stats.append(RoundStats(
                n_txs=n, n_blocks=n_blocks, n_valid=n_valid, wall_s=wall,
            ))
        return stats

    def _endorser_replay(self, retired: list, channel: int,
                         collect_valid: bool = False) -> tuple:
        """Endorser-cluster replica updates (their hardware) for one
        channel's retired blocks; returns ``(n_valid, valid_by_block)``.
        ``valid_by_block`` is one host-side bool array per block when
        ``collect_valid`` (the tx-outcome feed), else None — the obs-off
        path keeps its scalar-only host transfers."""
        ch = self.chans[channel]
        n_valid = 0
        valids: list | None = [] if collect_valid else None
        with self.obs.tracer.span(
            "round.endorser_replay", channel=channel,
            sync=lambda: ch.endorser_state.versions,
        ):
            for wire_b, valid in retired:
                dec = unmarshal.unmarshal(wire_b, self.cfg.dims)
                ch.endorser_state = endorser.apply_validated_jit(
                    ch.endorser_state, dec.txb, valid
                )
                if collect_valid:
                    v = np.asarray(valid)
                    valids.append(v)
                    n_valid += int(v.sum())
                else:
                    n_valid += int(valid.sum())
        return n_valid, valids

    def _count_round(self, channel: int, n: int, n_valid: int,
                     wall_s: float, n_blocks: int) -> int:
        """Fold one round into the totals, the health rollup's bucket
        ring, and (obs on) the overflow gauges + periodic recorder
        snapshot. Returns the NEWLY latched sticky overflow bits (0 with
        obs off) — a non-zero return is a fault edge."""
        ch = self.chans[channel]
        ch.total_valid += n_valid
        ch.total_txs += n
        self.total_valid += n_valid
        self.total_txs += n
        reg = self.obs.registry
        reg.counter("txs.valid").inc(n_valid)
        reg.counter("txs.invalid").inc(n - n_valid)
        if self.cfg.n_channels > 1:
            # Per-channel demand: makes hot channels visible in
            # stats_text() / collect() next to the aggregate counters.
            reg.counter("txs.valid", channel=channel).inc(n_valid)
            reg.counter("txs.invalid", channel=channel).inc(n - n_valid)
        self.health_rollup.push_round(
            channel, n_txs=n, n_valid=n_valid, wall_s=wall_s,
            n_blocks=n_blocks,
        )
        new_bits = 0
        if self.obs.on:
            new_bits = self._record_overflow_metrics(channel)
            self.recorder.snapshot_registry()
            if new_bits:
                self._fault("overflow_latch", channel=channel,
                            bits=new_bits)
        return new_bits

    def _commit_windows(self, blocks, channel: int = 0,
                        txr=None) -> list:
        """Slice the ordered round into pipeline-depth windows and hand
        each to the window committer; ship every block to the store with
        the committer's chain hashes. A round tail shorter than the depth
        becomes one shallower window (compiled once, reused)."""
        wc = self.window_committer
        ch = self.chans[channel]
        retired = []
        n_blocks = blocks.wire.shape[0]
        for lo in range(0, n_blocks, wc.depth):
            hi = min(lo + wc.depth, n_blocks)
            res = wc.commit_window(blocks.wire[lo:hi], blocks.tx_ids[lo:hi])
            if txr is not None:
                # commit_window host-synced the window's chain hashes in
                # its drain span — blocks [lo, hi) validated on that edge.
                txr.validated(lo, hi)
            for k in range(hi - lo):
                bno = ch.next_block_no
                ch.next_block_no += 1
                retired.append(self._ship(
                    blocks.wire[lo + k], bno, res.prev_hash[k],
                    res.block_hash[k], res.valid[k], channel=channel,
                ))
        return retired

    def _ship(self, wire_b, bno: int, prev_head, block_hash, valid,
              channel: int = 0):
        """Block leaves the pipeline: async handoff to the storage role."""
        if self.store is not None:
            with self.obs.tracer.span("block.ship", block_no=bno,
                                      channel=channel):
                self.store.submit(bno, prev_head, block_hash, wire_b,
                                  valid, channel=channel)
        return wire_b, valid

    # -- observability ---------------------------------------------------------

    def metrics(self) -> dict:
        """One-call snapshot of every engine metric (repro.obs Registry
        collect): counters/gauges as numbers, histograms as
        count/sum/mean/p50/p95/p99 dicts. Empty when obs is off."""
        return self.obs.registry.collect()

    def stats_text(self) -> str:
        """Prometheus text exposition of the engine metrics. Multi-channel
        engines label per-channel series (``txs.valid{channel="c"}``,
        ``state.shard_overflow{channel="c",shard="m"}``), so hot channels
        read straight off the scrape."""
        return self.obs.registry.to_prometheus()

    @property
    def tracer(self):
        return self.obs.tracer

    def _fault(self, reason: str, **ctx) -> None:
        """One engine fault edge fired: trip the flight recorder (which
        auto-dumps the post-mortem when ``cfg.recorder_dir`` is set) and
        surface the trip on the trace."""
        path = self.recorder.trip(reason, **ctx)
        self.obs.tracer.event("engine.fault", reason=reason,
                              dump=path or "")

    def health(self) -> "obs_mod.HealthVerdict":
        """The peer's SLO verdict NOW: ``healthy | degraded | critical``
        with per-channel / per-shard reasons (repro.obs.health).

        Feeds the rollup the live sticky overflow bits and per-shard
        occupancy fractions (one stacked :meth:`_shard_stats` read — NOT
        one sync per channel), evaluates the rolling round window, and
        mirrors the verdict onto ``health.status`` /
        ``health.channel{channel=c}`` gauges for :meth:`stats_text` when
        observability is on. Works with observability off too: the rollup
        runs on host-side round accounting, so the serving layer's
        backpressure can poll it on any engine."""
        chans = range(self.cfg.n_channels)
        stats = self._shard_stats(chans)
        for c in chans:
            occ, _min_free, cap, bits = stats[c]
            self.health_rollup.set_overflow(c, bits)
            self.health_rollup.set_occupancy(
                c, [int(o) / cap for o in occ]
            )
        verdict = self.health_rollup.evaluate()
        if self.obs.on:
            reg = self.obs.registry
            reg.gauge("health.status").set(
                obs_mod.STATUS_RANK[verdict.status]
            )
            for c, info in verdict.channels.items():
                reg.gauge("health.channel", channel=c).set(
                    obs_mod.STATUS_RANK[info["status"]]
                )
        return verdict

    def _record_overflow_metrics(self, channel: int = 0) -> int:
        """Per-shard overflow bits as a labeled gauge + a latch counter
        that fires once per NEWLY set bit. Gauges are keyed
        ``{channel=c, shard=m}`` — one channel's full shard can't hide
        behind (or masquerade as) another's. One tiny host transfer per
        round; only runs with obs on. Returns the newly latched bits (the
        round-level fault-edge signal)."""
        ch = self.chans[channel]
        bits = self.overflow_bits(channel)
        reg = self.obs.registry
        new = bits & ~ch.obs_seen_bits
        if new:
            reg.counter("overflow.latches").inc(bin(new).count("1"))
            ch.obs_seen_bits |= bits
        for m in range(self.n_shards):
            reg.gauge("state.shard_overflow", channel=channel,
                      shard=m).set((bits >> m) & 1)
        return new

    # -- elastic state (resize epochs) -----------------------------------------

    @property
    def n_shards(self) -> int:
        """Bucket shards of snapshot manifests / digest trees: the mesh
        committer's shard count when one is attached, else the configured
        host-side partition."""
        if self.window_committer is not None:
            return self.window_committer.n_shards
        return self.cfg.snapshot_shards

    def _state_view(self, channel: int = 0) -> ws.HashState:
        return (self.window_committer.hash_state(channel)
                if self.window_committer is not None
                else self.chans[channel].peer_state.hash_state)

    def _tree_head(self, state: ws.HashState | None = None,
                   channel: int = 0) -> np.ndarray:
        st = self._state_view(channel) if state is None else state
        return np.asarray(ws.tree_head(st, self.n_shards))

    def overflow_bits(self, channel: int = 0) -> int:
        """Sticky per-shard overflow bitmask of one channel (bit m ==
        shard m filled). Restored bits (a restart re-latching a persisted
        mask) OR in, so a mesh peer's which-shard information survives a
        host-side restore."""
        ch = self.chans[channel]
        if self.window_committer is not None:
            bits = self.window_committer.overflow_bits_for(channel)
        else:
            bits = int(bool(np.asarray(ch.overflow)))
        return bits | ch.restored_overflow_bits

    def _shard_stats(self, channels) -> dict:
        """channel -> (per-shard occupancy ``(M,)``, min free slots,
        per-shard slot capacity, sticky overflow bits) for every requested
        channel in ONE stacked device read — the committer runs a tiny
        jitted reduction per shape group, the host path device_gets one
        lazy tuple tree. Restored overflow bits are OR-ed in, matching
        :meth:`overflow_bits`."""
        channels = list(channels)
        if self.window_committer is not None:
            stats = self.window_committer.shard_stats(channels)
            return {
                c: (occ, mf, cap,
                    bits | self.chans[c].restored_overflow_bits)
                for c, (occ, mf, cap, bits) in stats.items()
            }
        m = self.n_shards
        lazy = {}
        for c in channels:
            st = self.chans[c].peer_state.hash_state
            lazy[c] = (ws.shard_occupancy(st, m),
                       ws.shard_min_free(st, m), self.chans[c].overflow)
        host = jax.device_get(lazy)
        out = {}
        for c in channels:
            st = self.chans[c].peer_state.hash_state
            occ, mf, ovf = host[c]
            out[c] = (
                np.asarray(occ), int(np.asarray(mf).min()),
                st.n_buckets // m * st.slots,
                int(bool(ovf)) | self.chans[c].restored_overflow_bits,
            )
        return out

    def _policy_pass(self, channels) -> dict:
        """The between-rounds policy trigger, vectorized: ONE stacked
        stats read (:meth:`_shard_stats`) drives every channel's
        grow/shrink decision — grow under bucket pressure or after an
        overflow (capacity repair instead of fail-stop), shrink a mostly-
        empty table — plus the per-channel ``state.occupancy`` /
        ``state.health`` gauges and the health rollup's occupancy feed,
        all from the same pass. Rounds are window boundaries, so a window
        committer is always drained here. No policy, no device read.
        Returns ``{channel: resize info}`` for channels that resized."""
        pol = self.cfg.resize_policy
        if pol is None:
            return {}
        channels = list(channels)
        stats = self._shard_stats(channels)
        reg = self.obs.registry
        if self.obs.on:
            reg.counter("resize.policy_checks").inc(len(channels))
        out = {}
        for c in channels:
            ch = self.chans[c]
            occ, min_free, cap, bits = stats[c]
            fills = [int(o) / cap for o in occ]
            self.health_rollup.set_occupancy(c, fills)
            pressure = bool(
                (pol.grow_free_slots and min_free <= pol.grow_free_slots)
                or (pol.grow_fill and max(fills) >= pol.grow_fill)
            )
            if self.obs.on:
                reg.gauge("state.occupancy", channel=c).set(max(fills))
                # 2 = overflowed (fail-stop shard), 1 = under grow
                # pressure, 0 = headroom — the at-a-glance shard health.
                reg.gauge("state.health", channel=c).set(
                    2 if bits else (1 if pressure else 0)
                )
            # Capacity repair: one overflow-triggered grow per NEWLY
            # latched shard bit (the bitmask is sticky, so comparing
            # against the repaired mask keeps a later overflow of a
            # different shard repairable without re-firing every round).
            if pressure or (pol.grow_on_overflow
                            and bits & ~ch.repaired_bits):
                if ch.n_buckets * 2 <= pol.max_buckets:
                    self.obs.tracer.event(
                        "resize.decision", action="grow",
                        min_free=min_free, overflow_bits=bits,
                        n_buckets=ch.n_buckets, channel=c,
                    )
                    ch.repaired_bits |= bits
                    out[c] = self.resize(ch.n_buckets * 2, c)
                elif bits & ~ch.repaired_bits:
                    # Overflowed at the policy's capacity ceiling: the
                    # repair cannot run — a fault edge (fail-stop shard
                    # with no recourse). Latch the bits as repaired so the
                    # refusal trips once, not every following round.
                    ch.repaired_bits |= bits
                    self._fault(
                        "resize_refused", channel=c,
                        n_buckets=ch.n_buckets,
                        max_buckets=pol.max_buckets, overflow_bits=bits,
                    )
                continue
            if (pol.shrink_fill and ch.n_buckets // 2 >= pol.min_buckets
                    and int(occ.sum()) < pol.shrink_fill
                    * (ch.n_buckets // 2) * self.cfg.slots):
                self.obs.tracer.event(
                    "resize.decision", action="shrink",
                    occupancy=int(occ.sum()), n_buckets=ch.n_buckets,
                    channel=c,
                )
                out[c] = self.resize(ch.n_buckets // 2, c)
        return out

    def _maybe_resize(self, channel: int = 0) -> dict | None:
        """Single-channel policy hook (back-compat surface): the round
        paths batch every channel through :meth:`_policy_pass` now."""
        return self._policy_pass((channel,)).get(channel)

    def resize(self, new_n_buckets: int, channel: int = 0) -> dict:
        """Halve/double ONE channel's world state NOW (between rounds) and
        commit a re-anchor record for the epoch — to that channel's
        journal; other channels' tables, heads and journals are untouched.
        The channel's endorser replica follows (its capacity must track
        the peer's or the replicas diverge on which inserts drop), and the
        journal is re-anchored at the drained boundary so verify/replay
        cross the resize."""
        if self.store is not None:
            self.store.drain()  # journal tip must be at the boundary
        ch = self.chans[channel]
        old_nb = ch.n_buckets
        hot = (self.window_committer.hot_shard(channel)
               if self.window_committer is not None
               else self._hot_shard(channel))
        if self.window_committer is not None:
            try:
                info = self.window_committer.resize(new_n_buckets, channel)
            except ValueError as e:
                # The committer refused the epoch (e.g. a no-op resize to
                # the current layout): a fault edge — the caller believed
                # a capacity change was needed and none happened.
                self._fault("resize_refused", channel=channel,
                            n_buckets=old_nb, requested=new_n_buckets,
                            error=str(e))
                raise
            tree, bits = info.tree_head, info.overflow_bits
        else:
            res = ws.resize(ch.peer_state.hash_state, new_n_buckets)
            ch.peer_state = ch.peer_state._replace(hash_state=res.state)
            ch.overflow = ch.overflow | res.overflow
            tree, bits = None, None
        eres = ws.resize(ch.endorser_state, new_n_buckets)
        ch.endorser_state = eres.state
        ch.n_buckets = new_n_buckets
        if tree is None:
            tree, bits = (self._tree_head(channel=channel),
                          self.overflow_bits(channel))
        if ch.journal is not None:
            ch.journal.append_reanchor(
                ch.next_block_no - 1,
                old_n_buckets=old_nb, new_n_buckets=new_n_buckets,
                n_shards=self.n_shards, tree_head=tree, overflow_bits=bits,
            )
        info = {
            "block_no": ch.next_block_no - 1, "old_n_buckets": old_nb,
            "new_n_buckets": new_n_buckets, "overflow_bits": bits,
            "hot_shard": hot, "channel": channel,
        }
        ch.reanchor_log.append(info)
        self.obs.registry.counter(
            "resize.grow" if new_n_buckets > old_nb else "resize.shrink"
        ).inc()
        self.obs.tracer.event("resize.epoch", **info)
        return info

    def _hot_shard(self, channel: int = 0) -> int:
        return ws.hot_shard(
            self.overflow_bits(channel),
            ws.shard_occupancy(self._state_view(channel), self.n_shards),
        )

    # -- durability layer (storage/) -------------------------------------------

    def _maybe_snapshot(self, channel: int = 0) -> None:
        """Snapshot cadence: dump one channel's world state every
        ``snapshot_every_blocks`` committed blocks (per-channel block
        counts — channels snapshot on their own schedules); prune that
        channel's chain + journal with a one-snapshot lag (the previous
        snapshot stays fully recoverable even if the newest one is lost or
        torn). Snapshots are per-shard files + manifest, and the manifest
        persists the sticky overflow bitmask + re-anchor head."""
        cfg = self.cfg
        if not cfg.snapshot_every_blocks:
            return
        ch = self.chans[channel]
        last = ch.snapshots[-1].block_no if ch.snapshots else -1
        tip = ch.next_block_no - 1  # last committed block
        if tip - last < cfg.snapshot_every_blocks:
            return
        self.store.drain()  # journal must cover every shipped block
        with self.obs.tracer.span("snapshot.take", block_no=tip,
                                  channel=channel):
            snap = snapshot.take(
                self._state_view(channel),
                block_no=tip,
                journal_head=self._peer_journal_head(channel),
                ledger_head=self._ledger_head(channel),
                n_shards=self.n_shards,
                overflow_bits=self.overflow_bits(channel),
                reanchor_head=(ch.journal.reanchor_head
                               if ch.journal is not None else None),
            )
        ch.snapshots.append(snap)
        if cfg.snapshot_dir is not None:
            sdir = ledger.channel_dir(cfg.snapshot_dir, channel)
            snapshot.save(sdir, snap, registry=self.obs.registry)
            snapshot.gc(sdir, keep=2, registry=self.obs.registry)
        if cfg.prune_chain and len(ch.snapshots) >= 2:
            base = ch.snapshots[-2].block_no
            self.store.prune_upto(base, channel)
            ch.journal.prune_upto(base)
            ch.snapshots = ch.snapshots[-2:]

    def recover(self, channel: int = 0) -> recovery.RecoveryResult:
        """Cold-start recovery of one channel from its latest snapshot +
        journal suffix (crossing any resize re-anchors in it)."""
        ch = self.chans[channel]
        if ch.journal is None:
            raise recovery.RecoveryError("engine has no journal")
        self.store.drain()
        return recovery.recover(
            ch.journal,
            snapshot=ch.snapshots[-1] if ch.snapshots else None,
            n_buckets=self.cfg.n_buckets,
            slots=self.cfg.slots,
            value_width=self.cfg.dims.vw,
            channel=channel,
        )

    @classmethod
    def restore(cls, cfg: EngineConfig) -> "FabricEngine":
        """Restart a peer from its persisted snapshots + journal spills
        (every configured channel restores from its own namespaced dirs).

        Requires ``journal_dir`` and ``snapshot_dir``. When the latest
        complete snapshot covers the journal tip (the engine snapshots
        after the round that produced the tip, so a crash between rounds
        restores exactly), the snapshot's heads restore directly. When the
        snapshot TRAILS the tip (crash between the journal write and the
        snapshot), the suffix's state replays from the journal and its
        ledger head rebuilds from the ``block_dir`` block spill — the
        spilled blocks must chain from the snapshot's head, and they
        re-seed the store so ``verify()`` replays the same suffix. The
        restored peer re-latches the persisted sticky overflow bitmask —
        overflowing, snapshotting and restarting no longer launders the
        health flag — and resumes on the persisted (post-resize) layout.
        """
        if cfg.journal_dir is None or cfg.snapshot_dir is None:
            raise recovery.RecoveryError(
                "restore requires journal_dir and snapshot_dir"
            )
        eng = cls(cfg)
        for c in range(cfg.n_channels):
            eng._restore_channel(c)
        return eng

    def _restore_channel(self, channel: int) -> None:
        cfg = self.cfg
        ch = self.chans[channel]
        jrnl = state_journal.StateJournal.load(
            cfg.dims, ledger.channel_dir(cfg.journal_dir, channel),
            metrics=self.obs.registry,
        )
        ch.journal = jrnl
        if self.store is not None:
            if channel == cfg.n_channels - 1:
                # Writer swap once, after the last channel's journal loads:
                # the fresh store multiplexes every restored journal.
                self.store.close()
                store = ledger.BlockStore(cfg.block_dir,
                                          journal=self.chans[0].journal)
                for c2 in range(1, cfg.n_channels):
                    if self.chans[c2].journal is not None:
                        store.set_journal(c2, self.chans[c2].journal)
                # Re-seed the already-restored channels' bases and chains.
                for c2 in range(channel):
                    old = self.store
                    store.chains[c2] = old.chains.get(c2, [])
                    store.base_block_nos[c2] = old.base_block_nos.get(
                        c2, -1)
                    store.base_hashes[c2] = old.base_hashes.get(
                        c2, np.zeros(2, np.uint32))
                self.store = store
        snap = snapshot.latest(ledger.channel_dir(cfg.snapshot_dir, channel))
        if snap is None:
            raise recovery.RecoveryError(
                f"no complete snapshot for channel {channel} in "
                f"{cfg.snapshot_dir}"
            )
        rec = recovery.recover(
            jrnl, snapshot=snap, n_buckets=cfg.n_buckets, slots=cfg.slots,
            value_width=cfg.dims.vw,
        )
        suffix: list[ledger.StoredBlock] = []
        if rec.block_no != snap.block_no:
            # The snapshot trails the journal tip: the journal already
            # replayed the suffix's STATE, but the ledger head only lives
            # in the block chain — rebuild it from the spilled blocks,
            # verifying they chain from the snapshot's head.
            if cfg.block_dir is None:
                raise recovery.RecoveryError(
                    f"journal tip {rec.block_no} past the latest snapshot "
                    f"{snap.block_no}: the suffix's ledger head is not "
                    "recoverable without the block spill (cfg.block_dir)"
                )
            suffix = ledger.load_spilled_blocks(
                cfg.block_dir, snap.block_no + 1, channel
            )
            suffix = [sb for sb in suffix if sb.block_no <= rec.block_no]
            if not suffix or suffix[-1].block_no != rec.block_no:
                have = suffix[-1].block_no if suffix else snap.block_no
                raise recovery.RecoveryError(
                    f"block spill covers channel {channel} only up to "
                    f"block {have}, journal tip is {rec.block_no}"
                )
            prev = np.asarray(snap.ledger_head)
            for sb in suffix:
                if not np.array_equal(sb.prev_hash, prev):
                    raise recovery.RecoveryError(
                        f"spilled block {sb.block_no} does not chain from "
                        "the snapshot's ledger head (corrupt or tampered)"
                    )
                expect = ledger.append_hash(
                    jnp.asarray(prev), jnp.uint32(sb.block_no),
                    ledger.block_body_digest(
                        jnp.asarray(sb.wire), jnp.asarray(sb.valid)),
                )
                if not np.array_equal(np.asarray(expect), sb.block_hash):
                    raise recovery.RecoveryError(
                        f"spilled block {sb.block_no} fails its chain "
                        "hash (corrupt or tampered)"
                    )
                prev = sb.block_hash
            ledger_head = prev
            # Resize epochs inside the suffix must re-enter the replay
            # log, or verify()'s chain replay lands on the wrong layout.
            for r in jrnl.suffix_reanchors(snap.block_no):
                ch.reanchor_log.append({
                    "block_no": r.block_no,
                    "old_n_buckets": r.old_n_buckets,
                    "new_n_buckets": r.new_n_buckets,
                    "overflow_bits": r.overflow_bits,
                    "hot_shard": -1,  # not persisted; advisory only
                    "channel": channel,
                })
        else:
            ledger_head = np.asarray(snap.ledger_head)
        ch.snapshots = [snap]
        ch.peer_state = ch.peer_state._replace(
            hash_state=rec.state,
            ledger_head=jnp.asarray(ledger_head),
            journal_head=jnp.asarray(rec.journal_head),
            block_no=jnp.uint32(rec.block_no + 1),
        )
        ch.endorser_state = ws.HashState(
            keys=jnp.array(rec.state.keys, copy=True),
            versions=jnp.array(rec.state.versions, copy=True),
            values=jnp.array(rec.state.values, copy=True),
        )
        ch.n_buckets = rec.n_buckets
        # Re-latch the persisted mask WITH its which-shard bits, and mark
        # those bits as already repaired: the pre-crash policy (or its
        # operator) had its chance — a restart must not trigger one more
        # doubling per boot on bits that can never un-latch. A shard that
        # newly overflows AFTER the restart still fires the repair.
        ch.restored_overflow_bits = rec.overflow_bits
        ch.repaired_bits = rec.overflow_bits
        ch.next_block_no = rec.block_no + 1
        if self.store is not None:
            # The chain re-anchors at the snapshot; a rebuilt suffix
            # re-enters it so verify() replays the same blocks recovery
            # replayed from the journal.
            self.store.base_block_nos[channel] = snap.block_no
            self.store.base_hashes[channel] = np.asarray(snap.ledger_head)
            self.store.chains[channel] = list(suffix)

    # -- durability checks (used by tests/examples) ----------------------------

    def _peer_digest(self, channel: int = 0) -> np.ndarray:
        """Digest of a channel's committed world state — from the
        mesh-backed window committer when one is attached, else the peer
        state."""
        if self.window_committer is not None:
            return self.window_committer.state_digest(channel)
        return np.asarray(
            ws.state_digest(self.chans[channel].peer_state.hash_state)
        )

    def _peer_journal_head(self, channel: int = 0) -> np.ndarray:
        if self.window_committer is not None:
            return self.window_committer.journal_head_for(channel)
        return np.asarray(self.chans[channel].peer_state.journal_head)

    def _ledger_head(self, channel: int = 0) -> np.ndarray:
        if self.window_committer is not None:
            return self.window_committer.ledger_head_for(channel)
        return np.asarray(self.chans[channel].peer_state.ledger_head)

    def overflowed(self, channel: int = 0) -> bool:
        """Sticky: any committed block of the channel ever dropped a write
        on a full bucket (mesh-backed committer or the single-host peer
        path)."""
        return bool(self.overflow_bits(channel))

    def verify(self, channel: int = 0) -> dict:
        """Drain storage, verify ONE channel's chain, check its replica
        consistency, check that none of its commits ever overflowed a
        bucket, and prove its recovery path reproduces the live peer.
        Strictly per-channel state: tampering with channel i's chain or
        journal flips channel i's verdicts only (``verify_all`` sweeps
        every channel)."""
        ch = self.chans[channel]
        out = {"chain_ok": True, "replica_ok": True, "replay_ok": True,
               "recovery_ok": True,
               "overflow_ok": not self.overflowed(channel)}
        if self.store is not None:
            self.store.drain()
            out["chain_ok"] = self.store.verify_chain(channel)
            start = None
            missing_base = False
            base_bno = self.store.base_block_nos.get(channel, -1)
            if base_bno >= 0:
                # Chain pruned at a snapshot boundary: replay resumes from
                # the snapshot that covers the compacted prefix. The list
                # may no longer hold it (pruned snapshots, reloaded dir) —
                # that is a verification failure, not a crash: without the
                # covering snapshot the compacted prefix cannot be
                # re-authenticated or replayed.
                base = next(
                    (s for s in ch.snapshots if s.block_no == base_bno),
                    None,
                )
                if base is None:
                    missing_base = True
                else:
                    start = snapshot.to_state(base)
            if missing_base:
                out["chain_ok"] = False
                out["replay_ok"] = False
            else:
                # Replay crosses resize epochs: the recorded halve/doubles
                # apply at their boundaries, so the replayed table lands on
                # the live (post-resize) layout.
                replay_from = base_bno if start is not None else -1
                resize_at: dict = {}
                for r in ch.reanchor_log:
                    if r["block_no"] > replay_from:
                        resize_at.setdefault(r["block_no"], []).append(
                            r["new_n_buckets"])
                replayed = self.store.replay_state(
                    self.cfg.dims, self.cfg.n_buckets, self.cfg.slots,
                    start_state=start, resize_at=resize_at,
                    channel=channel,
                )
                out["replay_ok"] = bool(
                    np.array_equal(
                        np.asarray(ws.state_digest(replayed)),
                        self._peer_digest(channel),
                    )
                ) if self.cfg.peer.hash_state else True
        if ch.journal is not None and self.cfg.peer.hash_state:
            try:
                rec = self.recover(channel)
                out["recovery_ok"] = bool(
                    np.array_equal(rec.state_digest,
                                   self._peer_digest(channel))
                    and np.array_equal(
                        rec.journal_head, self._peer_journal_head(channel)
                    )
                )
            except recovery.RecoveryError:
                out["recovery_ok"] = False
        if self.cfg.peer.hash_state:
            out["replica_ok"] = bool(
                np.array_equal(
                    np.asarray(ws.state_digest(ch.endorser_state)),
                    self._peer_digest(channel),
                )
            )
        if not all(out.values()):
            # Fault edge: the durability contract broke. Trip the flight
            # recorder with the verdict — plus WHICH journal record broke
            # the chain when the journal can say (verify_chain_reason).
            ctx = {"channel": channel,
                   "verdict": {k: bool(v) for k, v in out.items()}}
            if ch.journal is not None:
                jok, why = ch.journal.verify_chain_reason()
                if not jok:
                    ctx["journal_reason"] = why
            self._fault("verify_contract", **ctx)
        return out

    def verify_all(self) -> dict[int, dict]:
        """Per-channel :meth:`verify` verdicts for every channel."""
        return {c: self.verify(c) for c in range(self.cfg.n_channels)}
