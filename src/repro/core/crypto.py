"""Endorsement MACs: Carter-Wegman polynomial MAC over the Mersenne prime
2^31 - 1, in pure u32 vector arithmetic.

Paper mapping (§II-C2, §III-H): every transaction's endorsement signatures
must be verified on the critical path (X.509 / ECDSA in Fabric). ECDSA is
serial big-integer arithmetic with no TPU analogue, so we substitute a
polynomial MAC per endorser: tag_e = s_e + sum_i m_i * r_e^(W-i)  (mod p).
This is a *semantic weakening* (shared-key MAC, not public-key signature —
documented in DESIGN.md §2) but preserves what the paper measures: a
per-transaction verification whose cost scales with message length and that
every valid transaction must pass.

Everything here is u32-native: p = 2^31-1 lets 32x32 multiplication be done
with 16-bit limb decomposition entirely in uint32 (TPUs have no 64-bit
integer units). kernels/sig_mac is the Pallas version; this module is the
oracle and the default CPU path.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import hashing, types

U32 = jnp.uint32
P31 = jnp.uint32((1 << 31) - 1)
_MASK15 = jnp.uint32((1 << 15) - 1)
_MASK16 = jnp.uint32((1 << 16) - 1)


def mod31(x):
    """Reduce u32 -> [0, p). Two folds handle x up to 2^32-1."""
    x = x.astype(U32)
    x = (x & P31) + (x >> 31)
    x = (x & P31) + (x >> 31)
    return jnp.where(x == P31, jnp.uint32(0), x)


def addmod31(a, b):
    s = a + b  # both < p < 2^31 so s < 2^32: safe
    return mod31(s)


def mulmod31(a, b):
    """(a * b) mod (2^31-1) for a, b in [0, p), pure u32 ops.

    Split a = ah*2^16 + al, b = bh*2^16 + bl (ah, bh < 2^15; al, bl < 2^16):
      a*b = ah*bh*2^32 + (ah*bl + al*bh)*2^16 + al*bl
    with 2^31 = 1 (mod p) so 2^32 = 2 and x*2^16 folds via a 15/16 bit split.
    Each partial fits u32; each is reduced before summation.
    """
    a = a.astype(U32)
    b = b.astype(U32)
    ah, al = a >> 16, a & _MASK16
    bh, bl = b >> 16, b & _MASK16

    hi = ah * bh  # < 2^30
    hi2 = mod31(hi << 1)  # *2^32 == *2

    def shift16(x):  # (x * 2^16) mod p, x < 2^31
        x = mod31(x)
        return mod31(((x & _MASK15) << 16) + (x >> 15))

    mid = addmod31(shift16(ah * bl), shift16(al * bh))  # each prod < 2^31
    lo = mod31(al * bl)  # < 2^32: mod31 handles
    return addmod31(addmod31(hi2, mid), lo)


def endorser_keys(n_endorsers: int):
    """Derive (r, s) MAC keys for each endorser. (NE,) u32 arrays in [1, p)."""
    e = jnp.arange(n_endorsers, dtype=U32)
    r = mod31(hashing.hash_u32(e, seed=jnp.uint32(0x1234ABCD)))
    s = mod31(hashing.hash_u32(e, seed=jnp.uint32(0xFEED5EED)))
    one = jnp.uint32(1)
    return jnp.maximum(r, one), jnp.maximum(s, one)


def poly_mac(words: jnp.ndarray, r, s) -> jnp.ndarray:
    """MAC of (B, W) u32 messages under key (r, s). Returns (B,) u32 in [0,p).

    Horner evaluation: acc <- acc*r + m_i (mod p); tag = acc + s. Message
    words are reduced mod p on ingestion (the message encoding).
    """
    b, w = words.shape
    r = jnp.broadcast_to(jnp.asarray(r, U32), (b,))
    acc = jnp.zeros((b,), U32)
    for i in range(w):
        acc = addmod31(mulmod31(acc, r), mod31(words[:, i]))
    return addmod31(acc, jnp.broadcast_to(jnp.asarray(s, U32), (b,)))


def endorse_batch(txb: types.TxBatch, n_endorsers: int | None = None
                  ) -> jnp.ndarray:
    """Produce endorsement tags (B, NE) for a batch (the endorsers' side)."""
    ne = n_endorsers or txb.endorse_tags.shape[1]
    msg = types.message_words(txb)  # (B, W)
    r, s = endorser_keys(ne)
    tags = [poly_mac(msg, r[e], s[e]) for e in range(ne)]
    return jnp.stack(tags, axis=1)


def verify_tags(txb: types.TxBatch) -> jnp.ndarray:
    """All-of endorsement policy: every tag must verify. (B,) bool."""
    expect = endorse_batch(txb)
    return (expect == txb.endorse_tags).all(axis=1)
