"""Vectorized 32-bit integer hashing.

TPUs have no 64-bit integer units, so FastFabric's 256-bit transaction IDs
and arbitrary state keys become *paired independent u32 hashes*: two murmur3
finalizers with different seeds give 64-bit effective collision resistance
while every op stays in native u32 vector arithmetic (see DESIGN.md §2).

All functions are shape-polymorphic and jit/vmap/pallas friendly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

U32 = jnp.uint32

# Sentinel for "no key in this slot". Hash outputs are remapped away from it.
EMPTY_KEY = jnp.uint32(0)

# Two independent seeds for the paired hash.
SEED_A = jnp.uint32(0x9E3779B9)  # golden ratio
SEED_B = jnp.uint32(0x85EBCA6B)  # murmur3 c1


def _fmix32(x):
    """murmur3 32-bit finalizer — a strong bijective mixer."""
    x = x.astype(U32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def hash_u32(x, seed=SEED_A):
    """Hash u32 -> u32 with a seed. Bijective for fixed seed."""
    return _fmix32(x.astype(U32) ^ jnp.uint32(seed))


def hash_pair(x, seed=SEED_A):
    """Paired hash: (h1, h2) of a u32 input — 64-bit effective width."""
    h1 = hash_u32(x, seed)
    h2 = hash_u32(x, seed ^ SEED_B)
    return h1, h2


def combine(h, x):
    """Fold a new u32 word into a running hash (boost::hash_combine style)."""
    h = h.astype(U32)
    x = x.astype(U32)
    return h ^ (_fmix32(x) + jnp.uint32(0x9E3779B9) + (h << 6) + (h >> 2))


def hash_words(words, seed=SEED_A, axis=-1):
    """Hash an array of u32 words along ``axis`` into a single u32.

    Order-dependent: uses a multiply-accumulate chain so permutations hash
    differently. Implemented as a vectorized polynomial in u32 (wrapping
    arithmetic): h = ((h * P) + w) mixed at the end.
    """
    words = words.astype(U32)
    words = jnp.moveaxis(words, axis, 0)
    h = jnp.full(words.shape[1:], jnp.uint32(seed), dtype=U32)
    p = jnp.uint32(0x01000193)  # FNV prime
    for i in range(words.shape[0]):
        h = h * p + words[i]
        h = h ^ (h >> 15)
    return _fmix32(h)


def nonzero_key(h):
    """Remap a hash away from reserved sentinels (0 -> 1, 0xFFFFFFFF -> ...E).

    0 is the hash-table EMPTY_KEY; 0xFFFFFFFF is the sorted-store DEAD marker.
    """
    h = jnp.where(h == EMPTY_KEY, jnp.uint32(1), h)
    return jnp.where(h == jnp.uint32(0xFFFFFFFF), jnp.uint32(0xFFFFFFFE), h)


def lex_searchsorted(s_hi, s_lo, q_hi, q_lo):
    """Left insertion point of (q_hi, q_lo) pairs in a (hi, lo)-lexsorted
    store, without u64 (x64 stays disabled).

    Vectorized binary search over the pair order: 32-ish iterations of a
    branch-free bisection, each comparing (s_hi[mid], s_lo[mid]) against the
    query pair. Returns (B,) int32 in [0, N] — the *exact* position, so the
    caller needs a probe window of one: an arbitrarily long run of equal
    ``hi`` values (u32 birthday collisions at ~100k-element stores) can
    never push the match out of reach, unlike a fixed window after a
    searchsorted on ``hi`` alone.
    """
    n = s_hi.shape[0]
    lo = jnp.zeros(q_hi.shape, jnp.int32)
    hi = jnp.full(q_hi.shape, n, jnp.int32)
    if n == 0:
        return lo

    def body(_, carry):
        lo, hi = carry
        active = lo < hi  # converged lanes stop moving
        mid = (lo + hi) >> 1
        safe = jnp.minimum(mid, n - 1)
        mh = s_hi[safe]
        ml = s_lo[safe]
        less = (mh < q_hi) | ((mh == q_hi) & (ml < q_lo))
        lo = jnp.where(active & less, mid + 1, lo)
        hi = jnp.where(active & ~less, mid, hi)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, max(1, n.bit_length()), body, (lo, hi))
    return lo


def key_of_string(s: str) -> int:
    """Host-side: stable u32 key for a python string (for tests/examples)."""
    h = 2166136261
    for ch in s.encode():
        h = ((h ^ ch) * 16777619) & 0xFFFFFFFF
    return h or 1
