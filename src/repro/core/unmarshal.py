"""Marshal / unmarshal: the wire format and the P-III unmarshal cache.

Paper mapping (§III-I): Fabric moves protobuf bytes between nodes and pays a
large (de)serialization + allocation tax because every pipeline stage
re-unmarshals the layered block structure. FastFabric decodes once into a
cyclic cache sized to the validation pipeline and shares it lock-free.

TPU adaptation: a marshaled transaction is a row of u8 wire bytes. Decoding is
(a) a byte→u32 bitcast + field slicing (protobuf walk analogue) and (b) an
integrity pass — an FNV chain over *every* payload word checked against the
header checksum. (b) is what makes decode cost honest: like protobuf parsing,
it touches all payload bytes, so decode time scales with payload size and the
P-III cache saving is real, not simulated.

Wire layout per transaction, in u32 words (little-endian u8 on the wire):
  [0:2]   tx_id            [2]    client          [3]   channel
  [4]     payload checksum (FNV over words[5:P])
  [5:5+RK*3]               read_keys (RK,2) + read_vers (RK)
  [...]                    write_keys (WK,2) + write_vals (WK,VW)
  [...]                    endorse_tags (NE)
  [rest]                   opaque application payload (the 2.9 KB body)
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import hashing, types

U32 = jnp.uint32
_CHECK_SEED = jnp.uint32(0x811C9DC5)


def _layout(dims: types.FabricDims):
    """Word offsets of each field group."""
    o = {}
    pos = 0

    def take(name, n):
        nonlocal pos
        o[name] = (pos, pos + n)
        pos += n

    take("tx_id", 2)
    take("client", 1)
    take("channel", 1)
    take("checksum", 1)
    take("read_keys", dims.rk * 2)
    take("read_vers", dims.rk)
    take("write_keys", dims.wk * 2)
    take("write_vals", dims.wk * dims.vw)
    take("endorse_tags", dims.ne)
    o["opaque"] = (pos, dims.payload_words)
    return o


# Word index of the header checksum — in the fixed, dims-independent header
# prefix, so derived once here; anything that touches the checksum on the
# wire (e.g. orderer.order_batch's reassembly-miss poisoning) must use this
# rather than re-encode the layout.
CHECKSUM_WORD: int = _layout(types.FabricDims())["checksum"][0]


def payload_checksum(words: jnp.ndarray) -> jnp.ndarray:
    """FNV chain over the words after the checksum — the 'parse the whole
    buffer' cost."""
    return hashing.hash_words(words[:, CHECKSUM_WORD + 1:], seed=_CHECK_SEED)


def marshal(txb: types.TxBatch, dims: types.FabricDims, *, fill_seed: int = 1
            ) -> jnp.ndarray:
    """TxBatch -> wire bytes (B, 4*payload_words) u8."""
    b = txb.batch
    lay = _layout(dims)
    words = jnp.zeros((b, dims.payload_words), U32)

    def put(name, val):
        s, e = lay[name]
        return words.at[:, s:e].set(val.reshape(b, e - s).astype(U32))

    words = put("tx_id", txb.tx_id)
    words = put("client", txb.client)
    words = put("channel", txb.channel)
    words = put("read_keys", txb.read_keys)
    words = put("read_vers", txb.read_vers)
    words = put("write_keys", txb.write_keys)
    words = put("write_vals", txb.write_vals)
    words = put("endorse_tags", txb.endorse_tags)
    # Opaque application body: pseudo-random filler (content the committer
    # must still checksum, as protobuf must walk unparsed submessages).
    s, e = lay["opaque"]
    if e > s:
        filler = hashing.hash_u32(
            jnp.arange(b * (e - s), dtype=U32).reshape(b, e - s)
            + jnp.uint32(fill_seed)
        )
        words = words.at[:, s:e].set(filler)
    words = words.at[:, CHECKSUM_WORD].set(payload_checksum(words))
    return jax.lax.bitcast_convert_type(words, jnp.uint8).reshape(b, -1)


def struct_prefix_words(dims: types.FabricDims) -> int:
    """Words of the structured prefix (header incl. checksum + rw sets +
    tags) — what Opt O-I ships through consensus instead of the full wire."""
    lay = _layout(dims)
    return lay["endorse_tags"][1]


def unmarshal_prefix(words: jnp.ndarray, dims: types.FabricDims
                     ) -> types.TxBatch:
    """Decode a TxBatch from structured-prefix words (B, struct_prefix).

    The opaque body is absent, so no checksum verification happens here —
    body integrity is checked *locally* at the ingest rank before the
    prefix enters consensus (launch/fabric_step.py).
    """
    b = words.shape[0]
    lay = _layout(dims)

    def get(name, *shape):
        s, e = lay[name]
        return words[:, s:e].reshape(b, *shape) if shape else words[:, s]

    return types.TxBatch(
        tx_id=get("tx_id", 2),
        client=get("client"),
        channel=get("channel"),
        read_keys=get("read_keys", dims.rk, 2),
        read_vers=get("read_vers", dims.rk),
        write_keys=get("write_keys", dims.wk, 2),
        write_vals=get("write_vals", dims.wk, dims.vw),
        endorse_tags=get("endorse_tags", dims.ne),
    )


class Unmarshaled(NamedTuple):
    txb: types.TxBatch
    checksum_ok: jnp.ndarray  # (B,) bool


def unmarshal(wire: jnp.ndarray, dims: types.FabricDims) -> Unmarshaled:
    """Wire bytes -> TxBatch + integrity flag. Cost scales with payload size."""
    b = wire.shape[0]
    words = jax.lax.bitcast_convert_type(
        wire.reshape(b, dims.payload_words, 4), U32
    ).reshape(b, dims.payload_words)
    lay = _layout(dims)

    def get(name, *shape):
        s, e = lay[name]
        return words[:, s:e].reshape(b, *shape) if shape else words[:, s]

    txb = types.TxBatch(
        tx_id=get("tx_id", 2),
        client=get("client"),
        channel=get("channel"),
        read_keys=get("read_keys", dims.rk, 2),
        read_vers=get("read_vers", dims.rk),
        write_keys=get("write_keys", dims.wk, 2),
        write_vals=get("write_vals", dims.wk, dims.vw),
        endorse_tags=get("endorse_tags", dims.ne),
    )
    ok = payload_checksum(words) == get("checksum")
    return Unmarshaled(txb=txb, checksum_ok=ok)


class UnmarshalCache:
    """P-III: cyclic buffer of decoded blocks, sized to the pipeline depth.

    Host-side coordinator (the device arrays it holds are on-device). Mirrors
    the paper's lock-free cyclic buffer: a block's slot is ``block_no % depth``
    and a slot is only overwritten after its block left the pipeline, which
    the committer guarantees by construction (same argument as the paper's
    safety argument in §III-I).
    """

    def __init__(self, depth: int):
        self.depth = depth
        self._slots: list[Unmarshaled | None] = [None] * depth
        self._tags: list[int | None] = [None] * depth
        self.hits = 0
        self.misses = 0

    def get(self, block_no: int, wire: jnp.ndarray, dims: types.FabricDims
            ) -> Unmarshaled:
        slot = block_no % self.depth
        if self._tags[slot] == block_no:
            self.hits += 1
            return self._slots[slot]
        self.misses += 1
        dec = unmarshal(wire, dims)
        self._slots[slot] = dec
        self._tags[slot] = block_no
        return dec

    def put(self, block_no: int, dec: Unmarshaled) -> None:
        slot = block_no % self.depth
        self._slots[slot] = dec
        self._tags[slot] = block_no

    def evict(self, block_no: int) -> None:
        slot = block_no % self.depth
        if self._tags[slot] == block_no:
            self._tags[slot] = None
            self._slots[slot] = None
