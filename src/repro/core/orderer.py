"""Ordering service: Fabric 1.2 baseline vs FastFabric Opt O-I / O-II.

Paper mapping (§III-B, §III-C):
  * Baseline: full marshaled transactions are published to Kafka; the
    consensus log replicates *all payload bytes*, and incoming proposals are
    handled one at a time per connection.
  * O-I  (separate metadata from data): only TransactionIDs enter consensus;
    payloads wait in a local store and are reassembled (ID -> payload join)
    when the ordered IDs come back.
  * O-II (pipelining): proposal admission (auth check + publish) is processed
    concurrently instead of serially.

TPU adaptation: the Kafka log is modeled as a crash-fault-tolerant totally
ordered log whose replication cost is a chain hash over everything published
(bytes-proportional, inherently sequential — a faithful stand-in for leader
serialization). Ordering itself is a deterministic interleave of client
streams (argsort of an ID hash), identical across configs so all configs
produce byte-identical blocks. Serial admission is a lax.scan over proposals;
O-II turns it into vmapped vector work (the VPU lane is the TPU analogue of
the goroutine pool). Reassembly under O-I is a vectorized hash join.

The multi-device version of O-I (ID-only all-gather vs full-payload
all-gather across the `data` mesh axis) lives in launch/fabric_step.py; this
module is the single-shard engine used by benchmarks and the end-to-end
example.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import crypto, hashing, types, unmarshal

U32 = jnp.uint32


@dataclasses.dataclass(frozen=True)
class OrdererConfig:
    """Feature flags. Fabric 1.2 = both False; FastFabric = both True."""

    separate_metadata: bool = True  # Opt O-I
    pipelined: bool = True  # Opt O-II
    block_size: int = 100

    @property
    def name(self) -> str:
        tags = []
        if self.separate_metadata:
            tags.append("O-I")
        if self.pipelined:
            tags.append("O-II")
        return "+".join(tags) if tags else "fabric-1.2"


class OrderedBlocks(NamedTuple):
    """Output of one ordering round: blocks of marshaled transactions."""

    wire: jnp.ndarray  # (n_blocks, block_size, WB) u8
    tx_ids: jnp.ndarray  # (n_blocks, block_size, 2) u32
    log_head: jnp.ndarray  # (2,) u32 — consensus log chain hash
    auth_ok: jnp.ndarray  # (N,) bool — per-proposal admission flag
    join_ok: jnp.ndarray  # (N,) bool — ID->payload reassembly hit, in order


# Registered clients (membership service provider table size).
N_REGISTERED = jnp.uint32(1 << 16)


def _admission(tx_id, client):
    """Client authorization at admission: membership + a keyed MAC stamp.

    Models the orderer's 'is this client allowed to submit' check: a
    registry membership test plus an admission MAC over the header. The MAC
    tag is *stamped into the published words* (the orderer signs what it
    forwards to consensus), which keeps the verification cost live in the
    dataflow. Returns (stamp (N,) u32, auth_ok (N,) bool).
    """
    r, s = crypto.endorser_keys(1)
    words = jnp.stack(
        [tx_id[..., 0], tx_id[..., 1], client.astype(U32)], axis=-1
    )
    tag = crypto.poly_mac(words.reshape(-1, 3), r[0], s[0])
    return tag.reshape(client.shape), client.astype(U32) < N_REGISTERED


def consensus_order(tx_ids: jnp.ndarray) -> jnp.ndarray:
    """Deterministic total order (N,) — argsort of an ID hash.

    Models the interleaving of concurrent client streams at the Kafka topic;
    deterministic so every config (and every replica) agrees on the order.
    """
    mix = hashing.hash_u32(tx_ids[:, 0] ^ hashing.hash_u32(tx_ids[:, 1]))
    return jnp.argsort(mix)


def _log_chain(head: jnp.ndarray, words: jnp.ndarray, *, serial: bool
               ) -> jnp.ndarray:
    """Replicate ``words`` (N, W) through the consensus log chain hash.

    ``serial=True`` processes one row at a time (baseline one-by-one
    admission); otherwise rows are hashed in parallel and folded in one
    sequential pass over per-row digests (pipelined admission still ends in
    a single leader append).
    """
    if serial:
        def step(h, row):
            d1 = hashing.hash_words(row[None, :], seed=h[0])[0]
            d2 = hashing.hash_words(row[None, :], seed=h[1])[0]
            return jnp.stack([d1, d2]), None

        head, _ = jax.lax.scan(step, head, words)
        return head
    digests = hashing.hash_words(words, seed=hashing.SEED_A)  # (N,) parallel

    def fold(h, d):
        return jnp.stack([hashing.combine(h[0], d), hashing.combine(h[1], d)]), None

    head, _ = jax.lax.scan(fold, head, digests)
    return head


def order_batch(
    wire: jnp.ndarray,
    tx_ids: jnp.ndarray,
    clients: jnp.ndarray,
    log_head: jnp.ndarray,
    cfg: OrdererConfig,
) -> OrderedBlocks:
    """Order one round of N proposals into N/block_size blocks.

    N must be a multiple of block_size (the driver pads the tail round).
    """
    n, wb = wire.shape
    if n % cfg.block_size:
        raise ValueError(f"round size {n} not a multiple of {cfg.block_size}")

    # --- Admission: auth check per proposal (serial vs pipelined). ---
    if cfg.pipelined:
        stamp, auth_ok = _admission(tx_ids, clients)  # vmapped lanes
    else:
        def step(_, x):
            tid, cl = x
            st, ok = _admission(tid[None], cl[None])
            return None, (st[0], ok[0])

        _, (stamp, auth_ok) = jax.lax.scan(step, None, (tx_ids, clients))

    # --- Publish to the consensus log (admission-stamped). ---
    words = jax.lax.bitcast_convert_type(
        wire.reshape(n, wb // 4, 4), U32
    ).reshape(n, wb // 4)
    if cfg.separate_metadata:
        # (N, 2): IDs only — O-I.
        published = jnp.stack([tx_ids[:, 0] ^ stamp, tx_ids[:, 1]], axis=1)
    else:
        published = words.at[:, 0].set(words[:, 0] ^ stamp)
    log_head = _log_chain(log_head, published, serial=not cfg.pipelined)

    # --- Consensus decides the order; reassemble ID -> payload (O-I). ---
    order = consensus_order(tx_ids)
    if cfg.separate_metadata:
        ordered_ids = tx_ids[order]
        join = hash_join(ordered_ids, tx_ids)  # the paper's reassembly step
        ordered_wire = wire[join.idx]
        # A reassembly miss must never ship a silently wrong payload: the tx
        # stays in its block slot (Fabric semantics) but its checksum word
        # is inverted, so the committer's syntactic stage flags it invalid
        # deterministically.
        cb = 4 * unmarshal.CHECKSUM_WORD
        check = ordered_wire[:, cb:cb + 4]
        ordered_wire = ordered_wire.at[:, cb:cb + 4].set(
            jnp.where(join.found[:, None], check, ~check)
        )
        join_ok = join.found
    else:
        ordered_wire = wire[order]
        ordered_ids = tx_ids[order]
        join_ok = jnp.ones((n,), bool)

    nb = n // cfg.block_size
    return OrderedBlocks(
        wire=ordered_wire.reshape(nb, cfg.block_size, wb),
        tx_ids=ordered_ids.reshape(nb, cfg.block_size, 2),
        log_head=log_head,
        auth_ok=auth_ok,
        join_ok=join_ok,
    )


class JoinResult(NamedTuple):
    idx: jnp.ndarray  # (N,) int32 into the store; slot 0 when not found
    found: jnp.ndarray  # (N,) bool — query ID present in the store


def hash_join(query_ids: jnp.ndarray, store_ids: jnp.ndarray) -> JoinResult:
    """Vectorized join: for each query ID find its row in ``store_ids``.

    Lexsort the store by the paired ID, then an exact lexicographic binary
    search (hashing.lex_searchsorted) locates each pair. The search is
    exact, so no run of equal ``id[0]`` values — however long (u32 birthday
    collisions are expected at ~100k-tx rounds) — can push a present pair
    outside a probe window. Misses are reported in ``found``, never as an
    arbitrary store row.
    """
    order = jnp.lexsort((store_ids[:, 1], store_ids[:, 0]))
    s_hi = store_ids[order, 0]
    s_lo = store_ids[order, 1]
    pos = hashing.lex_searchsorted(
        s_hi, s_lo, query_ids[:, 0], query_ids[:, 1]
    )
    sel = jnp.clip(pos, 0, s_hi.shape[0] - 1)
    found = (
        (s_hi[sel] == query_ids[:, 0])
        & (s_lo[sel] == query_ids[:, 1])
        & (pos < s_hi.shape[0])
    )
    return JoinResult(idx=order[sel].astype(jnp.int32), found=found)


@functools.partial(jax.jit, static_argnames=("cfg",))
def order_batch_jit(wire, tx_ids, clients, log_head, cfg: OrdererConfig):
    return order_batch(wire, tx_ids, clients, log_head, cfg)
