"""Hot-path program registry: what the contract analyzer AOT-lowers.

Each module that owns a jitted hot path self-registers a *builder* at
import time (``@register("name")``). A builder takes a
:class:`BuildContext` (mesh + small fabric dims + table sizing) and
returns a :class:`BuiltProgram`: the jit-wrapped callable plus the
abstract arguments to lower it with — NO workload runs, the analyzer
compiles the program ahead of time exactly the way the engine would
(same jit wrapper, same ``donate_argnums``) and inspects the artifact.

Import direction: this module imports nothing from the hot paths; the
hot paths import :func:`register` (cheap, jax-free at call time).
:func:`discover` imports the owning modules so their registrations run.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Callable, Optional

# Modules whose import registers their hot-path programs.
_ENTRY_MODULES = (
    "repro.launch.fabric_step",
    "repro.pipeline.engine_bridge",
    "repro.serving.engine",
)

_PROGRAMS: dict[str, "Registered"] = {}


@dataclasses.dataclass(frozen=True)
class BuildContext:
    """Sizing for the analyzed programs: small enough to compile fast in
    CI, structurally identical to production (same stages, same
    collectives, same commit scatter)."""

    mesh: object  # jax Mesh with ("data", "model") axes
    dims: object  # types.FabricDims (TEST_DIMS by default in the gate)
    b_loc: int = 8  # txs per model rank per block
    n_buckets: int = 256  # global bucket count (divisible by model ranks)
    slots: int = 8
    n_channels: int = 1


@dataclasses.dataclass
class BuiltProgram:
    """One AOT-lowerable hot-path program.

    ``fn`` must expose ``.lower(*args)`` (a ``jax.jit`` wrapper);
    ``args`` are abstract (ShapeDtypeStruct trees) or concrete arrays.
    ``donate_argnums`` mirrors what the live call site donates — the
    donation verifier checks the compiled alias table against it.
    ``nb_local``/``slots`` parameterize the table-shaped scatter count
    (None skips that check for programs without a commit scatter).
    """

    name: str
    fn: object
    args: tuple
    donate_argnums: tuple = ()
    nb_local: Optional[int] = None
    slots: Optional[int] = None
    meta: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class Registered:
    name: str
    builder: Callable[[BuildContext], BuiltProgram]
    description: str = ""


def register(name: str, *, description: str = ""):
    """Decorator: register ``builder(ctx) -> BuiltProgram`` under ``name``.

    Re-registration overwrites (module reloads in tests)."""

    def deco(builder):
        _PROGRAMS[name] = Registered(name, builder, description)
        return builder

    return deco


def discover() -> dict[str, Registered]:
    """Import every entry module (running their registrations) and return
    the registry, name-sorted."""
    for mod in _ENTRY_MODULES:
        importlib.import_module(mod)
    return dict(sorted(_PROGRAMS.items()))


def programs() -> dict[str, Registered]:
    """The registry as currently populated (no imports)."""
    return dict(sorted(_PROGRAMS.items()))


def get(name: str) -> Registered:
    if name not in _PROGRAMS:
        discover()
    return _PROGRAMS[name]
