"""Compiled-artifact checks: contract clauses over lowered programs.

Every check takes the program name, its effective contract (see
``contracts.for_program``) and the relevant artifact text, and returns a
list of :class:`Violation` — empty means the clause holds. The gate
composes them; tests seed one defect at a time and assert exactly the
intended clause flips.

Artifact sources per check:

  * collectives / wire bytes — the COMPILED post-SPMD HLO, trip-count
    corrected through ``launch/hlo_cost.analyze`` (a collective inside a
    scan counts trip times; that is the per-window truth fig11 reports).
  * table-shaped commit scatters — the PRE-optimization StableHLO
    (CPU XLA expands scatters into loops before the final HLO, TPU
    keeps them; StableHLO is backend-stable so the contract is too).
  * forbidden ops / dtype widening — the compiled HLO text (what will
    actually execute, after any jax-level dtype laundering).
  * donation aliasing — the compiled module's ``input_output_alias``
    table (absent entry for a donated parameter == XLA copied it).
"""

from __future__ import annotations

import dataclasses
import re

from repro.launch import hlo_cost


@dataclasses.dataclass(frozen=True)
class Violation:
    """One broken contract clause, named precisely enough that the gate
    message tells the reader which program and which clause to look at
    (and where to amend ``contracts.json`` if the change is meant)."""

    program: str
    clause: str  # e.g. "collectives.all-gather", "donation.aliasing"
    message: str

    def __str__(self) -> str:
        return f"{self.program}: [{self.clause}] {self.message}"


# ---------------------------------------------------------------------------
# Collective budgets
# ---------------------------------------------------------------------------


def check_collectives(name: str, contract: dict, analysis: dict
                      ) -> list[Violation]:
    """Trip-corrected per-type instruction counts vs the budget map.

    Types NOT named in the budget have budget 0 — a new collective kind
    sneaking into a hot path is a violation until the contract names it.
    Budgets are ceilings: a single-device lowering that elides its
    collectives passes the same contract the 8-rank lowering is held to.
    """
    budget = contract.get("collectives")
    out: list[Violation] = []
    if budget is None:
        return out
    for op, stats in sorted((analysis.get("collectives") or {}).items()):
        allowed = budget.get(op, 0)
        count = stats["count"]
        if count > allowed:
            out.append(Violation(
                name, f"collectives.{op}",
                f"{count:g} {op} instructions (trip-corrected), budget "
                f"{allowed} — amend contracts.json [programs.{name}."
                f"collectives.{op}] if this regression is intentional",
            ))
    max_wire = contract.get("max_wire_bytes")
    wire = analysis.get("collective_wire_bytes", 0.0)
    if max_wire is not None and wire > max_wire:
        out.append(Violation(
            name, "collectives.wire_bytes",
            f"{wire:.3e} collective wire bytes per device, budget "
            f"{max_wire:.3e}",
        ))
    return out


# ---------------------------------------------------------------------------
# Fused window-commit scatter (table-shaped StableHLO scatters)
# ---------------------------------------------------------------------------


def table_scatter_passes(stablehlo: str, nb_local: int, slots: int
                         ) -> float:
    """Commit scatter PASSES in a lowered fabric program: scatter ops
    whose result is a state-table plane — leading dims (nb_local, slots)
    or (C, nb_local, slots) with the vmapped channel dim — divided by
    the 3 planes (keys/versions/values) one fused pass writes. Counted
    on StableHLO, not final HLO (CPU XLA loop-expands scatters there).

    This was fig11's private ``_table_scatters``; it lives here now so
    the benchmark, the gate, and CI count one way.
    """
    n, pos = 0, 0
    while True:
        i = stablehlo.find('"stablehlo.scatter"', pos)
        if i < 0:
            return n / 3
        j = stablehlo.find("-> tensor<", i)
        if j >= 0:
            dims = stablehlo[j + 10: j + 64].split("x")
            d = []
            for x in dims[:4]:
                try:
                    d.append(int(x))
                except ValueError:
                    break
            if len(d) >= 2 and d[0] == nb_local and d[1] == slots:
                n += 1
            elif len(d) >= 3 and d[1] == nb_local and d[2] == slots:
                n += 1
        pos = i + 1


def check_commit_scatters(name: str, contract: dict, stablehlo: str,
                          nb_local: int, slots: int) -> list[Violation]:
    want = contract.get("commit_scatter_passes")
    if want is None:
        return []
    got = table_scatter_passes(stablehlo, nb_local, slots)
    if got != want:
        return [Violation(
            name, "commit_scatter_passes",
            f"{got:g} table-shaped scatter passes in the lowered program, "
            f"contract requires exactly {want} (the fused window commit "
            f"pays ONE pass regardless of pipeline depth)",
        )]
    return []


# ---------------------------------------------------------------------------
# Forbidden ops: host callbacks and friends
# ---------------------------------------------------------------------------

_CUSTOM_CALL_RE = re.compile(
    r"\bcustom-call\b.*custom_call_target=\"([^\"]+)\"")
# Callback-shaped custom-call targets (jax pure_callback / io_callback /
# debug prints lower to these on every backend).
_CALLBACK_TARGET_RE = re.compile(
    r"callback|xla_python|xla_ffi_python|py_func", re.IGNORECASE)
_HOST_TRANSFER_RE = re.compile(r"^\s*(?:ROOT\s+)?%[\w.\-]+\s*=.*?\s"
                               r"(infeed|outfeed)\(")


def check_forbidden_ops(name: str, contract: dict, hlo_text: str
                        ) -> list[Violation]:
    """No host-callback custom-calls, infeeds or outfeeds in a hot-path
    program: each one is a device->host->device round trip serializing
    the step. Benign backend custom-calls (oneDNN matmul, topk, ...) are
    NOT callbacks and pass; anything matching a callback target fails
    unless explicitly named in ``allowed_custom_calls``."""
    if not contract.get("forbid_host_callbacks", False):
        return []
    allowed = set(contract.get("allowed_custom_calls", []))
    out: list[Violation] = []
    seen: set[str] = set()
    for line in hlo_text.splitlines():
        m = _CUSTOM_CALL_RE.search(line)
        if m:
            target = m.group(1)
            if target in allowed or target in seen:
                continue
            if _CALLBACK_TARGET_RE.search(target):
                seen.add(target)
                out.append(Violation(
                    name, "forbidden_ops.host_callback",
                    f"host-callback custom-call "
                    f"target=\"{target}\" in the compiled program "
                    f"(pure_callback/io_callback on the hot path)",
                ))
            continue
        m = _HOST_TRANSFER_RE.match(line)
        if m and m.group(1) not in seen:
            seen.add(m.group(1))
            out.append(Violation(
                name, "forbidden_ops.host_transfer",
                f"{m.group(1)} instruction in the compiled program",
            ))
    return out


# ---------------------------------------------------------------------------
# Dtype widening
# ---------------------------------------------------------------------------


def _widened_dtypes(hlo_text: str, forbidden: list[str]) -> dict[str, int]:
    """Occurrences of forbidden dtypes as NON-SCALAR buffers. Scalar
    s64[] bookkeeping (loop counters, callback tokens) is XLA-internal
    and harmless; a widened ARRAY means real data-path cost (2x the
    bytes of the u32/f32 the fabric programs are built on)."""
    counts: dict[str, int] = {}
    for dt in forbidden:
        n = len(re.findall(rf"\b{re.escape(dt)}\[\d", hlo_text))
        if n:
            counts[dt] = n
    return counts


def check_dtypes(name: str, contract: dict, hlo_text: str
                 ) -> list[Violation]:
    forbidden = contract.get("forbidden_dtypes")
    if not forbidden:
        return []
    return [
        Violation(
            name, f"forbidden_dtypes.{dt}",
            f"{n} non-scalar {dt} buffer(s) in the compiled program "
            f"(dtype widening on the hot path)",
        )
        for dt, n in sorted(_widened_dtypes(hlo_text, forbidden).items())
    ]


# ---------------------------------------------------------------------------
# Donation / aliasing: the silent-copy detector
# ---------------------------------------------------------------------------

_ALIAS_ENTRY_RE = re.compile(r"\{[\d,\s]*\}:\s*\((\d+)\s*,")


def parse_aliased_params(hlo_text: str) -> set[int]:
    """Flat parameter numbers that alias an output, parsed from the
    compiled module header's ``input_output_alias={ {out}: (param, {..},
    kind), ... }`` table (brace-matched: entries nest braces)."""
    header = hlo_text.split("\n", 1)[0]
    start = header.find("input_output_alias={")
    if start < 0:
        return set()
    i = start + len("input_output_alias=")
    depth, end = 0, i
    for j in range(i, len(header)):
        if header[j] == "{":
            depth += 1
        elif header[j] == "}":
            depth -= 1
            if depth == 0:
                end = j
                break
    table = header[i + 1: end]
    return {int(p) for p in _ALIAS_ENTRY_RE.findall(table)}


def donated_param_ids(args, donate_argnums) -> list[int]:
    """Flat parameter indices covered by the donated argnums — jit
    flattens each argument's pytree into consecutive parameters.
    (Assumes no argument is pruned as unused; every registered hot path
    uses all of its inputs.)"""
    import jax

    donated: list[int] = []
    base = 0
    for i, a in enumerate(args):
        n = len(jax.tree_util.tree_leaves(a))
        if i in donate_argnums:
            donated.extend(range(base, base + n))
        base += n
    return donated


def check_donation(name: str, contract: dict, hlo_text: str,
                   donated: list[int]) -> list[Violation]:
    """Every donated parameter must appear in the compiled alias table;
    one that does not was silently COPIED — the donation is a no-op and
    the program pays a full extra table write per invocation. The
    contract's ``min_aliased_fraction`` (default 1.0) tolerates
    intentionally-unaliasable leaves if a program ever needs that."""
    don = contract.get("donation")
    if don is None:
        return []
    if not donated:
        return [Violation(
            name, "donation.missing",
            "contract expects donated inputs but the program donates "
            "nothing (donate_argnums dropped?)",
        )]
    aliased = parse_aliased_params(hlo_text)
    hit = [p for p in donated if p in aliased]
    frac = len(hit) / len(donated)
    want = float(don.get("min_aliased_fraction", 1.0))
    if frac < want:
        missing = [p for p in donated if p not in aliased]
        return [Violation(
            name, "donation.aliasing",
            f"only {len(hit)}/{len(donated)} donated parameters alias an "
            f"output (need >= {want:.0%}); parameters {missing} were "
            f"silently copied by XLA despite donation",
        )]
    return []


# ---------------------------------------------------------------------------
# Composition over one artifact
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Artifact:
    """Everything the static checks need about one compiled program."""

    name: str
    hlo_text: str  # compiled (post-SPMD, scheduled) HLO
    stablehlo_text: str  # pre-optimization lowering
    donated: list[int]  # flat donated parameter ids
    nb_local: int | None = None
    slots: int | None = None

    def analysis(self) -> dict:
        return hlo_cost.analyze(self.hlo_text)


def check_artifact(art: Artifact, contract: dict) -> tuple[dict, list[Violation]]:
    """Run every static clause in the contract over one artifact.
    Returns (measured summary, violations)."""
    analysis = art.analysis()
    measured = {
        "collectives": {
            op: v["count"] for op, v in (analysis["collectives"] or {}).items()
        },
        "collective_wire_bytes": analysis["collective_wire_bytes"],
        "donated_params": art.donated,
        "aliased_params": sorted(
            p for p in art.donated
            if p in parse_aliased_params(art.hlo_text)
        ),
    }
    out = check_collectives(art.name, contract, analysis)
    if art.nb_local is not None and art.slots is not None:
        measured["commit_scatter_passes"] = table_scatter_passes(
            art.stablehlo_text, art.nb_local, art.slots
        )
        out += check_commit_scatters(
            art.name, contract, art.stablehlo_text, art.nb_local, art.slots
        )
    out += check_forbidden_ops(art.name, contract, art.hlo_text)
    out += check_dtypes(art.name, contract, art.hlo_text)
    out += check_donation(art.name, contract, art.hlo_text, art.donated)
    return measured, out
