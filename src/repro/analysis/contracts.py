"""Loader for ``contracts.json`` — the committed program contracts.

One file is the single source of truth for every compiled-program
invariant: the gate (``repro.analysis.gate``), the fig11 benchmark's
inline fused-commit assert, and the CI artifact checks all read the
SAME budgets from here, so an intentional change (a new collective, a
shifted budget) is amended in exactly one reviewed place.

Layout (see ``contracts.json``)::

    {
      "defaults":  {... clauses applied to every program ...},
      "programs":  {"<name>": {... per-program clauses, override ...}},
      "retrace":   {"max_signatures": {"default": N, "<name>": M}},
      "lint":      {"forbidden_calls": [...], "allow": ["file.py:qual*"]}
    }

Per-program clauses:
  ``collectives``            — {type: max trip-corrected instruction
                               count}; types NOT listed are budget 0.
  ``max_wire_bytes``         — per-device collective wire-byte ceiling.
  ``commit_scatter_passes``  — exact table-shaped StableHLO scatter
                               passes (keys/versions/values = 1 pass).
  ``forbidden_dtypes``       — dtypes that may not appear as non-scalar
                               buffers in the compiled program.
  ``forbid_host_callbacks``  — no callback custom-calls / infeed /
                               outfeed in the compiled program.
  ``donation``               — {"min_aliased_fraction": f}: fraction of
                               donated parameters that must actually
                               alias an output (silent-copy detector).
"""

from __future__ import annotations

import json
import os
from functools import lru_cache

CONTRACTS_PATH = os.path.join(os.path.dirname(__file__), "contracts.json")


@lru_cache(maxsize=None)
def _load_cached(path: str, mtime: float) -> dict:
    with open(path) as f:
        return json.load(f)


def load(path: str | None = None) -> dict:
    p = path or CONTRACTS_PATH
    return _load_cached(p, os.path.getmtime(p))


def for_program(name: str, data: dict | None = None) -> dict:
    """Effective contract for one program: defaults overlaid with the
    program's own clauses. Unknown programs get the defaults (so a newly
    registered hot path is checked against the baseline rules until a
    contract is committed for it)."""
    data = data or load()
    merged = dict(data.get("defaults", {}))
    merged.update(data.get("programs", {}).get(name, {}))
    return merged


def program_names(data: dict | None = None) -> list[str]:
    data = data or load()
    return sorted(data.get("programs", {}))


def commit_scatter_passes(data: dict | None = None) -> int:
    """The fused window-commit budget shared by every fabric_step
    program — what fig11 and the CI artifact assert. Refuses to guess if
    the committed contracts ever disagree across fabric_step variants."""
    data = data or load()
    vals = {
        c["commit_scatter_passes"]
        for n, c in data.get("programs", {}).items()
        if n.startswith("fabric_step/") and "commit_scatter_passes" in c
    }
    if len(vals) != 1:
        raise ValueError(
            f"fabric_step commit_scatter_passes contracts disagree: {vals}"
        )
    return vals.pop()


def retrace_budget(name: str, data: dict | None = None) -> int:
    data = data or load()
    rt = data.get("retrace", {}).get("max_signatures", {})
    return int(rt.get(name, rt.get("default", 4)))
