"""Program-contract static analysis for the hot-path programs.

Performance invariants used to be enforced ad hoc (a ``commit_scatters``
assert in fig11, ``hlo.*`` gauges in the window committer); this package
turns each of them into a *declarative, committed contract* checked
against the compiled artifact of every registered hot-path program —
without running a workload:

  * :mod:`repro.analysis.registry`  — program registry: each jitted hot
    path (the fabric step per (depth, shards, channels), the stacked
    stats pass, the resize butterfly exchange, the serving decode step)
    self-registers a builder the analyzer AOT-lowers.
  * :mod:`repro.analysis.contracts` — loads ``contracts.json``, the one
    source of truth for budgets (fig11 and CI consume the same file).
  * :mod:`repro.analysis.checks`    — compiled-artifact checks:
    per-program collective budgets (count by type + wire bytes),
    forbidden-op scan (host-callback custom-calls, dtype widening to
    f64/s64/u64), the donation/aliasing verifier (a donated argument
    that does not alias was silently copied), and the table-shaped
    StableHLO scatter counter (the fused window-commit contract).
  * :mod:`repro.analysis.retrace`   — runtime-boundary jit cache-miss
    auditor: wraps registered entry points and fails when a round
    triggers a trace outside the allowed key set (a genuinely new
    shape/sharding signature: first window, post-resize window).
  * :mod:`repro.analysis.lint`      — AST-level source lint flagging
    ``block_until_ready`` / ``device_get`` / ``pure_callback`` /
    ``io_callback`` outside the allowlisted phase-edge sites.
  * :mod:`repro.analysis.gate`      — ``python -m repro.analysis.gate``:
    per-program report, nonzero exit on any violation; CI runs it next
    to ``benchmarks/perf_gate.py`` and uploads the JSON report.

This is the machine-checked prerequisite for ROADMAP item 3 (async
double-buffered dispatch + compiled-program cache): donation, aliasing
and retrace behavior must be verified before windows overlap.
"""

from .checks import Violation  # noqa: F401
from .registry import BuildContext, BuiltProgram, register  # noqa: F401

__all__ = ["Violation", "BuildContext", "BuiltProgram", "register"]
