"""The contracts gate: ``python -m repro.analysis.gate``.

Runs the whole analyzer and exits nonzero on ANY violation:

  1. **static** — discover the registered hot-path programs
     (:mod:`repro.analysis.registry`), AOT-lower and compile each at a
     small but structurally faithful sizing, and check the artifact
     against its committed contract (collective budgets, fused-commit
     scatter count, forbidden ops, dtype widening, donation aliasing).
     No workload runs; this is pure compile-and-inspect.
  2. **retrace** — drive a small LIVE workload (windows, a stats read,
     a resize epoch, more windows) through a ``MeshWindowCommitter``
     with the jit cache-miss auditor attached; any trace outside the
     allowed key set (first window, sharded-layout window, post-resize
     window) fails.
  3. **lint** — AST scan of ``src/repro/`` for host-sync calls outside
     the allowlisted phase-edge sites.

``--json PATH`` writes the full per-program report (CI uploads it next
to the bench artifacts). Budgets are ceilings, so the same contracts
pass at 1 CPU device (collectives elided) and at 8 forced host devices
(real collectives) — CI runs both.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys

import jax

from repro.analysis import checks, contracts, lint, registry
from repro.analysis.retrace import RetraceAuditor
from repro.core import types


def make_mesh():
    """(1, M) mesh with M the largest power of two <= device count —
    the same shape fig11 sweeps; data=1 keeps every registered channel
    count valid."""
    n = len(jax.devices())
    m = 1 << (n.bit_length() - 1)
    return jax.make_mesh((1, m), ("data", "model"))


def build_context(mesh=None) -> registry.BuildContext:
    return registry.BuildContext(
        mesh=mesh if mesh is not None else make_mesh(),
        dims=types.TEST_DIMS,
    )


# ---------------------------------------------------------------------------
# 1. Static: compile every registered program, check its artifact
# ---------------------------------------------------------------------------


def run_static(ctx: registry.BuildContext, only: set | None = None
               ) -> tuple[dict, list[checks.Violation]]:
    report: dict = {}
    viols: list[checks.Violation] = []
    for name, reg in registry.discover().items():
        if only is not None and name not in only:
            continue
        built = reg.builder(ctx)
        lowered = built.fn.lower(*built.args)
        stablehlo = lowered.as_text()
        hlo = lowered.compile().as_text()
        donated = checks.donated_param_ids(built.args, built.donate_argnums)
        art = checks.Artifact(
            name=name, hlo_text=hlo, stablehlo_text=stablehlo,
            donated=donated, nb_local=built.nb_local, slots=built.slots,
        )
        measured, v = checks.check_artifact(art, contracts.for_program(name))
        report[name] = {
            "description": reg.description,
            "measured": measured,
            "violations": [str(x) for x in v],
        }
        viols += v
    return report, viols


# ---------------------------------------------------------------------------
# 2. Retrace: a small live workload under the cache-miss auditor
# ---------------------------------------------------------------------------


def run_retrace(mesh, dims) -> RetraceAuditor:
    """Windows -> stats -> resize -> windows on an audited committer.

    Every jit in this sequence is allowed its enumerable signatures
    (fresh state, sharded-output layout, one per resize) and nothing
    else; an accidental per-round retrace anywhere in the committer
    surfaces here as a violation.
    """
    import jax.numpy as jnp

    from repro.launch import fabric_step as fs
    from repro.pipeline.engine_bridge import MeshWindowCommitter

    auditor = RetraceAuditor()
    msize = mesh.shape["model"]
    cfg = dataclasses.replace(fs.FASTFABRIC_SHARDED_STEP, pipeline_depth=2)
    nb = 16 * msize
    wc = MeshWindowCommitter(dims, cfg, mesh, n_buckets=nb, slots=4)
    wc.attach_retrace_auditor(auditor)
    d, b_round = 2, 4 * msize
    wire = jnp.zeros((1, d, b_round, 4 * dims.payload_words), jnp.uint8)
    ids = jnp.zeros((1, d, b_round, 2), jnp.uint32)
    for _ in range(3):  # trace, sharded-layout trace, cache hit
        wc.commit_windows(wire, ids)
    wc.shard_stats([0])
    wc.shard_stats([0])  # second read must hit the stats cache
    wc.resize(2 * nb)  # epoch: butterfly exchange + new table layout
    for _ in range(2):  # post-resize trace(s), then steady state
        wc.commit_windows(wire, ids)
    wc.block_until_ready()
    return auditor


# ---------------------------------------------------------------------------
# 3. Lint
# ---------------------------------------------------------------------------


def run_lint() -> list[checks.Violation]:
    allow = contracts.load().get("lint", {}).get("allow", [])
    return lint.lint_tree(lint.default_root(), allow)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default=None,
                    help="write the full report as JSON to this path")
    ap.add_argument("--only", nargs="+", default=None,
                    help="restrict the static pass to these program names")
    ap.add_argument("--skip-retrace", action="store_true")
    ap.add_argument("--skip-lint", action="store_true")
    ap.add_argument("--list", action="store_true",
                    help="list registered programs and exit")
    args = ap.parse_args(argv)

    if args.list:
        for name, reg in registry.discover().items():
            print(f"{name:32s} {reg.description}")
        return 0

    mesh = make_mesh()
    ctx = build_context(mesh)
    report = {
        "n_devices": len(jax.devices()),
        "mesh": dict(mesh.shape),
        "programs": {},
        "retrace": {},
        "lint": [],
    }
    all_viols: list[checks.Violation] = []

    only = set(args.only) if args.only else None
    report["programs"], viols = run_static(ctx, only)
    all_viols += viols
    for name, rec in report["programs"].items():
        ok = "ok " if not rec["violations"] else "FAIL"
        m = rec["measured"]
        colls = ",".join(f"{k}={v:g}" for k, v in
                         sorted(m["collectives"].items())) or "-"
        csp = m.get("commit_scatter_passes")
        print(f"[{ok}] {name:28s} collectives: {colls:40s}"
              f" aliased {len(m['aliased_params'])}/{len(m['donated_params'])}"
              + (f"  commit_passes={csp:g}" if csp is not None else ""))

    if not args.skip_retrace:
        auditor = run_retrace(mesh, types.TEST_DIMS)
        report["retrace"] = auditor.report()
        all_viols += auditor.violations
        for name, rec in report["retrace"].items():
            ok = "ok " if not rec["violations"] else "FAIL"
            print(f"[{ok}] retrace {name:28s} calls={rec['calls']}"
                  f" traces={rec['traces']} signatures={rec['signatures']}")

    if not args.skip_lint:
        lviols = run_lint()
        report["lint"] = [str(v) for v in lviols]
        all_viols += lviols
        print(f"[{'ok ' if not lviols else 'FAIL'}] lint src/repro: "
              f"{len(lviols)} host-sync call(s) outside allowlisted sites")

    report["violations"] = [str(v) for v in all_viols]
    report["ok"] = not all_viols
    if args.json:
        import os

        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(report, f, indent=1)
    if all_viols:
        print(f"\n{len(all_viols)} contract violation(s):", file=sys.stderr)
        for v in all_viols:
            print(f"  {v}", file=sys.stderr)
        return 1
    print(f"\nall contracts hold "
          f"({len(report['programs'])} programs, "
          f"{len(report['retrace'])} audited entry points)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
