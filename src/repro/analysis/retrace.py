"""Runtime-boundary retrace detection: the jit cache-miss auditor.

A hot-path program is allowed to trace exactly once per *signature* —
the (shape, dtype, sharding) tuple of its arguments. Legitimate new
signatures are rare and enumerable: the first window after process
start, the second window when the step's sharded output layout replaces
the fresh unsharded input layout, and the first window after a resize
epoch changes the table shape. Anything else — a cache eviction, a
non-hashable static argument churning, a host value sneaking into the
trace — silently re-pays full trace+compile EVERY round and shows up
only as a vague TPS drift. The auditor makes it a hard failure:

  * a trace on an ALREADY-SEEN signature  -> ``retrace.recompiled``
  * more distinct signatures than the contract budget
                                          -> ``retrace.signature_churn``

Wiring: ``MeshWindowCommitter.attach_retrace_auditor(auditor)`` routes
every jit the committer builds (window steps, resize exchange, stats
pass) through :meth:`RetraceAuditor.wrap`; the gate drives a small live
workload through it (windows + a resize + stats reads) and folds any
violations into the report. The wrapper counts REAL traces (the python
body runs only while jax traces), so it cannot miss a retrace or
false-positive on a cache hit.
"""

from __future__ import annotations

import dataclasses

import jax

from repro.analysis.checks import Violation


def signature(args: tuple, kwargs: dict | None = None) -> str:
    """Stable trace-cache key of a call: shapes + dtypes + shardings of
    array leaves, repr of aux structure and non-array leaves. Includes
    sharding because jit retraces when a committed layout changes (the
    fresh-state -> mesh-sharded-output transition on window 2 is an
    ALLOWED new signature, not a recompile of an old one)."""

    def leaf(x):
        shp = getattr(x, "shape", None)
        if shp is None:
            return repr(x)
        dt = getattr(x, "dtype", "?")
        sh = getattr(x, "sharding", None)
        return f"{dt}{tuple(shp)}@{sh}"

    leaves, treedef = jax.tree_util.tree_flatten((args, kwargs or {}))
    return f"{treedef}|" + ";".join(leaf(x) for x in leaves)


@dataclasses.dataclass
class ProgramAudit:
    """Per-program trace history."""

    name: str
    traces: int = 0  # total traces of the wrapped python body
    calls: int = 0
    seen: dict = dataclasses.field(default_factory=dict)  # sig -> traces
    violations: list = dataclasses.field(default_factory=list)


class RetraceAuditor:
    """Wraps hot-path entry points; records and polices every trace."""

    def __init__(self, max_signatures: int | dict | None = None):
        # int: one budget for all programs; dict: per-name with
        # "default"; None: defer to contracts.retrace_budget at check().
        self._max_signatures = max_signatures
        self.programs: dict[str, ProgramAudit] = {}

    def _budget(self, name: str) -> int:
        ms = self._max_signatures
        if isinstance(ms, dict):
            return int(ms.get(name, ms.get("default", 4)))
        if ms is None:
            from repro.analysis import contracts

            return contracts.retrace_budget(name)
        return int(ms)

    def wrap(self, name: str, fn, **jit_kwargs):
        """``jax.jit(fn, **jit_kwargs)`` with trace accounting. The
        returned callable forwards ``.lower`` (AOT lowering retraces
        outside any audited call and is not policed)."""
        rec = self.programs.setdefault(name, ProgramAudit(name))

        def traced(*a, **k):
            rec.traces += 1
            return fn(*a, **k)

        jf = jax.jit(traced, **jit_kwargs)

        def audited(*args, **kwargs):
            sig = signature(args, kwargs)
            before = rec.traces
            out = jf(*args, **kwargs)
            rec.calls += 1
            if rec.traces > before:
                self._on_trace(rec, sig)
            return out

        audited.lower = jf.lower
        audited._audit = rec
        audited._jitted = jf
        return audited

    def _on_trace(self, rec: ProgramAudit, sig: str) -> None:
        if sig in rec.seen:
            rec.seen[sig] += 1
            rec.violations.append(Violation(
                rec.name, "retrace.recompiled",
                f"call #{rec.calls} re-traced an already-compiled "
                f"signature (trace {rec.seen[sig]} of {sig[:120]}...): "
                "cache eviction or a value outside the allowed key set "
                "is forcing a trace per round",
            ))
            return
        rec.seen[sig] = 1
        budget = self._budget(rec.name)
        if len(rec.seen) > budget:
            rec.violations.append(Violation(
                rec.name, "retrace.signature_churn",
                f"{len(rec.seen)} distinct trace signatures, budget "
                f"{budget} (allowed: first window, sharded-layout "
                "window, one per resize epoch) — something varies a "
                "shape or sharding every round",
            ))

    @property
    def violations(self) -> list[Violation]:
        return [v for rec in self.programs.values() for v in rec.violations]

    def report(self) -> dict:
        return {
            name: {
                "calls": rec.calls,
                "traces": rec.traces,
                "signatures": len(rec.seen),
                "violations": [str(v) for v in rec.violations],
            }
            for name, rec in sorted(self.programs.items())
        }
