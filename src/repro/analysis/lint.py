"""AST-level source lint: host-sync calls outside phase edges.

``jax.block_until_ready``, ``jax.device_get``, ``.block_until_ready()``,
``pure_callback`` and ``io_callback`` are phase-EDGE operations: they
belong where a window closes, a snapshot is cut, or a benchmark stops a
clock. Inside anything the engine calls per round they serialize the
device pipeline. The lint walks every file under ``src/repro/`` and
flags each call site whose enclosing qualname is not covered by the
``lint.allow`` patterns in ``contracts.json`` (fnmatch on
``relpath:qualname``, e.g. ``obs/trace.py:*`` or
``pipeline/engine_bridge.py:MeshWindowCommitter.resize``).

This is a source-level complement to the compiled-artifact callback
scan: the HLO check catches a callback that made it INTO a program; the
lint catches host syncs BETWEEN programs, which never lower at all.
"""

from __future__ import annotations

import ast
import fnmatch
import os

from repro.analysis.checks import Violation

# Call names that pin the device stream to the host.
_SYNC_ATTRS = {"block_until_ready", "device_get"}
_CALLBACK_NAMES = {"pure_callback", "io_callback"}


def _call_name(node: ast.Call) -> str | None:
    """The interesting tail of the called expression, or None."""
    f = node.func
    if isinstance(f, ast.Attribute):
        if f.attr in _SYNC_ATTRS or f.attr in _CALLBACK_NAMES:
            return f.attr
        return None
    if isinstance(f, ast.Name):
        if f.id in _CALLBACK_NAMES or f.id in _SYNC_ATTRS:
            return f.id
    return None


class _Walker(ast.NodeVisitor):
    """Collects (lineno, call, qualname) for every flagged call."""

    def __init__(self):
        self.stack: list[str] = []
        self.hits: list[tuple[int, str, str]] = []

    def _walk_scope(self, node, name: str):
        self.stack.append(name)
        self.generic_visit(node)
        self.stack.pop()

    def visit_ClassDef(self, node):
        self._walk_scope(node, node.name)

    def visit_FunctionDef(self, node):
        self._walk_scope(node, node.name)

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Call(self, node):
        name = _call_name(node)
        if name is not None:
            qual = ".".join(self.stack) or "<module>"
            self.hits.append((node.lineno, name, qual))
        self.generic_visit(node)


def lint_file(path: str, rel: str, allow: list[str]) -> list[Violation]:
    with open(path) as f:
        src = f.read()
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        return [Violation(rel, "lint.syntax", f"unparseable source: {e}")]
    w = _Walker()
    w.visit(tree)
    out: list[Violation] = []
    for lineno, call, qual in w.hits:
        site = f"{rel}:{qual}"
        if any(fnmatch.fnmatch(site, pat) for pat in allow):
            continue
        out.append(Violation(
            rel, f"lint.{call}",
            f"{call} at line {lineno} in {qual} — host sync outside the "
            f"allowlisted phase-edge sites; add '{site}' to contracts.json "
            f"[lint.allow] only if this site really is a phase edge",
        ))
    return out


def lint_tree(root: str, allow: list[str]) -> list[Violation]:
    """Lint every ``.py`` under ``root`` (skipping __pycache__)."""
    out: list[Violation] = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = sorted(d for d in dirnames if d != "__pycache__")
        for fn in sorted(filenames):
            if not fn.endswith(".py"):
                continue
            path = os.path.join(dirpath, fn)
            rel = os.path.relpath(path, root).replace(os.sep, "/")
            out.extend(lint_file(path, rel, allow))
    return out


def default_root() -> str:
    """``src/repro`` as installed — the package directory itself."""
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
