"""Shard-aware world-state snapshots: per-shard files + a manifest.

A snapshot freezes the peer's hash-table world state (core/world_state.py)
*as of* a block number, together with the authentication heads current at
that block (ledger chain hash, journal head, journal re-anchor head). The
elastic sharded state made the old one-``HashState``-per-file layout a
scaling bug — recovery of a sharded peer had to materialize the full table
on one host — so persistence is now:

  * ``shard_XXXXXXXX_MMMM.npz``  — ONE bucket shard's arrays (the high-bit
    partition of world_state.split_table), written first;
  * ``manifest_XXXXXXXX.npz``    — the commitment over all shards: layout
    (n_buckets/slots/value_width/n_shards), per-shard digests, the
    digest-tree head (world_state.shard_digest_tree), the XOR-fold
    state digest, the heads, and the STICKY overflow bitmask — written
    LAST, tmp-file + rename.

The manifest-last write order makes the whole snapshot atomic: a torn save
leaves shard files without a manifest, and :func:`latest` only considers
blocks whose manifest loads AND whose shard files are all present — a torn
snapshot is never selected. Foreign files in the directory are ignored by
every listing/GC path (strict filename patterns), and :func:`gc` drops a
block's manifest BEFORE its shard files so no reader ever sees a manifest
with missing shards.

Integrity: per-shard digests are recomputed over the loaded arrays
(``verify`` / ``verify_shard``), the tree head over the shard digests, and
the XOR decomposition ties them to the full-table digest — any tampering
with persisted arrays is detected before recovery replays on top of them.
Persisting the overflow bitmask closes the ROADMAP hole where an
overflowed peer that snapshotted and restarted came back reporting
healthy (the dropped inserts are not derivable from the table).
"""

from __future__ import annotations

import os
import re
import time
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import world_state as ws
from repro.obs.metrics import NULL_REGISTRY

_MANIFEST_RE = re.compile(r"^manifest_(\d{8})\.npz$")
_SHARD_RE = re.compile(r"^shard_(\d{8})_(\d{4})\.npz$")


class Manifest(NamedTuple):
    """The snapshot commitment: layout + digests + heads + health flag."""

    block_no: int
    journal_head: np.ndarray  # (2,) u32 — journal head after block_no
    ledger_head: np.ndarray  # (2,) u32 — chain hash after block_no
    reanchor_head: np.ndarray  # (2,) u32 — journal re-anchor chain head
    state_digest: np.ndarray  # (2,) u32 — XOR-fold full-table digest
    n_buckets: int  # GLOBAL bucket count at block_no (resize epochs vary it)
    slots: int
    value_width: int
    n_shards: int
    shard_digests: np.ndarray  # (M, 2) u32 — per-shard content digests
    tree_head: np.ndarray  # (2,) u32 — shard_digest_tree(shard_digests)
    overflow_bits: int  # sticky per-shard overflow bitmask (bit m ==
    # shard m filled) — persisted so the flag survives a restart

    @property
    def overflow(self) -> bool:
        """Health flag: any shard ever overflowed."""
        return bool(self.overflow_bits)


class ShardPart(NamedTuple):
    """One bucket shard's arrays (shard m owns buckets [m*NB/M, (m+1)*NB/M))."""

    shard: int
    keys: np.ndarray  # (NB/M, S, 2) u32
    versions: np.ndarray  # (NB/M, S) u32
    values: np.ndarray  # (NB/M, S, VW) u32


class Snapshot(NamedTuple):
    """A full snapshot held in memory: manifest + every shard part.

    Sharded recovery paths should prefer :func:`load_manifest` +
    :func:`load_shard` (one part per host); this merged view serves the
    single-host engine and the verification oracles.
    """

    manifest: Manifest
    shards: tuple  # tuple[ShardPart, ...], in shard order

    @property
    def block_no(self) -> int:
        return self.manifest.block_no

    @property
    def journal_head(self) -> np.ndarray:
        return self.manifest.journal_head

    @property
    def ledger_head(self) -> np.ndarray:
        return self.manifest.ledger_head

    @property
    def state_digest(self) -> np.ndarray:
        return self.manifest.state_digest


def take(state: ws.HashState, *, block_no: int, journal_head, ledger_head,
         n_shards: int = 1, overflow_bits: int = 0,
         reanchor_head=None) -> Snapshot:
    """Dump ``state`` to host as ``n_shards`` parts + manifest (the commit
    path is not blocked: callers run this between rounds / off the timed
    window). ``overflow_bits`` is the peer's sticky per-shard overflow
    bitmask — persisted so a restarted peer still reports unhealthy."""
    keys = np.asarray(jax.device_get(state.keys))
    vers = np.asarray(jax.device_get(state.versions))
    vals = np.asarray(jax.device_get(state.values))
    sk, sv, sva = ws.split_table(keys, vers, vals, n_shards)
    parts, digests = [], []
    for m in range(n_shards):
        parts.append(ShardPart(shard=m, keys=sk[m], versions=sv[m],
                               values=sva[m]))
        digests.append(np.asarray(ws.state_digest(
            ws.HashState(jnp.asarray(sk[m]), jnp.asarray(sv[m]),
                         jnp.asarray(sva[m]))
        )))
    shard_digests = np.stack(digests).astype(np.uint32)
    tree = np.asarray(ws.shard_digest_tree(jnp.asarray(shard_digests)))
    # XOR decomposition: full-table digest without a second full pass.
    full = np.bitwise_xor.reduce(shard_digests, axis=0)
    manifest = Manifest(
        block_no=int(block_no),
        journal_head=np.asarray(
            jax.device_get(journal_head)).astype(np.uint32),
        ledger_head=np.asarray(
            jax.device_get(ledger_head)).astype(np.uint32),
        reanchor_head=(np.zeros(2, np.uint32) if reanchor_head is None
                       else np.asarray(reanchor_head).astype(np.uint32)),
        state_digest=full,
        n_buckets=int(keys.shape[0]),
        slots=int(keys.shape[1]),
        value_width=int(vals.shape[2]),
        n_shards=int(n_shards),
        shard_digests=shard_digests,
        tree_head=tree,
        overflow_bits=int(overflow_bits),
    )
    return Snapshot(manifest=manifest, shards=tuple(parts))


def to_state(snap: Snapshot) -> ws.HashState:
    """Re-place the merged snapshot arrays on device (single-host view;
    concatenating the shard parts in order IS the high-bit partition)."""
    return ws.HashState(
        keys=jnp.asarray(np.concatenate([p.keys for p in snap.shards])),
        versions=jnp.asarray(
            np.concatenate([p.versions for p in snap.shards])),
        values=jnp.asarray(np.concatenate([p.values for p in snap.shards])),
    )


def verify_shard(manifest: Manifest, part: ShardPart) -> bool:
    """Recompute one shard's digest against the manifest."""
    got = np.asarray(ws.state_digest(ws.HashState(
        jnp.asarray(part.keys), jnp.asarray(part.versions),
        jnp.asarray(part.values),
    )))
    return bool(np.array_equal(got, manifest.shard_digests[part.shard]))


def verify(snap: Snapshot) -> bool:
    """Full verification: every shard digest, the tree head, and the XOR
    decomposition down to the full-table digest."""
    man = snap.manifest
    if len(snap.shards) != man.n_shards:
        return False
    if not all(verify_shard(man, p) for p in snap.shards):
        return False
    tree = np.asarray(
        ws.shard_digest_tree(jnp.asarray(man.shard_digests)))
    if not np.array_equal(tree, man.tree_head):
        return False
    full = np.bitwise_xor.reduce(man.shard_digests, axis=0)
    return bool(np.array_equal(full, man.state_digest))


# ---------------------------------------------------------------------------
# Persistence: shard files first, manifest last (atomic unit).
# ---------------------------------------------------------------------------


def path_for(directory: str, block_no: int) -> str:
    return os.path.join(directory, f"manifest_{block_no:08d}.npz")


def shard_path_for(directory: str, block_no: int, shard: int) -> str:
    return os.path.join(directory, f"shard_{block_no:08d}_{shard:04d}.npz")


def _atomic_savez(path: str, **arrays) -> None:
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
    os.replace(tmp, path)


def save(directory: str, snap: Snapshot, *, registry=None) -> str:
    """Persist: every shard part (tmp + rename each), THEN the manifest.
    Until the manifest lands the snapshot does not exist to readers."""
    reg = registry if registry is not None else NULL_REGISTRY
    t0 = time.perf_counter()
    os.makedirs(directory, exist_ok=True)
    man = snap.manifest
    nbytes = 0
    for part in snap.shards:
        nbytes += part.keys.nbytes + part.versions.nbytes + part.values.nbytes
        _atomic_savez(
            shard_path_for(directory, man.block_no, part.shard),
            shard=np.uint32(part.shard),
            block_no=np.int64(man.block_no),
            keys=part.keys, versions=part.versions, values=part.values,
        )
    final = path_for(directory, man.block_no)
    _atomic_savez(
        final,
        block_no=np.int64(man.block_no),
        journal_head=man.journal_head,
        ledger_head=man.ledger_head,
        reanchor_head=man.reanchor_head,
        state_digest=man.state_digest,
        n_buckets=np.uint32(man.n_buckets),
        slots=np.uint32(man.slots),
        value_width=np.uint32(man.value_width),
        n_shards=np.uint32(man.n_shards),
        shard_digests=man.shard_digests,
        tree_head=man.tree_head,
        overflow_bits=np.uint64(man.overflow_bits),
    )
    reg.counter("snapshot.saves").inc()
    reg.counter("snapshot.bytes").inc(nbytes)
    reg.histogram("snapshot.save.latency").record(time.perf_counter() - t0)
    return final


def load_manifest(path: str) -> Manifest:
    with np.load(path) as z:
        bits = int(z["overflow_bits"])
        return Manifest(
            block_no=int(z["block_no"]),
            journal_head=z["journal_head"],
            ledger_head=z["ledger_head"],
            reanchor_head=z["reanchor_head"],
            state_digest=z["state_digest"],
            n_buckets=int(z["n_buckets"]),
            slots=int(z["slots"]),
            value_width=int(z["value_width"]),
            n_shards=int(z["n_shards"]),
            shard_digests=z["shard_digests"],
            tree_head=z["tree_head"],
            overflow_bits=bits,
        )


def load_shard(directory: str, block_no: int, shard: int) -> ShardPart:
    """One shard's arrays — the sharded-recovery path loads ONLY the parts
    it needs, never the whole table."""
    with np.load(shard_path_for(directory, block_no, shard)) as z:
        return ShardPart(shard=int(z["shard"]), keys=z["keys"],
                         versions=z["versions"], values=z["values"])


def load(directory: str, block_no: int | None = None, *,
         registry=None) -> Snapshot:
    """Load manifest + every shard part (single-host view). With no
    ``block_no``, loads the newest complete snapshot."""
    reg = registry if registry is not None else NULL_REGISTRY
    t0 = time.perf_counter()
    if block_no is None:
        blocks = list_blocks(directory)
        if not blocks:
            raise FileNotFoundError(f"no complete snapshot in {directory}")
        block_no = blocks[-1]
    man = load_manifest(path_for(directory, block_no))
    parts = tuple(
        load_shard(directory, block_no, m) for m in range(man.n_shards)
    )
    reg.counter("snapshot.loads").inc()
    reg.histogram("snapshot.load.latency").record(time.perf_counter() - t0)
    return Snapshot(manifest=man, shards=parts)


def _complete(directory: str, block_no: int) -> bool:
    """A snapshot is complete iff its manifest loads and every shard file
    it names exists — the selection rule that makes torn saves invisible."""
    try:
        man = load_manifest(path_for(directory, block_no))
    except Exception:
        return False
    return all(
        os.path.exists(shard_path_for(directory, block_no, m))
        for m in range(man.n_shards)
    )


def list_blocks(directory: str) -> list[int]:
    """Block numbers of COMPLETE snapshots, ascending. Foreign files (and
    torn manifests / missing shard parts) are ignored, never errors."""
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        m = _MANIFEST_RE.match(name)
        if m and _complete(directory, int(m.group(1))):
            out.append(int(m.group(1)))
    return sorted(out)


def latest(directory: str) -> Snapshot | None:
    blocks = list_blocks(directory)
    return load(directory, blocks[-1]) if blocks else None


def latest_manifest(directory: str) -> Manifest | None:
    blocks = list_blocks(directory)
    return load_manifest(path_for(directory, blocks[-1])) if blocks else None


def gc(directory: str, *, keep: int = 2, registry=None) -> None:
    """Drop all but the newest ``keep`` complete snapshots, manifest+shards
    as a unit: the manifest goes FIRST (the snapshot stops existing), then
    its shard files. Shard files orphaned by earlier torn GCs of dropped
    blocks are swept too; files that match neither pattern are foreign and
    untouched, and parts of a save still in flight (block newer than every
    manifest) are preserved."""
    if not os.path.isdir(directory):
        return
    reg = registry if registry is not None else NULL_REGISTRY
    t0 = time.perf_counter()
    blocks = list_blocks(directory)
    keep_set = set(blocks[-keep:]) if keep else set()
    newest = blocks[-1] if blocks else -1
    dropped = 0
    # Manifests first.
    for name in sorted(os.listdir(directory)):
        m = _MANIFEST_RE.match(name)
        if m and int(m.group(1)) not in keep_set:
            _rm(os.path.join(directory, name))
            dropped += 1
    # Then shard files of dropped/orphaned blocks (an in-flight save has a
    # block number past the newest manifest — leave it alone).
    for name in sorted(os.listdir(directory)):
        m = _SHARD_RE.match(name)
        if m and int(m.group(1)) not in keep_set and int(m.group(1)) <= newest:
            _rm(os.path.join(directory, name))
    if dropped:
        reg.counter("snapshot.gc.dropped").inc(dropped)
        reg.histogram("snapshot.gc.latency").record(time.perf_counter() - t0)


def _rm(path: str) -> None:
    try:
        os.remove(path)
    except OSError:
        pass
