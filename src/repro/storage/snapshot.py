"""Compact world-state snapshots: device→host dump + content digest.

A snapshot freezes the peer's hash-table world state (core/world_state.py)
*as of* a block number, together with the two authentication heads current
at that block (ledger chain hash, journal head). Persistence is one
``snapshot_XXXXXXXX.npz`` per snapshot (the BlockStore spill pattern),
published atomically via tmp-file + rename.

Integrity: ``state_digest`` is the order-independent entry digest from
``world_state.state_digest`` recomputed over the dumped arrays —
``verify`` re-derives it, so any tampering with the persisted arrays is
detected before recovery replays on top of them.
"""

from __future__ import annotations

import os
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import world_state as ws


class Snapshot(NamedTuple):
    """World state at ``block_no`` (the last applied block), host-side."""

    block_no: int
    journal_head: np.ndarray  # (2,) u32 — journal head after block_no
    ledger_head: np.ndarray  # (2,) u32 — chain hash after block_no
    state_digest: np.ndarray  # (2,) u32 — world_state.state_digest
    keys: np.ndarray  # (NB, S, 2) u32
    versions: np.ndarray  # (NB, S) u32
    values: np.ndarray  # (NB, S, VW) u32


def take(state: ws.HashState, *, block_no: int, journal_head,
         ledger_head) -> Snapshot:
    """Dump ``state`` to host with its digest (the commit path is not
    blocked: callers run this between rounds / off the timed window)."""
    digest = np.asarray(jax.device_get(ws.state_digest(state)))
    return Snapshot(
        block_no=int(block_no),
        journal_head=np.asarray(jax.device_get(journal_head)).astype(np.uint32),
        ledger_head=np.asarray(jax.device_get(ledger_head)).astype(np.uint32),
        state_digest=digest,
        keys=np.asarray(jax.device_get(state.keys)),
        versions=np.asarray(jax.device_get(state.versions)),
        values=np.asarray(jax.device_get(state.values)),
    )


def to_state(snap: Snapshot) -> ws.HashState:
    """Re-place the snapshot arrays on device."""
    return ws.HashState(
        keys=jnp.asarray(snap.keys),
        versions=jnp.asarray(snap.versions),
        values=jnp.asarray(snap.values),
    )


def verify(snap: Snapshot) -> bool:
    """Recompute the state digest over the (possibly reloaded) arrays."""
    got = np.asarray(ws.state_digest(to_state(snap)))
    return bool(np.array_equal(got, snap.state_digest))


def path_for(directory: str, block_no: int) -> str:
    return os.path.join(directory, f"snapshot_{block_no:08d}.npz")


def save(directory: str, snap: Snapshot) -> str:
    """Persist atomically: write to a tmp name, then rename-publish."""
    os.makedirs(directory, exist_ok=True)
    final = path_for(directory, snap.block_no)
    tmp = final + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(
            f,
            block_no=np.uint32(snap.block_no),
            journal_head=snap.journal_head,
            ledger_head=snap.ledger_head,
            state_digest=snap.state_digest,
            keys=snap.keys,
            versions=snap.versions,
            values=snap.values,
        )
    os.replace(tmp, final)
    return final


def load(path: str) -> Snapshot:
    with np.load(path) as z:
        return Snapshot(
            block_no=int(z["block_no"]),
            journal_head=z["journal_head"],
            ledger_head=z["ledger_head"],
            state_digest=z["state_digest"],
            keys=z["keys"],
            versions=z["versions"],
            values=z["values"],
        )


def list_blocks(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("snapshot_") and name.endswith(".npz"):
            out.append(int(name[len("snapshot_"):-len(".npz")]))
    return sorted(out)


def latest(directory: str) -> Snapshot | None:
    blocks = list_blocks(directory)
    return load(path_for(directory, blocks[-1])) if blocks else None


def gc(directory: str, *, keep: int = 2) -> None:
    """Drop all but the newest ``keep`` snapshots."""
    for bno in list_blocks(directory)[:-keep]:
        os.remove(path_for(directory, bno))
