"""Append-only, digest-chained journal of validated write sets.

The statejournal idea (SNIPPETS.md): instead of maintaining a Merkle-style
authenticated structure over the world state, *update a running hash with
the stream of state updates* and write the updates to a journal. The latest
state stays in the plain hash table (core/world_state.py); authentication
comes from the journal's digest chain.

Two halves, mirroring core/ledger.py's ``append_hash`` / ``BlockStore``
split:

  * ``write_set_digest`` + ``update_head`` — the on-critical-path part:
    a (2,) u32 authentication head folded over each block's write sets and
    validity flags. Tiny, jit-able; the committer threads it through
    ``PeerState.journal_head`` so every commit program also advances the
    journal head (core/committer.py).
  * ``StateJournal`` — the off-path materialization: receives validated
    blocks from the storage role (BlockStore's writer thread), decodes the
    write sets, recomputes the head chain host-side, and keeps the records
    [+ optional ``.npz`` spill]. Recovery replays a suffix of these records
    onto a snapshot (storage/recovery.py).

The head chain is domain-separated from the ledger chain (``_JOURNAL_TAG``)
so a journal head can never be confused with a block hash.

Elastic state adds a third record kind: a **re-anchor record** committed at
every resize epoch (the halve/double of the sharded world state's bucket
count, world_state.resize / state_sharding.resize_sharded). A resize lands
*between* blocks and rewrites no history, so re-anchors ride a parallel
digest chain (``reanchor_head``, domain-separated by ``_REANCHOR_TAG``)
instead of advancing the block-write-set head: the main journal head stays
layout-independent (a channel that split mid-run carries the same journal
head as one that ran on the final layout from block 0 — the equivalence
the tests pin), while each re-anchor record binds (a) its boundary
position via the main head at that block, (b) the layout change, (c) the
post-resize digest-tree head, and (d) the sticky overflow bitmask. The
snapshot manifest persists the re-anchor chain head, so recovery verifies
the re-anchor suffix exactly like the block suffix, and ``replay`` applies
the recorded resizes at their boundaries — replay and verification cross
resize epochs.
"""

from __future__ import annotations

import time
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing, types, unmarshal
from repro.core import world_state as ws
from repro.obs.metrics import NULL_REGISTRY

U32 = jnp.uint32

GENESIS_HEAD = np.zeros((2,), np.uint32)

# Domain separation word folded into every head update.
_JOURNAL_TAG = jnp.uint32(0x4A524E4C)  # "JRNL"

# Domain separation for the resize re-anchor chain.
_REANCHOR_TAG = jnp.uint32(0x52414E43)  # "RANC"


def write_set_digest(write_keys: jnp.ndarray, write_vals: jnp.ndarray,
                     valid: jnp.ndarray) -> jnp.ndarray:
    """Content digest of a block's write sets + validity flags, (2,) u32.

    Order-dependent over transactions (the journal is a totally ordered
    stream), mirroring ``ledger.block_body_digest`` but over the *decoded*
    write sets rather than the wire bytes.
    """
    n = write_keys.shape[0]
    words = jnp.concatenate(
        [write_keys.reshape(n, -1), write_vals.reshape(n, -1)], axis=1
    ).astype(U32)
    d1 = hashing.hash_words(words, seed=hashing.SEED_A)  # (N,)
    d2 = hashing.hash_words(words, seed=hashing.SEED_B)
    v = valid.astype(U32)
    h1 = hashing.hash_words((d1 ^ v)[None, :], seed=hashing.SEED_A)[0]
    h2 = hashing.hash_words((d2 ^ (v << 1))[None, :], seed=hashing.SEED_B)[0]
    return jnp.stack([h1, h2])


def update_head(prev_head: jnp.ndarray, block_no: jnp.ndarray,
                ws_digest: jnp.ndarray) -> jnp.ndarray:
    """Chain: H(tag || prev || block_no || write-set digest). (2,) u32."""
    words = jnp.concatenate(
        [
            jnp.atleast_1d(_JOURNAL_TAG),
            prev_head,
            jnp.atleast_1d(block_no).astype(U32),
            ws_digest,
        ]
    )[None, :]
    return jnp.stack(
        [
            hashing.hash_words(words, seed=hashing.SEED_A)[0],
            hashing.hash_words(words, seed=hashing.SEED_B)[0],
        ]
    )


@jax.jit
def journal_head_update(prev_head, block_no, write_keys, write_vals, valid):
    """One fused head update — what the commit path executes per block."""
    return update_head(
        prev_head, block_no, write_set_digest(write_keys, write_vals, valid)
    )


def reanchor_head_update(prev_reanchor, prev_head, block_no, old_n_buckets,
                         new_n_buckets, n_shards, tree_head, overflow_bits
                         ) -> np.ndarray:
    """Re-anchor chain link, (2,) u32 (host-side; resizes are rare).

    H(tag || prev_reanchor || main head at the boundary || boundary block
    || old/new layout || post-resize tree head || overflow bitmask) — the
    main-head word pins the record to its chain position, so a re-anchor
    cannot be replayed at a different boundary.
    """
    overflow_bits = int(overflow_bits)  # numpy scalars shift unsafely at 32
    words = jnp.concatenate([
        jnp.atleast_1d(_REANCHOR_TAG),
        jnp.asarray(prev_reanchor, U32),
        jnp.asarray(prev_head, U32),
        jnp.atleast_1d(jnp.uint32(block_no + 1)),  # +1: boundary -1 is u32-safe
        jnp.atleast_1d(jnp.uint32(old_n_buckets)),
        jnp.atleast_1d(jnp.uint32(new_n_buckets)),
        jnp.atleast_1d(jnp.uint32(n_shards)),
        jnp.asarray(tree_head, U32),
        # Bitmask widened past 32 shards: fold as lo/hi u32 words so the
        # link stays u32-native (JAX x64 off) and covers 64 shard bits.
        jnp.asarray([overflow_bits & 0xFFFFFFFF,
                     (overflow_bits >> 32) & 0xFFFFFFFF], U32),
    ])[None, :]
    return np.asarray(jnp.stack([
        hashing.hash_words(words, seed=hashing.SEED_A)[0],
        hashing.hash_words(words, seed=hashing.SEED_B)[0],
    ]))


class ReanchorRecord(NamedTuple):
    """One resize epoch: layout change + post-resize commitment.

    Applies AFTER block ``block_no`` (the boundary's last committed block;
    -1 == before any block). ``prev_head`` is the MAIN journal head at that
    boundary — the record is bound to its position without advancing the
    layout-independent main chain. ``head`` chains re-anchors among
    themselves from ``prev_reanchor``.
    """

    block_no: int
    old_n_buckets: int
    new_n_buckets: int
    n_shards: int
    tree_head: np.ndarray  # (2,) u32 — shard_digest_tree of the new table
    overflow_bits: int  # sticky per-shard overflow bitmask at the boundary
    prev_head: np.ndarray  # (2,) u32 — main journal head at the boundary
    prev_reanchor: np.ndarray  # (2,) u32
    head: np.ndarray  # (2,) u32


# One decode program per dims, shared by every StateJournal instance.
_decode_jit = jax.jit(unmarshal.unmarshal, static_argnames="dims")


class ReplayResult(NamedTuple):
    """Result of :meth:`StateJournal.replay`: the rebuilt state plus
    whether any replayed commit/shrink dropped a write on a full bucket
    (deterministically re-derived — recovery re-latches it)."""

    state: ws.HashState
    overflow: bool


class JournalRecord(NamedTuple):
    """One journaled block: its validated write sets + the head chain link.

    All arrays are host-side numpy (the journal is the durable, off-device
    artifact); ``head == update_head(prev_head, block_no, digest(writes))``.
    """

    block_no: int
    write_keys: np.ndarray  # (B, WK, 2) u32
    write_vals: np.ndarray  # (B, WK, VW) u32
    valid: np.ndarray  # (B,) bool
    prev_head: np.ndarray  # (2,) u32
    head: np.ndarray  # (2,) u32


class StateJournal:
    """Host-side journal store: ordered records + running head.

    Appends happen on the storage role's writer thread (off the critical
    path); reads happen after ``BlockStore.drain()``. ``spill_dir`` persists
    one ``journal_XXXXXXXX.npz`` per record (same pattern as BlockStore
    block spill), which ``StateJournal.load`` can rebuild for a cold start.
    """

    def __init__(self, dims: types.FabricDims, *, spill_dir: str | None = None,
                 metrics=None):
        if spill_dir is not None:
            import os

            os.makedirs(spill_dir, exist_ok=True)
        self.dims = dims
        # Metrics sink (repro.obs.metrics.Registry); appends run on the
        # storage writer thread, so the registry must be thread-safe (it is).
        self._metrics = metrics if metrics is not None else NULL_REGISTRY
        self.records: list[JournalRecord] = []
        self.head = GENESIS_HEAD.copy()
        # Pruning base: records up to base_block_no were compacted away and
        # are covered by a snapshot; the chain re-anchors at base_head.
        self.base_block_no = -1
        self.base_head = GENESIS_HEAD.copy()
        # Resize re-anchor records + their own digest chain (see module
        # docstring): the main head stays layout-independent.
        self.reanchors: list[ReanchorRecord] = []
        self.reanchor_head = GENESIS_HEAD.copy()
        self.base_reanchor_head = GENESIS_HEAD.copy()
        self._spill_dir = spill_dir

    # --- append path (storage-role thread) --------------------------------

    def append_block(self, block_no: int, wire, valid) -> JournalRecord:
        """Decode a validated block's write sets and journal them."""
        dec = _decode_jit(jnp.asarray(wire), dims=self.dims)
        return self.append_writes(
            block_no, dec.txb.write_keys, dec.txb.write_vals, valid
        )

    def append_writes(self, block_no: int, write_keys, write_vals,
                      valid) -> JournalRecord:
        t0 = time.perf_counter()
        prev = self.head
        head = np.asarray(
            journal_head_update(
                jnp.asarray(prev), jnp.uint32(block_no),
                jnp.asarray(write_keys), jnp.asarray(write_vals),
                jnp.asarray(valid),
            )
        )
        rec = JournalRecord(
            block_no=int(block_no),
            write_keys=np.asarray(jax.device_get(write_keys)),
            write_vals=np.asarray(jax.device_get(write_vals)),
            valid=np.asarray(jax.device_get(valid)).astype(bool),
            prev_head=prev,
            head=head,
        )
        self.records.append(rec)
        self.head = head
        self._metrics.counter("journal.appends").inc()
        self._metrics.counter("journal.bytes").inc(
            rec.write_keys.nbytes + rec.write_vals.nbytes + rec.valid.nbytes
        )
        self._metrics.histogram("journal.append.latency").record(
            time.perf_counter() - t0
        )
        if self._spill_dir is not None:
            np.savez(
                f"{self._spill_dir}/journal_{rec.block_no:08d}.npz",
                block_no=np.uint32(rec.block_no),
                write_keys=rec.write_keys,
                write_vals=rec.write_vals,
                valid=rec.valid,
                prev_head=rec.prev_head,
                head=rec.head,
            )
        return rec

    def append_reanchor(self, block_no: int, *, old_n_buckets: int,
                        new_n_buckets: int, n_shards: int, tree_head,
                        overflow_bits: int = 0) -> ReanchorRecord:
        """Commit a resize epoch at the CURRENT boundary (after the last
        appended block — the caller drains the storage role first so the
        main head really is at ``block_no``)."""
        tip = self.records[-1].block_no if self.records else self.base_block_no
        if block_no != tip:
            raise ValueError(
                f"re-anchor at block {block_no} but journal tip is {tip} "
                "(drain the storage role before resizing)"
            )
        prev_r = self.reanchor_head
        tree = np.asarray(tree_head).astype(np.uint32)
        head = reanchor_head_update(
            prev_r, self.head, block_no, old_n_buckets, new_n_buckets,
            n_shards, tree, overflow_bits,
        )
        rec = ReanchorRecord(
            block_no=int(block_no), old_n_buckets=int(old_n_buckets),
            new_n_buckets=int(new_n_buckets), n_shards=int(n_shards),
            tree_head=tree, overflow_bits=int(overflow_bits),
            prev_head=self.head.copy(), prev_reanchor=prev_r, head=head,
        )
        self.reanchors.append(rec)
        self.reanchor_head = head
        self._metrics.counter("journal.reanchors").inc()
        if self._spill_dir is not None:
            seq = sum(r.block_no == rec.block_no for r in self.reanchors) - 1
            np.savez(
                f"{self._spill_dir}/reanchor_{rec.block_no + 1:08d}_"
                f"{seq:04d}.npz",
                block_no=np.int64(rec.block_no),
                old_n_buckets=np.uint32(rec.old_n_buckets),
                new_n_buckets=np.uint32(rec.new_n_buckets),
                n_shards=np.uint32(rec.n_shards),
                tree_head=rec.tree_head,
                overflow_bits=np.uint64(rec.overflow_bits),
                prev_head=rec.prev_head,
                prev_reanchor=rec.prev_reanchor,
                head=rec.head,
            )
        return rec

    # --- authentication ---------------------------------------------------

    def verify_chain(self, *, base_head: np.ndarray | None = None,
                     after_block_no: int | None = None,
                     reanchor_base: np.ndarray | None = None) -> bool:
        """Recompute the digest chains over (a suffix of) the records.

        With no arguments, verifies every retained record from the prune
        base. ``base_head``/``after_block_no`` verify a suffix against a
        trusted anchor (a snapshot's journal head) — the recovery check;
        ``reanchor_base`` is then the snapshot manifest's re-anchor chain
        head (defaults to the prune base). Re-anchor records in the suffix
        must chain from that anchor AND bind to the main head at their
        boundary block — so verification crosses resize epochs.
        """
        ok, _ = self.verify_chain_reason(
            base_head=base_head, after_block_no=after_block_no,
            reanchor_base=reanchor_base,
        )
        return ok

    def verify_chain_reason(self, *, base_head: np.ndarray | None = None,
                            after_block_no: int | None = None,
                            reanchor_base: np.ndarray | None = None
                            ) -> tuple[bool, str | None]:
        """:meth:`verify_chain` with a WHY: ``(ok, reason)`` where
        ``reason`` names the first failing record and check (None when the
        chain verifies). The flight recorder's ``verify_contract`` trip
        context carries it, so a post-mortem dump says which record broke
        the chain, not just that one did."""
        if after_block_no is None:
            after_block_no = self.base_block_no
            prev = self.base_head if base_head is None else base_head
        else:
            if base_head is None:
                raise ValueError("after_block_no requires a base_head anchor")
            prev = base_head
        # Main head at each boundary in the suffix (for re-anchor binding).
        head_at = {after_block_no: np.asarray(prev)}
        expect_no = after_block_no + 1
        for rec in self.suffix(after_block_no):
            if rec.block_no != expect_no:  # gap: records missing
                return False, (
                    f"record gap: expected block {expect_no}, found "
                    f"{rec.block_no}"
                )
            if not np.array_equal(rec.prev_head, prev):
                return False, (
                    f"record {rec.block_no}: prev_head does not chain "
                    "from the preceding head"
                )
            recomputed = np.asarray(
                journal_head_update(
                    jnp.asarray(prev), jnp.uint32(rec.block_no),
                    jnp.asarray(rec.write_keys), jnp.asarray(rec.write_vals),
                    jnp.asarray(rec.valid),
                )
            )
            if not np.array_equal(recomputed, rec.head):
                return False, (
                    f"record {rec.block_no}: recomputed head mismatch "
                    "(write set or validity bits tampered)"
                )
            prev = rec.head
            head_at[rec.block_no] = rec.head
            expect_no += 1
        # Re-anchor chain over the same suffix.
        prev_r = (self.base_reanchor_head if reanchor_base is None
                  else np.asarray(reanchor_base))
        for rec in self.suffix_reanchors(after_block_no):
            if rec.block_no not in head_at:  # boundary not in the suffix
                return False, (
                    f"re-anchor at block {rec.block_no}: boundary not in "
                    "the verified suffix"
                )
            if not np.array_equal(rec.prev_head, head_at[rec.block_no]):
                return False, (
                    f"re-anchor at block {rec.block_no}: does not bind "
                    "to the main head at its boundary"
                )
            if not np.array_equal(rec.prev_reanchor, prev_r):
                return False, (
                    f"re-anchor at block {rec.block_no}: does not chain "
                    "from the preceding re-anchor head"
                )
            recomputed = reanchor_head_update(
                prev_r, rec.prev_head, rec.block_no, rec.old_n_buckets,
                rec.new_n_buckets, rec.n_shards, rec.tree_head,
                rec.overflow_bits,
            )
            if not np.array_equal(recomputed, rec.head):
                return False, (
                    f"re-anchor at block {rec.block_no}: recomputed "
                    "re-anchor head mismatch (epoch record tampered)"
                )
            prev_r = rec.head
        return True, None

    # --- replay / compaction ----------------------------------------------

    def suffix(self, after_block_no: int) -> list[JournalRecord]:
        return [r for r in self.records if r.block_no > after_block_no]

    def suffix_reanchors(self, after_block_no: int) -> list[ReanchorRecord]:
        """Re-anchors strictly after ``after_block_no``. A re-anchor at
        boundary b is COVERED by a snapshot at block b (resizes land before
        the snapshot at the same boundary), so it is excluded — except at
        boundary -1: genesis is not a snapshot, so a pre-genesis resize
        (engine sized up before its first round) is always part of the
        from-genesis suffix and stays authenticated/replayed."""
        return [r for r in self.reanchors
                if r.block_no > after_block_no
                or (r.block_no == -1 and after_block_no == -1)]

    def replay(self, state: ws.HashState, *, after_block_no: int = -1,
               check_reanchors: bool = False) -> "ReplayResult":
        """Apply journaled write sets (block order) onto ``state``,
        CROSSING resize epochs: every re-anchor record in the suffix
        applies ``world_state.resize`` at its boundary, so the replay of a
        channel that split mid-run lands on the final layout. Returns
        :class:`ReplayResult` — ``overflow`` reports whether any replayed
        commit (or shrink) dropped a write, so recovery can re-latch
        overflow that struck AFTER the last snapshot persisted its mask.

        MVCC guarantees valid write sets within a block are disjoint, so
        each record is one conflict-free vectorized commit — replay cost is
        O(suffix), independent of payload size (no unmarshal, no
        re-validation). With ``check_reanchors`` the post-resize state is
        checked against the record's committed digest-tree head (the
        recovery path's proof that the rebuilt table matches the one the
        live peer re-anchored to).
        """
        by_boundary: dict[int, list[ReanchorRecord]] = {}
        for r in self.suffix_reanchors(after_block_no):
            by_boundary.setdefault(r.block_no, []).append(r)
        ovf = jnp.asarray(False)

        def cross(state, ovf, boundary):
            for r in by_boundary.pop(boundary, ()):
                if r.old_n_buckets != state.n_buckets:
                    raise ValueError(
                        f"re-anchor at block {r.block_no} expects "
                        f"{r.old_n_buckets} buckets, state has "
                        f"{state.n_buckets}"
                    )
                res = ws.resize(state, r.new_n_buckets)
                state, ovf = res.state, ovf | res.overflow
                if check_reanchors:
                    tree = np.asarray(ws.tree_head(state, r.n_shards))
                    if not np.array_equal(tree, r.tree_head):
                        raise ValueError(
                            f"re-anchor at block {r.block_no}: rebuilt "
                            "digest tree head does not match the record"
                        )
            return state, ovf

        for rec in self.suffix(after_block_no):
            state, ovf = cross(state, ovf, rec.block_no - 1)
            res = ws.commit_vectorized(
                state,
                jnp.asarray(rec.write_keys),
                jnp.asarray(rec.write_vals),
                jnp.asarray(rec.valid),
            )
            state, ovf = res.state, ovf | res.overflow
            state, ovf = cross(state, ovf, rec.block_no)
        # Re-anchors past the last retained record (resize at the tip).
        for boundary in sorted(by_boundary):
            state, ovf = cross(state, ovf, boundary)
        return ReplayResult(state=state, overflow=bool(np.asarray(ovf)))

    def prune_upto(self, block_no: int) -> int:
        """Drop records covered by a snapshot at ``block_no`` — from memory
        and from the spill directory; re-anchors at covered boundaries go
        with them (their chain re-anchors at ``base_reanchor_head``, which
        the covering snapshot's manifest also carries). Returns the number
        of block records dropped. Call only with the storage role
        drained."""
        import glob
        import os

        dropped_r = [r for r in self.reanchors if r.block_no <= block_no]
        if dropped_r:
            self.reanchors = self.suffix_reanchors(block_no)
            self.base_reanchor_head = dropped_r[-1].head
            if self._spill_dir is not None:
                for path in sorted(glob.glob(
                    os.path.join(self._spill_dir, "reanchor_*.npz")
                )):
                    with np.load(path) as z:
                        covered = int(z["block_no"]) <= block_no
                    if covered:
                        os.remove(path)
        dropped = [r for r in self.records if r.block_no <= block_no]
        if dropped:
            self.records = self.suffix(block_no)
            self.base_block_no = dropped[-1].block_no
            self.base_head = dropped[-1].head
            if self._spill_dir is not None:
                for rec in dropped:
                    path = os.path.join(
                        self._spill_dir, f"journal_{rec.block_no:08d}.npz"
                    )
                    if os.path.exists(path):
                        os.remove(path)
        return len(dropped)

    # --- cold-start reload ------------------------------------------------

    @classmethod
    def load(cls, dims: types.FabricDims, spill_dir: str, *,
             metrics=None) -> "StateJournal":
        """Rebuild a journal from its spill directory (cold start) —
        block records AND resize re-anchor records (their file names are
        keyed by boundary+1 so a pre-genesis re-anchor sorts first).
        Reloaded records do NOT count as appends (``metrics`` only sees
        post-restore appends — restore must not double count)."""
        import glob
        import os

        j = cls(dims, spill_dir=None, metrics=metrics)
        paths = sorted(glob.glob(os.path.join(spill_dir, "journal_*.npz")))
        for p in paths:
            with np.load(p) as z:
                rec = JournalRecord(
                    block_no=int(z["block_no"]),
                    write_keys=z["write_keys"],
                    write_vals=z["write_vals"],
                    valid=z["valid"].astype(bool),
                    prev_head=z["prev_head"],
                    head=z["head"],
                )
            if not j.records:
                j.base_block_no = rec.block_no - 1
                j.base_head = rec.prev_head.copy()
            j.records.append(rec)
            j.head = rec.head
        for p in sorted(glob.glob(os.path.join(spill_dir, "reanchor_*.npz"))):
            with np.load(p) as z:
                rec = ReanchorRecord(
                    block_no=int(z["block_no"]),
                    old_n_buckets=int(z["old_n_buckets"]),
                    new_n_buckets=int(z["new_n_buckets"]),
                    n_shards=int(z["n_shards"]),
                    tree_head=z["tree_head"],
                    overflow_bits=int(z["overflow_bits"]),
                    prev_head=z["prev_head"],
                    prev_reanchor=z["prev_reanchor"],
                    head=z["head"],
                )
            if not j.reanchors:
                j.base_reanchor_head = rec.prev_reanchor.copy()
            j.reanchors.append(rec)
            j.reanchor_head = rec.head
        j._spill_dir = spill_dir
        return j
