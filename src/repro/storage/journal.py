"""Append-only, digest-chained journal of validated write sets.

The statejournal idea (SNIPPETS.md): instead of maintaining a Merkle-style
authenticated structure over the world state, *update a running hash with
the stream of state updates* and write the updates to a journal. The latest
state stays in the plain hash table (core/world_state.py); authentication
comes from the journal's digest chain.

Two halves, mirroring core/ledger.py's ``append_hash`` / ``BlockStore``
split:

  * ``write_set_digest`` + ``update_head`` — the on-critical-path part:
    a (2,) u32 authentication head folded over each block's write sets and
    validity flags. Tiny, jit-able; the committer threads it through
    ``PeerState.journal_head`` so every commit program also advances the
    journal head (core/committer.py).
  * ``StateJournal`` — the off-path materialization: receives validated
    blocks from the storage role (BlockStore's writer thread), decodes the
    write sets, recomputes the head chain host-side, and keeps the records
    [+ optional ``.npz`` spill]. Recovery replays a suffix of these records
    onto a snapshot (storage/recovery.py).

The head chain is domain-separated from the ledger chain (``_JOURNAL_TAG``)
so a journal head can never be confused with a block hash.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing, types, unmarshal
from repro.core import world_state as ws

U32 = jnp.uint32

GENESIS_HEAD = np.zeros((2,), np.uint32)

# Domain separation word folded into every head update.
_JOURNAL_TAG = jnp.uint32(0x4A524E4C)  # "JRNL"


def write_set_digest(write_keys: jnp.ndarray, write_vals: jnp.ndarray,
                     valid: jnp.ndarray) -> jnp.ndarray:
    """Content digest of a block's write sets + validity flags, (2,) u32.

    Order-dependent over transactions (the journal is a totally ordered
    stream), mirroring ``ledger.block_body_digest`` but over the *decoded*
    write sets rather than the wire bytes.
    """
    n = write_keys.shape[0]
    words = jnp.concatenate(
        [write_keys.reshape(n, -1), write_vals.reshape(n, -1)], axis=1
    ).astype(U32)
    d1 = hashing.hash_words(words, seed=hashing.SEED_A)  # (N,)
    d2 = hashing.hash_words(words, seed=hashing.SEED_B)
    v = valid.astype(U32)
    h1 = hashing.hash_words((d1 ^ v)[None, :], seed=hashing.SEED_A)[0]
    h2 = hashing.hash_words((d2 ^ (v << 1))[None, :], seed=hashing.SEED_B)[0]
    return jnp.stack([h1, h2])


def update_head(prev_head: jnp.ndarray, block_no: jnp.ndarray,
                ws_digest: jnp.ndarray) -> jnp.ndarray:
    """Chain: H(tag || prev || block_no || write-set digest). (2,) u32."""
    words = jnp.concatenate(
        [
            jnp.atleast_1d(_JOURNAL_TAG),
            prev_head,
            jnp.atleast_1d(block_no).astype(U32),
            ws_digest,
        ]
    )[None, :]
    return jnp.stack(
        [
            hashing.hash_words(words, seed=hashing.SEED_A)[0],
            hashing.hash_words(words, seed=hashing.SEED_B)[0],
        ]
    )


@jax.jit
def journal_head_update(prev_head, block_no, write_keys, write_vals, valid):
    """One fused head update — what the commit path executes per block."""
    return update_head(
        prev_head, block_no, write_set_digest(write_keys, write_vals, valid)
    )


# One decode program per dims, shared by every StateJournal instance.
_decode_jit = jax.jit(unmarshal.unmarshal, static_argnames="dims")


class JournalRecord(NamedTuple):
    """One journaled block: its validated write sets + the head chain link.

    All arrays are host-side numpy (the journal is the durable, off-device
    artifact); ``head == update_head(prev_head, block_no, digest(writes))``.
    """

    block_no: int
    write_keys: np.ndarray  # (B, WK, 2) u32
    write_vals: np.ndarray  # (B, WK, VW) u32
    valid: np.ndarray  # (B,) bool
    prev_head: np.ndarray  # (2,) u32
    head: np.ndarray  # (2,) u32


class StateJournal:
    """Host-side journal store: ordered records + running head.

    Appends happen on the storage role's writer thread (off the critical
    path); reads happen after ``BlockStore.drain()``. ``spill_dir`` persists
    one ``journal_XXXXXXXX.npz`` per record (same pattern as BlockStore
    block spill), which ``StateJournal.load`` can rebuild for a cold start.
    """

    def __init__(self, dims: types.FabricDims, *, spill_dir: str | None = None):
        if spill_dir is not None:
            import os

            os.makedirs(spill_dir, exist_ok=True)
        self.dims = dims
        self.records: list[JournalRecord] = []
        self.head = GENESIS_HEAD.copy()
        # Pruning base: records up to base_block_no were compacted away and
        # are covered by a snapshot; the chain re-anchors at base_head.
        self.base_block_no = -1
        self.base_head = GENESIS_HEAD.copy()
        self._spill_dir = spill_dir

    # --- append path (storage-role thread) --------------------------------

    def append_block(self, block_no: int, wire, valid) -> JournalRecord:
        """Decode a validated block's write sets and journal them."""
        dec = _decode_jit(jnp.asarray(wire), dims=self.dims)
        return self.append_writes(
            block_no, dec.txb.write_keys, dec.txb.write_vals, valid
        )

    def append_writes(self, block_no: int, write_keys, write_vals,
                      valid) -> JournalRecord:
        prev = self.head
        head = np.asarray(
            journal_head_update(
                jnp.asarray(prev), jnp.uint32(block_no),
                jnp.asarray(write_keys), jnp.asarray(write_vals),
                jnp.asarray(valid),
            )
        )
        rec = JournalRecord(
            block_no=int(block_no),
            write_keys=np.asarray(jax.device_get(write_keys)),
            write_vals=np.asarray(jax.device_get(write_vals)),
            valid=np.asarray(jax.device_get(valid)).astype(bool),
            prev_head=prev,
            head=head,
        )
        self.records.append(rec)
        self.head = head
        if self._spill_dir is not None:
            np.savez(
                f"{self._spill_dir}/journal_{rec.block_no:08d}.npz",
                block_no=np.uint32(rec.block_no),
                write_keys=rec.write_keys,
                write_vals=rec.write_vals,
                valid=rec.valid,
                prev_head=rec.prev_head,
                head=rec.head,
            )
        return rec

    # --- authentication ---------------------------------------------------

    def verify_chain(self, *, base_head: np.ndarray | None = None,
                     after_block_no: int | None = None) -> bool:
        """Recompute the digest chain over (a suffix of) the records.

        With no arguments, verifies every retained record from the prune
        base. ``base_head``/``after_block_no`` verify a suffix against a
        trusted anchor (a snapshot's journal head) — the recovery check.
        """
        if after_block_no is None:
            after_block_no = self.base_block_no
            prev = self.base_head if base_head is None else base_head
        else:
            if base_head is None:
                raise ValueError("after_block_no requires a base_head anchor")
            prev = base_head
        expect_no = after_block_no + 1
        for rec in self.suffix(after_block_no):
            if rec.block_no != expect_no:  # gap: records missing
                return False
            if not np.array_equal(rec.prev_head, prev):
                return False
            recomputed = np.asarray(
                journal_head_update(
                    jnp.asarray(prev), jnp.uint32(rec.block_no),
                    jnp.asarray(rec.write_keys), jnp.asarray(rec.write_vals),
                    jnp.asarray(rec.valid),
                )
            )
            if not np.array_equal(recomputed, rec.head):
                return False
            prev = rec.head
            expect_no += 1
        return True

    # --- replay / compaction ----------------------------------------------

    def suffix(self, after_block_no: int) -> list[JournalRecord]:
        return [r for r in self.records if r.block_no > after_block_no]

    def replay(self, state: ws.HashState, *, after_block_no: int = -1
               ) -> ws.HashState:
        """Apply journaled write sets (block order) onto ``state``.

        MVCC guarantees valid write sets within a block are disjoint, so
        each record is one conflict-free vectorized commit — replay cost is
        O(suffix), independent of payload size (no unmarshal, no
        re-validation).
        """
        for rec in self.suffix(after_block_no):
            state = ws.commit_vectorized(
                state,
                jnp.asarray(rec.write_keys),
                jnp.asarray(rec.write_vals),
                jnp.asarray(rec.valid),
            ).state
        return state

    def prune_upto(self, block_no: int) -> int:
        """Drop records covered by a snapshot at ``block_no`` — from memory
        and from the spill directory. Returns the number dropped. Call only
        with the storage role drained."""
        import os

        dropped = [r for r in self.records if r.block_no <= block_no]
        if dropped:
            self.records = self.suffix(block_no)
            self.base_block_no = dropped[-1].block_no
            self.base_head = dropped[-1].head
            if self._spill_dir is not None:
                for rec in dropped:
                    path = os.path.join(
                        self._spill_dir, f"journal_{rec.block_no:08d}.npz"
                    )
                    if os.path.exists(path):
                        os.remove(path)
        return len(dropped)

    # --- cold-start reload ------------------------------------------------

    @classmethod
    def load(cls, dims: types.FabricDims, spill_dir: str) -> "StateJournal":
        """Rebuild a journal from its spill directory (cold start)."""
        import glob
        import os

        j = cls(dims, spill_dir=None)
        paths = sorted(glob.glob(os.path.join(spill_dir, "journal_*.npz")))
        for p in paths:
            with np.load(p) as z:
                rec = JournalRecord(
                    block_no=int(z["block_no"]),
                    write_keys=z["write_keys"],
                    write_vals=z["write_vals"],
                    valid=z["valid"].astype(bool),
                    prev_head=z["prev_head"],
                    head=z["head"],
                )
            if not j.records:
                j.base_block_no = rec.block_no - 1
                j.base_head = rec.prev_head.copy()
            j.records.append(rec)
            j.head = rec.head
        j._spill_dir = spill_dir
        return j
