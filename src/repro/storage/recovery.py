"""Cold-start recovery: latest snapshot + journal suffix, fully verified —
now shard-aware and resize-epoch-aware.

The restart story that lets P-I keep no database: load the most recent
snapshot (verifying its per-shard digests + tree head), verify the
journal's digest chains from the snapshot's heads forward (block records
AND resize re-anchor records), then replay only that suffix of write sets
— crossing resize boundaries by applying each re-anchor's recorded
halve/double, and proving each rebuilt table against the re-anchor's
committed digest-tree head. The recovered peer proves it matches the
crashed one by comparing ``state_digest`` and the terminal journal head
against the live values (engine.verify's ``recovery_ok``), and re-latches
the STICKY overflow bitmask persisted in the manifest/re-anchor records
(an overflowed peer must not come back reporting healthy).

:func:`recover_shard` is the sharded peer's path: it loads ONLY the shard
parts that feed one target bucket shard (never the full table), replays
the suffix with write sets masked to the owned bucket ranges, and steps
through each re-anchor with a local mask + compact. Because an aligned
bucket range behaves exactly like a shard-local table (the low bucket
bits ARE the local index), the partial replay is array-exact against the
live shard. The walk is per-epoch range LISTS: a grow epoch's preimage of
an aligned range is one aligned range (drop a key bit), but a SHRINK
epoch folds bucket g onto g mod nb_new — the preimage of [a, a+s) is the
two sibling ranges [a, +s) and [a + nb_new, +s), whose fragments merge at
the boundary by concatenation (low shard part first, matching the full
table's flat rehash order, so even a lossy shrink's slot-overflow drops
replay byte-identically).

Multi-channel engines namespace their storage per channel
(core/ledger.channel_dir): ``recover`` takes the channel id and resolves
``snapshot_dir`` to that channel's snapshots; the journal handed in is
already the channel's own.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core import types
from repro.core import world_state as ws
from repro.storage import journal as journal_mod
from repro.storage import snapshot as snapshot_mod


class RecoveryError(RuntimeError):
    """Snapshot or journal failed authentication (or coverage is missing)."""


class RecoveryResult(NamedTuple):
    state: ws.HashState  # recovered world state (on device)
    block_no: int  # last block reflected in ``state``
    journal_head: np.ndarray  # (2,) u32 — journal head after replay
    state_digest: np.ndarray  # (2,) u32 — digest of recovered state
    snapshot_block_no: int  # -1 if recovered from genesis
    replayed_records: int  # journal suffix length
    n_buckets: int  # FINAL layout (resize epochs in the suffix applied)
    overflow_bits: int  # sticky per-shard overflow bitmask, re-latched
    crossed_reanchors: int  # resize epochs crossed during replay


def recover(
    jrnl: journal_mod.StateJournal,
    *,
    snapshot: snapshot_mod.Snapshot | None = None,
    snapshot_dir: str | None = None,
    n_buckets: int,
    slots: int,
    value_width: int,
    channel: int = 0,
) -> RecoveryResult:
    """Rebuild world state from ``snapshot`` (or the newest complete one in
    ``snapshot_dir``, or genesis) + the journal suffix after it.

    Raises :class:`RecoveryError` if the snapshot digests do not match its
    arrays, either journal chain does not verify from the snapshot's
    anchors, a re-anchor's rebuilt table does not match its committed tree
    head, or the journal does not cover the suffix (pruned past the
    snapshot). ``n_buckets`` is the GENESIS layout — re-anchor records in
    the suffix carry every later resize, so the result lands on the final
    layout whichever base it starts from. ``channel`` namespaces
    ``snapshot_dir`` (channel 0 IS the base dir); ``jrnl`` must already be
    the channel's own journal.
    """
    if snapshot is None and snapshot_dir is not None:
        from repro.core import ledger

        snapshot = snapshot_mod.latest(
            ledger.channel_dir(snapshot_dir, channel)
        )

    if snapshot is not None:
        if not snapshot_mod.verify(snapshot):
            raise RecoveryError(
                f"snapshot at block {snapshot.block_no}: shard digest / "
                "tree head mismatch (corrupt or tampered)"
            )
        state = snapshot_mod.to_state(snapshot)
        after = snapshot.block_no
        anchor = np.asarray(snapshot.journal_head)
        reanchor_anchor = np.asarray(snapshot.manifest.reanchor_head)
        overflow_bits = snapshot.manifest.overflow_bits
    else:
        state = ws.create(n_buckets, slots, value_width)
        after = -1
        anchor = journal_mod.GENESIS_HEAD
        reanchor_anchor = journal_mod.GENESIS_HEAD
        overflow_bits = 0

    if jrnl.base_block_no > after:
        raise RecoveryError(
            f"journal pruned up to block {jrnl.base_block_no} but recovery "
            f"needs records after block {after} (no covering snapshot)"
        )
    if not jrnl.verify_chain(base_head=anchor, after_block_no=after,
                             reanchor_base=reanchor_anchor):
        raise RecoveryError(
            f"journal chain does not authenticate after block {after} "
            "(corrupt, tampered, or missing records)"
        )

    suffix = jrnl.suffix(after)
    reanchors = jrnl.suffix_reanchors(after)
    try:
        rep = jrnl.replay(state, after_block_no=after,
                          check_reanchors=True)
    except ValueError as e:
        raise RecoveryError(str(e)) from e
    state = rep.state
    for rec in reanchors:
        overflow_bits |= rec.overflow_bits
    # Overflow that struck in the suffix AFTER the last persisted mask is
    # re-derived by the replay itself. The merged replay cannot localize
    # the drop, so it latches bit 0 — health (bits != 0) stays honest;
    # exact shard attribution comes from re-anchor records/manifests.
    overflow_bits |= int(rep.overflow)
    head = suffix[-1].head if suffix else anchor
    return RecoveryResult(
        state=state,
        block_no=suffix[-1].block_no if suffix else after,
        journal_head=np.asarray(head),
        state_digest=np.asarray(ws.state_digest(state)),
        snapshot_block_no=snapshot.block_no if snapshot is not None else -1,
        replayed_records=len(suffix),
        n_buckets=state.n_buckets,
        overflow_bits=int(overflow_bits),
        crossed_reanchors=len(reanchors),
    )


# ---------------------------------------------------------------------------
# Per-shard recovery (sharded peer: one bucket shard per host).
# ---------------------------------------------------------------------------


class ShardRecoveryResult(NamedTuple):
    state: ws.HashState  # the recovered LOCAL bucket shard
    shard: int
    n_shards: int
    block_no: int
    journal_head: np.ndarray  # (2,) u32 — the (global) journal head
    shard_digest: np.ndarray  # (2,) u32 — content digest of the shard
    loaded_parts: int  # snapshot shard files read (<< n_shards)
    replayed_records: int
    crossed_reanchors: int


def _range_schedule(shard: int, n_shards: int, nbs: list[int]
                    ) -> list[list[tuple[int, int]]]:
    """Per-epoch aligned (start, size) global bucket ranges that feed
    ``shard``'s final range, walked BACKWARD from the last epoch.

    A grow maps old bucket g to g or g + nb_old (one more key bit), so the
    preimage of an aligned range [a, a+s) under one doubling is
    [a mod nb_old, +s) — still aligned — capped at the whole older table
    when s exceeds it. A SHRINK folds g onto g mod nb_new, so the preimage
    of [a, a+s) is TWO sibling ranges, [a, +s) and [a + nb_new, +s) —
    epochs therefore carry range LISTS (equal-size, aligned, disjoint,
    ascending). ``nbs`` is the global bucket count per epoch (snapshot
    layout first, post-resize layouts after)."""
    nb_loc_final = nbs[-1] // n_shards
    ranges = [(shard * nb_loc_final, nb_loc_final)]
    out = [ranges]
    for k in range(len(nbs) - 2, -1, -1):
        nb_old, nb_new = nbs[k], nbs[k + 1]
        prev: list[tuple[int, int]] = []
        if nb_new >= nb_old:  # grow boundary: drop a key bit
            for a, s in ranges:
                size = min(s, nb_old)
                start = a % nb_old
                start -= start % size  # keep the range aligned to its size
                prev.append((start, size))
        else:  # shrink boundary: the two sibling preimages
            for a, s in ranges:
                prev.append((a, s))
                prev.append((a + nb_new, s))
        ranges = sorted(set(prev))
        out.append(ranges)
    return out[::-1]


def recover_shard(
    jrnl: journal_mod.StateJournal,
    *,
    snapshot_dir: str,
    shard: int,
) -> ShardRecoveryResult:
    """Recover ONE bucket shard from per-shard snapshot files + the journal
    suffix, across grow AND shrink re-anchors, without materializing the
    full table.

    Each epoch's working set is a list of aligned bucket-range fragments
    (one after grows only; shrinks fork siblings — K shrinks in the suffix
    mean at most 2^K fragments, still one final-shard's worth of buckets
    each). At a shrink boundary the low and high sibling fragments
    concatenate (ascending global order, so the fused table's flat rehash
    order matches the full-table halve bucket for bucket — lossy shrinks
    drop the same slots) and compact to the new range; at a grow boundary
    each new range masks-and-compacts from the fragment covering its
    preimage.
    """
    man = snapshot_mod.latest_manifest(snapshot_dir)
    if man is None:
        raise RecoveryError(f"no complete snapshot in {snapshot_dir}")
    if jrnl.base_block_no > man.block_no:
        raise RecoveryError(
            f"journal pruned up to block {jrnl.base_block_no} past the "
            f"snapshot at block {man.block_no}"
        )
    if not jrnl.verify_chain(
        base_head=np.asarray(man.journal_head), after_block_no=man.block_no,
        reanchor_base=np.asarray(man.reanchor_head),
    ):
        raise RecoveryError(
            f"journal chain does not authenticate after block {man.block_no}"
        )
    reanchors = jrnl.suffix_reanchors(man.block_no)
    for r in reanchors:
        if r.n_shards != man.n_shards:
            raise RecoveryError("shard count changed across the suffix")
    m = man.n_shards
    if not 0 <= shard < m:
        raise RecoveryError(f"shard {shard} out of range for {m} shards")

    # Per-epoch bucket ranges feeding the target shard, walked backward
    # from the final layout; epoch 0 names the snapshot shard parts to
    # load.
    nbs = [man.n_buckets] + [r.new_n_buckets for r in reanchors]
    sched = _range_schedule(shard, m, nbs)
    nb_loc0 = man.n_buckets // m
    loaded = 0

    def load_range(start: int, size: int) -> ws.HashState:
        nonlocal loaded
        lo, cnt = start // nb_loc0, max(size // nb_loc0, 1)
        parts = []
        for s in range(lo, lo + cnt):
            part = snapshot_mod.load_shard(snapshot_dir, man.block_no, s)
            if not snapshot_mod.verify_shard(man, part):
                raise RecoveryError(
                    f"snapshot shard {s} at block {man.block_no}: digest "
                    "mismatch (corrupt or tampered)"
                )
            parts.append(part)
        loaded += cnt
        st = ws.HashState(
            keys=jnp.asarray(np.concatenate([p.keys for p in parts])),
            versions=jnp.asarray(np.concatenate([p.versions for p in parts])),
            values=jnp.asarray(np.concatenate([p.values for p in parts])),
        )
        if size < nb_loc0:
            # A sub-part range (a shrink's sibling narrower than one
            # snapshot part): mask to the owned range and compact down.
            mine = ws.shard_of(
                man.n_buckets, man.n_buckets // size, st.keys
            ) == start // size
            st = ws.resize(
                st._replace(keys=jnp.where(
                    mine[..., None], st.keys, jnp.uint32(0))),
                size,
            ).state
        return st

    # Fragments keyed by range start; each covers an ALIGNED global bucket
    # range, so the low bucket bits are its local index and it behaves as
    # one shard of a coarser partition (nb // size groups) — ownership
    # masks reuse shard_of, commits/resizes run the unmodified local
    # machinery.
    frags: dict[int, ws.HashState] = {
        a: load_range(a, s) for a, s in sched[0]
    }
    epoch = 0
    by_boundary: dict[int, list] = {}
    for k, r in enumerate(reanchors):
        by_boundary.setdefault(r.block_no, []).append((k, r))

    def cross(frags, epoch, boundary):
        for k, r in by_boundary.pop(boundary, ()):
            if r.old_n_buckets != nbs[k]:
                raise RecoveryError(
                    f"re-anchor at block {r.block_no} expects "
                    f"{r.old_n_buckets} buckets, epoch has {nbs[k]}"
                )
            new_nb = r.new_n_buckets
            old_size = sched[k][0][1]
            nxt: dict[int, ws.HashState] = {}
            for new_start, new_size in sched[k + 1]:
                if new_nb < nbs[k]:
                    # Shrink: fuse the sibling fragments in ascending
                    # global-bucket order, then rehash down — flat scan
                    # order equals the full table's, so slot drops match.
                    low = frags[new_start]
                    high = frags[new_start + new_nb]
                    fused = ws.HashState(
                        keys=jnp.concatenate([low.keys, high.keys]),
                        versions=jnp.concatenate(
                            [low.versions, high.versions]),
                        values=jnp.concatenate([low.values, high.values]),
                    )
                    nxt[new_start] = ws.resize(fused, new_size).state
                else:
                    # Grow: the fragment covering the preimage donates the
                    # new range's keys (mask to owners, compact). The
                    # preimage IS an epoch-k range (same formula the
                    # backward schedule walk used).
                    pre = new_start % nbs[k]
                    pre -= pre % old_size
                    src = frags[pre]
                    mine = ws.shard_of(
                        new_nb, new_nb // new_size, src.keys
                    ) == new_start // new_size
                    masked = src._replace(
                        keys=jnp.where(
                            mine[..., None], src.keys, jnp.uint32(0))
                    )
                    nxt[new_start] = ws.resize(masked, new_size).state
            frags = nxt
            epoch = k + 1
        return frags, epoch

    suffix = jrnl.suffix(man.block_no)
    for rec in suffix:
        frags, epoch = cross(frags, epoch, rec.block_no - 1)
        nb = nbs[epoch]
        size = sched[epoch][0][1]
        wk = jnp.asarray(rec.write_keys)
        wv = jnp.asarray(rec.write_vals)
        va = jnp.asarray(rec.valid)
        for start, _ in sched[epoch]:
            mine = ws.shard_of(nb, nb // size, wk) == (start // size)
            frags[start] = ws.commit_vectorized(
                frags[start],
                jnp.where(mine[..., None], wk, jnp.uint32(0)),
                wv,
                va,
            ).state
        frags, epoch = cross(frags, epoch, rec.block_no)
    for boundary in sorted(by_boundary):
        frags, epoch = cross(frags, epoch, boundary)

    # The final schedule entry IS the target shard's range by construction.
    (state,) = frags.values()
    head = suffix[-1].head if suffix else np.asarray(man.journal_head)
    return ShardRecoveryResult(
        state=state,
        shard=shard,
        n_shards=m,
        block_no=suffix[-1].block_no if suffix else man.block_no,
        journal_head=np.asarray(head),
        shard_digest=np.asarray(ws.state_digest(state)),
        loaded_parts=loaded,
        replayed_records=len(suffix),
        crossed_reanchors=len(reanchors),
    )


def full_replay(store, dims: types.FabricDims, *, n_buckets: int,
                slots: int) -> RecoveryResult:
    """The baseline recovery path: verify + replay the whole block chain
    (``BlockStore``), for comparison in benchmarks/fig9_recovery.py."""
    if store.base_block_no >= 0:
        raise RecoveryError(
            f"chain pruned up to block {store.base_block_no}: full replay "
            "from genesis would miss the compacted prefix (recover via "
            "snapshot + journal instead)"
        )
    if not store.verify_chain():
        raise RecoveryError("block chain does not authenticate")
    state = store.replay_state(dims, n_buckets, slots)
    return RecoveryResult(
        state=state,
        block_no=store.chain[-1].block_no if store.chain else -1,
        journal_head=journal_mod.GENESIS_HEAD,
        state_digest=np.asarray(ws.state_digest(state)),
        snapshot_block_no=-1,
        replayed_records=len(store.chain),
        n_buckets=state.n_buckets,
        overflow_bits=0,
        crossed_reanchors=0,
    )
