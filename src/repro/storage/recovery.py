"""Cold-start recovery: latest snapshot + journal suffix, fully verified.

The restart story that lets P-I keep no database: load the most recent
snapshot (verifying its content digest), verify the journal's digest chain
from the snapshot's journal head forward, then replay only that suffix of
write sets — O(blocks since last snapshot) instead of the O(chain length)
full ``BlockStore.replay_state``. The recovered peer proves it matches the
crashed one by comparing ``state_digest`` and the terminal journal head
against the live values (engine.verify's ``recovery_ok``).
"""

from __future__ import annotations

from typing import NamedTuple

import numpy as np

from repro.core import types
from repro.core import world_state as ws
from repro.storage import journal as journal_mod
from repro.storage import snapshot as snapshot_mod


class RecoveryError(RuntimeError):
    """Snapshot or journal failed authentication (or coverage is missing)."""


class RecoveryResult(NamedTuple):
    state: ws.HashState  # recovered world state (on device)
    block_no: int  # last block reflected in ``state``
    journal_head: np.ndarray  # (2,) u32 — journal head after replay
    state_digest: np.ndarray  # (2,) u32 — digest of recovered state
    snapshot_block_no: int  # -1 if recovered from genesis
    replayed_records: int  # journal suffix length


def recover(
    jrnl: journal_mod.StateJournal,
    *,
    snapshot: snapshot_mod.Snapshot | None = None,
    snapshot_dir: str | None = None,
    n_buckets: int,
    slots: int,
    value_width: int,
) -> RecoveryResult:
    """Rebuild world state from ``snapshot`` (or the newest in
    ``snapshot_dir``, or genesis) + the journal suffix after it.

    Raises :class:`RecoveryError` if the snapshot digest does not match its
    arrays, the journal chain does not verify from the snapshot's head, or
    the journal does not cover the suffix (pruned past the snapshot).
    """
    if snapshot is None and snapshot_dir is not None:
        snapshot = snapshot_mod.latest(snapshot_dir)

    if snapshot is not None:
        if not snapshot_mod.verify(snapshot):
            raise RecoveryError(
                f"snapshot at block {snapshot.block_no}: state digest "
                "mismatch (corrupt or tampered)"
            )
        state = snapshot_mod.to_state(snapshot)
        after = snapshot.block_no
        anchor = np.asarray(snapshot.journal_head)
    else:
        state = ws.create(n_buckets, slots, value_width)
        after = -1
        anchor = journal_mod.GENESIS_HEAD

    if jrnl.base_block_no > after:
        raise RecoveryError(
            f"journal pruned up to block {jrnl.base_block_no} but recovery "
            f"needs records after block {after} (no covering snapshot)"
        )
    if not jrnl.verify_chain(base_head=anchor, after_block_no=after):
        raise RecoveryError(
            f"journal chain does not authenticate after block {after} "
            "(corrupt, tampered, or missing records)"
        )

    suffix = jrnl.suffix(after)
    state = jrnl.replay(state, after_block_no=after)
    head = suffix[-1].head if suffix else anchor
    return RecoveryResult(
        state=state,
        block_no=suffix[-1].block_no if suffix else after,
        journal_head=np.asarray(head),
        state_digest=np.asarray(ws.state_digest(state)),
        snapshot_block_no=snapshot.block_no if snapshot is not None else -1,
        replayed_records=len(suffix),
    )


def full_replay(store, dims: types.FabricDims, *, n_buckets: int,
                slots: int) -> RecoveryResult:
    """The baseline recovery path: verify + replay the whole block chain
    (``BlockStore``), for comparison in benchmarks/fig9_recovery.py."""
    if store.base_block_no >= 0:
        raise RecoveryError(
            f"chain pruned up to block {store.base_block_no}: full replay "
            "from genesis would miss the compacted prefix (recover via "
            "snapshot + journal instead)"
        )
    if not store.verify_chain():
        raise RecoveryError("block chain does not authenticate")
    state = store.replay_state(dims, n_buckets, slots)
    return RecoveryResult(
        state=state,
        block_no=store.chain[-1].block_no if store.chain else -1,
        journal_head=journal_mod.GENESIS_HEAD,
        state_digest=np.asarray(ws.state_digest(state)),
        snapshot_block_no=-1,
        replayed_records=len(store.chain),
    )
