"""Authenticated state-journal + snapshot storage (the durability layer).

FastFabric's P-I drops the state database and P-II moves block storage off
the critical path, so a restarted peer must rebuild world state from the
chain — O(chain length) from genesis. This package gives the peer a restart
story that is O(journal suffix) instead:

  * :mod:`repro.storage.journal`  — append-only, digest-chained journal of
    per-block validated write sets (statejournal's "update a hash function
    with the stream of state updates" instead of a Merkle tree);
  * :mod:`repro.storage.snapshot` — periodic world-state snapshots as
    per-shard ``shard_*.npz`` files + a ``manifest_*.npz`` commitment
    (shard digests, tree head, sticky overflow bitmask), manifest-last
    atomic publication;
  * :mod:`repro.storage.recovery` — cold start: latest snapshot + journal
    suffix, with the digest chains verified end to end and resize
    re-anchor epochs crossed; ``recover_shard`` rebuilds one bucket shard
    without materializing the full table.
"""

from repro.storage import journal, recovery, snapshot  # noqa: F401
