"""Authenticated state-journal + snapshot storage (the durability layer).

FastFabric's P-I drops the state database and P-II moves block storage off
the critical path, so a restarted peer must rebuild world state from the
chain — O(chain length) from genesis. This package gives the peer a restart
story that is O(journal suffix) instead:

  * :mod:`repro.storage.journal`  — append-only, digest-chained journal of
    per-block validated write sets (statejournal's "update a hash function
    with the stream of state updates" instead of a Merkle tree);
  * :mod:`repro.storage.snapshot` — periodic compact world-state snapshots
    (device→host dump + content digest, ``.npz`` persisted);
  * :mod:`repro.storage.recovery` — cold start: latest snapshot + journal
    suffix, with the digest chain verified end to end.
"""

from repro.storage import journal, recovery, snapshot  # noqa: F401
