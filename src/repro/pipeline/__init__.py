"""Device-side block pipeline: multi-block in-flight validation.

FastFabric's P-II peer keeps many blocks in flight through a staged
validation pipeline. This subsystem is the mesh-step version of that idea:

  * :mod:`repro.pipeline.stages`       — the validation stage functions
    (syntactic checksum + unmarshal, endorsement MAC verify, MVCC + commit)
    factored out of ``launch/fabric_step.step_local`` so the depth-1 path
    and the pipelined path execute the *same* math;
  * :mod:`repro.pipeline.batched_mvcc` — the window-wide fill gather (read
    versions, write versions AND bucket free-slot counts in ONE routed
    all-to-all per pipeline fill instead of one per block), the exact
    in-window version repair, and the overflow-exact write planner that
    replays each block's commit decisions without touching the table;
  * :mod:`repro.pipeline.schedule`     — the ``lax.scan``-based
    fill/steady/drain software pipeline over a ``(D, ...)`` block window
    with double-buffered carries for the log/ledger/journal heads and the
    window write log, finished by ONE fused (key, block) last-writer-wins
    commit scatter (``world_state.commit_window``) for the whole window;
  * :mod:`repro.pipeline.engine_bridge` — the adapter that lets the
    single-host engine (``core/engine.py``) hand the mesh step a window of
    blocks per round.

Entry point: ``launch/fabric_step.make_fabric_step`` with
``FabricStepConfig.pipeline_depth > 1`` builds the pipelined step; depth 1
is byte-for-byte today's single-block path and serves as the oracle.
"""
