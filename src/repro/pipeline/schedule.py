"""Fill/steady/drain software pipeline over a (D, ...) window of blocks.

The mesh step used to validate exactly one block per invocation; this
module builds the shard_map body that pushes D blocks through the
validation stages per invocation, overlapping stages of different blocks:

  FILL    — the window-wide work that batches for free across blocks:
            local syntactic checksum + unmarshal + endorsement MAC verify
            of all D * B_loc ingested transactions at once, ONE consensus
            all-gather of the whole window's published words / ids / flags
            (instead of one per block), the window decode, and the ONE
            routed fill gather per window — read versions, write-key
            versions AND write-bucket free-slot counts ride the same
            collective (repro/pipeline/batched_mvcc.py). Then the first
            block's prepare stage primes the double buffer.
  STEADY  — a ``lax.scan`` whose iteration i runs the VALIDATE stage of
            block i (from the carried double buffer) next to the PREPARE
            stage of block i+1 (from the scan's xs). The two are
            data-independent, so block i's sequential MVCC bit-scan +
            write planning overlaps block i+1's ordering, decode
            permutation, conflict matrix and digest work.
  DRAIN   — the last block's validate stage, peeled after the scan, then
            the ONE fused window commit: the whole window write log is
            applied with a single (key, block) last-writer-wins scatter
            (``world_state.commit_window`` / the routed owner-shard
            variant) instead of one commit scatter per block.

PREPARE is a block's embarrassingly parallel precursor work (consensus
order + inverse, ordered views, conflict matrix, ledger/log digest
material); VALIDATE is the genuinely sequential tail (in-window version
repair, MVCC scan, write planning, log/ledger/journal head folds) — the
heads and the window write log ride the scan carry, double-buffered with
the prepared block. The write PLAN replays each block's commit decisions
(insert-or-update, slot budget, bucket overflow) against the fill
snapshot + the log, so no block touches the table until the fused commit;
a dropped insert contributes no version bump and the validity bits stay
byte-identical to running the depth-1 step D times — including windows
whose blocks overflow (tests/test_pipeline.py pins validity bits, all
three heads, block numbers, the sticky overflow flag, and state arrays).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import hashing, mvcc, orderer, types, unmarshal
from repro.core import world_state as ws
from repro.launch import state_sharding
from repro.pipeline import batched_mvcc, stages

U32 = jnp.uint32


class Prepared(NamedTuple):
    """One block's prepare-stage output — the pipeline's double buffer."""

    txb: types.TxBatch  # ordered, (B, ...) fields
    ok_ord: jnp.ndarray  # (B,) checksum & endorse flags, ordered
    cur_ord: jnp.ndarray  # (B, RK) fill-time read versions, ordered
    wv_ord: jnp.ndarray  # (B, WK) fill-time write-key versions, ordered
    free_ord: jnp.ndarray  # (B, WK) fill-time bucket free slots, ordered
    conflict: jnp.ndarray  # (B, B) MVCC conflict matrix
    inv: jnp.ndarray  # (B,) inverse order permutation (back to ingest)
    ledger_mat: jnp.ndarray  # (B,) ordered-row digests for the ledger fold
    log_mat: jnp.ndarray  # (B,) digests or (B, W) raw rows (serial fold)


def make_window_body(dims: types.FabricDims, cfg, msize: int, depth: int,
                     *, channel=None):
    """Build the shard_map-local body for a D-block window.

    ``channel`` (an id or tuple of ids, static) names the channel(s) this
    body serves in shape-cap raises (state_sharding.overflow_bits).

    Local input shapes (channel dim already peeled by the caller —
    launch/fabric_step vmaps this body over the local channel axis):
      keys (NB_loc, S, 2), versions, values, log/ledger/journal heads (2,),
      block_no () u32, overflow (LANES,) u32 (the sticky per-shard bitmask
      lanes, state_sharding.OVERFLOW_LANES), wire (D, B_loc, WB) u8,
      ids (D, B_loc, 2) u32.
    Returns (state arrays..., heads..., block_no, overflow, valid
    (D, B_loc)) with ``valid`` in ingest order for this rank's slice of
    every block.
    """
    spw = (unmarshal.struct_prefix_words(dims)
           if cfg.separate_metadata else None)

    def prepare(log_rows, ids_b, ok_b, cur_b, wv_b, free_b, txb_b
                ) -> Prepared:
        order = orderer.consensus_order(ids_b)
        inv = jnp.argsort(order)
        txb_t = jax.tree.map(lambda a: a[order], txb_b)
        ordered_words = log_rows[order]
        conf = mvcc.conflict_matrix(txb_t)
        ledger_mat = hashing.hash_words(ordered_words, seed=hashing.SEED_A)
        # O-II hashes consensus rows in parallel; the baseline's serial
        # seeded chain needs the raw rows at fold time.
        log_mat = (hashing.hash_words(log_rows, seed=hashing.SEED_A)
                   if cfg.pipelined else log_rows)
        return Prepared(
            txb=txb_t, ok_ord=ok_b[order], cur_ord=cur_b[order],
            wv_ord=wv_b[order], free_ord=free_b[order],
            conflict=conf, inv=inv, ledger_mat=ledger_mat, log_mat=log_mat,
        )

    def body(keys, vers, vals, log_head, ledger_head, journal_head,
             block_no, overflow, wire, ids):
        d, b_loc, wb = wire.shape
        assert d == depth
        st = ws.HashState(keys=keys, versions=vers, values=vals)
        nb_glob = st.n_buckets * (msize if cfg.shard_state else 1)
        rank = jax.lax.axis_index("model")

        # ---- FILL: stages 1+2, batched over the whole window -------------
        words, txb_loc, checksum_ok = stages.stage_syntax(
            wire.reshape(d * b_loc, wb), dims
        )
        endorse_ok = stages.stage_endorse(txb_loc)
        ok_loc = (checksum_ok & endorse_ok).reshape(d, b_loc)
        words = words.reshape(d, b_loc, -1)
        published = words[..., :spw] if cfg.separate_metadata else words

        # ---- FILL: one consensus all-gather for the whole window ---------
        log_glob = jax.lax.all_gather(
            published, "model", axis=1, tiled=True
        )  # (D, B, spw|W)
        ids_glob = jax.lax.all_gather(ids, "model", axis=1, tiled=True)
        ok_glob = jax.lax.all_gather(ok_loc, "model", axis=1, tiled=True)
        b_round = ids_glob.shape[1]

        # Window decode (ingest order) — feeds the batched fill gather.
        txb_win = stages.decode_published(
            log_glob.reshape(d * b_round, -1), dims, cfg.separate_metadata
        )

        # ---- FILL: ONE routed fill gather per window (read + write
        # versions + write-bucket free slots in the same collective) ------
        fill = batched_mvcc.gather_window_state(
            st, txb_win.read_keys, txb_win.write_keys, cfg.shard_state,
            n_buckets_global=nb_glob, n_shards=msize,
        )
        cur_win = fill.read_vers.reshape(d, b_round, -1)
        wv_win = fill.write_vers.reshape(d, b_round, -1)
        free_win = fill.write_free.reshape(d, b_round, -1)
        txb_dw = jax.tree.map(
            lambda a: a.reshape(d, b_round, *a.shape[1:]), txb_win
        )

        # ---- VALIDATE stage (block bt, from the double-buffered prep) ----
        wk = dims.wk
        lsz = b_round * wk

        def validate_stage(cstate, prep: Prepared, bt):
            (log_h, led_h, jrn_h, bno, ovf,
             wl_keys, wl_vals, wl_bumps, wl_new) = cstate
            adj = batched_mvcc.version_adjustment(
                prep.txb.read_keys, wl_keys, wl_bumps
            )
            res = mvcc.validate(
                prep.txb, prep.cur_ord + adj, checksum_ok=prep.ok_ord,
                conflict=prep.conflict,
            )
            valid = res.valid
            log_h2 = stages.fold_log_head(
                log_h, prep.log_mat, cfg, material_is_digests=cfg.pipelined
            )
            fold = (stages.fold_log_tree if cfg.tree_hash
                    else stages.fold_log_chain)
            led_h2 = fold(led_h, prep.ledger_mat ^ valid.astype(U32))
            jrn_h2 = stages.advance_journal_head(jrn_h, bno, prep.txb, valid)
            plan = batched_mvcc.plan_block_writes(
                prep.txb.write_keys, valid, cfg.sequential_commit,
                prep.wv_ord, prep.free_ord, wl_keys, wl_bumps, wl_new,
                n_buckets_global=nb_glob,
            )
            wl_keys = wl_keys.at[bt].set(plan.keys)
            wl_vals = wl_vals.at[bt].set(
                prep.txb.write_vals.reshape(lsz, -1)
            )
            wl_bumps = wl_bumps.at[bt].set(plan.bumps)
            wl_new = wl_new.at[bt].set(plan.new)
            # Sticky per-shard overflow bitmask: the plan is replicated on
            # every rank, so the owner-shard fold is collective-free and
            # must equal the depth-1 routed commit's mask bit for bit.
            ovf = ovf | state_sharding.dropped_write_bits(
                plan.keys, plan.dropped, nb_glob,
                msize if cfg.shard_state else 1, channel=channel,
            )
            mine = jax.lax.dynamic_slice_in_dim(
                valid[prep.inv], rank * b_loc, b_loc
            )
            return (
                (log_h2, led_h2, jrn_h2, bno + jnp.uint32(1), ovf,
                 wl_keys, wl_vals, wl_bumps, wl_new),
                mine,
            )

        # ---- SCHEDULE: fill P(0); steady V(i) || P(i+1); drain V(D-1),
        # then the ONE fused window commit --------------------------------
        per_block = (log_glob, ids_glob, ok_glob, cur_win, wv_win, free_win,
                     txb_dw)
        prep0 = prepare(*jax.tree.map(lambda a: a[0], per_block))
        cstate = (
            log_head, ledger_head, journal_head, block_no, overflow,
            jnp.zeros((d, lsz, 2), U32),  # window write log: keys
            jnp.zeros((d, lsz, dims.vw), U32),  # ... values
            jnp.zeros((d, lsz), bool),  # ... applied-bump flags
            jnp.zeros((d, lsz), bool),  # ... slot-consuming-insert flags
        )

        if depth > 1:
            xs = (
                jnp.arange(depth - 1),
                jax.tree.map(lambda a: a[1:], per_block),
            )

            def steady(carry, x):
                cstate, prep = carry
                bt, pin = x
                cstate2, mine = validate_stage(cstate, prep, bt)
                prep_next = prepare(*pin)  # independent of validate_stage:
                # block bt's validation overlaps block bt+1's prepare.
                return (cstate2, prep_next), mine

            (cstate, prep_last), valid_head = jax.lax.scan(
                steady, (cstate, prep0), xs
            )
        else:
            prep_last, valid_head = prep0, jnp.zeros((0, b_loc), bool)

        cstate, valid_tail = validate_stage(cstate, prep_last, depth - 1)
        (log_head, ledger_head, journal_head, block_no, overflow,
         wl_keys, wl_vals, wl_bumps, wl_new) = cstate

        # ---- COMMIT: one fused (key, block) LWW scatter for the window ---
        lk = wl_keys.reshape(-1, 2)
        lv = wl_vals.reshape(-1, dims.vw)
        lb = wl_bumps.reshape(-1)
        ln = wl_new.reshape(-1)
        if cfg.shard_state:
            st2 = state_sharding.commit_window_routed(
                st, lk, lv, lb, ln, nb_glob, msize
            )
        else:
            st2 = ws.commit_window(st, lk, lv, lb, ln)

        valid_mine = jnp.concatenate(
            [valid_head, valid_tail[None]], axis=0
        )  # (D, B_loc) ingest order, this rank's slice
        return (st2.keys, st2.versions, st2.values, log_head, ledger_head,
                journal_head, block_no, overflow, valid_mine)

    return body
