"""Window-batched MVCC state gathers + overflow-exact in-window repair.

The sharded-state fabric step (PR 2) pays one routed masked-psum lookup per
block to fetch the committed versions of the block's read keys. With D
blocks in flight, that is D collectives on the critical path — the ROADMAP
"cross-shard MVCC batching" item. This module coalesces the read AND write
sets of ALL in-flight blocks into ONE routed gather per pipeline fill
(:func:`gather_window_state`), then reconstructs, locally and exactly,
what a per-block lookup *would* have returned at each block's commit point:

  lookup-after-block-(t-1)  ==  lookup-at-fill  +  (number of APPLIED
  valid writes to that key by in-window blocks 0..t-1)

because every applied write bumps a key's version by exactly one (insert
writes version 1 == 0 + 1; update writes v + 1). "Applied" mirrors the
commit implementation in use — the vectorized commit first-wins-dedups
duplicate active keys within a block, the sequential commit bumps once per
occurrence — AND excludes writes dropped by bucket overflow: the fill
gather also fetches each write bucket's fill-time free-slot count, and
:func:`plan_block_writes` replays the commit's insert-fits decision
(rank among the window's new keys to that bucket vs the slots remaining),
so a dropped insert contributes no bump. Repairs sourced from a dropped
insert are thereby poisoned exactly — the pipelined path is byte-identical
to the depth-1 oracle even on windows whose blocks overflow. (This used to
be a documented PRECONDITION — "no bucket overflow inside a window" — and
is now a theorem the overflow regression suite in tests/test_pipeline.py
pins.)

The repair needs the valid bits of earlier in-flight blocks, which only
exist once those blocks validate — so the schedule threads a *window write
log* (keys + values + applied/new flags of planned-in-window blocks)
through its scan carry, calls :func:`version_adjustment` right before each
block's MVCC validation, and applies the whole log with ONE fused scatter
(:func:`world_state.commit_window`) after the drain. Blocks still take
effect in block order; both the read gather and the commit scatter are
hoisted out of the per-block loop.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core import hashing
from repro.core import world_state as ws
from repro.launch import state_sharding

U32 = jnp.uint32
I32 = jnp.int32


class WindowFill(NamedTuple):
    """Fill-time state snapshot for a window, gathered in one collective."""

    read_vers: jnp.ndarray  # (N, RK) u32 — committed versions of read keys
    write_vers: jnp.ndarray  # (N, WK) u32 — committed versions of write keys
    write_free: jnp.ndarray  # (N, WK) i32 — empty slots in each write
    # key's bucket at fill time (the overflow planner's slot budget)


def gather_window_state(local: ws.HashState, read_keys: jnp.ndarray,
                        write_keys: jnp.ndarray, shard_state: bool, *,
                        n_buckets_global: int, n_shards: int,
                        axis: str = "model") -> WindowFill:
    """Fetch a whole window's fill-time read/write state at once.

    ``read_keys`` (N, RK, 2) / ``write_keys`` (N, WK, 2) — the flattened
    (D * B) read and write sets of every in-flight block, in ingest order.
    Returns fill-time versions for both plus per-write-bucket free-slot
    counts: one routed masked psum over ``axis`` when the state is
    sharded (reads, writes and free counts ride the same collective), a
    single local probe otherwise.
    """
    n = read_keys.shape[0]
    rflat = read_keys.reshape(-1, 2)
    wflat = write_keys.reshape(-1, 2)
    allk = jnp.concatenate([rflat, wflat])
    if shard_state:
        vers, free = state_sharding.sharded_window_fill(
            local, allk, wflat, n_buckets_global, n_shards, axis=axis
        )
    else:
        vers = ws.lookup(local, allk).versions
        free = ws.bucket_free_slots(local, wflat)
    nr = rflat.shape[0]
    return WindowFill(
        read_vers=vers[:nr].reshape(n, -1),
        write_vers=vers[nr:].reshape(n, -1),
        write_free=free.astype(I32).reshape(n, -1),
    )


def version_adjustment(read_keys: jnp.ndarray, wlog_keys: jnp.ndarray,
                       wlog_bumps: jnp.ndarray) -> jnp.ndarray:
    """Per-read-key count of applied earlier in-window writes.

    ``read_keys`` (B, RK, 2); ``wlog_keys`` (..., 2) / ``wlog_bumps``
    (...,) — the window write log (rows of not-yet-planned blocks are
    zero, so they contribute nothing; bump flags already exclude writes
    dropped by overflow). Returns (B, RK) u32 to ADD to the fill-time
    versions.
    """
    lk = wlog_keys.reshape(-1, 2)
    lb = wlog_bumps.reshape(-1)
    eq = (
        (read_keys[..., None, 0] == lk[None, None, :, 0])
        & (read_keys[..., None, 1] == lk[None, None, :, 1])
        & (lk[None, None, :, 0] != hashing.EMPTY_KEY)
        & lb[None, None, :]
    )  # (B, RK, L)
    return eq.sum(axis=-1).astype(U32)


class BlockWritePlan(NamedTuple):
    """One block's write outcomes, flattened — the window write log row."""

    keys: jnp.ndarray  # (B*WK, 2)
    bumps: jnp.ndarray  # (B*WK,) bool — writes that advance the version
    new: jnp.ndarray  # (B*WK,) bool — bumps that consume a NEW slot
    dropped: jnp.ndarray  # (B*WK,) bool — writes dropped by overflow


def plan_block_writes(write_keys: jnp.ndarray, valid: jnp.ndarray,
                      sequential: bool, fill_vers: jnp.ndarray,
                      fill_free: jnp.ndarray, wl_keys: jnp.ndarray,
                      wl_bumps: jnp.ndarray, wl_new: jnp.ndarray, *,
                      n_buckets_global: int) -> BlockWritePlan:
    """Replay one block's commit decisions against fill state + the log.

    ``write_keys`` (B, WK, 2) and ``valid`` (B,) are the block's (ordered)
    write sets and MVCC validity bits; ``fill_vers`` / ``fill_free``
    (B, WK) the fill-time versions and bucket free-slot counts of the
    write keys; ``wl_*`` the window write log of earlier blocks. Mirrors
    the commit implementation in use exactly:

      * a key EXISTS at this block's commit point iff its fill version
        plus its applied in-window bumps is nonzero (versions never
        decrease and 0 means absent) — existing keys always apply;
      * a NEW key's insert fits iff its rank among this block's new keys
        to the same bucket is below the bucket's fill-time free slots
        minus the slots consumed by earlier in-window inserts (``wl_new``)
        — unfit inserts are DROPPED, exactly the per-block overflow;
      * duplicate active keys within the block: the vectorized commit
        applies only the first occurrence (later ones bump nothing), the
        sequential commit bumps every occurrence of an applied key.
    """
    wk = write_keys.shape[1]
    fk = write_keys.reshape(-1, 2)
    k = fk.shape[0]
    act = jnp.repeat(valid, wk) & (fk[:, 0] != hashing.EMPTY_KEY)

    # The shared dedup/ranking definitions (world_state) keep this replay
    # structurally in lockstep with the commit implementations.
    same_key = ws.same_key_matrix(fk)
    earlier = ws.earlier_mask(k)
    first = act & ~(same_key & earlier & act[None, :]).any(axis=1)
    eff = act if sequential else first  # occurrences that try to apply

    # Committed version of each write key right before this block.
    adj = version_adjustment(
        write_keys, wl_keys, wl_bumps
    ).reshape(-1)
    exists = (fill_vers.reshape(-1) + adj) > 0

    # Slot budget: fill-time free slots minus in-window consumed slots.
    bucket = ws.bucket_of(n_buckets_global, fk)
    lbuck = ws.bucket_of(n_buckets_global, wl_keys.reshape(-1, 2))
    used = (
        (bucket[:, None] == lbuck[None, :]) & wl_new.reshape(-1)[None, :]
    ).sum(axis=1)
    remaining = fill_free.reshape(-1) - used.astype(I32)

    is_new_first = first & ~exists
    same_bucket = bucket[None, :] == bucket[:, None]
    rank = (same_bucket & earlier & is_new_first[None, :]).sum(axis=1)
    fits = rank < remaining
    first_applied = first & (exists | fits)
    # An occurrence applies iff its key's first occurrence applied
    # (sequential later occurrences update the just-inserted key).
    key_ok = (same_key & first_applied[None, :]).any(axis=1)
    return BlockWritePlan(
        keys=fk,
        bumps=eff & key_ok,
        new=is_new_first & fits,
        dropped=eff & ~key_ok,
    )
