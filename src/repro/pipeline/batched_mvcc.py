"""Window-batched MVCC read-version gathers + in-window version repair.

The sharded-state fabric step (PR 2) pays one routed masked-psum lookup per
block to fetch the committed versions of the block's read keys. With D
blocks in flight, that is D collectives on the critical path — the ROADMAP
"cross-shard MVCC batching" item. This module coalesces the read sets of
ALL in-flight blocks into ONE routed gather per pipeline fill
(:func:`gather_window_versions`), then reconstructs, locally and exactly,
what a per-block lookup *would* have returned at each block's commit point:

  lookup-after-block-(t-1)  ==  lookup-at-fill  +  (number of effective
  valid writes to that key by in-window blocks 0..t-1)

because every applied write bumps a key's version by exactly one (insert
writes version 1 == 0 + 1; update writes v + 1). "Effective" mirrors the
commit implementation in use: the vectorized commit first-wins-dedups
duplicate active keys within a block, the sequential commit bumps once per
occurrence (:func:`effective_writes` reproduces both).

The repair needs the valid bits of earlier in-flight blocks, which only
exist once those blocks commit — so the schedule threads a *window write
log* (keys + effective flags of committed-in-window blocks) through its
scan carry and calls :func:`version_adjustment` right before each block's
MVCC validation. Commits still apply in block order; only the read gather
is hoisted and batched.

PRECONDITION — no bucket overflow inside a window: an insert dropped by an
overflowing commit is still counted as a bump here, whereas the depth-1
path's next block reads the real (un-bumped) table, so the byte-identical
guarantee holds only when no block in the window overflows. The depth-1
step already ignores the overflow flag for its own block; sizing tables so
blocks never overflow (as all tests/benchmarks do) satisfies both.
Threading the overflow bit through the window write log is a ROADMAP item.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import hashing, types
from repro.core import world_state as ws
from repro.launch import state_sharding

U32 = jnp.uint32


def gather_window_versions(local: ws.HashState, read_keys: jnp.ndarray,
                           shard_state: bool, *, n_buckets_global: int,
                           n_shards: int, axis: str = "model"
                           ) -> jnp.ndarray:
    """Fetch committed versions for a whole window's read sets at once.

    ``read_keys`` (N, RK, 2) — the flattened (D * B) read sets of every
    in-flight block, in ingest order. Returns (N, RK) u32 versions against
    the *fill-time* state: one routed all-to-all over ``axis`` when the
    state is sharded, a single local probe otherwise.
    """
    n = read_keys.shape[0]
    flat = read_keys.reshape(-1, 2)
    if shard_state:
        vers = state_sharding.sharded_lookup_versions(
            local, flat, n_buckets_global, n_shards, axis=axis
        )
    else:
        vers = ws.lookup(local, flat).versions
    return vers.reshape(n, -1)


def effective_writes(txb: types.TxBatch, valid: jnp.ndarray,
                     sequential: bool):
    """A committed block's version-bumping writes, flattened.

    Returns (keys (B*WK, 2), bumps (B*WK,) bool) where ``bumps`` marks the
    write slots that advanced a key's version: valid transaction, non-empty
    key, and — for the vectorized commit — not a duplicate of an earlier
    active slot (first wins, exactly ``world_state.commit_vectorized``'s
    dedup). The sequential commit bumps every occurrence, so no dedup.
    """
    fk = txb.write_keys.reshape(-1, 2)
    k = fk.shape[0]
    wk = txb.write_keys.shape[1]
    act = jnp.repeat(valid, wk) & (fk[:, 0] != hashing.EMPTY_KEY)
    if not sequential:
        same_key = (fk[:, 0][None, :] == fk[:, 0][:, None]) & (
            fk[:, 1][None, :] == fk[:, 1][:, None]
        )
        earlier = jnp.tril(jnp.ones((k, k), bool), k=-1)
        dup = (same_key & earlier & act[None, :]).any(axis=1) & act
        act = act & ~dup
    return fk, act


def version_adjustment(read_keys: jnp.ndarray, wlog_keys: jnp.ndarray,
                       wlog_bumps: jnp.ndarray) -> jnp.ndarray:
    """Per-read-key count of effective earlier in-window writes.

    ``read_keys`` (B, RK, 2); ``wlog_keys`` (..., 2) / ``wlog_bumps``
    (...,) — the window write log (rows of not-yet-committed blocks are
    zero, so they contribute nothing). Returns (B, RK) u32 to ADD to the
    fill-time versions.
    """
    lk = wlog_keys.reshape(-1, 2)
    lb = wlog_bumps.reshape(-1)
    eq = (
        (read_keys[..., None, 0] == lk[None, None, :, 0])
        & (read_keys[..., None, 1] == lk[None, None, :, 1])
        & (lk[None, None, :, 0] != hashing.EMPTY_KEY)
        & lb[None, None, :]
    )  # (B, RK, L)
    return eq.sum(axis=-1).astype(U32)
