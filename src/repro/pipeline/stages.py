"""Validation-stage functions shared by the depth-1 and pipelined steps.

These are the stages of ``launch/fabric_step.step_local``, factored out so
the software pipeline (:mod:`repro.pipeline.schedule`) can interleave them
across blocks — one block's endorsement MAC verification overlapping the
next block's state gather — while the depth-1 path keeps calling them in
program order. Both paths therefore execute the *same* math per block,
which is what makes the byte-identical oracle discipline
(tests/test_pipeline.py, same as PR 2's test_state_sharding.py) possible.

Stage map (paper's P-II pipeline):
  1. ``stage_syntax``    — byte→word bitcast, payload checksum, unmarshal;
  2. ``stage_endorse``   — endorsement MAC verification (worst case: every
     tag checked);
  3. ``stage_mvcc_commit`` — MVCC validation against the gathered read
     versions + owner-shard (or replicated) commit.
Plus the per-block head folds (consensus log, ledger, state journal) that
the schedule double-buffers through its scan carry.

Everything here runs INSIDE a shard_map body (the sharded commit uses axis
primitives); no collectives are issued by this module except through
``state_sharding.sharded_commit``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import crypto, hashing, mvcc, types, unmarshal
from repro.core import world_state as ws
from repro.launch import state_sharding
from repro.storage import journal as state_journal

U32 = jnp.uint32


# ---------------------------------------------------------------------------
# Consensus-log head folds (moved from launch/fabric_step; re-exported there)
# ---------------------------------------------------------------------------


def fold_log_chain(head, digests):
    """Chain per-row digests into the consensus log head (C-free, (2,))."""
    def fold(h, d):
        return jnp.stack(
            [hashing.combine(h[0], d), hashing.combine(h[1], d)]
        ), None

    head, _ = jax.lax.scan(fold, head, digests)
    return head


def fold_log_tree(head, digests):
    """Merkle-style pairwise reduction: O(log B) sequential depth instead
    of the O(B) chain — the beyond-paper collapse of the last serial stage
    of consensus (§Perf fabric iteration). Deterministic; head folds in
    once at the root."""
    d = digests
    while d.shape[0] > 1:
        if d.shape[0] % 2:
            d = jnp.concatenate([d, d[-1:]])
        d = hashing.combine(d[0::2], d[1::2])
    return jnp.stack(
        [hashing.combine(head[0], d[0]), hashing.combine(head[1], d[0])]
    )


def fold_log_head(log_head, log_mat, cfg, *, material_is_digests=False):
    """Advance the consensus log head over one block's replicated words.

    ``cfg.pipelined`` (O-II) hashes rows in parallel and folds digests
    (tree or chain per ``cfg.tree_hash``); the baseline replays the serial
    seeded chain, one row at a time. ``log_mat`` is the block's replicated
    rows, or — with ``material_is_digests`` — their precomputed SEED_A
    digests (the pipeline's prepare stage hashes them one step early; the
    serial baseline's fold is head-seeded, so its rows can never be
    pre-digested and the flag must stay False for it).
    """
    if cfg.pipelined:
        digests = (log_mat if material_is_digests
                   else hashing.hash_words(log_mat, seed=hashing.SEED_A))
        fold = fold_log_tree if cfg.tree_hash else fold_log_chain
        return fold(log_head, digests)

    def ser(h, row):
        d1 = hashing.hash_words(row[None, :], seed=h[0])[0]
        d2 = hashing.hash_words(row[None, :], seed=h[1])[0]
        return jnp.stack([d1, d2]), None

    log_head, _ = jax.lax.scan(ser, log_head, log_mat)
    return log_head


def fold_ledger_head(ledger_head, ordered_words, valid, cfg):
    """Ledger append over the ordered block (content + validity bits)."""
    d1 = hashing.hash_words(ordered_words, seed=hashing.SEED_A)
    fold = fold_log_tree if cfg.tree_hash else fold_log_chain
    return fold(ledger_head, d1 ^ valid.astype(U32))


def advance_journal_head(journal_head, block_no, txb: types.TxBatch, valid):
    """Fold one block's validated write sets into the state-journal head
    (storage/journal) — the commit-path half the off-path journal must
    reproduce."""
    return state_journal.update_head(
        journal_head,
        block_no,
        state_journal.write_set_digest(
            txb.write_keys, txb.write_vals, valid
        ),
    )


# ---------------------------------------------------------------------------
# Stage 1: syntactic verification (checksum + unmarshal)
# ---------------------------------------------------------------------------


def stage_syntax(wire, dims: types.FabricDims):
    """Local syntactic verification (P-II: validate-where-ingested).

    ``wire`` (B, WB) u8 → (words (B, W) u32, txb, checksum_ok (B,) bool).
    """
    b, wb = wire.shape
    words = jax.lax.bitcast_convert_type(
        wire.reshape(b, wb // 4, 4), U32
    ).reshape(b, wb // 4)
    checksum_ok = (
        unmarshal.payload_checksum(words)
        == words[:, unmarshal.CHECKSUM_WORD]
    )
    txb = unmarshal.unmarshal(wire, dims).txb
    return words, txb, checksum_ok


# ---------------------------------------------------------------------------
# Stage 2: endorsement MAC verification
# ---------------------------------------------------------------------------


def stage_endorse(txb: types.TxBatch):
    """Endorsement verification of locally ingested transactions (worst
    case: every tag checked). (B,) bool."""
    return crypto.verify_tags(txb)


# ---------------------------------------------------------------------------
# Decode of the replicated (post-consensus) words
# ---------------------------------------------------------------------------


def decode_published(words, dims: types.FabricDims, separate_metadata: bool
                     ) -> types.TxBatch:
    """Decode a batch of replicated consensus rows into a TxBatch.

    Under O-I the rows are the structured prefix; the baseline replicated
    the whole wire and must decode it again here.
    """
    if separate_metadata:
        return unmarshal.unmarshal_prefix(words, dims)
    wire_glob = jax.lax.bitcast_convert_type(
        words, jnp.uint8
    ).reshape(words.shape[0], -1)
    return unmarshal.unmarshal(wire_glob, dims).txb


# ---------------------------------------------------------------------------
# Stage 3: MVCC + commit
# ---------------------------------------------------------------------------


def stage_mvcc_commit(st: ws.HashState, txb: types.TxBatch, ok_ord, cur,
                      cfg, *, n_buckets_global: int, n_shards: int,
                      conflict=None, channel=None):
    """MVCC validation against ``cur`` read versions + state commit.

    ``cur`` (B, RK): the committed version of each read key at the time
    this block commits — from a per-block routed lookup (depth-1 path) or
    from the window-batched gather plus in-window adjustment
    (:mod:`repro.pipeline.batched_mvcc`). ``conflict``: optional
    precomputed conflict matrix (the pipeline's prepare stage computes it a
    step early). Returns (new state, valid (B,) bool, overflow (LANES,)
    u32 BITMASK — bit m of lane m//32 == shard m dropped a write on a full
    bucket; bit 0 for replicated state) — the depth-1 step ORs it sticky
    into the mesh state (a dropped insert is a silent version-accounting
    error otherwise, and the resize policy reads the hot shard off the
    bits).
    """
    res = mvcc.validate(txb, cur, checksum_ok=ok_ord, conflict=conflict)
    if cfg.shard_state:
        cres = state_sharding.sharded_commit(
            st, txb.write_keys, txb.write_vals, res.valid,
            n_buckets_global, n_shards, sequential=cfg.sequential_commit,
        )
        bits = state_sharding.overflow_bits(cres.shard_overflow,
                                            channel=channel)
    else:
        cres = ws.commit(
            st, txb.write_keys, txb.write_vals, res.valid,
            sequential=cfg.sequential_commit,
        )
        bits = state_sharding.overflow_bits(cres.overflow[None],
                                            channel=channel)
    return cres.state, res.valid, bits
