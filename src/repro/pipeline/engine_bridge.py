"""Engine ↔ mesh-step bridge: commit windows of blocks per round.

``core/engine.py`` is the single-host engine; its committer role used to
push one block at a time through ``committer.commit_block``. This adapter
lets the engine hand the MESH step (launch/fabric_step) a window of
``pipeline_depth`` blocks per invocation instead — the device-side block
pipeline — while still producing everything the storage role needs per
block (prev/block chain hashes for ``BlockStore.verify_chain``, per-tx
validity bits for the journal and the endorser-replica update).

The committer now drives N independent CHANNELS (the paper's deployment
unit — FastFabric's numbers are per channel): one ``FabricMeshState``
carries a group of channels with a leading channel dim sharded over the
mesh ``data`` axis, and the step vmaps the per-channel math so a whole
group commits in ONE dispatch. Because each channel resizes on its own
epoch schedule, channels are partitioned into *shape groups* by bucket
count: a resize drains the mesh, splits its channel out of its group, runs
the butterfly exchange on that channel alone, and re-merges it with any
group already at the new layout. Groups whose size divides the data axis
shard channels across ranks; odd-sized groups (transient, post-resize)
replicate over ``data`` until they merge back.

The engine stays the orchestrator: it orders each channel's round, slices
it into windows, ships each retired block to the store (channel-tagged),
and runs its usual durability checks against the per-channel
``state_digest`` / ``journal_head``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs as obs_mod
from repro.core import ledger, types
from repro.core import world_state as ws
from repro.launch import fabric_step as fs
from repro.launch import state_sharding

U32 = jnp.uint32


class ReanchorInfo(NamedTuple):
    """What one resize epoch commits to the journal (storage/journal
    append_reanchor): the boundary block, the layout change, the
    post-resize digest-tree head, and the sticky overflow bitmask."""

    block_no: int  # last committed block — the resize lands after it
    old_n_buckets: int  # global bucket count before
    new_n_buckets: int  # ... and after
    n_shards: int
    tree_head: np.ndarray  # (2,) u32 — shard_digest_tree of the new table
    overflow_bits: int  # sticky per-shard overflow bitmask at the boundary
    channel: int = 0  # which channel's table the epoch resized


class WindowResult(NamedTuple):
    """Per-block outputs of one committed window (block-major)."""

    valid: jnp.ndarray  # (D, B) bool, block order == input order
    prev_hash: np.ndarray  # (D, 2) u32 — store-chain prev per block
    block_hash: np.ndarray  # (D, 2) u32 — store-chain hash per block


class MultiWindowResult(NamedTuple):
    """Per-channel, per-block outputs of one multi-channel window."""

    valid: jnp.ndarray  # (C, D, B) bool
    prev_hash: np.ndarray  # (C, D, 2) u32
    block_hash: np.ndarray  # (C, D, 2) u32


@jax.jit
def _chain_hashes(prev_hash, block_no0, wire, valid):
    """Store-chain hashes for a window: (prev (D, 2), hash (D, 2))."""

    def link(prev, xs):
        wire_b, valid_b, k = xs
        digest = ledger.block_body_digest(wire_b, valid_b)
        bh = ledger.append_hash(prev, block_no0 + k, digest)
        return bh, (prev, bh)

    _, (prevs, hashes) = jax.lax.scan(
        link, prev_hash,
        (wire, valid, jnp.arange(wire.shape[0], dtype=U32)),
    )
    return prevs, hashes


@jax.jit
def _chain_hashes_multi(prev_hash, block_no0, wire, valid):
    """Channel-batched store-chain hashes: (C, D, 2) prevs and hashes."""
    return jax.vmap(_chain_hashes)(prev_hash, block_no0, wire, valid)


def make_stats_program(n_shards: int):
    """Per-group shard-stats pass (unjitted): vmapped occupancy +
    min-free reductions over a group's stacked state. Module-level so the
    committer's jit cache and the contract analyzer's registration lower
    the SAME program (repro.analysis registers it as
    ``pipeline/stats_pass``)."""

    def prog(keys, vers, vals):
        def one(k, v, va):
            st = ws.HashState(k, v, va)
            return (ws.shard_occupancy(st, n_shards),
                    ws.shard_min_free(st, n_shards))

        return jax.vmap(one)(keys, vers, vals)

    return prog


def make_resize_program(cfg: fs.FabricStepConfig, mesh, old_nb: int,
                        new_nb: int):
    """Halve/double of ONE channel's state (C=1) for ``mesh`` (unjitted).
    Sharded configs run the butterfly neighbor exchange inside shard_map;
    replicated configs resize every rank's copy locally. Module-level for
    the same reason as :func:`make_stats_program` (registered as
    ``pipeline/resize_exchange``)."""
    msize = mesh.shape["model"]
    if cfg.shard_state:
        new_nb_loc = new_nb // msize

        def body(keys, vers, vals):
            local = ws.HashState(keys[0], vers[0], vals[0])
            res = state_sharding.resize_sharded(
                local, new_nb_loc, old_nb, msize
            )
            bits = state_sharding.overflow_bits(res.shard_overflow)
            return (res.state.keys[None], res.state.versions[None],
                    res.state.values[None], bits[None])

        # A lone channel replicates over `data` (channels_over_data
        # False) — on a 1-rank data axis this is the old spec exactly.
        spec = fs.state_specs(mesh, shard_state=True,
                              channels_over_data=False)
        return fs._shard_map(
            body, mesh=mesh,
            in_specs=(spec.keys, spec.versions, spec.values),
            out_specs=(spec.keys, spec.versions, spec.values,
                       spec.overflow),
            **fs._SHARD_MAP_NO_CHECK,
        )

    def prog_fn(keys, vers, vals):
        res = jax.vmap(
            lambda k, v, va: ws.resize(ws.HashState(k, v, va), new_nb)
        )(keys, vers, vals)
        bits = jax.vmap(
            lambda o: state_sharding.overflow_bits(o[None])
        )(res.overflow)  # (C, LANES)
        return (res.state.keys, res.state.versions,
                res.state.values, bits)

    return prog_fn


class _ChannelGroup:
    """Channels sharing one bucket layout, stacked in one mesh state."""

    __slots__ = ("channels", "state")

    def __init__(self, channels: tuple[int, ...], state: fs.FabricMeshState):
        self.channels = channels
        self.state = state

    @property
    def n_buckets(self) -> int:
        return self.state.keys.shape[1]


def _take_channels(state: fs.FabricMeshState, idx: list[int]
                   ) -> fs.FabricMeshState:
    """Host-side gather of a channel subset (resize boundaries only)."""
    arrs = jax.device_get(tuple(state))
    return fs.FabricMeshState(*(jnp.asarray(a[idx]) for a in arrs))


def _concat_channels(states: list[fs.FabricMeshState]) -> fs.FabricMeshState:
    arrs = [jax.device_get(tuple(s)) for s in states]
    return fs.FabricMeshState(
        *(jnp.asarray(np.concatenate([a[i] for a in arrs]))
          for i in range(len(fs.FabricMeshState._fields)))
    )


class MeshWindowCommitter:
    """The committer role backed by the mesh fabric step, windowed.

    One instance owns ``n_channels`` independent channels (grouped by
    bucket layout, each group one ``FabricMeshState``) and feeds them
    windows of up to ``cfg.pipeline_depth`` blocks; remainder windows at a
    round's tail compile a shallower step once and reuse it. Depth-1
    windows take the single-block oracle path, so an engine driving this
    committer at depth 1 is byte-identical to depth D in every output —
    and every channel is byte-identical to a single-channel committer fed
    the same block stream (tests/test_multichannel.py).

    The single-channel surface (``commit_window``, ``state``,
    ``journal_head``, ``overflow_bits``, ``resize(nb)``...) is unchanged
    when ``n_channels == 1``; multi-channel callers use
    ``commit_windows`` and the ``*_for(channel)`` accessors.
    """

    def __init__(self, dims: types.FabricDims, cfg: fs.FabricStepConfig,
                 mesh=None, *, n_buckets: int = 1 << 12, slots: int = 8,
                 n_channels: int = 1):
        if mesh is None:
            mesh = jax.make_mesh((1, 1), ("data", "model"))
        if n_channels < 1:
            raise ValueError(f"n_channels must be >= 1, got {n_channels}")
        self.dims = dims
        self.cfg = cfg
        self.mesh = mesh
        self.n_channels = n_channels
        self.slots = slots
        self.groups: list[_ChannelGroup] = [
            _ChannelGroup(
                tuple(range(n_channels)),
                fs.create_mesh_state(
                    n_channels, dims, n_buckets=n_buckets, slots=slots
                ),
            )
        ]
        self._prev_hash: list = [jnp.zeros((2,), U32)
                                 for _ in range(n_channels)]
        self._steps: dict = {}
        self._resizes: dict = {}
        self._stats: dict = {}
        self.obs = obs_mod.Obs.disabled()
        self._hlo_gauged: set[int] = set()
        self._auditor = None

    def attach_retrace_auditor(self, auditor) -> None:
        """Route every jit this committer builds (window steps, resize
        exchange, stats pass) through ``auditor.wrap`` (repro.analysis.
        retrace.RetraceAuditor) — the contracts gate drives a live
        workload this way and fails on any trace outside the allowed
        key set. Attach BEFORE the first commit; already-built jits are
        not retrofitted."""
        self._auditor = auditor

    def _jit(self, name: str, fn, **jit_kwargs):
        """``jax.jit`` with optional cache-miss auditing under ``name``."""
        if self._auditor is not None:
            return self._auditor.wrap(name, fn, **jit_kwargs)
        return jax.jit(fn, **jit_kwargs)

    def attach_obs(self, obs) -> None:
        """Route window spans + metrics through ``obs`` (repro.obs.Obs).

        Span boundaries per window (see repro.obs.trace): ``window.fill``
        covers the async dispatch of the step AND the store-chain hash
        fold (host enqueue only), ``window.steady`` blocks until the
        device finishes the window's validate/commit work,
        ``window.drain`` covers the host transfer of the per-block
        hashes. With obs detached nothing syncs that didn't before, and
        with it attached nothing serializes that overlapped before."""
        self.obs = obs

    # -- channel bookkeeping -----------------------------------------------

    def _locate(self, channel: int) -> tuple[_ChannelGroup, int]:
        for g in self.groups:
            if channel in g.channels:
                return g, g.channels.index(channel)
        raise ValueError(
            f"channel {channel} out of range for {self.n_channels} channels"
        )

    def _channels_over_data(self, n: int) -> bool:
        return n % self.mesh.shape["data"] == 0

    @property
    def depth(self) -> int:
        return max(self.cfg.pipeline_depth, 1)

    @property
    def n_shards(self) -> int:
        """Bucket shards of a channel state: the mesh ``model`` size when
        the state is sharded, else 1 (replicated)."""
        return self.mesh.shape["model"] if self.cfg.shard_state else 1

    @property
    def prev_hash(self):
        """Channel 0's store-chain head (single-channel compat)."""
        return self._prev_hash[0]

    @property
    def state(self) -> fs.FabricMeshState:
        """THE mesh state — defined only while every channel shares one
        layout (always true for ``n_channels == 1``, the pre-multi-channel
        surface)."""
        if len(self.groups) != 1:
            raise ValueError(
                "channels hold different bucket layouts: use "
                "channel_state(c) instead of .state"
            )
        return self.groups[0].state

    def channel_state(self, channel: int) -> fs.FabricMeshState:
        """ONE channel's mesh state, with a singleton channel dim — shaped
        exactly like a single-channel committer's ``.state`` (the oracle
        the isolation tests compare against)."""
        g, pos = self._locate(channel)
        return fs.FabricMeshState(
            *(a[pos:pos + 1] for a in g.state)
        )

    @property
    def n_buckets(self) -> int:
        """CURRENT global bucket count of channel 0 (resize epochs move
        it); per-channel layouts via :meth:`n_buckets_for`."""
        return self.n_buckets_for(0)

    def n_buckets_for(self, channel: int) -> int:
        g, _ = self._locate(channel)
        return g.n_buckets

    # -- the window step ----------------------------------------------------

    def _step_for(self, d: int, channels: tuple):
        c_g = len(channels)
        over = self._channels_over_data(c_g)
        # ``channel`` only names the group's channels in shape-cap raises
        # (e.g. >64 model ranks) — it never enters the traced math, so the
        # cache stays keyed by shape alone and ignores channel identity.
        key = (d, c_g, over)
        if key not in self._steps:
            cfg = dataclasses.replace(self.cfg, pipeline_depth=d)
            chan = None if self.n_channels == 1 else channels
            # donate_argnums=(0,): the window step consumes the group
            # state in place — XLA aliases the table planes and heads
            # instead of allocating a second copy per window (the
            # contract analyzer's donation verifier pins that the alias
            # actually happens). Callers never reuse a pre-step state:
            # commit_windows reassigns g.state from the step's output
            # before anything else reads it.
            self._steps[key] = self._jit(
                f"pipeline/window_step/d{d}",
                fs.make_fabric_step(
                    self.dims, cfg, self.mesh, channels_over_data=over,
                    channel=chan,
                ),
                donate_argnums=(0,),
            )
        return self._steps[key]

    def commit_window(self, wire: jnp.ndarray, tx_ids: jnp.ndarray
                      ) -> WindowResult:
        """Commit ``wire`` (D, B, WB) / ``tx_ids`` (D, B, 2), D <= depth.
        Single-channel surface: requires ``n_channels == 1``."""
        if self.n_channels != 1:
            raise ValueError(
                "commit_window drives one channel: use commit_windows "
                f"for {self.n_channels} channels"
            )
        res = self.commit_windows(wire[None], tx_ids[None])
        return WindowResult(
            valid=res.valid[0], prev_hash=res.prev_hash[0],
            block_hash=res.block_hash[0],
        )

    def commit_windows(self, wires: jnp.ndarray, tx_ids: jnp.ndarray
                       ) -> MultiWindowResult:
        """Commit one window on EVERY channel: ``wires`` (C, D, B, WB) /
        ``tx_ids`` (C, D, B, 2), D <= depth. One mesh dispatch per shape
        group (one total while no channel has diverged its layout)."""
        if wires.shape[0] != self.n_channels:
            raise ValueError(
                f"expected {self.n_channels} channel windows, "
                f"got {wires.shape[0]}"
            )
        d = wires.shape[1]
        tracer, reg = self.obs.tracer, self.obs.registry
        t0 = time.perf_counter()
        step_by_group = [self._step_for(d, g.channels)
                         for g in self.groups]
        if self.obs.on and d not in self._hlo_gauged:
            self._record_hlo_gauges(step_by_group[0], self.groups[0],
                                    d, wires, tx_ids)
        valid_by_channel: list = [None] * self.n_channels
        prevs_by_channel: list = [None] * self.n_channels
        hashes_by_channel: list = [None] * self.n_channels
        with tracer.span("window.fill", depth=d):
            # Async dispatch only: the span measures host enqueue time of
            # the whole window — every group's step AND the store-chain
            # hash folds (dispatching all before any sync preserves the
            # overlap the uninstrumented path has).
            for g, step in zip(self.groups, step_by_group):
                chans = list(g.channels)
                wire_g = wires[jnp.asarray(chans)]
                ids_g = tx_ids[jnp.asarray(chans)]
                if d == 1:
                    g.state, valid = step(g.state, wire_g[:, 0],
                                          ids_g[:, 0])
                    valid = valid[:, None]  # (C_g, 1, B)
                else:
                    g.state, valid = step(g.state, wire_g, ids_g)
                # The step donated (and so invalidated) the pre-step
                # state; derive the window's first block number from the
                # post-step counter instead of reading it up front.
                bno0 = g.state.block_no - jnp.uint32(d)  # (C_g,)
                prev = jnp.stack([self._prev_hash[c] for c in chans])
                prevs, hashes = _chain_hashes_multi(
                    prev, bno0, wire_g, valid
                )
                for i, c in enumerate(chans):
                    self._prev_hash[c] = hashes[i, -1]
                    valid_by_channel[c] = valid[i]
                    prevs_by_channel[c] = prevs[i]
                    hashes_by_channel[c] = hashes[i]
        with tracer.span("window.steady", depth=d,
                         sync=lambda: [g.state.ledger_head
                                       for g in self.groups]):
            pass  # device executes the dispatched window inside this span
        with tracer.span("window.drain", depth=d):
            # Host transfer of the per-block chain hashes (the storage
            # role's input). This is the sync the obs-off path pays too.
            prevs = np.stack([np.asarray(p) for p in prevs_by_channel])
            hashes = np.stack([np.asarray(h) for h in hashes_by_channel])
        # Per-block commit latency, amortized over the window (blocks
        # inside a window retire together — the fused commit is the point).
        dt = (time.perf_counter() - t0) / d
        hist = reg.histogram("commit.latency")
        for _ in range(d):
            hist.record(dt)
        reg.counter("window.commits").inc()
        reg.counter("blocks.committed").inc(d * self.n_channels)
        if self.n_channels > 1:
            for c in range(self.n_channels):
                reg.counter("blocks.committed", channel=c).inc(d)
        return MultiWindowResult(
            valid=jnp.stack(valid_by_channel), prev_hash=prevs,
            block_hash=hashes,
        )

    def _record_hlo_gauges(self, jstep, group, d: int, wires, tx_ids
                           ) -> None:
        """Fold the compiled window program's cost model into gauges
        (launch/hlo_cost): collective count, wire bytes, scatter count —
        the contract numbers fig11 asserts, now visible per depth on any
        obs-enabled run. One-time per depth (AOT-lowers the same jit)."""
        from repro.launch import hlo_cost

        self._hlo_gauged.add(d)
        chans = jnp.asarray(list(group.channels))
        wire_g, ids_g = wires[chans], tx_ids[chans]
        args = ((group.state, wire_g[:, 0], ids_g[:, 0]) if d == 1
                else (group.state, wire_g, ids_g))
        try:
            an = hlo_cost.analyze(jstep.lower(*args).compile().as_text())
        except Exception:
            return  # cost model is best-effort; never fail a commit
        reg = self.obs.registry
        reg.gauge("hlo.collectives", depth=d).set(
            sum(v["count"] for v in an["collectives"].values())
        )
        reg.gauge("hlo.collective_wire_bytes", depth=d).set(
            an["collective_wire_bytes"]
        )
        reg.gauge("hlo.scatter_count", depth=d).set(an["scatter_count"])

    # -- elastic state: resize epochs --------------------------------------

    def _resize_program(self, old_nb: int, new_nb: int):
        """Jitted halve/double of ONE channel's state (C=1) for THIS mesh
        (:func:`make_resize_program`). Sharded configs run the butterfly
        neighbor exchange inside shard_map; replicated configs resize
        every rank's copy locally."""
        key = (old_nb, new_nb)
        if key not in self._resizes:
            self._resizes[key] = self._jit(
                "pipeline/resize_exchange",
                make_resize_program(self.cfg, self.mesh, old_nb, new_nb),
            )
        return self._resizes[key]

    def resize(self, new_n_buckets: int, channel: int = 0) -> ReanchorInfo:
        """Halve/double ONE channel's world state between windows.

        The epoch boundary of the elastic state: drains the in-flight
        window (the window write log assumes one partition per window, so
        with ``pipeline_depth > 1`` a resize may only land here, between
        ``commit_window(s)`` calls), splits the channel out of its shape
        group, exchanges/compacts its bucket shards, re-merges it with any
        group already at the new layout, latches any shrink overflow
        sticky, and returns the :class:`ReanchorInfo` the engine must
        commit to that channel's journal. Other channels' states, heads
        and windows are untouched — a resize drains and re-anchors only
        its own channel. The next window re-jits for the new group shapes
        automatically (jit caches per input shape).
        """
        g, pos = self._locate(channel)
        old_nb = g.n_buckets
        if new_n_buckets == old_nb:
            raise ValueError(f"resize to current size {old_nb}")
        self.block_until_ready()  # window boundary: nothing in flight
        # Split the channel out of its group (host-side; epoch-rare).
        if len(g.channels) > 1:
            rest = [i for i in range(len(g.channels)) if i != pos]
            g_state = _take_channels(g.state, rest)
            lone = _take_channels(g.state, [pos])
            g.state = g_state
            g.channels = tuple(c for c in g.channels if c != channel)
        else:
            lone = g.state
            self.groups.remove(g)
        keys, vers, vals, bits = self._resize_program(
            old_nb, new_n_buckets
        )(lone.keys, lone.versions, lone.values)
        lone = lone._replace(
            keys=keys, versions=vers, values=vals,
            overflow=lone.overflow | bits,
        )
        # Merge with an existing group at the new layout (keeps the group
        # count — and so dispatches per window — minimal).
        target = next(
            (h for h in self.groups if h.n_buckets == new_n_buckets), None
        )
        if target is None:
            self.groups.append(_ChannelGroup((channel,), lone))
        else:
            order = sorted(
                range(len(target.channels) + 1),
                key=lambda i: (target.channels + (channel,))[i],
            )
            merged = _concat_channels([target.state, lone])
            target.state = _take_channels(merged, order)
            target.channels = tuple(
                sorted(target.channels + (channel,))
            )
        g2, pos2 = self._locate(channel)
        info = ReanchorInfo(
            block_no=int(np.asarray(g2.state.block_no[pos2])) - 1,
            old_n_buckets=old_nb,
            new_n_buckets=new_n_buckets,
            n_shards=self.n_shards,
            tree_head=self.tree_head(channel),
            overflow_bits=state_sharding.bits_to_int(
                g2.state.overflow[pos2]
            ),
            channel=channel,
        )
        self.obs.tracer.event(
            "reanchor.epoch", block_no=info.block_no, channel=channel,
            old_n_buckets=old_nb, new_n_buckets=new_n_buckets,
            overflow_bits=info.overflow_bits,
        )
        return info

    # -- durability-check surface (engine.verify) --------------------------

    def _stats_program(self, c_g: int, nb: int):
        """Jitted per-group shard stats (:func:`make_stats_program`).
        Output is tiny ((C_g, M) ints), so the host read that follows is
        a few words — NOT the full-table device_get ``hash_state`` pays."""
        key = (c_g, nb)
        if key not in self._stats:
            self._stats[key] = self._jit(
                "pipeline/stats_pass", make_stats_program(self.n_shards)
            )
        return self._stats[key]

    def shard_stats(self, channels) -> dict:
        """channel -> (per-shard occupancy ``(M,)``, min free slots,
        per-shard slot capacity, sticky overflow bits) in ONE stacked
        read per shape group — the vectorized resize-policy /
        health-rollup feed (the serial path synced the host once per
        channel per round)."""
        want = set(channels)
        out = {}
        for g in self.groups:
            sel = [i for i, c in enumerate(g.channels) if c in want]
            if not sel:
                continue
            occ, mf = self._stats_program(len(g.channels), g.n_buckets)(
                g.state.keys, g.state.versions, g.state.values
            )
            occ, mf, ovf = jax.device_get((occ, mf, g.state.overflow))
            cap = g.n_buckets // self.n_shards * self.slots
            for i in sel:
                out[g.channels[i]] = (
                    np.asarray(occ[i]),
                    int(np.asarray(mf[i]).min()),
                    cap,
                    state_sharding.bits_to_int(ovf[i]),
                )
        return out

    def hash_state(self, channel: int = 0) -> ws.HashState:
        """A channel's committed world state as a single-host table
        (global view: for sharded configs the channel's concatenated
        bucket shards ARE the full table — the high-bit partition)."""
        g, pos = self._locate(channel)
        # device_get: the channel axis may be sharded over `data`, and the
        # digest reductions downstream run eagerly — a single-host copy
        # keeps them off the (unsupported) cross-device reduce path. These
        # accessors are cold (verify/snapshot), not the commit loop.
        return ws.HashState(
            keys=jnp.asarray(jax.device_get(g.state.keys[pos])),
            versions=jnp.asarray(jax.device_get(g.state.versions[pos])),
            values=jnp.asarray(jax.device_get(g.state.values[pos])),
        )

    def state_digest(self, channel: int = 0) -> np.ndarray:
        return np.asarray(ws.state_digest(self.hash_state(channel)))

    def tree_head(self, channel: int = 0) -> np.ndarray:
        """(2,) u32 digest-tree head over the per-shard digests — the
        layout-binding commitment re-anchor records and snapshot manifests
        carry (world_state.tree_head)."""
        return np.asarray(
            ws.tree_head(self.hash_state(channel), self.n_shards)
        )

    @property
    def journal_head(self) -> np.ndarray:
        return self.journal_head_for(0)

    def journal_head_for(self, channel: int) -> np.ndarray:
        g, pos = self._locate(channel)
        return np.asarray(g.state.journal_head[pos])

    def ledger_head_for(self, channel: int) -> np.ndarray:
        g, pos = self._locate(channel)
        return np.asarray(g.state.ledger_head[pos])

    @property
    def overflow(self) -> bool:
        """Sticky: any commit on ANY channel ever dropped a write on a
        full bucket — that channel's version accounting can no longer be
        trusted and ``FabricEngine.verify()`` reports it unhealthy."""
        return any(
            bool(np.asarray(g.state.overflow).any()) for g in self.groups
        )

    @property
    def overflow_bits(self) -> int:
        """Channel 0's sticky per-shard bitmask as one host int (lane
        words folded by state_sharding.bits_to_int; bit m == shard m ever
        filled)."""
        return self.overflow_bits_for(0)

    def overflow_bits_for(self, channel: int) -> int:
        g, pos = self._locate(channel)
        return state_sharding.bits_to_int(g.state.overflow[pos])

    @property
    def shard_overflow(self) -> np.ndarray:
        """(M,) bool — WHICH bucket shards of channel 0 ever filled,
        decoded from the sticky bitmask. The resize policy splits while
        this is still all False (pressure-triggered) or repairs capacity
        once a bit sets."""
        bits = self.overflow_bits
        return np.array(
            [(bits >> m) & 1 for m in range(self.n_shards)], dtype=bool
        )

    def hot_shard(self, channel: int = 0) -> int:
        """The shard a grow should relieve (recorded in the engine's
        re-anchor log): the first overflowed shard if any bit is set,
        else the fullest shard by occupancy (world_state.hot_shard)."""
        return ws.hot_shard(
            self.overflow_bits_for(channel),
            ws.shard_occupancy(self.hash_state(channel), self.n_shards),
        )

    def block_no_for(self, channel: int) -> int:
        g, pos = self._locate(channel)
        return int(np.asarray(g.state.block_no[pos]))

    def block_until_ready(self) -> None:
        jax.block_until_ready([g.state.ledger_head for g in self.groups])


# ---------------------------------------------------------------------------
# Contract-analyzer registrations (repro.analysis): the committer's two
# non-step jitted programs, built by the SAME module-level constructors
# its jit cache uses, lowered at BuildContext sizing.
# ---------------------------------------------------------------------------

from repro.analysis import registry as _areg  # noqa: E402


@_areg.register(
    "pipeline/stats_pass",
    description="stacked per-group shard occupancy/min-free reductions",
)
def _build_stats_pass(ctx):
    msize = ctx.mesh.shape["model"]
    fn = jax.jit(make_stats_program(msize))
    nb, s = ctx.n_buckets, ctx.slots
    c = max(ctx.n_channels, 1)
    sd = jax.ShapeDtypeStruct
    args = (
        sd((c, nb, s, 2), jnp.uint32),
        sd((c, nb, s), jnp.uint32),
        sd((c, nb, s, ctx.dims.vw), jnp.uint32),
    )
    return _areg.BuiltProgram(
        name="pipeline/stats_pass", fn=fn, args=args,
        meta={"n_shards": msize},
    )


@_areg.register(
    "pipeline/resize_exchange",
    description="butterfly bucket-shard exchange of one channel's table",
)
def _build_resize_exchange(ctx):
    cfg = fs.FASTFABRIC_SHARDED_STEP
    nb, s = ctx.n_buckets, ctx.slots
    fn = jax.jit(make_resize_program(cfg, ctx.mesh, nb, 2 * nb))
    sd = jax.ShapeDtypeStruct
    args = (
        sd((1, nb, s, 2), jnp.uint32),
        sd((1, nb, s), jnp.uint32),
        sd((1, nb, s, ctx.dims.vw), jnp.uint32),
    )
    return _areg.BuiltProgram(
        name="pipeline/resize_exchange", fn=fn, args=args,
        meta={"old_n_buckets": nb, "new_n_buckets": 2 * nb},
    )
