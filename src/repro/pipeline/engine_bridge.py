"""Engine ↔ mesh-step bridge: commit windows of blocks per round.

``core/engine.py`` is the single-host engine; its committer role used to
push one block at a time through ``committer.commit_block``. This adapter
lets the engine hand the MESH step (launch/fabric_step) a window of
``pipeline_depth`` blocks per invocation instead — the device-side block
pipeline — while still producing everything the storage role needs per
block (prev/block chain hashes for ``BlockStore.verify_chain``, per-tx
validity bits for the journal and the endorser-replica update).

The engine stays the orchestrator: it orders the round, slices it into
windows, ships each retired block to the store, and runs its usual
durability checks against :meth:`MeshWindowCommitter.state_digest` /
``journal_head`` instead of the per-block peer state.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ledger, types
from repro.core import world_state as ws
from repro.launch import fabric_step as fs

U32 = jnp.uint32


class WindowResult(NamedTuple):
    """Per-block outputs of one committed window (block-major)."""

    valid: jnp.ndarray  # (D, B) bool, block order == input order
    prev_hash: np.ndarray  # (D, 2) u32 — store-chain prev per block
    block_hash: np.ndarray  # (D, 2) u32 — store-chain hash per block


@jax.jit
def _chain_hashes(prev_hash, block_no0, wire, valid):
    """Store-chain hashes for a window: (prev (D, 2), hash (D, 2))."""

    def link(prev, xs):
        wire_b, valid_b, k = xs
        digest = ledger.block_body_digest(wire_b, valid_b)
        bh = ledger.append_hash(prev, block_no0 + k, digest)
        return bh, (prev, bh)

    _, (prevs, hashes) = jax.lax.scan(
        link, prev_hash,
        (wire, valid, jnp.arange(wire.shape[0], dtype=U32)),
    )
    return prevs, hashes


class MeshWindowCommitter:
    """The committer role backed by the mesh fabric step, windowed.

    One instance owns a ``FabricMeshState`` (C=1 channel) and feeds it
    windows of up to ``cfg.pipeline_depth`` blocks; remainder windows at a
    round's tail compile a shallower step once and reuse it. Depth-1
    windows take the single-block oracle path, so an engine driving this
    committer at depth 1 is byte-identical to depth D in every output.
    """

    def __init__(self, dims: types.FabricDims, cfg: fs.FabricStepConfig,
                 mesh=None, *, n_buckets: int = 1 << 12, slots: int = 8):
        if mesh is None:
            mesh = jax.make_mesh((1, 1), ("data", "model"))
        self.dims = dims
        self.cfg = cfg
        self.mesh = mesh
        self.state = fs.create_mesh_state(
            1, dims, n_buckets=n_buckets, slots=slots
        )
        self.prev_hash = jnp.zeros((2,), U32)
        self._steps: dict[int, object] = {}

    @property
    def depth(self) -> int:
        return max(self.cfg.pipeline_depth, 1)

    def _step_for(self, d: int):
        if d not in self._steps:
            cfg = dataclasses.replace(self.cfg, pipeline_depth=d)
            self._steps[d] = jax.jit(
                fs.make_fabric_step(self.dims, cfg, self.mesh)
            )
        return self._steps[d]

    def commit_window(self, wire: jnp.ndarray, tx_ids: jnp.ndarray
                      ) -> WindowResult:
        """Commit ``wire`` (D, B, WB) / ``tx_ids`` (D, B, 2), D <= depth."""
        d = wire.shape[0]
        block_no0 = self.state.block_no[0]
        step = self._step_for(d)
        if d == 1:
            self.state, valid = step(self.state, wire[0][None],
                                     tx_ids[0][None])
            valid = valid[:, None]  # (1, 1, B)
        else:
            self.state, valid = step(self.state, wire[None], tx_ids[None])
        valid = valid[0]  # (D, B)
        prevs, hashes = _chain_hashes(self.prev_hash, block_no0, wire, valid)
        self.prev_hash = hashes[-1]
        return WindowResult(
            valid=valid, prev_hash=np.asarray(prevs),
            block_hash=np.asarray(hashes),
        )

    # -- durability-check surface (engine.verify) --------------------------

    def hash_state(self) -> ws.HashState:
        """The committed world state as a single-host table (global view:
        for sharded configs the channel's concatenated bucket shards ARE
        the full table — the high-bit partition)."""
        return ws.HashState(
            keys=self.state.keys[0],
            versions=self.state.versions[0],
            values=self.state.values[0],
        )

    def state_digest(self) -> np.ndarray:
        return np.asarray(ws.state_digest(self.hash_state()))

    @property
    def journal_head(self) -> np.ndarray:
        return np.asarray(self.state.journal_head[0])

    @property
    def overflow(self) -> bool:
        """Sticky: any commit ever dropped a write on a full bucket —
        the channel's version accounting can no longer be trusted and
        ``FabricEngine.verify()`` reports it unhealthy."""
        return bool(np.asarray(self.state.overflow[0]))

    def block_until_ready(self) -> None:
        jax.block_until_ready(self.state.ledger_head)
