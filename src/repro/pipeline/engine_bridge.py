"""Engine ↔ mesh-step bridge: commit windows of blocks per round.

``core/engine.py`` is the single-host engine; its committer role used to
push one block at a time through ``committer.commit_block``. This adapter
lets the engine hand the MESH step (launch/fabric_step) a window of
``pipeline_depth`` blocks per invocation instead — the device-side block
pipeline — while still producing everything the storage role needs per
block (prev/block chain hashes for ``BlockStore.verify_chain``, per-tx
validity bits for the journal and the endorser-replica update).

The engine stays the orchestrator: it orders the round, slices it into
windows, ships each retired block to the store, and runs its usual
durability checks against :meth:`MeshWindowCommitter.state_digest` /
``journal_head`` instead of the per-block peer state.
"""

from __future__ import annotations

import dataclasses
import time
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs as obs_mod
from repro.core import ledger, types
from repro.core import world_state as ws
from repro.launch import fabric_step as fs
from repro.launch import state_sharding

U32 = jnp.uint32


class ReanchorInfo(NamedTuple):
    """What one resize epoch commits to the journal (storage/journal
    append_reanchor): the boundary block, the layout change, the
    post-resize digest-tree head, and the sticky overflow bitmask."""

    block_no: int  # last committed block — the resize lands after it
    old_n_buckets: int  # global bucket count before
    new_n_buckets: int  # ... and after
    n_shards: int
    tree_head: np.ndarray  # (2,) u32 — shard_digest_tree of the new table
    overflow_bits: int  # sticky per-shard overflow bitmask at the boundary


class WindowResult(NamedTuple):
    """Per-block outputs of one committed window (block-major)."""

    valid: jnp.ndarray  # (D, B) bool, block order == input order
    prev_hash: np.ndarray  # (D, 2) u32 — store-chain prev per block
    block_hash: np.ndarray  # (D, 2) u32 — store-chain hash per block


@jax.jit
def _chain_hashes(prev_hash, block_no0, wire, valid):
    """Store-chain hashes for a window: (prev (D, 2), hash (D, 2))."""

    def link(prev, xs):
        wire_b, valid_b, k = xs
        digest = ledger.block_body_digest(wire_b, valid_b)
        bh = ledger.append_hash(prev, block_no0 + k, digest)
        return bh, (prev, bh)

    _, (prevs, hashes) = jax.lax.scan(
        link, prev_hash,
        (wire, valid, jnp.arange(wire.shape[0], dtype=U32)),
    )
    return prevs, hashes


class MeshWindowCommitter:
    """The committer role backed by the mesh fabric step, windowed.

    One instance owns a ``FabricMeshState`` (C=1 channel) and feeds it
    windows of up to ``cfg.pipeline_depth`` blocks; remainder windows at a
    round's tail compile a shallower step once and reuse it. Depth-1
    windows take the single-block oracle path, so an engine driving this
    committer at depth 1 is byte-identical to depth D in every output.
    """

    def __init__(self, dims: types.FabricDims, cfg: fs.FabricStepConfig,
                 mesh=None, *, n_buckets: int = 1 << 12, slots: int = 8):
        if mesh is None:
            mesh = jax.make_mesh((1, 1), ("data", "model"))
        self.dims = dims
        self.cfg = cfg
        self.mesh = mesh
        self.state = fs.create_mesh_state(
            1, dims, n_buckets=n_buckets, slots=slots
        )
        self.prev_hash = jnp.zeros((2,), U32)
        self._steps: dict[int, object] = {}
        self._resizes: dict[int, object] = {}
        self.obs = obs_mod.Obs.disabled()
        self._hlo_gauged: set[int] = set()

    def attach_obs(self, obs) -> None:
        """Route window spans + metrics through ``obs`` (repro.obs.Obs).

        Span boundaries per window (see repro.obs.trace): ``window.fill``
        covers the async dispatch of the step AND the store-chain hash
        fold (host enqueue only), ``window.steady`` blocks until the
        device finishes the window's validate/commit work,
        ``window.drain`` covers the host transfer of the per-block
        hashes. With obs detached nothing syncs that didn't before, and
        with it attached nothing serializes that overlapped before."""
        self.obs = obs

    @property
    def depth(self) -> int:
        return max(self.cfg.pipeline_depth, 1)

    @property
    def n_shards(self) -> int:
        """Bucket shards of the channel state: the mesh ``model`` size when
        the state is sharded, else 1 (replicated)."""
        return self.mesh.shape["model"] if self.cfg.shard_state else 1

    @property
    def n_buckets(self) -> int:
        """CURRENT global bucket count (resize epochs change it)."""
        return self.state.keys.shape[1]

    def _step_for(self, d: int):
        if d not in self._steps:
            cfg = dataclasses.replace(self.cfg, pipeline_depth=d)
            self._steps[d] = jax.jit(
                fs.make_fabric_step(self.dims, cfg, self.mesh)
            )
        return self._steps[d]

    def commit_window(self, wire: jnp.ndarray, tx_ids: jnp.ndarray
                      ) -> WindowResult:
        """Commit ``wire`` (D, B, WB) / ``tx_ids`` (D, B, 2), D <= depth."""
        d = wire.shape[0]
        tracer, reg = self.obs.tracer, self.obs.registry
        t0 = time.perf_counter()
        block_no0 = self.state.block_no[0]
        step = self._step_for(d)
        if self.obs.on and d not in self._hlo_gauged:
            self._record_hlo_gauges(step, d, wire, tx_ids)
        with tracer.span("window.fill", depth=d):
            # Async dispatch only: the span measures host enqueue time of
            # the whole window — the step AND the store-chain hash fold
            # (dispatching both before any sync preserves the overlap the
            # uninstrumented path has; a sync between them would serialize
            # the device against the hash fold's enqueue).
            if d == 1:
                self.state, valid = step(self.state, wire[0][None],
                                         tx_ids[0][None])
                valid = valid[:, None]  # (1, 1, B)
            else:
                self.state, valid = step(self.state, wire[None],
                                         tx_ids[None])
            valid = valid[0]  # (D, B)
            prevs_d, hashes_d = _chain_hashes(
                self.prev_hash, block_no0, wire, valid
            )
            self.prev_hash = hashes_d[-1]
        with tracer.span("window.steady", depth=d,
                         sync=lambda: self.state.ledger_head):
            pass  # device executes the dispatched window inside this span
        with tracer.span("window.drain", depth=d):
            # Host transfer of the per-block chain hashes (the storage
            # role's input). This is the sync the obs-off path pays too.
            prevs, hashes = np.asarray(prevs_d), np.asarray(hashes_d)
        # Per-block commit latency, amortized over the window (blocks
        # inside a window retire together — the fused commit is the point).
        dt = (time.perf_counter() - t0) / d
        hist = reg.histogram("commit.latency")
        for _ in range(d):
            hist.record(dt)
        reg.counter("window.commits").inc()
        reg.counter("blocks.committed").inc(d)
        return WindowResult(
            valid=valid, prev_hash=prevs, block_hash=hashes,
        )

    def _record_hlo_gauges(self, jstep, d: int, wire, tx_ids) -> None:
        """Fold the compiled window program's cost model into gauges
        (launch/hlo_cost): collective count, wire bytes, scatter count —
        the contract numbers fig11 asserts, now visible per depth on any
        obs-enabled run. One-time per depth (AOT-lowers the same jit)."""
        from repro.launch import hlo_cost

        self._hlo_gauged.add(d)
        args = ((self.state, wire[0][None], tx_ids[0][None]) if d == 1
                else (self.state, wire[None], tx_ids[None]))
        try:
            an = hlo_cost.analyze(jstep.lower(*args).compile().as_text())
        except Exception:
            return  # cost model is best-effort; never fail a commit
        reg = self.obs.registry
        reg.gauge("hlo.collectives", depth=d).set(
            sum(v["count"] for v in an["collectives"].values())
        )
        reg.gauge("hlo.collective_wire_bytes", depth=d).set(
            an["collective_wire_bytes"]
        )
        reg.gauge("hlo.scatter_count", depth=d).set(an["scatter_count"])

    # -- elastic state: resize epochs --------------------------------------

    def _resize_program(self, new_nb: int):
        """Jitted halve/double of the channel state for THIS mesh. Sharded
        configs run the butterfly neighbor exchange inside shard_map;
        replicated configs resize every rank's copy locally."""
        if new_nb in self._resizes:
            return self._resizes[new_nb]
        nb = self.n_buckets
        msize = self.mesh.shape["model"]
        if self.cfg.shard_state:
            nb_loc, new_nb_loc = nb // msize, new_nb // msize

            def body(keys, vers, vals):
                local = ws.HashState(keys[0], vers[0], vals[0])
                res = state_sharding.resize_sharded(
                    local, new_nb_loc, nb, msize
                )
                bits = state_sharding.overflow_bits(res.shard_overflow)
                return (res.state.keys[None], res.state.versions[None],
                        res.state.values[None], bits[None])

            spec = fs.state_specs(self.mesh, shard_state=True)
            prog = jax.jit(fs._shard_map(
                body, mesh=self.mesh,
                in_specs=(spec.keys, spec.versions, spec.values),
                out_specs=(spec.keys, spec.versions, spec.values,
                           spec.overflow),
                **fs._SHARD_MAP_NO_CHECK,
            ))
        else:

            def prog_fn(keys, vers, vals):
                res = jax.vmap(
                    lambda k, v, va: ws.resize(
                        ws.HashState(k, v, va), new_nb
                    )
                )(keys, vers, vals)
                bits = jax.vmap(
                    lambda o: state_sharding.overflow_bits(o[None])
                )(res.overflow)  # (C, LANES)
                return (res.state.keys, res.state.versions,
                        res.state.values, bits)

            prog = jax.jit(prog_fn)
        self._resizes[new_nb] = prog
        return prog

    def resize(self, new_n_buckets: int) -> ReanchorInfo:
        """Halve/double the channel's world state between windows.

        The epoch boundary of the elastic state: drains the in-flight
        window (the window write log assumes one partition per window, so
        with ``pipeline_depth > 1`` a resize may only land here, between
        ``commit_window`` calls), exchanges/compacts the bucket shards,
        latches any shrink overflow sticky, and returns the
        :class:`ReanchorInfo` the engine must commit to its journal. The
        next window re-jits for the new shapes automatically (jit caches
        per input shape).
        """
        old_nb = self.n_buckets
        if new_n_buckets == old_nb:
            raise ValueError(f"resize to current size {old_nb}")
        self.block_until_ready()  # window boundary: nothing in flight
        keys, vers, vals, bits = self._resize_program(new_n_buckets)(
            self.state.keys, self.state.versions, self.state.values
        )
        self.state = self.state._replace(
            keys=keys, versions=vers, values=vals,
            overflow=self.state.overflow | bits,
        )
        self._resizes.clear()  # programs are shape-specific to old_nb
        info = ReanchorInfo(
            block_no=int(np.asarray(self.state.block_no[0])) - 1,
            old_n_buckets=old_nb,
            new_n_buckets=new_n_buckets,
            n_shards=self.n_shards,
            tree_head=self.tree_head(),
            overflow_bits=state_sharding.bits_to_int(self.state.overflow[0]),
        )
        self.obs.tracer.event(
            "reanchor.epoch", block_no=info.block_no,
            old_n_buckets=old_nb, new_n_buckets=new_n_buckets,
            overflow_bits=info.overflow_bits,
        )
        return info

    # -- durability-check surface (engine.verify) --------------------------

    def hash_state(self) -> ws.HashState:
        """The committed world state as a single-host table (global view:
        for sharded configs the channel's concatenated bucket shards ARE
        the full table — the high-bit partition)."""
        return ws.HashState(
            keys=self.state.keys[0],
            versions=self.state.versions[0],
            values=self.state.values[0],
        )

    def state_digest(self) -> np.ndarray:
        return np.asarray(ws.state_digest(self.hash_state()))

    def tree_head(self) -> np.ndarray:
        """(2,) u32 digest-tree head over the per-shard digests — the
        layout-binding commitment re-anchor records and snapshot manifests
        carry (world_state.tree_head)."""
        return np.asarray(ws.tree_head(self.hash_state(), self.n_shards))

    @property
    def journal_head(self) -> np.ndarray:
        return np.asarray(self.state.journal_head[0])

    @property
    def overflow(self) -> bool:
        """Sticky: any commit ever dropped a write on a full bucket —
        the channel's version accounting can no longer be trusted and
        ``FabricEngine.verify()`` reports it unhealthy."""
        return bool(np.asarray(self.state.overflow[0]).any())

    @property
    def overflow_bits(self) -> int:
        """Sticky per-shard bitmask as one host int (lane words folded by
        state_sharding.bits_to_int; bit m == shard m ever filled)."""
        return state_sharding.bits_to_int(self.state.overflow[0])

    @property
    def shard_overflow(self) -> np.ndarray:
        """(M,) bool — WHICH bucket shards ever filled, decoded from the
        sticky bitmask. The resize policy splits while this is still all
        False (pressure-triggered) or repairs capacity once a bit sets."""
        bits = self.overflow_bits
        return np.array(
            [(bits >> m) & 1 for m in range(self.n_shards)], dtype=bool
        )

    def hot_shard(self) -> int:
        """The shard a grow should relieve (recorded in the engine's
        re-anchor log): the first overflowed shard if any bit is set,
        else the fullest shard by occupancy (world_state.hot_shard)."""
        return ws.hot_shard(
            self.overflow_bits,
            ws.shard_occupancy(self.hash_state(), self.n_shards),
        )

    def block_until_ready(self) -> None:
        jax.block_until_ready(self.state.ledger_head)
