"""Deterministic, ID-ordered data pipeline (Opt O-I applied to input data).

Every batch is a pure function of (step, dp_rank) — no iterator state, no
files. Document IDs are ordered on the metadata plane
(core.orderer.consensus_order over u32 IDs); token payloads are generated
from the ID at consumption time. Consequences, exactly the paper's ledger
properties:
  * replay from step N is well-defined (checkpoint restore resumes the
    stream bit-exactly — tests/test_data.py),
  * elastic rescale re-partitions *IDs*, not buffered payloads: a worker
    joining at step N computes the same global batch as everyone else.

Task: affine-recurrence documents — token[t+1] = (m * token[t] + a) mod V
with per-document (m, a). In-context-learnable, so example drivers show a
really decreasing loss on CPU.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.models.lm import Batch

_GOLD = np.uint64(0x9E3779B97F4A7C15)


def _mix64(x: np.ndarray) -> np.ndarray:
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return x ^ (x >> np.uint64(31))


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    dp_shards: int = 1
    seed: int = 0
    n_prefix: int = 0  # vision stub positions
    d_model: int = 0  # for stub embeddings
    enc_frac: int = 0  # encdec: encoder length = seq_len // enc_frac


def doc_ids_for_step(cfg: DataConfig, step: int) -> np.ndarray:
    """Global batch of document IDs for a step (metadata plane only)."""
    base = np.uint64(step) * np.uint64(cfg.global_batch)
    ids = base + np.arange(cfg.global_batch, dtype=np.uint64)
    return _mix64(ids ^ (np.uint64(cfg.seed) * _GOLD))


def tokens_for_ids(cfg: DataConfig, ids: np.ndarray) -> np.ndarray:
    """(B,) ids -> (B, seq_len+1) tokens via the affine recurrence."""
    b = ids.shape[0]
    v = cfg.vocab
    # Derive (m, a, x0) per doc; m odd so the map is a permutation mod 2^k.
    m = (_mix64(ids) % np.uint64(max(v // 4, 2))).astype(np.int64) * 2 + 1
    a = (_mix64(ids ^ _GOLD) % np.uint64(v)).astype(np.int64)
    x0 = (_mix64(ids + np.uint64(7)) % np.uint64(v)).astype(np.int64)
    toks = np.empty((b, cfg.seq_len + 1), np.int64)
    toks[:, 0] = x0
    for t in range(cfg.seq_len):
        toks[:, t + 1] = (toks[:, t] * m + a) % v
    return toks


def global_batch_for_step(cfg: DataConfig, step: int, dp_rank: int = 0
                          ) -> Batch:
    """The dp_rank's shard of the step's global batch."""
    ids = doc_ids_for_step(cfg, step)
    per = cfg.global_batch // cfg.dp_shards
    ids = ids[dp_rank * per:(dp_rank + 1) * per]
    toks = tokens_for_ids(cfg, ids)
    inputs = toks[:, :-1].astype(np.int32)
    labels = toks[:, 1:].astype(np.int32)

    prefix = None
    enc = None
    if cfg.n_prefix and cfg.d_model:
        rng = np.random.default_rng(int(ids[0]) & 0x7FFFFFFF)
        prefix = rng.standard_normal(
            (per, cfg.n_prefix, cfg.d_model), dtype=np.float32
        )
        inputs = inputs[:, : cfg.seq_len - cfg.n_prefix]
        labels = labels[:, : cfg.seq_len - cfg.n_prefix]
    if cfg.enc_frac and cfg.d_model:
        rng = np.random.default_rng((int(ids[0]) >> 1) & 0x7FFFFFFF)
        enc = rng.standard_normal(
            (per, cfg.seq_len // cfg.enc_frac, cfg.d_model),
            dtype=np.float32,
        )
    return Batch(tokens=inputs, labels=labels, prefix_embeds=prefix,
                 enc_embeds=enc)
