"""Hash-chained async checkpoints — the fabric block store applied to
training state.

Paper mapping (§III-F): blocks are immutable and stored off the critical
path by a dedicated storage role; the in-memory world state (P-I) is safe
*because* the chain can rebuild it. Here: the training world state
(params + optimizer + ledger head) is snapshotted asynchronously by a
writer thread; every checkpoint carries
  * a content digest per leaf (FNV-1a over raw bytes),
  * a chain hash H(prev_chain, step, leaf digests) — checkpoint N commits
    to the whole history, so a restored run can prove provenance,
  * the train-ledger head (training/train_step.py), closing the loop:
    grad blocks -> step digests -> checkpoint chain.

Restore is *elastic*: arrays are saved unsharded (gathered) and re-placed
under any mesh/sharding at load (launch/train.py uses this to resume on a
different mesh shape).
"""

from __future__ import annotations

import json
import os
import queue
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np

_FNV_OFF = np.uint64(0xCBF29CE484222325)
_FNV_PRIME = np.uint64(0x100000001B3)


def _digest_bytes(buf: bytes) -> int:
    """FNV-1a over 8-byte strides (vectorized)."""
    arr = np.frombuffer(buf, dtype=np.uint8)
    pad = (-len(arr)) % 8
    if pad:
        arr = np.concatenate([arr, np.zeros(pad, np.uint8)])
    words = arr.view(np.uint64)
    mask = (1 << 64) - 1
    prime = int(_FNV_PRIME)
    h = int(_FNV_OFF)
    # Chunked horner over 64-bit words keeps this O(n) in numpy.
    for chunk in np.array_split(words, max(1, len(words) // 65536)):
        for w in chunk[:: max(1, len(chunk) // 64)]:  # strided sample
            h = ((h ^ int(w)) * prime) & mask
        h = (h ^ (len(chunk) * prime)) & mask
    return h


def _chain(prev: int, step: int, digests: list[int]) -> int:
    mask = (1 << 64) - 1
    h = (prev ^ (step * int(_FNV_PRIME))) & mask
    for d in digests:
        h = ((h ^ d) * int(_FNV_PRIME)) & mask
    return h


class Checkpointer:
    """Async writer (storage role) + elastic restorer."""

    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._q: "queue.Queue" = queue.Queue()
        self._err: Optional[Exception] = None
        self._t = threading.Thread(target=self._writer, daemon=True)
        self._t.start()

    # ------------------------------------------------------------- save path

    def save(self, step: int, state: Any, *, blocking: bool = False) -> None:
        """Snapshot (device_get) now; write off-thread (off critical path)."""
        leaves, treedef = jax.tree.flatten(state)
        host = [np.asarray(jax.device_get(l)) for l in leaves]
        self._q.put((step, host, str(treedef)))
        if blocking:
            self.wait()

    def wait(self) -> None:
        self._q.join()
        if self._err:
            raise self._err

    def _writer(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                self._q.task_done()
                return
            try:
                self._write(*item)
            except Exception as e:
                self._err = e
            finally:
                self._q.task_done()

    def _write(self, step: int, host: list, treedef_str: str) -> None:
        prev = self._latest_manifest()
        prev_chain = prev["chain"] if prev else 0
        digests = [_digest_bytes(a.tobytes()) for a in host]
        chain = _chain(prev_chain, step, digests)
        tmp = os.path.join(self.dir, f".tmp_step_{step:08d}")
        final = os.path.join(self.dir, f"step_{step:08d}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{f"leaf_{i}": a for i, a in enumerate(host)})
        manifest = {
            "step": step,
            "chain": chain,
            "prev_chain": prev_chain,
            "digests": digests,
            "treedef": treedef_str,
            "shapes": [list(a.shape) for a in host],
            "dtypes": [str(a.dtype) for a in host],
            "time": time.time(),
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()

    def _gc(self) -> None:
        steps = self.list_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ---------------------------------------------------------- restore path

    def list_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def _latest_manifest(self) -> Optional[dict]:
        steps = self.list_steps()
        if not steps:
            return None
        with open(os.path.join(
                self.dir, f"step_{steps[-1]:08d}", "manifest.json")) as f:
            return json.load(f)

    def restore(self, like: Any, *, step: Optional[int] = None,
                shardings: Any = None, verify: bool = True) -> tuple[Any, int]:
        """Load into the structure of ``like``; place per ``shardings``.

        Elastic: ``shardings`` may target any mesh (or None for default
        placement). Returns (state, step).
        """
        steps = self.list_steps()
        if not steps:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        step = steps[-1] if step is None else step
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        host = [data[f"leaf_{i}"] for i in range(len(data.files))]
        if verify:
            digests = [_digest_bytes(a.tobytes()) for a in host]
            if digests != manifest["digests"]:
                raise ValueError(f"checkpoint {step}: digest mismatch "
                                 "(corrupt or tampered)")
        leaves, treedef = jax.tree.flatten(like)
        if len(leaves) != len(host):
            raise ValueError(
                f"checkpoint {step} has {len(host)} leaves, expected "
                f"{len(leaves)} (architecture mismatch)"
            )
        shard_leaves = (jax.tree.flatten(shardings)[0] if shardings
                        is not None else [None] * len(host))
        placed = []
        for ref, arr, sh in zip(leaves, host, shard_leaves):
            arr = arr.astype(ref.dtype)
            placed.append(jax.device_put(arr, sh) if sh is not None
                          else jax.device_put(arr))
        return jax.tree.unflatten(treedef, placed), step

    def verify_chain(self) -> bool:
        """Walk every retained checkpoint and re-derive the chain."""
        prev = None
        for s in self.list_steps():
            with open(os.path.join(
                    self.dir, f"step_{s:08d}", "manifest.json")) as f:
                m = json.load(f)
            if prev is not None and m["prev_chain"] != prev:
                return False
            if _chain(m["prev_chain"], m["step"], m["digests"]) != m["chain"]:
                return False
            prev = m["chain"]
        return True

    def close(self) -> None:
        self._q.put(None)
        self._t.join()
        if self._err:
            raise self._err
