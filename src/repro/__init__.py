"""repro — FastFabric (Gorenflo et al., 2019) re-architected for TPU in JAX.

A transaction-processing engine (ordering / validation / commit) plus the
training & serving framework that embeds its principles: metadata-plane
scheduling, endorse->order->commit pipelines, in-memory hash-table world
state, and committer/endorser/storage role separation over the device mesh.
"""

__version__ = "0.1.0"
