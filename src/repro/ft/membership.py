"""Fault tolerance: failure detection, elastic membership, stragglers.

Paper mapping: permissioned fabrics run a membership service provider
(MSP) — every node is known, and the system reacts to faulty peers by
excluding them without stopping the network. Applied to the training
cluster:

  * ``HeartbeatMonitor`` — the MSP's liveness view: workers report
    heartbeats; silence past ``timeout_s`` marks a failure.
  * ``rendezvous_assign`` — deterministic highest-random-weight (HRW)
    assignment of data shards to surviving workers: when membership
    changes, only the failed worker's shards move (minimal-churn elastic
    rescale), and every survivor computes the same assignment with no
    coordinator — the consensus-free analogue of Fabric's deterministic
    ordering.
  * ``StragglerPolicy`` — the backup-endorsement rule: a microbatch whose
    endorsement (gradient) is ``beta`` x slower than the running median is
    speculatively re-executed on the fastest idle worker; first result
    wins (the paper's invalid-transaction flag never stalls the block).

All host-side and deterministic => unit-testable without a cluster
(tests/test_ft.py); launch/train.py wires the monitor + checkpoint restore
into the driver loop.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Iterable, Optional

import numpy as np

_FNV_PRIME = np.uint64(0x100000001B3)


def _h64(x: int, y: int) -> int:
    mask = (1 << 64) - 1
    h = 0xCBF29CE484222325
    for w in (x & mask, y & mask):
        h = ((h ^ w) * 0x100000001B3) & mask
        h ^= h >> 29
    return h


def rendezvous_assign(shard_ids: Iterable[int], workers: Iterable[int]
                      ) -> dict[int, int]:
    """HRW: shard -> argmax_w h(shard, w). Deterministic, minimal churn."""
    workers = list(workers)
    if not workers:
        raise ValueError("no live workers")
    return {
        s: max(workers, key=lambda w: _h64(s, w)) for s in shard_ids
    }


class HeartbeatMonitor:
    """Tracks worker liveness from heartbeat timestamps."""

    def __init__(self, workers: Iterable[int], *, timeout_s: float = 30.0,
                 clock=time.monotonic):
        self._clock = clock
        self.timeout_s = timeout_s
        now = clock()
        self._last = {w: now for w in workers}
        self._dead: set[int] = set()

    def beat(self, worker: int) -> None:
        if worker in self._dead:
            return  # must rejoin explicitly
        self._last[worker] = self._clock()

    def rejoin(self, worker: int) -> None:
        self._dead.discard(worker)
        self._last[worker] = self._clock()

    def check(self) -> set[int]:
        """Returns newly failed workers (and marks them dead)."""
        now = self._clock()
        newly = {
            w for w, t in self._last.items()
            if w not in self._dead and now - t > self.timeout_s
        }
        self._dead |= newly
        return newly

    @property
    def live(self) -> list[int]:
        return sorted(w for w in self._last if w not in self._dead)


@dataclasses.dataclass
class StragglerPolicy:
    """Backup-endorsement decision rule over observed step durations."""

    beta: float = 2.0  # re-execute if slower than beta x median
    window: int = 32

    def __post_init__(self):
        self._hist: list[float] = []

    def observe(self, duration_s: float) -> None:
        self._hist.append(duration_s)
        if len(self._hist) > self.window:
            self._hist.pop(0)

    @property
    def median(self) -> float:
        return float(np.median(self._hist)) if self._hist else 0.0

    def should_backup(self, elapsed_s: float) -> bool:
        """True if an in-flight microbatch should be speculatively
        duplicated onto an idle worker."""
        med = self.median
        return bool(med > 0 and elapsed_s > self.beta * med)


@dataclasses.dataclass
class ElasticPlan:
    """A concrete rescale decision after membership change."""

    survivors: list[int]
    assignment: dict[int, int]  # data shard -> worker
    resume_step: int

    @staticmethod
    def make(monitor: HeartbeatMonitor, n_shards: int, resume_step: int
             ) -> "ElasticPlan":
        live = monitor.live
        return ElasticPlan(
            survivors=live,
            assignment=rendezvous_assign(range(n_shards), live),
            resume_step=resume_step,
        )
