"""Top-level language models for every assigned family.

One ``LM`` class drives five block stacks (dense / moe / ssm / hybrid /
encdec) behind a uniform API:

  init(key)                                  -> params
  forward(params, batch)                     -> hidden states (B, S, D)
  loss(params, batch)                        -> (scalar CE, metrics)
  init_cache(batch, seq_len)                 -> decode cache
  prefill(params, batch, cache)              -> (last-token logits, cache)
  decode_step(params, cache, token, pos)     -> (logits, cache)

Design notes (all driven by the 40 dry-run cells):
  * Layers are stacked (leading L dim) and driven by lax.scan — HLO size
    stays O(1) in depth, which is what makes 64-layer x 512-device lowering
    tractable.
  * Logits are never materialized (B, S, V): the loss contracts hidden
    states against the vocab table in sequence chunks (``vocab_chunk``),
    bounding the f32 logits tile.
  * Attention picks ``attn_chunked`` for long sequences (exact-causal
    online softmax, see models/layers.py) and naive scores otherwise.
  * Modality frontends are stubs per the assignment: batches carry
    precomputed ``prefix_embeds`` (vision) or ``enc_embeds`` (audio).
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers, moe, ssm

Params = dict


@dataclasses.dataclass(frozen=True)
class Batch:
    """Uniform input bundle (any field may be None depending on family)."""

    tokens: jnp.ndarray  # (B, S_text) i32
    labels: Optional[jnp.ndarray] = None  # (B, S_text) i32; -1 = masked
    prefix_embeds: Optional[jnp.ndarray] = None  # (B, S_prefix, D)
    enc_embeds: Optional[jnp.ndarray] = None  # (B, S_enc, D)


jax.tree_util.register_dataclass(
    Batch,
    data_fields=["tokens", "labels", "prefix_embeds", "enc_embeds"],
    meta_fields=[],
)


@dataclasses.dataclass(frozen=True)
class DecodeCache:
    """Decode-time state. Fields unused by a family are None.

    k/v:            (L, B, S_max, Hkv, Dh) self-attention cache
    cross_k/v:      (L, B, S_enc, Hkv, Dh) encdec cross-attention cache
    conv/ssm_state: (L, B, K-1, C) / (L, B, H, P, N) mamba recurrent state
    hyb_k/v:        (Sites, B, S_max, Hkv, Dh) hybrid shared-attn caches
    """

    k: Any = None
    v: Any = None
    cross_k: Any = None
    cross_v: Any = None
    conv: Any = None
    ssm_state: Any = None
    hyb_k: Any = None
    hyb_v: Any = None


jax.tree_util.register_dataclass(
    DecodeCache,
    data_fields=["k", "v", "cross_k", "cross_v", "conv", "ssm_state",
                 "hyb_k", "hyb_v"],
    meta_fields=[],
)


def _stack_init(init_fn, key, n: int):
    """vmap an init over n layer keys -> stacked params (leading dim n)."""
    return jax.vmap(init_fn)(jax.random.split(key, n))


class LM:
    def __init__(self, cfg: ModelConfig, *, attn_impl: str = "auto",
                 q_chunk: int = 2048, kv_chunk: int = 2048,
                 ssd_chunk: int = 256, vocab_chunk: int = 512,
                 moe_capacity_factor: float = 1.25,
                 remat: str = "none", mesh_axes: tuple = (),
                 moe_dispatch: str = "sort", moe_groups: int = 1):
        self.cfg = cfg
        self.attn_impl = attn_impl
        self.q_chunk = q_chunk
        self.kv_chunk = kv_chunk
        self.ssd_chunk = ssd_chunk
        self.vocab_chunk = vocab_chunk
        self.moe_cf = moe_capacity_factor
        self.remat = remat
        # Beyond-paper §Perf knobs (baseline keeps both off/default):
        #   mesh_axes: non-empty enables explicit activation-sharding
        #   constraints — (B over dp, heads/hidden over model) — which pin
        #   GSPMD away from partial-sum attention schedules (EXPERIMENTS.md
        #   §Perf iteration 2). Must be lowered inside `with mesh:`.
        #   moe_dispatch: "sort" (distributed argsort) | "cumsum"
        #   (sort-free capacity assignment, §Perf iteration on the MoE cell)
        self.mesh_axes = tuple(mesh_axes)
        self.moe_dispatch = moe_dispatch
        self.moe_groups = moe_groups
        if mesh_axes:
            dp = tuple(a for a in mesh_axes if a in ("pod", "data"))
            self._dp = dp if len(dp) > 1 else dp[0]
        else:
            self._dp = None

    def _constrain(self, x, *spec):
        """with_sharding_constraint if mesh_axes configured, else no-op."""
        if self._dp is None or x is None:
            return x
        from jax.sharding import PartitionSpec as P

        return jax.lax.with_sharding_constraint(x, P(*spec))

    def shard_hidden(self, x):
        return self._constrain(x, self._dp, None, None)

    def shard_heads(self, x):
        """(B, S, H, D): batch over dp, heads over model."""
        return self._constrain(x, self._dp, None, "model", None)

    def shard_group(self, x):
        """MoE per-group buffers: leading group dim over dp."""
        return self._constrain(x, self._dp, *((None,) * (x.ndim - 1)))

    def _moe_kwargs(self) -> dict:
        return dict(
            capacity_factor=self.moe_cf, dispatch=self.moe_dispatch,
            groups=self.moe_groups,
            shard_group=(self.shard_group if self._dp is not None
                         and self.moe_groups > 1 else None),
        )

    # ------------------------------------------------------------------ init

    def init(self, key) -> Params:
        cfg = self.cfg
        dt = cfg.jnp_dtype
        keys = jax.random.split(key, 8)
        p: Params = {
            "embed": layers.init_embedding(
                keys[0], cfg.vocab_padded, cfg.d_model, dt
            ),
            "final_norm": layers.init_rmsnorm(cfg.d_model, dt),
        }
        if not cfg.tie_embeddings:
            p["lm_head"] = layers.init_embedding(
                keys[1], cfg.vocab_padded, cfg.d_model, dt
            )

        def dense_layer(k):
            k1, k2 = jax.random.split(k)
            return {
                "attn": layers.init_attention(k1, cfg),
                "mlp": layers.init_mlp(k2, cfg.d_model, cfg.d_ff, dt),
                "norm1": layers.init_rmsnorm(cfg.d_model, dt),
                "norm2": layers.init_rmsnorm(cfg.d_model, dt),
            }

        def moe_layer(k):
            k1, k2 = jax.random.split(k)
            return {
                "attn": layers.init_attention(k1, cfg),
                "moe": moe.init_moe(k2, cfg),
                "norm1": layers.init_rmsnorm(cfg.d_model, dt),
                "norm2": layers.init_rmsnorm(cfg.d_model, dt),
            }

        def mamba_layer(k):
            return {
                "mamba": ssm.init_mamba(k, cfg),
                "norm": layers.init_rmsnorm(cfg.d_model, dt),
            }

        def dec_layer(k):
            k1, k2, k3 = jax.random.split(k, 3)
            return {
                "self_attn": layers.init_attention(k1, cfg),
                "cross_attn": layers.init_attention(k2, cfg),
                "mlp": layers.init_mlp(k3, cfg.d_model, cfg.d_ff, dt),
                "norm1": layers.init_rmsnorm(cfg.d_model, dt),
                "norm2": layers.init_rmsnorm(cfg.d_model, dt),
                "norm3": layers.init_rmsnorm(cfg.d_model, dt),
            }

        fam = cfg.family
        if fam == "dense":
            p["layers"] = _stack_init(dense_layer, keys[2], cfg.n_layers)
        elif fam == "moe":
            p["layers"] = _stack_init(moe_layer, keys[2], cfg.n_layers)
        elif fam == "ssm":
            p["layers"] = _stack_init(mamba_layer, keys[2], cfg.n_layers)
        elif fam == "hybrid":
            p["layers"] = _stack_init(mamba_layer, keys[2], cfg.n_layers)
            p["shared_attn"] = dense_layer(keys[3])  # ONE param set, reused
        elif fam == "encdec":
            p["enc_layers"] = _stack_init(dense_layer, keys[2], cfg.enc_layers)
            p["layers"] = _stack_init(dec_layer, keys[3], cfg.n_layers)
            p["enc_final_norm"] = layers.init_rmsnorm(cfg.d_model, dt)
        else:
            raise ValueError(f"unknown family {fam}")
        return p

    # ----------------------------------------------------------- embeddings

    def _embed_inputs(self, params: Params, batch: Batch) -> jnp.ndarray:
        x = layers.embed(params["embed"], batch.tokens)
        if batch.prefix_embeds is not None:
            x = jnp.concatenate(
                [batch.prefix_embeds.astype(x.dtype), x], axis=1
            )
        return x

    def _maybe_remat(self, fn):
        if self.remat == "none":
            return fn
        policy = {
            "full": None,
            "dots": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
        }[self.remat]
        return jax.checkpoint(fn, policy=policy)

    def _attn_kwargs(self, seq: int) -> dict:
        impl = self.attn_impl
        if impl == "auto":
            impl = "chunked" if seq > 2 * self.q_chunk else "naive"
        return dict(impl=impl, q_chunk=self.q_chunk, kv_chunk=self.kv_chunk)

    # ---------------------------------------------------------- block bodies

    def _dense_block(self, lp: Params, x, positions, *, causal=True,
                     collect_kv=False, seq=None):
        cfg = self.cfg
        x = self.shard_hidden(x)
        h, kv = layers.attention(
            lp["attn"], cfg, layers.rmsnorm(lp["norm1"], x, cfg.norm_eps),
            positions=positions, causal=causal,
            shard_heads=(self.shard_heads if self._dp is not None
                         else None),
            **self._attn_kwargs(seq or x.shape[1]),
        )
        x = x + h
        mlp_in = layers.rmsnorm(lp["norm2"], x, cfg.norm_eps)
        if "moe" in lp:
            y, aux = moe.moe_mlp(lp["moe"], cfg, mlp_in,
                                 **self._moe_kwargs())
        else:
            y, aux = layers.mlp(lp["mlp"], mlp_in), jnp.float32(0)
        return x + y, aux

    def _mamba_block(self, lp: Params, x):
        cfg = self.cfg
        y = ssm.mamba_forward(
            lp["mamba"], cfg,
            layers.rmsnorm(lp["norm"], x, cfg.norm_eps),
            chunk=self.ssd_chunk,
        )
        return x + y

    # -------------------------------------------------------------- forward

    def forward(self, params: Params, batch: Batch) -> jnp.ndarray:
        """Hidden states after final norm, (B, S, D)."""
        cfg = self.cfg
        fam = cfg.family
        x = self._embed_inputs(params, batch)
        s = x.shape[1]
        positions = jnp.arange(s)

        if fam in ("dense", "moe"):
            def body(carry, lp):
                x, aux = carry
                x, a = self._dense_block(lp, x, positions, seq=s)
                return (x, aux + a), None

            body = self._maybe_remat(body)
            (x, aux), _ = jax.lax.scan(
                body, (x, jnp.float32(0)), params["layers"]
            )
            self._last_aux = aux / cfg.n_layers
        elif fam == "ssm":
            def body(x, lp):
                return self._mamba_block(lp, x), None

            body = self._maybe_remat(body)
            x, _ = jax.lax.scan(body, x, params["layers"])
        elif fam == "hybrid":
            x = self._hybrid_forward(params, x, positions)
        elif fam == "encdec":
            memory = self._encode(params, batch)
            x = self._decode_stack(params, x, positions, memory)
        x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        return x

    def _hybrid_groups(self):
        """[(site_before?, start, end)] mamba layer groups (static)."""
        cfg = self.cfg
        step = cfg.attn_every
        groups = []
        for start in range(0, cfg.n_layers, step):
            groups.append((start, min(start + step, cfg.n_layers)))
        return groups

    def _hybrid_forward(self, params, x, positions):
        cfg = self.cfg
        s = x.shape[1]
        for (start, end) in self._hybrid_groups():
            # Weight-shared attention block before each group (zamba2).
            x, _ = self._dense_block(
                params["shared_attn"], x, positions, seq=s
            )

            def body(x, lp):
                return self._mamba_block(lp, x), None

            body = self._maybe_remat(body)
            grp = jax.tree.map(lambda a: a[start:end], params["layers"])
            x, _ = jax.lax.scan(body, x, grp)
        return x

    def _encode(self, params, batch: Batch) -> jnp.ndarray:
        cfg = self.cfg
        mem = batch.enc_embeds.astype(cfg.jnp_dtype)
        pos = jnp.arange(mem.shape[1])

        def body(x, lp):
            x, _ = self._dense_block(lp, x, pos, causal=False,
                                     seq=mem.shape[1])
            return x, None

        body = self._maybe_remat(body)
        mem, _ = jax.lax.scan(body, mem, params["enc_layers"])
        return layers.rmsnorm(params["enc_final_norm"], mem, cfg.norm_eps)

    def _decode_stack(self, params, x, positions, memory):
        cfg = self.cfg
        s = x.shape[1]

        def body(x, lp):
            h, _ = layers.attention(
                lp["self_attn"], cfg,
                layers.rmsnorm(lp["norm1"], x, cfg.norm_eps),
                positions=positions, causal=True, shard_heads=(self.shard_heads if self._dp is not None
                             else None),
                **self._attn_kwargs(s),
            )
            x = x + h
            h, _ = layers.attention(
                lp["cross_attn"], cfg,
                layers.rmsnorm(lp["norm2"], x, cfg.norm_eps),
                positions=positions, memory=memory,
                **self._attn_kwargs(s),
            )
            x = x + h
            x = x + layers.mlp(
                lp["mlp"], layers.rmsnorm(lp["norm3"], x, cfg.norm_eps)
            )
            return x, None

        body = self._maybe_remat(body)
        x, _ = jax.lax.scan(body, x, params["layers"])
        return x

    # ------------------------------------------------------------------ loss

    def loss(self, params: Params, batch: Batch):
        """Chunked-vocab causal LM loss. Labels -1 are masked out."""
        cfg = self.cfg
        h = self.forward(params, batch)  # (B, S, D)
        if batch.prefix_embeds is not None:
            h = h[:, batch.prefix_embeds.shape[1]:]  # loss on text only
        labels = batch.labels
        b, s, d = h.shape
        table = params["embed"] if cfg.tie_embeddings else params["lm_head"]

        c = min(self.vocab_chunk, s)
        while s % c:
            c -= 1
        ns = s // c
        hc = jnp.moveaxis(h.reshape(b, ns, c, d), 1, 0)  # (ns, B, c, D)
        yc = jnp.moveaxis(labels.reshape(b, ns, c), 1, 0)

        vpad = cfg.vocab_padded

        def body(carry, inp):
            tot, cnt = carry
            hs, ys = inp
            logits = layers.unembed(table, hs, transpose=True)  # (B,c,Vp) f32
            if vpad != cfg.vocab:  # mask padded vocab rows out of the lse
                pad_mask = jnp.arange(vpad) >= cfg.vocab
                logits = jnp.where(pad_mask, -jnp.inf, logits)
            lse = jax.scipy.special.logsumexp(logits, axis=-1)
            mask = ys >= 0
            safe = jnp.maximum(ys, 0)
            ll = jnp.take_along_axis(
                logits, safe[..., None], axis=-1
            )[..., 0]
            tot = tot + jnp.sum(jnp.where(mask, lse - ll, 0.0))
            cnt = cnt + jnp.sum(mask)
            return (tot, cnt), None

        (tot, cnt), _ = jax.lax.scan(
            body, (jnp.float32(0), jnp.int32(0)), (hc, yc)
        )
        ce = tot / jnp.maximum(cnt, 1)
        metrics = {"ce": ce, "tokens": cnt}
        aux = getattr(self, "_last_aux", None)
        if cfg.family == "moe" and aux is not None:
            metrics["aux"] = aux
            return ce + 0.01 * aux, metrics
        return ce, metrics

    def logits(self, params: Params, batch: Batch) -> jnp.ndarray:
        """Full logits — small models / tests only."""
        cfg = self.cfg
        h = self.forward(params, batch)
        table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
        return layers.unembed(table, h, transpose=True)[..., : cfg.vocab]

    # ----------------------------------------------------------------- cache

    def init_cache(self, batch_size: int, seq_len: int,
                   enc_len: int = 0) -> DecodeCache:
        cfg = self.cfg
        dt = cfg.jnp_dtype
        l, kvh, hd = cfg.n_layers, cfg.n_kv, cfg.head_dim
        kv_shape = (l, batch_size, seq_len, kvh, hd)
        fam = cfg.family
        if fam in ("dense", "moe"):
            return DecodeCache(k=jnp.zeros(kv_shape, dt),
                               v=jnp.zeros(kv_shape, dt))
        if fam == "ssm":
            return DecodeCache(
                conv=jnp.zeros(
                    (l, batch_size, cfg.d_conv - 1,
                     cfg.d_inner + 2 * cfg.ssm_state), dt),
                ssm_state=jnp.zeros(
                    (l, batch_size, cfg.ssm_heads, cfg.ssm_head_dim,
                     cfg.ssm_state), jnp.float32),
            )
        if fam == "hybrid":
            sites = len(self._hybrid_groups())
            return DecodeCache(
                conv=jnp.zeros(
                    (l, batch_size, cfg.d_conv - 1,
                     cfg.d_inner + 2 * cfg.ssm_state), dt),
                ssm_state=jnp.zeros(
                    (l, batch_size, cfg.ssm_heads, cfg.ssm_head_dim,
                     cfg.ssm_state), jnp.float32),
                hyb_k=jnp.zeros((sites, batch_size, seq_len, kvh, hd), dt),
                hyb_v=jnp.zeros((sites, batch_size, seq_len, kvh, hd), dt),
            )
        if fam == "encdec":
            return DecodeCache(
                k=jnp.zeros(kv_shape, dt), v=jnp.zeros(kv_shape, dt),
                cross_k=jnp.zeros((l, batch_size, enc_len, kvh, hd), dt),
                cross_v=jnp.zeros((l, batch_size, enc_len, kvh, hd), dt),
            )
        raise ValueError(fam)

    # --------------------------------------------------------------- prefill

    def prefill(self, params: Params, batch: Batch, cache: DecodeCache):
        """Process the prompt, fill the cache, return last-token logits.

        Works per-family; the returned cache is positioned at
        pos = prompt length (callers pass it to decode_step).
        """
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        b, s, d = x.shape
        positions = jnp.arange(s)
        fam = cfg.family

        if fam in ("dense", "moe"):
            def body(x, lp):
                h, (key, val) = layers.attention(
                    lp["attn"], cfg,
                    layers.rmsnorm(lp["norm1"], x, cfg.norm_eps),
                    positions=positions, causal=True,
                    shard_heads=(self.shard_heads if self._dp is not None
                             else None),
                **self._attn_kwargs(s),
                )
                x = x + h
                mlp_in = layers.rmsnorm(lp["norm2"], x, cfg.norm_eps)
                if "moe" in lp:
                    y, _ = moe.moe_mlp(lp["moe"], cfg, mlp_in,
                                       **self._moe_kwargs())
                else:
                    y = layers.mlp(lp["mlp"], mlp_in)
                return x + y, (key, val)

            x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
            smax = cache.k.shape[2]
            pad = smax - s
            ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            cache = dataclasses.replace(cache, k=ks.astype(cache.k.dtype),
                                        v=vs.astype(cache.v.dtype))
        elif fam == "ssm":
            def body(x, lp):
                y, conv, st = ssm.mamba_forward(
                    lp["mamba"], cfg,
                    layers.rmsnorm(lp["norm"], x, cfg.norm_eps),
                    chunk=self.ssd_chunk, return_state=True,
                )
                return x + y, (conv, st)

            x, (convs, states) = jax.lax.scan(body, x, params["layers"])
            cache = dataclasses.replace(
                cache, conv=convs.astype(cache.conv.dtype), ssm_state=states
            )
        elif fam == "hybrid":
            cache = self._hybrid_prefill(params, batch, cache)
            return cache  # (logits, cache) packed inside
        elif fam == "encdec":
            return self._encdec_prefill(params, batch, cache)
        else:
            raise ValueError(fam)

        x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        last = x[:, -1]
        table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
        logits = layers.unembed(table, last[:, None], transpose=True)[:, 0][:, : cfg.vocab]
        return logits, cache

    def _hybrid_prefill(self, params, batch: Batch, cache: DecodeCache):
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        b, s, _ = x.shape
        positions = jnp.arange(s)
        convs, states, hks, hvs = [], [], [], []
        for gi, (start, end) in enumerate(self._hybrid_groups()):
            lp = params["shared_attn"]
            h, (key, val) = layers.attention(
                lp["attn"], cfg,
                layers.rmsnorm(lp["norm1"], x, cfg.norm_eps),
                positions=positions, causal=True, shard_heads=(self.shard_heads if self._dp is not None
                             else None),
                **self._attn_kwargs(s),
            )
            hks.append(key)
            hvs.append(val)
            x = x + h
            x = x + layers.mlp(
                lp["mlp"], layers.rmsnorm(lp["norm2"], x, cfg.norm_eps)
            )

            def body(x, lpm):
                y, conv, st = ssm.mamba_forward(
                    lpm["mamba"], cfg,
                    layers.rmsnorm(lpm["norm"], x, cfg.norm_eps),
                    chunk=self.ssd_chunk, return_state=True,
                )
                return x + y, (conv, st)

            grp = jax.tree.map(lambda a: a[start:end], params["layers"])
            x, (cv, st) = jax.lax.scan(body, x, grp)
            convs.append(cv)
            states.append(st)

        smax = cache.hyb_k.shape[2]
        pad = smax - s
        hk = jnp.pad(jnp.stack(hks), ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        hv = jnp.pad(jnp.stack(hvs), ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        cache = dataclasses.replace(
            cache,
            conv=jnp.concatenate(convs).astype(cache.conv.dtype),
            ssm_state=jnp.concatenate(states),
            hyb_k=hk.astype(cache.hyb_k.dtype),
            hyb_v=hv.astype(cache.hyb_v.dtype),
        )
        x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
        logits = layers.unembed(table, x[:, -1:], transpose=True)[:, 0][:, : cfg.vocab]
        return logits, cache

    def _encdec_prefill(self, params, batch: Batch, cache: DecodeCache):
        cfg = self.cfg
        memory = self._encode(params, batch)
        b = memory.shape[0]

        # Precompute cross-attention K/V once per layer.
        def cross_kv(lp):
            key = (memory @ lp["cross_attn"]["wk"].astype(memory.dtype)
                   ).reshape(b, -1, cfg.n_kv, cfg.head_dim)
            val = (memory @ lp["cross_attn"]["wv"].astype(memory.dtype)
                   ).reshape(b, -1, cfg.n_kv, cfg.head_dim)
            return key, val

        cks, cvs = jax.vmap(cross_kv)(params["layers"])

        x = layers.embed(params["embed"], batch.tokens)
        s = x.shape[1]
        positions = jnp.arange(s)

        def body(x, inp):
            lp, ck, cv = inp
            h, (key, val) = layers.attention(
                lp["self_attn"], cfg,
                layers.rmsnorm(lp["norm1"], x, cfg.norm_eps),
                positions=positions, causal=True, shard_heads=(self.shard_heads if self._dp is not None
                             else None),
                **self._attn_kwargs(s),
            )
            x = x + h
            h2 = layers.attn_naive(
                (layers.rmsnorm(lp["norm2"], x, cfg.norm_eps)
                 @ lp["cross_attn"]["wq"].astype(x.dtype)
                 ).reshape(b, s, cfg.n_heads, cfg.head_dim),
                ck, cv, causal=False,
            ).reshape(b, s, cfg.n_heads * cfg.head_dim)
            x = x + h2 @ lp["cross_attn"]["wo"].astype(x.dtype)
            x = x + layers.mlp(
                lp["mlp"], layers.rmsnorm(lp["norm3"], x, cfg.norm_eps)
            )
            return x, (key, val)

        x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cks, cvs))
        smax = cache.k.shape[2]
        pad = smax - s
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        cache = dataclasses.replace(
            cache, k=ks.astype(cache.k.dtype), v=vs.astype(cache.v.dtype),
            cross_k=cks.astype(cache.cross_k.dtype),
            cross_v=cvs.astype(cache.cross_v.dtype),
        )
        x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
        logits = layers.unembed(table, x[:, -1:], transpose=True)[:, 0][:, : cfg.vocab]
        return logits, cache

    # ------------------------------------------------------------ decode step

    def decode_step(self, params: Params, cache: DecodeCache,
                    token: jnp.ndarray, pos: jnp.ndarray):
        """One token for the whole batch. token (B,) i32, pos () i32.

        Returns (logits (B, V) f32, updated cache).
        """
        cfg = self.cfg
        fam = cfg.family
        x = layers.embed(params["embed"], token)[:, None, :]  # (B, 1, D)
        positions = pos[None] if pos.ndim == 0 else pos

        if fam in ("dense", "moe"):
            def body(x, inp):
                lp, ck, cv = inp
                h, kv = layers.attention(
                    lp["attn"], cfg,
                    layers.rmsnorm(lp["norm1"], x, cfg.norm_eps),
                    positions=positions, kv_cache=(ck, cv), cache_len=pos,
                )
                x = x + h
                mlp_in = layers.rmsnorm(lp["norm2"], x, cfg.norm_eps)
                if "moe" in lp:
                    y, _ = moe.moe_mlp(lp["moe"], cfg, mlp_in,
                                       **self._moe_kwargs())
                else:
                    y = layers.mlp(lp["mlp"], mlp_in)
                return x + y, kv

            x, (ks, vs) = jax.lax.scan(
                body, x, (params["layers"], cache.k, cache.v)
            )
            cache = dataclasses.replace(cache, k=ks, v=vs)
        elif fam == "ssm":
            def body(x, inp):
                lp, conv, st = inp
                y, conv, st = ssm.mamba_decode_step(
                    lp["mamba"], cfg,
                    layers.rmsnorm(lp["norm"], x, cfg.norm_eps), conv, st,
                )
                return x + y, (conv, st)

            x, (convs, states) = jax.lax.scan(
                body, x, (params["layers"], cache.conv, cache.ssm_state)
            )
            cache = dataclasses.replace(cache, conv=convs, ssm_state=states)
        elif fam == "hybrid":
            x, cache = self._hybrid_decode(params, cache, x, positions, pos)
        elif fam == "encdec":
            def body(x, inp):
                lp, ck, cv, xk, xv = inp
                h, kv = layers.attention(
                    lp["self_attn"], cfg,
                    layers.rmsnorm(lp["norm1"], x, cfg.norm_eps),
                    positions=positions, kv_cache=(ck, cv), cache_len=pos,
                )
                x = x + h
                b = x.shape[0]
                q = (layers.rmsnorm(lp["norm2"], x, cfg.norm_eps)
                     @ lp["cross_attn"]["wq"].astype(x.dtype)).reshape(
                    b, 1, cfg.n_heads, cfg.head_dim
                )
                h2 = layers.attn_grouped(q, xk, xv, causal=False).reshape(
                    b, 1, cfg.n_heads * cfg.head_dim
                )
                x = x + h2 @ lp["cross_attn"]["wo"].astype(x.dtype)
                x = x + layers.mlp(
                    lp["mlp"], layers.rmsnorm(lp["norm3"], x, cfg.norm_eps)
                )
                return x, kv

            x, (ks, vs) = jax.lax.scan(
                body, x,
                (params["layers"], cache.k, cache.v, cache.cross_k,
                 cache.cross_v),
            )
            cache = dataclasses.replace(cache, k=ks, v=vs)
        else:
            raise ValueError(fam)

        x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
        logits = layers.unembed(table, x, transpose=True)[:, 0][:, : cfg.vocab]
        return logits, cache

    def _hybrid_decode(self, params, cache, x, positions, pos):
        cfg = self.cfg
        new_hk, new_hv, new_conv, new_st = [], [], [], []
        for gi, (start, end) in enumerate(self._hybrid_groups()):
            lp = params["shared_attn"]
            h, kv = layers.attention(
                lp["attn"], cfg,
                layers.rmsnorm(lp["norm1"], x, cfg.norm_eps),
                positions=positions,
                kv_cache=(cache.hyb_k[gi], cache.hyb_v[gi]), cache_len=pos,
            )
            new_hk.append(kv[0])
            new_hv.append(kv[1])
            x = x + h
            x = x + layers.mlp(
                lp["mlp"], layers.rmsnorm(lp["norm2"], x, cfg.norm_eps)
            )

            def body(x, inp):
                lpm, conv, st = inp
                y, conv, st = ssm.mamba_decode_step(
                    lpm["mamba"], cfg,
                    layers.rmsnorm(lpm["norm"], x, cfg.norm_eps), conv, st,
                )
                return x + y, (conv, st)

            grp = jax.tree.map(lambda a: a[start:end], params["layers"])
            x, (cv, st) = jax.lax.scan(
                body, x, (grp, cache.conv[start:end],
                          cache.ssm_state[start:end])
            )
            new_conv.append(cv)
            new_st.append(st)
        cache = dataclasses.replace(
            cache,
            hyb_k=jnp.stack(new_hk), hyb_v=jnp.stack(new_hv),
            conv=jnp.concatenate(new_conv),
            ssm_state=jnp.concatenate(new_st),
        )
        return x, cache
