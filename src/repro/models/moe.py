"""Mixture-of-Experts MLP: shared experts + routed top-k, sort-based dispatch.

Dispatch is the TPU-idiomatic sort/scatter formulation (MegaBlocks-lite):
token->expert assignments are sorted, packed into a capacity-bounded
(E, C, D) buffer, run through batched expert matmuls, and gathered back.
Under pjit the buffer and expert weights shard over the mesh ``model``
(=expert-parallel) axis, so the scatter/gather lower to the EP all-to-all.
Overflowing tokens are *dropped* (their residual passes through — standard
capacity-factor semantics); tests cover conservation at cf where no drops
occur vs the dense oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers

Params = dict


def init_moe(key, cfg: ModelConfig) -> Params:
    dt = cfg.jnp_dtype
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    import math
    scale_in = 1.0 / math.sqrt(d)
    scale_out = 1.0 / math.sqrt(f)

    def ew(k, din, dout, scale):
        return (jax.random.truncated_normal(k, -2, 2, (e, din, dout))
                * scale).astype(dt)

    p = {
        "router": layers._dense_init(ks[0], d, e, jnp.float32),
        "w_gate": ew(ks[1], d, f, scale_in),
        "w_up": ew(ks[2], d, f, scale_in),
        "w_down": ew(ks[3], f, d, scale_out),
    }
    if cfg.n_shared:
        p["shared"] = layers.init_mlp(ks[4], d, cfg.n_shared * cfg.d_ff, dt)
    return p


def route(router_w: jnp.ndarray, x2d: jnp.ndarray, top_k: int):
    """Router: (T, D) -> (weights (T,K) f32, experts (T,K) i32, aux loss)."""
    logits = x2d.astype(jnp.float32) @ router_w  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, experts = jax.lax.top_k(probs, top_k)
    weights = weights / jnp.maximum(
        weights.sum(axis=-1, keepdims=True), 1e-9
    )
    # Load-balancing auxiliary loss (Switch-style): E * sum_e f_e * p_e.
    e = router_w.shape[1]
    hits = jax.nn.one_hot(experts[:, 0], e, dtype=jnp.float32).mean(axis=0)
    mean_prob = probs.mean(axis=0)
    aux = e * jnp.sum(hits * mean_prob)
    return weights, experts, aux


def moe_mlp(p: Params, cfg: ModelConfig, x: jnp.ndarray,
            *, capacity_factor: float = 1.25, dispatch: str = "sort",
            groups: int = 1, shard_group=None):
    """(B, S, D) -> ((B, S, D), aux_loss). Shared experts always-on.

    ``dispatch``:
      "sort"   — stable argsort of token->expert assignments (baseline;
                 under pjit the sort over the data-sharded token dim lowers
                 to an expensive distributed sort).
      "cumsum" — sort-free: position-in-expert via a cumulative count of
                 one-hot assignments. Same drop semantics, identical
                 results (tests assert so); the cumsum lowers to cheap
                 collective-permute carries instead of a global sort
                 (§Perf iteration on the MoE cells).
    ``groups`` > 1 — per-data-shard dispatch: tokens scatter into a
      per-group (G, E, C/G, D) buffer (group dim sharded over DP via
      ``shard_group``), so packing is collective-free and the only EP
      communication left is the buffer<->expert all-to-all at the expert
      matmul. Capacity is enforced per group (the production semantics);
      with no drops the result equals the global dispatch exactly.
    """
    b, s, d = x.shape
    t = b * s
    k = cfg.top_k
    e = cfg.n_experts
    x2d = x.reshape(t, d)

    weights, experts, aux = route(p["router"], x2d, k)

    if groups > 1 and t % groups == 0:
        return _moe_grouped(p, cfg, x, x2d, weights, experts, aux,
                            capacity_factor, groups, shard_group)

    flat_e = experts.reshape(t * k)  # (TK,)
    flat_w = weights.reshape(t * k)
    tok_of_slot = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    cap = int(max(1, capacity_factor * t * k / e))

    if dispatch == "sort":
        order = jnp.argsort(flat_e, stable=True)  # (TK,)
        sorted_e = flat_e[order]
        sorted_tok = tok_of_slot[order]
        sorted_w = flat_w[order]
        # Position within the expert's group: arange - group start offset.
        counts = jnp.bincount(flat_e, length=e)  # (E,)
        starts = jnp.concatenate(
            [jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]]
        )
        pos = jnp.arange(t * k, dtype=jnp.int32) - starts[sorted_e].astype(
            jnp.int32
        )
    elif dispatch == "cumsum":
        # pos[i] = #{j < i : e_j == e_i} — an exclusive cumulative count.
        onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)  # (TK, E)
        pos_all = jnp.cumsum(onehot, axis=0) - onehot  # exclusive
        pos = jnp.take_along_axis(
            pos_all, flat_e[:, None].astype(jnp.int32), axis=1
        )[:, 0]
        sorted_e = flat_e  # identity "order": scatter handles placement
        sorted_tok = tok_of_slot
        sorted_w = flat_w
    else:
        raise ValueError(dispatch)

    keep = pos < cap  # overflow drops
    # Dropped slots get an out-of-bounds position: mode="drop" then skips
    # the write entirely (writing zeros at position 0 would clobber a real
    # entry).
    safe_pos = jnp.where(keep, pos, cap)

    # Pack tokens into the (E, C, D) buffer (dropped slots write nothing).
    buf = jnp.zeros((e, cap, d), x.dtype)
    buf = buf.at[sorted_e, safe_pos].set(
        x2d[sorted_tok].astype(x.dtype), mode="drop",
    )

    # Batched expert matmuls (SwiGLU per expert).
    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(buf.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(buf.dtype))
    act = jax.nn.silu(g.astype(jnp.float32)).astype(buf.dtype) * u
    out_buf = jnp.einsum("ecf,efd->ecd", act, p["w_down"].astype(buf.dtype))

    # Gather back + weighted combine over the k assignments.
    y_slot = out_buf[sorted_e, safe_pos]  # (TK, D)
    y_slot = jnp.where(keep[:, None], y_slot, 0)
    contrib = y_slot.astype(jnp.float32) * sorted_w[:, None]
    y = jnp.zeros((t, d), jnp.float32).at[sorted_tok].add(contrib)

    if cfg.n_shared:
        y = y + layers.mlp(p["shared"], x2d).astype(jnp.float32)
    return y.reshape(b, s, d).astype(x.dtype), aux


def _moe_grouped(p, cfg, x, x2d, weights, experts, aux, capacity_factor,
                 groups, shard_group):
    """Per-group dispatch (see moe_mlp docstring)."""
    t, d = x2d.shape
    k, e = cfg.top_k, cfg.n_experts
    g = groups
    tg = t // g
    cap = int(max(1, capacity_factor * tg * k / e))

    con = shard_group or (lambda z: z)
    xg = con(x2d.reshape(g, tg, d))
    eg = experts.reshape(g, tg * k)
    wg = weights.reshape(g, tg * k)
    tokg = jnp.broadcast_to(
        jnp.repeat(jnp.arange(tg, dtype=jnp.int32), k)[None], (g, tg * k)
    )

    onehot = jax.nn.one_hot(eg, e, dtype=jnp.int32)  # (G, TgK, E)
    pos_all = jnp.cumsum(onehot, axis=1) - onehot  # exclusive, per group
    pos = jnp.take_along_axis(pos_all, eg[..., None].astype(jnp.int32),
                              axis=2)[..., 0]  # (G, TgK)
    keep = pos < cap
    safe_pos = jnp.where(keep, pos, cap)  # OOB -> dropped by mode="drop"

    # vmap over the group axis so scatter/gather carry it as a batching
    # dim GSPMD can keep data-sharded (explicit index arrays for G made
    # the partitioner replicate the whole update tensor — §Perf log).
    upd = jnp.take_along_axis(xg, tokg[..., None], axis=1).astype(x.dtype)

    def pack(e_g, pos_g, upd_g):
        return jnp.zeros((e, cap, d), x.dtype).at[e_g, pos_g].set(
            upd_g, mode="drop")

    buf = con(jax.vmap(pack)(eg, safe_pos, upd))

    gg = jnp.einsum("gecd,edf->gecf", buf, p["w_gate"].astype(buf.dtype))
    uu = jnp.einsum("gecd,edf->gecf", buf, p["w_up"].astype(buf.dtype))
    act = jax.nn.silu(gg.astype(jnp.float32)).astype(buf.dtype) * uu
    out_buf = jnp.einsum("gecf,efd->gecd", act,
                         p["w_down"].astype(buf.dtype))
    out_buf = con(out_buf)

    def unpack(out_g, e_g, pos_g):
        return out_g[e_g, jnp.minimum(pos_g, cap - 1)]

    y_slot = jax.vmap(unpack)(out_buf, eg, safe_pos)  # (G, TgK, D)
    y_slot = jnp.where(keep[..., None], y_slot, 0)
    contrib = y_slot.astype(jnp.float32) * wg[..., None]

    def combine(tok_g, con_g):
        return jnp.zeros((tg, d), jnp.float32).at[tok_g].add(con_g)

    yg = jax.vmap(combine)(tokg, contrib)
    y = yg.reshape(t, d)
    if cfg.n_shared:
        y = y + layers.mlp(p["shared"], x2d).astype(jnp.float32)
    b, s, _ = x.shape
    return y.reshape(b, s, d).astype(x.dtype), aux


def moe_mlp_dense_oracle(p: Params, cfg: ModelConfig, x: jnp.ndarray):
    """Reference: run every expert densely, combine by router weights.

    Exact when no token overflows capacity (tests pick cf accordingly).
    """
    b, s, d = x.shape
    x2d = x.reshape(b * s, d)
    weights, experts, aux = route(p["router"], x2d, cfg.top_k)
    y = jnp.zeros((b * s, d), jnp.float32)
    for ei in range(cfg.n_experts):
        g = x2d @ p["w_gate"][ei].astype(x2d.dtype)
        u = x2d @ p["w_up"][ei].astype(x2d.dtype)
        o = (jax.nn.silu(g.astype(jnp.float32)).astype(x2d.dtype) * u) @ p[
            "w_down"
        ][ei].astype(x2d.dtype)
        w_e = jnp.where(experts == ei, weights, 0.0).sum(axis=1)
        y = y + o.astype(jnp.float32) * w_e[:, None]
    if cfg.n_shared:
        y = y + layers.mlp(p["shared"], x2d).astype(jnp.float32)
    return y.reshape(b, s, d).astype(x.dtype), aux
