"""Transformer substrate: norms, rotary embeddings, GQA attention, SwiGLU.

Pure-functional: ``init_*`` builds param pytrees (plain dicts), apply
functions are jit/scan/pjit friendly. No framework dependency.

Attention has two implementations sharing one oracle-checked semantics:
  * ``attn_naive``   — materializes (S, S) scores; used for smoke tests,
    short sequences and single-token decode.
  * ``attn_chunked`` — online-softmax over KV chunks with a Python-unrolled
    loop over Q chunks so causal cells process only kv_chunk <= q_chunk
    (exact N^2/2 FLOPs, no fully-masked chunk waste); peak memory is one
    (B, H, q_chunk, kv_chunk) tile. This is the XLA flash-attention
    restructuring used by the 32k prefill cells; kernels/flash_attention is
    the Pallas TPU version of the same loop.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Params = dict


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def _dense_init(key, d_in: int, d_out: int, dtype) -> jnp.ndarray:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.truncated_normal(key, -2, 2, (d_in, d_out)) * scale
            ).astype(dtype)


def init_rmsnorm(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


@partial(jax.custom_vjp, nondiff_argnums=(2,))
def _rmsnorm_core(x: jnp.ndarray, scale: jnp.ndarray, eps: float
                  ) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def _rmsnorm_fwd(x, scale, eps):
    return _rmsnorm_core(x, scale, eps), (x, scale)


def _rmsnorm_bwd(eps, res, dy):
    """Hand-written VJP: identical math to autodiff, but one fused formula
    whose boundary tensors stay in the input dtype — autodiff's backward
    materialized several f32 hidden-sized cotangents per call, which the
    train-cell roofline showed as the dominant HBM traffic (§Perf)."""
    x, scale = res
    xf = x.astype(jnp.float32)
    gf = dy.astype(jnp.float32) * scale.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    r = jax.lax.rsqrt(var + eps)
    xhat = xf * r
    dx = r * (gf - xhat * jnp.mean(gf * xhat, axis=-1, keepdims=True))
    dscale = jnp.sum(
        (dy.astype(jnp.float32) * xhat).reshape(-1, x.shape[-1]), axis=0
    )
    return dx.astype(x.dtype), dscale.astype(scale.dtype)


_rmsnorm_core.defvjp(_rmsnorm_fwd, _rmsnorm_bwd)


def rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    return _rmsnorm_core(x, p["scale"], eps)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(d_head: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies (d_head/2,) f32."""
    exponent = jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head
    return 1.0 / (theta ** exponent)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float
               ) -> jnp.ndarray:
    """x (B, S, H, D), positions (S,) or (B, S) -> rotated x (same dtype)."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)  # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, D/2)
    if ang.ndim == 2:  # (S, D/2) -> broadcast over batch
        ang = ang[None]
    cos = jnp.cos(ang)[:, :, None, :]  # (B|1, S, 1, D/2)
    sin = jnp.sin(ang)[:, :, None, :]
    xf = x.astype(jnp.float32)
    x1, x2 = xf[..., : d // 2], xf[..., d // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig) -> Params:
    dt = cfg.jnp_dtype
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], d, h * hd, dt),
        "wk": _dense_init(ks[1], d, kv * hd, dt),
        "wv": _dense_init(ks[2], d, kv * hd, dt),
        "wo": _dense_init(ks[3], h * hd, d, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dt)
        p["bk"] = jnp.zeros((kv * hd,), dt)
        p["bv"] = jnp.zeros((kv * hd,), dt)
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd, dt)
        p["k_norm"] = init_rmsnorm(hd, dt)
    return p


def _expand_kv(k: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """(B, S, Hkv, D) -> (B, S, H, D) by group broadcast."""
    b, s, hkv, d = k.shape
    if hkv == n_heads:
        return k
    group = n_heads // hkv
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, s, hkv, group, d))
    return k.reshape(b, s, n_heads, d)


def attn_grouped(q, k, v, *, causal: bool, q_offset=0) -> jnp.ndarray:
    """GQA attention without expanding KV: q is reshaped to (Hkv, G) groups.

    Used on the decode path where the KV cache is sequence-sharded: keeping
    K/V in their native (B, S, Hkv, D) layout means the softmax/contraction
    reductions over the sharded S lower to small all-reduces (flash-decode)
    instead of an involuntary KV all-gather (observed with the broadcast
    formulation — see EXPERIMENTS.md §Perf).
    """
    b, sq, h, d = q.shape
    hkv = k.shape[2]
    g = h // hkv
    scale = 1.0 / math.sqrt(d)
    qg = q.reshape(b, sq, hkv, g, d).astype(jnp.float32)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32)
                        ) * scale
    if causal:
        sk = k.shape[1]
        qpos = jnp.arange(sq) + q_offset
        mask = qpos[:, None] >= jnp.arange(sk)[None, :]
        scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, h, d).astype(q.dtype)


def attn_naive(q, k, v, *, causal: bool, q_offset=0) -> jnp.ndarray:
    """q (B,Sq,H,D), k/v (B,Sk,Hkv,D) -> (B,Sq,H,D). Scores materialized.

    ``q_offset``: absolute position of q[0] relative to k[0] (decode: Sk-1).
    """
    h = q.shape[2]
    k = _expand_kv(k, h)
    v = _expand_kv(v, h)
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        qpos = jnp.arange(sq) + q_offset
        mask = qpos[:, None] >= jnp.arange(sk)[None, :]
        scores = jnp.where(mask[None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def attn_chunked(q, k, v, *, causal: bool, q_chunk: int = 2048,
                 kv_chunk: int = 2048) -> jnp.ndarray:
    """Online-softmax attention; memory ~ one (B,H,qc,kc) tile.

    Q chunks unrolled in Python; each scans only the KV chunks its causal
    mask can reach (static bound), so compiled FLOPs are the exact causal
    N^2/2 — this is what the 32k prefill roofline measures.
    """
    b, sq, h, d = q.shape
    sk = k.shape[1]
    k = _expand_kv(k, h)
    v = _expand_kv(v, h)
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, sk)
    if sq % q_chunk or sk % kv_chunk:
        raise ValueError(f"seq ({sq},{sk}) not divisible by chunks "
                         f"({q_chunk},{kv_chunk})")
    scale = 1.0 / math.sqrt(d)
    nq = sq // q_chunk
    nk = sk // kv_chunk
    kc = k.reshape(b, nk, kv_chunk, h, d)
    vc = v.reshape(b, nk, kv_chunk, h, d)

    outs = []
    for iq in range(nq):
        qi = q[:, iq * q_chunk:(iq + 1) * q_chunk].astype(jnp.float32)
        # Causal: only kv chunks that start at or before this q chunk's end.
        hi = nk if not causal else min(
            nk, (iq + 1) * q_chunk // kv_chunk + (1 if q_chunk % kv_chunk else 0)
        )
        hi = max(hi, 1)

        def body(carry, ik):
            acc, m, l = carry
            kj = jax.lax.dynamic_index_in_dim(kc, ik, 1, keepdims=False)
            vj = jax.lax.dynamic_index_in_dim(vc, ik, 1, keepdims=False)
            s = jnp.einsum(
                "bqhd,bkhd->bhqk", qi, kj.astype(jnp.float32)
            ) * scale  # (B, H, qc, kc)
            if causal:
                qpos = iq * q_chunk + jnp.arange(q_chunk)
                kpos = ik * kv_chunk + jnp.arange(kv_chunk)
                s = jnp.where(
                    qpos[:, None] >= kpos[None, :], s, -jnp.inf
                )
            m_new = jnp.maximum(m, s.max(axis=-1))  # (B, H, qc)
            # Renormalize the running accumulator.
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l = l * alpha + p.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, vj.astype(jnp.float32)
            )
            return (acc, m_new, l), None

        acc0 = jnp.zeros((b, h, q_chunk, d), jnp.float32)
        m0 = jnp.full((b, h, q_chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, h, q_chunk), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(
            body, (acc0, m0, l0), jnp.arange(hi)
        )
        out = acc / jnp.maximum(l[..., None], 1e-37)
        outs.append(out.swapaxes(1, 2))  # (B, qc, H, D)
    return jnp.concatenate(outs, axis=1).astype(q.dtype)


def attention(p: Params, cfg: ModelConfig, x: jnp.ndarray, *,
              positions: jnp.ndarray, causal: bool = True,
              kv_cache: tuple[jnp.ndarray, jnp.ndarray] | None = None,
              cache_len: jnp.ndarray | None = None,
              impl: str = "naive", memory: jnp.ndarray | None = None,
              q_chunk: int = 2048, kv_chunk: int = 2048,
              shard_heads=None):
    """Full attention sub-layer: projections + rope + core + output.

    Modes:
      * self-attention over x (train/prefill): kv_cache None.
      * cached decode: kv_cache=(k,v) (B, Smax, Hkv, D), cache_len = filled
        length; x is the new token(s). Returns (out, (k, v) updated).
      * cross-attention: ``memory`` (B, Sm, Dm) provides K/V (no rope, no
        causal mask).
    """
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim

    def proj(w, bias, src, nh):
        y = src @ w.astype(src.dtype)
        if bias is not None:
            y = y + bias.astype(y.dtype)
        return y.reshape(src.shape[0], src.shape[1], nh, hd)

    q = proj(p["wq"], p.get("bq"), x, h)
    kv_src = memory if memory is not None else x
    key = proj(p["wk"], p.get("bk"), kv_src, kv)
    val = proj(p["wv"], p.get("bv"), kv_src, kv)

    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        key = rmsnorm(p["k_norm"], key, cfg.norm_eps)

    if memory is None:  # rope only for self-attention
        q = apply_rope(q, positions, cfg.rope_theta)
        key = apply_rope(key, positions, cfg.rope_theta)

    if shard_heads is not None:  # pin (B,S,H,D) layout (perf: see lm.py)
        # Only Q: KV head counts (GQA) rarely divide the model axis; the
        # expand-to-H broadcast then inherits Q's head sharding.
        q = shard_heads(q)

    if kv_cache is not None:
        ck, cv = kv_cache
        # Insert the new K/V rows at cache_len (decode: s == 1).
        ck = jax.lax.dynamic_update_slice(
            ck, key.astype(ck.dtype), (0, cache_len, 0, 0)
        )
        cv = jax.lax.dynamic_update_slice(
            cv, val.astype(cv.dtype), (0, cache_len, 0, 0)
        )
        kv_out = (ck, cv)
        # Attend over the whole cache; entries past cache_len+s are masked
        # by the causal offset (q_offset = cache_len). Grouped formulation:
        # no KV expansion, S stays sequence-sharded.
        out = attn_grouped(q, ck, cv, causal=True, q_offset=cache_len)
    else:
        kv_out = (key, val)
        if impl == "chunked" and s > q_chunk:
            out = attn_chunked(q, key, val, causal=causal and memory is None,
                               q_chunk=q_chunk, kv_chunk=kv_chunk)
        else:
            out = attn_naive(q, key, val, causal=causal and memory is None)

    out = out.reshape(b, s, h * hd) @ p["wo"].astype(x.dtype)
    return out, kv_out


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int, dtype) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": _dense_init(ks[0], d_model, d_ff, dtype),
        "w_up": _dense_init(ks[1], d_model, d_ff, dtype),
        "w_down": _dense_init(ks[2], d_ff, d_model, dtype),
    }


def mlp(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    dt = x.dtype
    g = x @ p["w_gate"].astype(dt)
    u = x @ p["w_up"].astype(dt)
    return (jax.nn.silu(g.astype(jnp.float32)).astype(dt) * u) @ p[
        "w_down"
    ].astype(dt)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def init_embedding(key, vocab: int, d_model: int, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d_model)) * 0.02).astype(dtype)


def embed(table: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(table, tokens, axis=0)


def unembed(table_or_head: jnp.ndarray, x: jnp.ndarray, *, transpose: bool
            ) -> jnp.ndarray:
    """Logits in f32. ``transpose``: table is (V, D) tied embedding."""
    w = table_or_head.astype(jnp.float32)
    xf = x.astype(jnp.float32)
    return xf @ (w.T if transpose else w)
