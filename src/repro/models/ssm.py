"""Mamba2 / SSD (state-space duality) block.

Forward (train/prefill) uses the chunked SSD algorithm: quadratic
attention-like work *within* a chunk (MXU-friendly batched matmuls), a
linear recurrence *between* chunks (one lax.scan over chunk states). Decode
is the O(1) recurrent update. ``ssd_sequential_reference`` is the
step-by-step oracle the chunked path is tested against.

Recurrence (per head h, with dt folded in):
    H_t = exp(dt_t * A_h) * H_{t-1} + dt_t * B_t x_t^T      (P x N state)
    y_t = C_t . H_t + D_h x_t
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers

Params = dict


def init_mamba(key, cfg: ModelConfig) -> Params:
    dt = cfg.jnp_dtype
    d = cfg.d_model
    di = cfg.d_inner
    n = cfg.ssm_state
    nh = cfg.ssm_heads
    conv_ch = di + 2 * n  # x, B, C all pass the causal conv
    ks = jax.random.split(key, 4)
    # dt bias init so softplus(dt_bias) spans [1e-3, 1e-1] (mamba2 default).
    u = jax.random.uniform(ks[2], (nh,), minval=math.log(1e-3),
                           maxval=math.log(1e-1))
    dt_bias = jnp.log(jnp.expm1(jnp.exp(u)))  # inverse softplus
    return {
        "in_proj": layers._dense_init(ks[0], d, 2 * di + 2 * n + nh, dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.d_conv, conv_ch))
                   * (1.0 / math.sqrt(cfg.d_conv))).astype(dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "dt_bias": dt_bias.astype(jnp.float32),
        "A_log": jnp.log(
            jnp.arange(1, nh + 1, dtype=jnp.float32)
        ),  # A = -exp(A_log): distinct negative eigenvalues per head
        "D": jnp.ones((nh,), jnp.float32),
        "norm": layers.init_rmsnorm(di, dt),
        "out_proj": layers._dense_init(ks[3], di, d, dt),
    }


def _split_proj(cfg: ModelConfig, zxbcdt: jnp.ndarray):
    di, n, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di: 2 * di + 2 * n]
    dt = zxbcdt[..., 2 * di + 2 * n:]
    assert dt.shape[-1] == nh
    return z, xbc, dt


def causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                prev: jnp.ndarray | None = None):
    """Depthwise causal conv1d. xbc (B, S, C); w (K, C). Returns (y, tail).

    ``prev`` (B, K-1, C): trailing context from the previous segment (decode
    cache); zeros when None. ``tail`` is the new trailing context.
    """
    k = w.shape[0]
    bsz, s, c = xbc.shape
    if prev is None:
        prev = jnp.zeros((bsz, k - 1, c), xbc.dtype)
    full = jnp.concatenate([prev, xbc], axis=1)  # (B, K-1+S, C)
    y = jnp.zeros((bsz, s, c), jnp.float32)
    for i in range(k):  # K is tiny (4): unrolled taps
        y = y + full[:, i: i + s].astype(jnp.float32) * w[i].astype(
            jnp.float32
        )
    y = jax.nn.silu(y + b.astype(jnp.float32))
    tail = full[:, -(k - 1):] if k > 1 else jnp.zeros((bsz, 0, c), xbc.dtype)
    return y.astype(xbc.dtype), tail


def ssd_chunked(x, dt, a_neg, bmat, cmat, *, chunk: int):
    """Chunked SSD. x (B,S,H,P); dt (B,S,H); a_neg (H,); B/C (B,S,N) f32.

    Returns (y (B,S,H,P) f32, final_state (B,H,P,N) f32).
    """
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    chunk = min(chunk, s)
    if s % chunk:
        raise ValueError(f"seq {s} not divisible by chunk {chunk}")
    c = s // chunk

    xe = (x * dt[..., None]).reshape(b, c, chunk, h, p)  # dt-folded input
    da = (dt * a_neg[None, None, :]).reshape(b, c, chunk, h)  # log-decay
    bm = bmat.reshape(b, c, chunk, n)
    cm = cmat.reshape(b, c, chunk, n)

    acs = jnp.cumsum(da, axis=2)  # (b,c,l,h) inclusive
    # Intra-chunk: L[l,m] = exp(acs[l]-acs[m]) for l>=m (decay m+1..l).
    diff = acs[:, :, :, None, :] - acs[:, :, None, :, :]  # (b,c,l,m,h)
    ltri = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay_lm = jnp.where(ltri[None, None, :, :, None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("bcln,bcmn->bclm", cm, bm)  # (b,c,l,m)
    y_diag = jnp.einsum(
        "bclm,bclmh,bcmhp->bclhp", scores, decay_lm, xe
    )

    # Chunk-final states: sum_m exp(acs[-1]-acs[m]) * B_m (x) xe_m.
    decay_end = jnp.exp(acs[:, :, -1:, :] - acs)  # (b,c,l,h)
    states = jnp.einsum("bcln,bclh,bclhp->bchpn", bm, decay_end, xe)

    # Inter-chunk recurrence (the only sequential part).
    chunk_decay = jnp.exp(acs[:, :, -1, :])  # (b,c,h)

    def scan_fn(carry, inp):
        st, dec = inp  # (b,h,p,n), (b,h)
        new = carry * dec[:, :, None, None] + st
        return new, carry  # emit the state *entering* this chunk

    st_c = jnp.moveaxis(states, 1, 0)  # (c,b,h,p,n)
    dec_c = jnp.moveaxis(chunk_decay, 1, 0)  # (c,b,h)
    final_state, prev_states = jax.lax.scan(
        scan_fn, jnp.zeros((b, h, p, n), jnp.float32), (st_c, dec_c)
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (b,c,h,p,n)

    # Contribution of the carried-in state: C_l . (decay(start..l) * H_in).
    decay_in = jnp.exp(acs)  # (b,c,l,h)
    y_prev = jnp.einsum(
        "bcln,bclh,bchpn->bclhp", cm, decay_in, prev_states
    )
    y = (y_diag + y_prev).reshape(b, s, h, p)
    return y, final_state


def ssd_sequential_reference(x, dt, a_neg, bmat, cmat):
    """Step-by-step oracle of the same recurrence. Returns (y, final_state)."""
    b, s, h, p = x.shape
    n = bmat.shape[-1]

    def step(state, inp):
        xt, dtt, bt, ct = inp  # (b,h,p),(b,h),(b,n),(b,n)
        dec = jnp.exp(dtt * a_neg[None, :])  # (b,h)
        upd = jnp.einsum("bn,bhp->bhpn", bt, xt * dtt[..., None])
        state = state * dec[:, :, None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", ct, state)
        return state, y

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(bmat, 1, 0), jnp.moveaxis(cmat, 1, 0))
    final, ys = jax.lax.scan(step, jnp.zeros((b, h, p, n), jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1), final


def mamba_forward(p: Params, cfg: ModelConfig, x: jnp.ndarray, *,
                  chunk: int = 256,
                  conv_state: jnp.ndarray | None = None,
                  ssm_state: jnp.ndarray | None = None,
                  return_state: bool = False):
    """Full Mamba2 block forward. x (B, S, D) -> (B, S, D) [+ states]."""
    bsz, s, _ = x.shape
    di, n, nh, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z, xbc, dt_raw = _split_proj(cfg, zxbcdt)
    xbc, conv_tail = causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xs = xbc[..., :di]
    bmat = xbc[..., di: di + n].astype(jnp.float32)
    cmat = xbc[..., di + n:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a_neg = -jnp.exp(p["A_log"])  # (H,)

    xh = xs.reshape(bsz, s, nh, hp).astype(jnp.float32)
    if ssm_state is None:
        y, final = ssd_chunked(xh, dt, a_neg, bmat, cmat, chunk=chunk)
    else:
        # Continue from a carried state: fold it in as chunk 0's carry by
        # running the sequential path (used for short continuation segments).
        def step(state, inp):
            xt, dtt, bt, ct = inp
            dec = jnp.exp(dtt * a_neg[None, :])
            upd = jnp.einsum("bn,bhp->bhpn", bt, xt * dtt[..., None])
            state = state * dec[:, :, None, None] + upd
            return state, jnp.einsum("bn,bhpn->bhp", ct, state)

        xs_seq = (jnp.moveaxis(xh, 1, 0), jnp.moveaxis(dt, 1, 0),
                  jnp.moveaxis(bmat, 1, 0), jnp.moveaxis(cmat, 1, 0))
        final, ys = jax.lax.scan(step, ssm_state, xs_seq)
        y = jnp.moveaxis(ys, 0, 1)

    y = y + xh * p["D"][None, None, :, None]
    y = y.reshape(bsz, s, di).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)  # gate
    y = layers.rmsnorm(p["norm"], y, cfg.norm_eps)
    out = y @ p["out_proj"].astype(x.dtype)
    if return_state:
        return out, conv_tail, final
    return out


def mamba_decode_step(p: Params, cfg: ModelConfig, x: jnp.ndarray,
                      conv_state: jnp.ndarray, ssm_state: jnp.ndarray):
    """One-token recurrent update. x (B, 1, D). Returns (y, conv, ssm)."""
    return mamba_forward(
        p, cfg, x, conv_state=conv_state, ssm_state=ssm_state,
        return_state=True,
    )
