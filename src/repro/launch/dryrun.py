"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Usage (resumable; JSON per cell under experiments/dryrun/):
    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b \
        --shape train_4k --mesh single

The FIRST two lines below must run before any other import so the 512
placeholder host devices exist when jax initializes. Do not move them.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import base as cfg_base  # noqa: E402
from repro.configs import shapes as shp  # noqa: E402
from repro.launch import hlo_cost  # noqa: E402
from repro.launch import mesh as mesh_lib  # noqa: E402
from repro.launch import sharding, specs  # noqa: E402
from repro.models.lm import LM  # noqa: E402
from repro.training import optimizer, train_step as ts_lib  # noqa: E402

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

# Per-arch train-cell knobs (microbatches, accumulation dtype) chosen so the
# per-device footprint fits a 16 GB HBM chip — derivations in EXPERIMENTS.md.
TRAIN_KNOBS = {
    "qwen2.5-14b": dict(microbatches=8, accum_dtype="float32"),
    "llava-next-34b": dict(microbatches=16, accum_dtype="bfloat16"),
    "moonshot-v1-16b-a3b": dict(microbatches=8, accum_dtype="bfloat16"),
    "qwen2-moe-a2.7b": dict(microbatches=8, accum_dtype="bfloat16"),
    # SSD intra-chunk decay tensors (b, c, l, l, h) scale with the
    # per-device microbatch — mb=8 keeps them ~2.7 GB under remat.
    "mamba2-2.7b": dict(microbatches=8, accum_dtype="float32"),
    "zamba2-1.2b": dict(microbatches=8, accum_dtype="float32"),
}
DEFAULT_TRAIN_KNOBS = dict(microbatches=4, accum_dtype="float32")

# HLO collective parsing lives in launch/hlo_cost (one parser for the
# dry-run census, the roofline, and the analysis gate); re-exported here
# for existing callers. The private copy this file used to carry had
# drifted (no f8 variants, no s4/u4).
parse_collectives = hlo_cost.parse_collectives


# ---------------------------------------------------------------------------
# Cell construction
# ---------------------------------------------------------------------------


def build_model(cfg, shape: shp.ShapeSpec, variant: dict | None = None
                ) -> LM:
    """``variant`` (perf-iteration knobs, see benchmarks/hillclimb.py):
    shard_acts (mesh_axes constraints), q_chunk, attn_impl, moe_dispatch."""
    v = variant or {}
    remat = v.get("remat") or ("full" if shape.step == "train" else "none")
    mesh_axes = ()
    if v.get("shard_acts"):
        mesh_axes = (("pod", "data", "model") if v.get("multi_pod")
                     else ("data", "model"))
    moe_groups = v.get("moe_groups", 1)
    if moe_groups == "dp":
        moe_groups = 32 if v.get("multi_pod") else 16
    return LM(cfg,
              attn_impl=v.get("attn_impl", "auto"),
              q_chunk=v.get("q_chunk", 2048), kv_chunk=v.get("q_chunk",
                                                             2048),
              ssd_chunk=256, vocab_chunk=256, remat=remat,
              mesh_axes=mesh_axes,
              moe_dispatch=v.get("moe_dispatch", "sort"),
              moe_groups=moe_groups)


def lower_cell(arch: str, shape: shp.ShapeSpec, mesh,
               variant: dict | None = None):
    """Build + lower one cell. Returns (lowered, meta)."""
    cfg = cfg_base.get(arch)
    if variant is not None:
        variant = dict(variant)
        variant["multi_pod"] = "pod" in mesh.axis_names
    model = build_model(cfg, shape, variant)
    meta = {
        "arch": arch, "shape": shape.name, "step": shape.step,
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "n_params": cfg.n_params(), "n_active_params": cfg.n_active_params(),
        "family": cfg.family,
    }

    if shape.step == "train":
        knobs = TRAIN_KNOBS.get(arch, DEFAULT_TRAIN_KNOBS)
        tcfg = ts_lib.TrainConfig(
            microbatches=knobs["microbatches"],
            accum_dtype=knobs["accum_dtype"],
        )
        meta.update(knobs)
        step_fn = ts_lib.make_train_step(model, tcfg)
        state_shapes = specs.train_state_shapes(model)
        batch_shapes = specs.batch_specs(
            cfg, shape.seq_len, shape.global_batch, with_labels=True
        )
        state_sh = sharding.to_named(
            ts_lib.TrainState(
                params=sharding.param_specs(state_shapes.params, mesh),
                opt=sharding.opt_specs(state_shapes.params, mesh),
                ledger_head=jax.sharding.PartitionSpec(),
            ), mesh,
        )
        batch_sh = sharding.to_named(
            sharding.batch_pspecs(batch_shapes, mesh), mesh
        )
        fn = jax.jit(
            step_fn,
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, None),
            donate_argnums=(0,),
        )
        lowered = fn.lower(state_shapes, batch_shapes)
    elif shape.step == "prefill":
        batch_shapes = specs.batch_specs(
            cfg, shape.seq_len, shape.global_batch, with_labels=False
        )
        cache_shapes = specs.cache_shapes(
            model, shape.global_batch, shape.seq_len
        )
        p_shapes = specs.param_shapes(model)
        p_sh = sharding.to_named(sharding.param_specs(p_shapes, mesh), mesh)
        b_sh = sharding.to_named(
            sharding.batch_pspecs(batch_shapes, mesh), mesh
        )
        c_sh = sharding.to_named(
            sharding.cache_pspecs(cache_shapes, mesh), mesh
        )
        fn = jax.jit(
            model.prefill,
            in_shardings=(p_sh, b_sh, c_sh),
            out_shardings=(None, c_sh),
            donate_argnums=(2,),
        )
        lowered = fn.lower(p_shapes, batch_shapes, cache_shapes)
    elif shape.step == "decode":
        cache_shapes = specs.cache_shapes(
            model, shape.global_batch, shape.seq_len
        )
        p_shapes = specs.param_shapes(model)
        tok_spec, pos_spec = specs.decode_token_specs(shape.global_batch)
        p_sh = sharding.to_named(sharding.param_specs(p_shapes, mesh), mesh)
        c_sh = sharding.to_named(
            sharding.cache_pspecs(cache_shapes, mesh), mesh
        )
        t_sh = sharding.to_named(
            sharding.token_pspec(shape.global_batch, mesh), mesh
        )
        fn = jax.jit(
            model.decode_step,
            in_shardings=(p_sh, c_sh, t_sh,
                          sharding.to_named(jax.sharding.PartitionSpec(),
                                            mesh)),
            out_shardings=(None, c_sh),
            donate_argnums=(1,),
        )
        lowered = fn.lower(p_shapes, cache_shapes, tok_spec, pos_spec)
    else:
        raise ValueError(shape.step)
    return lowered, meta


# The combined beyond-paper optimization bundle (§Perf): explicit
# activation sharding + sort-free per-DP-group MoE dispatch.
OPTIMIZED_VARIANT = {"shard_acts": True, "moe_dispatch": "cumsum",
                     "moe_groups": "dp"}


def run_cell(arch: str, shape: shp.ShapeSpec, mesh_name: str,
             out_dir: str, *, force: bool = False,
             variant: dict | None = None) -> dict:
    path = os.path.join(
        out_dir, f"{arch}__{shape.name}__{mesh_name}.json"
    )
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    cfg = cfg_base.get(arch)
    ok, reason = shp.applicable(cfg, shape)
    if not ok:
        rec = {"arch": arch, "shape": shape.name, "mesh": mesh_name,
               "status": "skipped", "reason": reason}
        _write(path, rec)
        return rec

    mesh = mesh_lib.make_production_mesh(multi_pod=(mesh_name == "multi"))
    t0 = time.time()
    try:
        with mesh:
            lowered, meta = lower_cell(arch, shape, mesh, variant=variant)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = _cost_dict(compiled)
            hlo_text = compiled.as_text()
            coll = parse_collectives(hlo_text)
            tc_cost = hlo_cost.analyze(hlo_text)  # trip-count-corrected
            _save_hlo(path, hlo_text)
        rec = {
            **meta,
            "mesh": mesh_name,
            "n_devices": mesh.size,
            "status": "ok",
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
                "output_bytes": getattr(mem, "output_size_in_bytes", 0),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
                "generated_code_bytes": getattr(
                    mem, "generated_code_size_in_bytes", 0
                ),
                "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
            },
            "cost": {
                "flops": cost.get("flops", 0.0),
                "bytes_accessed": cost.get("bytes accessed", 0.0),
                "transcendentals": cost.get("transcendentals", 0.0),
            },
            # Trip-count-corrected costs (launch/hlo_cost.py) — XLA's own
            # cost_analysis counts while bodies once; these multiply loops
            # out and are what §Roofline consumes.
            "hlo_cost": tc_cost,
            "collectives": coll,
        }
    except Exception as e:  # record the failure; the suite flags it
        rec = {
            "arch": arch, "shape": shape.name, "mesh": mesh_name,
            "status": "error", "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-4000:],
        }
    _write(path, rec)
    return rec


def _cost_dict(compiled) -> dict:
    return hlo_cost.cost_dict(compiled)


def _save_hlo(json_path: str, hlo_text: str) -> None:
    import gzip

    os.makedirs(os.path.dirname(json_path), exist_ok=True)
    with gzip.open(json_path.replace(".json", ".hlo.gz"), "wt") as f:
        f.write(hlo_text)


def _write(path: str, rec: dict) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)


def run_fabric_cell(variant: str, mesh_name: str, out_dir: str,
                    *, b_loc: int = 100, force: bool = False) -> dict:
    """Dry-run the paper's own workload: the sharded fabric step.

    ``variant``: "fastfabric" (O-I+O-II+vectorized commit), "fabric-v12"
    (full-payload consensus, serial admission + commit),
    "fastfabric-sharded" (world state bucket-partitioned over the `model`
    axis — launch/state_sharding), or "fastfabric-pipelined" (sharded
    state + the depth-8 device-side block pipeline of repro/pipeline: one
    consensus gather and one routed MVCC gather per 8-block window).
    PAPER_DIMS = 2.9 KB transactions, one channel per data rank, one
    orderer-replica / validation worker per model rank, 100
    txs/worker/round (per block for the pipelined variant).
    """
    from repro.core import types as ftypes  # noqa: PLC0415
    from repro.launch import fabric_step as fs  # noqa: PLC0415

    path = os.path.join(out_dir, f"{variant}__step__{mesh_name}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    mesh = mesh_lib.make_production_mesh(multi_pod=(mesh_name == "multi"))
    dims = ftypes.PAPER_DIMS
    cfg = {
        "fastfabric": fs.FASTFABRIC_STEP,
        "fabric-v12": fs.FABRIC_V12_STEP,
        "fastfabric-sharded": fs.FASTFABRIC_SHARDED_STEP,
        "fastfabric-pipelined": fs.FASTFABRIC_PIPELINED_STEP,
    }[variant]
    t0 = time.time()
    try:
        with mesh:
            step = fs.make_fabric_step(dims, cfg, mesh)
            n_ch = mesh.shape["data"] * mesh.shape.get("pod", 1)
            state_shape = jax.eval_shape(
                lambda: fs.create_mesh_state(n_ch, dims)
            )
            wire_s, ids_s = fs.input_specs(
                mesh, dims, b_loc=b_loc,
                pipeline_depth=cfg.pipeline_depth,
            )
            fn = jax.jit(step, donate_argnums=(0,))
            lowered = fn.lower(state_shape, wire_s, ids_s)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = _cost_dict(compiled)
            hlo_text = compiled.as_text()
            coll = parse_collectives(hlo_text)
            tc_cost = hlo_cost.analyze(hlo_text)
            _save_hlo(path, hlo_text)
        txs = n_ch * b_loc * mesh.shape["model"] * cfg.pipeline_depth
        rec = {
            "arch": variant, "shape": "step", "step": "fabric",
            "mesh": mesh_name, "n_devices": mesh.size, "status": "ok",
            "txs_per_round": txs, "payload_bytes": dims.payload_bytes,
            "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
                "output_bytes": getattr(mem, "output_size_in_bytes", 0),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
                "generated_code_bytes": getattr(
                    mem, "generated_code_size_in_bytes", 0),
                "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
            },
            "cost": {
                "flops": cost.get("flops", 0.0),
                "bytes_accessed": cost.get("bytes accessed", 0.0),
                "transcendentals": cost.get("transcendentals", 0.0),
            },
            "hlo_cost": tc_cost,
            "collectives": coll,
        }
    except Exception as e:
        rec = {"arch": variant, "shape": "step", "mesh": mesh_name,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
    _write(path, rec)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--fabric", action="store_true",
                    help="also dry-run the paper's fabric step cells")
    ap.add_argument("--optimized", action="store_true",
                    help="apply the beyond-paper optimization bundle and "
                         "write to experiments/optimized/")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.out is None:
        args.out = os.path.abspath(
            OUT_DIR.replace("dryrun", "optimized") if args.optimized
            else OUT_DIR
        )
    variant = OPTIMIZED_VARIANT if args.optimized else None

    fabric_variants = ("fastfabric", "fabric-v12", "fastfabric-sharded",
                       "fastfabric-pipelined")
    if args.fabric or (args.arch in fabric_variants):
        variants = ([args.arch] if args.arch in fabric_variants
                    else list(fabric_variants))
        meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
        for v in variants:
            for m in meshes:
                rec = run_fabric_cell(v, m, args.out, force=args.force)
                if rec["status"] == "ok":
                    print(f"[ok]   {v:22s} step         {m:6s}"
                          f" compile={rec['compile_s']:7.1f}s"
                          f" coll={rec['collectives']['total_wire_bytes']:.3e}B")
                else:
                    print(f"[ERR]  {v}: {rec['error']}")
        if not args.all:
            return

    archs = [args.arch] if args.arch else list(cfg_base.ARCH_IDS)
    shapes = ([shp.SHAPES_BY_NAME[args.shape]] if args.shape
              else list(shp.SHAPES))
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if not (args.all or args.arch or args.shape):
        ap.error("pass --all or --arch/--shape")

    n_ok = n_skip = n_err = 0
    for arch in archs:
        for shape in shapes:
            for mesh_name in meshes:
                rec = run_cell(arch, shape, mesh_name, args.out,
                               force=args.force, variant=variant)
                status = rec["status"]
                if status == "ok":
                    n_ok += 1
                    mem = rec["memory"]
                    per_dev = (mem["argument_bytes"] + mem["temp_bytes"]
                               + mem["output_bytes"])
                    print(
                        f"[ok]   {arch:22s} {shape.name:12s} {mesh_name:6s}"
                        f" compile={rec['compile_s']:7.1f}s"
                        f" flops={rec['cost']['flops']:.3e}"
                        f" coll={rec['collectives']['total_wire_bytes']:.3e}B"
                    )
                elif status == "skipped":
                    n_skip += 1
                    print(f"[skip] {arch:22s} {shape.name:12s} {mesh_name}")
                else:
                    n_err += 1
                    print(f"[ERR]  {arch:22s} {shape.name:12s} {mesh_name}: "
                          f"{rec['error']}")
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
