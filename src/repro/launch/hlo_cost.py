"""Trip-count-aware cost analysis of post-SPMD HLO text.

``compiled.cost_analysis()`` counts a while-loop body ONCE regardless of
trip count (verified: scan(matmul, 8) reports the flops of one matmul), so
any scanned program — every model here scans over layers/microbatches —
is undercounted by orders of magnitude. This module re-derives per-device
costs from the compiled HLO text with loops multiplied out:

  flops  — exact for dot/convolution (2 * out_elems * contracted size),
           one per output element for elementwise ops;
  bytes  — memory-traffic model: operands + outputs per materialized
           instruction; fusions count only their boundary buffers (XLA's
           own fusion-traffic model); dynamic-(update-)slice / gather /
           scatter count only the touched slice (in-place semantics), so
           KV-cache updates inside scans don't absurdly overcount;
  wire   — collective bytes with ring factors: all-gather/reduce-scatter/
           all-to-all F*(g-1)/g, all-reduce 2*F*(g-1)/g, permute F;
  while  — body+cond costs multiplied by the trip count parsed from the
           loop condition (jax emits compare(iv, constant(N)), LT).

Shapes in post-partitioning HLO are per-device shard shapes, so all
results are per-device; multiply by mesh size for global totals.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e3m4": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
    "s16": 2, "u16": 2, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$"
)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_PARAM_RE = re.compile(r"([\w.\-]+):\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?))")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# Opcodes that produce no memory traffic of their own.
_FREE = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
         "after-all", "partition-id", "replica-id", "iota", "reshape"}
# Sliced-access ops: count touched slices, not whole operands.
_SLICED = {"dynamic-slice", "dynamic-update-slice", "gather", "scatter"}


def _shape_list(text: str) -> list[tuple[str, tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt in _DTYPE_BYTES:
            out.append((dt, tuple(int(d) for d in dims.split(",") if d)))
    return out


def _bytes_of(shapes) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _elems_of(shapes) -> int:
    total = 0
    for _, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    out_shapes: list
    operands: list  # operand %names
    line: str


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    wire: float = 0.0
    coll_counts: Optional[dict] = None
    # Trip-count-corrected scatter-instruction count: the state-commit
    # scatters are the only scatters in the fabric programs, so this is
    # how fig11/CI assert the window commit is fused (scatters must not
    # scale with pipeline depth).
    scatters: float = 0.0

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        self.wire += o.wire
        self.scatters += o.scatters
        for k, v in (o.coll_counts or {}).items():
            self.coll_counts = self.coll_counts or {}
            dst = self.coll_counts.setdefault(
                k, {"count": 0, "wire_bytes": 0.0})
            dst["count"] += v["count"]
            dst["wire_bytes"] += v["wire_bytes"]
        return self

    def scaled(self, k: float) -> "Cost":
        return Cost(
            self.flops * k, self.bytes * k, self.wire * k,
            {kk: {"count": v["count"] * k, "wire_bytes": v["wire_bytes"] * k}
             for kk, v in (self.coll_counts or {}).items()} or None,
            self.scatters * k,
        )


class HloModule:
    def __init__(self, text: str):
        self.computations: dict[str, list[Instr]] = {}
        self.shape_of: dict[tuple[str, str], list] = {}  # (comp, name)
        self._parse(text)
        self._memo: dict[str, Cost] = {}

    def _parse(self, text: str) -> None:
        comp = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if not line or line.startswith(("HloModule", "//", "#")):
                continue
            mc = _COMP_RE.match(line.strip())
            if mc and line.rstrip().endswith("{"):
                comp = mc.group(1)
                self.computations[comp] = []
                # Parameter shapes from the signature.
                for pname, ptype in _PARAM_RE.findall(mc.group(2)):
                    self.shape_of[(comp, pname)] = _shape_list(ptype)
                continue
            if comp is None:
                continue
            if line.strip() == "}":
                comp = None
                continue
            mi = _INSTR_RE.match(line)
            if not mi:
                continue
            name, out_type, opcode, rest = mi.groups()
            out_shapes = _shape_list(out_type)
            # Operand names: inside the first paren group only.
            depth, args = 0, ""
            for ch in "(" + rest:
                if ch == "(":
                    depth += 1
                    if depth == 1:
                        continue
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        break
                if depth >= 1:
                    args += ch
            operands = _OPERAND_RE.findall(args)
            ins = Instr(name, opcode, out_shapes, operands, line.strip())
            self.computations[comp].append(ins)
            self.shape_of[(comp, name)] = out_shapes

    # ----- trip count of a while loop -----

    def _trip_count(self, cond_comp: str) -> int:
        best = 1
        for ins in self.computations.get(cond_comp, []):
            for m in _CONST_RE.finditer(ins.line):
                # scalar integer constants in the condition; jax loops
                # compare the induction var against the trip count.
                if "s32[]" in ins.line or "u32[]" in ins.line \
                        or "s64[]" in ins.line:
                    best = max(best, int(m.group(1)))
            if ins.opcode == "fusion":
                m = _CALLS_RE.search(ins.line)
                if m:
                    best = max(best, self._trip_count(m.group(1)))
        return best

    # ----- per-instruction costs -----

    def _dot_flops(self, comp: str, ins: Instr) -> float:
        out_elems = _elems_of(ins.out_shapes)
        m = _LHS_C_RE.search(ins.line)
        k = 1
        if m and ins.operands:
            lhs_shapes = self.shape_of.get((comp, ins.operands[0]))
            if lhs_shapes:
                _, dims = lhs_shapes[0]
                for idx in (int(i) for i in m.group(1).split(",") if i):
                    if idx < len(dims):
                        k *= dims[idx]
        return 2.0 * out_elems * k

    def _instr_cost(self, comp: str, ins: Instr) -> Cost:
        op = ins.opcode
        if op in _FREE or op.startswith("constant"):
            return Cost()
        if op == "while":
            body = _BODY_RE.search(ins.line)
            cond = _COND_RE.search(ins.line)
            trips = self._trip_count(cond.group(1)) if cond else 1
            inner = Cost()
            if body:
                inner += self.comp_cost(body.group(1))
            if cond:
                inner += self.comp_cost(cond.group(1))
            return inner.scaled(trips)
        if op in ("call", "async-start"):
            m = _CALLS_RE.search(ins.line) or _COND_RE.search(ins.line)
            return self.comp_cost(m.group(1)) if m else Cost()
        if op == "conditional":
            # max over branch computations (upper bound).
            branches = re.findall(r"branch_computations=\{([^}]*)\}",
                                  ins.line)
            names = []
            if branches:
                names = _OPERAND_RE.findall(branches[0])
            costs = [self.comp_cost(n) for n in names]
            best = Cost()
            for c in costs:
                if c.flops + c.bytes > best.flops + best.bytes:
                    best = c
            return best

        out_bytes = _bytes_of(ins.out_shapes)
        opnd_bytes = sum(
            _bytes_of(self.shape_of.get((comp, o), [])) for o in ins.operands
        )
        c = Cost()
        if op == "fusion":
            m = _CALLS_RE.search(ins.line)
            if m:
                nested = self.comp_cost(m.group(1))
                c.flops += nested.flops  # dots inside fusions still count
                c.wire += nested.wire
                c.scatters += nested.scatters
                if nested.coll_counts:
                    c += Cost(coll_counts=nested.coll_counts)
            c.bytes += out_bytes + opnd_bytes  # boundary traffic only
            return c
        if op == "dot":
            c.flops = self._dot_flops(comp, ins)
            c.bytes = out_bytes + opnd_bytes
            return c
        if op in _SLICED:
            # Touched region ~ the small operand/output, not the big buffer.
            small = min(out_bytes, opnd_bytes) if opnd_bytes else out_bytes
            if op == "dynamic-update-slice" and len(ins.operands) >= 2:
                upd = _bytes_of(
                    self.shape_of.get((comp, ins.operands[1]), []))
                small = 2 * upd
            c.bytes = small + out_bytes if op != "dynamic-update-slice" \
                else small
            if op == "scatter":
                c.scatters = 1.0
            return c
        base = op.split("-start")[0]
        if base in COLLECTIVES:
            full = max(out_bytes, opnd_bytes)
            g = 2
            m = _GROUPS_RE.search(ins.line)
            if m:
                g = len(m.group(1).split(","))
            else:
                m = _GROUPS_IOTA_RE.search(ins.line)
                if m:
                    g = int(m.group(2))
            g = max(g, 2)
            ring = (g - 1) / g
            wire = {"all-reduce": 2 * full * ring,
                    "collective-permute": full}.get(base, full * ring)
            c.wire = wire
            c.bytes = out_bytes + opnd_bytes
            c.coll_counts = {base: {"count": 1, "wire_bytes": wire}}
            return c
        # Generic elementwise / data movement.
        c.bytes = out_bytes + opnd_bytes
        c.flops = float(_elems_of(ins.out_shapes))  # 1 flop per out elem
        if op in ("transpose", "copy", "slice", "concatenate", "pad",
                  "broadcast", "reverse", "convert"):
            c.flops = 0.0
        return c

    def comp_cost(self, comp: str) -> Cost:
        if comp in self._memo:
            return self._memo[comp]
        total = Cost(coll_counts={})
        for ins in self.computations.get(comp, []):
            total += self._instr_cost(comp, ins)
        self._memo[comp] = total
        return total

    def entry_cost(self) -> Cost:
        # The entry computation is conventionally the last one, but find
        # the one that is not referenced by any other computation.
        referenced = set()
        for instrs in self.computations.values():
            for ins in instrs:
                for pat in (_CALLS_RE, _COND_RE, _BODY_RE):
                    m = pat.search(ins.line)
                    if m:
                        referenced.add(m.group(1))
                for b in re.findall(r"branch_computations=\{([^}]*)\}",
                                    ins.line):
                    referenced.update(_OPERAND_RE.findall(b))
        roots = [c for c in self.computations if c not in referenced]
        # Heuristic: the entry has the most instructions among roots.
        entry = max(roots or list(self.computations),
                    key=lambda c: len(self.computations[c]))
        return self.comp_cost(entry)


def cost_dict(compiled) -> dict:
    """Normalize ``compiled.cost_analysis()`` across jax versions (newer
    versions return a dict, older ones a one-element list of dicts)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0] if cost else {}
    return cost or {}


def analyze(hlo_text: str) -> dict:
    mod = HloModule(hlo_text)
    c = mod.entry_cost()
    return {
        "flops": c.flops,
        "bytes": c.bytes,
        "collective_wire_bytes": c.wire,
        "collectives": c.coll_counts or {},
        "scatter_count": c.scatters,
    }


def parse_collectives(hlo: str) -> dict:
    """Flat per-line collective census of post-SPMD HLO (the dry-run's
    report format): ``{type: {count, wire_bytes, buffer_bytes}}`` plus
    ``total_wire_bytes``. UNLIKE :func:`analyze` this counts each
    instruction once regardless of loop trip counts — it is the
    static-text census dryrun records next to the trip-corrected
    ``hlo_cost`` block. Shapes are per-device shard shapes; ring
    transfer factors as in :func:`analyze` (all-gather/reduce-scatter/
    all-to-all F*(g-1)/g, all-reduce 2*F*(g-1)/g, permute F).

    This is the one shared parser — ``launch/dryrun.py`` re-exports it
    (its private copy had drifted: no f8e4m3/f8e3m4, no s4/u4).
    """
    out = {c: {"count": 0, "wire_bytes": 0.0, "buffer_bytes": 0.0}
           for c in COLLECTIVES}
    for line in hlo.splitlines():
        s = line.strip()
        if not s or s.startswith("//"):
            continue
        op = None
        for c in COLLECTIVES:
            if f" {c}(" in s or f" {c}-start(" in s:
                op = c
                break
        if op is None:
            continue
        full = max((_bytes_of([t]) for t in _shape_list(s)), default=0)
        g = None
        m = _GROUPS_RE.search(s)
        if m:
            g = len(m.group(1).split(","))
        else:
            m = _GROUPS_IOTA_RE.search(s)
            if m:
                g = int(m.group(2))
        if not g or g <= 1:
            g = 2  # conservative
        ring = (g - 1) / g
        if op == "all-reduce":
            wire = 2 * full * ring
        elif op == "collective-permute":
            wire = full
        else:
            wire = full * ring
        out[op]["count"] += 1
        out[op]["wire_bytes"] += wire
        out[op]["buffer_bytes"] += full
    out["total_wire_bytes"] = sum(
        v["wire_bytes"] for v in out.values() if isinstance(v, dict)
    )
    return out
