"""Serving driver: batched requests through the fabric serving engine.

CPU-runnable with smoke configs:
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b \
        --requests 12 --slots 4 --max-new 16

Prints per-request outputs plus engine stats (steps, slot reuse, the
request ledger versions that prove exactly-once slot commits).
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import base as cfg_base
from repro.models.lm import LM
from repro.serving.engine import Request, ServeEngine


def run(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=64)
    args = ap.parse_args(argv)

    cfg = cfg_base.get_smoke(args.arch)
    if cfg.family not in ("dense", "moe"):
        raise SystemExit("serving engine drives dense/moe archs "
                         f"(got {cfg.family}); ssm serving uses decode_step")
    model = LM(cfg, moe_capacity_factor=2.0)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, slots=args.slots, max_len=args.max_len)

    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i,
                prompt=rng.integers(0, cfg.vocab, args.prompt_len
                                    ).astype(np.int32),
                max_new=args.max_new)
        for i in range(args.requests)
    ]
    t0 = time.time()
    eng.run(reqs)
    wall = time.time() - t0
    done = sum(r.done or len(r.out) >= r.max_new for r in reqs)
    for r in reqs[:4]:
        print(f"req {r.rid}: {len(r.out)} tokens, ledger_version="
              f"{eng.request_version(r.rid)}")
    stats = {
        "completed": done,
        "total": len(reqs),
        "engine_steps": eng.steps,
        "tokens_out": eng.tokens_out,
        "tok_per_s": eng.tokens_out / wall,
    }
    print(stats)
    return stats


if __name__ == "__main__":
    run()
