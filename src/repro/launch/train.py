"""Training driver: data pipeline -> fabric train step -> checkpoints -> ft.

CPU-runnable end to end with smoke configs (this is what
examples/train_lm.py wraps); the same builder functions serve the dry-run
and would drive the production mesh unchanged.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --smoke \
        --steps 50 --batch 8 --seq 64

Fault-tolerance wiring (all exercised in tests/test_train.py):
  * checkpoint every ``--ckpt-every`` steps (async, hash-chained);
  * ``--kill-at N`` simulates a coordinator death at step N: the driver
    restarts, restores the latest checkpoint, and the data pipeline's
    statelessness resumes the stream bit-exactly;
  * per-step durations feed the straggler policy (backup-endorsement
    decisions are logged).
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import base as cfg_base
from repro.data import pipeline
from repro.ft.membership import StragglerPolicy
from repro.models.lm import LM, Batch
from repro.training import optimizer, train_step as ts_lib


def build(arch: str, *, smoke: bool, seq: int, batch: int,
          microbatches: int, lr: float, total_steps: int):
    cfg = cfg_base.get_smoke(arch) if smoke else cfg_base.get(arch)
    model = LM(cfg, vocab_chunk=min(seq, 128),
               moe_capacity_factor=2.0, remat="none")
    tcfg = ts_lib.TrainConfig(
        opt=optimizer.AdamWConfig(lr=lr, warmup_steps=max(total_steps // 20,
                                                          5),
                                  total_steps=total_steps),
        microbatches=microbatches,
    )
    dcfg = pipeline.DataConfig(
        vocab=cfg.vocab, seq_len=seq, global_batch=batch,
        n_prefix=cfg.n_prefix if cfg.frontend == "vision" else 0,
        d_model=cfg.d_model,
        enc_frac=4 if cfg.family == "encdec" else 0,
    )
    return cfg, model, tcfg, dcfg


def run(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--kill-at", type=int, default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg, model, tcfg, dcfg = build(
        args.arch, smoke=args.smoke, seq=args.seq, batch=args.batch,
        microbatches=args.microbatches, lr=args.lr, total_steps=args.steps,
    )
    step_fn = jax.jit(ts_lib.make_train_step(model, tcfg),
                      donate_argnums=(0,))

    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    state = ts_lib.init_state(model, jax.random.PRNGKey(0))
    if args.resume and ckpt and ckpt.list_steps():
        state, start = ckpt.restore(state)
        assert ckpt.verify_chain(), "checkpoint chain verification failed"
        print(f"[restore] resumed from step {start} (chain verified)")

    straggler = StragglerPolicy()
    losses = []
    t_start = time.time()
    for step in range(start, args.steps):
        batch_np = pipeline.global_batch_for_step(dcfg, step)
        batch = jax.tree.map(
            lambda x: None if x is None else jax.numpy.asarray(x), batch_np,
            is_leaf=lambda x: x is None,
        )
        t0 = time.time()
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        straggler.observe(dt)
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} {dt*1e3:.0f}ms"
                  + (" [backup-candidate]"
                     if straggler.should_backup(dt) else ""))
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, state)
        if args.kill_at is not None and step + 1 == args.kill_at:
            if ckpt:
                ckpt.wait()
            print(f"[kill] simulated failure after step {step}")
            return {"killed_at": step + 1, "losses": losses}

    if ckpt:
        ckpt.save(args.steps, state, blocking=True)
    tokens = (args.steps - start) * args.batch * args.seq
    wall = time.time() - t_start
    out = {
        "first_loss": losses[0] if losses else None,
        "last_loss": losses[-1] if losses else None,
        "tokens_per_s": tokens / wall,
        "losses": losses,
        "final_step": args.steps,
    }
    print(f"done: loss {out['first_loss']:.3f} -> {out['last_loss']:.3f}, "
          f"{out['tokens_per_s']:.0f} tok/s")
    return out


if __name__ == "__main__":
    run()
