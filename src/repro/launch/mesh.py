"""Production meshes.

Single pod: 16 x 16 = 256 chips, axes (data, model).
Multi-pod:  2 x 16 x 16 = 512 chips, axes (pod, data, model) — the pod axis
is an outer data-parallel dimension with hierarchical (pod-local first)
gradient reduction; it is also the committer/endorser role-split axis for
the fabric engine (core/roles in DESIGN.md §5).

Functions, not module constants: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host has, as a 1 x N (data, model) mesh — used by the
    CPU examples and tests."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))


def dp_axes(mesh) -> tuple:
    """The data-parallel axis names of a mesh (pod folds into data)."""
    names = mesh.axis_names
    return tuple(n for n in names if n in ("pod", "data"))


def dp_size(mesh) -> int:
    s = 1
    for n in dp_axes(mesh):
        s *= mesh.shape[n]
    return s


def model_size(mesh) -> int:
    return mesh.shape.get("model", 1)
