"""Distributed FastFabric step over the production mesh (shard_map).

Topology mapping (DESIGN.md §2/§5): N independent *channels* sharded over
the ``data`` axis (the paper's future-work "separate ordering and fast
peer per channel" — each data rank holds C/data_size local channels,
vmapped inside the body), and the ``model`` axis inside a channel is the
orderer-replica/validation-worker cluster. Per step and channel:

  1. ingest      — each model rank holds B_loc client proposals (payloads
                   stay put for the whole step: the O-I invariant);
                   syntactic checksum runs locally (P-II parallel
                   validation: each worker validates what it ingested);
  2. consensus   — the log is replicated to every orderer replica:
                   all-gather over ``model`` of the FULL wire (baseline) or
                   only the structured prefix (O-I: IDs + rw sets + tags,
                   ~structure bytes instead of payload bytes) + chain hash;
  3. order       — deterministic interleave by ID hash (identical on every
                   replica, consensus-free);
  4. validate    — endorsement MACs on local txs (parallel), validity bits
                   all-gathered (1 word/tx); MVCC runs on the replicated
                   structured sets — the sequential scan every replica
                   executes identically;
  5. commit      — the channel's world state (replicated over ``model``,
                   sharded over ``data``) applies valid write sets.

The collective-byte asymmetry (payload vs structure bytes over the
``model`` axis) is the paper's Opt O-I, visible directly in the dry-run
HLO — benchmarks/fabric_roofline.py reads it out.

With ``FabricStepConfig.pipeline_depth > 1`` the step takes a WINDOW of D
blocks per invocation and software-pipelines them through the stages
(repro/pipeline/schedule.py): one consensus all-gather, one routed fill
gather (read/write versions + bucket free slots) and ONE fused window
commit scatter instead of one of each per block, with blocks still taking
effect in block order. Depth 1 is this module's single-block body below —
the byte-identical oracle the pipelined path is pinned against, including
windows whose blocks overflow their buckets (tests/test_pipeline.py); both
paths latch the commit overflow flag sticky on the mesh state.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:  # jax >= 0.6: public API, replication check kwarg is `check_vma`
    _shard_map = jax.shard_map
    _SHARD_MAP_NO_CHECK = {"check_vma": False}
except AttributeError:  # jax 0.4.x/0.5.x: experimental, kwarg is `check_rep`
    from jax.experimental.shard_map import shard_map as _shard_map

    _SHARD_MAP_NO_CHECK = {"check_rep": False}

from repro.core import orderer, types, unmarshal
from repro.core import world_state as ws
from repro.launch import state_sharding
from repro.pipeline import stages

U32 = jnp.uint32


class FabricMeshState(NamedTuple):
    """Per-channel peer state, channel dim leading (sharded over `data`)."""

    keys: jnp.ndarray  # (C, NB, S, 2)
    versions: jnp.ndarray  # (C, NB, S)
    values: jnp.ndarray  # (C, NB, S, VW)
    log_head: jnp.ndarray  # (C, 2)
    ledger_head: jnp.ndarray  # (C, 2)
    journal_head: jnp.ndarray  # (C, 2) — state-journal digest chain
    block_no: jnp.ndarray  # (C,) — next block number (journal chain input)
    overflow: jnp.ndarray  # (C, LANES) u32 — STICKY per-shard BITMASK in
    # state_sharding.OVERFLOW_LANES lane words: bit m of lane m//32 set ==
    # shard m (bit 0 for replicated state) ever dropped a write because
    # a bucket ran out of slots. An overflowed channel's version accounting
    # is no longer trustworthy (the dropped insert never bumped), so
    # FabricEngine.verify() reports it unhealthy — and the elastic-state
    # resize policy reads the hot shard straight off the set bits
    # (state_sharding.overflow_bits; both step paths produce identical
    # masks, pinned by the oracle-equivalence tests).


def create_mesh_state(n_channels: int, dims: types.FabricDims,
                      n_buckets: int = 1 << 10, slots: int = 8
                      ) -> FabricMeshState:
    z = lambda *s: jnp.zeros(s, U32)
    return FabricMeshState(
        keys=z(n_channels, n_buckets, slots, 2),
        versions=z(n_channels, n_buckets, slots),
        values=z(n_channels, n_buckets, slots, dims.vw),
        log_head=z(n_channels, 2),
        ledger_head=z(n_channels, 2),
        journal_head=z(n_channels, 2),
        block_no=z(n_channels),
        overflow=z(n_channels, state_sharding.OVERFLOW_LANES),
    )


def state_specs(mesh, *, shard_state: bool = False,
                channels_over_data: bool = True) -> FabricMeshState:
    """Channel dim over `data`. World-state arrays are replicated over
    `model` (replica cluster) by default; with ``shard_state`` their bucket
    dim splits over `model` instead — the high-bit bucket partition of
    launch/state_sharding. Heads stay replicated (identical on every
    rank). With ``channels_over_data=False`` the channel dim replicates
    over `data` instead of sharding it — the fallback for channel groups
    whose size does not divide the data axis (every data rank computes
    every channel of the group; correct, not work-minimal)."""
    d = "data" if channels_over_data else None
    c = lambda nd: P(d, *((None,) * nd))
    s = lambda nd: P(d, "model", *((None,) * (nd - 1)))
    st = s if shard_state else c
    return FabricMeshState(
        keys=st(3), versions=st(2), values=st(3), log_head=c(1),
        ledger_head=c(1), journal_head=c(1), block_no=c(0), overflow=c(1),
    )


def make_fabric_step(dims: types.FabricDims, cfg: "FabricStepConfig", mesh,
                     *, channels_over_data: bool = True, channel=None):
    """Build the jit-able sharded step for C independent channels.

    Inputs (global shapes), with D = ``cfg.pipeline_depth``:
      state: FabricMeshState with C channels leading
      depth 1:  wire (C, B_round, WB) u8, ids (C, B_round, 2) u32
      depth D>1: wire (C, D, B_round, WB) u8, ids (C, D, B_round, 2) u32
    where B_round is one whole channel block; each model rank ingests
    B_round/model_size per block. Returns (state, valid) with valid
    (C, B_round) at depth 1 and (C, D, B_round) at depth D.

    The channel dim shards over `data` ranks when ``channels_over_data``
    (C must be a multiple of the data axis size; each rank holds
    C/data_size local channels) and replicates otherwise. Inside the
    shard_map body the per-channel math is vmapped over the local channel
    axis, so any C_loc >= 1 runs in ONE dispatch — channels share the
    step's collectives but no state, heads, or validity bits (the
    cross-channel isolation the multi-channel tests pin). ``channel``
    (static id or tuple of ids) names the channel(s) in shape-cap raises.

    With ``cfg.shard_state`` the world-state bucket dim is partitioned over
    ``model`` (each rank holds NB/model_size buckets, the high-bit bucket
    partition); reads route to their owner rank via masked-psum gather and
    commits apply only on the owning shard. The replicated path stays as
    the oracle — both must produce byte-identical validity bits and
    ledger/log heads. Depth D > 1 pipelines the window's blocks
    (repro/pipeline/schedule.py) and must be byte-identical to D
    invocations of the depth-1 step.
    """
    msize = mesh.shape["model"]
    if cfg.pipeline_depth > 1:
        return _make_pipelined(dims, cfg, mesh, msize,
                               channels_over_data=channels_over_data,
                               channel=channel)
    spw = unmarshal.struct_prefix_words(dims)

    def chan_body(keys, vers, vals, log_head, ledger_head, journal_head,
                  bno, ovf, wire, ids):
        # ONE channel's local shapes: (NB, S, 2), ..., (B_loc, WB). The
        # shard_map body below vmaps this over the local channel axis.
        b_loc = wire.shape[0]

        # --- 1. local syntactic verification (P-II: validate-where-ingested)
        words, txb_loc, checksum_ok = stages.stage_syntax(wire, dims)
        # Local endorsement verification (worst case: every tag checked).
        endorse_ok = stages.stage_endorse(txb_loc)
        ok_loc = checksum_ok & endorse_ok

        # --- 2. consensus replication over the `model` replica cluster.
        published = (words[:, :spw] if cfg.separate_metadata else words)
        log_glob = jax.lax.all_gather(
            published, "model", axis=0, tiled=True
        )  # (B_round, spw|W)
        log_head = stages.fold_log_head(log_head, log_glob, cfg)

        # --- 3. deterministic order over the channel round.
        ids_glob = jax.lax.all_gather(ids, "model", axis=0, tiled=True)
        order = orderer.consensus_order(ids_glob)

        # --- 4. replicated validation state: flags + structured sets.
        ok_glob = jax.lax.all_gather(ok_loc, "model", axis=0, tiled=True)
        ordered_words = log_glob[order]
        txb = stages.decode_published(
            ordered_words, dims, cfg.separate_metadata
        )
        ok_ord = ok_glob[order]

        st = ws.HashState(keys=keys, versions=vers, values=vals)
        if cfg.shard_state:
            # Routed path: `st` is this rank's bucket shard; reads gather
            # (found, version, value) from the owner rank by masked psum.
            nb_glob = st.n_buckets * msize
            cur = state_sharding.sharded_lookup(
                st, txb.read_keys.reshape(-1, 2), nb_glob, msize
            ).versions.reshape(txb.batch, -1)
        else:
            nb_glob = st.n_buckets
            cur = ws.lookup(
                st, txb.read_keys.reshape(-1, 2)
            ).versions.reshape(txb.batch, -1)

        # --- 5. MVCC + commit (sharded: owner ranks only; else every
        # replica applies the same deltas). The overflow bitmask latches
        # sticky: a dropped insert silently miscounted versions before,
        # and bit m names the hot shard the resize policy should split.
        st2, valid, blk_ovf = stages.stage_mvcc_commit(
            st, txb, ok_ord, cur, cfg,
            n_buckets_global=nb_glob, n_shards=msize, channel=channel,
        )
        ovf = ovf | blk_ovf

        # Ledger append over the ordered round (content + validity), and
        # the state-journal head over the validated write sets.
        led = stages.fold_ledger_head(ledger_head, ordered_words, valid, cfg)
        jrn = stages.advance_journal_head(journal_head, bno, txb, valid)

        # Un-order validity back to ingest layout, return this rank's slice.
        inv = jnp.argsort(order)
        valid_ingest = valid[inv]
        rank = jax.lax.axis_index("model")
        mine = jax.lax.dynamic_slice_in_dim(
            valid_ingest, rank * b_loc, b_loc
        )
        return (
            st2.keys, st2.versions, st2.values,
            log_head, led, jrn, bno + jnp.uint32(1), ovf, mine,
        )

    def step_local(*args):
        # Channels are independent: vmap the per-channel body over the
        # local channel axis (C_loc = C / data_size when sharded, C when
        # replicated). Collectives inside the body batch over channels.
        return jax.vmap(chan_body)(*args)

    cspec = state_specs(mesh, shard_state=cfg.shard_state,
                        channels_over_data=channels_over_data)
    cd = "data" if channels_over_data else None
    io_spec = P(cd, "model", None)
    step = _shard_map(
        step_local,
        mesh=mesh,
        in_specs=(cspec.keys, cspec.versions, cspec.values,
                  cspec.log_head, cspec.ledger_head, cspec.journal_head,
                  cspec.block_no, cspec.overflow, io_spec, io_spec),
        out_specs=(cspec.keys, cspec.versions, cspec.values, cspec.log_head,
                   cspec.ledger_head, cspec.journal_head, cspec.block_no,
                   cspec.overflow, P(cd, "model")),
        **_SHARD_MAP_NO_CHECK,
    )

    def apply(state: FabricMeshState, wire, ids):
        if cfg.shard_state:
            ws.shard_buckets(state.keys.shape[1], msize)  # validate split
        out = step(
            state.keys, state.versions, state.values, state.log_head,
            state.ledger_head, state.journal_head, state.block_no,
            state.overflow, wire, ids,
        )
        return FabricMeshState(*out[:-1]), out[-1]

    return apply


def _make_pipelined(dims: types.FabricDims, cfg: "FabricStepConfig", mesh,
                    msize: int, *, channels_over_data: bool = True,
                    channel=None):
    """Window variant: D blocks in flight per invocation (schedule.py)."""
    from repro.pipeline import schedule  # local: keeps layering one-way

    depth = cfg.pipeline_depth
    body = schedule.make_window_body(dims, cfg, msize, depth,
                                     channel=channel)

    def step_local(*args):
        # vmap the single-channel window body over the local channel axis.
        return jax.vmap(body)(*args)

    cspec = state_specs(mesh, shard_state=cfg.shard_state,
                        channels_over_data=channels_over_data)
    cd = "data" if channels_over_data else None
    io_spec = P(cd, None, "model", None)  # (C, D, B_round, ...)
    step = _shard_map(
        step_local,
        mesh=mesh,
        in_specs=(cspec.keys, cspec.versions, cspec.values,
                  cspec.log_head, cspec.ledger_head, cspec.journal_head,
                  cspec.block_no, cspec.overflow, io_spec, io_spec),
        out_specs=(cspec.keys, cspec.versions, cspec.values, cspec.log_head,
                   cspec.ledger_head, cspec.journal_head, cspec.block_no,
                   cspec.overflow, P(cd, None, "model")),
        **_SHARD_MAP_NO_CHECK,
    )

    def apply(state: FabricMeshState, wire, ids):
        if cfg.shard_state:
            ws.shard_buckets(state.keys.shape[1], msize)  # validate split
        if wire.ndim != 4 or wire.shape[1] != depth:
            raise ValueError(
                f"pipeline_depth={depth} expects wire (C, {depth}, B, WB); "
                f"got {wire.shape}"
            )
        out = step(
            state.keys, state.versions, state.values, state.log_head,
            state.ledger_head, state.journal_head, state.block_no,
            state.overflow, wire, ids,
        )
        return FabricMeshState(*out[:-1]), out[-1]

    return apply


@dataclasses.dataclass(frozen=True)
class FabricStepConfig:
    separate_metadata: bool = True  # O-I
    pipelined: bool = True  # O-II
    sequential_commit: bool = False  # paper-faithful serial commit if True
    tree_hash: bool = False  # beyond-paper: O(log B) consensus-log fold
    # (replaces the serial 1600-step chain with a Merkle-style pairwise
    # reduction — different but equally deterministic log head; §Perf)
    shard_state: bool = False  # beyond-paper: world state sharded over
    # `model` by high bucket bits (launch/state_sharding) — the table grows
    # model_size x beyond one device's VMEM budget; replicated path is the
    # oracle (byte-identical validity bits and ledger/log heads).
    pipeline_depth: int = 1  # P-II device-side block pipeline: blocks in
    # flight per step invocation (repro/pipeline). Depth 1 is the
    # single-block path above; depth D takes a (C, D, B, ...) window,
    # issues ONE consensus gather + ONE routed fill gather + ONE fused
    # window commit scatter, and must stay byte-identical to D depth-1
    # invocations — including when blocks overflow their buckets.

    @property
    def name(self) -> str:
        base = "fastfabric" if self.separate_metadata else "fabric-1.2"
        return (base + ("+tree" if self.tree_hash else "")
                + ("+shard" if self.shard_state else "")
                + (f"+pipe{self.pipeline_depth}"
                   if self.pipeline_depth > 1 else ""))


FASTFABRIC_STEP = FabricStepConfig()
FASTFABRIC_SHARDED_STEP = FabricStepConfig(shard_state=True)
FASTFABRIC_PIPELINED_STEP = FabricStepConfig(shard_state=True,
                                             pipeline_depth=8)
FABRIC_V12_STEP = FabricStepConfig(
    separate_metadata=False, pipelined=False, sequential_commit=True
)


def input_specs(mesh, dims: types.FabricDims, b_loc: int = 100,
                pipeline_depth: int = 1, n_channels: int | None = None):
    """ShapeDtypeStructs for the dry-run: one round of B_loc txs per device
    (per block; ``pipeline_depth`` blocks per window when > 1).
    ``n_channels`` defaults to one channel per data rank."""
    c = n_channels if n_channels is not None else mesh.shape["data"]
    m = mesh.shape["model"]
    b_round = b_loc * m
    wb = 4 * dims.payload_words
    if pipeline_depth > 1:
        d = pipeline_depth
        return (
            jax.ShapeDtypeStruct((c, d, b_round, wb), jnp.uint8),
            jax.ShapeDtypeStruct((c, d, b_round, 2), U32),
        )
    return (
        jax.ShapeDtypeStruct((c, b_round, wb), jnp.uint8),
        jax.ShapeDtypeStruct((c, b_round, 2), U32),
    )


# ---------------------------------------------------------------------------
# Contract-analyzer registrations (repro.analysis): each step variant
# self-registers a builder the gate AOT-lowers with the SAME jit wrapper
# and donation the live committer uses — no workload runs.
# ---------------------------------------------------------------------------

from repro.analysis import registry as _areg  # noqa: E402


def _register_step(name: str, cfg: FabricStepConfig, depth: int,
                   n_channels: int = 1, description: str = "") -> None:
    @_areg.register(name, description=description)
    def _build(ctx, cfg=cfg, depth=depth, n_channels=n_channels):
        dcfg = dataclasses.replace(cfg, pipeline_depth=depth)
        step = jax.jit(
            make_fabric_step(ctx.dims, dcfg, ctx.mesh), donate_argnums=(0,)
        )
        state = jax.eval_shape(lambda: create_mesh_state(
            n_channels, ctx.dims, n_buckets=ctx.n_buckets, slots=ctx.slots
        ))
        wire_s, ids_s = input_specs(
            ctx.mesh, ctx.dims, b_loc=ctx.b_loc, pipeline_depth=depth,
            n_channels=n_channels,
        )
        nb_local = ctx.n_buckets // (
            ctx.mesh.shape["model"] if dcfg.shard_state else 1
        )
        return _areg.BuiltProgram(
            name=name, fn=step, args=(state, wire_s, ids_s),
            donate_argnums=(0,), nb_local=nb_local, slots=ctx.slots,
            meta={"depth": depth, "n_channels": n_channels,
                  "config": dcfg.name},
        )


_register_step("fabric_step/repl/d1", FASTFABRIC_STEP, 1,
               description="replicated-state single-block step (the oracle)")
_register_step("fabric_step/shard/d1", FASTFABRIC_SHARDED_STEP, 1,
               description="bucket-sharded single-block step (routed MVCC)")
_register_step("fabric_step/shard/d8", FASTFABRIC_PIPELINED_STEP, 8,
               description="sharded depth-8 window step (fused commit)")
_register_step("fabric_step/shard/d4/c2", FASTFABRIC_SHARDED_STEP, 4,
               n_channels=2,
               description="two channels vmapped through a depth-4 window")
