"""Distributed FastFabric step over the production mesh (shard_map).

Topology mapping (DESIGN.md §2/§5): one *channel* per ``data`` rank (the
paper's future-work "separate ordering and fast peer per channel"), and the
``model`` axis inside a channel is the orderer-replica/validation-worker
cluster. Per step and channel:

  1. ingest      — each model rank holds B_loc client proposals (payloads
                   stay put for the whole step: the O-I invariant);
                   syntactic checksum runs locally (P-II parallel
                   validation: each worker validates what it ingested);
  2. consensus   — the log is replicated to every orderer replica:
                   all-gather over ``model`` of the FULL wire (baseline) or
                   only the structured prefix (O-I: IDs + rw sets + tags,
                   ~structure bytes instead of payload bytes) + chain hash;
  3. order       — deterministic interleave by ID hash (identical on every
                   replica, consensus-free);
  4. validate    — endorsement MACs on local txs (parallel), validity bits
                   all-gathered (1 word/tx); MVCC runs on the replicated
                   structured sets — the sequential scan every replica
                   executes identically;
  5. commit      — the channel's world state (replicated over ``model``,
                   sharded over ``data``) applies valid write sets.

The collective-byte asymmetry (payload vs structure bytes over the
``model`` axis) is the paper's Opt O-I, visible directly in the dry-run
HLO — benchmarks/fabric_roofline.py reads it out.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:  # jax >= 0.6: public API, replication check kwarg is `check_vma`
    _shard_map = jax.shard_map
    _SHARD_MAP_NO_CHECK = {"check_vma": False}
except AttributeError:  # jax 0.4.x/0.5.x: experimental, kwarg is `check_rep`
    from jax.experimental.shard_map import shard_map as _shard_map

    _SHARD_MAP_NO_CHECK = {"check_rep": False}

from repro.core import crypto, hashing, mvcc, orderer, types, unmarshal
from repro.core import world_state as ws
from repro.launch import state_sharding

U32 = jnp.uint32


class FabricMeshState(NamedTuple):
    """Per-channel peer state, channel dim leading (sharded over `data`)."""

    keys: jnp.ndarray  # (C, NB, S, 2)
    versions: jnp.ndarray  # (C, NB, S)
    values: jnp.ndarray  # (C, NB, S, VW)
    log_head: jnp.ndarray  # (C, 2)
    ledger_head: jnp.ndarray  # (C, 2)


def create_mesh_state(n_channels: int, dims: types.FabricDims,
                      n_buckets: int = 1 << 10, slots: int = 8
                      ) -> FabricMeshState:
    z = lambda *s: jnp.zeros(s, U32)
    return FabricMeshState(
        keys=z(n_channels, n_buckets, slots, 2),
        versions=z(n_channels, n_buckets, slots),
        values=z(n_channels, n_buckets, slots, dims.vw),
        log_head=z(n_channels, 2),
        ledger_head=z(n_channels, 2),
    )


def state_specs(mesh, *, shard_state: bool = False) -> FabricMeshState:
    """Channel dim over `data`. World-state arrays are replicated over
    `model` (replica cluster) by default; with ``shard_state`` their bucket
    dim splits over `model` instead — the high-bit bucket partition of
    launch/state_sharding. Heads stay replicated (identical on every
    rank)."""
    c = lambda nd: P("data", *((None,) * nd))
    s = lambda nd: P("data", "model", *((None,) * (nd - 1)))
    st = s if shard_state else c
    return FabricMeshState(
        keys=st(3), versions=st(2), values=st(3), log_head=c(1),
        ledger_head=c(1),
    )


def _fold_log(head, digests):
    """Chain per-row digests into the consensus log head (C-free, (2,))."""
    def fold(h, d):
        return jnp.stack(
            [hashing.combine(h[0], d), hashing.combine(h[1], d)]
        ), None

    head, _ = jax.lax.scan(fold, head, digests)
    return head


def _fold_log_tree(head, digests):
    """Merkle-style pairwise reduction: O(log B) sequential depth instead
    of the O(B) chain — the beyond-paper collapse of the last serial stage
    of consensus (§Perf fabric iteration). Deterministic; head folds in
    once at the root."""
    d = digests
    while d.shape[0] > 1:
        if d.shape[0] % 2:
            d = jnp.concatenate([d, d[-1:]])
        d = hashing.combine(d[0::2], d[1::2])
    return jnp.stack(
        [hashing.combine(head[0], d[0]), hashing.combine(head[1], d[0])]
    )


def make_fabric_step(dims: types.FabricDims, cfg: "FabricStepConfig", mesh):
    """Build the jit-able sharded step.

    Inputs (global shapes):
      state: FabricMeshState with C = data axis size
      wire (C, B_round, WB) u8, ids (C, B_round, 2) u32 — B_round is the
      whole channel round; each model rank ingests B_round/model_size.
    Returns (state, valid (C, B_round) bool).

    With ``cfg.shard_state`` the world-state bucket dim is partitioned over
    ``model`` (each rank holds NB/model_size buckets, the high-bit bucket
    partition); reads route to their owner rank via masked-psum gather and
    commits apply only on the owning shard. The replicated path stays as
    the oracle — both must produce byte-identical validity bits and
    ledger/log heads.
    """
    spw = unmarshal.struct_prefix_words(dims)
    msize = mesh.shape["model"]

    def step_local(keys, vers, vals, log_head, ledger_head, wire, ids):
        # Shapes inside shard_map: (1, NB, S, 2), ..., (1, B_loc, WB).
        keys, vers, vals = keys[0], vers[0], vals[0]
        log_head, ledger_head = log_head[0], ledger_head[0]
        wire, ids = wire[0], ids[0]
        b_loc, wb = wire.shape

        words = jax.lax.bitcast_convert_type(
            wire.reshape(b_loc, wb // 4, 4), U32
        ).reshape(b_loc, wb // 4)

        # --- 1. local syntactic verification (P-II: validate-where-ingested)
        checksum_ok = (
            unmarshal.payload_checksum(words)
            == words[:, unmarshal.CHECKSUM_WORD]
        )
        # Local endorsement verification (worst case: every tag checked).
        txb_loc = unmarshal.unmarshal(wire, dims).txb
        endorse_ok = crypto.verify_tags(txb_loc)
        ok_loc = checksum_ok & endorse_ok

        # --- 2. consensus replication over the `model` replica cluster.
        published = (words[:, :spw] if cfg.separate_metadata else words)
        log_glob = jax.lax.all_gather(
            published, "model", axis=0, tiled=True
        )  # (B_round, spw|W)
        if cfg.pipelined:
            digests = hashing.hash_words(log_glob, seed=hashing.SEED_A)
            fold = _fold_log_tree if cfg.tree_hash else _fold_log
            log_head = fold(log_head, digests)
        else:
            def ser(h, row):
                d1 = hashing.hash_words(row[None, :], seed=h[0])[0]
                d2 = hashing.hash_words(row[None, :], seed=h[1])[0]
                return jnp.stack([d1, d2]), None

            log_head, _ = jax.lax.scan(ser, log_head, log_glob)

        # --- 3. deterministic order over the channel round.
        ids_glob = jax.lax.all_gather(ids, "model", axis=0, tiled=True)
        order = orderer.consensus_order(ids_glob)

        # --- 4. replicated validation state: flags + structured sets.
        ok_glob = jax.lax.all_gather(ok_loc, "model", axis=0, tiled=True)
        ordered_words = log_glob[order]
        if cfg.separate_metadata:
            txb = unmarshal.unmarshal_prefix(ordered_words, dims)
        else:  # baseline replicated the whole wire; decode it again here
            wire_glob = jax.lax.bitcast_convert_type(
                ordered_words, jnp.uint8
            ).reshape(ordered_words.shape[0], -1)
            txb = unmarshal.unmarshal(wire_glob, dims).txb
        ok_ord = ok_glob[order]

        st = ws.HashState(keys=keys, versions=vers, values=vals)
        if cfg.shard_state:
            # Routed path: `st` is this rank's bucket shard; reads gather
            # (found, version, value) from the owner rank by masked psum.
            nb_glob = st.n_buckets * msize
            cur = state_sharding.sharded_lookup(
                st, txb.read_keys.reshape(-1, 2), nb_glob, msize
            ).versions.reshape(txb.batch, -1)
        else:
            cur = ws.lookup(
                st, txb.read_keys.reshape(-1, 2)
            ).versions.reshape(txb.batch, -1)
        res = mvcc.validate(txb, cur, checksum_ok=ok_ord)

        # --- 5. commit (sharded: owner ranks only; else every replica
        # applies the same deltas).
        if cfg.shard_state:
            cres = state_sharding.sharded_commit(
                st, txb.write_keys, txb.write_vals, res.valid,
                nb_glob, msize, sequential=cfg.sequential_commit,
            )
        else:
            cres = ws.commit(
                st, txb.write_keys, txb.write_vals, res.valid,
                sequential=cfg.sequential_commit,
            )
        st2 = cres.state

        # Ledger append over the ordered round (content + validity).
        d1 = hashing.hash_words(ordered_words, seed=hashing.SEED_A)
        fold2 = _fold_log_tree if cfg.tree_hash else _fold_log
        led = fold2(ledger_head, d1 ^ res.valid.astype(U32))

        # Un-order validity back to ingest layout, return this rank's slice.
        inv = jnp.argsort(order)
        valid_ingest = res.valid[inv]
        rank = jax.lax.axis_index("model")
        mine = jax.lax.dynamic_slice_in_dim(
            valid_ingest, rank * b_loc, b_loc
        )
        return (
            st2.keys[None], st2.versions[None], st2.values[None],
            log_head[None], led[None], mine[None],
        )

    cspec = state_specs(mesh, shard_state=cfg.shard_state)
    io_spec = P("data", "model", None)
    step = _shard_map(
        step_local,
        mesh=mesh,
        in_specs=(cspec.keys, cspec.versions, cspec.values,
                  cspec.log_head, cspec.ledger_head, io_spec, io_spec),
        out_specs=(cspec.keys, cspec.versions, cspec.values, cspec.log_head,
                   cspec.ledger_head, P("data", "model")),
        **_SHARD_MAP_NO_CHECK,
    )

    def apply(state: FabricMeshState, wire, ids):
        if cfg.shard_state:
            ws.shard_buckets(state.keys.shape[1], msize)  # validate split
        keys, vers, vals, log_head, led, valid = step(
            state.keys, state.versions, state.values, state.log_head,
            state.ledger_head, wire, ids,
        )
        return FabricMeshState(keys, vers, vals, log_head, led), valid

    return apply


@dataclasses.dataclass(frozen=True)
class FabricStepConfig:
    separate_metadata: bool = True  # O-I
    pipelined: bool = True  # O-II
    sequential_commit: bool = False  # paper-faithful serial commit if True
    tree_hash: bool = False  # beyond-paper: O(log B) consensus-log fold
    # (replaces the serial 1600-step chain with a Merkle-style pairwise
    # reduction — different but equally deterministic log head; §Perf)
    shard_state: bool = False  # beyond-paper: world state sharded over
    # `model` by high bucket bits (launch/state_sharding) — the table grows
    # model_size x beyond one device's VMEM budget; replicated path is the
    # oracle (byte-identical validity bits and ledger/log heads).

    @property
    def name(self) -> str:
        base = "fastfabric" if self.separate_metadata else "fabric-1.2"
        return (base + ("+tree" if self.tree_hash else "")
                + ("+shard" if self.shard_state else ""))


FASTFABRIC_STEP = FabricStepConfig()
FASTFABRIC_SHARDED_STEP = FabricStepConfig(shard_state=True)
FABRIC_V12_STEP = FabricStepConfig(
    separate_metadata=False, pipelined=False, sequential_commit=True
)


def input_specs(mesh, dims: types.FabricDims, b_loc: int = 100):
    """ShapeDtypeStructs for the dry-run: one round of B_loc txs per device."""
    c = mesh.shape["data"]
    m = mesh.shape["model"]
    b_round = b_loc * m
    return (
        jax.ShapeDtypeStruct((c, b_round, 4 * dims.payload_words),
                             jnp.uint8),
        jax.ShapeDtypeStruct((c, b_round, 2), U32),
    )
