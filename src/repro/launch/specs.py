"""ShapeDtypeStruct stand-ins for every (arch x shape) dry-run cell.

No device allocation happens here: params/caches come from jax.eval_shape,
inputs are ShapeDtypeStructs. ``enc_len_for``/``text_len_for`` centralize
the modality-stub conventions (audio frames = seq//4; vision prefix =
cfg.n_prefix patches).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeSpec
from repro.models.lm import LM, Batch
from repro.training import optimizer, train_step as ts_lib


def enc_len_for(cfg: ModelConfig, seq_len: int) -> int:
    """Audio-frame (encoder) length for encdec archs: seq//4."""
    return seq_len // 4 if cfg.family == "encdec" else 0


def text_len_for(cfg: ModelConfig, seq_len: int) -> int:
    """Token positions = seq minus the vision prefix."""
    if cfg.frontend == "vision":
        return seq_len - cfg.n_prefix
    return seq_len


def batch_specs(cfg: ModelConfig, seq_len: int, batch: int,
                *, with_labels: bool) -> Batch:
    s = lambda *shape, dt=jnp.int32: jax.ShapeDtypeStruct(shape, dt)
    st = text_len_for(cfg, seq_len)
    prefix = None
    enc = None
    if cfg.frontend == "vision":
        prefix = s(batch, cfg.n_prefix, cfg.d_model, dt=cfg.jnp_dtype)
    if cfg.family == "encdec":
        enc = s(batch, enc_len_for(cfg, seq_len), cfg.d_model,
                dt=cfg.jnp_dtype)
    return Batch(
        tokens=s(batch, st),
        labels=s(batch, st) if with_labels else None,
        prefix_embeds=prefix,
        enc_embeds=enc,
    )


def param_shapes(model: LM):
    return jax.eval_shape(model.init, jax.random.PRNGKey(0))


def train_state_shapes(model: LM):
    return jax.eval_shape(
        lambda k: ts_lib.init_state(model, k), jax.random.PRNGKey(0)
    )


def cache_shapes(model: LM, batch: int, seq_len: int):
    enc_len = enc_len_for(model.cfg, seq_len)
    return jax.eval_shape(
        lambda: model.init_cache(batch, seq_len, enc_len=enc_len)
    )


def decode_token_specs(batch: int):
    return (jax.ShapeDtypeStruct((batch,), jnp.int32),
            jax.ShapeDtypeStruct((), jnp.int32))
