"""Model-axis sharded world state: routing, sharded lookup/commit, digests.

FastFabric's P-I in-memory state table is the hot structure every pipeline
stage touches. The replicated layout (every ``model`` rank holds the whole
table) caps the table at one device's VMEM budget and wastes memory
``model_size``-fold. This module partitions the buckets across ``model``
ranks by the HIGH bits of the global bucket index (core.world_state.shard_of):

  * rank m owns the contiguous bucket range [m*nb_loc, (m+1)*nb_loc), so the
    global (NB, S, ...) arrays split over the mesh ``model`` axis — or a
    host-side reshape to (M, nb_loc, S, ...) — ARE the shard layout;
  * a shard-local probe with nb_loc buckets masks to the LOW bucket bits,
    which is exactly the local index of an owned key, so the replicated
    lookup/commit code runs unchanged on the local slice;
  * lookups route read keys to their owner rank with a masked psum-gather
    of (found, version, value): every rank probes the (replicated) key
    batch against its local shard, masks by ownership, and one psum over
    ``model`` delivers the owner's answer everywhere (each key has exactly
    one owner, so the sum is a select);
  * commits apply each block's validated write set only on the owning
    shard, by blanking non-owned write keys to the EMPTY sentinel before
    the ordinary commit.

Equivalence: concatenating the shard tables in rank order reproduces the
replicated table ARRAY-FOR-ARRAY (same buckets, same slot assignment, same
versions), because same-bucket writes always share an owner, so intra-bucket
slot ranking sees the same write sequence. Sharded and replicated
fabric-step configs must therefore produce byte-identical validity bits,
ledger heads, and state contents — tests/test_state_sharding.py pins this.

The per-shard digests fold into one head with world_state.shard_digest_tree
(deterministic tree in rank order); the XOR-fold state_digest also
decomposes across shards (XOR of shard digests == full-table digest).

These helpers run INSIDE shard_map bodies (they use axis primitives); the
host-side single-device analogues used by kernels/hash_table/ops.py live in
split_table / merge_table.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import world_state as ws

U32 = jnp.uint32


def owned_mask(keys: jnp.ndarray, n_buckets_global: int, n_shards: int,
               *, axis: str = "model") -> jnp.ndarray:
    """Mask of paired keys (..., 2) owned by this rank's shard -> (...,)."""
    rank = jax.lax.axis_index(axis)
    return ws.shard_of(n_buckets_global, n_shards, keys) == rank


def sharded_lookup(local: ws.HashState, keys: jnp.ndarray,
                   n_buckets_global: int, n_shards: int,
                   *, axis: str = "model") -> ws.Lookup:
    """Routed probe: every rank holds the replicated (B, 2) key batch; the
    owner's local result is gathered with a masked psum. ``slots`` in the
    result are shard-local (meaningful only on the owner rank)."""
    mine = owned_mask(keys, n_buckets_global, n_shards, axis=axis)
    look = ws.lookup(local, keys)  # local bucket = low bits: owned keys land
    z = jnp.uint32(0)
    found = jax.lax.psum(
        jnp.where(mine, look.found, False).astype(U32), axis
    ) > 0
    vers = jax.lax.psum(jnp.where(mine, look.versions, z), axis)
    vals = jax.lax.psum(jnp.where(mine[:, None], look.values, z), axis)
    return ws.Lookup(found=found, versions=vers, values=vals,
                     slots=look.slots)


def sharded_lookup_versions(local: ws.HashState, keys: jnp.ndarray,
                            n_buckets_global: int, n_shards: int,
                            *, axis: str = "model") -> jnp.ndarray:
    """Routed *version-only* gather for a flat (K, 2) key batch -> (K,) u32.

    The MVCC read-version check needs only versions, so this issues ONE
    masked psum over ``axis`` instead of :func:`sharded_lookup`'s three
    (found / versions / values). The block pipeline coalesces the read
    sets of all in-flight blocks into a single call per pipeline fill
    (repro/pipeline/batched_mvcc.py) — one routed all-to-all per window
    instead of one per block, the ROADMAP cross-shard-batching item.
    """
    mine = owned_mask(keys, n_buckets_global, n_shards, axis=axis)
    vers = ws.lookup(local, keys).versions
    return jax.lax.psum(jnp.where(mine, vers, jnp.uint32(0)), axis)


def sharded_window_fill(local: ws.HashState, keys: jnp.ndarray,
                        free_keys: jnp.ndarray, n_buckets_global: int,
                        n_shards: int, *, axis: str = "model"):
    """Routed fill-time gather for the fused-commit pipeline: versions of a
    flat (K, 2) key batch AND empty-slot counts of the buckets of a flat
    (F, 2) key batch, in ONE masked psum over ``axis``.

    The free counts feed the pipeline's overflow planner
    (pipeline/batched_mvcc.plan_block_writes): a block's insert fits iff
    its rank among the window's new keys to that bucket is below the
    bucket's fill-time free-slot count minus the slots already consumed by
    earlier in-window inserts. Returns (versions (K,) u32, free (F,) u32).
    """
    mine_v = owned_mask(keys, n_buckets_global, n_shards, axis=axis)
    vers = jnp.where(mine_v, ws.lookup(local, keys).versions, jnp.uint32(0))
    mine_f = owned_mask(free_keys, n_buckets_global, n_shards, axis=axis)
    free = jnp.where(
        mine_f, ws.bucket_free_slots(local, free_keys), jnp.uint32(0)
    )
    out = jax.lax.psum(jnp.concatenate([vers, free]), axis)
    return out[: keys.shape[0]], out[keys.shape[0]:]


class RoutedCommitResult(NamedTuple):
    state: ws.HashState
    overflow: jnp.ndarray  # () bool — any shard overflowed (step contract)
    shard_overflow: jnp.ndarray  # (M,) bool — WHICH shards filled


def sharded_commit(local: ws.HashState, write_keys: jnp.ndarray,
                   write_vals: jnp.ndarray, active: jnp.ndarray,
                   n_buckets_global: int, n_shards: int,
                   *, axis: str = "model",
                   sequential: bool = False) -> RoutedCommitResult:
    """Apply a block's validated write set on the owning shards only.

    Non-owned write keys are blanked to the EMPTY sentinel, which the
    commit's flatten step drops — ``active`` stays per-transaction, so a
    transaction whose writes straddle shards commits each write on its
    owner. Overflow is reduced with one psum of a rank-one-hot vector, so
    the result carries both the global OR (the step contract's sticky
    flag) and the per-shard vector (diagnostics / rebalancing can target
    the hot shard instead of guessing which of M tables filled).
    """
    mine = owned_mask(write_keys, n_buckets_global, n_shards, axis=axis)
    wk = jnp.where(mine[..., None], write_keys, jnp.uint32(0))
    res = ws.commit(local, wk, write_vals, active, sequential=sequential)
    rank = jax.lax.axis_index(axis)
    onehot = (jnp.arange(n_shards) == rank) & res.overflow
    shard_ovf = jax.lax.psum(onehot.astype(U32), axis) > 0  # (M,)
    return RoutedCommitResult(state=res.state, overflow=shard_ovf.any(),
                              shard_overflow=shard_ovf)


def commit_window_routed(local: ws.HashState, log_keys: jnp.ndarray,
                         log_vals: jnp.ndarray, log_bumps: jnp.ndarray,
                         log_new: jnp.ndarray, n_buckets_global: int,
                         n_shards: int, *, axis: str = "model"
                         ) -> ws.HashState:
    """Owner-shard variant of :func:`world_state.commit_window`.

    The window write log is replicated (every rank planned it from the
    same routed fill gather); each rank applies only its owned entries —
    non-owned keys blank to EMPTY and their bump/new flags are masked, so
    the local fused scatter touches exactly the owned buckets. Purely
    local: the single routed collective of the window is the fill gather.
    """
    mine = owned_mask(log_keys, n_buckets_global, n_shards, axis=axis)
    lk = jnp.where(mine[:, None], log_keys, jnp.uint32(0))
    return ws.commit_window(
        local, lk, log_vals, log_bumps & mine, log_new & mine
    )


# Sticky overflow bitmask lanes. JAX disables 64-bit ints by default, so
# the mask is carried as OVERFLOW_LANES u32 words (lane l holds shard bits
# [32*l, 32*l+32)) instead of one u64 — 64 model ranks of exact hot-shard
# reporting. Host-side code converts with bits_to_int / int_to_lanes.
OVERFLOW_LANES = 2
MAX_OVERFLOW_SHARDS = 32 * OVERFLOW_LANES


def overflow_bits(shard_overflow: jnp.ndarray, *,
                  channel=None) -> jnp.ndarray:
    """Per-shard overflow vector (M,) bool -> sticky BITMASK (LANES,) u32.

    Bit m of lane m//32 set == shard m dropped a write on a full bucket.
    The mesh state latches these words sticky (FabricMeshState.overflow),
    so the resize policy can pick the hot shard without a second
    collective; M <= 32 * OVERFLOW_LANES (one mesh axis of model ranks).
    ``channel`` (a channel id or tuple of ids, static) names the channel(s)
    in the too-many-shards raise — a multi-channel mesh otherwise reports
    the cap with no way to tell WHICH channel's state hit it."""
    m = shard_overflow.shape[0]
    if m > MAX_OVERFLOW_SHARDS:
        where = "" if channel is None else f" (channel {channel})"
        raise ValueError(
            f"overflow bitmask supports <= {MAX_OVERFLOW_SHARDS} shards, "
            f"got {m}{where}"
        )
    idx = jnp.arange(m)
    word = shard_overflow.astype(U32) << (idx % 32).astype(U32)  # (M,)
    lane = (idx // 32)[:, None] == jnp.arange(OVERFLOW_LANES)  # (M, LANES)
    return (word[:, None] * lane).sum(axis=0, dtype=U32)  # (LANES,)


def dropped_write_bits(keys: jnp.ndarray, dropped: jnp.ndarray,
                       n_buckets_global: int, n_shards: int, *,
                       channel=None) -> jnp.ndarray:
    """Overflow bitmask of a window's dropped writes, (LANES,) u32.

    ``keys`` (L, 2) / ``dropped`` (L,) bool are the write planner's log row
    (pipeline/batched_mvcc.plan_block_writes) — replicated on every rank,
    so the owner-shard fold needs NO collective and must equal the bitmask
    the depth-1 routed commit produces (bit m == shard m dropped)."""
    owner = ws.shard_of(n_buckets_global, n_shards, keys)  # (L,)
    onehot = (
        (owner[:, None] == jnp.arange(n_shards)) & dropped[:, None]
    ).any(axis=0)  # (M,)
    return overflow_bits(onehot, channel=channel)


def bits_to_int(lanes) -> int:
    """Host-side decode: (LANES,) u32 lane words -> one Python int."""
    import numpy as np

    arr = np.asarray(lanes).reshape(-1).astype(np.uint64)
    return int(sum(int(w) << (32 * l) for l, w in enumerate(arr)))


def int_to_lanes(bits: int):
    """Host-side encode: Python int -> (LANES,) u32 lane words."""
    import numpy as np

    return np.array(
        [(bits >> (32 * l)) & 0xFFFFFFFF for l in range(OVERFLOW_LANES)],
        dtype=np.uint32,
    )


class RoutedResizeResult(NamedTuple):
    state: ws.HashState  # this rank's NEW local bucket shard
    overflow: jnp.ndarray  # () bool — any shard dropped entries (shrink)
    shard_overflow: jnp.ndarray  # (M,) bool — WHICH shards dropped


def _butterfly_perms(n_shards: int, grow: bool):
    """The two table swaps of a halve/double step.

    Growing, new shard j (and its high twin j + M/2) rebuilds from the
    ADJACENT old pair (2j, 2j+1); shrinking, new shard j rebuilds from the
    old pair (j//2, j//2 + M/2). Each direction is two true permutations
    over ``model`` (every rank sends its full table once per permute)."""
    h = n_shards // 2
    if grow:
        pa = ([(2 * j, j) for j in range(h)]
              + [(2 * j + 1, j + h) for j in range(h)])
        pb = ([(2 * j + 1, j) for j in range(h)]
              + [(2 * j, j + h) for j in range(h)])
    else:
        pa = ([(j, 2 * j) for j in range(h)]
              + [(j + h, 2 * j + 1) for j in range(h)])
        pb = ([(j, 2 * j + 1) for j in range(h)]
              + [(j + h, 2 * j) for j in range(h)])
    return pa, pb


def resize_sharded(local: ws.HashState, new_nb_loc: int,
                   n_buckets_global: int, n_shards: int,
                   *, axis: str = "model") -> RoutedResizeResult:
    """Halve/double every shard's bucket count under a live mesh.

    Runs INSIDE a shard_map body. The high-bucket-bit partition makes a
    global resize a *local reshape + neighbor exchange*: when the global
    bucket count doubles, the keys of the adjacent old shard pair
    (2j, 2j+1) redistribute exactly onto new shards j and j + M/2 (the new
    top bucket bit is the new top SHARD bit), and symmetrically for a
    halve. So each rank swaps whole tables with its butterfly partner (two
    ppermutes — 2x table bytes on the wire, independent of M; an
    all-gather would ship M-1x and transiently materialize the full table
    per rank), masks the concatenated pair down to the keys it owns under
    the new layout, and compacts with :func:`world_state.resize`. The
    concatenated pair is ascending in old global bucket order, so the
    grow stays ARRAY-exact shard by shard (world_state.resize docstring).

    ``new_nb_loc`` must be 2x or x/2 the current local bucket count.
    Shrink can overflow a merged bucket; the per-shard flags are reduced
    with one one-hot psum (same pattern as sharded_commit).
    """
    nb_loc = local.n_buckets
    if new_nb_loc not in (2 * nb_loc, nb_loc // 2):
        raise ValueError(
            f"resize_sharded steps by 2x only: nb_loc={nb_loc} -> "
            f"{new_nb_loc}"
        )
    grow = new_nb_loc == 2 * nb_loc
    new_nb_glob = n_buckets_global * 2 if grow else n_buckets_global // 2
    ws.shard_buckets(new_nb_glob, n_shards)  # validate the new partition

    if n_shards == 1:
        res = ws.resize(local, new_nb_loc)
        return RoutedResizeResult(
            state=res.state, overflow=res.overflow,
            shard_overflow=res.overflow[None],
        )

    rank = jax.lax.axis_index(axis)
    pa, pb = _butterfly_perms(n_shards, grow)
    swap = lambda perm: jax.tree.map(
        lambda x: jax.lax.ppermute(x, axis, perm), local
    )
    a, b = swap(pa), swap(pb)
    # Ascending old-global-bucket order: growing, rank j < M/2 received the
    # LOW source (2j) via pa; shrinking, even ranks received the low source
    # (r//2) via pa. The twin rank got them swapped.
    lo_is_a = (rank < n_shards // 2) if grow else (rank % 2 == 0)
    sel = lambda x, y: jnp.where(lo_is_a, x, y)
    pair = jax.tree.map(
        lambda x, y: jnp.concatenate([sel(x, y), sel(y, x)]), a, b
    )  # (2 * nb_loc, S, ...)

    # Keep only the keys this rank owns under the NEW layout, then compact.
    mine = ws.shard_of(new_nb_glob, n_shards, pair.keys) == rank
    masked = pair._replace(
        keys=jnp.where(mine[..., None], pair.keys, jnp.uint32(0))
    )
    res = ws.resize(masked, new_nb_loc)

    onehot = (jnp.arange(n_shards) == rank) & res.overflow
    shard_ovf = jax.lax.psum(onehot.astype(U32), axis) > 0
    return RoutedResizeResult(
        state=res.state, overflow=shard_ovf.any(), shard_overflow=shard_ovf
    )


def sharded_digest(local: ws.HashState, *, axis: str = "model"
                   ) -> jnp.ndarray:
    """(2,) head of the sharded state: deterministic tree over the
    all-gathered per-shard digests (identical on every rank)."""
    per_shard = jax.lax.all_gather(ws.state_digest(local), axis)  # (M, 2)
    return ws.shard_digest_tree(per_shard)


# Host-side (single-device) shard views live in core.world_state (they
# have no mesh dependence; kernels/hash_table/ops.py uses them without
# importing launch/). Re-exported here because they are the single-device
# analogue of this module's partition.
split_table = ws.split_table
merge_table = ws.merge_table
shards_for_budget = ws.shards_for_budget
