"""Sharding rules: params, optimizer state, batches, decode caches.

The layout implements DP (data [+ pod]) x TP (model) with:
  * vocab/embedding over ``model``;
  * attention QKV output dim and MLP hidden over ``model`` (Megatron
    column/row split: wq/wk/wv/w_gate/w_up column-, wo/w_down row-parallel);
  * MoE experts over ``model`` (expert parallelism; the sort-based dispatch
    lowers to the EP all-to-all);
  * Mamba inner channels / SSD heads over ``model``;
  * decode KV/SSD caches: batch over DP when divisible, sequence over
    ``model`` (decode-time sequence parallelism — the softmax reductions
    over the sharded KV length lower to small all-reduces, the flash-decode
    pattern); batch=1 long-context shards the sequence over *all* axes.
  * ZeRO-1: optimizer moments take the param sharding plus a ``data`` shard
    on the first replicated, divisible dim (optional, default on).

Non-divisible cases (e.g. 28 heads on a 16-wide model axis, vocab 256206)
are left to GSPMD's implicit padding — documented in EXPERIMENTS.md where
they show up as useful-FLOP ratio loss.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch import mesh as mesh_lib

# Param-leaf names that shard their LAST dim over `model`.
_COL = {"wq", "wk", "wv", "bq", "bk", "bv", "w_gate", "w_up", "in_proj",
        "conv_w", "conv_b", "dt_bias", "A_log", "D"}
# Param-leaf names that shard their SECOND-TO-LAST dim over `model`.
_ROW = {"wo", "w_down", "out_proj"}
# Fully replicated.
_REPL = {"scale", "router"}


def _path_names(path) -> list[str]:
    out = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            out.append(p.key)
        elif isinstance(p, jax.tree_util.GetAttrKey):
            out.append(p.name)
    return out


def param_pspec(path, leaf, msize: int) -> P:
    """Sharding rule for one param leaf. ``msize``: model-axis width.

    Shape-aware: a dim is only sharded if divisible by the axis width
    (explicit jit in_shardings require exact divisibility — unlike
    propagated shardings, GSPMD will not pad them). Fallbacks:
      * MoE experts not divisible (qwen2-moe: 60 on 16) -> intra-expert
        tensor parallelism on the hidden dim instead of EP;
      * anything else non-divisible -> replicate (embeddings are padded to
        a multiple of 256 in the model, so vocab always shards).
    """
    names = _path_names(path)
    last = names[-1]
    nd = leaf.ndim
    div = lambda i: leaf.shape[i] % msize == 0 and leaf.shape[i] >= msize
    if last in ("embed", "lm_head"):
        return P("model", None) if div(0) else P(None, None)
    if last in _REPL:
        return P(*((None,) * nd))
    in_moe = "moe" in names and "shared" not in names
    if in_moe and last in ("w_gate", "w_up", "w_down"):
        # (L, E, D, F): experts over model (EP)...
        if div(nd - 3):
            return P(*((None,) * (nd - 3)), "model", None, None)
        # ...else TP inside each expert (column for gate/up, row for down).
        if last in ("w_gate", "w_up") and div(nd - 1):
            return P(*((None,) * (nd - 1)), "model")
        if last == "w_down" and div(nd - 2):
            return P(*((None,) * (nd - 2)), "model", None)
        return P(*((None,) * nd))
    if last in _COL:
        return (P(*((None,) * (nd - 1)), "model") if div(nd - 1)
                else P(*((None,) * nd)))
    if last in _ROW:
        return (P(*((None,) * (nd - 2)), "model", None) if div(nd - 2)
                else P(*((None,) * nd)))
    return P(*((None,) * nd))


def param_specs(params_shape, mesh=None) -> Any:
    """Pytree of PartitionSpec for a params pytree (of arrays or
    ShapeDtypeStructs)."""
    msize = mesh_lib.model_size(mesh) if mesh is not None else 16
    return jax.tree_util.tree_map_with_path(
        lambda pth, lf: param_pspec(pth, lf, msize), params_shape
    )


def zero1_pspec(spec: P, shape, dp: tuple, dp_total: int) -> P:
    """Add a `data` shard to the first replicated divisible dim (ZeRO-1)."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    for i, (e, dim) in enumerate(zip(entries, shape)):
        if e is None and dim % dp_total == 0 and dim >= dp_total:
            entries[i] = dp if len(dp) > 1 else dp[0]
            return P(*entries)
    return spec


def opt_specs(params_shape, mesh, *, zero1: bool = True):
    """AdamWState specs: moments = param spec (+ZeRO-1), step replicated."""
    pspecs = param_specs(params_shape, mesh)
    dp = mesh_lib.dp_axes(mesh)
    dpt = mesh_lib.dp_size(mesh)
    if zero1:
        mspecs = jax.tree.map(
            lambda s, l: zero1_pspec(s, l.shape, dp, dpt),
            pspecs, params_shape,
        )
    else:
        mspecs = pspecs
    from repro.training.optimizer import AdamWState

    return AdamWState(step=P(), m=mspecs, v=mspecs)


def batch_pspecs(batch_shape, mesh):
    """Batch pytree specs: leading batch dim over DP axes."""
    dp = mesh_lib.dp_axes(mesh)
    dpa = dp if len(dp) > 1 else dp[0]

    def spec(x):
        if x is None:
            return None
        return P(dpa, *((None,) * (x.ndim - 1)))

    return jax.tree.map(spec, batch_shape)


def cache_pspecs(cache_shape, mesh):
    """DecodeCache specs (see module docstring for the layout)."""
    dp = mesh_lib.dp_axes(mesh)
    dpa = dp if len(dp) > 1 else dp[0]
    dpt = mesh_lib.dp_size(mesh)

    def kv_spec(x):
        # (L|Sites, B, S, H, Dh)
        if x is None:
            return None
        _, b, s, _, _ = x.shape
        if b % dpt == 0 and b >= dpt:
            return P(None, dpa, "model", None, None)
        # batch too small (long-context b=1): shard S over everything.
        all_axes = tuple(mesh.axis_names)
        return P(None, None, all_axes, None, None)

    def conv_spec(x):
        # (L, B, K-1, C)
        if x is None:
            return None
        b = x.shape[1]
        bspec = dpa if (b % dpt == 0 and b >= dpt) else None
        return P(None, bspec, None, "model")

    def ssm_spec(x):
        # (L, B, H, P, N)
        if x is None:
            return None
        b = x.shape[1]
        bspec = dpa if (b % dpt == 0 and b >= dpt) else None
        return P(None, bspec, "model", None, None)

    from repro.models.lm import DecodeCache

    return DecodeCache(
        k=kv_spec(cache_shape.k),
        v=kv_spec(cache_shape.v),
        cross_k=kv_spec(cache_shape.cross_k),
        cross_v=kv_spec(cache_shape.cross_v),
        conv=conv_spec(cache_shape.conv),
        ssm_state=ssm_spec(cache_shape.ssm_state),
        hyb_k=kv_spec(cache_shape.hyb_k),
        hyb_v=kv_spec(cache_shape.hyb_v),
    )


def token_pspec(batch_size: int, mesh):
    dp = mesh_lib.dp_axes(mesh)
    dpa = dp if len(dp) > 1 else dp[0]
    dpt = mesh_lib.dp_size(mesh)
    if batch_size % dpt == 0 and batch_size >= dpt:
        return P(dpa)
    return P()


def to_named(tree_of_pspecs, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s,
        tree_of_pspecs,
        is_leaf=lambda x: isinstance(x, P) or x is None,
    )
