"""Metrics registry: counters, gauges, and log2 latency histograms.

Dependency-free (stdlib only) so every layer — core, pipeline, storage,
serving, benchmarks — can emit metrics without import cycles or optional
deps. The paper's whole method is per-stage measurement (§IV: find where
peer time goes, then remove it); this registry is the engine-wide carrier
for those measurements.

Three instrument kinds:

  * :class:`Counter` — monotonically increasing total (txs validated,
    journal appends, overflow latches).
  * :class:`Gauge`   — last-set value (admission-queue depth, per-shard
    overflow bits, compiled-program collective counts).
  * :class:`Histogram` — fixed log2 buckets over a [lo, hi) value range.
    Bucket edges are ``lo * 2**i``, so two histograms with the same range
    have IDENTICAL bucket boundaries and :meth:`Histogram.merge` (count
    addition) is *exact*: the merged histogram equals the histogram of the
    pooled samples, bucket for bucket — which makes percentiles of merged
    per-shard/per-round histograms well-defined, not approximated twice.
    Percentiles use the nearest-rank rule (``ceil(q/100 * n)``) over
    bucket counts and report the bucket's upper edge: a conservative bound
    that is within one bucket ratio (2x) of the exact sample percentile
    (``numpy.percentile(..., method="inverted_cdf")``), pinned by
    tests/test_obs.py.

Instruments support labels (``registry.gauge("state.shard_overflow",
shard=3)``); a labeled instrument is keyed ``name{shard=3}`` in
:meth:`Registry.collect` snapshots. ``Registry.to_prometheus`` renders the
standard text exposition (histograms as cumulative ``_bucket{le=...}``
series) for the serving path's ``stats_text`` endpoint hook.

``NULL_REGISTRY`` is a shared no-op registry: instrumented code paths take
a registry argument defaulting to it, so observability-off engines pay one
attribute lookup and a no-op call, nothing else.
"""

from __future__ import annotations

import collections
import math
import threading

__all__ = [
    "Counter", "Gauge", "Histogram", "Registry", "NULL_REGISTRY",
    "null_registry",
]


class Counter:
    """Monotonic counter. ``inc`` with a negative amount is an error."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def inc(self, amount: int | float = 1) -> None:
        if amount < 0:
            raise ValueError(f"counter decrement: {amount}")
        self.value += amount


class Gauge:
    """Last-written value."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def set(self, value) -> None:
        self.value = value

    def inc(self, amount: int | float = 1) -> None:
        self.value += amount

    def dec(self, amount: int | float = 1) -> None:
        self.value -= amount


class Histogram:
    """Fixed-bucket log2 histogram over ``[lo, hi)``, with exemplars.

    Bucket 0 holds values ``<= lo``; bucket ``1 + i`` holds
    ``(lo * 2**i, lo * 2**(i+1)]``; the last bucket is the OVERFLOW
    bucket and holds values clamped past ``hi``. Defaults cover
    100ns..~1700s — the full latency range of a block commit, a snapshot
    save, or a whole benchmark round — in 35 buckets.

    Pinned edge behavior (tests/test_obs.py):

      * empty histogram — :meth:`percentile` returns ``nan``;
      * rank in the overflow bucket — :meth:`percentile` returns ``inf``
        (the value was clamped at ``hi``: widen the range if it matters);
      * exemplars recorded for clamped values are NEVER silently filed
        under the clamp bucket's index — they live under the explicit
        ``"overflow"`` key in :meth:`exemplar_snapshot`, so a p99 of
        ``inf`` still names the transactions that caused it while making
        the clamping visible.

    Exemplar sampling: ``record(v, exemplar=meta)`` retains up to
    ``max_exemplars`` most-recent ``meta`` payloads PER BUCKET — a tail
    bucket therefore always carries concrete recent instances (tx-ids +
    their phase breakdown for the tx-lifecycle histograms), making a p99
    spike attributable without replaying the workload. ``record(v, n=k)``
    records ``k`` occurrences of one value in O(1) (the engine's
    per-block amortized phase times weight by block size this way).
    """

    __slots__ = ("lo", "n_buckets", "counts", "count", "sum", "_edges",
                 "max_exemplars", "_exemplars")

    def __init__(self, lo: float = 1e-7, hi: float = 1e3,
                 max_exemplars: int = 4) -> None:
        if not (lo > 0 and hi > lo):
            raise ValueError(f"bad histogram range [{lo}, {hi})")
        self.lo = float(lo)
        self.n_buckets = int(math.ceil(math.log2(hi / lo))) + 2
        self.counts = [0] * self.n_buckets
        self.count = 0
        self.sum = 0.0
        self._edges = [lo * 2.0 ** i for i in range(self.n_buckets - 1)]
        self.max_exemplars = int(max_exemplars)
        self._exemplars: dict = {}  # bucket index | "overflow" -> deque

    def bucket_of(self, value: float) -> int:
        if value <= self.lo:
            return 0
        return min(int(math.ceil(math.log2(value / self.lo))),
                   self.n_buckets - 1)

    def record(self, value: float, n: int = 1, exemplar=None) -> None:
        self.count += n
        self.sum += value * n
        i = self.bucket_of(value)
        self.counts[i] += n
        if exemplar is not None and self.max_exemplars:
            key = "overflow" if i == self.n_buckets - 1 else i
            dq = self._exemplars.get(key)
            if dq is None:
                dq = self._exemplars[key] = collections.deque(
                    maxlen=self.max_exemplars
                )
            dq.append(exemplar)

    @property
    def edges(self) -> list[float]:
        """Upper edges of the finite buckets (the last bucket is +inf)."""
        return list(self._edges)

    def percentile(self, q: float) -> float:
        """Nearest-rank percentile, reported as the bucket's upper edge.

        Exact modulo bucket resolution: the true sample at rank
        ``ceil(q/100 * count)`` lies in the returned bucket, so the result
        over-reports by at most one bucket ratio (2x). Returns ``nan`` on
        an empty histogram and ``inf`` when the rank falls in the overflow
        bucket (values past ``hi`` — widen the range if that matters).
        """
        i = self._bucket_at_rank(q)
        if i is None:
            return float("nan")
        return self._edges[i] if i < len(self._edges) else float("inf")

    def merge(self, other: "Histogram") -> None:
        """Exact pooled merge (bucket edges must match). Exemplars pool
        too, keeping each bucket's most recent ``max_exemplars``."""
        if other.lo != self.lo or other.n_buckets != self.n_buckets:
            raise ValueError("histogram ranges differ: merge is not exact")
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.count += other.count
        self.sum += other.sum
        for key, dq in other._exemplars.items():
            mine = self._exemplars.get(key)
            if mine is None:
                mine = self._exemplars[key] = collections.deque(
                    maxlen=self.max_exemplars
                )
            mine.extend(dq)

    def _bucket_at_rank(self, q: float) -> int | None:
        """Bucket index holding the nearest-rank sample for ``q``."""
        if self.count == 0:
            return None
        rank = max(1, math.ceil(q / 100.0 * self.count))
        acc = 0
        for i, c in enumerate(self.counts):
            acc += c
            if acc >= rank:
                return i
        return self.n_buckets - 1

    def exemplars_for(self, q: float) -> list:
        """Exemplar payloads retained in the bucket holding percentile
        ``q`` (the ``"overflow"`` bin when that bucket is the clamp
        bucket). Empty when nothing was recorded with an exemplar."""
        i = self._bucket_at_rank(q)
        if i is None:
            return []
        key = "overflow" if i == self.n_buckets - 1 else i
        return list(self._exemplars.get(key, ()))

    def exemplar_snapshot(self) -> dict:
        """All retained exemplars keyed by bucket index (clamped values
        under the explicit ``"overflow"`` key)."""
        return {k: list(v) for k, v in self._exemplars.items()}

    def snapshot(self) -> dict:
        """count/sum/mean + the standard percentiles, one dict. When any
        exemplars were recorded, ``p99_exemplars`` carries the payloads
        retained in the p99 bucket (the exemplar contract benchmarks
        assert: a p99 spike names concrete tx-ids)."""
        mean = self.sum / self.count if self.count else float("nan")
        snap = {
            "count": self.count, "sum": self.sum, "mean": mean,
            "p50": self.percentile(50), "p95": self.percentile(95),
            "p99": self.percentile(99),
        }
        if self._exemplars:
            snap["p99_exemplars"] = self.exemplars_for(99)
        return snap


def _key(name: str, labels: dict) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class Registry:
    """Get-or-create instrument store with a one-call snapshot.

    Thread-safe creation (the storage role's writer thread records journal
    metrics concurrently with the engine thread); individual increments
    ride the GIL like every other host-side counter in the repo.
    """

    def __init__(self) -> None:
        self._instruments: dict[str, object] = {}
        self._kinds: dict[str, str] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, labels: dict, kind: str, factory):
        key = _key(name, labels)
        inst = self._instruments.get(key)
        if inst is not None:
            if self._kinds[key] != kind:
                raise TypeError(
                    f"{key} already registered as {self._kinds[key]}"
                )
            return inst
        with self._lock:
            inst = self._instruments.get(key)
            if inst is None:
                inst = factory()
                self._instruments[key] = inst
                self._kinds[key] = kind
            elif self._kinds[key] != kind:
                raise TypeError(
                    f"{key} already registered as {self._kinds[key]}"
                )
            return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get(name, labels, "counter", Counter)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(name, labels, "gauge", Gauge)

    def histogram(self, name: str, lo: float = 1e-7, hi: float = 1e3,
                  max_exemplars: int = 4, **labels) -> Histogram:
        return self._get(name, labels, "histogram",
                         lambda: Histogram(lo, hi, max_exemplars))

    def collect(self) -> dict:
        """Flat snapshot: ``name{labels}`` -> value (histograms -> the
        count/sum/mean/p50/p95/p99 dict). Safe to call any time; values
        are plain Python numbers, JSON-ready."""
        out = {}
        for key, inst in sorted(self._instruments.items()):
            if isinstance(inst, Histogram):
                out[key] = inst.snapshot()
            else:
                out[key] = inst.value
        return out

    def to_prometheus(self) -> str:
        """Prometheus text exposition (metric names sanitized to
        ``[a-zA-Z0-9_]``, histograms as cumulative ``le`` buckets)."""
        by_name: dict[str, list] = {}
        for key, inst in sorted(self._instruments.items()):
            name, _, rest = key.partition("{")
            labels = rest[:-1] if rest else ""
            by_name.setdefault(name, []).append((labels, inst))
        lines = []
        for name, entries in sorted(by_name.items()):
            pname = "".join(
                c if c.isalnum() or c == "_" else "_" for c in name
            )
            kind = self._kinds[_key(name, {})] if name in self._kinds \
                else self._kinds[
                    next(k for k in self._kinds if k.startswith(name + "{"))]
            ptype = {"counter": "counter", "gauge": "gauge",
                     "histogram": "histogram"}[kind]
            lines.append(f"# TYPE {pname} {ptype}")
            for labels, inst in entries:
                plabels = labels.replace("=", '="').replace(",", '",') \
                    + ('"' if labels else "")
                sfx = f"{{{plabels}}}" if labels else ""
                if isinstance(inst, Histogram):
                    acc = 0
                    for i, c in enumerate(inst.counts):
                        acc += c
                        le = (f"{inst.edges[i]:.9g}" if i < len(inst.edges)
                              else "+Inf")
                        sep = "," if labels else ""
                        lines.append(
                            f'{pname}_bucket{{{plabels}{sep}le="{le}"}} '
                            f"{acc}"
                        )
                    lines.append(f"{pname}_sum{sfx} {inst.sum:.9g}")
                    lines.append(f"{pname}_count{sfx} {inst.count}")
                else:
                    lines.append(f"{pname}{sfx} {inst.value}")
        return "\n".join(lines) + "\n"


class _NullInstrument:
    """Absorbs every instrument method; always reads as empty/0."""

    value = 0
    count = 0
    sum = 0.0

    def inc(self, amount=1) -> None:
        pass

    def dec(self, amount=1) -> None:
        pass

    def set(self, value) -> None:
        pass

    def record(self, value, n=1, exemplar=None) -> None:
        pass

    def merge(self, other) -> None:
        pass

    def percentile(self, q) -> float:
        return float("nan")

    def exemplars_for(self, q) -> list:
        return []

    def exemplar_snapshot(self) -> dict:
        return {}

    def snapshot(self) -> dict:
        return {}


_NULL_INSTRUMENT = _NullInstrument()


class NullRegistry:
    """No-op registry: obs-off engines route here (one call, no state)."""

    def counter(self, name, **labels):
        return _NULL_INSTRUMENT

    def gauge(self, name, **labels):
        return _NULL_INSTRUMENT

    def histogram(self, name, lo=1e-7, hi=1e3, max_exemplars=4, **labels):
        return _NULL_INSTRUMENT

    def collect(self) -> dict:
        return {}

    def to_prometheus(self) -> str:
        return ""


NULL_REGISTRY = NullRegistry()


def null_registry() -> NullRegistry:
    return NULL_REGISTRY
