"""Transaction-lifecycle tracing: what happened to ONE transaction.

PR 6's spans and histograms measure *stages* (where peer time goes —
the paper's §IV method); this module measures *transactions*: every
proposal gets a tx-id at submission (the endorser's paired content hash,
``TxBatch.tx_id``), a host-side sidecar of per-block timestamps rides
alongside the blocks through order → window fill → validate → commit,
and each tx's phase durations land in per-phase histograms:

  * ``tx.phase.queue``    — submission (pre-endorsed wire ready) to
    order start: time waiting at the ordering service.
  * ``tx.phase.order``    — the ordering span (O-I/O-II work).
  * ``tx.phase.validate`` — order end to the tx's block/window clearing
    the validation pipeline (the drain sync of its window, or the
    round-commit sync on the per-block path).
  * ``tx.phase.commit``   — validation done to the round's retirement
    (endorser-replica apply + ship); the post-validation commit work.
  * ``tx.e2e``            — submission to retirement. By construction
    ``queue + order + validate + commit == e2e`` exactly per tx.

Timestamps are taken ONLY on sync edges the PR 6 spans already forced
(order-span exit, window drains, round-commit sync, endorser-replay
exit) — the tracer never adds a device sync, so nothing serializes that
overlapped before. Transactions in one block share those edges, so each
block records once with ``n=block_size`` weight (O(blocks), not O(txs),
host work per round) and attaches ONE exemplar — its first tx-id plus
the full phase breakdown — so every histogram bucket retains up to K
concrete recent transactions (see :class:`repro.obs.metrics.Histogram`).

Outcomes are labeled counters under ``tx.outcome``:

  * ``valid``            — committed, version bumps applied;
  * ``mvcc_conflict``    — failed validation (read-set version mismatch
    — the dominant invalidity class in this engine's pipeline);
  * ``overflow_dropped`` — the tx's round latched a NEW sticky overflow
    bit on its channel: its writes may have been dropped by a full
    bucket, so "valid" can no longer be claimed. Attribution is
    round-granular (the fused scatter doesn't name the dropped tx), a
    deliberate upper bound — the channel is tainted either way.

A bounded ring of full per-tx lifecycles (sampled per block: the first
tx, plus the first invalid tx when the block has one) feeds the flight
recorder's ``lifecycles.json`` dump.

Stdlib-only: tx-id arrays arrive as host-side numpy sidecars and are
consumed duck-typed (``len``/indexing/``int()``/``.sum()``).
"""

from __future__ import annotations

import time

__all__ = ["TxTracer", "RoundTxTrace", "NullTxTracer", "NULL_TXTRACER",
           "NULL_ROUND", "PHASES"]

PHASES = ("queue", "order", "validate", "commit")


def _tx_hex(row) -> str:
    """(2,) u32 paired-hash tx-id -> 16-char hex string."""
    return f"{int(row[0]):08x}{int(row[1]):08x}"


class RoundTxTrace:
    """Per-round sidecar: tx-ids + the phase timestamps of one round.

    The engine stamps it at the existing sync edges (``order_start``,
    ``ordered``, ``validated(lo, hi)`` per drained window,
    ``committed``) and ``finish(...)`` folds the stamps into the
    registry histograms, outcome counters and lifecycle ring.
    """

    __slots__ = ("tt", "channel", "tx_ids", "bs", "n_blocks", "block_no0",
                 "t_submit", "t_order0", "t_order1", "t_end",
                 "t_validated")

    def __init__(self, tt: "TxTracer", channel: int, tx_ids, bs: int,
                 block_no0: int):
        self.tt = tt
        self.channel = channel
        self.tx_ids = tx_ids  # (N, 2) host-side sidecar
        self.bs = bs
        self.n_blocks = len(tx_ids) // bs
        self.block_no0 = block_no0
        self.t_submit = time.perf_counter()
        self.t_order0 = self.t_order1 = self.t_end = 0.0
        self.t_validated: list = [None] * self.n_blocks

    def order_start(self) -> None:
        self.t_order0 = time.perf_counter()

    def ordered(self) -> None:
        self.t_order1 = time.perf_counter()

    def validated(self, lo: int, hi: int) -> None:
        """Blocks [lo, hi) of the round cleared validation NOW (called
        right after the window that carried them drained)."""
        t = time.perf_counter()
        for k in range(lo, min(hi, self.n_blocks)):
            self.t_validated[k] = t

    def committed(self) -> None:
        self.t_end = time.perf_counter()

    def finish(self, valid_by_block: list | None,
               overflow_latched: bool = False) -> None:
        """Record the round: ``valid_by_block`` is one host-side bool
        array per block (None skips outcome/lifecycle accounting)."""
        if self.t_end == 0.0:
            self.t_end = time.perf_counter()
        self.tt._finish(self, valid_by_block, overflow_latched)


class TxTracer:
    """Engine-side factory + sink for :class:`RoundTxTrace` sidecars."""

    def __init__(self, registry, *, recorder=None, max_exemplars: int = 4,
                 lifecycle_capacity: int = 64):
        from .trace import Ring  # stdlib sibling; avoids import cycles

        self.registry = registry
        self.recorder = recorder
        self.max_exemplars = max_exemplars
        self.lifecycles = Ring(lifecycle_capacity)
        self._hists = {
            p: registry.histogram(f"tx.phase.{p}",
                                  max_exemplars=max_exemplars)
            for p in PHASES
        }
        self._hists["e2e"] = registry.histogram(
            "tx.e2e", max_exemplars=max_exemplars
        )

    def begin_round(self, channel: int, tx_ids, block_size: int,
                    block_no0: int) -> RoundTxTrace:
        """Open a round sidecar at SUBMISSION time (the pre-endorsed
        wire is ready; the tx-ids are the endorser's content hashes)."""
        return RoundTxTrace(self, channel, tx_ids, block_size, block_no0)

    def _finish(self, rt: RoundTxTrace, valid_by_block,
                overflow_latched: bool) -> None:
        reg = self.registry
        queue = max(rt.t_order0 - rt.t_submit, 0.0)
        order = max(rt.t_order1 - rt.t_order0, 0.0)
        for k in range(rt.n_blocks):
            tv = rt.t_validated[k]
            if tv is None:
                tv = rt.t_end  # never marked: clears with the round sync
            validate = max(tv - rt.t_order1, 0.0)
            commit = max(rt.t_end - tv, 0.0)
            e2e = queue + order + validate + commit
            phases = {"queue": queue, "order": order,
                      "validate": validate, "commit": commit}
            first = rt.tx_ids[k * rt.bs]
            exemplar = {
                "tx_id": _tx_hex(first), "channel": rt.channel,
                "block_no": rt.block_no0 + k, "e2e": e2e, **phases,
            }
            for p, v in phases.items():
                self._hists[p].record(v, n=rt.bs, exemplar=exemplar)
            self._hists["e2e"].record(e2e, n=rt.bs, exemplar=exemplar)

            if valid_by_block is None:
                continue
            valid = valid_by_block[k]
            nv = int(valid.sum())
            ok_label = "overflow_dropped" if overflow_latched else "valid"
            if nv:
                reg.counter("tx.outcome", outcome=ok_label).inc(nv)
            if rt.bs - nv:
                reg.counter("tx.outcome", outcome="mvcc_conflict").inc(
                    rt.bs - nv
                )
            # Lifecycle samples: block's first tx; plus its first invalid
            # tx, so conflict lifecycles stay represented in the ring.
            sample = [0]
            if nv < rt.bs:
                sample.append(int(valid.argmin()))
            for i in dict.fromkeys(sample):
                tx = rt.tx_ids[k * rt.bs + i]
                ok = bool(valid[i])
                lc = {
                    "tx_id": _tx_hex(tx), "channel": rt.channel,
                    "block_no": rt.block_no0 + k,
                    "outcome": (ok_label if ok else "mvcc_conflict"),
                    "t_submit": rt.t_submit, "phases": phases, "e2e": e2e,
                }
                self.lifecycles.push(lc)
                if self.recorder is not None:
                    self.recorder.record_lifecycle(lc)


class _NullRoundTxTrace:
    __slots__ = ()

    def order_start(self) -> None:
        pass

    def ordered(self) -> None:
        pass

    def validated(self, lo, hi) -> None:
        pass

    def committed(self) -> None:
        pass

    def finish(self, valid_by_block=None, overflow_latched=False) -> None:
        pass


NULL_ROUND = _NullRoundTxTrace()


class NullTxTracer:
    """Obs-off tx tracing: no sidecars, no host transfers, no stamps.
    Callers skip materializing the tx-id sidecar (pass ``None``)."""

    lifecycles = None

    def begin_round(self, channel, tx_ids, block_size, block_no0):
        return NULL_ROUND


NULL_TXTRACER = NullTxTracer()
