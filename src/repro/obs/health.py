"""Health/SLO rollup: rolling-window verdicts from per-round buckets.

The backpressure/admission layer (ROADMAP item 1) needs one question
answered continuously: *is this peer meeting its objectives, and if not,
which channel/shard is the reason?* Aggregate counters can't say (a
healthy hour hides a failing minute), so the rollup keeps a bounded ring
of per-round buckets per channel (:class:`repro.obs.trace.Ring` again —
fixed memory, drop-oldest) and evaluates three objectives over that
window:

  * **commit latency** — the window's p95 per-block commit latency must
    stay under ``SLOConfig.commit_p95_s``;
  * **validity rate**  — valid/total over the window must stay above
    ``min_validity_rate`` (``critical_validity_rate`` floors it: below
    that the channel is not degraded, it is failing);
  * **capacity headroom** — per-shard occupancy must stay under
    ``max_occupancy``, and a latched sticky overflow bit is immediately
    ``critical`` (writes were DROPPED on that shard; FastFabric's
    version accounting is no longer trustworthy there — the fig12
    fail-stop condition).

Verdicts are ``healthy | degraded | critical`` with per-channel,
per-shard reasons; ``FabricEngine.health()`` feeds the rollup live
overflow/occupancy (one stacked stats read) and mirrors the verdict to
``health.status`` / ``health.channel{channel=c}`` gauges on the
existing ``stats_text()`` Prometheus path.

Stdlib-only, registry-independent: the rollup runs on host-side round
accounting, so ``health()`` works with observability off.
"""

from __future__ import annotations

import dataclasses
import math

from .trace import Ring

__all__ = ["SLOConfig", "HealthVerdict", "HealthRollup",
           "HEALTHY", "DEGRADED", "CRITICAL", "STATUS_RANK"]

HEALTHY = "healthy"
DEGRADED = "degraded"
CRITICAL = "critical"
STATUS_RANK = {HEALTHY: 0, DEGRADED: 1, CRITICAL: 2}


def _worst(a: str, b: str) -> str:
    return a if STATUS_RANK[a] >= STATUS_RANK[b] else b


@dataclasses.dataclass(frozen=True)
class SLOConfig:
    """The peer's objectives. Defaults are deliberately loose (a CPU CI
    runner must read healthy); deployments tighten them."""

    commit_p95_s: float = 1.0  # window p95 of per-block commit latency
    min_validity_rate: float = 0.99  # below -> degraded
    critical_validity_rate: float = 0.5  # below -> critical
    max_occupancy: float = 0.85  # any shard above -> degraded (headroom)
    window_rounds: int = 16  # per-round buckets retained per channel


@dataclasses.dataclass
class HealthVerdict:
    """Structured verdict: overall status + per-channel breakdown."""

    status: str
    reasons: list
    channels: dict  # channel -> {"status": str, "reasons": [str, ...]}

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class HealthRollup:
    """Ring-of-round-buckets SLO evaluator for one engine."""

    def __init__(self, slo: SLOConfig | None = None, n_channels: int = 1):
        self.slo = slo if slo is not None else SLOConfig()
        self.n_channels = n_channels
        self._rounds = [Ring(self.slo.window_rounds)
                        for _ in range(n_channels)]
        self._overflow: dict[int, int] = {}  # latest sticky bits
        self._occupancy: dict[int, list] = {}  # latest per-shard fraction

    # -- feeds (engine-side, per round / per stats pass) --------------------

    def push_round(self, channel: int, *, n_txs: int, n_valid: int,
                   wall_s: float, n_blocks: int) -> None:
        self._rounds[channel].push({
            "n_txs": n_txs, "n_valid": n_valid,
            "block_latency_s": wall_s / max(n_blocks, 1),
            "n_blocks": n_blocks,
        })

    def set_overflow(self, channel: int, bits: int) -> None:
        self._overflow[channel] = bits

    def set_occupancy(self, channel: int, fractions) -> None:
        """Latest per-shard occupancy fractions (one stacked stats read
        feeds every channel — the resize-policy pass or ``health()``)."""
        self._occupancy[channel] = [float(f) for f in fractions]

    # -- evaluation ---------------------------------------------------------

    def _window_p95(self, buckets: list) -> float:
        lats = sorted(b["block_latency_s"] for b in buckets
                      for _ in range(b["n_blocks"]))
        if not lats:
            return float("nan")
        rank = max(1, math.ceil(0.95 * len(lats)))
        return lats[rank - 1]

    def evaluate_channel(self, channel: int) -> tuple[str, list]:
        slo = self.slo
        status = HEALTHY
        reasons: list[str] = []
        bits = self._overflow.get(channel, 0)
        m = 0
        while bits >> m:
            if (bits >> m) & 1:
                status = _worst(status, CRITICAL)
                reasons.append(
                    f"channel {channel} shard {m}: sticky overflow "
                    f"latched (writes dropped)"
                )
            m += 1
        buckets = self._rounds[channel].items()
        n_txs = sum(b["n_txs"] for b in buckets)
        n_valid = sum(b["n_valid"] for b in buckets)
        if n_txs:
            rate = n_valid / n_txs
            if rate < slo.critical_validity_rate:
                status = _worst(status, CRITICAL)
                reasons.append(
                    f"channel {channel}: validity rate {rate:.3f} below "
                    f"critical floor {slo.critical_validity_rate}"
                )
            elif rate < slo.min_validity_rate:
                status = _worst(status, DEGRADED)
                reasons.append(
                    f"channel {channel}: validity rate {rate:.3f} below "
                    f"objective {slo.min_validity_rate}"
                )
        p95 = self._window_p95(buckets)
        if p95 == p95 and p95 > slo.commit_p95_s:  # nan-safe
            status = _worst(status, DEGRADED)
            reasons.append(
                f"channel {channel}: commit p95 {p95:.3f}s over "
                f"objective {slo.commit_p95_s}s"
            )
        for shard, frac in enumerate(self._occupancy.get(channel, ())):
            if frac >= slo.max_occupancy:
                status = _worst(status, DEGRADED)
                reasons.append(
                    f"channel {channel} shard {shard}: occupancy "
                    f"{frac:.2f} over headroom {slo.max_occupancy}"
                )
        return status, reasons

    def evaluate(self) -> HealthVerdict:
        status = HEALTHY
        reasons: list[str] = []
        channels = {}
        for c in range(self.n_channels):
            st, rs = self.evaluate_channel(c)
            channels[c] = {"status": st, "reasons": rs}
            status = _worst(status, st)
            reasons.extend(rs)
        return HealthVerdict(status=status, reasons=reasons,
                             channels=channels)
