"""Engine-wide observability: span tracing + metrics registry.

Stdlib-only (no jax/numpy at import time) so any layer of the repro —
core, pipeline, storage, launch, serving, benchmarks — can depend on it
without cycles. See :mod:`repro.obs.trace` and :mod:`repro.obs.metrics`
for the design contracts (device-sync boundaries, exact histogram merge).

Typical wiring::

    from repro import obs

    o = obs.Obs.enabled()             # or obs.Obs.disabled()
    with o.tracer.span("commit.block", sync=lambda: state):
        state = commit(state, block)
    o.registry.counter("txs.valid").inc(n_valid)
    print(o.registry.to_prometheus())
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .metrics import (  # noqa: F401
    NULL_REGISTRY, Counter, Gauge, Histogram, NullRegistry, Registry,
    null_registry,
)
from .trace import (  # noqa: F401
    NULL_TRACER, NullTracer, Span, Tracer, null_tracer,
)

__all__ = [
    "Obs", "Counter", "Gauge", "Histogram", "Registry", "NullRegistry",
    "Span", "Tracer", "NullTracer", "NULL_REGISTRY", "NULL_TRACER",
    "null_registry", "null_tracer",
]


@dataclass
class Obs:
    """One handle bundling a tracer + registry, on or off together."""

    tracer: object = field(default_factory=lambda: NULL_TRACER)
    registry: object = field(default_factory=lambda: NULL_REGISTRY)

    @classmethod
    def enabled(cls) -> "Obs":
        return cls(tracer=Tracer(), registry=Registry())

    @classmethod
    def disabled(cls) -> "Obs":
        return cls()

    @property
    def on(self) -> bool:
        return not isinstance(self.tracer, NullTracer)
