"""Engine-wide observability: spans, metrics, tx tracing, health.

Stdlib-only (no jax/numpy at import time) so any layer of the repro —
core, pipeline, storage, launch, serving, benchmarks — can depend on it
without cycles. See the submodules for the design contracts:

  * :mod:`repro.obs.trace`    — span tracer (device-sync boundaries,
    bounded drop-oldest ring), shared :class:`~repro.obs.trace.Ring`.
  * :mod:`repro.obs.metrics`  — counters/gauges/log2 histograms with
    exact merge and per-bucket exemplar sampling.
  * :mod:`repro.obs.txtrace`  — per-transaction lifecycle tracing
    (queue/order/validate/commit phases, outcomes, lifecycle ring).
  * :mod:`repro.obs.recorder` — always-on flight recorder with
    fault-edge auto-dump.
  * :mod:`repro.obs.health`   — rolling-window SLO rollup
    (``healthy | degraded | critical`` verdicts).

Typical wiring::

    from repro import obs

    o = obs.Obs.enabled()             # or obs.Obs.disabled()
    with o.tracer.span("commit.block", sync=lambda: state):
        state = commit(state, block)
    o.registry.counter("txs.valid").inc(n_valid)
    print(o.registry.to_prometheus())
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .health import (  # noqa: F401
    CRITICAL, DEGRADED, HEALTHY, STATUS_RANK, HealthRollup, HealthVerdict,
    SLOConfig,
)
from .metrics import (  # noqa: F401
    NULL_REGISTRY, Counter, Gauge, Histogram, NullRegistry, Registry,
    null_registry,
)
from .recorder import FlightRecorder  # noqa: F401
from .trace import (  # noqa: F401
    NULL_TRACER, NullTracer, Ring, Span, Tracer, null_tracer,
)
from .txtrace import (  # noqa: F401
    NULL_ROUND, NULL_TXTRACER, NullTxTracer, RoundTxTrace, TxTracer,
)

__all__ = [
    "Obs", "Counter", "Gauge", "Histogram", "Registry", "NullRegistry",
    "Span", "Tracer", "NullTracer", "Ring", "NULL_REGISTRY", "NULL_TRACER",
    "null_registry", "null_tracer",
    "TxTracer", "RoundTxTrace", "NullTxTracer", "NULL_TXTRACER",
    "NULL_ROUND", "FlightRecorder",
    "SLOConfig", "HealthRollup", "HealthVerdict",
    "HEALTHY", "DEGRADED", "CRITICAL", "STATUS_RANK",
]


@dataclass
class Obs:
    """One handle bundling a tracer + registry, on or off together."""

    tracer: object = field(default_factory=lambda: NULL_TRACER)
    registry: object = field(default_factory=lambda: NULL_REGISTRY)

    @classmethod
    def enabled(cls, max_events: int | None = None) -> "Obs":
        """Live pair. ``max_events`` bounds the tracer (drop-oldest ring)
        and wires its evictions to the ``trace.dropped_events`` counter
        — long-running engines pass a bound; short benchmark runs keep
        the default unbounded complete trace."""
        registry = Registry()
        tracer = Tracer(max_events=max_events)
        if max_events is not None:
            tracer.set_drop_counter(
                registry.counter("trace.dropped_events")
            )
        return cls(tracer=tracer, registry=registry)

    @classmethod
    def disabled(cls) -> "Obs":
        return cls()

    @property
    def on(self) -> bool:
        return not isinstance(self.tracer, NullTracer)
