"""Span tracer: nested named spans with explicit device-sync boundaries.

JAX dispatches asynchronously: ``commit(block)`` returns as soon as the
computation is enqueued, and the wall time of whichever host line *next*
forces a transfer absorbs all pending device work. A naive ``perf_counter``
pair around one stage therefore mis-attributes latency to a bystander. The
tracer's contract is the opposite: device syncs happen only at span edges,
and only when the caller asks for them —

    with tracer.span("window.steady", sync=outputs):
        outputs = committer.step(...)          # async dispatch inside

``sync=`` (a pytree, callable, or None) is resolved with
``jax.block_until_ready`` at span *exit*, so the span's duration covers
dispatch + device execution of exactly the work it encloses, and code
outside the span keeps overlapping. Spans with ``sync=None`` time pure
host work and never touch the device.

Spans nest per-thread (a ``threading.local`` stack — the storage writer
thread traces its journal appends without corrupting the engine thread's
stack) and carry a depth + parent name so ordering is reconstructible from
the flat event list. Export formats:

  * :meth:`Tracer.dump_jsonl` — one JSON object per line
    (``{"name", "ts", "dur", "depth", "parent", "tid", "args"}``), the
    stable machine-readable form CI asserts against.
  * :meth:`Tracer.dump_chrome` — Chrome ``trace_event`` JSON (``"ph": "X"``
    complete events, microsecond timestamps) loadable in chrome://tracing
    or https://ui.perfetto.dev.

``tracer.event(name, **args)`` records zero-duration structured events
(resize decisions, re-anchor epochs) that appear as instant events in the
Chrome view. ``NULL_TRACER`` is the shared no-op used when obs is off.

Memory is bounded on request: ``Tracer(max_events=N)`` keeps the N most
recent records in a drop-oldest :class:`Ring` (the same ring the flight
recorder uses) and counts evictions in :attr:`Tracer.dropped_events` —
long soak runs stop growing the event list without losing the recent
window that matters for a post-mortem. The default stays unbounded (short
benchmark runs export their complete trace).

Stdlib-only module: ``jax`` is imported lazily inside ``_block`` so the
obs package itself stays dependency-free (and so does every unit test of
the tracer).
"""

from __future__ import annotations

import collections
import json
import threading
import time

__all__ = ["Ring", "Span", "Tracer", "NULL_TRACER", "null_tracer",
           "chrome_events"]


class Ring:
    """Bounded drop-oldest buffer with an exact eviction counter.

    The fixed-memory primitive shared by the bounded tracer and the
    flight recorder: pushes never fail, the oldest item falls out once
    ``capacity`` is reached, and ``dropped`` counts exactly how many
    items the window no longer holds. ``capacity=None`` is unbounded.
    """

    __slots__ = ("capacity", "dropped", "_items")

    def __init__(self, capacity: int | None = None):
        if capacity is not None and capacity < 1:
            raise ValueError(f"ring capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.dropped = 0
        self._items: collections.deque = collections.deque(maxlen=capacity)

    def push(self, item) -> None:
        if self.capacity is not None and len(self._items) == self.capacity:
            self.dropped += 1
        self._items.append(item)

    def items(self) -> list:
        return list(self._items)

    def __len__(self) -> int:
        return len(self._items)

    def clear(self) -> None:
        self._items.clear()
        self.dropped = 0


def _block(obj) -> None:
    """Resolve a sync target: call it if callable, then block on it."""
    if obj is None:
        return
    if callable(obj):
        obj = obj()
    if obj is None:
        return
    import jax

    jax.block_until_ready(obj)


class Span:
    """Context manager for one timed region. Created via Tracer.span."""

    __slots__ = ("tracer", "name", "sync", "args", "t0", "depth", "parent")

    def __init__(self, tracer: "Tracer", name: str, sync, args: dict):
        self.tracer = tracer
        self.name = name
        self.sync = sync
        self.args = args
        self.t0 = 0.0
        self.depth = 0
        self.parent = None

    def __enter__(self) -> "Span":
        stack = self.tracer._stack()
        self.depth = len(stack)
        self.parent = stack[-1].name if stack else None
        stack.append(self)
        # Sync on entry too, so pending work dispatched *before* the span
        # is not billed to it. Entry sync reuses the same target: by the
        # time the span opens the target usually doesn't exist yet, so
        # callers pass a callable or rely on the default (None = no sync).
        self.t0 = time.perf_counter()
        return self

    def set_sync(self, sync) -> None:
        """Install/replace the exit sync target from inside the span."""
        self.sync = sync

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None:
            _block(self.sync)
        t1 = time.perf_counter()
        stack = self.tracer._stack()
        if stack and stack[-1] is self:
            stack.pop()
        self.tracer._emit(self, t1)


class Tracer:
    """Collects spans and instant events; exports JSONL / Chrome JSON.

    ``max_events`` bounds the retained records (drop-oldest);
    ``drop_counter`` is an optional counter-like object (``.inc()``)
    bumped once per evicted record — the ``trace.dropped_events``
    registry counter when wired through :class:`repro.obs.Obs`. Sinks
    registered via :meth:`add_sink` see every record as it completes
    (the flight recorder taps the stream this way) regardless of what
    the ring later evicts.
    """

    def __init__(self, max_events: int | None = None,
                 drop_counter=None) -> None:
        self._events = Ring(max_events)
        self._drop_counter = drop_counter
        self._sinks: list = []
        self._local = threading.local()
        self._lock = threading.Lock()
        self._epoch = time.perf_counter()

    @property
    def dropped_events(self) -> int:
        """Records evicted by the ``max_events`` bound so far."""
        return self._events.dropped

    def add_sink(self, fn) -> None:
        """Register ``fn(record)`` to observe every completed record."""
        self._sinks.append(fn)

    def set_drop_counter(self, counter) -> None:
        self._drop_counter = counter

    def _append(self, rec: dict) -> None:
        with self._lock:
            before = self._events.dropped
            self._events.push(rec)
            if self._events.dropped != before \
                    and self._drop_counter is not None:
                self._drop_counter.inc()
        for fn in self._sinks:
            fn(rec)

    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def span(self, name: str, sync=None, **args) -> Span:
        """Open a nested span. ``sync`` is blocked on at exit (see module
        docstring); ``args`` become structured payload on the record."""
        return Span(self, name, sync, args)

    def event(self, name: str, **args) -> None:
        """Zero-duration structured event at the current nesting level."""
        stack = self._stack()
        rec = {
            "name": name,
            "ts": time.perf_counter() - self._epoch,
            "dur": 0.0,
            "depth": len(stack),
            "parent": stack[-1].name if stack else None,
            "tid": threading.get_ident(),
            "args": args,
        }
        self._append(rec)

    def _emit(self, span: Span, t1: float) -> None:
        rec = {
            "name": span.name,
            "ts": span.t0 - self._epoch,
            "dur": t1 - span.t0,
            "depth": span.depth,
            "parent": span.parent,
            "tid": threading.get_ident(),
            "args": span.args,
        }
        self._append(rec)

    # -- export ----------------------------------------------------------

    def records(self) -> list[dict]:
        """Completed records, ordered by start time."""
        with self._lock:
            return sorted(self._events.items(), key=lambda r: r["ts"])

    def dump_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            for rec in self.records():
                f.write(json.dumps(rec) + "\n")

    def chrome_events(self) -> list[dict]:
        """Chrome trace_event list: "X" complete events (+instants)."""
        return chrome_events(self.records())

    def dump_chrome(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump({"traceEvents": self.chrome_events(),
                       "displayTimeUnit": "ms"}, f)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
        self._epoch = time.perf_counter()


def chrome_events(records: list[dict]) -> list[dict]:
    """Tracer-record list -> Chrome trace_event list (shared with the
    flight recorder, whose ring holds records of the same schema)."""
    out = []
    for rec in records:
        ev = {
            "name": rec["name"],
            "cat": rec["parent"] or "root",
            "pid": 1,
            "tid": rec["tid"],
            "ts": rec["ts"] * 1e6,
            "args": rec["args"],
        }
        if rec["dur"] > 0.0:
            ev["ph"] = "X"
            ev["dur"] = rec["dur"] * 1e6
        else:
            ev["ph"] = "i"
            ev["s"] = "t"
        out.append(ev)
    return out


class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return None

    def set_sync(self, sync) -> None:
        pass


_NULL_SPAN = _NullSpan()


class NullTracer:
    """No-op tracer. span() skips even the sync (obs-off must not add
    device blocking that obs-on placed deliberately at span edges)."""

    dropped_events = 0

    def span(self, name, sync=None, **args):
        return _NULL_SPAN

    def event(self, name, **args) -> None:
        pass

    def add_sink(self, fn) -> None:
        pass

    def set_drop_counter(self, counter) -> None:
        pass

    def records(self) -> list:
        return []

    def chrome_events(self) -> list:
        return []

    def dump_jsonl(self, path) -> None:
        pass

    def dump_chrome(self, path) -> None:
        pass

    def clear(self) -> None:
        pass


NULL_TRACER = NullTracer()


def null_tracer() -> NullTracer:
    return NULL_TRACER
