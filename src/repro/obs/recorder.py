"""Flight recorder: always-on bounded rings + fault-edge auto-dump.

A production peer cannot run with an unbounded tracer, but the run that
matters most — the one that hits a fault — is exactly the run whose last
seconds you want on disk. The flight recorder keeps fixed-memory
drop-oldest rings (:class:`repro.obs.trace.Ring` — the same machinery
that bounds the tracer) of:

  * recent span/event records (tapped from the engine tracer as a sink,
    so the recorder's window survives the tracer's own eviction);
  * recent full tx lifecycles (fed by :mod:`repro.obs.txtrace`);
  * periodic registry snapshots (one per engine round, last few kept).

Evictions are counted, never silent (``dropped`` per ring, surfaced in
the dump's ``meta.json``).

``dump(dir)`` writes the whole window as a self-contained post-mortem:

  * ``trace.jsonl``       — the ring's records, one JSON object/line;
  * ``trace_chrome.json`` — the same window as Chrome trace_event JSON;
  * ``metrics.json``      — the freshest registry snapshot (plus the
    periodic snapshot ring, so rate-of-change is reconstructible);
  * ``lifecycles.json``   — the last-N complete tx lifecycles;
  * ``meta.json``         — trip reasons/contexts, ring drop counters.

The engine trips the recorder automatically on its fault edges —
``verify()`` contract failure, a NEW sticky overflow latch, a resize
refusal, an exception escaping ``run_rounds`` — and the trip auto-dumps
when a dump directory is configured (``EngineConfig.recorder_dir``);
without one the trip is still recorded (ring note + trip log) and
``dump()`` stays available manually.

Stdlib-only (json/os/threading/time), like the rest of repro.obs.
"""

from __future__ import annotations

import json
import os
import threading
import time

from .trace import NullTracer, Ring, chrome_events

__all__ = ["FlightRecorder"]


def _jsonable(obj):
    """Best-effort plain-JSON coercion for trip contexts / exemplars."""
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, (str, int, float, bool)) or obj is None:
        return obj
    try:
        return int(obj)  # numpy scalars
    except (TypeError, ValueError):
        return repr(obj)


class FlightRecorder:
    """Bounded always-on recorder with fault-edge auto-dump."""

    def __init__(self, *, capacity: int = 2048,
                 lifecycle_capacity: int = 64,
                 snapshot_capacity: int = 8,
                 dump_dir: str | None = None,
                 registry=None):
        self.spans = Ring(capacity)
        self.lifecycles = Ring(lifecycle_capacity)
        self.snapshots = Ring(snapshot_capacity)
        self.dump_dir = dump_dir
        self.registry = registry
        self.trips: list[dict] = []
        self._lock = threading.Lock()
        self._epoch = time.perf_counter()

    # -- feeds --------------------------------------------------------------

    def attach(self, tracer) -> None:
        """Tap ``tracer`` as a record sink (no-op for the null tracer:
        obs-off engines keep their no-sync contract; the recorder then
        holds only explicit notes + lifecycles)."""
        if not isinstance(tracer, NullTracer):
            tracer.add_sink(self._on_record)

    def _on_record(self, rec: dict) -> None:
        with self._lock:
            self.spans.push(rec)

    def record_lifecycle(self, lc: dict) -> None:
        with self._lock:
            self.lifecycles.push(lc)

    def snapshot_registry(self) -> None:
        """Push one periodic metrics snapshot (engine calls per round)."""
        if self.registry is None:
            return
        snap = {"ts": time.perf_counter() - self._epoch,
                "metrics": self.registry.collect()}
        with self._lock:
            self.snapshots.push(snap)

    def note(self, name: str, **args) -> None:
        """Instant event straight into the span ring (works obs-off)."""
        rec = {"name": name, "ts": time.perf_counter() - self._epoch,
               "dur": 0.0, "depth": 0, "parent": None,
               "tid": threading.get_ident(), "args": _jsonable(args)}
        with self._lock:
            self.spans.push(rec)

    # -- fault edges --------------------------------------------------------

    def trip(self, reason: str, **ctx) -> str | None:
        """One fault edge fired: log it, and auto-dump when a dump dir is
        configured. Returns the dump path (or None)."""
        self.note(f"flightrec.trip.{reason}", **ctx)
        self.trips.append({
            "reason": reason, "ctx": _jsonable(ctx),
            "ts": time.perf_counter() - self._epoch,
        })
        if self.dump_dir is not None:
            return self.dump(self.dump_dir)
        return None

    @property
    def tripped(self) -> bool:
        return bool(self.trips)

    # -- dump ---------------------------------------------------------------

    def dump(self, out_dir: str) -> str:
        """Write the current window to ``out_dir`` (created if needed);
        later dumps overwrite with a fresher window. Returns the dir."""
        os.makedirs(out_dir, exist_ok=True)
        with self._lock:
            spans = sorted(self.spans.items(), key=lambda r: r["ts"])
            lifecycles = self.lifecycles.items()
            snapshots = self.snapshots.items()
            meta = {
                "trips": list(self.trips),
                "dropped": {
                    "spans": self.spans.dropped,
                    "lifecycles": self.lifecycles.dropped,
                    "snapshots": self.snapshots.dropped,
                },
                "counts": {
                    "spans": len(spans), "lifecycles": len(lifecycles),
                    "snapshots": len(snapshots),
                },
            }
        with open(os.path.join(out_dir, "trace.jsonl"), "w") as f:
            for rec in spans:
                f.write(json.dumps(_jsonable(rec)) + "\n")
        with open(os.path.join(out_dir, "trace_chrome.json"), "w") as f:
            json.dump({"traceEvents": _jsonable(chrome_events(spans)),
                       "displayTimeUnit": "ms"}, f)
        metrics = {
            "latest": (self.registry.collect()
                       if self.registry is not None else {}),
            "periodic": snapshots,
        }
        with open(os.path.join(out_dir, "metrics.json"), "w") as f:
            json.dump(_jsonable(metrics), f, indent=1)
        with open(os.path.join(out_dir, "lifecycles.json"), "w") as f:
            json.dump(_jsonable(lifecycles), f, indent=1)
        with open(os.path.join(out_dir, "meta.json"), "w") as f:
            json.dump(_jsonable(meta), f, indent=1)
        return out_dir
