"""Serving engine: continuous batching on FastFabric principles.

Paper mapping (DESIGN.md §5):
  * O-I  metadata-plane scheduling — admission control orders fixed-width
    request IDs only (core.orderer.consensus_order); prompt payloads stay in
    the local queue and are joined back at slot-assignment time.
  * P-I  world state — the slot table is the core in-memory hash table:
    key = request id, value = (slot, steps, done), version-bumped on every
    transition. Exactly-once slot commit is checked the way MVCC checks
    read/write versions.
  * P-II role separation — prefill (endorser) and decode (committer) are
    separate jit programs; on the production mesh they run on disjoint
    mesh slices (launch/serve.py), here sequentially on one device.
  * P-III decode-once — prompts are tokenized/prefilled exactly once; the
    KV cache slot is the unmarshal-cache analogue (cyclic slot reuse, a
    slot is only overwritten after its request retires).

The engine is CPU-runnable with smoke configs (examples/fabric_serve.py)
and lowers for the production mesh via launch/serve.py.
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing, orderer
from repro.core import world_state as ws
from repro.models import layers
from repro.models.lm import LM, Batch, DecodeCache
from repro.obs import health as health_mod
from repro.obs.metrics import Registry

U32 = jnp.uint32


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (P,) int32
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


# ---------------------------------------------------------------------------
# Batched decode with per-slot positions (continuous batching core).
# ---------------------------------------------------------------------------


def decode_step_slots(model: LM, params, cache: DecodeCache,
                      token: jnp.ndarray, pos_b: jnp.ndarray,
                      active: jnp.ndarray):
    """One decode step with per-slot positions.

    token (B,) i32; pos_b (B,) i32 — each slot's current length; active (B,)
    bool — inactive slots compute but commit nothing (their cache rows are
    masked out of the scatter), the Fabric invalid-tx-stays-in-block rule.
    Dense/MoE families only (recurrent families have no position concept
    beyond the state itself).
    """
    cfg = model.cfg
    x = layers.embed(params["embed"], token)[:, None, :]
    bsz = token.shape[0]
    brange = jnp.arange(bsz)

    def body(x, inp):
        lp, ck, cv = inp
        nrm = layers.rmsnorm(lp["norm1"], x, cfg.norm_eps)
        h_dim = cfg.n_heads * cfg.head_dim

        def proj(w, b, nh):
            y = nrm @ w.astype(nrm.dtype)
            if b is not None:
                y = y + b.astype(y.dtype)
            return y.reshape(bsz, 1, nh, cfg.head_dim)

        q = proj(lp["attn"]["wq"], lp["attn"].get("bq"), cfg.n_heads)
        k = proj(lp["attn"]["wk"], lp["attn"].get("bk"), cfg.n_kv)
        v = proj(lp["attn"]["wv"], lp["attn"].get("bv"), cfg.n_kv)
        if cfg.qk_norm:
            q = layers.rmsnorm(lp["attn"]["q_norm"], q, cfg.norm_eps)
            k = layers.rmsnorm(lp["attn"]["k_norm"], k, cfg.norm_eps)
        q = layers.apply_rope(q, pos_b[:, None], cfg.rope_theta)
        k = layers.apply_rope(k, pos_b[:, None], cfg.rope_theta)

        # Per-slot scatter of the new K/V row (masked for inactive slots).
        upd_k = jnp.where(active[:, None, None], k[:, 0], ck[brange, pos_b])
        upd_v = jnp.where(active[:, None, None], v[:, 0], cv[brange, pos_b])
        ck = ck.at[brange, pos_b].set(upd_k.astype(ck.dtype))
        cv = cv.at[brange, pos_b].set(upd_v.astype(cv.dtype))

        # Attention over each slot's prefix (mask by per-slot position).
        smax = ck.shape[1]
        mask = jnp.arange(smax)[None, :] <= pos_b[:, None]  # (B, S)
        hkv = cfg.n_kv
        g = cfg.n_heads // hkv
        qg = q.reshape(bsz, 1, hkv, g, cfg.head_dim).astype(jnp.float32)
        scores = jnp.einsum(
            "bqhgd,bkhd->bhgqk", qg, ck.astype(jnp.float32)
        ) / jnp.sqrt(jnp.float32(cfg.head_dim))
        scores = jnp.where(mask[:, None, None, None, :], scores, -jnp.inf)
        probs = jax.nn.softmax(scores, axis=-1)
        att = jnp.einsum("bhgqk,bkhd->bqhgd", probs,
                         cv.astype(jnp.float32))
        att = att.reshape(bsz, 1, h_dim).astype(x.dtype)
        x = x + att @ lp["attn"]["wo"].astype(x.dtype)

        mlp_in = layers.rmsnorm(lp["norm2"], x, cfg.norm_eps)
        if "moe" in lp:
            from repro.models import moe as moe_mod
            y, _ = moe_mod.moe_mlp(lp["moe"], cfg, mlp_in,
                                   capacity_factor=model.moe_cf)
        else:
            y = layers.mlp(lp["mlp"], mlp_in)
        return x + y, (ck, cv)

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache.k, cache.v))
    cache = dataclasses.replace(cache, k=ks, v=vs)
    x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = layers.unembed(table, x, transpose=True)[:, 0][:, : cfg.vocab]
    return logits, cache


def insert_prefill(cache: DecodeCache, slot_cache: DecodeCache,
                   slot: int) -> DecodeCache:
    """Copy a single-request prefill cache (B=1) into batch slot ``slot``."""
    def ins(big, small):
        if big is None:
            return None
        # (L, B, S, H, D) <- (L, 1, Sp, H, D) at [:, slot, :Sp]
        pad = big.shape[2] - small.shape[2]
        smallp = jnp.pad(
            small, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))
        )
        return big.at[:, slot].set(smallp[:, 0].astype(big.dtype))

    return dataclasses.replace(
        cache, k=ins(cache.k, slot_cache.k), v=ins(cache.v, slot_cache.v)
    )


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------


class ServeEngine:
    """Slot-based continuous batching with fabric-style bookkeeping."""

    def __init__(self, model: LM, params, *, slots: int = 4,
                 max_len: int = 256, greedy: bool = True,
                 registry: Registry | None = None):
        self.model = model
        self.params = params
        self.n_slots = slots
        self.max_len = max_len
        self.greedy = greedy
        # Metrics sink (repro.obs): admission-queue depth, active slots,
        # decode-step latency, token/request counters. Always a REAL
        # registry — serving stats are cheap host-side bookkeeping, and
        # stats_text() should work out of the box.
        self.registry = registry if registry is not None else Registry()
        self.cache = model.init_cache(slots, max_len)
        self.pos = np.zeros((slots,), np.int32)
        self.slot_req: list[Optional[Request]] = [None] * slots
        self.queue: list[Request] = []
        # P-I world state: request ledger (rid -> slot/steps), versioned.
        self.state = ws.create(n_buckets=256, slots=8, value_width=4)
        self.decode_fn = jax.jit(
            partial(decode_step_slots, self.model), donate_argnums=(1,)
        )
        self.prefill_fn = jax.jit(self.model.prefill)
        self.steps = 0
        self.tokens_out = 0

    # ---- fabric bookkeeping ----

    def _rid_key(self, rid: int) -> jnp.ndarray:
        h1, h2 = hashing.hash_pair(jnp.uint32(rid))
        return jnp.stack([hashing.nonzero_key(h1), h2])[None]  # (1, 2)

    def _commit_state(self, rid: int, slot: int, steps: int, done: int):
        val = jnp.asarray([[slot, steps, done, 0]], U32)
        res = ws.commit_vectorized(
            self.state, self._rid_key(rid)[:, None, :], val[:, None, :],
            jnp.ones((1,), bool),
        )
        self.state = res.state

    def request_version(self, rid: int) -> int:
        return int(ws.lookup(self.state, self._rid_key(rid)).versions[0])

    # ---- admission (O-I): order IDs, payloads join at assignment ----

    def submit(self, requests: list[Request]) -> None:
        ids = jnp.asarray(
            [hashing.hash_pair(jnp.uint32(r.rid)) for r in requests],
            U32,
        ).reshape(len(requests), 2)
        order = np.asarray(orderer.consensus_order(ids))
        self.queue.extend(requests[i] for i in order)
        self.registry.counter("serving.requests.submitted").inc(len(requests))
        self.registry.gauge("serving.queue.depth").set(len(self.queue))

    # ---- scheduling loop ----

    def _assign_free_slots(self) -> None:
        for s in range(self.n_slots):
            if self.slot_req[s] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            prompt = jnp.asarray(req.prompt, jnp.int32)[None]
            small = self.model.init_cache(1, int(prompt.shape[1]))
            logits, small = self.prefill_fn(
                self.params, Batch(tokens=prompt), small
            )
            self.cache = insert_prefill(self.cache, small, s)
            tok = int(jnp.argmax(logits[0][: self.model.cfg.vocab]))
            req.out.append(tok)
            self.slot_req[s] = req
            self.pos[s] = len(req.prompt)
            self._commit_state(req.rid, s, 1, 0)
            self.registry.counter("serving.prefills").inc()
            self.registry.gauge("serving.queue.depth").set(len(self.queue))

    def step(self) -> int:
        """One engine step: assign slots, one batched decode. Returns the
        number of active slots."""
        self._assign_free_slots()
        active_mask = np.asarray(
            [r is not None and not r.done for r in self.slot_req]
        )
        self.registry.gauge("serving.slots.active").set(
            int(active_mask.sum())
        )
        if not active_mask.any():
            return 0
        t0 = time.perf_counter()
        last_tok = np.asarray(
            [(r.out[-1] if r is not None and r.out else 0)
             for r in self.slot_req], np.int32,
        )
        logits, self.cache = self.decode_fn(
            self.params, self.cache, jnp.asarray(last_tok),
            jnp.asarray(self.pos), jnp.asarray(active_mask),
        )
        nxt = np.asarray(jnp.argmax(logits, axis=-1))  # syncs the step
        self.registry.histogram("serving.decode.latency").record(
            time.perf_counter() - t0
        )
        self.steps += 1
        for s, r in enumerate(self.slot_req):
            if r is None or not active_mask[s]:
                continue
            r.out.append(int(nxt[s]))
            self.pos[s] += 1
            self.tokens_out += 1
            if (len(r.out) >= r.max_new
                    or self.pos[s] >= self.max_len - 1):
                r.done = True
                self._commit_state(r.rid, s, len(r.out), 1)
                self.slot_req[s] = None  # slot freed (cyclic reuse)
                self.registry.counter("serving.requests.completed").inc()
        self.registry.counter("serving.tokens.out").inc(
            int(active_mask.sum())
        )
        return int(active_mask.sum())

    def run(self, requests: list[Request], *, max_steps: int = 10_000
            ) -> list[Request]:
        self.submit(requests)
        for _ in range(max_steps):
            if not self.step() and not self.queue:
                break
        return requests

    # ---- observability ----

    def metrics(self) -> dict:
        """Flat snapshot of the serving metrics (repro.obs collect)."""
        return self.registry.collect()

    def stats_text(self) -> str:
        """Prometheus text exposition of the serving metrics — the scrape
        endpoint body for an HTTP wrapper (or a log line for smoke runs)."""
        return self.registry.to_prometheus()

    def health(self, *, decode_p95_s: float = 1.0,
               max_queue_depth: int = 1024) -> health_mod.HealthVerdict:
        """Serving-side SLO verdict (repro.obs.health statuses) — the
        signal a backpressure front end (ROADMAP item 1) keys admission
        on: decode p95 latency against ``decode_p95_s`` (degraded when
        over) and admission-queue depth against ``max_queue_depth``
        (degraded when over, critical past double — requests are piling
        up faster than slots retire them). Mirrors the verdict onto a
        ``serving.health`` gauge for :meth:`stats_text`."""
        status = health_mod.HEALTHY
        reasons: list[str] = []
        p95 = self.registry.histogram("serving.decode.latency").percentile(95)
        if p95 == p95 and p95 != float("inf") and p95 > decode_p95_s:
            status = health_mod.DEGRADED
            reasons.append(
                f"decode p95 {p95:.3f}s over objective {decode_p95_s}s"
            )
        depth = len(self.queue)
        if depth > 2 * max_queue_depth:
            status = health_mod.CRITICAL
            reasons.append(
                f"queue depth {depth} past 2x limit {max_queue_depth} "
                "(admission outrunning retirement)"
            )
        elif depth > max_queue_depth:
            if status == health_mod.HEALTHY:
                status = health_mod.DEGRADED
            reasons.append(
                f"queue depth {depth} over limit {max_queue_depth}"
            )
        self.registry.gauge("serving.health").set(
            health_mod.STATUS_RANK[status]
        )
        return health_mod.HealthVerdict(
            status=status, reasons=reasons,
            channels={0: {"status": status, "reasons": reasons}},
        )


# ---------------------------------------------------------------------------
# Contract-analyzer registration (repro.analysis): the batched decode
# step exactly as ServeEngine jits it (same partial, same cache
# donation), lowered on a smoke model so the gate compiles in seconds.
# ---------------------------------------------------------------------------

from repro.analysis import registry as _areg  # noqa: E402


@_areg.register(
    "serving/decode_step",
    description="slot-batched decode step with per-slot positions",
)
def _build_decode_step(ctx):
    from repro.configs import base as cfg_base
    from repro.launch import specs

    cfg = cfg_base.get_smoke("qwen2-7b")
    model = LM(cfg, vocab_chunk=8)
    slots, max_len = 2, 16
    params = specs.param_shapes(model)
    cache = jax.eval_shape(lambda: model.init_cache(slots, max_len))
    fn = jax.jit(partial(decode_step_slots, model), donate_argnums=(1,))
    sd = jax.ShapeDtypeStruct
    args = (
        params, cache,
        sd((slots,), jnp.int32),  # token
        sd((slots,), jnp.int32),  # pos_b
        sd((slots,), jnp.bool_),  # active
    )
    return _areg.BuiltProgram(
        name="serving/decode_step", fn=fn, args=args, donate_argnums=(1,),
        meta={"arch": "qwen2-7b-smoke", "slots": slots,
              "max_len": max_len},
    )
