"""Training step: CE loss -> grads -> AdamW, with the FastFabric
endorse->order->commit pipeline applied to gradient blocks.

Paper integration (DESIGN.md §5): a microbatch's gradient is a
*transaction* —
  endorse  — per-microbatch finiteness + norm checks ("business rules"),
             plus a content digest (the MAC analogue) for the audit chain;
  order    — microbatches are combined in a deterministic order (the scan),
             so every replica commits the same update: the optimizer state
             is the world state;
  commit   — AdamW applies only endorsed microbatches; a failed endorsement
             (NaN/inf from a bad node) is *flagged and skipped* without
             stalling the step — Fabric's invalid-transaction semantics —
             and the step digest is chained into a ledger head that
             checkpoints verify against.

``make_train_step`` builds the jit-able function; grad accumulation is a
lax.scan over microbatches (activation memory ~ one microbatch).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import hashing, ledger
from repro.models.lm import LM, Batch
from repro.training import optimizer


class TrainState(NamedTuple):
    params: Any
    opt: optimizer.AdamWState
    ledger_head: jnp.ndarray  # (2,) u32 — chained step digests


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: optimizer.AdamWConfig = optimizer.AdamWConfig()
    clip_norm: float = 1.0
    microbatches: int = 1  # grad accumulation steps (endorse per microbatch)
    endorse_grads: bool = True  # finite-check each microbatch (fabric mode)
    accum_dtype: str = "float32"  # grad-accumulator dtype (bf16 for the
    # biggest archs: halves the accumulator footprint; see launch/dryrun.py)


def init_state(model: LM, key) -> TrainState:
    params = model.init(key)
    return TrainState(
        params=params,
        opt=optimizer.init(params),
        ledger_head=jnp.zeros((2,), jnp.uint32),
    )


def grad_digest(grads) -> jnp.ndarray:
    """Cheap content digest of a gradient pytree, (2,) u32.

    Hashes per-leaf f32 sums (bitcast) — an integrity stamp for the ledger
    chain, not a cryptographic commitment (crypto cost model lives in
    core.crypto).
    """
    sums = jnp.stack(
        [jnp.sum(g.astype(jnp.float32)) for g in jax.tree.leaves(grads)]
    )
    words = jax.lax.bitcast_convert_type(sums, jnp.uint32)[None, :]
    return jnp.stack([
        hashing.hash_words(words, seed=hashing.SEED_A)[0],
        hashing.hash_words(words, seed=hashing.SEED_B)[0],
    ])


def _split_batch(batch: Batch, n: int) -> Batch:
    """(B, ...) -> (n, B/n, ...) for scan over microbatches."""
    def r(x):
        if x is None:
            return None
        b = x.shape[0]
        return x.reshape(n, b // n, *x.shape[1:])

    return Batch(tokens=r(batch.tokens), labels=r(batch.labels),
                 prefix_embeds=r(batch.prefix_embeds),
                 enc_embeds=r(batch.enc_embeds))


def _index_batch(batch: Batch, i) -> Batch:
    g = lambda x: None if x is None else x[i]
    return Batch(tokens=g(batch.tokens), labels=g(batch.labels),
                 prefix_embeds=g(batch.prefix_embeds),
                 enc_embeds=g(batch.enc_embeds))


def make_train_step(model: LM, cfg: TrainConfig) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics). jit-able."""

    def loss_fn(params, mb: Batch):
        loss, metrics = model.loss(params, mb)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def endorse(grads, loss):
        """Per-microbatch endorsement: all-finite AND loss finite."""
        finite = jnp.isfinite(loss)
        for g in jax.tree.leaves(grads):
            finite = finite & jnp.all(jnp.isfinite(g))
        return finite

    def train_step(state: TrainState, batch: Batch):
        n_mb = cfg.microbatches
        if n_mb == 1:
            (loss, metrics), grads = grad_fn(state.params, batch)
            ok = (endorse(grads, loss) if cfg.endorse_grads
                  else jnp.asarray(True))
            n_ok = ok.astype(jnp.float32)
            grads = jax.tree.map(
                lambda g: jnp.where(ok, g, jnp.zeros_like(g)), grads
            )
        else:
            mbs = _split_batch(batch, n_mb)
            acc_dt = jnp.dtype(cfg.accum_dtype)

            def body(carry, i):
                acc, loss_acc, nok = carry
                mb = _index_batch(mbs, i)
                (loss, _), grads = grad_fn(state.params, mb)
                ok = (endorse(grads, loss) if cfg.endorse_grads
                      else jnp.asarray(True))
                okf = ok.astype(jnp.float32)
                acc = jax.tree.map(
                    lambda a, g: a + jnp.where(ok, g, 0).astype(acc_dt),
                    acc, grads,
                )
                return (acc, loss_acc + okf * loss, nok + okf), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), state.params
            )
            (grads, loss_sum, n_ok), _ = jax.lax.scan(
                body, (zeros, jnp.float32(0), jnp.float32(0)),
                jnp.arange(n_mb),
            )
            denom = jnp.maximum(n_ok, 1.0)
            grads = jax.tree.map(
                lambda g: (g / denom.astype(g.dtype)).astype(g.dtype), grads
            )
            loss = loss_sum / denom
            metrics = {"ce": loss}

        grads, gnorm = optimizer.clip_by_global_norm(grads, cfg.clip_norm)
        # Commit: skip the whole block only if *no* microbatch endorsed.
        skip = n_ok < 0.5
        params, opt, lr = optimizer.apply(
            cfg.opt, state.opt, state.params, grads, skip=skip
        )
        # Ledger append: chain the step digest (audit for checkpoints).
        digest = grad_digest(grads)
        head = ledger.append_hash(
            state.ledger_head, state.opt.step.astype(jnp.uint32), digest
        )
        out_metrics = {
            "loss": loss,
            "grad_norm": gnorm,
            "lr": lr,
            "endorsed_mb": n_ok,
            "skipped": skip.astype(jnp.int32),
        }
        out_metrics.update(
            {k: v for k, v in metrics.items() if k not in out_metrics}
        )
        return TrainState(params, opt, head), out_metrics

    return train_step
