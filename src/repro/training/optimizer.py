"""AdamW from scratch (no optax): f32 moments, decoupled weight decay.

Moments are f32 regardless of param dtype; the update upcasts params to
f32, applies the step, and casts back — the production pattern when bf16
params are trained without a separate master copy. Under the launcher the
moment pytrees take the *param* sharding plus an extra ZeRO-1 shard over
the ``data`` axis (see launch/sharding.py) when enabled.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray  # () i32
    m: dict
    v: dict


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup + cosine decay to min_lr_frac."""
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (s - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog)
    )
    return cfg.lr * jnp.minimum(warm, cos)


def global_norm(tree) -> jnp.ndarray:
    sq = jax.tree.map(
        lambda g: jnp.sum(g.astype(jnp.float32) ** 2), tree
    )
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq, jnp.float32(0)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), tree), norm


def apply(cfg: AdamWConfig, state: AdamWState, params, grads,
          *, skip: jnp.ndarray | None = None):
    """One AdamW step. ``skip``: () bool — when True (e.g. non-finite grads
    detected by the grad-commit pipeline) moments and params pass through
    unchanged but the step counter still advances.
    """
    step = state.step + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    no_skip = (jnp.logical_not(skip) if skip is not None
               else jnp.asarray(True))

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * gf
        v2 = b2 * v + (1 - b2) * gf * gf
        mhat = m2 / bc1
        vhat = v2 / bc2
        pf = p.astype(jnp.float32)
        step_vec = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * pf
        p2 = pf - lr * step_vec
        keep = no_skip
        return (
            jnp.where(keep, p2, pf).astype(p.dtype),
            jnp.where(keep, m2, m),
            jnp.where(keep, v2, v),
        )

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v), lr
