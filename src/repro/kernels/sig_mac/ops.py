"""Jit'd dispatch for the endorsement-MAC kernel (Pallas on TPU, ref on CPU)."""

from __future__ import annotations

import jax

from repro.kernels.sig_mac import kernel, ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def mac_many(msg, rs, ss, *, use_pallas: bool | None = None):
    """(B, W) messages x (NE,) keys -> (B, NE) tags."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return kernel.mac_many(msg, rs, ss, interpret=not _on_tpu())
    return ref.mac_many_ref(msg, rs, ss)
