"""Pure-jnp oracle for the endorsement-MAC kernel (repro.core.crypto)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import crypto


def mac_ref(msg, r, s):
    """(B, W) u32 messages -> (B,) u32 tags under key (r, s)."""
    return crypto.poly_mac(msg, r, s)


def mac_many_ref(msg, rs, ss):
    """(B, W) x (NE,) keys -> (B, NE) tags."""
    tags = [crypto.poly_mac(msg, rs[e], ss[e]) for e in range(rs.shape[0])]
    return jnp.stack(tags, axis=1)
