"""Pallas TPU kernel for the Carter-Wegman endorsement MAC.

The endorsement-policy check (§III-H) verifies every transaction's tags on
the critical path. The MAC is a degree-W polynomial over GF(2^31-1)
evaluated by Horner's rule: sequential in W (the polynomial chain) but
embarrassingly parallel across transactions — the kernel maps transactions
to VPU lanes and walks the message words with a fori_loop, all operands
VMEM-resident.

Mersenne-31 modular multiply uses 16-bit limb decomposition (see
repro.core.crypto): TPUs have no 64-bit integer units, so 32x32 products
are assembled from 16x16 partials that each fit u32 — every op here is a
native VPU u32 op.

Block shape: (TB, W) message tiles; all NE endorser keys are verified in
one pass per tile (grid = tx tiles x endorsers).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

U32 = jnp.uint32


def _mod31(x):
    p = jnp.uint32((1 << 31) - 1)
    x = (x & p) + (x >> 31)
    x = (x & p) + (x >> 31)
    return jnp.where(x == p, jnp.uint32(0), x)


def _addmod31(a, b):
    return _mod31(a + b)


def _mulmod31(a, b):
    m16 = jnp.uint32(0xFFFF)
    m15 = jnp.uint32(0x7FFF)
    ah, al = a >> 16, a & m16
    bh, bl = b >> 16, b & m16
    hi2 = _mod31((ah * bh) << 1)  # *2^32 == *2 (mod p)

    def shift16(x):  # (x * 2^16) mod p for x < 2^31
        x = _mod31(x)
        return _mod31(((x & m15) << 16) + (x >> 15))

    mid = _addmod31(shift16(ah * bl), shift16(al * bh))
    lo = _mod31(al * bl)
    return _addmod31(_addmod31(hi2, mid), lo)


def _mac_kernel(msg_ref, r_ref, s_ref, tag_ref):
    """msg (TB, W); r/s scalars for this endorser (SMEM); tag (TB, 1)."""
    tb, w = msg_ref.shape
    r = r_ref[0]
    s = s_ref[0]

    def body(i, acc):
        m = _mod31(msg_ref[:, i])
        return _addmod31(_mulmod31(acc, jnp.full((tb,), r)), m)

    acc = jax.lax.fori_loop(0, w, body, jnp.zeros((tb,), U32))
    tag_ref[:, 0] = _addmod31(acc, jnp.full((tb,), s))


@functools.partial(jax.jit, static_argnames=("tx_tile", "interpret"))
def mac_many(msg, rs, ss, *, tx_tile: int = 256, interpret: bool = True):
    """Tags for all endorsers: (B, W) x (NE,) -> (B, NE) u32."""
    b, w = msg.shape
    ne = rs.shape[0]
    pad = (-b) % tx_tile
    msgp = jnp.pad(msg, ((0, pad), (0, 0)))
    bp = msgp.shape[0]
    tags = pl.pallas_call(
        _mac_kernel,
        grid=(bp // tx_tile, ne),
        in_specs=[
            pl.BlockSpec((tx_tile, w), lambda i, e: (i, 0)),
            pl.BlockSpec((1,), lambda i, e: (e,)),
            pl.BlockSpec((1,), lambda i, e: (e,)),
        ],
        out_specs=pl.BlockSpec((tx_tile, 1), lambda i, e: (i, e)),
        out_shape=jax.ShapeDtypeStruct((bp, ne), U32),
        interpret=interpret,
    )(msgp, rs, ss)
    return tags[:b]
