"""Jit'd dispatch for the MVCC validation kernel (Pallas on TPU, ref on CPU)."""

from __future__ import annotations

import jax

from repro.kernels.mvcc_validate import kernel, ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def validate(read_keys, read_vers, write_keys, current_versions, ok0,
             *, use_pallas: bool | None = None):
    """Single-block validate: (B,RK,2),(B,RK),(B,WK,2),(B,RK),(B,) -> (B,)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return kernel.validate_blocks(
            read_keys[None], read_vers[None], write_keys[None],
            current_versions[None], ok0[None],
            interpret=not _on_tpu(),
        )[0]
    return ref.validate_ref(
        read_keys, read_vers, write_keys, current_versions, ok0
    )
